from repro.data.synthetic import (TokenStream, rmat_graph, recsys_events,
                                  uniform_graph)
from repro.data.graph_sampler import NeighborSampler
