"""Uniform fanout neighbor sampler (GraphSAGE-style) for minibatch GNN
training — the ``minibatch_lg`` input shape.

The sampler IS a one-level WCOJ prefix extension (DESIGN.md §4): seeds play
P_1, sampled neighbors are capped Proposals from the reverse/forward CSR —
the same ragged-expansion machinery as bigjoin's Proposal operator, with a
fanout cap instead of the intersection stage.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass
class SampledBlock:
    """One bipartite message-passing block (dst nodes <- sampled srcs)."""

    src_nodes: np.ndarray  # [n_src] global ids (superset of dst_nodes)
    dst_nodes: np.ndarray  # [n_dst] global ids
    edge_src: np.ndarray  # [n_edge] local indices into src_nodes
    edge_dst: np.ndarray  # [n_edge] local indices into dst_nodes


class NeighborSampler:
    def __init__(self, edges: np.ndarray, num_vertices: int):
        edges = np.asarray(edges, np.int64)
        order = np.lexsort((edges[:, 0], edges[:, 1]))  # sort by dst
        self.by_dst = edges[order]
        self.dst_off = np.searchsorted(self.by_dst[:, 1],
                                       np.arange(num_vertices + 1))
        self.num_vertices = num_vertices

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """For each node, <= fanout uniform in-neighbors.

        Returns (edge_src_global, edge_dst_global).
        """
        nodes = np.asarray(nodes, np.int64)
        start = self.dst_off[nodes]
        deg = self.dst_off[nodes + 1] - start
        take = np.minimum(deg, fanout)
        total = int(take.sum())
        row = np.repeat(np.arange(nodes.shape[0]), take)
        cum = np.concatenate([[0], np.cumsum(take)])
        k = np.arange(total) - cum[row]
        # uniform without replacement via random offsets when deg <= fanout,
        # else floyd-ish: random with replacement then dedup is acceptable
        # for fanout << deg; we use stride sampling with random phase for
        # determinism at scale.
        phase = rng.integers(0, np.maximum(deg, 1))[row]
        idx = (phase + (k * np.maximum(deg[row] // np.maximum(take[row], 1),
                                       1))) % np.maximum(deg[row], 1)
        pos = start[row] + idx
        src = self.by_dst[pos, 0]
        dst = nodes[row]
        return src.astype(np.int64), dst.astype(np.int64)

    def sample_blocks(self, seeds: np.ndarray, fanouts: List[int],
                      seed: int = 0) -> List[SampledBlock]:
        """Layered blocks, innermost-first (fanouts like [15, 10])."""
        rng = np.random.default_rng(seed)
        blocks: List[SampledBlock] = []
        dst = np.asarray(seeds, np.int64)
        for f in fanouts:
            es, ed = self.sample_neighbors(dst, f, rng)
            src_nodes = np.unique(np.concatenate([dst, es]))
            lookup = {int(v): i for i, v in enumerate(src_nodes)}
            edge_src = np.fromiter((lookup[int(v)] for v in es), np.int32,
                                   len(es))
            dlookup = {int(v): i for i, v in enumerate(dst)}
            edge_dst = np.fromiter((dlookup[int(v)] for v in ed), np.int32,
                                   len(ed))
            blocks.append(SampledBlock(src_nodes, dst, edge_src, edge_dst))
            dst = src_nodes  # next (outer) layer samples for these
        return blocks[::-1]  # outermost first for forward propagation
