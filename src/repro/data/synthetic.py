"""Synthetic data generators — deterministic, shardable, restartable.

Every generator is a pure function of (seed, shard, step); any worker can
re-derive any shard after a restart or an elastic resize (the fault-
tolerance contract of the data layer — no state to checkpoint beyond the
step counter).

``rmat_graph`` matters for the paper: its skew (power-law degrees) is what
makes the Balance machinery of BiGJoin-S non-optional at scale.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


def rmat_graph(scale: int, edge_factor: int = 16, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19
               ) -> np.ndarray:
    """R-MAT generator (Graph500 parameters by default): [E, 2] int32.

    Produces heavily skewed degree distributions — the adversarial regime
    for workload balance (§3.4).
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    e = n * edge_factor
    src = np.zeros(e, np.int64)
    dst = np.zeros(e, np.int64)
    for bit in range(scale):
        r = rng.random(e)
        # quadrant probabilities (a, b, c, d)
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    keep = src != dst
    edges = np.unique(np.stack([src[keep], dst[keep]], 1), axis=0)
    return edges.astype(np.int32)


def uniform_graph(num_vertices: int, num_edges: int, seed: int = 0
                  ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, num_vertices, num_edges)
    v = rng.integers(0, num_vertices, num_edges)
    keep = u != v
    return np.unique(np.stack([u[keep], v[keep]], 1).astype(np.int32),
                     axis=0)


@dataclasses.dataclass
class TokenStream:
    """Deterministic LM token batches: batch [B, S+1] int32 (inputs+labels).

    Shard-aware: worker ``shard`` of ``num_shards`` sees a disjoint
    deterministic substream; ``at_step`` provides O(1) seek for restart.
    """

    vocab_size: int
    batch_size: int
    seq_len: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.num_shards + self.shard)
        # zipf-ish marginal over the vocab — cheap stand-in for text
        z = rng.zipf(1.3, size=(self.batch_size, self.seq_len + 1))
        return (z % self.vocab_size).astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class EdgeUpdateStream:
    """Mixed insert/delete edge-update batches for streaming graph monitors.

    Deterministic pure function of (seed, shard, step, live): any worker can
    re-derive any epoch's batch after a restart (same contract as
    :class:`TokenStream`).  Batches are intentionally DIRTY — duplicates,
    self-loops, inserts of already-live edges and deletes of absent edges —
    because the engine's ``normalize`` must net them out; ``insert_frac``
    of each batch are candidate inserts, the rest deletes drawn from the
    caller's live set (plus a sprinkle of absent-edge deletes that must be
    no-ops).  Insert endpoints are zipf-skewed: hot vertices keep the
    Balance machinery honest under maintenance, not just static loads.
    """

    num_vertices: int
    batch_size: int
    insert_frac: float = 0.75
    skew: float = 0.0  # 0 = uniform endpoints; >1 = zipf exponent
    seed: int = 0
    shard: int = 0
    num_shards: int = 1

    def batch_at(self, step: int, live: np.ndarray | None = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 9_999_991 + step) * self.num_shards + self.shard)
        nv = self.num_vertices
        n_ins = int(round(self.batch_size * self.insert_frac))
        n_del = self.batch_size - n_ins
        if self.skew > 1.0:
            u = (rng.zipf(self.skew, n_ins) % nv).astype(np.int32)
            v = rng.integers(0, nv, n_ins).astype(np.int32)
            ins = np.stack([u, v], 1)
        else:
            ins = rng.integers(0, nv, (n_ins, 2)).astype(np.int32)
        parts = [ins]
        n_live = 0
        if n_del and live is not None and np.asarray(live).size:
            live = np.asarray(live, np.int32).reshape(-1, 2)
            n_live = max(n_del - n_del // 4, 1)
            parts.append(live[rng.integers(0, live.shape[0], n_live)])
        if n_del - n_live > 0:  # absent-edge deletes: must normalize away
            parts.append(rng.integers(0, nv, (n_del - n_live, 2)
                                      ).astype(np.int32))
        upd = np.concatenate(parts, axis=0)
        w = np.concatenate([np.ones(n_ins, np.int32),
                            -np.ones(upd.shape[0] - n_ins, np.int32)])
        return upd, w


def clean_update_batches(edges: np.ndarray, num_vertices: int,
                         batch_size: int, epochs: int, seed: int = 0):
    """Pre-generate ``epochs`` CLEAN, net-balanced edge-update batches.

    Clean = sign-consistent at its point in the stream: every delete names
    a then-live edge, every insert a then-absent one, no duplicates inside
    a batch.  Two properties follow that the dirty
    :class:`EdgeUpdateStream` deliberately lacks (serving contract,
    DESIGN.md §9): (a) concatenating consecutive clean batches and
    normalizing ONCE nets to the same state as applying them one at a time
    — what makes the serving pool's adaptive coalescing exact — and (b)
    the live count stays pinned at ``|edges|`` (each batch deletes and
    inserts ``batch_size // 2``), so the base region never outgrows its
    pow2 rung and the post-prewarm zero-compile budget holds for streams
    of any length.  Returns ``[(rows [B,2], weights [B]), ...]``.
    """
    rng = np.random.default_rng(seed * 7_654_321 + 17)
    live = {(int(u), int(v))
            for u, v in np.asarray(edges, np.int32).reshape(-1, 2)}
    half = batch_size // 2
    out = []
    for _ in range(epochs):
        dels = [live.pop() for _ in range(min(half, len(live) - 1))]
        ins = []
        while len(ins) < half:
            u, v = rng.integers(0, num_vertices, 2)
            e = (int(u), int(v))
            if u != v and e not in live:
                live.add(e)
                ins.append(e)
        rows = np.array(dels + ins, np.int32)
        w = np.concatenate([-np.ones(len(dels), np.int32),
                            np.ones(len(ins), np.int32)])
        out.append((rows, w))
    return out


def recsys_events(num_users: int, num_items: int, batch: int, step: int,
                  table_sizes: Tuple[int, ...], multi_hot: int = 8,
                  seed: int = 0):
    """One batch of retrieval events: (user_feats, item_ids, labels).

    user_feats: dict of categorical id arrays per embedding table —
    ``multi_hot`` ids per example for bag features (EmbeddingBag path).
    """
    rng = np.random.default_rng(seed * 7_777_777 + step)
    feats = {}
    for t, size in enumerate(table_sizes):
        # zipf over table rows: hot items/users (the skew the paper fights)
        ids = rng.zipf(1.2, size=(batch, multi_hot)) % size
        feats[f"table_{t}"] = ids.astype(np.int32)
    item_ids = (rng.zipf(1.2, size=(batch,)) % num_items).astype(np.int32)
    labels = rng.integers(0, 2, size=(batch,)).astype(np.float32)
    return feats, item_ids, labels
