"""WCOJ motif features: the paper's engine inside the GNN data pipeline.

Per-vertex structural features (triangle count, diamond participation)
computed by BiGJoin and appended to node features — the §5.4 triangle-index
idea resurfacing as feature engineering.  This is the first-class
integration point between the paper's contribution and the assigned GNN
architectures (DESIGN.md §4).
"""
from __future__ import annotations

import numpy as np

from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.csr import Graph
from repro.core.plan import make_plan


def motif_counts(graph: Graph, motif: str = "triangle",
                 cfg: BigJoinConfig | None = None) -> np.ndarray:
    """[num_vertices] float32 count of motif instances per vertex."""
    g = graph.degree_relabel()
    q = Q.query_by_name(motif, symmetric=motif in (
        "triangle", "4-clique", "5-clique"))
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    cfg = cfg or BigJoinConfig(batch=4096, seed_chunk=4096,
                               out_capacity=1 << 22)
    idx = build_indices(plan, rels)
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
    counts = np.zeros(graph.num_vertices, np.float32)
    if res.tuples is not None and res.tuples.size:
        np.add.at(counts, res.tuples.reshape(-1), 1.0)
    # relabeling is a bijection applied identically to features: invert it
    deg = np.zeros(graph.num_vertices, np.int64)
    np.add.at(deg, graph.edges[:, 0], 1)
    np.add.at(deg, graph.edges[:, 1], 1)
    order = np.lexsort((np.arange(graph.num_vertices), deg))
    inv = np.empty_like(counts)
    inv[order] = counts[np.arange(graph.num_vertices)]
    return inv


def motif_features(graph: Graph, motifs=("triangle",)) -> np.ndarray:
    """[num_vertices, len(motifs)] log1p-scaled motif feature matrix."""
    cols = [np.log1p(motif_counts(graph, m)) for m in motifs]
    return np.stack(cols, axis=1).astype(np.float32)
