"""Typed recoverable errors (DESIGN.md §10).

The engines used to crash on any capacity overflow with a bare
``RuntimeError("raise batch/out_capacity")`` — but the MPC bounds the
capacities are sized against (Beame–Koutris–Suciu) are probabilistic, so
under adversarially skewed streams overflow is an EXPECTED event, not a
bug.  This module gives every recoverable failure a type the drivers can
dispatch on:

- :class:`CapacityOverflow` carries *which* device buffer overflowed as a
  bitmask (out buffer, level queue, BiGJoin-S piece queue, per-peer route
  table, seed enqueue) so escalate-and-replay can bump exactly the
  offending :class:`~repro.core.capacity.Ratchet` rung and re-run the
  staged epoch;
- :class:`WalError` / :class:`SnapshotError` type the durability paths so
  the serving pool can retry/degrade instead of killing a tenant;
- :class:`FaultInjected` is raised by :mod:`repro.faults` fault points —
  the deterministic chaos harness.

Every class subclasses :class:`RuntimeError`: pre-existing callers that
caught ``RuntimeError`` keep working unchanged.

The overflow flags are plain ints OR-able inside jitted dataflows (the
``BigJoinState.overflow`` field is an int32 mask accumulated on device and
decoded host-side by :func:`overflow_kinds`).
"""
from __future__ import annotations

from typing import FrozenSet

# BigJoinState.overflow bitmask — one bit per distinct buffer kind.  The
# mask is OR-accumulated inside the jitted dataflow (and bit-OR-psum'd
# across mesh workers), then decoded host-side into kind names.
OVF_OUT = 1       # collect-mode output buffer (cfg.out_capacity)
OVF_QUEUE = 2     # a level queue (2·batch rows; bounded by Lemma 3.1)
OVF_PIECE = 4     # a BiGJoin-S piece queue (balance.piece_caps)
OVF_ROUTE = 8     # per-peer route table (DistConfig.route_capacity)
OVF_SEED = 16     # seed-chunk enqueue (cfg.seed_chunk / dealt chunk)

_KIND_BITS = (
    ("out", OVF_OUT),
    ("queue", OVF_QUEUE),
    ("piece", OVF_PIECE),
    ("route", OVF_ROUTE),
    ("seed", OVF_SEED),
)

# which buffer kind escalates which capacity knob
ESCALATES_BATCH = frozenset({"queue", "piece", "seed"})
ESCALATES_OUT = frozenset({"out"})
ESCALATES_ROUTE = frozenset({"route"})


def overflow_kinds(mask: int) -> FrozenSet[str]:
    """Decode an overflow bitmask into buffer-kind names."""
    return frozenset(name for name, bit in _KIND_BITS if int(mask) & bit)


class ReproError(RuntimeError):
    """Base of every typed repro error (a RuntimeError for old callers)."""


class CapacityOverflow(ReproError):
    """A static device buffer overflowed — recoverable by rung escalation.

    ``mask`` is the raw device bitmask; :attr:`kinds` names the buffers
    (``{"out", "queue", "piece", "route", "seed"}`` subsets); ``where``
    says which driver detected it (diagnostics only).
    """

    def __init__(self, mask: int, where: str = "", detail: str = ""):
        self.mask = int(mask)
        self.kinds = overflow_kinds(mask)
        self.where = where
        names = "/".join(sorted(self.kinds)) or f"mask={self.mask}"
        msg = f"capacity overflow [{names}]"
        if where:
            msg += f" in {where}"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


class WalError(ReproError):
    """Write-ahead log append/fsync/verify failure (retryable)."""


class SnapshotError(ReproError):
    """Snapshot write/restore failure (the WAL still covers the epochs)."""


class FaultInjected(ReproError):
    """Raised by a :mod:`repro.faults` fault point when its schedule fires."""

    def __init__(self, point: str, hit: int):
        self.point = point
        self.hit = int(hit)
        super().__init__(f"injected fault at {point!r} (hit #{hit})")


__all__ = [
    "OVF_OUT", "OVF_QUEUE", "OVF_PIECE", "OVF_ROUTE", "OVF_SEED",
    "ESCALATES_BATCH", "ESCALATES_OUT", "ESCALATES_ROUTE",
    "overflow_kinds", "ReproError", "CapacityOverflow", "WalError",
    "SnapshotError", "FaultInjected",
]
