"""Fault-tolerant checkpointing (no orbax in this environment).

Design for 1000+-node operation:
  * atomic commit — writes go to ``<step>.tmp/`` and are renamed only after
    every shard file and the manifest have been fsynced; a crashed writer
    leaves no half-checkpoint that restore could pick up.
  * manifest — pytree structure, leaf dtypes/shapes, mesh shape, and a
    content checksum per leaf file; restore verifies before trusting.
  * elastic resharding — arrays are saved *unsharded by logical leaf* (each
    leaf a .npy), so a checkpoint written on mesh A restores onto mesh B of
    any shape: the restorer re-applies the target sharding at load.  At real
    scale each host writes only its addressable shards; the single-process
    container serializes full leaves, which is the degenerate case of the
    same layout.
  * retention — keep_last N; the manager also auto-resumes from the newest
    intact checkpoint, skipping corrupt ones (crash-during-write test).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_files(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def save_pytree(tree, directory: str, step: int,
                extra: Optional[dict] = None) -> str:
    """Atomically write one checkpoint; returns its final path."""
    final = os.path.join(directory, f"ckpt_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(_leaf_files(tree)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        # raw-bytes serialization: dtype recorded in the manifest, so
        # non-native dtypes (bfloat16 et al.) roundtrip losslessly
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, np.frombuffer(arr.tobytes(), np.uint8))
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "name": name, "file": fname, "dtype": str(arr.dtype),
            "shape": list(arr.shape), "sha": _checksum(arr)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # the atomic commit point
    return final


def load_raw(path: str) -> Tuple[list, dict]:
    """Load one checkpoint's leaves in saved order WITHOUT a template:
    returns ``(leaves, manifest)`` with each leaf a verified host array.

    The template-free entry point for state whose structure is recorded in
    the manifest itself (``extra=``) rather than in caller code — e.g.
    ``RegionStore.snapshot()`` metadata names its leaves, so a recovering
    serving worker can restore before rebuilding any engine structure."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for rec in manifest["leaves"]:
        raw = np.load(os.path.join(path, rec["file"]))
        try:
            arr = np.frombuffer(raw.tobytes(), np.dtype(rec["dtype"])
                                ).reshape(rec["shape"])
        except (TypeError, ValueError) as e:
            raise IOError(f"undecodable leaf {rec['file']}: {e}")
        if _checksum(arr) != rec["sha"]:
            raise IOError(f"checksum mismatch in {rec['file']}")
        leaves.append(arr)
    return leaves, manifest


def load_pytree(template, path: str, shardings=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of jax.sharding.Sharding matching the
    template — the elastic-resharding hook: leaves are device_put with the
    *target* sharding regardless of the mesh that wrote them.
    """
    leaves, manifest = load_raw(path)
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    if len(flat_t) != len(manifest["leaves"]):
        raise ValueError(
            f"leaf count mismatch: template {len(flat_t)} vs "
            f"checkpoint {len(manifest['leaves'])}")
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings,
            is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))
    return tree, manifest


def _intact(path: str) -> bool:
    return os.path.isfile(os.path.join(path, "manifest.json"))


class CheckpointManager:
    """save / restore-latest / retention, tolerant of partial writes."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def all_steps(self) -> list:
        out = []
        for name in sorted(os.listdir(self.directory)):
            full = os.path.join(self.directory, name)
            if name.startswith("ckpt_") and not name.endswith(".tmp") \
                    and _intact(full):
                out.append(int(name.split("_")[1]))
        return out

    def save(self, tree, step: int, extra: Optional[dict] = None) -> str:
        path = save_pytree(tree, self.directory, step, extra)
        self._retain()
        return path

    def _retain(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"ckpt_{s:010d}"), ignore_errors=True)
        # clear stale tmp dirs from crashed writers
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def restore_latest(self, template, shardings=None):
        """Newest intact checkpoint, or None.  Corrupt ones are skipped."""
        for s in reversed(self.all_steps()):
            path = os.path.join(self.directory, f"ckpt_{s:010d}")
            try:
                return load_pytree(template, path, shardings)
            except (IOError, ValueError):
                continue
        return None

    def restore_latest_raw(self):
        """Newest intact checkpoint as ``(leaves, manifest)`` — no
        template (see :func:`load_raw`); None when nothing restorable."""
        for s in reversed(self.all_steps()):
            path = os.path.join(self.directory, f"ckpt_{s:010d}")
            try:
                return load_raw(path)
            except (IOError, ValueError):
                continue
        return None
