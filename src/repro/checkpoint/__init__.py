from repro.checkpoint.checkpoint import (CheckpointManager, load_pytree,
                                         load_raw, save_pytree)
