"""Textual pattern DSL for conjunctive subgraph queries.

One pattern per string, datalog-ish::

    tri(a, b, c)  := e(a, b), e(a, c), e(b, c)
    diam(a,b,c,d) := e(a,b), e(b,c), e(d,a), e(d,c)
    sym3(a,b,c)   := e(a,b), e(a,c), e(b,c), a < b, b < c

Head variables fix the attribute order (attribute ``i`` is the i-th head
variable); body terms are relational atoms (``e``/``edge`` is the graph's
binary edge relation; any other name — e.g. ``tri`` — names a stored
relation) or ``x < y`` symmetry-breaking inequality filters.  The result is
a plain :class:`repro.core.query.Query`, so parsed patterns and the
hand-built motifs of ``core/query.py`` are interchangeable everywhere.
"""
from __future__ import annotations

import re
from typing import List, Tuple

from repro.core.query import EDGE, Atom, Filter, Query

_HEAD_RE = re.compile(
    r"^\s*(?P<name>[A-Za-z0-9_-]+)\s*\(\s*(?P<vars>[^)]*)\)\s*$")
_ATOM_RE = re.compile(
    r"^\s*(?P<rel>[A-Za-z_]\w*)\s*\(\s*(?P<vars>[^)]*)\)\s*$")
_INEQ_RE = re.compile(
    r"^\s*(?P<lo>[A-Za-z_]\w*)\s*<\s*(?P<hi>[A-Za-z_]\w*)\s*$")
_VAR_RE = re.compile(r"^[A-Za-z_]\w*$")


class PatternSyntaxError(ValueError):
    """Raised on malformed pattern text (the message cites the bad part)."""


def _split_terms(body: str) -> List[str]:
    """Split the body on commas OUTSIDE parentheses."""
    terms, depth, cur = [], 0, []
    for ch in body:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise PatternSyntaxError(f"unbalanced ')' in {body!r}")
        if ch == "," and depth == 0:
            terms.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth:
        raise PatternSyntaxError(f"unbalanced '(' in {body!r}")
    terms.append("".join(cur))
    return [t for t in terms if t.strip()]


def _parse_vars(raw: str, where: str) -> List[str]:
    names = [v.strip() for v in raw.split(",")] if raw.strip() else []
    for v in names:
        if not _VAR_RE.match(v):
            raise PatternSyntaxError(f"bad variable {v!r} in {where}")
    return names


def parse_pattern(text: str, name: str = None) -> Query:
    """Parse one pattern string into a :class:`Query`.

    Raises :class:`PatternSyntaxError` for malformed text and
    ``ValueError`` for semantically bad patterns (unbound variables, arity
    mismatches, head variables no atom covers).
    """
    if ":=" not in text:
        raise PatternSyntaxError(
            f"pattern needs 'head(vars) := body': {text!r}")
    head_txt, body_txt = text.split(":=", 1)
    m = _HEAD_RE.match(head_txt)
    if not m:
        raise PatternSyntaxError(f"bad pattern head {head_txt.strip()!r}")
    qname = name if name is not None else m.group("name")
    head_vars = _parse_vars(m.group("vars"), "head")
    if not head_vars:
        raise PatternSyntaxError("pattern head has no variables")
    if len(set(head_vars)) != len(head_vars):
        raise PatternSyntaxError(
            f"repeated variable in head {head_txt.strip()!r}")
    attr_of = {v: i for i, v in enumerate(head_vars)}

    atoms: List[Atom] = []
    filters: List[Filter] = []
    arity_of = {}
    for term in _split_terms(body_txt):
        iq = _INEQ_RE.match(term)
        if iq:
            lo, hi = iq.group("lo"), iq.group("hi")
            for v in (lo, hi):
                if v not in attr_of:
                    raise ValueError(
                        f"unbound variable {v!r} in filter {term.strip()!r}")
            filters.append(Filter(attr_of[lo], attr_of[hi]))
            continue
        am = _ATOM_RE.match(term)
        if not am:
            raise PatternSyntaxError(f"bad body term {term.strip()!r}")
        rel = am.group("rel")
        vs = _parse_vars(am.group("vars"), f"atom {term.strip()!r}")
        for v in vs:
            if v not in attr_of:
                raise ValueError(
                    f"unbound variable {v!r} in atom {term.strip()!r} "
                    f"(head vars: {', '.join(head_vars)})")
        if rel in ("e", EDGE):
            rel = EDGE
            if len(vs) != 2:
                raise ValueError(
                    f"arity mismatch: edge atom {term.strip()!r} must be "
                    "binary")
        want = arity_of.setdefault(rel, len(vs))
        if want != len(vs):
            raise ValueError(
                f"arity mismatch: relation {rel!r} used with arity "
                f"{len(vs)} after arity {want}")
        atoms.append(Atom(rel, tuple(attr_of[v] for v in vs)))
    if not atoms:
        raise PatternSyntaxError("pattern body has no atoms")
    # Query.__post_init__ rejects uncovered head attrs / repeated atom vars
    return Query(qname, len(head_vars), tuple(atoms), tuple(filters))


_DEF_VARS = "abcdefghijklmnopqrstuvwxyz"


def pattern_of(q: Query) -> str:
    """Serialize a Query back to DSL text; ``parse_pattern(pattern_of(q))``
    reproduces ``q`` exactly (atom order, filters, name)."""
    if q.num_attrs > len(_DEF_VARS):
        raise ValueError("too many attributes to serialize")
    v = _DEF_VARS[:q.num_attrs]
    head = f"{q.name}({', '.join(v)})"
    terms: List[str] = []
    for atom in q.atoms:
        rel = "e" if atom.rel == EDGE else atom.rel
        terms.append(f"{rel}({', '.join(v[a] for a in atom.attrs)})")
    for f in q.filters:
        terms.append(f"{v[f.lo]} < {v[f.hi]}")
    return f"{head} := {', '.join(terms)}"
