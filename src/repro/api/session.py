"""GraphSession: one graph, many standing queries, one commit per epoch.

The facade over the paper's engines (ROADMAP north-star shape, cf. HUGE
arXiv:2103.14294 / DDSL arXiv:1810.05972): a session OWNS the dynamic graph
— one :class:`~repro.core.delta.RegionStore` holding every multi-version
index projection (host-local, or hash-sharded over a device mesh) — and is
the sole public entry point.  Queries register against the session and get a
:class:`QueryHandle` (static count/enumerate + standing delta subscription);
``session.update`` runs ONE normalize → dAQ_1..dAQ_n (for every registered
query) → commit per epoch off the shared regions, so N standing queries pay
neither N index copies nor N commits.

Compiled artifacts are cached at every layer: plans per (query, mode),
single-host dataflows per (plan, config) (``bigjoin._compiled_fns``), and
mesh programs per (plan, config, mesh)
(``distributed.get_distributed_program``) — steady-state epochs recompile
nothing.

Capacities (B' proposal budget, output buffers, route slots) are sized
automatically from the query's AGM bound; pass overrides only when you know
better.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import compilestats
from repro.core import delta as _delta
from repro.core.bigjoin import BigJoinConfig, run_bigjoin
from repro.core.csr import pow2_capacity
from repro.core.plan import Plan, make_plan
from repro.core.query import Query, fractional_edge_cover, query_by_name
from repro.api.dsl import parse_pattern
from repro.errors import (CapacityOverflow, ESCALATES_BATCH, ESCALATES_OUT,
                          ESCALATES_ROUTE)


def _pow2(n: int) -> int:
    return pow2_capacity(max(int(n), 1))


@dataclasses.dataclass(frozen=True)
class Sizing:
    """Derived capacities for one query (see :func:`auto_sizing`)."""

    batch: int  # B' — per-step proposal budget
    out_capacity: int  # collect-mode output rows per dataflow run
    route_capacity: int  # per peer-pair request slots (mesh only)


def auto_sizing(query: Query, num_edges: int, num_workers: int = 1,
                update_batch: int = 2048) -> Sizing:
    """Capacity defaults from the AGM bound (§1.1): with |E| = IN and
    fractional edge-cover number rho*, MaxOut = IN^rho* and one seed edge
    extends to at most IN^(rho*-1) results.

    - ``batch`` (B', PER WORKER): enough proposals per step to amortize a
      launch but bounded for VMEM — the per-seed extension bound, clamped
      to [1024, 8192] globally and split across workers no lower than 256.
    - ``out_capacity``: one epoch's worst-case signed output,
      n_atoms · |dR| · IN^(rho*-1), clamped to [2^14, 2^22].
    - ``route_capacity``: the BiGJoin-S balls-into-bins regime — each
      worker's B' per-step requests spread over w owners, 4x slack:
      4·batch/w per peer pair, floor 64 (matches
      ``distributed.default_delta_config``).
    """
    E = max(int(num_edges), 2)
    rho = fractional_edge_cover(query)
    per_seed = float(E) ** max(rho - 1.0, 0.0)
    batch = int(np.clip(_pow2(per_seed), 1024, 8192))
    batch = max(batch // max(num_workers, 1), 256)
    out_rows = query.num_atoms * update_batch * per_seed
    out_capacity = int(np.clip(_pow2(out_rows), 1 << 14, 1 << 22))
    return Sizing(batch, out_capacity, _route_for(batch, num_workers))


def _route_for(batch: int, num_workers: int) -> int:
    return max(4 * batch // max(num_workers, 1), 64)


@dataclasses.dataclass
class EpochResult:
    """What one ``session.update`` produced: the normalized batch and each
    registered query's signed output delta (keyed by handle name).

    ``ins`` / ``dels`` are the EDGE relation's normalized rows (empty when
    the epoch touched other relations only); ``by_rel`` carries every
    relation's normalized ``(ins, dels)`` pair.  ``compile_events`` counts
    the jit traces (= XLA compiles) this epoch triggered — after
    :meth:`GraphSession.prewarm` it must be ZERO on every warm epoch
    (DESIGN.md §8), which is what the compile-stability suite asserts.
    """

    epoch: int
    ins: np.ndarray
    dels: np.ndarray
    deltas: Dict[str, _delta.DeltaResult]
    by_rel: Dict[str, Tuple[np.ndarray, np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    compile_events: int = 0

    @property
    def is_noop(self) -> bool:
        return all(i.size == 0 and d.size == 0
                   for i, d in self.by_rel.values()) \
            if self.by_rel else (self.ins.size == 0 and self.dels.size == 0)

    def advance(self, live: np.ndarray) -> np.ndarray:
        """Advance a host live-edge array by this epoch's normalized delta
        (np.unique row order, same as ``session.edges``) — lets stream
        drivers track the live set without pulling the device-resident
        store's O(|E|) mirror every epoch."""
        if self.is_noop:
            return live
        kept = _delta._diff_rows(live, self.dels)
        return np.unique(np.concatenate([kept, self.ins]), axis=0)


class QueryHandle:
    """One standing query registered on a :class:`GraphSession`.

    Static evaluation (:meth:`count` / :meth:`enumerate`) reads the live
    graph through the session's shared regions; the standing side is fed by
    ``session.update`` — every epoch's :class:`~repro.core.delta.DeltaResult`
    lands in :attr:`last_delta`, accumulates into :attr:`net_change`, and is
    pushed to any :meth:`subscribe` callbacks.
    """

    def __init__(self, session: "GraphSession", name: str, query: Query,
                 batch: Optional[int] = None,
                 out_capacity: Optional[int] = None):
        self.session = session
        self.name = name
        self.query = query
        self._batch = batch
        self._out_capacity = out_capacity
        self._engine: Optional[_delta.DeltaBigJoin] = None
        self.last_delta: Optional[_delta.DeltaResult] = None
        self.net_change = 0
        self._subscribers: List[Callable] = []

    @property
    def engine(self) -> _delta.DeltaBigJoin:
        """The standing delta engine (shares the session's RegionStore).
        Built lazily on the first update epoch, so static-only handles
        never pay the delta plans' region construction."""
        if self._engine is None:
            self._engine = self.session._make_engine(
                self.query, self._batch, self._out_capacity)
        return self._engine

    def count(self) -> int:
        """Exact instance count over the CURRENT graph (worst-case optimal
        static dataflow over the shared live regions)."""
        return self.session._static_eval(self.query, "count").count

    def enumerate(self) -> Tuple[np.ndarray, np.ndarray]:
        """All instances over the current graph: (tuples [N, m], weights)."""
        res = self.session._static_eval(self.query, "collect")
        m = self.query.num_attrs
        if res.tuples is None:
            return (np.zeros((0, m), np.int32), np.zeros(0, np.int32))
        return res.tuples, res.weights

    def subscribe(self, fn: Callable[[int, _delta.DeltaResult], None]):
        """Call ``fn(epoch, delta_result)`` after every update epoch."""
        self._subscribers.append(fn)
        return fn

    def _deliver(self, epoch: int, res: _delta.DeltaResult):
        self.last_delta = res
        self.net_change += res.count_delta
        for fn in self._subscribers:
            fn(epoch, res)

    def __repr__(self):  # pragma: no cover - debug aid
        return (f"QueryHandle({self.name!r}, atoms={self.query.num_atoms}, "
                f"net_change={self.net_change:+d})")


class GraphSession:
    """The facade: owns one dynamic graph and serves many standing queries.

    Engine selection: ``local=True`` keeps everything on the host
    (single-process BiGJoin); ``local=False`` hash-shards every index region
    over the device mesh and runs the request/response dataflow of §3.4.
    Default (``local=None``): the mesh when more than one device (or an
    explicit ``mesh``) is available, the host engine otherwise.

    Either way the session's RegionStore is DEVICE-RESIDENT by default
    (DESIGN.md §6): one jitted normalize probe and one jitted sorted-merge
    commit per epoch serve every registered query, with warm epoch cost
    proportional to the delta, not the graph.  ``device_resident=False``
    selects the legacy host-truth store (contrast benchmarks only).
    """

    def __init__(self, initial_edges, *, local: bool = None,
                 mesh=None, balance: bool = False,
                 batch: Optional[int] = None,
                 out_capacity: Optional[int] = None,
                 update_batch: int = 2048,
                 compact_ratio: float = 0.5,
                 device_resident: bool = True,
                 prewarm: bool = False):
        import jax
        if local is None:
            local = mesh is None and jax.device_count() == 1
        self.local = bool(local)
        self.balance = balance
        self._batch_override = batch
        self._out_override = out_capacity
        self.update_batch = update_batch
        if self.local:
            self.mesh = None
            self.w = 1
        else:
            if mesh is None:
                from jax.sharding import Mesh
                from repro.core.distributed import AXIS
                mesh = Mesh(np.array(jax.devices()), (AXIS,))
            self.mesh = mesh
            self.w = int(np.prod(
                [mesh.shape[a] for a in mesh.axis_names]))
        self.store = _delta.RegionStore(
            initial_edges, shard_w=0 if self.local else self.w,
            compact_ratio=compact_ratio, device_resident=device_resident)
        self.handles: Dict[str, QueryHandle] = {}
        self.epoch = 0
        self._static_plans: Dict[Query, Plan] = {}
        self.programs_built = 0  # engine/program constructions (cache proof)
        # walk the AOT compile ladder at register() time (DESIGN.md §8)
        self.auto_prewarm = bool(prewarm)

    # -- registration -------------------------------------------------------
    def register(self, pattern, name: Optional[str] = None,
                 symmetric: bool = False,
                 batch: Optional[int] = None,
                 out_capacity: Optional[int] = None) -> QueryHandle:
        """Register a standing query and return its handle.

        ``pattern`` is a :class:`Query`, a DSL string (``"tri(a,b,c) :=
        e(a,b), e(a,c), e(b,c)"``), or a registry name (``"4-clique"``).
        Registering the same name twice returns the existing handle.
        """
        if isinstance(pattern, Query):
            q = pattern
        elif ":=" in pattern:
            q = parse_pattern(pattern, name=name)
        else:
            q = query_by_name(pattern, symmetric=symmetric)
        name = name or q.name
        if name in self.handles:
            if self.handles[name].query != q:
                raise ValueError(
                    f"query name {name!r} already registered with a "
                    "different pattern")
            return self.handles[name]
        # declare any relation the query reads that the store doesn't hold
        # yet (created empty; add_relation() beforehand seeds real tuples)
        # — so ``update({"tri": ...})`` works right after registration,
        # without waiting for the lazily-built engine to declare it
        for atom in q.atoms:
            if atom.rel not in self.store.relations:
                self.store.add_relation(
                    atom.rel, np.zeros((0, atom.arity), np.int32))
        handle = QueryHandle(self, name, q, batch, out_capacity)
        self.handles[name] = handle
        if self.auto_prewarm:
            self.prewarm()
        return handle

    def prewarm(self, horizon: Optional[int] = None) -> int:
        """Walk the AOT compile ladder (DESIGN.md §8): pin the delta/probe/
        seed marks to ``update_batch``, then compile-and-execute (on
        zero-filled prototypes — see ``delta._warm_call``) every fold and
        dataflow signature the ratcheted capacity ladder can request for
        every registered query — store folds
        (``RegionStore.prewarm_folds``), the local step/seed_step pairs,
        and the mesh shard_map programs.  ``horizon`` optionally caps the
        warmed committed ladder at the stream's total expected churn
        (epochs × batch) so short streams over huge graphs don't pay for
        rungs they can never reach.

        After this, every epoch with batches ≤ ``update_batch`` reports
        ``EpochResult.compile_events == 0`` until a relation's base region
        outgrows its pow2 rung (amortized-rare; that one epoch re-walks a
        warm-cached ladder).  With the persistent compilation cache
        (``REPRO_COMPILE_CACHE``) a restarted process pays deserialization,
        not XLA, for the same ladder.  Returns compile events spent (also
        surfaced as ``StoreStats.prewarm_compiles``)."""
        snap = compilestats.snapshot()
        # engines first: their lazily-created projections must exist
        # before the store enumerates fold groups
        engines = [h.engine for h in self.handles.values()]
        self.store.prewarm_folds(self.update_batch, horizon)
        for engine in engines:
            self.store.stats.prewarm_compiles += \
                engine.prewarm(self.update_batch, horizon)
        self.store._sync_compile_stats()
        return compilestats.since(snap)

    def kernel_coverage(self) -> dict:
        """Per-relation Pallas-dispatch evidence (``RegionStore.
        kernel_coverage``): for each relation, the traced ``pallas_call``
        count of the exact commit fold and probe the warm serving path
        dispatches to.  The CI kernel-coverage gate asserts zero warm
        compiles AND a fused (single-launch) fold on every composite
        relation from this one dict."""
        return self.store.kernel_coverage(self.update_batch)

    def query_by_name(self, name: str) -> QueryHandle:
        """Fetch a registered handle; registers the named motif on miss."""
        return self.handles.get(name) or self.register(name)

    def __getitem__(self, name: str) -> QueryHandle:
        return self.handles[name]

    def add_relation(self, rel: str, rows: np.ndarray,
                     arity: Optional[int] = None):
        """Register one more dynamic relation (e.g. a materialized ``tri``
        relation) with its initial tuples; later ``update`` batches may
        then address it by name."""
        self.store.add_relation(rel, rows, arity=arity)

    def relation(self, rel: str) -> np.ndarray:
        """One relation's live tuples (host view)."""
        return self.store.relation_rows(rel)

    def num_tuples(self, rel: str) -> int:
        return self.store.num_tuples(rel)

    def _sizing(self, q: Query, batch, out_capacity) -> Sizing:
        # the AGM inputs ride a ratchet: |E| jitter around a pow2 boundary
        # must not flap the derived B'/out/route capacities (each one keys
        # a jit cache — DESIGN.md §8)
        live = self.store.base_ratchet.capacity(
            ("sizing",), self.store.max_live or self.update_batch)
        s = auto_sizing(q, live, self.w, self.update_batch)
        b = batch or self._batch_override or s.batch
        oc = out_capacity or self._out_override or s.out_capacity
        # escalation marks (DESIGN.md §10) are FLOORS: once an overflow
        # escalated a query's rung, every rebuilt engine / static-eval
        # config / restored session starts at the raised capacity instead
        # of re-discovering the overflow
        r = self.store.ratchet
        b = max(b, r.peek(("cap", "batch", q.name)))
        oc = max(oc, r.peek(("cap", "out", q.name)))
        rt = max(_route_for(b, self.w),  # route follows the FINAL B'
                 r.peek(("cap", "route", q.name)))
        return Sizing(b, oc, rt)

    def _make_engine(self, q: Query, batch, out_capacity
                     ) -> _delta.DeltaBigJoin:
        s = self._sizing(q, batch, out_capacity)
        self.programs_built += 1
        if self.local:
            cfg = BigJoinConfig(batch=s.batch, seed_chunk=s.batch,
                                mode="collect", out_capacity=s.out_capacity)
            return _delta.DeltaBigJoin(q, None, cfg=cfg, store=self.store)
        from repro.core.distributed import (DistDeltaBigJoin,
                                            default_delta_config)
        dcfg = default_delta_config(self.w, batch=s.batch,
                                    out_capacity=s.out_capacity,
                                    balance=self.balance)
        return DistDeltaBigJoin(q, None, mesh=self.mesh, dcfg=dcfg,
                                store=self.store)

    # -- the epoch loop -----------------------------------------------------
    def prepare(self, updates, weights=None) -> _delta.PreparedBatch:
        """Stage A of :meth:`update` on the host only (validate, pack,
        sentinel-pad — pure numpy, no device call): the serving pipeline
        prepares batch k+1 on a prep thread while batch k is still
        committing, then passes the result to ``update(prepared=...)``
        (DESIGN.md §9)."""
        return self.store.prepare(updates, weights)

    def update(self, updates=None, weights=None, *,
               prepared: Optional[_delta.PreparedBatch] = None
               ) -> EpochResult:
        """Apply one update batch to the graph and every standing query:
        ONE normalize, one staged uncommitted region set, each registered
        query's dAQ pipeline off the shared regions, ONE commit.

        ``updates`` is an [N, 2] edge array (with optional ``weights``), or
        a per-relation dict ``{"edge": (rows, w), "tri": (rows, w), ...}``
        updating any subset of the session's relations in one epoch —
        or pass ``prepared=`` (from :meth:`prepare`) to skip the host
        packing stage.

        TRANSACTIONAL (DESIGN.md §10): the epoch counter advances and the
        handles observe the delta only after the commit succeeded.  Any
        failure between staging and commit — a capacity overflow that
        exhausted its escalations, an injected fault — rolls the store
        back to the epoch boundary and re-raises; the same batch can then
        be retried verbatim.
        """
        snap = compilestats.snapshot()
        if prepared is None:
            prepared = self.store.prepare(updates, weights)
        elif updates is not None or weights is not None:
            raise ValueError("pass updates OR prepared=, not both")
        batches = self.store.normalize_prepared(prepared)
        e_ins, e_dels = batches.get(
            "edge", (np.zeros((0, 2), np.int32),) * 2)
        if all(i.size == 0 and d.size == 0 for i, d in batches.values()):
            self.epoch += 1
            zero = _delta.DeltaResult(0, None, None, [])
            deltas = {name: zero for name in self.handles}
            for name, h in self.handles.items():
                h._deliver(self.epoch, zero)
            return EpochResult(self.epoch, e_ins, e_dels, deltas, batches,
                               compile_events=compilestats.since(snap))
        # touch every handle's engine BEFORE staging: a lazily-built engine
        # must create its projections first, or they would miss the
        # uncommitted batch begin_epoch installs on existing regions
        engines = [(name, h.engine) for name, h in self.handles.items()]
        try:
            self.store.begin_epoch(batches)
            deltas: Dict[str, _delta.DeltaResult] = {}
            for name, engine in engines:
                deltas[name] = engine.run_delta_plans(batches)
            self.store.commit(batches)
        except Exception:
            self.store.rollback()
            raise
        self.epoch += 1
        for name, h in self.handles.items():
            h._deliver(self.epoch, deltas[name])
        return EpochResult(self.epoch, e_ins, e_dels, deltas, batches,
                           compile_events=compilestats.since(snap))

    # -- durability (DESIGN.md §9) ------------------------------------------
    def snapshot(self) -> Tuple[List[np.ndarray], dict]:
        """Serialize the session's dynamic state: the store's regions and
        ratchet marks (``RegionStore.snapshot``) plus the session layer —
        epoch counter and every registered handle (pattern DSL round-trip
        + accumulated ``net_change``).  Returns ``(leaves, meta)`` ready
        for ``repro.checkpoint.save_pytree(leaves, ..., extra=meta)``;
        restore with :meth:`restore` on a session of the same mesh
        width/engine mode."""
        from repro.api.dsl import pattern_of
        leaves, meta = self.store.snapshot()
        meta["session"] = {
            "epoch": int(self.epoch),
            "w": int(self.w),
            "local": bool(self.local),
            "update_batch": int(self.update_batch),
            "handles": {name: {"pattern": pattern_of(h.query),
                               "net_change": int(h.net_change)}
                        for name, h in self.handles.items()},
        }
        return leaves, meta

    def restore(self, leaves: List[np.ndarray], meta: dict) -> None:
        """Restore a :meth:`snapshot` onto this session in place: store
        regions + ratchet marks first, then the session layer (epoch,
        handles re-registered from their pattern DSL with net_change
        reinstated).  Handles already registered with the same name keep
        their handle object (and subscribers); the snapshot's counters
        overwrite theirs.  A WAL replay on top of this brings the session
        to the exact pre-crash state (``repro.serve.wal``)."""
        sess = meta.get("session", {})
        w = int(sess.get("w", self.w))
        if w != self.w:
            raise ValueError(
                f"snapshot was taken on a {w}-worker session; this one has "
                f"{self.w} workers — failover restores onto the same mesh "
                "width")
        if bool(sess.get("local", self.local)) != self.local:
            raise ValueError("snapshot engine mode (local/mesh) mismatch")
        self.store.restore(leaves, meta)
        self.epoch = int(sess.get("epoch", 0))
        for name, rec in sess.get("handles", {}).items():
            h = self.register(rec["pattern"], name=name)
            h.net_change = int(rec["net_change"])
            h.last_delta = None

    # -- static evaluation over the shared regions --------------------------
    def _static_plan(self, q: Query) -> Plan:
        """Plan reading version "old" = base + cins − cdel, i.e. the live
        committed graph, through the SAME shared regions the delta path
        maintains — a static query costs no extra index build."""
        plan = self._static_plans.get(q)
        if plan is None:
            plan = make_plan(q, versions=("old",) * q.num_atoms)
            self.store.ensure_plan(plan)
            self._static_plans[q] = plan
        return plan

    def _escalate_static(self, q: Query, exc: CapacityOverflow,
                         s: Sizing) -> None:
        """Static-eval overflow recovery: bump the same per-query marks
        the delta engines use (``_sizing`` applies them as floors, so the
        retried config — and every later engine build — starts on the
        raised rung).  Re-raises when no named buffer can grow."""
        r = self.store.ratchet
        changed = False
        if exc.kinds & ESCALATES_OUT:
            r.escalate(("cap", "out", q.name), floor=s.out_capacity)
            changed = True
        if exc.kinds & ESCALATES_BATCH:
            r.escalate(("cap", "batch", q.name), floor=s.batch)
            changed = True
        if exc.kinds & ESCALATES_ROUTE:
            r.escalate(("cap", "route", q.name), floor=s.route_capacity)
            changed = True
        if not changed:
            raise exc
        self.store.stats.escalations += 1
        self.store.stats.replays += 1

    def _static_eval(self, q: Query, mode: str):
        from repro.core.bigjoin import seed_tuples_for
        plan = self._static_plan(q)
        seed_rel = q.atoms[plan.seed_atom].rel
        seed = seed_tuples_for(plan,
                               {seed_rel: self.store.relation_rows(
                                   seed_rel)})
        indices = self.store.indices_for(plan)
        for attempt in range(_delta.DeltaBigJoin.MAX_ESCALATIONS + 1):
            s = self._sizing(q, None, None)  # re-read escalated floors
            out_cap = s.out_capacity if mode == "collect" else 1
            try:
                if self.local:
                    cfg = BigJoinConfig(batch=s.batch, seed_chunk=s.batch,
                                        mode=mode, out_capacity=out_cap)
                    return run_bigjoin(plan, indices, seed, cfg=cfg)
                from repro.core.distributed import (DistConfig,
                                                    get_distributed_program,
                                                    run_program)
                base = BigJoinConfig(batch=s.batch, seed_chunk=s.batch,
                                     mode=mode, out_capacity=out_cap)
                dcfg = DistConfig(base, self.w,
                                  route_capacity=s.route_capacity,
                                  balance=self.balance)
                program = get_distributed_program(plan, dcfg, self.mesh)
                return run_program(program, self.w, mode == "collect",
                                   indices, seed,
                                   np.ones(seed.shape[0], np.int32),
                                   width=plan.seed_width)
            except CapacityOverflow as exc:
                if attempt >= _delta.DeltaBigJoin.MAX_ESCALATIONS:
                    raise
                self._escalate_static(q, exc, s)
        raise AssertionError("unreachable")

    # -- introspection ------------------------------------------------------
    @property
    def edges(self) -> np.ndarray:
        """The live edge set (host truth)."""
        return self.store.edges

    @property
    def num_edges(self) -> int:
        return int(self.store.num_edges)  # O(1): no mirror materialization

    @property
    def stats(self) -> _delta.StoreStats:
        return self.store.stats

    def __repr__(self):  # pragma: no cover - debug aid
        where = "local" if self.local else f"{self.w}-worker mesh"
        return (f"GraphSession({self.num_edges:,} edges, "
                f"{len(self.handles)} queries, {where}, "
                f"epoch {self.epoch})")
