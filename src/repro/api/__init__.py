"""repro.api — the public facade over the WCOJ dataflow engines.

Everything a driver, example, or service needs lives here::

    from repro.api import GraphSession

    session = GraphSession(initial_edges)            # owns the graph
    tri = session.register("triangle")               # named motif
    diam = session.register(
        "diam(a,b,c,d) := e(a,b), e(b,c), e(d,a), e(d,c)")  # pattern DSL
    print(tri.count())                               # static count
    res = session.update(edge_batch, weights)        # ONE commit per epoch
    print(res.deltas["triangle"].count_delta)        # per-query signed delta

The engine modules under ``repro.core`` (``run_bigjoin``,
``distributed_join``, ``DeltaBigJoin``, ``DistDeltaBigJoin``) remain the
implementation layer; importing them directly is deprecated for
application code — register queries on a session instead.
"""
from repro.api.dsl import PatternSyntaxError, parse_pattern, pattern_of
from repro.api.session import (EpochResult, GraphSession, QueryHandle,
                               Sizing, auto_sizing)
from repro.core import compilestats
from repro.core.capacity import Ratchet
from repro.core.csr import Graph, pow2_capacity
from repro.core.delta import canon_signed
from repro.core.query import (PAPER_QUERIES, QUERY_NAMES, QUERY_REGISTRY,
                              Query, agm_bound, query_by_name)

__all__ = [
    "GraphSession", "QueryHandle", "EpochResult", "Sizing", "auto_sizing",
    "parse_pattern", "pattern_of", "PatternSyntaxError",
    "Query", "query_by_name", "QUERY_NAMES", "QUERY_REGISTRY",
    "PAPER_QUERIES", "agm_bound", "Graph", "oracle_count", "canon_signed",
    "pow2_capacity", "Ratchet", "compilestats",
]


def oracle_count(query, edges) -> int:
    """Serial Generic-Join ground truth over an edge array — or a full
    relations dict ``{"edge": ..., "tri": ...}`` for multi-relation queries
    (the COST-style single-core baseline) — for verification in examples
    and drivers without reaching into ``repro.core``."""
    from repro.core.generic_join import generic_join
    from repro.core.query import EDGE
    if isinstance(query, str):
        query = query_by_name(query) if ":=" not in query \
            else parse_pattern(query)
    relations = edges if isinstance(edges, dict) else {EDGE: edges}
    _, cnt = generic_join(query, relations, enumerate_results=False)
    return int(cnt)
