"""The paper's engine as a CLI: static and incremental subgraph queries.

    python -m repro.launch.run_query --query triangle --scale 12 \
        --mode static|delta|distributed
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="triangle",
                    choices=["triangle", "4-clique", "diamond", "house",
                             "5-clique"])
    ap.add_argument("--mode", default="static",
                    choices=["static", "delta", "distributed", "serial"])
    ap.add_argument("--scale", type=int, default=11,
                    help="RMAT scale (2^scale vertices)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4096,
                    help="B' dataflow batch")
    ap.add_argument("--update-batches", type=int, default=5)
    ap.add_argument("--update-size", type=int, default=1000)
    ap.add_argument("--symmetric", action="store_true",
                    help="degree-relabel + symmetry-breaking filters")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core import query as Q
    from repro.core.bigjoin import (BigJoinConfig, build_indices,
                                    run_bigjoin, seed_tuples_for)
    from repro.core.csr import Graph
    from repro.core.plan import make_plan
    from repro.data.synthetic import rmat_graph

    edges = rmat_graph(args.scale, args.edge_factor, seed=args.seed)
    g = Graph.from_edges(edges)
    if args.symmetric:
        g = g.degree_relabel()
    q = Q.PAPER_QUERIES[args.query](symmetric=args.symmetric) \
        if args.query in ("triangle", "4-clique", "5-clique") \
        else Q.PAPER_QUERIES[args.query]()
    rels = {Q.EDGE: g.edges}
    print(f"graph: {g.num_vertices:,} vertices {g.num_edges:,} edges "
          f"(max outdeg {np.bincount(g.edges[:, 0]).max():,})")

    if args.mode == "serial":
        from repro.core.generic_join import generic_join
        t0 = time.time()
        _, cnt = generic_join(q, rels, enumerate_results=False)
        print(f"serial GJ: {cnt:,} results in {time.time()-t0:.2f}s")
    elif args.mode == "static":
        plan = make_plan(q)
        cfg = BigJoinConfig(batch=args.batch, seed_chunk=args.batch,
                            mode="count")
        t0 = time.time()
        idx = build_indices(plan, rels)
        t_index = time.time() - t0
        t0 = time.time()
        res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
        print(f"BiGJoin: {res.count:,} results in {time.time()-t0:.2f}s "
              f"(index {t_index:.2f}s, {res.steps} rounds, "
              f"{res.proposals:,} proposals)")
    elif args.mode == "delta":
        from repro.core.delta import DeltaBigJoin
        cfg = BigJoinConfig(batch=args.batch, seed_chunk=args.batch,
                            mode="collect", out_capacity=1 << 22)
        n0 = g.num_edges - args.update_batches * args.update_size
        engine = DeltaBigJoin(q, g.edges[:n0], cfg=cfg)
        print(f"loaded {n0:,} edges; streaming "
              f"{args.update_batches} x {args.update_size} updates")
        for i in range(args.update_batches):
            lo = n0 + i * args.update_size
            batch = g.edges[lo:lo + args.update_size]
            t0 = time.time()
            res = engine.apply(batch)
            dt = time.time() - t0
            print(f"  batch {i}: +{res.count_delta:,} results "
                  f"({batch.shape[0]/dt:,.0f} updates/s, "
                  f"{abs(res.count_delta)/dt:,.0f} changes/s)")
    else:  # distributed
        from repro.core.distributed import DistConfig, distributed_join
        plan = make_plan(q)
        cfg = DistConfig(
            BigJoinConfig(batch=args.batch, mode="count"),
            1, route_capacity=args.batch)
        t0 = time.time()
        res = distributed_join(plan, rels, cfg=cfg)
        print(f"distributed BiGJoin (w=1): {res.count:,} results in "
              f"{time.time()-t0:.2f}s ({res.steps} rounds, max load "
              f"{res.max_load:,})")


if __name__ == "__main__":
    main()
