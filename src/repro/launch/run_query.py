"""The paper's engine as a CLI, driven through the GraphSession facade.

    python -m repro.launch.run_query --query triangle --scale 12 \
        --mode static|delta|distributed|serial

``static`` counts on a host-local session, ``distributed`` on the device
mesh (every local device a worker), ``delta`` streams update batches through
a standing registration, ``serial`` runs the Generic-Join oracle baseline.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import Graph, GraphSession, QUERY_NAMES, oracle_count
from repro.data.synthetic import rmat_graph


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="triangle",
                    help=f"named motif ({', '.join(QUERY_NAMES)}, path-N) "
                    "or a DSL pattern 'name(a,b,..) := e(a,b), ...'")
    ap.add_argument("--mode", default="static",
                    choices=["static", "delta", "distributed", "serial"])
    ap.add_argument("--scale", type=int, default=11,
                    help="RMAT scale (2^scale vertices)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--batch", type=int, default=None,
                    help="B' dataflow batch (default: AGM auto-sizing)")
    ap.add_argument("--update-batches", type=int, default=5)
    ap.add_argument("--update-size", type=int, default=1000)
    ap.add_argument("--symmetric", action="store_true",
                    help="degree-relabel + symmetry-breaking filters")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    g = Graph.from_edges(rmat_graph(args.scale, args.edge_factor,
                                    seed=args.seed))
    if args.symmetric:
        g = g.degree_relabel()
    print(f"graph: {g.num_vertices:,} vertices {g.num_edges:,} edges "
          f"(max outdeg {np.bincount(g.edges[:, 0]).max():,})")

    if args.mode == "serial":
        t0 = time.time()
        cnt = oracle_count(args.query, g.edges)
        print(f"serial GJ: {cnt:,} results in {time.time()-t0:.2f}s")
        return

    if args.mode == "delta":
        n0 = g.num_edges - args.update_batches * args.update_size
        session = GraphSession(g.edges[:n0], local=True, batch=args.batch,
                               update_batch=args.update_size)
        handle = session.register(args.query, symmetric=args.symmetric)
        print(f"loaded {n0:,} edges; streaming "
              f"{args.update_batches} x {args.update_size} updates")
        for i in range(args.update_batches):
            lo = n0 + i * args.update_size
            batch = g.edges[lo:lo + args.update_size]
            t0 = time.time()
            res = session.update(batch)
            dt = time.time() - t0
            d = res.deltas[handle.name]
            print(f"  batch {i}: +{d.count_delta:,} results "
                  f"({batch.shape[0]/dt:,.0f} updates/s, "
                  f"{abs(d.count_delta)/dt:,.0f} changes/s)")
        return

    # static count — host-local or on the device mesh
    session = GraphSession(g.edges, local=(args.mode == "static"),
                           batch=args.batch)
    t0 = time.time()
    handle = session.register(args.query, symmetric=args.symmetric)
    t_reg = time.time() - t0
    t0 = time.time()
    count = handle.count()
    where = "host-local" if session.local else f"w={session.w} mesh"
    print(f"BiGJoin: {count:,} results in {time.time()-t0:.2f}s "
          f"({where}, register {t_reg:.2f}s)")


if __name__ == "__main__":
    main()
