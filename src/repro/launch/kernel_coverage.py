"""CI kernel-coverage gate: warm composite stream, fused kernels on path.

Drives the §5.4 two-session pipeline (triangle feeder -> streamed ``tri``
relation -> standing 4-clique-tri) with the AOT prewarm ladder, then
asserts the two halves of the PR-10 contract:

- **zero serving compiles**: after ``prewarm``, every epoch reports
  ``EpochResult.compile_events == 0`` — the composite fused-fold path
  reuses the warmed jit cache, it does not fork new signatures;
- **composite kernels on the dispatch path**: ``GraphSession.
  kernel_coverage()`` shows, for the composite ``tri`` relation, exactly
  ONE fused ``pallas_call`` in the commit fold and >= 1 in the versioned
  probe — the launches a warm epoch actually executes.

Prints one JSON line (machine-readable for the CI heredoc) and exits
non-zero on any violation.  Run:

    PYTHONPATH=src python -m repro.launch.kernel_coverage \
        [--scale 8] [--epochs 6] [--batch-size 64]
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8,
                    help="graph scale: nv = 2**scale")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--update-batch", type=int, default=0,
                    help="pinned delta mark; 0 = 4x batch-size (triangle "
                    "deltas fan out past the edge batch that caused them)")
    args = ap.parse_args(argv)
    update_batch = args.update_batch or 4 * args.batch_size

    from repro.api import GraphSession
    from repro.data.synthetic import EdgeUpdateStream, uniform_graph

    # nv*3 edges sit MID-rung (cap 4·nv) and the stream churns balanced
    # (insert_frac=0.5): the gate measures kernel coverage at steady state,
    # so the live sets must not random-walk across a base rung mid-stream —
    # a rung crossing recompiles by design (DESIGN.md §8), which would
    # mask a real coverage regression behind a capacity artifact.
    nv = 1 << args.scale
    edges = uniform_graph(nv, nv * 3, seed=7)
    sess = GraphSession(edges, local=True, batch=1024,
                        out_capacity=1 << 16, update_batch=update_batch)
    tri = sess.register("triangle")
    tri0, _ = tri.enumerate()
    sess.add_relation("tri", tri0)
    sess.register("4-clique-tri")
    prewarm_compiles = sess.prewarm(
        horizon=(args.warmup + args.epochs) * update_batch)

    stream = EdgeUpdateStream(nv, args.batch_size, insert_frac=0.5, seed=11)
    live = sess.edges
    warm_compiles, epoch_compiles = 0, []
    for step in range(args.warmup + args.epochs):
        upd, w = stream.batch_at(step, live=live)
        res = sess.update(upd, w)
        live = res.advance(live)
        d = res.deltas["triangle"]
        t_upd = d.tuples if d.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = d.weights if d.weights is not None else np.zeros(0, np.int32)
        res2 = sess.update({"tri": (t_upd, t_w)})
        ev = res.compile_events + res2.compile_events
        epoch_compiles.append(ev)
        if step >= args.warmup:
            warm_compiles += ev

    cov = sess.kernel_coverage()
    composite = {rel: c for rel, c in cov.items() if c["composite"]}
    rec = {
        "gate": "kernel_coverage",
        "prewarm_compiles": int(prewarm_compiles),
        "warm_compiles": int(warm_compiles),
        "epoch_compiles": epoch_compiles,
        "coverage": cov,
        "composite_relations": sorted(composite),
    }
    failures = []
    if warm_compiles != 0:
        failures.append(f"serving compiles after warmup: {warm_compiles}")
    if not composite:
        failures.append("no composite relation in the stream")
    for rel, c in composite.items():
        if c["fold_pallas_calls"] != 1:
            failures.append(
                f"{rel}: commit fold traces {c['fold_pallas_calls']} "
                "pallas_calls, want the ONE fused launch")
        if c["probe_pallas_calls"] < 1:
            failures.append(f"{rel}: no pallas launch in the probe path")
    rec["ok"] = not failures
    rec["failures"] = failures
    print(json.dumps(rec))
    print(f"kernel-coverage: {warm_compiles} serving compiles after "
          f"warmup; composite fold launches: "
          f"{ {r: c['fold_pallas_calls'] for r, c in composite.items()} }; "
          f"{'OK' if not failures else 'FAILED: ' + '; '.join(failures)}",
          file=sys.stderr)
    return 0 if not failures else 1


if __name__ == "__main__":
    raise SystemExit(main())
