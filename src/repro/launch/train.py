"""Fault-tolerant training driver: ``python -m repro.launch.train --arch X``.

Production behaviors exercised end-to-end (and tested in
tests/test_train_driver.py):
  * checkpoint/restart — atomic checkpoints every --ckpt-every steps;
    relaunching the same command auto-resumes from the newest intact one
    (crash-during-write leaves only skippable partial state).
  * elastic scaling — the data pipeline is a pure function of
    (seed, shard, step) and checkpoints store unsharded leaves, so a restart
    onto a different mesh/host count replays losslessly (the restore applies
    the new mesh's shardings).
  * straggler mitigation — deterministic balanced work splits inside the
    step (BiGJoin-S Balance for join workloads; fixed-capacity MoE dispatch
    for LM): a slow worker delays one collective, never grows a queue.

On this CPU container the driver runs the *smoke* config by default; pass
--full to build the assigned production config (requires real accelerators).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_lm(spec, args):
    from repro.configs.lm_family import make_train_step
    from repro.data import TokenStream
    from repro.models import transformer as T
    from repro.optim import adamw_init

    cfg = spec.full_config if args.full else spec.smoke_config
    params = T.init(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg),
                      donate_argnums=(0, 1))

    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(args.ckpt_dir, keep_last=3)
    state = {"params": params, "opt": opt}
    start = 0
    restored = mgr.restore_latest(state)
    if restored is not None:
        state, manifest = restored
        start = manifest["step"]
        print(f"resumed from step {start}")
    params, opt = state["params"], state["opt"]

    ts = TokenStream(cfg.vocab, args.batch, args.seq, seed=args.seed)
    t0 = time.time()
    for s in range(start, args.steps):
        b = ts.batch_at(s)
        batch = {"tokens": jnp.asarray(b[:, :-1]),
                 "labels": jnp.asarray(b[:, 1:])}
        params, opt, m = step_fn(params, opt, batch)
        if (s + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            tok_s = args.batch * args.seq / dt
            print(f"step {s+1} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['gnorm']):.3f} {tok_s:,.0f} tok/s",
                  flush=True)
            t0 = time.time()
        if (s + 1) % args.ckpt_every == 0 or s + 1 == args.steps:
            mgr.save({"params": params, "opt": opt}, s + 1,
                     extra={"loss": float(m["loss"])})
    return float(m["loss"])


def train_gnn(spec, args):
    from repro.configs.gnn_family import make_train_step
    import dataclasses
    from repro.core.csr import Graph
    from repro.data import NeighborSampler, uniform_graph
    from repro.data.motifs import motif_features
    from repro.models import gnn as G
    from repro.optim import adamw_init

    base = spec.smoke_config
    edges = uniform_graph(args.nodes, args.nodes * 8, seed=args.seed)
    graph = Graph.from_edges(edges, args.nodes)
    rng = np.random.default_rng(args.seed)
    # WCOJ motif features from the paper's engine (DESIGN.md §4)
    motifs = motif_features(graph, ("triangle",))
    feats = np.concatenate(
        [rng.normal(size=(args.nodes, 8)).astype(np.float32), motifs], 1)
    labels = (motifs[:, 0] > np.median(motifs[:, 0])).astype(np.int32)
    cfg = dataclasses.replace(base, d_in=feats.shape[1], d_out=2)
    params = G.init(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg), donate_argnums=(0, 1))
    sampler = NeighborSampler(edges, args.nodes)

    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(args.ckpt_dir, keep_last=3)
    state = {"params": params, "opt": opt}
    start = 0
    restored = mgr.restore_latest(state)
    if restored is not None:
        state, manifest = restored
        start = manifest["step"]
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    N_max, E_max = 512, 2048
    for s in range(start, args.steps):
        srng = np.random.default_rng(args.seed * 7919 + s)
        seeds = srng.choice(args.nodes, 64, replace=False)
        blocks = sampler.sample_blocks(seeds, [5, 5], seed=args.seed + s)
        # union-graph flattening (configs/gnn_family.py convention)
        nodes = blocks[0].src_nodes
        es = np.concatenate([b.src_nodes[b.edge_src] for b in blocks])
        ed = np.concatenate([b.dst_nodes[b.edge_dst] for b in blocks])
        lookup = {int(v): i for i, v in enumerate(nodes)}
        es = np.array([lookup[int(v)] for v in es], np.int32)
        ed = np.array([lookup[int(v)] for v in ed], np.int32)
        n, e = len(nodes), len(es)
        if n > N_max or e > E_max:
            n, e = min(n, N_max), min(e, E_max)
        batch = {
            "feats": jnp.asarray(np.pad(feats[nodes][:n],
                                        ((0, N_max - n), (0, 0)))),
            "coords": jnp.zeros((N_max, 3), jnp.float32),
            "edge_src": jnp.asarray(np.pad(es[:e], (0, E_max - e))),
            "edge_dst": jnp.asarray(np.pad(ed[:e], (0, E_max - e))),
            "edge_mask": jnp.asarray(np.arange(E_max) < e),
            "edge_feats": jnp.ones((E_max, 1), jnp.float32),
            "labels": jnp.asarray(np.pad(labels[nodes][:n],
                                         (0, N_max - n))),
            "label_mask": jnp.asarray(
                np.isin(nodes[:n], seeds, assume_unique=False).__and__(
                    np.arange(n) < n) if n else np.zeros(0, bool)),
        }
        batch["label_mask"] = jnp.asarray(
            np.pad(np.asarray(batch["label_mask"]), (0, N_max - n)))
        params, opt, m = step_fn(params, opt, batch)
        if (s + 1) % args.log_every == 0:
            print(f"step {s+1} loss {float(m['loss']):.4f} "
                  f"acc {float(m.get('acc', 0)):.3f}", flush=True)
        if (s + 1) % args.ckpt_every == 0 or s + 1 == args.steps:
            mgr.save({"params": params, "opt": opt}, s + 1)
    return float(m["loss"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--nodes", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    from repro.configs import get_arch
    spec = get_arch(args.arch)
    if spec.family == "lm":
        loss = train_lm(spec, args)
    elif spec.family == "gnn":
        loss = train_gnn(spec, args)
    else:
        m = spec.smoke_run(spec.smoke_config)
        loss = m.get("loss_last", 0.0)
    print(f"final loss {loss:.4f}")


if __name__ == "__main__":
    main()
