"""Serving drivers.

Two serving modes share this entry point:

**LM decode** (the original path): batched prefill + autoregressive decode
with a KV cache::

    python -m repro.launch.serve --arch gemma2-2b --batch 4 --steps 32

**Streaming subgraph monitoring** (the paper's deployment, §5.3): load a
graph, then run the distributed Delta-BiGJoin epoch loop
``normalize -> dAQ_1..dAQ_n -> commit`` on the local device mesh as edge
updates stream in::

    python -m repro.launch.serve --stream --query triangle --scale 10 \
        --epochs 12 --batch-size 512

Every epoch applies one mixed insert/delete batch from
``data.synthetic.EdgeUpdateStream`` through ``DistDeltaBigJoin`` (all local
devices are mesh workers; ``--local`` falls back to the host engine) and
reports per-epoch latency and update/output-change throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_stream(args):
    from repro.core import query as Q
    from repro.core.csr import Graph
    from repro.core.distributed import make_delta_monitor
    from repro.data.synthetic import EdgeUpdateStream, rmat_graph

    g = Graph.from_edges(rmat_graph(args.scale, args.edge_factor,
                                    seed=args.seed))
    q = Q.PAPER_QUERIES[args.query]()
    eng = make_delta_monitor(q, g.edges, local=args.local,
                             batch=args.bprime,
                             out_capacity=args.out_capacity,
                             balance=args.balance)
    mode = "host-local" if args.local else (
        f"{jax.device_count()}-worker mesh"
        + (" (balanced)" if args.balance else ""))
    stream = EdgeUpdateStream(g.num_vertices, args.batch_size,
                              insert_frac=args.insert_frac,
                              skew=args.stream_skew, seed=args.seed + 1)
    print(f"monitoring {args.query} over {g.num_edges:,} edges on {mode}; "
          f"{args.epochs} epochs x {args.batch_size} updates")

    total = 0
    times = []
    for step in range(args.epochs):
        upd, wts = stream.batch_at(step, live=eng.edges)
        t0 = time.time()
        res = eng.apply(upd, wts)
        dt = max(time.time() - t0, 1e-9)  # no-op epochs can be ~0s
        times.append(dt)
        total += res.count_delta
        changes = 0 if res.weights is None else int(
            np.abs(res.weights).sum())
        print(f"  epoch {step}: {res.count_delta:+,} net "
              f"({changes:,} changes) in {dt*1e3:.0f} ms — "
              f"{upd.shape[0]/dt:,.0f} upd/s, {changes/dt:,.0f} changes/s")
    warm = times[2:] or times
    print(f"steady state: {np.median(warm)*1e3:.0f} ms/epoch, "
          f"{args.batch_size/np.median(warm):,.0f} upd/s; "
          f"net instance change {total:+,}")

    if args.verify:
        from repro.core.generic_join import generic_join
        ref = generic_join(q, {Q.EDGE: eng.edges},
                           enumerate_results=False)[1]
        ref0 = generic_join(q, {Q.EDGE: g.edges},
                            enumerate_results=False)[1]
        if total != ref - ref0:  # not assert: must survive python -O
            raise RuntimeError(
                f"maintained total {total} != recompute diff {ref - ref0}")
        print(f"verified: maintained total == recompute diff "
              f"({ref:,} instances now) ✓")
    return total


def serve_lm(args):
    from repro.configs import get_arch
    from repro.models import transformer as T

    spec = get_arch(args.arch)
    assert spec.family == "lm", "serve.py drives the LM archs"
    cfg = spec.full_config if args.full else spec.smoke_config
    params = T.init(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.steps

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg),
                     donate_argnums=(1,))

    t0 = time.time()
    logits, pcache = prefill(params, prompts)
    # right-size the cache: copy prefill K/V into a max_len cache
    cache = T.make_cache(cfg, args.batch, max_len)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], pcache["k"].astype(cache["k"].dtype),
            (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], pcache["v"].astype(cache["v"].dtype),
            (0, 0, 0, 0, 0)),
    }
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    print(f"prefill {args.prompt_len} tokens in {time.time()-t0:.2f}s")

    out = [tok]
    t0 = time.time()
    for s in range(args.steps - 1):
        pos = jnp.asarray(args.prompt_len + s, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / max(args.steps - 1, 1)
    toks = np.concatenate([np.asarray(t) for t in out], 1)
    print(f"decode: {dt*1e3:.1f} ms/step, {args.batch/dt:,.1f} tok/s "
          f"aggregate; sample: {toks[0][:16].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="LM arch to serve (decode mode)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # streaming subgraph monitor mode
    ap.add_argument("--stream", action="store_true",
                    help="serve a streaming subgraph monitor instead of an "
                    "LM (distributed Delta-BiGJoin epoch loop)")
    ap.add_argument("--query", default="triangle",
                    help="paper query to monitor (stream mode)")
    ap.add_argument("--scale", type=int, default=10,
                    help="rmat scale of the base graph (stream mode)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=512,
                    help="updates per epoch (stream mode)")
    ap.add_argument("--insert-frac", type=float, default=0.75)
    ap.add_argument("--stream-skew", type=float, default=0.0,
                    help="zipf exponent for insert endpoints (0 = uniform)")
    ap.add_argument("--bprime", type=int, default=2048,
                    help="B' per-worker proposal budget (stream mode)")
    ap.add_argument("--out-capacity", type=int, default=1 << 20)
    ap.add_argument("--balance", action="store_true",
                    help="BiGJoin-S Balance operator (stream mode)")
    ap.add_argument("--local", action="store_true",
                    help="host-local DeltaBigJoin baseline (stream mode)")
    ap.add_argument("--verify", action="store_true",
                    help="check the maintained total against full "
                    "recomputation at the end (stream mode)")
    args = ap.parse_args(argv)

    if args.stream:
        return serve_stream(args)
    if not args.arch:
        ap.error("--arch is required unless --stream is given")
    return serve_lm(args)


if __name__ == "__main__":
    main()
