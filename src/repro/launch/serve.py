"""Serving driver: batched decode with a KV cache.

``python -m repro.launch.serve --arch gemma2-2b --batch 4 --steps 32``
runs prefill + autoregressive decode on the smoke config and reports
per-step latency; ``--full`` builds the assigned config (accelerators).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_arch
    from repro.models import transformer as T

    spec = get_arch(args.arch)
    assert spec.family == "lm", "serve.py drives the LM archs"
    cfg = spec.full_config if args.full else spec.smoke_config
    params = T.init(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.steps

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg),
                     donate_argnums=(1,))

    t0 = time.time()
    logits, pcache = prefill(params, prompts)
    # right-size the cache: copy prefill K/V into a max_len cache
    cache = T.make_cache(cfg, args.batch, max_len)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], pcache["k"].astype(cache["k"].dtype),
            (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], pcache["v"].astype(cache["v"].dtype),
            (0, 0, 0, 0, 0)),
    }
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    print(f"prefill {args.prompt_len} tokens in {time.time()-t0:.2f}s")

    out = [tok]
    t0 = time.time()
    for s in range(args.steps - 1):
        pos = jnp.asarray(args.prompt_len + s, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / max(args.steps - 1, 1)
    toks = np.concatenate([np.asarray(t) for t in out], 1)
    print(f"decode: {dt*1e3:.1f} ms/step, {args.batch/dt:,.1f} tok/s "
          f"aggregate; sample: {toks[0][:16].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    return toks


if __name__ == "__main__":
    main()
