"""Serving drivers.

Two serving modes share this entry point:

**LM decode** (the original path): batched prefill + autoregressive decode
with a KV cache::

    python -m repro.launch.serve --arch gemma2-2b --batch 4 --steps 32

**Streaming subgraph monitoring** (the paper's deployment, §5.3): load a
graph into a :class:`repro.api.GraphSession`, register one or more standing
queries, then run the Delta-BiGJoin epoch loop ``normalize ->
dAQ_1..dAQ_n (every query) -> commit`` as edge updates stream in::

    python -m repro.launch.serve --stream --query triangle,diamond \
        --scale 10 --epochs 12 --batch-size 512

Every epoch applies one mixed insert/delete batch from
``data.synthetic.EdgeUpdateStream`` through the session — all registered
queries ride the SAME shared index regions and the same single commit (all
local devices are mesh workers; ``--local`` keeps the session on the host)
— and reports per-epoch latency and update/output-change throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_stream(args):
    """Single-tenant streaming monitor: a thin wrapper over the serving
    pool (DESIGN.md §9) — one tenant, coalesce=1, synchronous
    submit→result per logical epoch, so the printed per-epoch numbers mean
    exactly what the bespoke driver's used to.  The prep/apply pipeline,
    admission prewarm and (``--durable-dir``) WAL+snapshot durability all
    come from :class:`repro.serve.SessionPool` instead of bespoke code."""
    from repro.api import Graph, compilestats, oracle_count
    from repro.data.synthetic import EdgeUpdateStream, rmat_graph
    from repro.serve import SessionPool

    g = Graph.from_edges(rmat_graph(args.scale, args.edge_factor,
                                    seed=args.seed))
    names = [n.strip() for n in args.query.split(",") if n.strip()]
    # queries over the materialized ``tri`` relation (e.g. 4-clique-tri,
    # §5.4): a standing triangle query on the SAME session feeds the tri
    # relation — each logical epoch is then two session updates, edge batch
    # first, the resulting signed triangle delta second.  Registration and
    # tri seeding run inside the pool's admission ``setup`` hook so the
    # admission prewarm covers every standing query.
    state = {}

    def setup(session):
        handles = [session.register(n) for n in names]
        needs_tri = any(atom.rel == "tri"
                        for h in handles for atom in h.query.atoms)
        tri0 = None
        if needs_tri:
            feeder = session.register("triangle")
            tri0, _ = feeder.enumerate()
            session.add_relation("tri", tri0)
            if feeder not in handles:
                handles = [feeder] + handles
        state.update(handles=handles, needs_tri=needs_tri, tri0=tri0)

    pool = SessionPool(local=args.local, balance=args.balance,
                       update_batch=args.batch_size, prewarm=args.prewarm,
                       horizon=args.epochs * args.batch_size,
                       durable_dir=args.durable_dir,
                       snapshot_every=args.snapshot_every)
    t0 = time.time()
    tenant = pool.admit("stream", g.edges, setup=setup, coalesce=1,
                        batch=args.bprime, out_capacity=args.out_capacity)
    t_admit = time.time() - t0
    session = tenant.session
    handles, needs_tri, tri0 = \
        state["handles"], state["needs_tri"], state["tri0"]
    mode = "host-local" if session.local else (
        f"{session.w}-worker mesh" + (" (balanced)" if args.balance else ""))
    stream = EdgeUpdateStream(g.num_vertices, args.batch_size,
                              insert_frac=args.insert_frac,
                              skew=args.stream_skew, seed=args.seed + 1)
    print(f"monitoring {', '.join(names)} over {g.num_edges:,} edges on "
          f"{mode}; {args.epochs} epochs x {args.batch_size} updates "
          "(one shared commit per epoch"
          + (", tri relation fed by the standing triangle query)"
         if needs_tri else ")"))
    if args.prewarm:
        print(f"prewarm: walked the AOT capacity ladder in "
              f"{t_admit:.1f}s ({tenant.stats.prewarm_compiles} compile "
              "events"
              + (", persistent cache "
                 f"{compilestats.cache_dir()}" if compilestats.cache_dir()
                 else "") + ")")
    if args.durable_dir and session.epoch > 0:
        print(f"recovered epoch {session.epoch} from {args.durable_dir} "
              f"({tenant.stats.replayed} WAL epochs replayed)")

    times = []
    compiles = []
    noops = 0
    updates_sent = 0
    # the stream generator needs the live set to pick deletes; maintain it
    # incrementally from each epoch's normalized (ins, dels) instead of
    # pulling session.edges — the device-resident store's mirror would cost
    # an O(|E|) materialization per epoch otherwise
    live = session.edges
    for step in range(args.epochs):
        upd, wts = stream.batch_at(step, live=live)
        t0 = time.time()
        res = tenant.submit(upd, wts).result()
        updates_sent += 1
        res2 = None
        if needs_tri:
            td = res.deltas["triangle"]
            t_upd = td.tuples if td.tuples is not None else \
                np.zeros((0, 3), np.int32)
            t_w = td.weights if td.weights is not None else \
                np.zeros(0, np.int32)
            res2 = tenant.submit({"tri": (t_upd, t_w)}).result()
            updates_sent += 1
            noops += int(res2.is_noop)
        dt = max(time.time() - t0, 1e-9)  # no-op epochs can be ~0s
        live = res.advance(live)  # host bookkeeping outside the timer
        times.append(dt)
        compiles.append(res.compile_events +
                        (res2.compile_events if res2 is not None else 0))
        noops += int(res.is_noop)
        parts = []
        changes = 0
        for h in handles:
            # a logical epoch's delta is the sum over both session updates
            # (edge-fed queries fire on the first, tri-fed on the second)
            ds = [res.deltas[h.name]]
            if res2 is not None:
                ds.append(res2.deltas[h.name])
            cd = sum(d.count_delta for d in ds)
            chg = sum(0 if d.weights is None else int(np.abs(
                d.weights).sum()) for d in ds)
            changes += chg
            parts.append(f"{h.name} {cd:+,}")
        print(f"  epoch {step}: {'  '.join(parts)} "
              f"({changes:,} changes) in {dt*1e3:.0f} ms — "
              f"{upd.shape[0]/dt:,.0f} upd/s, {changes/dt:,.0f} changes/s")
    warm = times[2:] or times
    warm_compiles = sum(compiles[2:]) if len(compiles) > 2 else 0
    st = session.stats
    p50, p99 = np.percentile(times, [50, 99])
    print(f"steady state: {np.median(warm)*1e3:.0f} ms/epoch, "
          f"{args.batch_size/np.median(warm):,.0f} upd/s; net "
          + " ".join(f"{h.name} {h.net_change:+,}" for h in handles)
          + f"; {st.commit_calls} commits / {st.normalize_calls} "
          f"normalizes over {st.epochs} epochs")
    print(f"latency: p50 {p50*1e3:.1f} ms  p99 {p99*1e3:.1f} ms  max "
          f"{max(times)*1e3:.1f} ms (p99/p50 {p99/max(p50, 1e-9):.1f}x); "
          f"compile events: {st.prewarm_compiles} prewarm + "
          f"{sum(compiles)} streaming ({warm_compiles} after warmup)"
          + (f"; {compilestats.persistent_hits()} persistent-cache hits"
             if compilestats.cache_dir() else ""))

    if args.verify:
        rels_now = {"edge": session.edges}
        rels_0 = {"edge": g.edges}
        if needs_tri:
            rels_now["tri"] = session.relation("tri")
            rels_0["tri"] = tri0
        for h in handles:
            ref = oracle_count(h.query, rels_now)
            ref0 = oracle_count(h.query, rels_0)
            if h.net_change != ref - ref0:  # not assert: survives python -O
                raise RuntimeError(
                    f"{h.name}: maintained total {h.net_change} != "
                    f"recompute diff {ref - ref0}")
            print(f"verified {h.name}: maintained total == recompute diff "
                  f"({ref:,} instances now) ✓")
        # one normalize per update, one commit per NON-no-op epoch,
        # regardless of how many standing queries are registered
        if st.normalize_calls != updates_sent or \
                st.commit_calls != updates_sent - noops or \
                st.commit_calls != st.epochs:
            raise RuntimeError(
                f"epoch contract violated: {st.commit_calls} commits / "
                f"{st.normalize_calls} normalizes for {updates_sent} "
                f"updates ({noops} no-ops)")
    pool.close()
    return sum(h.net_change for h in handles)


def serve_concurrent(args):
    """N-tenant concurrent serving demo: one :class:`SessionPool`, one
    mesh, ``--concurrent`` tenants each monitoring its own graph + update
    stream from its own client thread.  Prints the pool's aggregate stats
    (latency percentiles, coalescing, backpressure sheds, snapshot/replay
    counters, serving compile budget); ``--verify`` recomputes every
    tenant's maintained total from scratch at the end."""
    import threading

    from repro.api import oracle_count
    from repro.data.synthetic import EdgeUpdateStream, rmat_graph
    from repro.serve import SessionPool

    names = [n.strip() for n in args.query.split(",") if n.strip()]
    # admission prewarm is non-optional here: the multi-tenant serving
    # contract (DESIGN.md §9) is zero serving-path compiles, which
    # --verify asserts below
    pool = SessionPool(local=args.local, balance=args.balance,
                       update_batch=args.batch_size, prewarm=True,
                       horizon=args.epochs * args.batch_size,
                       durable_dir=args.durable_dir,
                       snapshot_every=args.snapshot_every)
    graphs, tenants = {}, {}
    t0 = time.time()
    for i in range(args.concurrent):
        name = f"tenant{i}"
        graphs[name] = rmat_graph(args.scale, args.edge_factor,
                                  seed=args.seed + i)
        tenants[name] = pool.admit(
            name, graphs[name], queries=names, coalesce=args.coalesce,
            max_queue=args.max_queue, batch=args.bprime,
            out_capacity=args.out_capacity)
    mode = "host-local" if pool.local else "mesh"
    print(f"admitted {len(tenants)} tenants ({', '.join(names)} each) on "
          f"one {mode} pool in {time.time()-t0:.1f}s; {args.epochs} epochs "
          f"x {args.batch_size} updates per tenant")

    # materialize each tenant's live mirror + epoch on THIS thread, before
    # any
    # client submits: session.edges runs a jitted device fold, and all
    # device work must stay off the client threads once the pool's apply
    # dispatcher is live (DESIGN.md §9)
    live0 = {name: tenants[name].session.edges for name in tenants}
    starts = {name: tenants[name].session.epoch for name in tenants}

    def client(name):
        # balanced stream (insert_frac 0.5): live set stays within its
        # pow2 base rung, so the zero-compile serving budget holds
        stream = EdgeUpdateStream(
            1 << args.scale, args.batch_size, insert_frac=args.insert_frac,
            skew=args.stream_skew,
            seed=args.seed + 1 + len(tenants) + int(name[6:]))
        live = live0[name]
        start = starts[name]  # >0 after durable recovery
        for step in range(start, args.epochs):
            upd, wts = stream.batch_at(step, live=live)
            ticket = tenants[name].submit(upd, wts)
            if ticket is None:
                continue  # shed by backpressure
            live = ticket.result().advance(live)

    threads = [threading.Thread(target=client, args=(n,), daemon=True)
               for n in tenants]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    pool.drain()
    stats = pool.stats()
    print(stats.render())
    if args.verify:
        for name, handle in tenants.items():
            for h in handle.session.handles.values():
                ref = oracle_count(h.query, {"edge": handle.session.edges})
                ref0 = oracle_count(h.query, {"edge": graphs[name]})
                if h.net_change != ref - ref0:
                    raise RuntimeError(
                        f"{name}/{h.name}: maintained total "
                        f"{h.net_change} != recompute diff {ref - ref0}")
            print(f"verified {name}: maintained totals == recompute ✓")
        if stats.serve_compiles:
            raise RuntimeError(
                f"{stats.serve_compiles} serving-path compile events "
                "(admission prewarm must cover the whole stream)")
    pool.close()
    return stats


def serve_lm(args):
    from repro.configs import get_arch
    from repro.models import transformer as T

    spec = get_arch(args.arch)
    assert spec.family == "lm", "serve.py drives the LM archs"
    cfg = spec.full_config if args.full else spec.smoke_config
    params = T.init(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.steps

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg),
                     donate_argnums=(1,))

    t0 = time.time()
    logits, pcache = prefill(params, prompts)
    # right-size the cache: copy prefill K/V into a max_len cache
    cache = T.make_cache(cfg, args.batch, max_len)
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], pcache["k"].astype(cache["k"].dtype),
            (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], pcache["v"].astype(cache["v"].dtype),
            (0, 0, 0, 0, 0)),
    }
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    print(f"prefill {args.prompt_len} tokens in {time.time()-t0:.2f}s")

    out = [tok]
    t0 = time.time()
    for s in range(args.steps - 1):
        pos = jnp.asarray(args.prompt_len + s, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / max(args.steps - 1, 1)
    toks = np.concatenate([np.asarray(t) for t in out], 1)
    print(f"decode: {dt*1e3:.1f} ms/step, {args.batch/dt:,.1f} tok/s "
          f"aggregate; sample: {toks[0][:16].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="LM arch to serve (decode mode)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # streaming subgraph monitor mode
    ap.add_argument("--stream", action="store_true",
                    help="serve a streaming subgraph monitor instead of an "
                    "LM (distributed Delta-BiGJoin epoch loop)")
    ap.add_argument("--query", default="triangle",
                    help="comma list of named queries to monitor on ONE "
                    "shared session (stream mode)")
    ap.add_argument("--scale", type=int, default=10,
                    help="rmat scale of the base graph (stream mode)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=512,
                    help="updates per epoch (stream mode)")
    ap.add_argument("--insert-frac", type=float, default=0.75)
    ap.add_argument("--stream-skew", type=float, default=0.0,
                    help="zipf exponent for insert endpoints (0 = uniform)")
    ap.add_argument("--bprime", type=int, default=2048,
                    help="B' per-worker proposal budget (stream mode)")
    ap.add_argument("--out-capacity", type=int, default=1 << 20)
    ap.add_argument("--balance", action="store_true",
                    help="BiGJoin-S Balance operator (stream mode)")
    ap.add_argument("--local", action="store_true",
                    help="host-local DeltaBigJoin baseline (stream mode)")
    ap.add_argument("--prewarm", action="store_true",
                    help="walk the AOT capacity ladder before the first "
                    "epoch so warm epochs trigger zero XLA compiles "
                    "(stream mode; pairs with REPRO_COMPILE_CACHE)")
    ap.add_argument("--verify", action="store_true",
                    help="check the maintained total against full "
                    "recomputation at the end (stream mode)")
    # concurrent serving (DESIGN.md §9): N tenants on one SessionPool
    ap.add_argument("--concurrent", type=int, default=0, metavar="N",
                    help="serve N tenants concurrently on one pool "
                    "(implies --stream semantics per tenant)")
    ap.add_argument("--coalesce", type=int, default=8,
                    help="max queued batches folded into one device epoch "
                    "per tenant (concurrent mode)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="per-tenant ingest queue bound — full queues "
                    "backpressure their own client only")
    ap.add_argument("--durable-dir", default=None,
                    help="WAL + snapshot directory: crash-killed serves "
                    "restore the last snapshot and replay the log "
                    "bit-exactly on restart")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="snapshot cadence in epochs (with --durable-dir)")
    args = ap.parse_args(argv)

    if args.concurrent:
        return serve_concurrent(args)
    if args.stream:
        return serve_stream(args)
    if not args.arch:
        ap.error("--arch is required unless --stream is given")
    return serve_lm(args)


if __name__ == "__main__":
    main()
