"""Production meshes.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run overrides the device count before any
jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) single-pod / (2,16,16) two-pod TPU-v5e production mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has, as a 1-axis mesh (tests, smoke)."""
    import numpy as np
    devs = np.array(jax.devices())
    return jax.sharding.Mesh(devs, ("data",))


# TPU v5e hardware constants used by the roofline (EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
