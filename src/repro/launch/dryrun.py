import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder devices.
(Only this entry point sets the override — tests and benches see 1 device.)

Per cell this driver:
  1. builds the cell's step function + ShapeDtypeStruct inputs,
  2. applies logical->physical shardings for the target mesh,
  3. jit(...).lower(...).compile()   (failure here = sharding bug),
  4. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the optimized HLO,
  5. derives the three roofline terms (EXPERIMENTS.md §Roofline).

Outputs one JSON line per cell to --out (benchmarks/results/dryrun.jsonl).
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _cost_dict(cost) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on new JAX and a
    one-element list of dicts on older releases; normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}

# per-chip wire-byte factor applied to the op's RESULT bytes (ring
# algorithms; g = group size): all-reduce moves ~2x the tensor, all-gather
# receives (g-1)/g ~ 1x of its (already full-size) result, reduce-scatter
# sends (g-1)/g of its operand = result*g, all-to-all exchanges ~1x.
def _wire_factor(op: str, group: int) -> float:
    g = max(group, 2)
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)  # result bytes * g * (g-1)/g
    if op == "all-to-all":
        return (g - 1) / g
    return 1.0  # collective-permute


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in the result portion of an HLO line."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> Dict[str, Any]:
    """Per-op-type result bytes + estimated per-chip wire bytes."""
    stats = {op: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
             for op in _COLLECTIVES}
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rest = m.group(1)
        op_match = re.match(r"(\([^)]*\)|\S+)\s+([\w\-]+)", rest)
        if not op_match:
            continue
        opname = op_match.group(2)
        base = opname.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES or opname.endswith("-done"):
            continue
        result = op_match.group(1)
        rbytes = _shape_bytes(result)
        # the CPU backend upcasts bf16 collectives to f32 (TPUs run them
        # native): count convert-fed f32 collectives at bf16 width
        if re.search(rf"{opname}\([^)]*convert", ls) and "f32" in result:
            rbytes //= 2
        g = 0
        gm = _GROUPS_RE.search(ls)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gi = _GROUPS_IOTA_RE.search(ls)
            if gi:
                g = int(gi.group(2))
        g = g or 2
        stats[base]["count"] += 1
        stats[base]["result_bytes"] += rbytes
        stats[base]["wire_bytes"] += rbytes * _wire_factor(base, g)
    stats["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------

def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, no_probe: bool = False
             ) -> Dict[str, Any]:
    import jax
    from repro.configs import get_arch
    from repro.distributed.sharding import sharding_tree
    from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                                   make_production_mesh)

    spec = get_arch(arch_id)
    cell = spec.cells[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind,
    }
    if cell.skip_reason:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip_reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    def lower_compile(step, args, axes, donate):
        if axes is not None:
            in_shardings = sharding_tree(axes, mesh, template=args)
            jitted = jax.jit(step, in_shardings=in_shardings,
                             donate_argnums=donate)
        else:
            jitted = jax.jit(step, donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*args)
            return lowered.compile()

    t0 = time.time()
    compiled = lower_compile(*cell.build(mesh))
    t_compile = time.time() - t0
    t_lower = 0.0

    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))

    # ---- depth-probe extrapolation: XLA cost analysis counts a scan body
    # once, so layer-stacked models probe at two unrolled depths (d1, d2)
    # and extrapolate cost(L) = c1 + (c2 - c1) * (L - d1) / (d2 - d1).
    if cell.probe is not None and not no_probe:
        d1, d2 = cell.probe_depths
        pts = []
        for d in (d1, d2):
            c = lower_compile(*cell.probe(mesh, d))
            ca = _cost_dict(c.cost_analysis())
            pc = parse_collectives(c.as_text())
            pts.append((float(ca.get("flops", 0.0)),
                        float(ca.get("bytes accessed", 0.0)),
                        float(pc["total_wire_bytes"])))
        Lfull = cell.full_depth
        scale = (Lfull - d1) / max(d2 - d1, 1)

        def extrap(i):
            # slope clamped >= 0: XLA occasionally optimizes the deeper
            # probe harder, which would extrapolate negative
            slope = max(pts[1][i] - pts[0][i], 0.0)
            return max(pts[0][i] + slope * scale,
                       pts[1][i]) * cell.probe_scale

        flops_dev = extrap(0)
        bytes_dev = extrap(1)
        coll["total_wire_bytes"] = extrap(2)
        rec["probe"] = {"depths": [d1, d2], "points": pts,
                        "full_depth": Lfull}
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "chips": chips,
        "per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collectives": {k: (v if not isinstance(v, dict) else
                            {kk: int(vv) for kk, vv in v.items()})
                        for k, v in coll.items()},
    })

    # ---- roofline terms (seconds; per-chip view of a balanced SPMD step) --
    # memory term: structural — each live buffer (args incl. params/opt/
    # cache + temps) streams through HBM ~2x per step (read + write) on a
    # fused TPU program.  cost_analysis bytes are recorded as the unfused
    # upper bound (every HLO op's operands counted at full width).
    compute_s = flops_dev / PEAK_FLOPS_BF16
    live = float((getattr(mem, "argument_size_in_bytes", 0) or 0)
                 + (getattr(mem, "temp_size_in_bytes", 0) or 0))
    memory_s = 2.0 * live / HBM_BW
    memory_s_nofusion = bytes_dev / HBM_BW
    collective_s = float(coll["total_wire_bytes"]) / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    model_flops = float(spec.model_flops(shape_name))
    useful = model_flops / max(flops_dev * chips, 1.0)
    rec["roofline"] = {
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_s_nofusion": memory_s_nofusion,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_total": model_flops,
        "useful_flops_ratio": useful,
        "bound_s": max(compute_s, memory_s, collective_s),
    }
    if verbose:
        arg_gb = (rec["per_device"]["argument_bytes"] or 0) / 2**30
        tmp_gb = (rec["per_device"]["temp_bytes"] or 0) / 2**30
        print(f"[{rec['mesh']}] {arch_id}/{shape_name}: compile "
              f"{t_compile:.0f}s args {arg_gb:.2f}GiB temp {tmp_gb:.2f}GiB "
              f"compute {compute_s*1e3:.2f}ms mem {memory_s*1e3:.2f}ms "
              f"coll {collective_s*1e3:.2f}ms -> {dominant} "
              f"(useful {useful:.2f})", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--append", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="compile-only pass (multi-pod shardability check; "
                         "roofline terms come from the single-pod run)")
    args = ap.parse_args(argv)

    from repro.configs import get_arch, list_archs
    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    mode = "a" if args.append else "w"
    failures = 0
    with open(args.out, mode) as f:
        for arch_id in archs:
            spec = get_arch(arch_id)
            shapes = (list(spec.cells) if args.shape == "all"
                      else args.shape.split(","))
            for shape in shapes:
                if shape not in spec.cells:
                    continue
                for multi in meshes:
                    try:
                        rec = run_cell(arch_id, shape, multi,
                                       no_probe=args.no_probe)
                    except Exception as e:  # a failure IS a system bug
                        rec = {"arch": arch_id, "shape": shape,
                               "mesh": "2x16x16" if multi else "16x16",
                               "status": "error",
                               "error": f"{type(e).__name__}: {e}"}
                        traceback.print_exc()
                        failures += 1
                        print(f"FAILED {arch_id}/{shape}", flush=True)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
    print(f"done; {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
