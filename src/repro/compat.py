"""Version-tolerant aliases for JAX APIs that moved between releases.

The repo targets the newest stable JAX spelling (``jax.shard_map``,
``jax.tree.flatten_with_path``) but must run on older runtimes where those
live under ``jax.experimental.shard_map`` / ``jax.tree_util``.  Importing
through this module keeps call sites on one spelling and confines the
feature detection to a single place.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):  # jax >= 0.6 spelling
    _shard_map_impl = jax.shard_map
    _REP_KW = "check_vma"
else:  # pragma: no cover - exercised only on old runtimes
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _REP_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check kwarg renamed as needed
    (``check_vma`` in new JAX, ``check_rep`` before the move out of
    ``jax.experimental``)."""
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_REP_KW: check_vma})


if hasattr(jax.tree, "flatten_with_path"):
    tree_flatten_with_path = jax.tree.flatten_with_path
else:  # pragma: no cover
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path
