"""Decoder-only transformer family covering the five assigned LM archs.

One definition, config-selected features:
  * GQA with separate head_dim (gemma), RoPE, RMSNorm (optionally gemma's
    1+w), SwiGLU / GeGLU
  * MoE (mixtral 8x top-2, llama4-scout 16x top-1) with sort-based
    capacity-bounded dispatch — the fixed-capacity bucketing is the same
    primitive as the join engine's routing (DESIGN.md §4)
  * attention patterns: full, sliding-window (mixtral), local/global
    alternation (gemma2, llama4-scout) — per-layer window array threaded
    through one lax.scan so the HLO stays O(1) in depth
  * logit softcaps (gemma2)
  * KV-cache decode and prefill paths for the serving shapes

Parameters are plain dicts; layer params carry a leading [L] axis and are
consumed by lax.scan (compact HLO: essential for 48-60 layer dry-runs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"  # "silu" (SwiGLU) | "gelu" (GeGLU)
    # MoE (n_experts == 0 -> dense MLP)
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # attention pattern
    window: int = 0  # sliding window width (0 = full)
    local_global_period: int = 0  # every p-th layer global, rest local
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 10000.0
    norm_plus_one: bool = False
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d)
    param_dtype: Any = jnp.bfloat16
    act_dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_unroll: bool = False  # dry-run depth probes: exact HLO cost
    pure_dp: bool = False  # ZeRO-3: batch over every axis, weights fully
    # gathered JIT (dense-arch train cells; §Perf iter 3)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_windows(self) -> np.ndarray:
        """Per-layer attention window (0 = full attention)."""
        if self.local_global_period > 0:
            return np.array(
                [0 if (l + 1) % self.local_global_period == 0
                 else self.window for l in range(self.num_layers)],
                np.int32)
        return np.full(self.num_layers, self.window, np.int32)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer does full attention over unbounded context...
        used by configs to gate the long_500k cell."""
        return bool(self.window > 0 and self.local_global_period == 0) or \
            self.local_global_period > 0  # hybrid: bounded local majority

    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd \
            + self.n_heads * hd * d
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            mlp = 3 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        return self.num_layers * per_layer + self.vocab * d + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        inactive = (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return self.param_count() - self.num_layers * inactive


# ---------------------------------------------------------------------------
# init + metadata
# ---------------------------------------------------------------------------

def init(rng: jax.Array, cfg: TransformerConfig) -> Params:
    Lr, d, hd = cfg.num_layers, cfg.d_model, cfg.head_dim
    H, K, ff, V = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab
    keys = jax.random.split(rng, 10)
    pd = cfg.param_dtype

    def li(key, shape, fan_in):
        return L.he_init(key, (Lr,) + shape, pd, fan_in)

    layer = {
        "ln1": jnp.zeros((Lr, d), pd) if cfg.norm_plus_one
        else jnp.ones((Lr, d), pd),
        "ln2": jnp.zeros((Lr, d), pd) if cfg.norm_plus_one
        else jnp.ones((Lr, d), pd),
        "wq": li(keys[0], (d, H * hd), d),
        "wk": li(keys[1], (d, K * hd), d),
        "wv": li(keys[2], (d, K * hd), d),
        "wo": li(keys[3], (H * hd, d), H * hd),
    }
    if cfg.is_moe:
        layer.update({
            "router": li(keys[4], (d, cfg.n_experts), d),
            "w_in": L.he_init(keys[5], (Lr, cfg.n_experts, d, 2 * ff), pd,
                              d),
            "w_out": L.he_init(keys[6], (Lr, cfg.n_experts, ff, d), pd, ff),
        })
    else:
        layer.update({
            "w_in": li(keys[5], (d, 2 * ff), d),
            "w_out": li(keys[6], (ff, d), ff),
        })
    return {
        "embed": L.embed_init(keys[7], (V, d), pd),
        "final_norm": jnp.zeros(d, pd) if cfg.norm_plus_one
        else jnp.ones(d, pd),
        "layers": layer,
    }


def abstract_params(cfg: TransformerConfig) -> Params:
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


def gather_fsdp(params: Params) -> Params:
    """Hoisted FSDP all-gather: materialize the TP-sharded-only view of the
    stacked layer weights once per step, so a microbatch accumulation scan
    does not re-gather them every iteration (§Perf iter 2).  Differentiable
    (its transpose is the reduce-scatter of the weight grads)."""
    spec = {
        "wq": (None, None, "model"), "wk": (None, None, "model"),
        "wv": (None, None, "model"), "wo": (None, "model", None),
        "w_in": (None, None, "model"), "w_out": (None, "model", None),
        "router": (None, None, None),
    }
    if "w_in" in params["layers"] and params["layers"]["w_in"].ndim == 4:
        spec["w_in"] = (None, "model", None, "model")
        spec["w_out"] = (None, "model", "model", None)
    lw = {k: (L.maybe_shard(v, *spec[k]) if k in spec else v)
          for k, v in params["layers"].items()}
    return {**params, "layers": lw}


def logical_axes(cfg: TransformerConfig) -> Params:
    layer = {
        "ln1": (None, None), "ln2": (None, None),
        "wq": (None, "embed", "heads"),
        "wk": (None, "embed", "kv_heads"),
        "wv": (None, "embed", "kv_heads"),
        "wo": (None, "heads", "embed"),
    }
    if cfg.is_moe:
        layer.update({
            "router": (None, "embed", None),
            # expert -> model when E divides the axis (llama4: 16); else the
            # mlp dim takes it (mixtral: 8 experts fall back to ff sharding)
            "w_in": (None, "expert", "embed", "mlp"),
            "w_out": (None, "expert", "mlp", "embed"),
        })
    else:
        layer.update({
            "w_in": (None, "embed", "mlp"),
            "w_out": (None, "mlp", "embed"),
        })
    # the embed table shards on vocab only: a d-dim (FSDP) shard would
    # force logits-scale all-reduces in the fused CE (§Perf iter 1)
    return {"embed": ("vocab", None),
            "final_norm": (None,), "layers": layer}


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attention(x, lp, cfg: TransformerConfig, positions, window,
               kv_cache=None, cache_pos=None):
    """x [B, S, d].  window: traced scalar (0 = full).  If kv_cache is given
    ((k, v) [B, Smax, K, hd]), attends over the cache (decode path)."""
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    # FSDP: weights stored data-axis-sharded on their d dim are gathered
    # just-in-time (ZeRO-3); otherwise XLA resolves the data-axis conflict
    # by collective-permuting the much larger activations (§Perf iter 1).
    # pure_dp gathers the TP dim too (batch owns every mesh axis).
    tp = None if cfg.pure_dp else "model"
    wq = L.maybe_shard(lp["wq"], None, tp)
    wk = L.maybe_shard(lp["wk"], None, tp)
    wv = L.maybe_shard(lp["wv"], None, tp)
    wo = L.maybe_shard(lp["wo"], tp, None)
    q = jnp.einsum("bsd,dh->bsh", x, wq).reshape(B, S, K, G, hd)
    k = jnp.einsum("bsd,dh->bsh", x, wk).reshape(B, S, K, hd)
    v = jnp.einsum("bsd,dh->bsh", x, wv).reshape(B, S, K, hd)
    # keep heads on the model axis and head_dim replicated: a sharded hd
    # contraction would all-reduce the S^2-scale score tensors
    bspec = ("pod", "data", "model") if cfg.pure_dp else ("pod", "data")
    q = L.maybe_shard(q, bspec, None, tp, None, None)
    k = L.maybe_shard(k, bspec, None, tp, None)
    v = L.maybe_shard(v, bspec, None, tp, None)
    q = L.rope(q.reshape(B, S, K * G, hd), positions, cfg.rope_theta
               ).reshape(B, S, K, G, hd)
    k = L.rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        ck, cv = kv_cache
        zero = jnp.asarray(0, cache_pos.dtype)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (zero, cache_pos, zero, zero))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (zero, cache_pos, zero, zero))
        k_all, v_all = ck, cv
        k_pos = jnp.arange(k_all.shape[1], dtype=jnp.int32)[None, :]
        new_cache = (ck, cv)
    else:
        k_all, v_all = k, v
        k_pos = positions[:, :] if positions.ndim == 2 else \
            positions[None, :]
        new_cache = None

    scale = 1.0 / np.sqrt(hd)
    q_pos = positions if positions.ndim == 2 else positions[None, :]

    def attend(qc, qp):
        """qc [B, C, K, G, hd]; qp [B, C] -> [B, C, K, G, hd].

        Scores stay sharded over kv-heads (consistent with wk/wv weight
        sharding: no per-layer resharding); the q-chunking bounds the
        scores buffer at C*Sk per head — the pure-XLA stand-in for the
        flash kernel's blocking (kernels/flash_attention is the TPU path).
        """
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, k_all) * scale
        s = s.astype(jnp.float32)
        if cfg.attn_softcap > 0.0:
            s = jnp.tanh(s / cfg.attn_softcap) * cfg.attn_softcap
        causal = k_pos[:, None, :] <= qp[:, :, None]  # [B, C, Sk]
        win_ok = jnp.where(window > 0,
                           k_pos[:, None, :] > qp[:, :, None] - window,
                           True)
        mask = (causal & win_ok)[:, None, None, :, :]
        s = jnp.where(mask, s, -1e30)
        probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", probs, v_all)

    CQ = min(S, 512)
    if S % CQ != 0 or cfg.scan_unroll:
        # dense path: irregular smoke shapes, and cost probes (one einsum
        # gives the exact attention flops without unrolled chunk bodies)
        CQ = S
    if S == CQ:
        out = attend(q, q_pos)
    else:
        nq = S // CQ
        qs = q.reshape(B, nq, CQ, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
        ps = jnp.broadcast_to(q_pos, (B, S)).reshape(B, nq, CQ
                                                     ).transpose(1, 0, 2)

        def body(_, qp):
            return None, attend(*qp)

        _, outs = jax.lax.scan(body, None, (qs, ps),
                               unroll=cfg.scan_unroll)
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, hd)
    out = out.reshape(B, S, H * hd)
    return jnp.einsum("bsh,hd->bsd", out, wo), new_cache


def _moe_mlp(x2d, lp, cfg: TransformerConfig = None):
    """Sort-based capacity-bounded MoE dispatch.  x2d [T, d]."""
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    C = int(np.ceil(cfg.capacity_factor * T * k / E / 8) * 8)
    logits = jnp.einsum("td,de->te", x2d, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)  # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    ids = topi.reshape(-1).astype(jnp.int32)  # [T*k]
    wts = topv.reshape(-1)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(ids, stable=True).astype(jnp.int32)
    sid = ids[order]
    first = jnp.searchsorted(sid, sid, side="left").astype(jnp.int32)
    rank = jnp.arange(T * k, dtype=jnp.int32) - first
    keep = rank < C
    slot = jnp.where(keep, sid * C + rank, E * C)

    buf = jnp.zeros((E * C, d), x2d.dtype)
    buf = buf.at[slot].set(x2d[tok[order]], mode="drop")
    # expert-parallel buffer [E, C, d]: experts on the model axis (when E
    # divides it) and capacity on the DP axes, so per-chip MoE flops scale
    # as tokens/chips even when E < |model| (mixtral)
    if cfg is not None and cfg.pure_dp:
        # §Perf iter B2: full expert gathers, capacity over every axis —
        # sidesteps XLA's pathological scatter-emulated EP all-to-all
        bufe = L.maybe_shard(buf.reshape(E, C, d), None,
                             ("pod", "data", "model"), None)
        w_in = L.maybe_shard(lp["w_in"], None, None, None)
        w_out = L.maybe_shard(lp["w_out"], None, None, None)
    else:
        bufe = L.maybe_shard(buf.reshape(E, C, d), "model",
                             ("pod", "data"), None)
        w_in = L.maybe_shard(lp["w_in"], "model", None, "model")
        w_out = L.maybe_shard(lp["w_out"], "model", "model", None)
    h = jnp.einsum("ecd,edf->ecf", bufe, w_in)
    gate, up = jnp.split(h, 2, axis=-1)
    g = jax.nn.silu(gate.astype(jnp.float32)) if cfg.act == "silu" \
        else jax.nn.gelu(gate.astype(jnp.float32), approximate=True)
    h = (g * up.astype(jnp.float32)).astype(x2d.dtype)
    eout = jnp.einsum("ecf,efd->ecd", h, w_out).reshape(E * C, d)

    contrib = eout[jnp.minimum(slot, E * C - 1)]
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    out = jnp.zeros((T, d), x2d.dtype)
    out = out.at[tok[order]].add(contrib * wts[order][:, None].astype(
        x2d.dtype))
    # load-balance aux loss (Switch-style)
    frac = jax.ops.segment_sum(jnp.ones_like(wts), ids,
                               num_segments=E) / (T * k)
    mean_prob = probs.mean(0)
    aux = E * jnp.sum(frac * mean_prob)
    return out, aux


def _block(x, lp, cfg: TransformerConfig, positions, window,
           kv_cache=None, cache_pos=None):
    h, new_cache = _attention(
        L.rms_norm(x, lp["ln1"], plus_one=cfg.norm_plus_one), lp, cfg,
        positions, window, kv_cache, cache_pos)
    x = x + h
    y = L.rms_norm(x, lp["ln2"], plus_one=cfg.norm_plus_one)
    if cfg.is_moe:
        B, S, d = y.shape
        # inner checkpoint: the dispatch gathers/scatters are recomputed in
        # backward instead of keeping [T*k, d]-scale intermediates live
        moe = jax.checkpoint(functools.partial(_moe_mlp, cfg=cfg)) \
            if cfg.remat else functools.partial(_moe_mlp, cfg=cfg)
        out, aux = moe(y.reshape(B * S, d), lp)
        y = out.reshape(B, S, d)
    else:
        tp = None if cfg.pure_dp else "model"
        y = L.gated_mlp(y, L.maybe_shard(lp["w_in"], None, tp),
                        L.maybe_shard(lp["w_out"], tp, None), cfg.act)
        aux = jnp.float32(0.0)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def forward(params: Params, tokens: jax.Array, cfg: TransformerConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (final hidden states [B, S, d], aux loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.act_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = jnp.asarray(cfg.layer_windows())

    def body(carry, xs):
        x, aux = carry
        lp, win = xs
        x, _, a = _block(x, lp, cfg, positions, win)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               (params["layers"], windows),
                               unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    return x, aux


def logits_fn(params: Params, hidden: jax.Array,
              cfg: TransformerConfig) -> jax.Array:
    """Tied unembedding.  hidden [..., d] -> logits [..., V]."""
    lg = jnp.einsum("...d,vd->...v", hidden, params["embed"])
    if cfg.final_softcap > 0:
        lg = (jnp.tanh(lg.astype(jnp.float32) / cfg.final_softcap)
              * cfg.final_softcap).astype(lg.dtype)
    return lg


def _chunked_ce(params: Params, hidden: jax.Array, labels: jax.Array,
                cfg: TransformerConfig) -> jax.Array:
    """Cross entropy with the unembedding fused into a sequence-chunked
    scan: the [B, S, V] logits tensor is never materialized (the big-vocab
    archs would otherwise spend gigabytes per device on it)."""
    B, S, d = hidden.shape
    CS = 512 if (S % 512 == 0 and not cfg.scan_unroll) else S
    nc = S // CS

    def chunk(total, xl):
        xc, lc = xl  # [B, CS, d], [B, CS]
        lg = jnp.einsum("bsd,vd->bsv", xc, params["embed"]
                        ).astype(jnp.float32)
        if cfg.final_softcap > 0:
            lg = jnp.tanh(lg / cfg.final_softcap) * cfg.final_softcap
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lc[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return total + (lse - gold).sum(), None

    if nc == 1:
        total, _ = chunk(jnp.float32(0.0), (hidden, labels))
    else:
        xs = (hidden.reshape(B, nc, CS, d).transpose(1, 0, 2, 3),
              labels.reshape(B, nc, CS).transpose(1, 0, 2))
        body = jax.checkpoint(chunk) if cfg.remat else chunk
        total, _ = jax.lax.scan(body, jnp.float32(0.0), xs,
                                unroll=cfg.scan_unroll)
    return total / (B * S)


def loss_fn(params: Params, batch: Dict[str, jax.Array],
            cfg: TransformerConfig) -> Tuple[jax.Array, Dict[str, Any]]:
    hidden, aux = forward(params, batch["tokens"], cfg)
    ce = _chunked_ce(params, hidden, batch["labels"], cfg)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---- serving ---------------------------------------------------------------

def make_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None) -> Dict[str, jax.Array]:
    dtype = dtype or cfg.act_dtype
    shape = (cfg.num_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache(cfg: TransformerConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: make_cache(cfg, batch, max_seq))


def cache_logical_axes(cfg: TransformerConfig, shard_seq: bool = True):
    """KV cache [L, B, S, K, hd]: batch over the DP axes, sequence over the
    model axis (32k-500k caches are the dominant serving footprint; the
    shape-aware rules drop whichever axis does not divide, e.g. batch=1 at
    long_500k)."""
    ax = (None, "batch", "seq_shard" if shard_seq else None, None, None)
    return {"k": ax, "v": ax}


def decode_step(params: Params, cache: Dict[str, jax.Array],
                tokens: jax.Array, pos: jax.Array,
                cfg: TransformerConfig):
    """One decode step.  tokens [B, 1]; pos [] int32 (current length).

    Returns (logits [B, V], new cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.act_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    windows = jnp.asarray(cfg.layer_windows())

    def body(x, xs):
        lp, win, ck, cv = xs
        y, new_cache, _ = _block(x, lp, cfg, positions, win,
                                 kv_cache=(ck, cv), cache_pos=pos)
        return y, new_cache

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k"], cache["v"]),
        unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    logits = logits_fn(params, x[:, 0], cfg)
    return logits, {"k": nk, "v": nv}


def prefill(params: Params, tokens: jax.Array, cfg: TransformerConfig):
    """Prefill: full forward, returning last-position logits and the cache.

    tokens [B, S] -> (logits [B, V], cache with k/v [L, B, S, K, hd])."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.act_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = jnp.asarray(cfg.layer_windows())
    K, hd = cfg.n_kv_heads, cfg.head_dim

    def body(x, xs):
        lp, win = xs
        # recompute k/v for cache emission (cheap relative to attention)
        xn = L.rms_norm(x, lp["ln1"], plus_one=cfg.norm_plus_one)
        k = jnp.einsum("bsd,dh->bsh", xn, lp["wk"]).reshape(B, S, K, hd)
        k = L.rope(k, positions, cfg.rope_theta)
        v = jnp.einsum("bsd,dh->bsh", xn, lp["wv"]).reshape(B, S, K, hd)
        y, _, _ = _block(x, lp, cfg, positions, win)
        return y, (k.astype(cfg.act_dtype), v.astype(cfg.act_dtype))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, (params["layers"], windows),
                               unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    logits = logits_fn(params, x[:, -1], cfg)
    return logits, {"k": ks, "v": vs}
