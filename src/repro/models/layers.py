"""Shared layers: initializers, norms, RoPE, MLPs — pure functions over
param dicts, with logical-axis metadata built alongside."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def maybe_shard(x: jax.Array, *spec) -> jax.Array:
    """Mesh- and shape-aware with_sharding_constraint.

    Degrades gracefully: outside a mesh context it is a no-op; axes missing
    from the mesh or not dividing the dimension are dropped (e.g. 4 kv
    heads cannot shard over a 16-way model axis — the constraint then
    leaves that dim unsharded instead of erroring)."""
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        sizes = dict(mesh.shape)
        clean = []
        used = set()
        for dim, s in zip(x.shape, spec):
            cands = s if isinstance(s, tuple) else (s,)
            kept, prod = [], 1
            for a in cands:
                if a is None or a not in sizes or a in used:
                    continue
                if dim % (prod * sizes[a]) == 0:
                    kept.append(a)
                    used.add(a)
                    prod *= sizes[a]
            clean.append(tuple(kept) if len(kept) > 1
                         else (kept[0] if kept else None))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*clean))
    except (RuntimeError, ValueError, KeyError, TypeError, ImportError):
        return x


def he_init(rng, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    std = (2.0 / max(fan, 1)) ** 0.5
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32)
            * (1.0 / shape[-1] ** 0.5)).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm; ``plus_one`` is the gemma convention (scale = 1 + w)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one \
        else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
         ) -> jax.Array:
    """Rotary embedding.  x [..., S, H, Dh]; positions [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def gated_mlp(x: jax.Array, w_in: jax.Array, w_out: jax.Array,
              act: str = "silu") -> jax.Array:
    """SwiGLU / GeGLU: w_in [d, 2*ff] packs (gate, up)."""
    h = jnp.einsum("...d,df->...f", x, w_in)
    gate, up = jnp.split(h, 2, axis=-1)
    g = jax.nn.silu(gate.astype(jnp.float32)) if act == "silu" \
        else jax.nn.gelu(gate.astype(jnp.float32), approximate=True)
    h = (g * up.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_out)


def mlp(x: jax.Array, w1: jax.Array, b1, w2: jax.Array, b2,
        act: str = "relu") -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w1) + b1
    h = jax.nn.relu(h) if act == "relu" else jax.nn.silu(h)
    return jnp.einsum("...f,fo->...o", h, w2) + b2


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       softcap: float = 0.0) -> jax.Array:
    """Mean token cross entropy; logsumexp in f32.  logits [..., V]."""
    lg = logits.astype(jnp.float32)
    if softcap > 0.0:
        lg = jnp.tanh(lg / softcap) * softcap
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)
