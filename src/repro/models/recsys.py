"""Two-tower retrieval (RecSys'19-style) with native EmbeddingBag.

JAX has no EmbeddingBag — per the assignment, the lookup IS part of the
system: ``jnp.take`` over row-sharded tables + mean pooling (a segment_sum
in disguise; the Pallas segment-ops kernel serves the explicit-bag path).

Shapes:
  train_batch     — in-batch + shared sampled-negative softmax
  serve_p99/bulk  — user-tower inference + dot against request items
  retrieval_cand  — one query scored against 1M candidates (batched matmul
                    + top-k, never a loop)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    # (table name, rows) — user side bags; item table separate
    user_tables: Tuple[Tuple[str, int], ...] = (
        ("user_id", 10_000_000), ("hist_items", 1_000_000),
        ("context", 100_000))
    num_items: int = 1_000_000
    multi_hot: int = 8
    num_negatives: int = 1024
    use_kernel: bool = False
    param_dtype: Any = jnp.float32

    def param_count(self) -> int:
        rows = sum(r for _, r in self.user_tables) + self.num_items
        mlp = 0
        din = self.embed_dim * len(self.user_tables)
        for h in self.tower_mlp:
            mlp += din * h + h
            din = h
        din = self.embed_dim
        for h in self.tower_mlp:
            mlp += din * h + h
            din = h
        return rows * self.embed_dim + mlp


def init(rng: jax.Array, cfg: TwoTowerConfig) -> Params:
    ks = jax.random.split(rng, 4 + len(cfg.user_tables))
    pd = cfg.param_dtype
    params: Params = {"tables": {}, "user_mlp": [], "item_mlp": []}
    for i, (name, rows) in enumerate(cfg.user_tables):
        params["tables"][name] = L.embed_init(ks[i], (rows, cfg.embed_dim),
                                              pd)
    params["item_table"] = L.embed_init(ks[-4], (cfg.num_items,
                                                 cfg.embed_dim), pd)
    din = cfg.embed_dim * len(cfg.user_tables)
    kk = jax.random.split(ks[-3], len(cfg.tower_mlp))
    for k, h in zip(kk, cfg.tower_mlp):
        params["user_mlp"].append({"w": L.he_init(k, (din, h), pd),
                                   "b": jnp.zeros(h, pd)})
        din = h
    din = cfg.embed_dim
    kk = jax.random.split(ks[-2], len(cfg.tower_mlp))
    for k, h in zip(kk, cfg.tower_mlp):
        params["item_mlp"].append({"w": L.he_init(k, (din, h), pd),
                                   "b": jnp.zeros(h, pd)})
        din = h
    return params


def abstract_params(cfg: TwoTowerConfig) -> Params:
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


def logical_axes(cfg: TwoTowerConfig) -> Params:
    ax: Params = {"tables": {}, "user_mlp": [], "item_mlp": []}
    for name, _ in cfg.user_tables:
        ax["tables"][name] = ("table_rows", None)
    ax["item_table"] = ("table_rows", None)
    for _ in cfg.tower_mlp:
        ax["user_mlp"].append({"w": (None, "mlp"), "b": ("mlp",)})
        ax["item_mlp"].append({"w": (None, "mlp"), "b": ("mlp",)})
    return ax


def embedding_bag(table: jax.Array, ids: jax.Array,
                  use_kernel: bool = False) -> jax.Array:
    """Mean-pooled bag lookup.  ids [B, M] -> [B, D].

    ``use_kernel`` demonstrates the explicit-bag path: flatten lookups and
    reduce with the Pallas segment-sum kernel (ids as segments)."""
    B, M = ids.shape
    if use_kernel:
        from repro.kernels.segment_ops.ops import segment_sum
        flat = jnp.take(table, ids.reshape(-1), axis=0)
        bag = jnp.repeat(jnp.arange(B, dtype=jnp.int32), M)
        return (segment_sum(flat, bag, B, is_sorted=True) / M
                ).astype(table.dtype)
    return jnp.take(table, ids, axis=0).mean(axis=1)


def _tower(mlp_params, x):
    for i, layer in enumerate(mlp_params):
        x = jnp.einsum("bd,df->bf", x, layer["w"]) + layer["b"]
        if i < len(mlp_params) - 1:
            x = jax.nn.relu(x)
    # L2-normalized output embeddings (retrieval convention)
    return x * jax.lax.rsqrt(
        jnp.sum(jnp.square(x), -1, keepdims=True) + 1e-12)


def user_embedding(params: Params, feats: Dict[str, jax.Array],
                   cfg: TwoTowerConfig) -> jax.Array:
    cols = [embedding_bag(params["tables"][name], feats[name],
                          cfg.use_kernel)
            for name, _ in cfg.user_tables]
    return _tower(params["user_mlp"], jnp.concatenate(cols, -1))


def item_embedding(params: Params, item_ids: jax.Array,
                   cfg: TwoTowerConfig) -> jax.Array:
    emb = jnp.take(params["item_table"], item_ids, axis=0)
    return _tower(params["item_mlp"], emb)


def loss_fn(params: Params, batch: Dict[str, jax.Array],
            cfg: TwoTowerConfig) -> Tuple[jax.Array, Dict[str, Any]]:
    """Sampled softmax: positives on the diagonal, shared negatives from the
    first ``num_negatives`` in-batch items."""
    u = user_embedding(params, batch["feats"], cfg)  # [B, D]
    it = item_embedding(params, batch["item_ids"], cfg)  # [B, D]
    temp = 20.0
    pos = jnp.sum(u * it, -1, keepdims=True) * temp  # [B, 1]
    neg = jnp.einsum("bd,nd->bn", u,
                     it[:cfg.num_negatives]) * temp  # [B, Nneg]
    # mask the accidental positive among negatives
    bidx = jnp.arange(u.shape[0])[:, None]
    nidx = jnp.arange(min(cfg.num_negatives, u.shape[0]))[None, :]
    neg = jnp.where(bidx == nidx, -1e30, neg[:, :nidx.shape[1]])
    logits = jnp.concatenate([pos, neg], -1).astype(jnp.float32)
    loss = jnp.mean(jax.scipy.special.logsumexp(logits, -1)
                    - logits[:, 0])
    return loss, {"pos_score": pos.mean() / temp}


def serve_scores(params: Params, feats: Dict[str, jax.Array],
                 item_ids: jax.Array, cfg: TwoTowerConfig) -> jax.Array:
    """Online/bulk inference: score each (user, item) pair.  [B]."""
    u = user_embedding(params, feats, cfg)
    it = item_embedding(params, item_ids, cfg)
    return jnp.sum(u * it, -1)


def retrieval_topk(params: Params, feats: Dict[str, jax.Array],
                   cand_ids: jax.Array, cfg: TwoTowerConfig,
                   k: int = 100):
    """One query against n_candidates: batched matmul + top-k."""
    u = user_embedding(params, feats, cfg)  # [1, D]
    it = item_embedding(params, cand_ids, cfg)  # [C, D]
    scores = jnp.einsum("bd,cd->bc", u, it)[0]  # [C]
    return jax.lax.top_k(scores, k)
