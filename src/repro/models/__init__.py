"""Model zoo: LM transformers (dense + MoE), GNNs, recsys two-tower.

Every model follows the same functional contract:

    init(rng, cfg)            -> params pytree (real arrays; smoke configs)
    abstract_params(cfg)      -> ShapeDtypeStruct pytree (dry-run, no alloc)
    logical_axes(cfg)         -> pytree of logical-axis tuples (sharding)
    loss_fn / train_step / serve-path functions

Dtype discipline: parameters bf16 (configurable), activations bf16, softmax
and reductions f32, optimizer moments f32.
"""
