"""GNN family: EGNN, GatedGCN, GAT, GraphCast-style encoder-processor-decoder.

JAX has no sparse message passing — per the assignment, scatter/gather IS
part of the system: messages flow through ``segment_sum``/``segment_max``
over an edge list (dst-sorted edges can route through the Pallas
segment-ops kernel).  All four archs share one graph-batch convention:

    batch = {
      "feats":  [N, F] f32,   "coords": [N, 3] (EGNN only),
      "edge_src": [E] i32, "edge_dst": [E] i32, "edge_mask": [E] bool,
      "labels": [N] i32 / [N, out] f32 / [G] f32, "label_mask": [N] bool,
      "graph_id": [N] i32 (molecule batches),
    }

Padded nodes/edges are masked, so one static shape serves sampled
minibatches (the union-graph flattening of sampler blocks), full batches,
and molecule batches.  Layer stacks run under lax.scan (compact HLO for the
16-layer processor at ogb_products scale).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str  # "egnn" | "gatedgcn" | "gat" | "graphcast"
    n_layers: int
    d_hidden: int
    d_in: int
    d_out: int
    n_heads: int = 1
    aggregator: str = "sum"  # "sum" | "gated" | "attn"
    task: str = "node_class"  # "node_class" | "node_reg" | "graph_reg"
    use_kernel: bool = False  # route aggregation through Pallas segment_sum
    param_dtype: Any = jnp.float32
    act_dtype: Any = jnp.float32
    scan_unroll: bool = False  # dry-run depth probes: exact HLO cost

    def param_count(self) -> int:
        p = abstract_params(self)
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))


def _shard_rows(x):
    """Row dimension (nodes or edges) over the DP axes: keeps per-edge
    message tensors and per-node aggregates partitioned instead of
    replicated (62M-edge graphs would otherwise materialize TB-scale
    temporaries per device)."""
    return L.maybe_shard(x, ("pod", "data"), *([None] * (x.ndim - 1)))


def _segsum(cfg: GNNConfig, data, seg, num_segments):
    if cfg.use_kernel:
        from repro.kernels.segment_ops.ops import segment_sum
        return _shard_rows(
            segment_sum(data, seg, num_segments).astype(data.dtype))
    return _shard_rows(
        jax.ops.segment_sum(data, seg, num_segments=num_segments))


def _mlp2_init(rng, din, dh, dout, dtype):
    k1, k2 = jax.random.split(rng)
    return {"w1": L.he_init(k1, (din, dh), dtype),
            "b1": jnp.zeros(dh, dtype),
            "w2": L.he_init(k2, (dh, dout), dtype),
            "b2": jnp.zeros(dout, dtype)}


def _mlp2(p, x):
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w1"]) + p["b1"])
    return jnp.einsum("...f,fo->...o", h, p["w2"]) + p["b2"]


def _mlp2_axes():
    return {"w1": (None, "feat"), "b1": ("feat",),
            "w2": ("feat", None), "b2": (None,)}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(rng: jax.Array, cfg: GNNConfig) -> Params:
    d, Lr = cfg.d_hidden, cfg.n_layers
    pd = cfg.param_dtype
    ks = jax.random.split(rng, 8)

    def stack_init(key, fn):
        keys = jax.random.split(key, Lr)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[fn(k) for k in keys])

    params: Params = {
        "encode": _mlp2_init(ks[0], cfg.d_in, d, d, pd),
        "decode": _mlp2_init(ks[1], d, d, cfg.d_out, pd),
    }
    if cfg.arch == "egnn":
        params["layers"] = stack_init(ks[2], lambda k: {
            "phi_e": _mlp2_init(jax.random.fold_in(k, 0), 2 * d + 1, d, d,
                                pd),
            "phi_x": _mlp2_init(jax.random.fold_in(k, 1), d, d, 1, pd),
            "phi_h": _mlp2_init(jax.random.fold_in(k, 2), 2 * d, d, d, pd),
        })
    elif cfg.arch == "gatedgcn":
        params["layers"] = stack_init(ks[2], lambda k: {
            "A": L.he_init(jax.random.fold_in(k, 0), (d, d), pd),
            "B": L.he_init(jax.random.fold_in(k, 1), (d, d), pd),
            "C": L.he_init(jax.random.fold_in(k, 2), (d, d), pd),
            "U": L.he_init(jax.random.fold_in(k, 3), (d, d), pd),
            "V": L.he_init(jax.random.fold_in(k, 4), (d, d), pd),
            "ln_h": jnp.ones(d, pd), "ln_e": jnp.ones(d, pd),
        })
        params["edge_encode"] = _mlp2_init(ks[3], 1, d, d, pd)
    elif cfg.arch == "gat":
        H, dh = cfg.n_heads, d // cfg.n_heads
        params["layers"] = stack_init(ks[2], lambda k: {
            "W": L.he_init(jax.random.fold_in(k, 0), (d, d), pd),
            "a_src": L.he_init(jax.random.fold_in(k, 1), (H, dh), pd),
            "a_dst": L.he_init(jax.random.fold_in(k, 2), (H, dh), pd),
        })
    elif cfg.arch == "graphcast":
        params["layers"] = stack_init(ks[2], lambda k: {
            "edge_mlp": _mlp2_init(jax.random.fold_in(k, 0), 3 * d, d, d,
                                   pd),
            "node_mlp": _mlp2_init(jax.random.fold_in(k, 1), 2 * d, d, d,
                                   pd),
            "ln_h": jnp.ones(d, pd), "ln_e": jnp.ones(d, pd),
        })
        params["edge_encode"] = _mlp2_init(ks[3], 1, d, d, pd)
    else:
        raise ValueError(cfg.arch)
    return params


def abstract_params(cfg: GNNConfig) -> Params:
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


def logical_axes(cfg: GNNConfig) -> Params:
    def like(p):
        return jax.tree.map(lambda x: tuple([None] * (x.ndim - 1) +
                                            ["feat"]) if x.ndim else (),
                            p)
    # feature dims stay replicated by default; nodes/edges shard via inputs
    return jax.tree.map(lambda x: tuple(None for _ in x.shape),
                        abstract_params(cfg))


# ---------------------------------------------------------------------------
# message-passing layers
# ---------------------------------------------------------------------------

def _egnn_layer(lp, h, x, src, dst, emask, N, cfg):
    hi, hj = _shard_rows(h[dst]), _shard_rows(h[src])
    xi, xj = _shard_rows(x[dst]), _shard_rows(x[src])
    d2 = jnp.sum((xi - xj) ** 2, -1, keepdims=True)
    m = _mlp2(lp["phi_e"], jnp.concatenate([hi, hj, d2], -1))
    m = jnp.where(emask[:, None], m, 0.0)
    m = _shard_rows(m)
    w = _mlp2(lp["phi_x"], m)
    xupd = _segsum(cfg, (xi - xj) * w / (d2 + 1.0), dst, N)
    magg = _segsum(cfg, m, dst, N)
    h2 = h + _mlp2(lp["phi_h"], jnp.concatenate([h, magg], -1))
    return h2, x + 0.1 * xupd


def _gatedgcn_layer(lp, h, e, src, dst, emask, N, cfg):
    eh = _shard_rows(jnp.einsum("nd,df->nf", h, lp["A"])[dst]) \
        + _shard_rows(jnp.einsum("nd,df->nf", h, lp["B"])[src]) \
        + jnp.einsum("ed,df->ef", e, lp["C"])
    e2 = e + jax.nn.silu(L.rms_norm(eh, lp["ln_e"]))
    gate = jax.nn.sigmoid(e2) * emask[:, None]
    vh = _shard_rows(jnp.einsum("nd,df->nf", h, lp["V"])[src])
    num = _segsum(cfg, gate * vh, dst, N)
    den = _segsum(cfg, gate, dst, N) + 1e-6
    h2 = h + jax.nn.silu(L.rms_norm(
        jnp.einsum("nd,df->nf", h, lp["U"]) + num / den, lp["ln_h"]))
    return h2, e2


def _gat_layer(lp, h, src, dst, emask, N, cfg):
    H = cfg.n_heads
    d = h.shape[-1]
    dh = d // H
    z = jnp.einsum("nd,df->nf", h, lp["W"]).reshape(N, H, dh)
    s_src = jnp.einsum("nhd,hd->nh", z, lp["a_src"])
    s_dst = jnp.einsum("nhd,hd->nh", z, lp["a_dst"])
    score = jax.nn.leaky_relu(_shard_rows(s_src[src])
                              + _shard_rows(s_dst[dst]), 0.2)  # [E, H]
    score = jnp.where(emask[:, None], score, -1e30)
    smax = jax.ops.segment_max(score, dst, num_segments=N)
    ex = jnp.exp(score - smax[dst]) * emask[:, None]
    den = _segsum(cfg, ex, dst, N) + 1e-9
    alpha = ex / den[dst]
    msg = _shard_rows((alpha[..., None] * _shard_rows(z[src])
                       ).reshape(-1, d))
    out = _segsum(cfg, msg, dst, N).reshape(N, H, dh)
    return jax.nn.elu(out.reshape(N, d))


def _graphcast_layer(lp, h, e, src, dst, emask, N, cfg):
    em = _mlp2(lp["edge_mlp"],
               jnp.concatenate([L.rms_norm(e, lp["ln_e"]),
                                _shard_rows(h[src]),
                                _shard_rows(h[dst])], -1))
    e2 = _shard_rows(e + jnp.where(emask[:, None], em, 0.0))
    agg = _segsum(cfg, e2 * emask[:, None], dst, N)
    h2 = h + _mlp2(lp["node_mlp"],
                   jnp.concatenate([L.rms_norm(h, lp["ln_h"]), agg], -1))
    return h2, e2


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(params: Params, batch: Dict[str, jax.Array], cfg: GNNConfig
            ) -> jax.Array:
    feats = batch["feats"].astype(cfg.act_dtype)
    src = batch["edge_src"].astype(jnp.int32)
    dst = batch["edge_dst"].astype(jnp.int32)
    emask = batch.get("edge_mask")
    if emask is None:
        emask = jnp.ones(src.shape[0], bool)
    N = feats.shape[0]
    h = _shard_rows(_mlp2(params["encode"], feats))

    if cfg.arch == "egnn":
        x = batch["coords"].astype(cfg.act_dtype)

        def body(carry, lp):
            h, x = carry
            return _egnn_layer(lp, h, x, src, dst, emask, N, cfg), None

        (h, x), _ = jax.lax.scan(body, (h, x), params["layers"],
                                 unroll=cfg.scan_unroll)
    elif cfg.arch in ("gatedgcn", "graphcast"):
        dist = batch.get("edge_feats")
        if dist is None:
            dist = jnp.ones((src.shape[0], 1), cfg.act_dtype)
        e = _shard_rows(_mlp2(params["edge_encode"], dist))
        layer = _gatedgcn_layer if cfg.arch == "gatedgcn" \
            else _graphcast_layer

        def body(carry, lp):
            h, e = carry
            return layer(lp, h, e, src, dst, emask, N, cfg), None

        (h, e), _ = jax.lax.scan(body, (h, e), params["layers"],
                                 unroll=cfg.scan_unroll)
    elif cfg.arch == "gat":
        def body(h, lp):
            return _gat_layer(lp, h, src, dst, emask, N, cfg), None

        h, _ = jax.lax.scan(body, h, params["layers"],
                            unroll=cfg.scan_unroll)
    else:
        raise ValueError(cfg.arch)

    if cfg.task == "graph_reg":
        gid = batch["graph_id"].astype(jnp.int32)
        G = int(batch["labels"].shape[0])
        pooled = _segsum(cfg, h, gid, G)
        return _mlp2(params["decode"], pooled)  # [G, d_out]
    return _mlp2(params["decode"], h)  # [N, d_out]


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: GNNConfig
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    out = forward(params, batch, cfg)
    mask = batch.get("label_mask")
    if cfg.task == "node_class":
        labels = batch["labels"].astype(jnp.int32)
        lg = out.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lg, -1)
        gold = jnp.take_along_axis(lg, labels[:, None], 1)[:, 0]
        per = lse - gold
        if mask is not None:
            per = jnp.where(mask, per, 0.0)
            loss = per.sum() / jnp.maximum(mask.sum(), 1)
        else:
            loss = per.mean()
        acc = (lg.argmax(-1) == labels)
        acc = (jnp.where(mask, acc, False).sum()
               / jnp.maximum(mask.sum(), 1)) if mask is not None \
            else acc.mean()
        return loss, {"acc": acc}
    # regression (node or graph)
    err = (out.astype(jnp.float32)
           - batch["labels"].astype(jnp.float32)) ** 2
    if mask is not None and cfg.task == "node_reg":
        err = jnp.where(mask[:, None], err, 0.0)
        loss = err.sum() / jnp.maximum(mask.sum() * out.shape[-1], 1)
    else:
        loss = err.mean()
    return loss, {"mse": loss}
