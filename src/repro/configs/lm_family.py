"""LM-family ArchSpec builder: train / prefill / decode / long-context cells.

The dry-run lowers the *full* update step for training cells (fwd + bwd +
AdamW, params/opt donated) and the cache-carrying decode step for serving
cells — the complete memory story, not just a forward pass.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec, Cell
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, cosine_decay

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256, microbatches=8),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, shard_seq=True),
}


def _batch_axes(pure_dp=False):
    name = "batch_dp3" if pure_dp else "batch"
    return {"tokens": (name, None), "labels": (name, None)}


def _opt_axes(params_axes):
    from repro.optim.adamw import AdamWState
    return AdamWState((), params_axes, params_axes)


def make_train_step(cfg: T.TransformerConfig, schedule=None,
                    microbatches: int = 1):
    """Full update step.  ``microbatches`` > 1 runs gradient accumulation:
    activations scale with B/M while the optimizer still sees the global
    batch (the paper's low-memory batching discipline applied to training —
    DESIGN.md §4)."""
    sched = schedule or cosine_decay(3e-4, 2000, 100_000)

    def train_step(params, opt, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                T.loss_fn, has_aux=True)(params, batch, cfg)
        else:
            M = microbatches

            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(
                    T.loss_fn, has_aux=True)(params, mb, cfg)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                micro, (g0, jnp.float32(0.0)), mbs,
                unroll=cfg.scan_unroll)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
            metrics = {}
        params, opt, gnorm = adamw_update(params, grads, opt,
                                          lr=sched(opt.step))
        return params, opt, {"loss": loss, "gnorm": gnorm, **metrics}

    return train_step


def lm_arch(arch_id: str, describe: str, full: T.TransformerConfig,
            smoke: T.TransformerConfig,
            long_ok: Optional[bool] = None) -> ArchSpec:
    long_ok = full.sub_quadratic if long_ok is None else long_ok

    def build_train(shape, cfg_override=None):
        def build(mesh=None):
            cfg = cfg_override or full
            # §Perf iter A3: dense train cells run pure-DP (ZeRO-3), no
            # microbatching needed (per-chip activations are 1/|mesh|).
            # MoE keeps EP + microbatching: full-DP expert gathers measured
            # WORSE (B2 refuted — the dispatch scatter dominates either way
            # and full gathers blow the temp footprint).
            import dataclasses as _d
            pure = not cfg.is_moe
            cfg = _d.replace(cfg, pure_dp=pure)
            M = 1 if pure else shape.get("microbatches", 1)
            params = T.abstract_params(cfg)
            opt = jax.eval_shape(adamw_init, params)
            B, S = shape["batch"], shape["seq"]
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            p_ax = T.logical_axes(cfg)
            axes = (p_ax, _opt_axes(p_ax), _batch_axes(pure))
            step = make_train_step(cfg, microbatches=M)
            return step, (params, opt, batch), axes, (0, 1)
        return build

    def build_prefill(shape, cfg_override=None):
        def build(mesh=None):
            cfg = cfg_override or full
            params = T.abstract_params(cfg)
            B, S = shape["batch"], shape["seq"]
            tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
            axes = (T.logical_axes(cfg), ("batch", None))
            step = functools.partial(T.prefill, cfg=cfg)
            return step, (params, tokens), axes, ()
        return build

    def build_decode(shape, cfg_override=None):
        def build(mesh=None):
            cfg = cfg_override or full
            params = T.abstract_params(cfg)
            B, S = shape["batch"], shape["seq"]
            cache = T.abstract_cache(cfg, B, S)
            tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            axes = (T.logical_axes(cfg), T.cache_logical_axes(cfg),
                    ("batch", None), ())
            step = functools.partial(T.decode_step, cfg=cfg)
            return step, (params, cache, tokens, pos), axes, (1,)
        return build

    import dataclasses as _dc

    cells: Dict[str, Cell] = {}
    period = max(full.local_global_period, 1)
    for name, shape in SHAPES.items():
        kind = shape["kind"]
        skip = None
        if name == "long_500k" and not long_ok:
            skip = ("pure full-attention architecture: 524k dense attention "
                    "is out of assignment scope (see DESIGN.md §4)")
        maker = {"train": build_train, "prefill": build_prefill,
                 "decode": build_decode}[kind]

        # probes: unrolled layer scan at two depths; train probes drop
        # microbatching but keep the full batch (one fwd+bwd over B tokens
        # equals the M-microbatch total, and per-chip sharding matches)
        pshape = dict(shape)
        scale = 1.0
        if kind == "train":
            pshape["microbatches"] = 1

        def probe(mesh, depth, maker=maker, pshape=pshape):
            cfg2 = _dc.replace(full, num_layers=depth, scan_unroll=True)
            return maker(pshape, cfg2)(mesh)

        cells[name] = Cell(name, kind, maker(shape), skip, probe,
                           (period, 2 * period), full.num_layers, scale)

    def smoke_run(cfg=None):
        cfg = cfg or smoke
        from repro.data import TokenStream
        rng = jax.random.PRNGKey(0)
        params = T.init(rng, cfg)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg))
        ts = TokenStream(cfg.vocab, 2, 32, seed=0)
        losses = []
        for s in range(2):
            b = ts.batch_at(s)
            batch = {"tokens": jnp.asarray(b[:, :-1]),
                     "labels": jnp.asarray(b[:, 1:])}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        # decode path shape check
        cache = T.make_cache(cfg, 1, 16)
        lg, cache = jax.jit(functools.partial(T.decode_step, cfg=cfg))(
            params, cache, jnp.zeros((1, 1), jnp.int32),
            jnp.asarray(0, jnp.int32))
        assert lg.shape == (1, cfg.vocab)
        assert np.isfinite(np.asarray(lg, np.float32)).all()
        return {"loss_first": losses[0], "loss_last": losses[-1]}

    def model_flops(shape_name: str) -> float:
        shape = SHAPES[shape_name]
        n_active = full.active_param_count()
        tokens = shape["batch"] * (shape["seq"]
                                   if shape["kind"] != "decode" else 1)
        factor = 6.0 if shape["kind"] == "train" else 2.0
        return factor * n_active * tokens

    return ArchSpec(arch_id, "lm", describe, full, smoke, cells, smoke_run,
                    model_flops)
