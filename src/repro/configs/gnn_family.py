"""GNN-family ArchSpec builder: the four assigned graph shapes.

Shapes span three execution regimes: full-batch small (cora), sampled
minibatch (reddit-scale: the neighbor-sampler blocks flattened to one padded
union graph), full-batch large (ogbn-products), and batched small graphs
(molecule).  One padded-graph convention serves all (models/gnn.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec, Cell
from repro.models import gnn as G
from repro.optim import adamw_init, adamw_update, cosine_decay

# (name, dict) — node/edge counts from the assignment; d_feat/classes from
# the public datasets these shapes correspond to (cora / reddit / products).
SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          n_classes=7, kind="train"),
    "minibatch_lg": dict(n_nodes=164_864, n_edges=163_840, d_feat=602,
                         n_classes=41, kind="train",
                         note="1024 seeds x fanout 15-10 union graph of the"
                              " 232,965-node graph"),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                         n_classes=47, kind="train"),
    "molecule": dict(n_nodes=30 * 128, n_edges=64 * 128, d_feat=16,
                     n_graphs=128, kind="train"),
}


def _shape_cfg(base: G.GNNConfig, shape: Dict) -> G.GNNConfig:
    """Bind d_in/d_out/task to the dataset shape."""
    task = base.task
    if "n_graphs" in shape:
        task = "graph_reg"
        d_out = 1
    elif task == "node_class":
        d_out = shape["n_classes"]
    else:
        d_out = base.d_out
    return dataclasses.replace(base, d_in=shape["d_feat"], d_out=d_out,
                               task=task)


def make_train_step(cfg: G.GNNConfig, schedule=None):
    sched = schedule or cosine_decay(1e-3, 100, 10_000)

    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            G.loss_fn, has_aux=True)(params, batch, cfg)
        params, opt, gnorm = adamw_update(params, grads, opt,
                                          lr=sched(opt.step),
                                          weight_decay=0.0)
        return params, opt, {"loss": loss, **metrics}

    return train_step


def _abstract_batch(cfg: G.GNNConfig, shape: Dict):
    # pad node/edge counts to mesh-divisible sizes (512 covers the 2x16x16
    # production mesh); the assignment's exact counts ride in the masks.
    # Without this, odd counts (e.g. 2,449,029 nodes) defeat every sharding
    # rule and the graph replicates per chip.
    N = -(-shape["n_nodes"] // 512) * 512
    E = -(-shape["n_edges"] // 512) * 512
    batch = {
        "feats": jax.ShapeDtypeStruct((N, shape["d_feat"]), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((E,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
        "label_mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
    }
    axes = {
        "feats": ("nodes", None), "edge_src": ("edges",),
        "edge_dst": ("edges",), "edge_mask": ("edges",),
        "label_mask": ("nodes",),
    }
    if cfg.arch == "egnn":
        batch["coords"] = jax.ShapeDtypeStruct((N, 3), jnp.float32)
        axes["coords"] = ("nodes", None)
    if cfg.arch in ("gatedgcn", "graphcast"):
        batch["edge_feats"] = jax.ShapeDtypeStruct((E, 1), jnp.float32)
        axes["edge_feats"] = ("edges", None)
    if cfg.task == "graph_reg":
        Gn = shape["n_graphs"]
        batch["graph_id"] = jax.ShapeDtypeStruct((N,), jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((Gn, 1), jnp.float32)
        axes["graph_id"] = ("nodes",)
        axes["labels"] = ("batch", None)
    elif cfg.task == "node_class":
        batch["labels"] = jax.ShapeDtypeStruct((N,), jnp.int32)
        axes["labels"] = ("nodes",)
    else:
        batch["labels"] = jax.ShapeDtypeStruct((N, cfg.d_out), jnp.float32)
        axes["labels"] = ("nodes", None)
    return batch, axes


def _param_axes_like(params):
    return jax.tree.map(lambda x: tuple(None for _ in x.shape), params)


def gnn_arch(arch_id: str, describe: str, base: G.GNNConfig,
             smoke: G.GNNConfig) -> ArchSpec:
    cells: Dict[str, Cell] = {}
    for name, shape in SHAPES.items():
        def build(mesh=None, shape=shape, cfg_override=None):
            cfg = cfg_override or _shape_cfg(base, shape)
            params = G.abstract_params(cfg)
            opt = jax.eval_shape(adamw_init, params)
            batch, baxes = _abstract_batch(cfg, shape)
            p_ax = _param_axes_like(params)
            from repro.optim.adamw import AdamWState
            axes = (p_ax, AdamWState((), p_ax, p_ax), baxes)
            return make_train_step(cfg), (params, opt, batch), axes, (0, 1)

        def probe(mesh, depth, shape=shape, build=build):
            cfg2 = dataclasses.replace(_shape_cfg(base, shape),
                                       n_layers=depth, scan_unroll=True)
            return build(mesh, cfg_override=cfg2)

        cells[name] = Cell(name, "train", build, None, probe, (1, 2),
                           base.n_layers)

    def smoke_run(cfg=None):
        cfg = cfg or smoke
        rng = np.random.default_rng(0)
        N, E = 40, 160
        cfg = dataclasses.replace(cfg, d_in=8,
                                  d_out=3 if cfg.task == "node_class"
                                  else cfg.d_out)
        batch = {
            "feats": jnp.asarray(rng.normal(size=(N, 8)), jnp.float32),
            "coords": jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
            "edge_src": jnp.asarray(rng.integers(0, N, E), jnp.int32),
            "edge_dst": jnp.asarray(rng.integers(0, N, E), jnp.int32),
            "edge_mask": jnp.ones(E, bool),
            "edge_feats": jnp.asarray(rng.normal(size=(E, 1)), jnp.float32),
            "label_mask": jnp.ones(N, bool),
        }
        if cfg.task == "node_class":
            batch["labels"] = jnp.asarray(rng.integers(0, 3, N), jnp.int32)
        elif cfg.task == "graph_reg":
            batch["graph_id"] = jnp.asarray(rng.integers(0, 4, N),
                                            jnp.int32)
            batch["labels"] = jnp.asarray(rng.normal(size=(4, 1)),
                                          jnp.float32)
        else:
            batch["labels"] = jnp.asarray(
                rng.normal(size=(N, cfg.d_out)), jnp.float32)
        params = G.init(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg))
        losses = []
        for _ in range(3):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0] * 1.5 + 1.0
        return {"loss_first": losses[0], "loss_last": losses[-1]}

    def model_flops(shape_name: str) -> float:
        shape = SHAPES[shape_name]
        cfg = _shape_cfg(base, shape)
        d, L = cfg.d_hidden, cfg.n_layers
        N, E = shape["n_nodes"], shape["n_edges"]
        ce = {"egnn": 4, "gatedgcn": 3, "gat": 2, "graphcast": 8}[cfg.arch]
        cn = {"egnn": 6, "gatedgcn": 6, "gat": 2, "graphcast": 6}[cfg.arch]
        per_step = (N * cfg.d_in * d + L * (E * ce * d * d
                                            + N * cn * d * d)
                    + N * d * cfg.d_out)
        return 6.0 * per_step  # fwd+bwd

    return ArchSpec(arch_id, "gnn", describe, base, smoke, cells,
                    smoke_run, model_flops)


EGNN = gnn_arch(
    "egnn", "4L d64 E(n)-equivariant [arXiv:2102.09844; paper]",
    G.GNNConfig("egnn", "egnn", 4, 64, d_in=16, d_out=1, task="node_reg"),
    G.GNNConfig("egnn-smoke", "egnn", 2, 16, d_in=8, d_out=1,
                task="node_reg"))

GRAPHCAST = gnn_arch(
    "graphcast", "16L d512 mesh-GNN encoder-processor-decoder, sum "
    "aggregator, n_vars=227 [arXiv:2212.12794; unverified] — applied to the "
    "assigned generic graph shapes (see DESIGN.md)",
    G.GNNConfig("graphcast", "graphcast", 16, 512, d_in=227, d_out=227,
                task="node_reg"),
    G.GNNConfig("graphcast-smoke", "graphcast", 2, 16, d_in=8, d_out=4,
                task="node_reg"))

GATEDGCN = gnn_arch(
    "gatedgcn", "16L d70 gated aggregator [arXiv:2003.00982; paper]",
    G.GNNConfig("gatedgcn", "gatedgcn", 16, 70, d_in=16, d_out=7,
                task="node_class"),
    G.GNNConfig("gatedgcn-smoke", "gatedgcn", 2, 16, d_in=8, d_out=3,
                task="node_class"))

GAT_CORA = gnn_arch(
    "gat-cora", "2L d_hidden 8x8 heads attention aggregator "
    "[arXiv:1710.10903; paper]",
    G.GNNConfig("gat-cora", "gat", 2, 64, d_in=1433, d_out=7, n_heads=8,
                task="node_class", aggregator="attn"),
    G.GNNConfig("gat-smoke", "gat", 2, 16, d_in=8, d_out=3, n_heads=4,
                task="node_class", aggregator="attn"))
