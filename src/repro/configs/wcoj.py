"""The paper's own workload as an architecture: distributed WCOJ subgraph
queries on the production mesh (every chip = one dataflow worker).

Cells lower the full SPMD join program (seed -> while(extend) -> psum) with
hash-partitioned index shards as inputs.  ``*_delta`` cells lower the same
program against a three-region multi-version index (one dQ_i of
Delta-BiGJoin) seeded by an update batch.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchSpec, Cell
from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig
from repro.core.distributed import DistConfig, build_per_worker
from repro.core.plan import make_delta_plan, make_plan
from repro.core.query import delta_queries

SHAPES = {
    # IN = edge count; B' = per-worker proposal budget
    "triangle_static": dict(kind="join", query="triangle", edges=1 << 26,
                            batch=4096),
    "fourclique_static": dict(kind="join", query="4-clique", edges=1 << 24,
                              batch=4096),
    "triangle_delta_1m": dict(kind="delta", query="triangle",
                              edges=1 << 26, delta=1_000_000, batch=4096),
    "diamond_delta_1m": dict(kind="delta", query="diamond", edges=1 << 26,
                             delta=1_000_000, batch=4096),
}


def _abstract_indices(plan, edges: int, w: int, delta: int = 0):
    """SDS stand-ins for hash-partitioned index shards [w, cap]."""
    from repro.core.csr import round_capacity
    from repro.core.dataflow_index import VersionedIndex
    # SEG-aligned like csr.build_index, so the kernel view is a free reshape
    cap = round_capacity(np.ceil(edges / w * 1.3))
    dcap = round_capacity(max(int(np.ceil(delta / w * 2.0)), 1))

    def sds_region(c):
        from repro.core.csr import IndexData
        return IndexData(
            jax.ShapeDtypeStruct((w, c), jnp.int32),
            jax.ShapeDtypeStruct((w, c), jnp.int32),
            jax.ShapeDtypeStruct((w,), jnp.int32))

    out = {}
    for index_id, rel, key_pos, ext_pos, version in plan.index_ids():
        if version == "static":
            out[index_id] = VersionedIndex((sds_region(cap),), ())
        elif version == "old":
            out[index_id] = VersionedIndex(
                (sds_region(cap), sds_region(dcap)), (sds_region(dcap),))
        else:  # new
            out[index_id] = VersionedIndex(
                (sds_region(cap), sds_region(dcap), sds_region(dcap)),
                (sds_region(dcap), sds_region(dcap)))
    return out


def _build_cell(shape: Dict):
    def build(mesh=None):
        assert mesh is not None, "wcoj cells lower under an explicit mesh"
        w = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        axis = tuple(mesh.axis_names)
        q = Q.PAPER_QUERIES[shape["query"]]()
        if shape["kind"] == "join":
            plan = make_plan(q)
            seed_total = shape["edges"]
        else:
            plan = make_delta_plan(delta_queries(q)[0])
            seed_total = shape["delta"]
        B = shape["batch"]
        dcfg = DistConfig(
            BigJoinConfig(batch=B, mode="count",
                          use_kernel=shape.get("use_kernel", True)), w,
            route_capacity=max(4 * B // w, 16), aggregate=True, axis=axis)
        per_worker = build_per_worker(plan, dcfg)
        indices = _abstract_indices(plan, shape["edges"], w,
                                    shape.get("delta", 0))
        S = int(np.ceil(seed_total / w))
        seed = jax.ShapeDtypeStruct((w, S, 2), jnp.int32)
        seed_n = jax.ShapeDtypeStruct((w,), jnp.int32)
        # signed seed weights: all ones for static joins, ±1 for dR seeds
        seed_w = jax.ShapeDtypeStruct((w, S), jnp.int32)

        specs = (jax.tree.map(lambda _: P(axis), indices,
                              is_leaf=lambda x: isinstance(
                                  x, jax.ShapeDtypeStruct)),
                 P(axis), P(axis), P(axis))
        fn = compat.shard_map(per_worker, mesh=mesh, in_specs=specs,
                              out_specs=(P(),) * 7, check_vma=False)
        return fn, (indices, seed, seed_n, seed_w), None, ()
    return build


def _smoke_run(_cfg=None):
    """Reduced config: real distributed join on the 1-device mesh."""
    from jax.sharding import Mesh
    from repro.core.distributed import distributed_join
    from repro.core.generic_join import generic_join
    from repro.data.synthetic import rmat_graph
    e = rmat_graph(9, 4, seed=3)
    q = Q.triangle()
    plan = make_plan(q)
    mesh = Mesh(np.array(jax.devices()[:1]), ("workers",))
    cfg = DistConfig(BigJoinConfig(batch=512, mode="count"), 1,
                     route_capacity=512)
    res = distributed_join(plan, {Q.EDGE: e}, mesh=mesh, cfg=cfg)
    _, ref = generic_join(q, {Q.EDGE: e}, plan=plan)
    assert res.count == ref, (res.count, ref)
    return {"count": float(res.count), "steps": float(res.steps)}


def _model_flops(shape_name: str) -> float:
    """Useful work PER ROUND (the wcoj cells lower a while-loop program and
    their HLO costs are per dataflow round): w*B' proposals, each probed
    against ~n_atoms binary-search indices of depth log2(IN/w)."""
    shape = SHAPES[shape_name]
    q = Q.PAPER_QUERIES[shape["query"]]()
    w, B = 512.0, float(shape["batch"])
    depth = np.log2(max(shape["edges"] / w, 2.0))
    return w * B * q.num_atoms * 8.0 * depth


WCOJ = ArchSpec(
    "wcoj-subgraph", "wcoj",
    "the paper's contribution: BiGJoin/Delta-BiGJoin distributed WCOJ "
    "dataflow, every chip a worker",
    None, None,
    {name: Cell(name, shape["kind"], _build_cell(shape))
     for name, shape in SHAPES.items()},
    _smoke_run, _model_flops)
