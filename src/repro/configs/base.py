"""Config system: every architecture is an ArchSpec with
  * the exact assigned full config (dry-run only: abstract, never allocated)
  * a reduced smoke config (CPU-runnable: one real train step in tests)
  * its input-shape set, each cell exposing
      - abstract_inputs / abstract_state  (ShapeDtypeStructs)
      - logical axes for both             (sharding)
      - step(cfg) -> the jittable function the dry-run lowers
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Cell:
    """One (architecture x input-shape) dry-run cell."""

    shape_name: str
    kind: str  # train | prefill | decode | serve | retrieval | join | delta
    # () -> (step_fn, abstract_args pytree, logical_axes pytree (or None),
    #        donate_argnums)
    build: Callable[[], Tuple[Callable, Tuple, Any, Tuple[int, ...]]]
    skip_reason: Optional[str] = None
    # depth probing for exact HLO cost extrapolation (scan bodies are
    # counted once by XLA cost analysis): probe(mesh, depth) builds the same
    # cell at a reduced, fully-unrolled layer depth.
    probe: Optional[Callable] = None
    probe_depths: Tuple[int, int] = (1, 2)
    full_depth: int = 0
    probe_scale: float = 1.0  # full-cell cost / probe cost (batch ratio)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | wcoj
    describe: str
    full_config: Any
    smoke_config: Any
    cells: Dict[str, Cell]
    # smoke_run(cfg) -> metrics dict; runs a real reduced-config step on CPU
    smoke_run: Callable[[Any], Dict[str, float]]
    model_flops: Callable[[str], float]  # analytic 6*N*D-style FLOPs/step
