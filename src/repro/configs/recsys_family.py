"""Two-tower recsys ArchSpec: train / online / bulk / retrieval cells."""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec, Cell
from repro.models import recsys as R
from repro.optim import adamw_init, adamw_update, cosine_decay

SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000),
}


def _feat_specs(cfg: R.TwoTowerConfig, B: int):
    feats = {name: jax.ShapeDtypeStruct((B, cfg.multi_hot), jnp.int32)
             for name, _ in cfg.user_tables}
    axes = {name: ("batch", None) for name, _ in cfg.user_tables}
    return feats, axes


def make_train_step(cfg: R.TwoTowerConfig, schedule=None):
    sched = schedule or cosine_decay(1e-3, 500, 50_000)

    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            R.loss_fn, has_aux=True)(params, batch, cfg)
        params, opt, gnorm = adamw_update(params, grads, opt,
                                          lr=sched(opt.step),
                                          weight_decay=0.0)
        return params, opt, {"loss": loss, **metrics}

    return train_step


def recsys_arch(arch_id: str, describe: str, full: R.TwoTowerConfig,
                smoke: R.TwoTowerConfig) -> ArchSpec:
    cells: Dict[str, Cell] = {}

    def build_train(mesh=None):
        cfg = full
        params = R.abstract_params(cfg)
        opt = jax.eval_shape(adamw_init, params)
        B = SHAPES["train_batch"]["batch"]
        feats, faxes = _feat_specs(cfg, B)
        batch = {"feats": feats,
                 "item_ids": jax.ShapeDtypeStruct((B,), jnp.int32)}
        baxes = {"feats": faxes, "item_ids": ("batch",)}
        p_ax = R.logical_axes(cfg)
        from repro.optim.adamw import AdamWState
        axes = (p_ax, AdamWState((), p_ax, p_ax), baxes)
        return make_train_step(cfg), (params, opt, batch), axes, (0, 1)

    def build_serve(B):
        def build(mesh=None):
            cfg = full
            params = R.abstract_params(cfg)
            feats, faxes = _feat_specs(cfg, B)
            items = jax.ShapeDtypeStruct((B,), jnp.int32)
            axes = (R.logical_axes(cfg), faxes, ("batch",))
            step = functools.partial(R.serve_scores, cfg=cfg)
            return step, (params, feats, items), axes, ()
        return build

    def build_retrieval(mesh=None):
        cfg = full
        params = R.abstract_params(cfg)
        C = SHAPES["retrieval_cand"]["n_candidates"]
        feats, faxes = _feat_specs(cfg, 1)
        cands = jax.ShapeDtypeStruct((C,), jnp.int32)
        axes = (R.logical_axes(cfg), faxes, ("candidates",))
        step = functools.partial(R.retrieval_topk, cfg=cfg)
        return step, (params, feats, cands), axes, ()

    cells["train_batch"] = Cell("train_batch", "train", build_train)
    cells["serve_p99"] = Cell("serve_p99", "serve", build_serve(512))
    cells["serve_bulk"] = Cell("serve_bulk", "serve", build_serve(262_144))
    cells["retrieval_cand"] = Cell("retrieval_cand", "retrieval",
                                   build_retrieval)

    def smoke_run(cfg=None):
        cfg = cfg or smoke
        from repro.data.synthetic import recsys_events
        params = R.init(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg))
        losses = []
        for s in range(3):
            feats, items, _ = recsys_events(
                1000, cfg.num_items, 64, s,
                tuple(r for _, r in cfg.user_tables),
                multi_hot=cfg.multi_hot)
            feats = {name: jnp.asarray(feats[f"table_{i}"] % rows)
                     for i, (name, rows) in enumerate(cfg.user_tables)}
            batch = {"feats": feats, "item_ids": jnp.asarray(items)}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses)
        # retrieval path
        vals, idx = R.retrieval_topk(
            params, {k: v[:1] for k, v in feats.items()},
            jnp.arange(cfg.num_items, dtype=jnp.int32), cfg, k=10)
        assert np.isfinite(np.asarray(vals)).all()
        return {"loss_first": losses[0], "loss_last": losses[-1]}

    def model_flops(shape_name: str) -> float:
        cfg = full
        shape = SHAPES[shape_name]
        din_u = cfg.embed_dim * len(cfg.user_tables)
        mlp_u = sum(a * b for a, b in zip(
            (din_u,) + cfg.tower_mlp[:-1], cfg.tower_mlp))
        mlp_i = sum(a * b for a, b in zip(
            (cfg.embed_dim,) + cfg.tower_mlp[:-1], cfg.tower_mlp))
        if shape_name == "train_batch":
            B = shape["batch"]
            score = B * cfg.num_negatives * cfg.tower_mlp[-1]
            return 6.0 * (B * (mlp_u + mlp_i) + score)
        if shape_name == "retrieval_cand":
            C = shape["n_candidates"]
            return 2.0 * (mlp_u + C * mlp_i + C * cfg.tower_mlp[-1])
        B = shape["batch"]
        return 2.0 * B * (mlp_u + mlp_i + cfg.tower_mlp[-1])

    return ArchSpec(arch_id, "recsys", describe, full, smoke, cells,
                    smoke_run, model_flops)


TWO_TOWER = recsys_arch(
    "two-tower-retrieval",
    "embed 256, towers 1024-512-256, dot interaction, sampled softmax "
    "[RecSys'19 (YouTube); unverified]",
    R.TwoTowerConfig(),
    R.TwoTowerConfig(name="two-tower-smoke",
                     user_tables=(("user_id", 1000), ("hist_items", 500),
                                  ("context", 100)),
                     num_items=2000, embed_dim=32, tower_mlp=(64, 32, 16),
                     num_negatives=32))
