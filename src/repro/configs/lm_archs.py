"""The five assigned LM architectures — exact configs from the assignment.

[source; verified-tier] annotations are in the describe strings.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.lm_family import lm_arch
from repro.models.transformer import TransformerConfig


def _smoke(name, **kw):
    base = dict(num_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab=199,
                param_dtype=jnp.float32, act_dtype=jnp.float32, remat=False)
    base.update(kw)
    return TransformerConfig(name + "-smoke", **base)


LLAMA4_SCOUT = lm_arch(
    "llama4-scout-17b-a16e",
    "48L d5120 40H(kv8) ff8192 v202048 MoE16 top-1; chunked-local + "
    "periodic-global attention (iRoPE) [hf:meta-llama/Llama-4-Scout-17B-16E;"
    " unverified]",
    TransformerConfig(
        "llama4-scout-17b-a16e", num_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
        n_experts=16, top_k=1, window=8192, local_global_period=4,
        rope_theta=500000.0),
    _smoke("llama4-scout", n_experts=4, top_k=1, window=8,
           local_global_period=4))

MIXTRAL_8X7B = lm_arch(
    "mixtral-8x7b",
    "32L d4096 32H(kv8) ff14336 v32000 MoE8 top-2, sliding-window attention"
    " [arXiv:2401.04088; hf]",
    TransformerConfig(
        "mixtral-8x7b", num_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
        n_experts=8, top_k=2, window=4096, rope_theta=1e6),
    _smoke("mixtral", n_experts=4, top_k=2, window=8))

YI_34B = lm_arch(
    "yi-34b",
    "60L d7168 56H(kv8) ff20480 v64000 dense llama-arch GQA, full attention"
    " [arXiv:2403.04652; hf]",
    TransformerConfig(
        "yi-34b", num_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        head_dim=128, d_ff=20480, vocab=64000, rope_theta=5e6),
    _smoke("yi"))

GEMMA_7B = lm_arch(
    "gemma-7b",
    "28L d3072 16H(kv16) head_dim=256 ff24576 v256000 dense GeGLU, full "
    "attention [arXiv:2403.08295; hf]",
    TransformerConfig(
        "gemma-7b", num_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
        head_dim=256, d_ff=24576, vocab=256000, act="gelu",
        norm_plus_one=True, embed_scale=True),
    _smoke("gemma7b", act="gelu", norm_plus_one=True, embed_scale=True,
           n_kv_heads=4))

GEMMA2_2B = lm_arch(
    "gemma2-2b",
    "26L d2304 8H(kv4) head_dim=256 ff9216 v256000, local/global "
    "alternating, logit softcaps [arXiv:2408.00118; hf]",
    TransformerConfig(
        "gemma2-2b", num_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        head_dim=256, d_ff=9216, vocab=256000, act="gelu",
        window=4096, local_global_period=2, attn_softcap=50.0,
        final_softcap=30.0, norm_plus_one=True, embed_scale=True),
    _smoke("gemma2", act="gelu", window=8, local_global_period=2,
           attn_softcap=50.0, final_softcap=30.0, norm_plus_one=True,
           embed_scale=True))
