"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ArchSpec


def _all() -> Dict[str, ArchSpec]:
    from repro.configs import lm_archs as lm
    from repro.configs.gnn_family import EGNN, GAT_CORA, GATEDGCN, GRAPHCAST
    from repro.configs.recsys_family import TWO_TOWER
    from repro.configs.wcoj import WCOJ
    specs = [
        lm.LLAMA4_SCOUT, lm.MIXTRAL_8X7B, lm.YI_34B, lm.GEMMA_7B,
        lm.GEMMA2_2B,
        EGNN, GRAPHCAST, GATEDGCN, GAT_CORA,
        TWO_TOWER,
        WCOJ,
    ]
    return {s.arch_id: s for s in specs}


def list_archs() -> List[str]:
    return list(_all().keys())


def get_arch(arch_id: str) -> ArchSpec:
    table = _all()
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; known: "
                       f"{', '.join(table)}")
    return table[arch_id]
