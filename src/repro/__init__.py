"""repro: worst-case optimal low-memory dataflows (BiGJoin) in JAX.

x64 is enabled globally: the join engine packs 2-column index keys into
int64.  All model code uses explicit dtypes (bf16/f32/int32) so this does not
change numeric behaviour elsewhere.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
