"""AdamW (decoupled weight decay) over arbitrary pytrees.

No optax in this environment — this is the full optimizer substrate:
bias-corrected moments, decoupled weight decay with a maskable predicate
(norms/embeddings usually excluded), global-norm clipping, and f32 master
moments regardless of parameter dtype (bf16-safe training).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdamWState:
    step: jax.Array  # [] int32
    mu: dict  # first moments (f32 pytree)
    nu: dict  # second moments (f32 pytree)

    def tree_flatten(self):
        return (self.step, self.mu, self.nu), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.asarray(0, jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped grads, pre-clip global norm)."""
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 decay_mask: Optional[Callable[[str], bool]] = None,
                 max_grad_norm: float = 1.0):
    """One AdamW step.  ``lr`` may be a scalar or a schedule value.

    ``decay_mask(path_string) -> bool`` selects which leaves receive weight
    decay (default: every leaf with ndim >= 2, the usual matrix-only rule).
    """
    if max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        _, gnorm = clip_by_global_norm(grads, 1.0)
    step = state.step + 1
    b1t = 1.0 - jnp.power(jnp.float32(b1), step.astype(jnp.float32))
    b2t = 1.0 - jnp.power(jnp.float32(b2), step.astype(jnp.float32))

    from repro.compat import tree_flatten_with_path
    flat_p, treedef = tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)

    new_p, new_mu, new_nu = [], [], []
    for (path, p), g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mhat = mu / b1t
        vhat = nu / b2t
        upd = mhat / (jnp.sqrt(vhat) + eps)
        pstr = jax.tree_util.keystr(path)
        apply_wd = (decay_mask(pstr) if decay_mask is not None
                    else p.ndim >= 2)
        if apply_wd and weight_decay > 0:
            upd = upd + weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)

    params2 = jax.tree.unflatten(treedef, new_p)
    mu2 = jax.tree.unflatten(treedef, new_mu)
    nu2 = jax.tree.unflatten(treedef, new_nu)
    return params2, AdamWState(step, mu2, nu2), gnorm
