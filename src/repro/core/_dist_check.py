"""Multi-device distributed-join correctness harness.

Run as a subprocess so the XLA host-platform device-count override applies
before jax initializes (tests and benches must keep seeing 1 device):

    python -m repro.core._dist_check --workers 8 --query triangle ...

Prints one JSON line with counts from the distributed engine and the oracle.
"""
import os
import sys

if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--query", default="triangle")
    ap.add_argument("--nv", type=int, default=60)
    ap.add_argument("--ne", type=int, default=500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skew", action="store_true")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--route-capacity", type=int, default=64)
    ap.add_argument("--no-aggregate", action="store_true")
    ap.add_argument("--balance", action="store_true")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.workers}")

    import json

    import numpy as np

    from repro.core import query as Q
    from repro.core.bigjoin import BigJoinConfig
    from repro.core.distributed import DistConfig, distributed_join
    from repro.core.generic_join import generic_join
    from repro.core.plan import make_plan

    rng = np.random.default_rng(args.seed)
    if args.skew:
        u = (rng.zipf(1.4, args.ne) % args.nv).astype(np.int64)
        v = rng.integers(0, args.nv, args.ne)
    else:
        u = rng.integers(0, args.nv, args.ne)
        v = rng.integers(0, args.nv, args.ne)
    keep = u != v
    e = np.unique(np.stack([u[keep], v[keep]], 1).astype(np.int32), axis=0)

    q = Q.query_by_name(args.query)
    plan = make_plan(q)
    rels = {Q.EDGE: e}
    base = BigJoinConfig(batch=args.batch, mode="collect",
                         out_capacity=1 << 18)
    cfg = DistConfig(base, args.workers, route_capacity=args.route_capacity,
                     aggregate=not args.no_aggregate, balance=args.balance)
    import time
    t0 = time.time()
    res = distributed_join(plan, rels, cfg=cfg)
    elapsed = time.time() - t0
    # second run = warm jit cache: the steady-state number
    t0 = time.time()
    res = distributed_join(plan, rels, cfg=cfg)
    warm = time.time() - t0
    ref, cnt = generic_join(q, rels, plan=plan)
    got = (np.unique(res.tuples, axis=0) if res.tuples is not None
           and res.tuples.size else np.zeros((0, q.num_attrs)))
    exact = bool(got.shape[0] == cnt
                 and (cnt == 0
                      or np.array_equal(got, np.unique(ref, axis=0))))
    print(json.dumps({
        "query": args.query, "workers": args.workers,
        "dist_count": res.count, "oracle_count": cnt,
        "tuples_exact": exact, "steps": res.steps,
        "proposals": res.proposals, "max_load": res.max_load,
        "mean_load": res.mean_load, "edges": int(e.shape[0]),
        "elapsed_s": round(elapsed, 3), "warm_s": round(warm, 3),
    }))
    sys.exit(0 if (res.count == cnt and exact) else 1)
