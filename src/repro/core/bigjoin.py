"""BiGJoin: the paper's dataflow primitive (§3.1) + join driver (§3.2) in JAX.

The adaptation is described in DESIGN.md §2: the paper's batching optimization
(§3.1.2) becomes the static shape itself.  Each *step* pops a window of the
deepest non-empty prefix queue and pushes at most ``B'`` proposals through

    count-minimization -> candidate proposal -> intersection

exactly as Fig. 2, with partially-extended prefixes resuming via their
``rem-ext`` offset (the paper's (p, min-c, min-i, rem-ext) quadruples).

Scheduling follows §3.2: always extend the *deepest* level with pending work,
which bounds every queue at O(B') entries (Lemma 3.1's memory invariant —
asserted by tests/test_bigjoin.py::test_queue_invariant).

All shapes are static; the step function is jit-compiled once per
(plan, config) and reused.  Weighted prefixes (+1/-1) make the same dataflow
serve Delta-BiGJoin (delta.py) without modification.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compilestats
from repro.core.dataflow_index import VersionedIndex
from repro.core.plan import Plan
from repro.errors import (CapacityOverflow, OVF_OUT, OVF_QUEUE, OVF_SEED)

Indices = Dict[str, VersionedIndex]


@dataclasses.dataclass(frozen=True)
class BigJoinConfig:
    """``batch`` is B' — the per-step proposal budget (§3.1.2).

    ``use_kernel`` (default on) routes each level's extension step through
    the fused Pallas pipeline (kernels/extend) and membership probes through
    the multi-region intersect kernel; ``kernel_interpret`` overrides the
    platform gating (None = compiled on TPU, interpret elsewhere).  The
    jnp path (``use_kernel=False``) remains as oracle and fallback.
    """

    batch: int = 4096
    seed_chunk: int = 4096
    out_capacity: int = 1 << 20
    mode: str = "collect"  # "collect" | "count"
    use_kernel: bool = True  # fused Pallas extension step + member kernels
    kernel_interpret: Optional[bool] = None  # None: platform detection

    def queue_capacity(self) -> int:
        return 2 * self.batch

    def __post_init__(self):
        assert self.mode in ("collect", "count")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LevelQueue:
    prefix: jax.Array  # [cap, width] int32
    k: jax.Array  # [cap] int32 — next extension offset (rem-ext cursor)
    weight: jax.Array  # [cap] int32
    size: jax.Array  # [] int32

    def tree_flatten(self):
        return (self.prefix, self.k, self.weight, self.size), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BigJoinState:
    queues: Tuple[LevelQueue, ...]  # widths 2..m-1
    out_buf: jax.Array  # [Ocap, m] int32 (or [1, m] in count mode)
    out_weight: jax.Array  # [Ocap] int32
    out_n: jax.Array  # [] int32 rows used in out_buf
    out_count: jax.Array  # [] int64 weighted output count
    overflow: jax.Array  # [] int32 — OVF_* bitmask (repro.errors); stays 0
    proposals: jax.Array  # [] int64 work counter
    intersections: jax.Array  # [] int64 work counter
    recv_load: jax.Array  # [] int64 — requests served (distributed only)

    def tree_flatten(self):
        return (self.queues, self.out_buf, self.out_weight, self.out_n,
                self.out_count, self.overflow, self.proposals,
                self.intersections, self.recv_load), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def make_state(plan: Plan, cfg: BigJoinConfig,
               seed_capacity: Optional[int] = None) -> BigJoinState:
    m = plan.query.num_attrs
    sw = plan.seed_width
    queues = []
    for width in range(sw, m):
        cap = (seed_capacity or cfg.seed_chunk) if width == sw \
            else cfg.queue_capacity()
        queues.append(LevelQueue(
            jnp.zeros((cap, width), jnp.int32),
            jnp.zeros(cap, jnp.int32),
            jnp.zeros(cap, jnp.int32),
            jnp.asarray(0, jnp.int32)))
    ocap = cfg.out_capacity if cfg.mode == "collect" else 1
    return BigJoinState(
        tuple(queues),
        jnp.zeros((ocap, m), jnp.int32),
        jnp.zeros(ocap, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int64),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int64),
        jnp.asarray(0, jnp.int64),
        jnp.asarray(0, jnp.int64))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _pack_cols(prefix: jax.Array, positions: Sequence[int], dtype):
    """Pack prefix columns into a probe key via the ONE shared packer
    (``csr.pack_key``): a single array cast to the index key dtype, or the
    (hi, lo) int64 pair for 3-4 bound columns (composite indices)."""
    from repro.core import csr
    packed = csr.pack_key(tuple(prefix[:, p] for p in positions))
    if isinstance(packed, tuple):
        return packed
    return packed.astype(dtype)


def _binding_key(prefix: jax.Array, bound_attrs: Sequence[int],
                 key_attrs: Sequence[int], idx: VersionedIndex):
    pos = [list(bound_attrs).index(a) for a in key_attrs]
    return _pack_cols(prefix, pos, idx.pos[0].key.dtype)


def _compact(arrays, keep: jax.Array):
    """Stable-partition rows with keep=True to the front; returns new size."""
    perm = jnp.argsort(~keep, stable=True)
    return [a[perm] for a in arrays], keep.sum().astype(jnp.int32)


def _scatter_append(dst: jax.Array, size: jax.Array, src: jax.Array,
                    alive: jax.Array):
    """Append alive rows of src to dst at [size, ...); returns (dst, n, ovf)."""
    cap = dst.shape[0]
    cum = (jnp.cumsum(alive.astype(jnp.int32), dtype=jnp.int32)
           - alive.astype(jnp.int32))
    dest = jnp.where(alive, size + cum, cap)  # cap => dropped
    n_new = alive.sum().astype(jnp.int32)
    ovf = (size + n_new) > cap
    return dst.at[dest].set(src, mode="drop"), n_new, ovf


# ---------------------------------------------------------------------------
# the dataflow step
# ---------------------------------------------------------------------------

def _level_branch(plan: Plan, cfg: BigJoinConfig, li: int):
    """Build the pop→count-min→propose→intersect→push branch for level li.

    With ``cfg.use_kernel`` the count-min/propose/intersect middle runs as
    ONE fused ``pallas_call`` (kernels/extend): proposals are born, gathered
    and membership-filtered in VMEM without HBM round-trips between stages.
    The jnp stage sequence below is the bit-exact oracle and fallback.
    """
    lv = plan.levels[li]
    m = plan.query.num_attrs
    B = cfg.batch
    is_last = li == len(plan.levels) - 1
    new_bound = lv.bound_attrs + (lv.ext_attr,)

    def middle_fused(wprefix, wk, valid, indices):
        from repro.kernels.extend.ops import fused_extend
        qks, pos, neg = [], [], []
        for b in lv.bindings:
            idx = indices[b.index_id]
            qks.append(_binding_key(wprefix, lv.bound_attrs, b.key_attrs,
                                    idx))
            pos.append(idx.pos)
            neg.append(idx.neg)
        cand, r, alive, allowed, consumed, counters = fused_extend(
            tuple(pos), tuple(neg), tuple(qks), wk, valid, B,
            interpret=cfg.kernel_interpret)
        return (cand, r, alive, allowed, consumed,
                counters[0].astype(jnp.int64), counters[1].astype(jnp.int64))

    def middle_jnp(wprefix, wk, valid, indices):
        # ---- count minimization (one pass per binding, Fig 2 "Count") ----
        starts_b, counts_b, totals = [], [], []
        for b in lv.bindings:
            idx = indices[b.index_id]
            qk = _binding_key(wprefix, lv.bound_attrs, b.key_attrs, idx)
            s, c = idx.ranges(qk)
            starts_b.append(s)
            counts_b.append(c)
            totals.append(c.sum(-1))
        tot = jnp.stack(totals, -1)  # [W, NB]
        min_i = jnp.argmin(tot, -1).astype(jnp.int32)
        min_c = tot.min(-1)
        W = wk.shape[0]

        # ---- proposal budget allocation (rem-ext resumption) -------------
        remaining = jnp.where(valid, jnp.maximum(min_c - wk, 0), 0)
        acum = jnp.cumsum(remaining, dtype=jnp.int32)
        allowed = jnp.clip(B - (acum - remaining), 0, remaining
                           ).astype(jnp.int32)
        consumed = valid & (allowed == remaining)

        aacum = jnp.cumsum(allowed, dtype=jnp.int32)
        t = jnp.arange(B, dtype=jnp.int32)
        pvalid = t < aacum[-1]
        r = jnp.clip(jnp.searchsorted(aacum, t, side="right"), 0, W - 1)
        r = r.astype(jnp.int32)
        k_off = t - (aacum[r] - allowed[r]) + wk[r]

        # ---- candidate proposal (Fig 2 "Proposal") ------------------------
        cand = jnp.zeros(B, jnp.int32)
        for bi, b in enumerate(lv.bindings):
            idx = indices[b.index_id]
            v = idx.gather(starts_b[bi][r], counts_b[bi][r], k_off)
            cand = jnp.where(min_i[r] == bi, v, cand)
        new_prefix = jnp.concatenate([wprefix[r], cand[:, None]], axis=1)
        alive = pvalid
        n_proposed = pvalid.sum().astype(jnp.int64)

        # ---- intersection (Fig 2 "Intersect") -----------------------------
        n_isect = jnp.asarray(0, jnp.int64)
        for bi, b in enumerate(lv.bindings):
            idx = indices[b.index_id]
            pos = [list(new_bound).index(a) for a in b.key_attrs]
            qk = _pack_cols(new_prefix, pos, idx.pos[0].key.dtype)
            is_min = min_i[r] == bi
            ok = jnp.where(
                is_min,
                ~idx.deleted(qk, cand),
                idx.member(qk, cand))
            n_isect = n_isect + (alive & ~is_min).sum().astype(jnp.int64)
            alive = alive & ok
        return cand, r, alive, allowed, consumed, n_proposed, n_isect

    def branch(state: BigJoinState, indices: Indices) -> BigJoinState:
        qu = state.queues[li]
        W = min(B, qu.prefix.shape[0])
        wprefix, wk = qu.prefix[:W], qu.k[:W]
        wweight = qu.weight[:W]
        valid = jnp.arange(W, dtype=jnp.int32) < qu.size

        use_fused = cfg.use_kernel
        if use_fused:
            from repro.kernels.intersect.ops import (default_interpret,
                                                     fused_fits)
            regions = [reg for b in lv.bindings
                       for reg in (indices[b.index_id].pos
                                   + indices[b.index_id].neg)]
            # compiled path: drop to the jnp oracle when the level's regions
            # (composite lo word tiles included — fused_fits counts their
            # 8 B/slot) cannot be VMEM-resident (DESIGN.md §3), rather than
            # failing Mosaic
            use_fused = (default_interpret(cfg.kernel_interpret)
                         or fused_fits(regions, B))
        middle = middle_fused if use_fused else middle_jnp
        (cand, r, alive, allowed, consumed, n_proposed,
         n_isect) = middle(wprefix, wk, valid, indices)
        new_prefix = jnp.concatenate([wprefix[r], cand[:, None]], axis=1)
        weight = wweight[r]
        for f in lv.filters:
            lo = new_prefix[:, list(new_bound).index(f.lo)]
            hi = new_prefix[:, list(new_bound).index(f.hi)]
            alive = alive & (lo < hi)

        # ---- retire consumed prefixes from this queue ---------------------
        kfull = qu.k.at[:W].set(wk + allowed)
        live_row = jnp.arange(qu.prefix.shape[0], dtype=jnp.int32) < qu.size
        keep = live_row & ~jnp.pad(consumed, (0, qu.prefix.shape[0] - W))
        (pfx, kk, ww), nsz = _compact([qu.prefix, kfull, qu.weight], keep)
        queues = list(state.queues)
        queues[li] = LevelQueue(pfx, kk, ww, nsz)

        out_buf, out_weight = state.out_buf, state.out_weight
        out_n, out_count = state.out_n, state.out_count
        overflow = state.overflow
        if is_last:
            out_count = out_count + (weight * alive).sum().astype(jnp.int64)
            if cfg.mode == "collect":
                perm = np.argsort(np.asarray(plan.attr_order))
                rows = new_prefix[:, perm]
                out_buf, n_new, ovf1 = _scatter_append(
                    out_buf, out_n, rows, alive)
                out_weight, _, _ = _scatter_append(
                    out_weight, out_n, weight, alive)
                out_n = jnp.minimum(out_n + n_new,
                                    jnp.int32(out_buf.shape[0]))
                overflow = overflow | jnp.where(ovf1, OVF_OUT, 0)
        else:
            nxt = queues[li + 1]
            npfx, n_new, ovf1 = _scatter_append(
                nxt.prefix, nxt.size, new_prefix, alive)
            nk, _, _ = _scatter_append(
                nxt.k, nxt.size, jnp.zeros(B, jnp.int32), alive)
            nw, _, _ = _scatter_append(nxt.weight, nxt.size, weight, alive)
            queues[li + 1] = LevelQueue(
                npfx, nk, nw,
                jnp.minimum(nxt.size + n_new, jnp.int32(nxt.prefix.shape[0])))
            overflow = overflow | jnp.where(ovf1, OVF_QUEUE, 0)

        return BigJoinState(
            tuple(queues), out_buf, out_weight, out_n, out_count, overflow,
            state.proposals + n_proposed.astype(jnp.int64),
            state.intersections + n_isect, state.recv_load)

    return branch


def build_step(plan: Plan, cfg: BigJoinConfig):
    """One scheduler step: extend the deepest non-empty level (§3.2)."""
    branches = [_level_branch(plan, cfg, li)
                for li in range(len(plan.levels))]
    if not branches:
        # the seed covers every attribute (single-atom delta plans): seeds
        # go straight to output in the seed step; there is nothing to drain
        def step(state: BigJoinState, indices: Indices) -> BigJoinState:
            compilestats.record("bigjoin.step")
            return state

        return step

    def step(state: BigJoinState, indices: Indices) -> BigJoinState:
        compilestats.record("bigjoin.step")
        sizes = jnp.stack([q.size for q in state.queues])
        nz = sizes > 0
        deepest = (len(branches) - 1
                   - jnp.argmax(nz[::-1]).astype(jnp.int32))
        deepest = jnp.clip(deepest, 0, len(branches) - 1)
        return jax.lax.switch(deepest, branches, state, indices)

    return step


def build_seed_step(plan: Plan, cfg: BigJoinConfig):
    """Enqueue a chunk of P_w seed prefixes, applying seed filters (§4.2).

    Width 2 for projection-seeded static plans; an n-ary delta plan seeds
    its full dR_i tuples directly into the width-r queue.  When the seed
    covers EVERY attribute (single-atom delta plans) filtered seeds go
    straight to the output buffer — there are no extension levels.
    """

    def seed_step(state: BigJoinState, indices: Indices, prefixes: jax.Array,
                  weights: jax.Array, valid: jax.Array) -> BigJoinState:
        compilestats.record("bigjoin.seed_step")
        alive = valid
        bound = tuple(plan.attr_order[:plan.seed_width])
        for b in plan.seed_filters:
            idx = indices[b.index_id]
            qk = _binding_key(prefixes, bound, b.key_attrs, idx)
            qv = prefixes[:, bound.index(b.ext_attr)]
            alive = alive & idx.member(qk, qv, cfg.use_kernel,
                                       cfg.kernel_interpret)
        for f in plan.seed_ineq:
            alive = alive & (prefixes[:, bound.index(f.lo)]
                             < prefixes[:, bound.index(f.hi)])
        if not plan.levels:  # seed covers all attrs: direct output
            weights = weights.astype(jnp.int32)
            out_count = state.out_count + (
                weights * alive).sum().astype(jnp.int64)
            out_buf, out_weight = state.out_buf, state.out_weight
            out_n, overflow = state.out_n, state.overflow
            if cfg.mode == "collect":
                perm = np.argsort(np.asarray(plan.attr_order))
                out_buf, n_new, ovf = _scatter_append(
                    out_buf, out_n, prefixes[:, perm], alive)
                out_weight, _, _ = _scatter_append(
                    out_weight, out_n, weights, alive)
                out_n = jnp.minimum(out_n + n_new,
                                    jnp.int32(out_buf.shape[0]))
                overflow = overflow | jnp.where(ovf, OVF_OUT, 0)
            return dataclasses.replace(
                state, out_buf=out_buf, out_weight=out_weight, out_n=out_n,
                out_count=out_count, overflow=overflow)
        q0 = state.queues[0]
        npfx, n_new, ovf = _scatter_append(q0.prefix, q0.size, prefixes, alive)
        nk, _, _ = _scatter_append(
            q0.k, q0.size, jnp.zeros(prefixes.shape[0], jnp.int32), alive)
        nw, _, _ = _scatter_append(q0.weight, q0.size, weights, alive)
        queues = list(state.queues)
        queues[0] = LevelQueue(
            npfx, nk, nw,
            jnp.minimum(q0.size + n_new, jnp.int32(q0.prefix.shape[0])))
        return dataclasses.replace(
            state, queues=tuple(queues),
            overflow=state.overflow | jnp.where(ovf, OVF_SEED, 0))

    return seed_step


@functools.lru_cache(maxsize=64)
def _compiled_fns(plan: Plan, cfg: BigJoinConfig):
    return (jax.jit(build_step(plan, cfg)),
            jax.jit(build_seed_step(plan, cfg)))


@dataclasses.dataclass
class JoinResult:
    count: int  # weighted output count
    tuples: Optional[np.ndarray]  # [N, m] in attribute order (collect mode)
    weights: Optional[np.ndarray]
    proposals: int
    intersections: int
    steps: int


def run_bigjoin(plan: Plan, indices: Indices, seed: np.ndarray,
                weights: Optional[np.ndarray] = None,
                cfg: BigJoinConfig = BigJoinConfig()) -> JoinResult:
    """Host driver: feed seed chunks, drain the dataflow to completion."""
    step, seed_step = _compiled_fns(plan, cfg)
    state = make_state(plan, cfg)
    seed = np.asarray(seed, np.int32).reshape(-1, plan.seed_width)
    if weights is None:
        weights = np.ones(seed.shape[0], np.int32)
    weights = np.asarray(weights, np.int32)
    S = cfg.seed_chunk
    nsteps = 0
    for lo in range(0, max(seed.shape[0], 1), S):
        chunk = seed[lo:lo + S]
        wchunk = weights[lo:lo + S]
        n = chunk.shape[0]
        if n == 0:
            continue
        pad = S - n
        chunk = np.pad(chunk, ((0, pad), (0, 0)))
        wchunk = np.pad(wchunk, (0, pad))
        vmask = np.arange(S) < n
        state = seed_step(state, indices, jnp.asarray(chunk),
                          jnp.asarray(wchunk), jnp.asarray(vmask))
        while True:
            sizes = [int(q.size) for q in state.queues]
            if not any(s > 0 for s in sizes):
                break
            state = step(state, indices)
            nsteps += 1
    mask = int(state.overflow)
    if mask:
        raise CapacityOverflow(
            mask, where="local bigjoin",
            detail=f"batch={cfg.batch} out_capacity={cfg.out_capacity}")
    tuples = wts = None
    if cfg.mode == "collect":
        n = int(state.out_n)
        tuples = np.asarray(state.out_buf)[:n]
        wts = np.asarray(state.out_weight)[:n]
    return JoinResult(int(state.out_count), tuples, wts,
                      int(state.proposals), int(state.intersections), nsteps)


def build_indices(plan: Plan, relations: Dict[str, np.ndarray],
                  capacity_slack: float = 1.0) -> Indices:
    """Static VersionedIndex per plan index id (version 'static' only)."""
    from repro.core.csr import build_index
    out: Indices = {}
    for index_id, rel, key_pos, ext_pos, version in plan.index_ids():
        if version != "static":
            raise ValueError("use delta.DeltaIndexStore for delta plans")
        tuples = np.asarray(relations[rel])
        cap = max(int(tuples.shape[0] * capacity_slack), 1)
        out[index_id] = VersionedIndex.static(
            build_index(tuples, key_pos, ext_pos, cap))
    return out


def seed_tuples_for(plan: Plan, relations: Dict[str, np.ndarray]
                    ) -> np.ndarray:
    rel = np.asarray(relations[plan.query.atoms[plan.seed_atom].rel])
    return np.unique(rel[:, list(plan.seed_cols)], axis=0).astype(np.int32)
