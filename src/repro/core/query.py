"""Conjunctive (multiway equi-join) queries over relations.

The paper's setting (§2.1): a full conjunctive query

    Q(a_1,...,a_m) :- R_1(a_11,...,a_1r1), ..., R_n(a_n1,...,a_nrn)

For subgraph queries every atom is a replica of the binary ``edge`` relation
of the input graph; §5.4 additionally uses a ternary ``tri`` relation.

This module is pure metadata: atoms, attributes, the five paper queries,
symmetry-breaking filters, and delta-query generation (§3.3.1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

EDGE = "edge"  # canonical name of the graph edge relation


@dataclasses.dataclass(frozen=True)
class Atom:
    """One relational atom R(attrs...). ``rel`` names the stored relation."""

    rel: str
    attrs: Tuple[int, ...]

    @property
    def arity(self) -> int:
        return len(self.attrs)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"{self.rel}({','.join('a%d' % a for a in self.attrs)})"


@dataclasses.dataclass(frozen=True)
class Filter:
    """Inequality filter ``a_lo < a_hi`` (symmetry breaking, §5.4)."""

    lo: int
    hi: int


@dataclasses.dataclass(frozen=True)
class Query:
    """A full conjunctive query over ``num_attrs`` attributes."""

    name: str
    num_attrs: int
    atoms: Tuple[Atom, ...]
    filters: Tuple[Filter, ...] = ()

    def __post_init__(self):
        for atom in self.atoms:
            for a in atom.attrs:
                if not (0 <= a < self.num_attrs):
                    raise ValueError(f"attribute a{a} out of range in {atom}")
            if len(set(atom.attrs)) != len(atom.attrs):
                raise ValueError(f"repeated attribute in atom {atom}")
        seen = set()
        for atom in self.atoms:
            seen.update(atom.attrs)
        if seen != set(range(self.num_attrs)):
            raise ValueError("every attribute must appear in some atom")

    @property
    def num_atoms(self) -> int:
        return len(self.atoms)

    def attrs_of(self, rel: str) -> Sequence[Tuple[int, ...]]:
        return [a.attrs for a in self.atoms if a.rel == rel]


# ---------------------------------------------------------------------------
# The paper's five benchmark queries (§5, directed form).
# ---------------------------------------------------------------------------

def _clique(name: str, k: int, symmetric: bool = False) -> Query:
    atoms = tuple(
        Atom(EDGE, (i, j)) for i in range(k) for j in range(i + 1, k)
    )
    filt = tuple(Filter(i, i + 1) for i in range(k - 1)) if symmetric else ()
    return Query(name, k, atoms, filt)


def triangle(symmetric: bool = False) -> Query:
    """tri(a1,a2,a3) :- e(a1,a2), e(a2,a3), e(a1,a3).

    The paper's §5 triangle uses e(a1,a2),e(a1,a3),e(a2,a3); with
    ``symmetric`` the a1<a2<a3 symmetry-breaking filters are added
    (valid on degree-ordered / DAG-ified graphs).
    """
    return _clique("triangle", 3, symmetric)


def four_clique(symmetric: bool = False) -> Query:
    return _clique("4-clique", 4, symmetric)


def five_clique(symmetric: bool = False) -> Query:
    return _clique("5-clique", 5, symmetric)


def diamond() -> Query:
    """diamond :- e(a1,a2), e(a2,a3), e(a4,a1), e(a4,a3)."""
    return Query(
        "diamond",
        4,
        (
            Atom(EDGE, (0, 1)),
            Atom(EDGE, (1, 2)),
            Atom(EDGE, (3, 0)),
            Atom(EDGE, (3, 2)),
        ),
    )


def house(symmetric: bool = False) -> Query:
    """SEED q6 (§5): 5-clique minus edges (a1,a4),(a1,a5)."""
    atoms = (
        Atom(EDGE, (0, 1)),
        Atom(EDGE, (0, 2)),
        Atom(EDGE, (1, 2)),
        Atom(EDGE, (1, 3)),
        Atom(EDGE, (2, 3)),
        Atom(EDGE, (1, 4)),
        Atom(EDGE, (2, 4)),
        Atom(EDGE, (3, 4)),
    )
    # symmetry of the (a2,a3) pair and of the (a4,a5) pair
    filt = (Filter(1, 2), Filter(3, 4)) if symmetric else ()
    return Query("house", 5, atoms, filt)


def four_clique_tri() -> Query:
    """4-clique rewritten over the ternary ``tri`` relation (§5.4):

        4clq :- tri(a1,a2,a3), tri(a1,a2,a4), tri(a1,a3,a4)
    """
    return Query(
        "4-clique-tri",
        4,
        (
            Atom("tri", (0, 1, 2)),
            Atom("tri", (0, 1, 3)),
            Atom("tri", (0, 2, 3)),
        ),
    )


def path(length: int) -> Query:
    """Open path a1 -> a2 -> ... (the classic edge-at-a-time blowup case)."""
    atoms = tuple(Atom(EDGE, (i, i + 1)) for i in range(length))
    return Query(f"path-{length}", length + 1, atoms)


PAPER_QUERIES = {
    "triangle": triangle,
    "4-clique": four_clique,
    "5-clique": five_clique,
    "diamond": diamond,
    "house": house,
    "4-clique-tri": four_clique_tri,
}

# ---------------------------------------------------------------------------
# Named-query registry: the ONE query-name -> builder mapping shared by every
# driver (launch/run_query, launch/serve, benchmarks, examples, repro.api).
# ---------------------------------------------------------------------------

# builders that accept the ``symmetric`` keyword (symmetry-breaking filters)
_SYMMETRIC_OK = frozenset({"triangle", "4-clique", "5-clique", "house"})

# alternate spellings accepted by query_by_name (normalized form -> canonical)
_ALIASES = {
    "tri": "triangle",
    "four-clique": "4-clique",
    "five-clique": "5-clique",
    "4clique": "4-clique",
    "5clique": "5-clique",
    "four-clique-tri": "4-clique-tri",
}

QUERY_REGISTRY = dict(PAPER_QUERIES)
QUERY_NAMES = tuple(QUERY_REGISTRY)


def query_by_name(name: str, symmetric: bool = False) -> Query:
    """Build a named query: the paper's five benchmark motifs plus
    ``path-N``.  Accepts underscore/case variants (``four_clique``) and
    threads ``symmetric`` only to the builders that support it."""
    norm = name.strip().lower().replace("_", "-")
    norm = _ALIASES.get(norm, norm)
    if norm.startswith("path-"):
        if symmetric:
            raise ValueError(f"query {norm!r} has no symmetric variant")
        try:
            return path(int(norm[len("path-"):]))
        except ValueError:
            raise KeyError(f"bad path length in query name {name!r}")
    if norm not in QUERY_REGISTRY:
        raise KeyError(
            f"unknown query {name!r}; known: {', '.join(QUERY_NAMES)} "
            "or path-N")
    build = QUERY_REGISTRY[norm]
    if symmetric and norm not in _SYMMETRIC_OK:
        raise ValueError(f"query {norm!r} has no symmetric variant")
    return build(symmetric=symmetric) if norm in _SYMMETRIC_OK else build()


# ---------------------------------------------------------------------------
# Delta queries (§3.3.1).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeltaQuery:
    """dQ_i :- R'_1,...,R'_{i-1}, dR_i, R_{i+1},...,R_n.

    ``versions[k]`` gives the version of atom k: "new" for k<i, "delta" for
    k==i, "old" for k>i.  ``seed_atom`` is i.  The attribute order for dQ_i
    must begin with atom i's attributes (Thm 3.2) — enforced by the planner.
    """

    query: Query
    seed_atom: int

    @property
    def versions(self) -> Tuple[str, ...]:
        i = self.seed_atom
        return tuple(
            "new" if k < i else ("delta" if k == i else "old")
            for k in range(self.query.num_atoms)
        )


def delta_queries(q: Query) -> Tuple[DeltaQuery, ...]:
    return tuple(DeltaQuery(q, i) for i in range(q.num_atoms))


# ---------------------------------------------------------------------------
# AGM bound (fractional edge cover) — used by tests and the roofline of the
# paper's own workload.  For the common case of subgraph queries over a
# single edge relation with |E| = IN, MaxOut_Q = IN^{rho*}.
# ---------------------------------------------------------------------------

def fractional_edge_cover(q: Query) -> float:
    """Solve the fractional edge cover LP by brute force over vertices of the
    LP polytope for small queries (n_atoms <= 10) via scipy-free simplex on a
    grid refinement; falls back to known closed forms for cliques."""
    # Known closed forms: k-clique rho* = k/2.
    import itertools

    import numpy as np

    n, m = q.num_atoms, q.num_attrs
    # Solve min 1.x  s.t. A x >= 1, x >= 0 where A[j,i] = attr j in atom i.
    A = np.zeros((m, n))
    for i, atom in enumerate(q.atoms):
        for a in atom.attrs:
            A[a, i] = 1.0
    # Vertices of {A x >= 1, x >= 0} arise from choosing n tight constraints
    # among the m + n available; enumerate (fine for paper-sized queries).
    rows = [(A[j], 1.0) for j in range(m)] + [
        (np.eye(n)[i], 0.0) for i in range(n)
    ]
    best = float("inf")
    for combo in itertools.combinations(range(len(rows)), n):
        M = np.stack([rows[c][0] for c in combo])
        b = np.array([rows[c][1] for c in combo])
        try:
            x = np.linalg.solve(M, b)
        except np.linalg.LinAlgError:
            continue
        if (x >= -1e-9).all() and (A @ x >= 1.0 - 1e-9).all():
            best = min(best, float(x.sum()))
    return best


def agm_bound(q: Query, num_edges: int) -> float:
    """MaxOut_Q = IN^{rho*} when every relation has size IN (§1.1)."""
    return float(num_edges) ** fractional_edge_cover(q)
