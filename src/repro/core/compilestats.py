"""Compile-event observability and the persistent compilation cache knob.

Two small facilities that make the latency tail a *measured* quantity
(DESIGN.md §8):

**Trace counters.**  A Python statement inside a jitted function's body runs
exactly when jax traces the function — i.e. once per distinct shape
signature, which on a single backend is once per XLA compilation.  Every
jitted fold in the repo calls :func:`record` with a stable name as its first
body statement, generalizing the old ``distributed._PROGRAM_BUILDS`` counter
to `merge_index`/`_commit_fold`/`_compact_fold`/dataflow steps.  ``StoreStats``
and ``EpochResult`` surface :func:`total` snapshots so tests and benchmarks
can assert "zero recompiles after warmup" instead of eyeballing medians.

**Persistent cache.**  :func:`enable_persistent_cache` wires
``jax.experimental.compilation_cache`` so a restarted worker or CI run
deserializes XLA executables instead of recompiling them.  It must run
BEFORE the first jit use of the process; importing :mod:`repro.core.delta`
(or any api module) is early enough because that import triggers this
module, which auto-enables when ``REPRO_COMPILE_CACHE`` is set to a
directory path.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional

_LOCK = threading.Lock()
_COUNTS: Dict[str, int] = {}
_PERSISTENT_HITS = [0]
_CACHE_DIR: Optional[str] = None

ENV_VAR = "REPRO_COMPILE_CACHE"


def record(name: str) -> None:
    """Count one trace (= compile) event.  Call as the FIRST statement of a
    jitted function body: the Python side of the body runs once per trace,
    never on cached concrete calls."""
    with _LOCK:
        _COUNTS[name] = _COUNTS.get(name, 0) + 1


def counts() -> Dict[str, int]:
    """Per-site compile-event counts (copy)."""
    with _LOCK:
        return dict(_COUNTS)


def total() -> int:
    """Total compile events since process start (or :func:`reset`)."""
    with _LOCK:
        return sum(_COUNTS.values())


def snapshot() -> int:
    """Alias of :func:`total` — pair with :func:`since` around a region."""
    return total()


def since(snap: int) -> int:
    """Compile events recorded after a :func:`snapshot`."""
    return total() - snap


def reset() -> None:
    with _LOCK:
        _COUNTS.clear()
        _PERSISTENT_HITS[0] = 0


def persistent_hits() -> int:
    """Executables deserialized from the persistent cache (0 unless
    :func:`enable_persistent_cache` ran and hits occurred)."""
    return _PERSISTENT_HITS[0]


def cache_dir() -> Optional[str]:
    """The active persistent-cache directory, or None when disabled."""
    return _CACHE_DIR


def enable_persistent_cache(path: Optional[str] = None) -> Optional[str]:
    """Point jax at a persistent on-disk compilation cache.  Idempotent.

    ``path`` defaults to ``$REPRO_COMPILE_CACHE``; returns the directory in
    use, or None when no path is configured.  Must run before the process's
    first jit execution — later calls still help future compilations but
    cannot recover ones already done.  The thresholds are zeroed so even
    sub-second CPU kernels (our folds) persist; jax's own default would skip
    anything compiling in < 1s, which on the CPU CI lane is everything.
    """
    global _CACHE_DIR
    path = path or os.environ.get(ENV_VAR) or None
    if not path:
        return None
    if _CACHE_DIR == path:
        return _CACHE_DIR
    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    from jax.experimental.compilation_cache import compilation_cache as cc
    cc.set_cache_dir(path)
    if _CACHE_DIR is None:  # register the hit listener once
        try:
            from jax import monitoring

            def _listener(event: str, **kw):
                if "cache_hit" in event:
                    _PERSISTENT_HITS[0] += 1

            monitoring.register_event_listener(_listener)
        except Exception:  # pragma: no cover - older jax without monitoring
            pass
    _CACHE_DIR = path
    return _CACHE_DIR


# env knob: the earliest import of this module (delta/session import it
# before building anything jitted) switches the cache on for the process
if os.environ.get(ENV_VAR):  # pragma: no cover - exercised via subprocess
    enable_persistent_cache()
