"""Delta-GJ / Delta-BiGJoin (§3.3): incremental maintenance of join queries.

For each update batch dR (signed edge tuples) the engine runs the n delta
queries

    dQ_i :- R'_1, ..., R'_{i-1}, dR_i, R_{i+1}, ..., R_n

each through the *same* BiGJoin dataflow (bigjoin.py), seeded with dR_i and
planned with an attribute order that begins with R_i's attributes (Thm 3.2).
Atoms left of the seed read the NEW version, atoms right of it the OLD
version — the logical sequencing that makes simultaneous updates correct.

The multi-version index is the paper's three-region LSM structure (§4.3):

    base   — compacted committed state (large, device-resident)
    cins/cdel — uncompacted committed inserts/deletes since last compaction
    uins/udel — the current (uncommitted) batch

OLD = base + cins - cdel;   NEW = OLD + uins - udel.

Commit folds uins/udel into cins/cdel with cancellation, keeping the
invariants  cins ∩ base = ∅,  cdel ⊆ base,  cins ∩ cdel = ∅  so positive
regions never hold duplicates.  Compaction (merge committed into base) runs
when the committed regions exceed ``compact_ratio`` × |base| — and eagerly in
the rare re-insertion-of-committed-delete case, which would otherwise create
a positive/negative overlap (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bigjoin import (BigJoinConfig, Indices, JoinResult,
                                run_bigjoin)
from repro.core.csr import IndexData, build_index
from repro.core.dataflow_index import VersionedIndex
from repro.core.plan import Plan, make_delta_plan
from repro.core.query import Query, delta_queries

Projection = Tuple[str, Tuple[int, ...], int]  # (rel, key_pos, ext_pos)


def _pack2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (a.astype(np.int64) << 32) | b.astype(np.int64)


def _pow2(n: int) -> int:
    """Index capacities rounded up to powers of two (>= one kernel segment):
    stable shapes across update batches keep the jitted dataflow's
    compilation cache warm, and SEG-aligned capacities make the kernels'
    segment-major view a free reshape.  Delegates to the same helper the
    sharded region builds use, so host and shard capacities stay in sync."""
    from repro.core.csr import _pow2_capacity
    return _pow2_capacity(n)


@dataclasses.dataclass
class _Regions:
    """Host-truth + device mirrors of one projection's regions.

    With ``shard_w > 0`` the device mirrors are hash-partitioned over that
    many mesh workers (``csr.build_sharded_index``): every region array
    carries a leading [w] worker axis and each (key, val) entry is stored by
    exactly one worker — the distributed engine's memory-linearity contract.
    ``shard_w == 0`` keeps the single-host mirrors.
    """

    key_pos: Tuple[int, ...]
    ext_pos: int
    base: np.ndarray  # [Nb, arity] tuples
    cins: np.ndarray
    cdel: np.ndarray
    shard_w: int = 0
    d_base: IndexData = None
    d_cins: IndexData = None
    d_cdel: IndexData = None
    d_uins: IndexData = None
    d_udel: IndexData = None

    def _build(self, tup: np.ndarray) -> IndexData:
        rows = tup.reshape(-1, self.arity)
        if self.shard_w:
            from repro.core.csr import build_sharded_index
            per = -(-max(rows.shape[0], 1) // self.shard_w)
            return build_sharded_index(rows, self.key_pos, self.ext_pos,
                                       self.shard_w, capacity=_pow2(per))
        return build_index(rows, self.key_pos, self.ext_pos,
                           capacity=_pow2(rows.shape[0]))

    def refresh(self, which=("base", "cins", "cdel")):
        for name in which:
            setattr(self, "d_" + name, self._build(getattr(self, name)))

    @property
    def arity(self) -> int:
        return max(max(self.key_pos, default=0), self.ext_pos) + 1

    def set_uncommitted(self, uins: np.ndarray, udel: np.ndarray):
        self.d_uins = self._build(uins)
        self.d_udel = self._build(udel)

    def versioned(self, version: str) -> VersionedIndex:
        if version == "old":
            return VersionedIndex((self.d_base, self.d_cins), (self.d_cdel,))
        if version == "new":
            return VersionedIndex((self.d_base, self.d_cins, self.d_uins),
                                  (self.d_cdel, self.d_udel))
        if version == "static":
            return VersionedIndex((self.d_base,), ())
        raise ValueError(version)


def _diff_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rows of a not in b (both [N,2] int)."""
    if a.size == 0 or b.size == 0:
        return a
    pa, pb = _pack2(a[:, 0], a[:, 1]), _pack2(b[:, 0], b[:, 1])
    return a[~np.isin(pa, pb)]


def _inter_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if a.size == 0 or b.size == 0:
        return a[:0]
    pa, pb = _pack2(a[:, 0], a[:, 1]), _pack2(b[:, 0], b[:, 1])
    return a[np.isin(pa, pb)]


@dataclasses.dataclass
class DeltaResult:
    count_delta: int
    tuples: Optional[np.ndarray]
    weights: Optional[np.ndarray]
    per_dq: List[JoinResult]


@dataclasses.dataclass
class StoreStats:
    """Per-store epoch accounting.  ``normalize_calls`` / ``commit_calls``
    are the facade's one-commit-per-epoch contract: with N standing queries
    on one store both advance by exactly 1 per update epoch."""

    normalize_calls: int = 0
    commit_calls: int = 0
    compactions: int = 0
    epochs: int = 0


class RegionStore:
    """Owner of the live edge set and every projection's LSM regions.

    This is the shared substrate under both the single-query engines and the
    :class:`repro.api.GraphSession` facade: projections are created on demand
    (:meth:`ensure`) and SHARED between every query registered against the
    store, so N standing queries pay one region build, one ``normalize`` and
    one ``commit`` per epoch instead of N copies of each.

    ``shard_w > 0`` builds every device mirror hash-partitioned over that
    many mesh workers (the distributed engine's layout); ``shard_w == 0``
    keeps single-host mirrors.
    """

    def __init__(self, initial_edges: np.ndarray, shard_w: int = 0,
                 compact_ratio: float = 0.5):
        self.edges = np.unique(
            np.asarray(initial_edges, np.int32).reshape(-1, 2), axis=0)
        self.shard_w = shard_w
        self.compact_ratio = compact_ratio
        self.projections: Dict[Projection, _Regions] = {}
        self.stats = StoreStats()

    def ensure(self, rel: str, key_pos: Tuple[int, ...], ext_pos: int
               ) -> _Regions:
        """Region storage for one projection, built from the CURRENT live
        edge set on first use and reused by every later query that needs the
        same projection (the hoisted per-query path of old DeltaBigJoin)."""
        if rel != "edge":
            raise NotImplementedError(
                "dynamic non-edge relations: extend _Regions storage")
        proj = (rel, key_pos, ext_pos)
        reg = self.projections.get(proj)
        if reg is None:
            empty = self.edges[:0]
            reg = _Regions(key_pos, ext_pos, self.edges, empty, empty,
                           shard_w=self.shard_w)
            reg.refresh()
            reg.set_uncommitted(empty, empty)
            self.projections[proj] = reg
        return reg

    def ensure_plan(self, plan: Plan):
        for _id, rel, key_pos, ext_pos, _v in plan.index_ids():
            self.ensure(rel, key_pos, ext_pos)

    def indices_for(self, plan: Plan) -> Indices:
        """Assemble the plan's VersionedIndex dict off the shared regions."""
        return {
            _id: self.ensure(rel, key_pos, ext_pos).versioned(version)
            for _id, rel, key_pos, ext_pos, version in plan.index_ids()}

    # ------------------------------------------------------------------
    def normalize(self, updates: np.ndarray, weights: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Net out a batch against the live edge set: returns (ins, del)."""
        self.stats.normalize_calls += 1
        updates = np.asarray(updates, np.int32).reshape(-1, 2)
        weights = np.asarray(weights, np.int32)
        keep = updates[:, 0] != updates[:, 1]
        updates, weights = updates[keep], weights[keep]
        packed = _pack2(updates[:, 0], updates[:, 1])
        uniq, inv = np.unique(packed, return_inverse=True)
        net = np.zeros(uniq.shape[0], np.int64)
        np.add.at(net, inv, weights)
        rows = np.stack([(uniq >> 32).astype(np.int32),
                         (uniq & 0xFFFFFFFF).astype(np.int32)], 1)
        live = _pack2(self.edges[:, 0], self.edges[:, 1]) if \
            self.edges.size else np.zeros(0, np.int64)
        exists = np.isin(uniq, live)
        ins = rows[(net > 0) & ~exists]
        dels = rows[(net < 0) & exists]
        return ins.astype(np.int32), dels.astype(np.int32)

    def _maybe_compact(self, force: bool = False):
        for reg in self.projections.values():
            committed = reg.cins.shape[0] + reg.cdel.shape[0]
            if force or committed > self.compact_ratio * max(
                    reg.base.shape[0], 1):
                if reg.cins.size or reg.cdel.size:
                    reg.base = np.unique(np.concatenate(
                        [_diff_rows(reg.base, reg.cdel), reg.cins]), axis=0)
                    self.stats.compactions += 1
                reg.cins = reg.cins[:0]
                reg.cdel = reg.cdel[:0]
                reg.refresh()

    def begin_epoch(self, ins: np.ndarray, dels: np.ndarray):
        """Stage one normalized batch as the uncommitted region of EVERY
        projection (after the eager re-insertion compaction check)."""
        # eager compaction iff a committed delete is being re-inserted
        # (would create a positive/negative region overlap, DESIGN.md §2)
        need = any(_inter_rows(ins, reg.cdel).size
                   for reg in self.projections.values())
        self._maybe_compact(force=bool(need))
        for reg in self.projections.values():
            reg.set_uncommitted(ins, dels)

    def commit(self, ins: np.ndarray, dels: np.ndarray):
        """Fold uins/udel into the committed regions (with cancellation) and
        advance the live edge set — once per epoch, shared by every query."""
        self.stats.commit_calls += 1
        self.stats.epochs += 1
        for reg in self.projections.values():
            cins = np.unique(np.concatenate(
                [_diff_rows(reg.cins, dels), _diff_rows(ins, reg.cdel)]),
                axis=0) if (ins.size or reg.cins.size) else reg.cins
            cdel = np.unique(np.concatenate(
                [reg.cdel, _inter_rows(dels, reg.base)]), axis=0) \
                if (dels.size or reg.cdel.size) else reg.cdel
            reg.cins, reg.cdel = cins, cdel
            reg.refresh(("cins", "cdel"))
            reg.set_uncommitted(ins[:0], dels[:0])
        if ins.size:
            self.edges = np.unique(np.concatenate([self.edges, ins]), axis=0)
        if dels.size:
            self.edges = _diff_rows(self.edges, dels)
        self._maybe_compact()


class DeltaBigJoin:
    """Incremental maintenance of one query over one dynamic edge relation.

    General n-ary dynamic relations follow the same structure; the engine is
    specialized (as the paper's implementation is, §4) to graph workloads
    where every atom reads the single ``edge`` relation.

    Region/commit bookkeeping lives in a :class:`RegionStore`; by default the
    engine owns a private one, but a shared store may be injected (``store=``)
    so many engines ride one graph with one commit per epoch — that is what
    :class:`repro.api.GraphSession` does.  Prefer the session facade for new
    code; this class remains the single-query engine underneath it.
    """

    def __init__(self, query: Query, initial_edges: Optional[np.ndarray],
                 cfg: BigJoinConfig = BigJoinConfig(mode="collect"),
                 compact_ratio: float = 0.5,
                 store: Optional[RegionStore] = None):
        self.query = query
        self.cfg = cfg
        self.compact_ratio = compact_ratio
        self.plans: List[Plan] = [make_delta_plan(dq)
                                  for dq in delta_queries(query)]
        if store is None:
            store = self._new_store(initial_edges, compact_ratio)
        self.store = store
        for plan in self.plans:
            self.store.ensure_plan(plan)

    def _new_store(self, edges: np.ndarray, compact_ratio: float
                   ) -> RegionStore:
        """Private store; the distributed engine overrides this to build
        worker-sharded device mirrors."""
        return RegionStore(edges, shard_w=0, compact_ratio=compact_ratio)

    # store delegation (public surface predating RegionStore) --------------
    @property
    def edges(self) -> np.ndarray:
        return self.store.edges

    @property
    def projections(self) -> Dict[Projection, _Regions]:
        return self.store.projections

    def normalize(self, updates, weights):
        return self.store.normalize(updates, weights)

    def _maybe_compact(self, force: bool = False):
        self.store._maybe_compact(force)

    def _run_plan(self, plan: Plan, indices: Indices, seed: np.ndarray,
                  weights: np.ndarray) -> JoinResult:
        """Run one delta query's dataflow; overridden by the mesh engine."""
        return run_bigjoin(plan, indices, seed, weights, cfg=self.cfg)

    # ------------------------------------------------------------------
    def run_delta_plans(self, ins: np.ndarray, dels: np.ndarray
                        ) -> DeltaResult:
        """Evaluate dAQ_1..dAQ_n for one staged batch (the store must have
        ``begin_epoch``-ed it); does NOT commit — the caller owns the epoch
        boundary, so a facade can run many queries off one staged batch."""
        delta_edges = np.concatenate([ins, dels], axis=0)
        delta_w = np.concatenate([
            np.ones(ins.shape[0], np.int32),
            -np.ones(dels.shape[0], np.int32)])

        per_dq: List[JoinResult] = []
        total = 0
        tuples, wts = [], []
        for plan in self.plans:
            if delta_edges.size == 0:
                break
            seed = delta_edges[:, list(plan.seed_cols)]
            res = self._run_plan(plan, self.store.indices_for(plan), seed,
                                 delta_w)
            per_dq.append(res)
            total += res.count
            if res.tuples is not None and res.tuples.size:
                tuples.append(res.tuples)
                wts.append(res.weights)
        out_t = np.concatenate(tuples) if tuples else None
        out_w = np.concatenate(wts) if wts else None
        return DeltaResult(total, out_t, out_w, per_dq)

    def apply(self, updates: np.ndarray,
              weights: Optional[np.ndarray] = None) -> DeltaResult:
        """Process one update batch: emit output changes, then commit."""
        updates = np.asarray(updates, np.int32).reshape(-1, 2)
        if weights is None:
            weights = np.ones(updates.shape[0], np.int32)
        ins, dels = self.store.normalize(updates, weights)
        if ins.size == 0 and dels.size == 0:
            # net-zero batch (no-op inserts of live edges, deletes of absent
            # edges, +/- cancellations): an EXACT no-op — no region rebuilds,
            # no compaction, no dataflow run (tests/test_delta_stream.py).
            return DeltaResult(0, None, None, [])
        self.store.begin_epoch(ins, dels)
        result = self.run_delta_plans(ins, dels)
        self.store.commit(ins, dels)
        return result


def rows_isin(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-membership mask of ``a``'s rows in ``b`` (both [N, m] int).

    Packed-row diff: rows are mapped to dense ids by one ``np.unique`` over
    the concatenation, then compared with ``np.isin`` on the id vectors — no
    Python set-of-tuples.  O((Na+Nb) log) and fully vectorized; this is the
    stress suite's hot path (delta_oracle on every update batch).
    """
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape[0] == 0 or b.shape[0] == 0:
        return np.zeros(a.shape[0], bool)
    both = np.concatenate([a, b], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    inv = inv.reshape(-1)  # numpy>=2.0 may return [N,1]
    return np.isin(inv[:a.shape[0]], inv[a.shape[0]:])


def delta_oracle(query: Query, edges_before: np.ndarray,
                 edges_after: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Ground truth: signed difference of full recomputation.

    Returns (tuples [N, m] int32, weights [N] ±1) with the added rows first,
    each block in lexicographic row order (``np.unique`` order — the same
    order the old set-of-tuples implementation produced via ``sorted``).
    """
    from repro.core.generic_join import generic_join
    a, _ = generic_join(query, {"edge": edges_before})
    b, _ = generic_join(query, {"edge": edges_after})
    m = query.num_attrs
    a = np.unique(np.asarray(a, np.int32).reshape(-1, m), axis=0)
    b = np.unique(np.asarray(b, np.int32).reshape(-1, m), axis=0)
    added = b[~rows_isin(b, a)]
    removed = a[~rows_isin(a, b)]
    t = np.concatenate([added, removed]).astype(np.int32).reshape(-1, m)
    w = np.concatenate([np.ones(added.shape[0], np.int32),
                        -np.ones(removed.shape[0], np.int32)])
    return t, w
