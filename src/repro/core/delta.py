"""Delta-GJ / Delta-BiGJoin (§3.3): incremental maintenance of join queries.

For each update batch dR (signed edge tuples) the engine runs the n delta
queries

    dQ_i :- R'_1, ..., R'_{i-1}, dR_i, R_{i+1}, ..., R_n

each through the *same* BiGJoin dataflow (bigjoin.py), seeded with dR_i and
planned with an attribute order that begins with R_i's attributes (Thm 3.2).
Atoms left of the seed read the NEW version, atoms right of it the OLD
version — the logical sequencing that makes simultaneous updates correct.

The multi-version index is the paper's three-region LSM structure (§4.3):

    base   — compacted committed state (large, device-resident)
    cins/cdel — uncompacted committed inserts/deletes since last compaction
    uins/udel — the current (uncommitted) batch

OLD = base + cins - cdel;   NEW = OLD + uins - udel.

Commit folds uins/udel into cins/cdel with cancellation, keeping the
invariants  cins ∩ base = ∅,  cdel ⊆ base,  cins ∩ cdel = ∅  so positive
regions never hold duplicates.  Compaction (merge committed into base) runs
when the committed regions exceed ``compact_ratio`` × |base| — and eagerly in
the rare re-insertion-of-committed-delete case, which would otherwise create
a positive/negative overlap (see DESIGN.md §2).

Region state is DEVICE-RESIDENT (DESIGN.md §6): the live edge set is a
sorted packed-int64 device array maintained as its own three-region LSM,
``normalize`` is a jitted searchsorted membership probe against it, and
``commit`` is a jitted sorted-merge/diff fold (``csr.merge_index`` /
``diff_index`` / ``intersect_index``) that touches only the committed
regions and the delta — the compacted base is merged at (amortized)
compaction only, so warm epoch cost is O(|Δ|·log|E| + |committed|) instead
of the full-graph rescan the host path pays.  Host numpy arrays are a
lazily-materialized debug mirror, pulled only by oracle/differential paths
(``StoreStats.mirror_pulls`` counts the pulls).  ``device_resident=False``
keeps the legacy host-truth store (with an incrementally-maintained packed
live-edge cache) for contrast benchmarks.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csr
from repro.core.bigjoin import (BigJoinConfig, Indices, JoinResult,
                                run_bigjoin)
from repro.core.csr import IndexData, build_index
from repro.core.dataflow_index import VersionedIndex
from repro.core.plan import Plan, make_delta_plan
from repro.core.query import Query, delta_queries

Projection = Tuple[str, Tuple[int, ...], int]  # (rel, key_pos, ext_pos)

# With the strict flag every jitted device step of the store runs under
# ``jax.transfer_guard("disallow")``: any host<->device copy on the warm
# normalize/commit path raises instead of silently re-uploading an index.
# The CI transfer-guard lane sets this for the delta-stream suites; the
# delta-sized staging uploads and scalar count pulls happen OUTSIDE the
# guarded scopes by construction (they are proportional to |Δ|, not |E|).
STRICT_TRANSFERS = os.environ.get("REPRO_STRICT_TRANSFERS", "") not in ("",
                                                                        "0")

# Merge-rank kernel routing for the fold inner loop: None = compiled Pallas
# on TPU / pure jnp elsewhere; True/False force.  The sharded (vmapped)
# folds always use the jnp path.
USE_MERGE_KERNEL: Optional[bool] = None


def _merge_kernel_on() -> bool:
    if USE_MERGE_KERNEL is None:
        return jax.default_backend() == "tpu"
    return bool(USE_MERGE_KERNEL)


@contextlib.contextmanager
def _device_scope():
    if STRICT_TRANSFERS:
        with jax.transfer_guard("disallow"):
            yield
    else:
        yield


def _pack2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (a.astype(np.int64) << 32) | b.astype(np.int64)


def _unpack2(packed: np.ndarray) -> np.ndarray:
    packed = np.asarray(packed, np.int64)
    return np.stack([(packed >> 32).astype(np.int32),
                     (packed & 0xFFFFFFFF).astype(np.int32)], 1)


def _pow2(n: int) -> int:
    """Index capacities rounded up to powers of two (>= one kernel segment):
    stable shapes across update batches keep the jitted dataflow's
    compilation cache warm, and SEG-aligned capacities make the kernels'
    segment-major view a free reshape.  Delegates to the same helper the
    sharded region builds use, so host and shard capacities stay in sync."""
    from repro.core.csr import _pow2_capacity
    return _pow2_capacity(n)


def _total(n) -> int:
    return int(np.sum(n))


def _maxn(n) -> int:
    return int(np.max(n)) if np.ndim(np.asarray(n)) else int(n)


def _count_of(d: IndexData):
    """Exact live count(s) of a device region: int single-host, [w] int64
    vector sharded.  One scalar/vector pull — never the index arrays."""
    n = np.asarray(d.n)
    return n.astype(np.int64) if n.ndim else int(n)


# ---------------------------------------------------------------------------
# jitted device cores (called by RegionStore under _device_scope; all
# arguments are device arrays — no implicit transfers on the warm path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("sharded",))
def _normalize_core(upd: jax.Array, w: jax.Array, base: IndexData,
                    cins: IndexData, cdel: IndexData, sharded: bool = False):
    """Net one padded update batch against the live LSM: (ins, n_ins,
    dels, n_dels) as sentinel-padded sorted packed-int64 arrays.

    upd [B,2] int32 / w [B] int32 (padding rows are self-loops with w=0);
    base/cins/cdel: the store's packed live regions (IndexData, val≡0),
    hash-partitioned over a leading [w] worker axis when ``sharded`` — a
    key lives on exactly one shard, so membership is an OR over vmapped
    per-shard probes and per-worker live memory stays O(|E|/w).
    live = (base \\ cdel) ∪ cins under the commit invariants.
    """
    SENT = jnp.int64(csr.SENTINEL)
    u, v = upd[:, 0], upd[:, 1]
    valid = (u != v) & (w != 0)
    p = jnp.where(valid, (u.astype(jnp.int64) << 32) | v.astype(jnp.int64),
                  SENT)
    order = jnp.argsort(p)
    ps, ws = p[order], w[order]
    first = jnp.concatenate([jnp.ones(1, bool), ps[1:] != ps[:-1]])
    ids = jnp.cumsum(first.astype(jnp.int32)) - 1
    net = jax.ops.segment_sum(ws.astype(jnp.int64), ids,
                              num_segments=ps.shape[0])
    uniq = jnp.full(ps.shape[0], SENT, jnp.int64).at[ids].set(ps)
    zeros = jnp.zeros(ps.shape[0], jnp.int32)

    def member(idx):
        if sharded:
            return jax.vmap(
                lambda d: csr.index_member(d, uniq, zeros))(idx).any(0)
        return csr.index_member(idx, uniq, zeros)

    in_base = member(base)
    in_cins = member(cins)
    in_cdel = member(cdel)
    exists = (in_base & ~in_cdel) | in_cins
    alive = uniq < SENT
    ins_m = alive & (net > 0) & ~exists
    del_m = alive & (net < 0) & exists

    def compact(mask):
        cum = jnp.cumsum(mask.astype(jnp.int32))
        pos = jnp.where(mask, cum - 1, mask.shape[0])
        out = jnp.full(mask.shape[0], SENT, jnp.int64
                       ).at[pos].set(uniq, mode="drop")
        return out, mask.sum(dtype=jnp.int32)

    oi, ni = compact(ins_m)
    od, nd = compact(del_m)
    return oi, ni, od, nd


@functools.partial(jax.jit, static_argnames=("cins_cap", "cdel_cap",
                                             "sharded", "use_kernel"))
def _commit_fold(base: IndexData, cins: IndexData, cdel: IndexData,
                 uins: IndexData, udel: IndexData, *, cins_cap: int,
                 cdel_cap: int, sharded: bool, use_kernel: bool = False):
    """The committed-region fold of one epoch, merged never rebuilt:

        cins' = (cins \\ udel) ∪ (uins \\ cdel)
        cdel' = cdel ∪ (udel ∩ base)

    Touches only the committed regions and the delta — ``base`` is probed
    (O(|Δ|·log|base|)), never scanned.  ``sharded`` vmaps the fold over the
    leading worker axis: ownership is by packed key, so every merge is
    shard-local and the distributed commit stays collective-free.
    """
    def fold(ba, ci, cd, ui, ud):
        kept = csr._select_core(ci, ud, ci.capacity, False, use_kernel)
        fresh = csr._select_core(ui, cd, ui.capacity, False, use_kernel)
        new_cins = csr._merge_core(kept, fresh, cins_cap, use_kernel)
        dead = csr._select_core(ud, ba, ud.capacity, True, use_kernel)
        new_cdel = csr._merge_core(cd, dead, cdel_cap, use_kernel)
        return new_cins, new_cdel

    if sharded:
        return jax.vmap(fold)(base, cins, cdel, uins, udel)
    return fold(base, cins, cdel, uins, udel)


@functools.partial(jax.jit, static_argnames=("out_cap", "sharded",
                                             "use_kernel"))
def _compact_fold(base: IndexData, cins: IndexData, cdel: IndexData, *,
                  out_cap: int, sharded: bool, use_kernel: bool = False
                  ) -> IndexData:
    """base' = (base \\ cdel) ∪ cins — the amortized O(|base|) merge."""
    def fold(ba, ci, cd):
        kept = csr._select_core(ba, cd, ba.capacity, False, use_kernel)
        return csr._merge_core(kept, ci, out_cap, use_kernel)

    if sharded:
        return jax.vmap(fold)(base, cins, cdel)
    return fold(base, cins, cdel)


@functools.partial(jax.jit, static_argnames=("sharded",))
def _any_member(idx: IndexData, qk: jax.Array, qv: jax.Array,
                sharded: bool = False) -> jax.Array:
    """any((qk,qv) ∈ idx) — the eager re-insertion probe (delta-sized)."""
    if sharded:
        return jax.vmap(lambda d: csr.index_member(d, qk, qv))(idx).any()
    return csr.index_member(idx, qk, qv).any()


def _packed_index(rows: np.ndarray, shard_w: int = 0) -> IndexData:
    """Packed-edge IndexData (key = src<<32|dst, val ≡ 0) from host rows —
    only ever built for the initial graph and per-epoch deltas.  Delegates
    to the csr builders over a zero ext column, so the sharded layout and
    ownership (``csr.shard_of``) are THE SAME code path as the projections'
    shards — the cross-structure shard agreement the distributed commit
    folds rely on is not re-implemented here."""
    rows3 = np.concatenate(
        [np.asarray(rows, np.int32).reshape(-1, 2),
         np.zeros((rows.shape[0], 1), np.int32)], axis=1)
    if shard_w:
        return csr.build_sharded_index(rows3, (0, 1), 2, shard_w,
                                       narrow=False)
    return csr.build_index(rows3, (0, 1), 2,
                           capacity=_pow2(rows3.shape[0]), narrow=False)


def _empty_packed(shard_w: int = 0) -> IndexData:
    if not shard_w:
        return csr.empty_index(narrow=False)
    w = int(shard_w)
    return IndexData(
        jnp.full((w, csr.SEG), jnp.int64(csr.SENTINEL), jnp.int64),
        jnp.zeros((w, csr.SEG), jnp.int32), jnp.zeros(w, jnp.int32))


def _pad_probe(keys: np.ndarray, vals: np.ndarray, sent) -> Tuple:
    B = _pow2(keys.shape[0])
    k = np.full(B, sent, keys.dtype)
    k[:keys.shape[0]] = keys
    v = np.zeros(B, np.int32)
    v[:vals.shape[0]] = vals
    return jnp.asarray(k), jnp.asarray(v)


@dataclasses.dataclass
class _Regions:
    """Device truth of one projection's regions (+ optional mirrors).

    ``device_resident`` (default): ``d_base/d_cins/d_cdel`` ARE the state —
    sorted device IndexData updated by the jitted folds above; ``base`` /
    ``cins`` / ``cdel`` are lazily-materialized host mirrors for debug and
    differential paths.  Legacy mode inverts this: ``_host`` numpy arrays
    are the truth and ``refresh()`` rebuilds the device mirrors from them.

    With ``shard_w > 0`` every region array carries a leading [w] worker
    axis and each (key, val) entry is stored by exactly one worker
    (``csr.build_sharded_index``) — the distributed engine's
    memory-linearity contract; the folds vmap over the axis, so each worker
    folds only its owned rows.
    """

    key_pos: Tuple[int, ...]
    ext_pos: int
    shard_w: int = 0
    device_resident: bool = True
    narrow: bool = True
    d_base: IndexData = None
    d_cins: IndexData = None
    d_cdel: IndexData = None
    d_uins: IndexData = None
    d_udel: IndexData = None
    # exact live counts (host bookkeeping, pulled once per fold):
    # ints single-host, [w] int64 vectors sharded
    n_base: object = 0
    n_cins: object = 0
    n_cdel: object = 0
    _host: dict = dataclasses.field(default_factory=dict)
    _mirror: dict = dataclasses.field(default_factory=dict)
    _store: object = None

    @property
    def arity(self) -> int:
        return max(max(self.key_pos, default=0), self.ext_pos) + 1

    def _build(self, tup: np.ndarray) -> IndexData:
        rows = np.asarray(tup).reshape(-1, self.arity)
        if self.shard_w:
            from repro.core.csr import build_sharded_index
            per = -(-max(rows.shape[0], 1) // self.shard_w)
            return build_sharded_index(rows, self.key_pos, self.ext_pos,
                                       self.shard_w, capacity=_pow2(per),
                                       narrow=self.narrow)
        return build_index(rows, self.key_pos, self.ext_pos,
                           capacity=_pow2(rows.shape[0]),
                           narrow=self.narrow)

    # -- host rows: legacy truth, or the device mode's lazy debug mirror ----
    def _rows(self, name: str) -> np.ndarray:
        if not self.device_resident:
            return self._host[name]
        if name not in self._mirror:
            self._mirror[name] = self._materialize(getattr(self,
                                                           "d_" + name))
            if self._store is not None:
                self._store.stats.mirror_pulls += 1
        return self._mirror[name]

    @property
    def base(self) -> np.ndarray:
        return self._rows("base")

    @property
    def cins(self) -> np.ndarray:
        return self._rows("cins")

    @property
    def cdel(self) -> np.ndarray:
        return self._rows("cdel")

    def _materialize(self, d: IndexData) -> np.ndarray:
        """Reconstruct host tuple rows from the device (key, val) arrays;
        canonical row-lex (np.unique) order, like the old host truth."""
        keys, vals, ns = np.asarray(d.key), np.asarray(d.val), np.asarray(d.n)
        if self.shard_w:
            key = np.concatenate([keys[k][:ns[k]]
                                  for k in range(self.shard_w)])
            val = np.concatenate([vals[k][:ns[k]]
                                  for k in range(self.shard_w)])
        else:
            key, val = keys[:int(ns)], vals[:int(ns)]
        rows = np.zeros((key.shape[0], self.arity), np.int32)
        if len(self.key_pos) == 1:
            rows[:, self.key_pos[0]] = key.astype(np.int64) & 0xFFFFFFFF
        elif len(self.key_pos) == 2:
            k64 = key.astype(np.int64)
            rows[:, self.key_pos[0]] = (k64 >> 32).astype(np.int32)
            rows[:, self.key_pos[1]] = (k64 & 0xFFFFFFFF).astype(np.int32)
        rows[:, self.ext_pos] = val
        order = np.lexsort(tuple(rows[:, c]
                                 for c in range(rows.shape[1] - 1, -1, -1)))
        return rows[order]

    def refresh(self, which=("base", "cins", "cdel")):
        """Legacy mode only: rebuild device mirrors from the host truth."""
        assert not self.device_resident, \
            "device-resident regions are merged, never rebuilt"
        for name in which:
            setattr(self, "d_" + name, self._build(self._host[name]))

    def set_uncommitted(self, uins: np.ndarray, udel: np.ndarray):
        self.d_uins = self._build(uins)
        self.d_udel = self._build(udel)

    def probe_cdel(self, ins: np.ndarray) -> bool:
        """any(ins ∈ cdel) — device probe, O(|Δ|·log|cdel|)."""
        key = csr.pack_key(tuple(ins[:, p].astype(np.int32)
                                 for p in self.key_pos))
        kdt = np.dtype(self.d_cdel.key.dtype.name)
        sent = csr.SENTINEL32 if kdt == np.int32 else csr.SENTINEL
        qk, qv = _pad_probe(key.astype(kdt),
                            ins[:, self.ext_pos].astype(np.int32), sent)
        return bool(_any_member(self.d_cdel, qk, qv,
                                sharded=bool(self.shard_w)))

    def versioned(self, version: str) -> VersionedIndex:
        if version == "old":
            return VersionedIndex((self.d_base, self.d_cins), (self.d_cdel,))
        if version == "new":
            return VersionedIndex((self.d_base, self.d_cins, self.d_uins),
                                  (self.d_cdel, self.d_udel))
        if version == "static":
            return VersionedIndex((self.d_base,), ())
        raise ValueError(version)


def _diff_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rows of a not in b (both [N,2] int)."""
    if a.size == 0 or b.size == 0:
        return a
    pa, pb = _pack2(a[:, 0], a[:, 1]), _pack2(b[:, 0], b[:, 1])
    return a[~np.isin(pa, pb)]


def _inter_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if a.size == 0 or b.size == 0:
        return a[:0]
    pa, pb = _pack2(a[:, 0], a[:, 1]), _pack2(b[:, 0], b[:, 1])
    return a[np.isin(pa, pb)]


@dataclasses.dataclass
class DeltaResult:
    count_delta: int
    tuples: Optional[np.ndarray]
    weights: Optional[np.ndarray]
    per_dq: List[JoinResult]


@dataclasses.dataclass
class StoreStats:
    """Per-store epoch accounting.  ``normalize_calls`` / ``commit_calls``
    are the facade's one-commit-per-epoch contract: with N standing queries
    on one store both advance by exactly 1 per update epoch.
    ``mirror_pulls`` counts host materializations of device-resident state
    (debug/differential paths only — zero on the warm epoch loop);
    ``live_compactions`` tracks the store-level live-set LSM separately
    from the per-projection ``compactions``."""

    normalize_calls: int = 0
    commit_calls: int = 0
    compactions: int = 0
    epochs: int = 0
    live_compactions: int = 0
    mirror_pulls: int = 0


class RegionStore:
    """Owner of the live edge set and every projection's LSM regions.

    This is the shared substrate under both the single-query engines and the
    :class:`repro.api.GraphSession` facade: projections are created on demand
    (:meth:`ensure`) and SHARED between every query registered against the
    store, so N standing queries pay one region build, one ``normalize`` and
    one ``commit`` per epoch instead of N copies of each.

    ``device_resident=True`` (default): the source of truth is on device —
    the live edge set is its own packed three-region LSM, ``normalize`` is
    a jitted membership probe, ``commit``/compaction are jitted sorted-merge
    folds, and ``edges`` / region rows are lazily-pulled debug mirrors.
    ``device_resident=False`` keeps the legacy host-numpy truth (the old
    behaviour, with an incrementally-maintained packed live-edge cache).

    ``shard_w > 0`` builds every device region hash-partitioned over that
    many mesh workers (the distributed engine's layout); the commit folds
    vmap over the worker axis, so each worker folds only its owned rows and
    the distributed commit needs no collectives.
    """

    def __init__(self, initial_edges: np.ndarray, shard_w: int = 0,
                 compact_ratio: float = 0.5, device_resident: bool = True):
        edges = np.unique(
            np.asarray(initial_edges, np.int32).reshape(-1, 2), axis=0)
        self.shard_w = shard_w
        self.compact_ratio = compact_ratio
        self.device_resident = bool(device_resident)
        self.projections: Dict[Projection, _Regions] = {}
        self.stats = StoreStats()
        self._staged: Optional[Tuple[np.ndarray, np.ndarray]] = None
        if self.device_resident:
            # the live-edge LSM shards like the projections (ownership by
            # packed key), so per-worker live memory stays O(|E|/w)
            self._lb = _packed_index(edges, shard_w)
            self._lc_ins = _empty_packed(shard_w)
            self._lc_del = _empty_packed(shard_w)
            zero = np.zeros(shard_w, np.int64) if shard_w else 0
            nb = _count_of(self._lb) if shard_w else edges.shape[0]
            self._n_live = [nb, zero, zero]  # base, cins, cdel
            self._edges_mirror: Optional[np.ndarray] = edges
        else:
            self._edges = edges
            self._packed_live = np.sort(_pack2(edges[:, 0], edges[:, 1])) \
                if edges.size else np.zeros(0, np.int64)

    # -- the live edge set --------------------------------------------------
    @property
    def edges(self) -> np.ndarray:
        """Live edges as host rows.  Legacy: the truth.  Device-resident:
        a lazily-materialized mirror (oracle/differential paths only — the
        warm epoch loop never touches it)."""
        if not self.device_resident:
            return self._edges
        if self._edges_mirror is None:
            nb, nci, _ = self._n_live
            cap = _pow2(_maxn(np.asarray(nb) + np.asarray(nci)))
            live = _compact_fold(self._lb, self._lc_ins, self._lc_del,
                                 out_cap=cap, sharded=bool(self.shard_w))
            if self.shard_w:
                ns = np.asarray(live.n)
                keys = np.asarray(live.key)
                packed = np.sort(np.concatenate(
                    [keys[k][:ns[k]] for k in range(self.shard_w)]))
            else:
                packed = np.asarray(live.key)[:int(live.n)]
            self._edges_mirror = _unpack2(packed)
            self.stats.mirror_pulls += 1
        return self._edges_mirror

    @property
    def num_edges(self) -> int:
        """Live edge count, O(1) from the tracked region sizes — no mirror
        materialization (|live| = |base| + |cins| − |cdel|)."""
        if not self.device_resident:
            return int(self._edges.shape[0])
        nb, nci, ncd = self._n_live
        return _total(nb) + _total(nci) - _total(ncd)

    def ensure(self, rel: str, key_pos: Tuple[int, ...], ext_pos: int
               ) -> _Regions:
        """Region storage for one projection, built from the CURRENT live
        edge set on first use and reused by every later query that needs the
        same projection (the hoisted per-query path of old DeltaBigJoin)."""
        if rel != "edge":
            raise NotImplementedError(
                "dynamic non-edge relations: extend _Regions storage")
        proj = (rel, key_pos, ext_pos)
        reg = self.projections.get(proj)
        if reg is not None:
            return reg
        rows = self.edges
        # narrow is decided ONCE per projection (merges must keep one
        # dtype): auto-widen when an id already collides with the int32
        # sentinel, like build_index's per-build check did
        narrow = len(key_pos) <= 1 and \
            (rows.size == 0 or int(rows.max()) < int(csr.SENTINEL32))
        reg = _Regions(key_pos, ext_pos, shard_w=self.shard_w,
                       device_resident=self.device_resident, narrow=narrow,
                       _store=self)
        empty = rows[:0]
        if self.device_resident:
            reg.d_base = reg._build(rows)
            reg.d_cins = reg._build(empty)
            reg.d_cdel = reg._build(empty)
            reg.n_base = _count_of(reg.d_base) if self.shard_w \
                else rows.shape[0]
            reg.n_cins = np.zeros(self.shard_w, np.int64) if self.shard_w \
                else 0
            reg.n_cdel = np.zeros(self.shard_w, np.int64) if self.shard_w \
                else 0
            reg._mirror["base"] = rows
            reg._mirror["cins"] = empty
            reg._mirror["cdel"] = empty
        else:
            reg._host = {"base": rows, "cins": empty, "cdel": empty}
            reg.refresh()
        # a projection ensured mid-epoch (after begin_epoch, before commit)
        # must see the staged batch: its base is the PRE-commit live set, so
        # old = base and new = base + uins - udel stay consistent, and the
        # commit fold picks the delta up instead of losing it
        ins, dels = self._staged if self._staged is not None else \
            (empty, empty)
        reg.set_uncommitted(ins, dels)
        self.projections[proj] = reg
        return reg

    def ensure_plan(self, plan: Plan):
        for _id, rel, key_pos, ext_pos, _v in plan.index_ids():
            self.ensure(rel, key_pos, ext_pos)

    def indices_for(self, plan: Plan) -> Indices:
        """Assemble the plan's VersionedIndex dict off the shared regions."""
        return {
            _id: self.ensure(rel, key_pos, ext_pos).versioned(version)
            for _id, rel, key_pos, ext_pos, version in plan.index_ids()}

    # ------------------------------------------------------------------
    def normalize(self, updates: np.ndarray, weights: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Net out a batch against the live edge set: returns (ins, del).

        Device-resident: one jitted probe against the packed live LSM —
        O(|Δ|·log|E|), no full-graph scan, no mirror pull.
        """
        self.stats.normalize_calls += 1
        updates = np.asarray(updates, np.int32).reshape(-1, 2)
        weights = np.asarray(weights, np.int32)
        if not self.device_resident:
            return self._normalize_host(updates, weights)
        B = _pow2(updates.shape[0])
        upd = np.zeros((B, 2), np.int32)  # pad rows are self-loops, w=0
        wts = np.zeros(B, np.int32)
        upd[:updates.shape[0]] = updates
        wts[:weights.shape[0]] = weights
        dup, dw = jnp.asarray(upd), jnp.asarray(wts)
        with _device_scope():
            oi, ni, od, nd = _normalize_core(dup, dw, self._lb,
                                             self._lc_ins, self._lc_del,
                                             sharded=bool(self.shard_w))
        ins = _unpack2(np.asarray(oi)[:int(ni)])
        dels = _unpack2(np.asarray(od)[:int(nd)])
        return ins, dels

    def _normalize_host(self, updates: np.ndarray, weights: np.ndarray):
        """Legacy host path, probing the incrementally-maintained sorted
        ``_packed_live`` cache (no per-call re-pack of the edge list)."""
        keep = updates[:, 0] != updates[:, 1]
        updates, weights = updates[keep], weights[keep]
        packed = _pack2(updates[:, 0], updates[:, 1])
        uniq, inv = np.unique(packed, return_inverse=True)
        net = np.zeros(uniq.shape[0], np.int64)
        np.add.at(net, inv, weights)
        rows = _unpack2(uniq)
        live = self._packed_live
        if live.size:
            pos = np.searchsorted(live, uniq)
            exists = (pos < live.shape[0]) & \
                (live[np.minimum(pos, live.shape[0] - 1)] == uniq)
        else:
            exists = np.zeros(uniq.shape[0], bool)
        ins = rows[(net > 0) & ~exists]
        dels = rows[(net < 0) & exists]
        return ins.astype(np.int32), dels.astype(np.int32)

    # ------------------------------------------------------------------
    def _maybe_compact(self, force: bool = False):
        if not self.device_resident:
            self._maybe_compact_host(force)
            return
        use_k = _merge_kernel_on() and not self.shard_w
        nb, nci, ncd = self._n_live
        if (force or _total(nci) + _total(ncd) >
                self.compact_ratio * max(_total(nb), 1)) and \
                (_total(nci) or _total(ncd)):
            new_nb = np.asarray(nb) - np.asarray(ncd) + np.asarray(nci)
            with _device_scope():
                self._lb = _compact_fold(self._lb, self._lc_ins,
                                         self._lc_del,
                                         out_cap=_pow2(_maxn(new_nb)),
                                         sharded=bool(self.shard_w),
                                         use_kernel=use_k)
            zero = np.zeros(self.shard_w, np.int64) if self.shard_w else 0
            self._lc_ins = _empty_packed(self.shard_w)
            self._lc_del = _empty_packed(self.shard_w)
            self._n_live = [new_nb if self.shard_w else int(new_nb),
                            zero, zero]
            self.stats.live_compactions += 1
            self._edges_mirror = None
            # invariant audit: cdel ⊆ base and cins ∩ base = ∅ make the
            # compacted size exact arithmetic — a mismatch means corruption
            assert (np.asarray(_count_of(self._lb)) == new_nb).all()
        for reg in self.projections.values():
            committed = _total(reg.n_cins) + _total(reg.n_cdel)
            if not (force or committed >
                    self.compact_ratio * max(_total(reg.n_base), 1)):
                continue
            if committed:
                new_n = np.asarray(reg.n_base) - np.asarray(reg.n_cdel) \
                    + np.asarray(reg.n_cins)
                with _device_scope():
                    reg.d_base = _compact_fold(
                        reg.d_base, reg.d_cins, reg.d_cdel,
                        out_cap=_pow2(_maxn(new_n)),
                        sharded=bool(self.shard_w), use_kernel=use_k)
                assert (np.asarray(_count_of(reg.d_base)) == new_n).all()
                reg.n_base = _count_of(reg.d_base) if self.shard_w \
                    else int(new_n)
                empty = np.zeros((0, reg.arity), np.int32)
                reg.d_cins = reg._build(empty)
                reg.d_cdel = reg._build(empty)
                reg.n_cins = np.zeros(self.shard_w, np.int64) \
                    if self.shard_w else 0
                reg.n_cdel = np.zeros(self.shard_w, np.int64) \
                    if self.shard_w else 0
                self.stats.compactions += 1
                reg._mirror.clear()

    def _maybe_compact_host(self, force: bool = False):
        for reg in self.projections.values():
            h = reg._host
            committed = h["cins"].shape[0] + h["cdel"].shape[0]
            if force or committed > self.compact_ratio * max(
                    h["base"].shape[0], 1):
                if h["cins"].size or h["cdel"].size:
                    h["base"] = np.unique(np.concatenate(
                        [_diff_rows(h["base"], h["cdel"]), h["cins"]]),
                        axis=0)
                    self.stats.compactions += 1
                h["cins"] = h["cins"][:0]
                h["cdel"] = h["cdel"][:0]
                reg.refresh()

    def begin_epoch(self, ins: np.ndarray, dels: np.ndarray):
        """Stage one normalized batch as the uncommitted region of EVERY
        projection (after the eager re-insertion compaction check)."""
        # eager compaction iff a committed delete is being re-inserted
        # (would create a positive/negative region overlap, DESIGN.md §2)
        if self.device_resident:
            need = False
            if ins.size:
                if _total(self._n_live[2]):
                    pi = _pack2(ins[:, 0], ins[:, 1])
                    qk, qv = _pad_probe(pi, np.zeros(pi.shape[0], np.int32),
                                        np.int64(csr.SENTINEL))
                    need = bool(_any_member(self._lc_del, qk, qv,
                                            sharded=bool(self.shard_w)))
                if not need:
                    need = any(reg.probe_cdel(ins)
                               for reg in self.projections.values()
                               if _total(reg.n_cdel))
        else:
            need = any(_inter_rows(ins, reg._host["cdel"]).size
                       for reg in self.projections.values())
        if ins.size and int(ins.max()) >= int(csr.SENTINEL32) and \
                any(reg.narrow for reg in self.projections.values()):
            raise ValueError(
                f"vertex id >= {int(csr.SENTINEL32)} collides with the "
                "narrow int32 index sentinel of an existing projection; "
                "ids this large must be present in the initial edge set "
                "so the projection is built wide")
        self._maybe_compact(force=bool(need))
        for reg in self.projections.values():
            reg.set_uncommitted(ins, dels)
        self._staged = (ins, dels)

    def commit(self, ins: np.ndarray, dels: np.ndarray):
        """Fold uins/udel into the committed regions (with cancellation) and
        advance the live edge set — once per epoch, shared by every query.

        Device-resident: jitted sorted-merge/diff folds over the committed
        regions and the staged delta only; the compacted base region object
        passes through UNTOUCHED (no rebuild, no re-upload).
        """
        self.stats.commit_calls += 1
        self.stats.epochs += 1
        if self._staged is None:
            # raw commit without begin_epoch: net the args against the live
            # set first (a live "insert" or absent "delete" must be a no-op,
            # exactly as normalize guarantees on the staged path), then
            # stage — so projections and the live set fold the SAME batch
            ins = np.asarray(ins, np.int32).reshape(-1, 2)
            dels = np.asarray(dels, np.int32).reshape(-1, 2)
            ins, dels = self.normalize(
                np.concatenate([ins, dels]),
                np.concatenate([np.ones(ins.shape[0], np.int32),
                                -np.ones(dels.shape[0], np.int32)]))
            self.begin_epoch(ins, dels)
        ins, dels = self._staged
        self._staged = None
        if not self.device_resident:
            self._commit_host(ins, dels)
            return
        use_k = _merge_kernel_on() and not self.shard_w
        # live-set LSM fold (store-level, packed; shard-local when sharded)
        li = _packed_index(ins, self.shard_w)
        ld = _packed_index(dels, self.shard_w)
        nb, nci, ncd = self._n_live
        live_cins_cap = _pow2(_maxn(np.asarray(nci)
                                    + np.asarray(_count_of(li))))
        live_cdel_cap = _pow2(_maxn(np.asarray(ncd)
                                    + np.asarray(_count_of(ld))))
        with _device_scope():
            new_ci, new_cd = _commit_fold(
                self._lb, self._lc_ins, self._lc_del, li, ld,
                cins_cap=live_cins_cap, cdel_cap=live_cdel_cap,
                sharded=bool(self.shard_w), use_kernel=use_k)
        self._lc_ins, self._lc_del = new_ci, new_cd
        self._n_live = [nb, _count_of(new_ci), _count_of(new_cd)]
        self._edges_mirror = None
        # per-projection folds (vmapped over shards when distributed)
        for reg in self.projections.values():
            ci_cap = _pow2(_maxn(np.asarray(reg.n_cins)
                                 + np.asarray(_count_of(reg.d_uins))))
            cd_cap = _pow2(_maxn(np.asarray(reg.n_cdel)
                                 + np.asarray(_count_of(reg.d_udel))))
            with _device_scope():
                d_cins, d_cdel = _commit_fold(
                    reg.d_base, reg.d_cins, reg.d_cdel, reg.d_uins,
                    reg.d_udel, cins_cap=ci_cap, cdel_cap=cd_cap,
                    sharded=bool(self.shard_w), use_kernel=use_k)
            reg.d_cins, reg.d_cdel = d_cins, d_cdel
            reg.n_cins = _count_of(d_cins)
            reg.n_cdel = _count_of(d_cdel)
            reg.set_uncommitted(ins[:0], dels[:0])
            # commit never touches d_base: keep its mirror (compaction's
            # full clear is the one that must drop it)
            reg._mirror.pop("cins", None)
            reg._mirror.pop("cdel", None)
        self._maybe_compact()

    def _commit_host(self, ins: np.ndarray, dels: np.ndarray):
        for reg in self.projections.values():
            h = reg._host
            cins = np.unique(np.concatenate(
                [_diff_rows(h["cins"], dels), _diff_rows(ins, h["cdel"])]),
                axis=0) if (ins.size or h["cins"].size) else h["cins"]
            cdel = np.unique(np.concatenate(
                [h["cdel"], _inter_rows(dels, h["base"])]), axis=0) \
                if (dels.size or h["cdel"].size) else h["cdel"]
            h["cins"], h["cdel"] = cins, cdel
            reg.refresh(("cins", "cdel"))
            reg.set_uncommitted(ins[:0], dels[:0])
        # incremental sorted maintenance of the packed live cache (and the
        # edge rows derived from it): O(|E|) memmove, no re-pack, no re-sort
        if ins.size:
            pi = np.sort(_pack2(ins[:, 0], ins[:, 1]))
            self._packed_live = np.insert(
                self._packed_live, np.searchsorted(self._packed_live, pi),
                pi)
        if dels.size:
            pd = np.sort(_pack2(dels[:, 0], dels[:, 1]))
            pos = np.searchsorted(self._packed_live, pd)
            # normalize guarantees dels ⊆ live, but stay tolerant of raw
            # commit() calls: only positions that actually match are removed
            hit = (pos < self._packed_live.shape[0]) & \
                (self._packed_live[np.minimum(
                    pos, max(self._packed_live.shape[0] - 1, 0))] == pd)
            self._packed_live = np.delete(self._packed_live, pos[hit])
        self._edges = _unpack2(self._packed_live)
        self._maybe_compact()


class DeltaBigJoin:
    """Incremental maintenance of one query over one dynamic edge relation.

    General n-ary dynamic relations follow the same structure; the engine is
    specialized (as the paper's implementation is, §4) to graph workloads
    where every atom reads the single ``edge`` relation.

    Region/commit bookkeeping lives in a :class:`RegionStore`; by default the
    engine owns a private one, but a shared store may be injected (``store=``)
    so many engines ride one graph with one commit per epoch — that is what
    :class:`repro.api.GraphSession` does.  Prefer the session facade for new
    code; this class remains the single-query engine underneath it.
    """

    def __init__(self, query: Query, initial_edges: Optional[np.ndarray],
                 cfg: BigJoinConfig = BigJoinConfig(mode="collect"),
                 compact_ratio: float = 0.5,
                 store: Optional[RegionStore] = None,
                 device_resident: bool = True):
        self.query = query
        self.cfg = cfg
        self.compact_ratio = compact_ratio
        self.device_resident = device_resident
        self.plans: List[Plan] = [make_delta_plan(dq)
                                  for dq in delta_queries(query)]
        if store is None:
            store = self._new_store(initial_edges, compact_ratio)
        self.store = store
        for plan in self.plans:
            self.store.ensure_plan(plan)

    def _new_store(self, edges: np.ndarray, compact_ratio: float
                   ) -> RegionStore:
        """Private store; the distributed engine overrides this to build
        worker-sharded device regions."""
        return RegionStore(edges, shard_w=0, compact_ratio=compact_ratio,
                           device_resident=self.device_resident)

    # store delegation (public surface predating RegionStore) --------------
    @property
    def edges(self) -> np.ndarray:
        return self.store.edges

    @property
    def projections(self) -> Dict[Projection, _Regions]:
        return self.store.projections

    def normalize(self, updates, weights):
        return self.store.normalize(updates, weights)

    def _maybe_compact(self, force: bool = False):
        self.store._maybe_compact(force)

    def _run_plan(self, plan: Plan, indices: Indices, seed: np.ndarray,
                  weights: np.ndarray) -> JoinResult:
        """Run one delta query's dataflow; overridden by the mesh engine."""
        return run_bigjoin(plan, indices, seed, weights, cfg=self.cfg)

    # ------------------------------------------------------------------
    def run_delta_plans(self, ins: np.ndarray, dels: np.ndarray
                        ) -> DeltaResult:
        """Evaluate dAQ_1..dAQ_n for one staged batch (the store must have
        ``begin_epoch``-ed it); does NOT commit — the caller owns the epoch
        boundary, so a facade can run many queries off one staged batch."""
        delta_edges = np.concatenate([ins, dels], axis=0)
        delta_w = np.concatenate([
            np.ones(ins.shape[0], np.int32),
            -np.ones(dels.shape[0], np.int32)])

        per_dq: List[JoinResult] = []
        total = 0
        tuples, wts = [], []
        for plan in self.plans:
            if delta_edges.size == 0:
                break
            seed = delta_edges[:, list(plan.seed_cols)]
            res = self._run_plan(plan, self.store.indices_for(plan), seed,
                                 delta_w)
            per_dq.append(res)
            total += res.count
            if res.tuples is not None and res.tuples.size:
                tuples.append(res.tuples)
                wts.append(res.weights)
        out_t = np.concatenate(tuples) if tuples else None
        out_w = np.concatenate(wts) if wts else None
        return DeltaResult(total, out_t, out_w, per_dq)

    def apply(self, updates: np.ndarray,
              weights: Optional[np.ndarray] = None) -> DeltaResult:
        """Process one update batch: emit output changes, then commit."""
        updates = np.asarray(updates, np.int32).reshape(-1, 2)
        if weights is None:
            weights = np.ones(updates.shape[0], np.int32)
        ins, dels = self.store.normalize(updates, weights)
        if ins.size == 0 and dels.size == 0:
            # net-zero batch (no-op inserts of live edges, deletes of absent
            # edges, +/- cancellations): an EXACT no-op — no region rebuilds,
            # no compaction, no dataflow run (tests/test_delta_stream.py).
            return DeltaResult(0, None, None, [])
        self.store.begin_epoch(ins, dels)
        result = self.run_delta_plans(ins, dels)
        self.store.commit(ins, dels)
        return result


def rows_isin(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-membership mask of ``a``'s rows in ``b`` (both [N, m] int).

    Packed-row diff: rows are mapped to dense ids by one ``np.unique`` over
    the concatenation, then compared with ``np.isin`` on the id vectors — no
    Python set-of-tuples.  O((Na+Nb) log) and fully vectorized; this is the
    stress suite's hot path (delta_oracle on every update batch).
    """
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape[0] == 0 or b.shape[0] == 0:
        return np.zeros(a.shape[0], bool)
    both = np.concatenate([a, b], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    inv = inv.reshape(-1)  # numpy>=2.0 may return [N,1]
    return np.isin(inv[:a.shape[0]], inv[a.shape[0]:])


def delta_oracle(query: Query, edges_before: np.ndarray,
                 edges_after: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Ground truth: signed difference of full recomputation.

    Returns (tuples [N, m] int32, weights [N] ±1) with the added rows first,
    each block in lexicographic row order (``np.unique`` order — the same
    order the old set-of-tuples implementation produced via ``sorted``).
    """
    from repro.core.generic_join import generic_join
    a, _ = generic_join(query, {"edge": edges_before})
    b, _ = generic_join(query, {"edge": edges_after})
    m = query.num_attrs
    a = np.unique(np.asarray(a, np.int32).reshape(-1, m), axis=0)
    b = np.unique(np.asarray(b, np.int32).reshape(-1, m), axis=0)
    added = b[~rows_isin(b, a)]
    removed = a[~rows_isin(a, b)]
    t = np.concatenate([added, removed]).astype(np.int32).reshape(-1, m)
    w = np.concatenate([np.ones(added.shape[0], np.int32),
                        -np.ones(removed.shape[0], np.int32)])
    return t, w
