"""Delta-GJ / Delta-BiGJoin (§3.3): incremental maintenance of join queries.

For each update batch dR (signed edge tuples) the engine runs the n delta
queries

    dQ_i :- R'_1, ..., R'_{i-1}, dR_i, R_{i+1}, ..., R_n

each through the *same* BiGJoin dataflow (bigjoin.py), seeded with dR_i and
planned with an attribute order that begins with R_i's attributes (Thm 3.2).
Atoms left of the seed read the NEW version, atoms right of it the OLD
version — the logical sequencing that makes simultaneous updates correct.

The multi-version index is the paper's three-region LSM structure (§4.3):

    base   — compacted committed state (large, device-resident)
    cins/cdel — uncompacted committed inserts/deletes since last compaction
    uins/udel — the current (uncommitted) batch

OLD = base + cins - cdel;   NEW = OLD + uins - udel.

Commit folds uins/udel into cins/cdel with cancellation, keeping the
invariants  cins ∩ base = ∅,  cdel ⊆ base,  cins ∩ cdel = ∅  so positive
regions never hold duplicates.  Compaction (merge committed into base) runs
when the committed regions exceed ``compact_ratio`` × |base| — and eagerly in
the rare re-insertion-of-committed-delete case, which would otherwise create
a positive/negative overlap (see DESIGN.md §2).

Region state is DEVICE-RESIDENT (DESIGN.md §6): each live relation is a
sorted packed device array maintained as its own three-region LSM,
``normalize`` is a jitted searchsorted membership probe against it, and
``commit`` is a jitted sorted-merge/diff fold (``csr.merge_index`` /
``diff_index`` / ``intersect_index``) that touches only the committed
regions and the delta — the compacted base is merged at (amortized)
compaction only, so warm epoch cost is O(|Δ|·log|R| + |committed|) instead
of the full rescan the host path pays.  Host numpy arrays are a
lazily-materialized debug mirror, pulled only by oracle/differential paths
(``StoreStats.mirror_pulls`` counts the pulls).  ``device_resident=False``
keeps the legacy host-truth store (with an incrementally-maintained packed
live cache) for contrast benchmarks.

The store is MULTI-RELATION (DESIGN.md §7): any mix of dynamic relations
of arity 2..4 (the binary ``edge`` graph, the ternary ``tri`` relation of
§5.4, ...), each with its own live LSM, per-relation update batches, and
composite-key (hi, lo) regions sharded by the same ownership hash as the
binary ones.  Projections that don't cover a relation's full row are
DERIVED on demand instead of folded (see :class:`_Regions`).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import itertools
import os
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.core import compilestats, csr
from repro.core.bigjoin import (BigJoinConfig, Indices, JoinResult,
                                run_bigjoin)
from repro.core.capacity import Ratchet
from repro.core.csr import IndexData, build_index
from repro.core.dataflow_index import VersionedIndex
from repro.core.plan import Plan, make_delta_plan
from repro.core.query import Query, delta_queries
from repro.errors import (CapacityOverflow, ESCALATES_BATCH, ESCALATES_OUT,
                          SnapshotError)

Projection = Tuple[str, Tuple[int, ...], int]  # (rel, key_pos, ext_pos)

# With the strict flag every jitted device step of the store runs under
# ``jax.transfer_guard("disallow")``: any host<->device copy on the warm
# normalize/commit path raises instead of silently re-uploading an index.
# The CI transfer-guard lane sets this for the delta-stream suites; the
# delta-sized staging uploads and scalar count pulls happen OUTSIDE the
# guarded scopes by construction (they are proportional to |Δ|, not |E|).
STRICT_TRANSFERS = os.environ.get("REPRO_STRICT_TRANSFERS", "") not in ("",
                                                                        "0")

# Merge/fold kernel routing for the commit path: None = ON everywhere
# (compiled Pallas on TPU, interpret-mode Pallas — i.e. the same kernel
# body lowered through XLA — on CPU, matching the intersect/extend ops);
# True/False force.  REPRO_MERGE_KERNEL=0 disables from the environment.
# The commit fold takes the single-launch fused kernel (kernels/merge/fold)
# when its operands fit the VMEM budget, sharded meshes included (the
# kernel grids over the worker axis); the compaction fold keeps the
# rank-kernel-per-op chain, jnp when sharded (vmap-of-pallas is not a
# supported production path).
USE_MERGE_KERNEL: Optional[bool] = None


def _merge_kernel_on() -> bool:
    if USE_MERGE_KERNEL is None:
        env = os.environ.get("REPRO_MERGE_KERNEL", "")
        if env != "":
            return env not in ("0", "false", "off")
        return True
    return bool(USE_MERGE_KERNEL)


@contextlib.contextmanager
def _device_scope():
    if STRICT_TRANSFERS:
        with jax.transfer_guard("disallow"):
            yield
    else:
        yield


def _pack2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (a.astype(np.int64) << 32) | b.astype(np.int64)


def _unpack2(packed: np.ndarray) -> np.ndarray:
    packed = np.asarray(packed, np.int64)
    return np.stack([(packed >> 32).astype(np.int32),
                     (packed & 0xFFFFFFFF).astype(np.int32)], 1)


def _pack_rows(rows: np.ndarray, arity: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Full rows of an n-ary relation as the (hi, lo) lex word pair the
    live-set LSM keys on (lo ≡ 0 for arity <= 2, matching the legacy
    single-word packing bit for bit)."""
    rows = np.asarray(rows, np.int32).reshape(-1, arity)
    packed = csr.pack_key(tuple(rows[:, c] for c in range(arity)))
    if isinstance(packed, tuple):
        return packed
    return packed, np.zeros(rows.shape[0], np.int64)


def _unpack_rows(hi: np.ndarray, lo: np.ndarray, arity: int) -> np.ndarray:
    """Inverse of :func:`_pack_rows`: [N, arity] int32 rows."""
    if arity <= 2:
        return csr.unpack_key(np.asarray(hi, np.int64), arity)
    return csr.unpack_key((np.asarray(hi, np.int64),
                           np.asarray(lo, np.int64)), arity)


def _degenerate_rows(rows: np.ndarray) -> np.ndarray:
    """Rows with any repeated vertex (self-loops generalized to n-ary):
    normalize drops them, exactly as the edge path drops u == v."""
    rows = np.asarray(rows)
    bad = np.zeros(rows.shape[0], bool)
    for i in range(rows.shape[1]):
        for j in range(i + 1, rows.shape[1]):
            bad |= rows[:, i] == rows[:, j]
    return bad


def _check_batch(rel: str, updates, weights, arity: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Validate one relation's update batch: integer dtype, [N, arity]
    shape, non-negative int32-representable ids, matching weights — loud
    errors instead of the old silent ``reshape(-1, 2)`` mangling."""
    arr = np.asarray(updates)
    if arr.size == 0:  # empty batches are always a valid no-op
        arr = np.zeros((0, arity), np.int64)
    if not np.issubdtype(arr.dtype, np.integer):
        raise TypeError(
            f"{rel!r} update batch must be integer tuples, got dtype "
            f"{arr.dtype}")
    if arr.ndim != 2 or arr.shape[1] != arity:
        raise ValueError(
            f"{rel!r} update batch must be [N, {arity}] (relation arity "
            f"{arity}), got shape {arr.shape}")
    if arr.size:
        amin, amax = int(arr.min()), int(arr.max())
        if amin < 0:
            raise ValueError(
                f"{rel!r} update batch contains negative id {amin}")
        if amax >= 2 ** 31:
            raise ValueError(
                f"{rel!r} update batch contains id {amax} outside the "
                "int32 vertex-id domain")
    if weights is None:
        weights = np.ones(arr.shape[0], np.int32)
    w = np.asarray(weights)
    if not np.issubdtype(w.dtype, np.integer):
        raise TypeError(
            f"{rel!r} update weights must be signed integers, got dtype "
            f"{w.dtype}")
    if w.shape != (arr.shape[0],):
        raise ValueError(
            f"{rel!r} update weights must be [N] = [{arr.shape[0]}], got "
            f"shape {w.shape}")
    return arr.astype(np.int32), w.astype(np.int32)


def _pow2(n: int) -> int:
    """Index capacities rounded up to powers of two (>= one kernel segment):
    stable shapes across update batches keep the jitted dataflow's
    compilation cache warm, and SEG-aligned capacities make the kernels'
    segment-major view a free reshape.  Alias of THE canonical helper
    (``csr.pow2_capacity``) the sharded region builds and the session
    sizing use, so every capacity in the repo sits on one ladder."""
    return csr.pow2_capacity(n)


def _total(n) -> int:
    return int(np.sum(n))


def _maxn(n) -> int:
    return int(np.max(n)) if np.ndim(np.asarray(n)) else int(n)


def _count_of(d: IndexData):
    """Exact live count(s) of a device region: int single-host, [w] int64
    vector sharded.  One scalar/vector pull — never the index arrays."""
    n = np.asarray(d.n)
    return n.astype(np.int64) if n.ndim else int(n)


# ---------------------------------------------------------------------------
# jitted device cores (called by RegionStore under _device_scope; all
# arguments are device arrays — no implicit transfers on the warm path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("sharded",))
def _normalize_core(p_hi: jax.Array, p_lo: jax.Array, w: jax.Array,
                    base: IndexData, cins: IndexData, cdel: IndexData,
                    sharded: bool = False):
    """Net one padded update batch against a relation's live LSM:
    (ins_hi, ins_lo, n_ins, del_hi, del_lo, n_dels) as sentinel-padded
    sorted lex word pairs.

    p_hi/p_lo [B] int64 are the packed rows (degenerate/padding rows
    pre-masked to the sentinel on the host — the batch is delta-sized);
    base/cins/cdel: the relation's packed live regions (IndexData, val≡0;
    composite ``lo`` word for arity > 2), hash-partitioned over a leading
    [w] worker axis when ``sharded`` — a key lives on exactly one shard, so
    membership is an OR over vmapped per-shard probes and per-worker live
    memory stays O(|R|/w).  live = (base \\ cdel) ∪ cins under the commit
    invariants.
    """
    compilestats.record("delta.normalize_core")
    SENT = jnp.int64(csr.SENTINEL)
    order = jnp.lexsort((p_lo, p_hi))
    hs, ls, ws = p_hi[order], p_lo[order], w[order]
    first = jnp.concatenate([jnp.ones(1, bool),
                             (hs[1:] != hs[:-1]) | (ls[1:] != ls[:-1])])
    ids = jnp.cumsum(first.astype(jnp.int32)) - 1
    net = jax.ops.segment_sum(ws.astype(jnp.int64), ids,
                              num_segments=hs.shape[0])
    uniq_h = jnp.full(hs.shape[0], SENT, jnp.int64).at[ids].set(hs)
    uniq_l = jnp.full(hs.shape[0], SENT, jnp.int64).at[ids].set(ls)
    zeros = jnp.zeros(hs.shape[0], jnp.int32)
    composite = base.lo is not None  # static: arity > 2 relations
    qkey = (uniq_h, uniq_l) if composite else uniq_h

    def member(idx):
        if sharded:
            return jax.vmap(
                lambda d: csr.index_member(d, qkey, zeros))(idx).any(0)
        return csr.index_member(idx, qkey, zeros)

    in_base = member(base)
    in_cins = member(cins)
    in_cdel = member(cdel)
    exists = (in_base & ~in_cdel) | in_cins
    alive = uniq_h < SENT
    ins_m = alive & (net > 0) & ~exists
    del_m = alive & (net < 0) & exists

    def compact(mask):
        cum = jnp.cumsum(mask.astype(jnp.int32))
        pos = jnp.where(mask, cum - 1, mask.shape[0])
        oh = jnp.full(mask.shape[0], SENT, jnp.int64
                      ).at[pos].set(uniq_h, mode="drop")
        ol = jnp.full(mask.shape[0], SENT, jnp.int64
                      ).at[pos].set(uniq_l, mode="drop")
        return oh, ol, mask.sum(dtype=jnp.int32)

    oih, oil, ni = compact(ins_m)
    odh, odl, nd = compact(del_m)
    return oih, oil, ni, odh, odl, nd


# donation-mismatch advisories are expected on rung-growth epochs (the
# donated cins/cdel at rung r cannot alias outputs at the next rung) and
# during prewarm's cross-rung walk; steady state the shapes match and the
# donation holds — silence the per-signature lowering warning
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


# Committed-region donation is disabled whenever the persistent
# compilation cache is active: executables deserialized from the on-disk
# cache mis-handle the in-place aliasing (observed on the CPU mesh path
# as corrupted committed regions — compaction-count assertion failures —
# in an otherwise bit-identical run that passes when the same fold is
# compiled fresh).  Donation only saves one committed-region generation
# of memory per epoch, and the donation config is part of the executable
# fingerprint, so the two variants never collide in the cache.
_COMMIT_DONATE = () if os.environ.get(compilestats.ENV_VAR) else (1, 2)


def _commit_fold_impl(base: IndexData, cins: IndexData, cdel: IndexData,
                      uins: IndexData, udel: IndexData, *, cins_cap: int,
                      cdel_cap: int, sharded: bool, use_kernel: bool = False):
    """The committed-region fold of one epoch, merged never rebuilt:

        cins' = (cins \\ udel) ∪ (uins \\ cdel)
        cdel' = cdel ∪ (udel ∩ base)

    Touches only the committed regions and the delta — ``base`` is probed
    (O(|Δ|·log|base|)), never scanned.  ``sharded`` vmaps the fold over the
    leading worker axis: ownership is by packed key, so every merge is
    shard-local and the distributed commit stays collective-free.

    The committed inputs (``cins``/``cdel``) are DONATED: commit replaces
    both with the fold outputs immediately, and steady state (no rung
    growth) the output capacities equal the input capacities, so XLA
    aliases the buffers in place of allocating a second committed-region
    generation — the serving pipeline's epoch k commit never doubles
    committed memory while batch k+1 is being prepared (DESIGN.md §9).
    ``base`` passes through untouched and is never donated; the staged
    delta regions stay undonated too (their pinned delta capacity can
    never alias a committed-rung output).  Exception: with the persistent
    compilation cache enabled donation is switched off entirely — see
    ``_COMMIT_DONATE`` above.

    ``use_kernel`` routes the whole fold — both outputs — through ONE
    fused ``pallas_call`` per relation (`kernels/merge/fold.py`): only the
    delta-sized ``udel ∩ base`` probe stays a jnp search (its bit vector is
    the kernel's ``in_ba`` input), so base never enters VMEM.  Folds the
    fused kernel cannot serve (over-VMEM compiled calls) fall back to the
    five-stage rank chain, bit-exactly.
    """
    compilestats.record("delta.commit_fold")
    if use_kernel:
        from repro.kernels.merge import fold as merge_fold
        if merge_fold.commit_fold_ok(cins, cdel, uins, udel,
                                     cins_cap, cdel_cap):
            def in_ba_of(ba, ud):
                lt, le = csr.index_ranks(ba, csr._qcols_of(ud), ud.val)
                return (le > lt).astype(jnp.int32)

            in_ba = (jax.vmap(in_ba_of)(base, udel) if sharded
                     else in_ba_of(base, udel))
            return merge_fold.commit_fold(
                cins, cdel, uins, udel, in_ba,
                cins_cap=cins_cap, cdel_cap=cdel_cap, sharded=sharded)
    # the rank-kernel chain stays single-host only: under the sharded vmap
    # each stage would relaunch per shard, which the fused path avoids
    chain_k = use_kernel and not sharded

    def fold(ba, ci, cd, ui, ud):
        kept = csr._select_core(ci, ud, ci.capacity, False, chain_k)
        fresh = csr._select_core(ui, cd, ui.capacity, False, chain_k)
        new_cins = csr._merge_core(kept, fresh, cins_cap, chain_k)
        dead = csr._select_core(ud, ba, ud.capacity, True, chain_k)
        new_cdel = csr._merge_core(cd, dead, cdel_cap, chain_k)
        return new_cins, new_cdel

    if sharded:
        return jax.vmap(fold)(base, cins, cdel, uins, udel)
    return fold(base, cins, cdel, uins, udel)


_COMMIT_STATICS = ("cins_cap", "cdel_cap", "sharded", "use_kernel")
_commit_fold = functools.partial(
    jax.jit, static_argnames=_COMMIT_STATICS,
    donate_argnums=_COMMIT_DONATE)(_commit_fold_impl)
# rollback-safe variant: no donation, so the old committed regions survive
# the fold and a mid-commit fault can roll the store back to them.
# ``RegionStore.commit`` selects it whenever fault injection is armed.
_commit_fold_safe = functools.partial(
    jax.jit, static_argnames=_COMMIT_STATICS)(_commit_fold_impl)


@functools.partial(jax.jit, static_argnames=("out_cap", "sharded",
                                             "use_kernel"))
def _compact_fold(base: IndexData, cins: IndexData, cdel: IndexData, *,
                  out_cap: int, sharded: bool, use_kernel: bool = False
                  ) -> IndexData:
    """base' = (base \\ cdel) ∪ cins — the amortized O(|base|) merge."""
    compilestats.record("delta.compact_fold")

    def fold(ba, ci, cd):
        kept = csr._select_core(ba, cd, ba.capacity, False, use_kernel)
        return csr._merge_core(kept, ci, out_cap, use_kernel)

    if sharded:
        return jax.vmap(fold)(base, cins, cdel)
    return fold(base, cins, cdel)


@functools.partial(jax.jit, static_argnames=("sharded",))
def _any_member(idx: IndexData, qk: jax.Array, qv: jax.Array,
                sharded: bool = False) -> jax.Array:
    """any((qk,qv) ∈ idx) — the eager re-insertion probe (delta-sized)."""
    compilestats.record("delta.any_member")
    if sharded:
        return jax.vmap(lambda d: csr.index_member(d, qk, qv))(idx).any()
    return csr.index_member(idx, qk, qv).any()


def _packed_index(rows: np.ndarray, shard_w: int = 0,
                  arity: int = 2, capacity: Optional[int] = None
                  ) -> IndexData:
    """Packed full-row IndexData (key = the relation's lex word pair,
    val ≡ 0) from host rows — only ever built for the initial relations and
    per-epoch deltas.  Delegates to the csr builders over a zero ext column
    with key_pos = ALL columns, so the sharded layout and ownership
    (``csr.shard_of``) are THE SAME code path as the projections' shards —
    the cross-structure shard agreement the distributed commit folds rely
    on is not re-implemented here.  ``capacity`` (a per-shard floor when
    sharded) lets the caller pin the ratcheted rung; the pow2 of the actual
    row count is the lower bound either way."""
    rows = np.asarray(rows, np.int32).reshape(-1, arity)
    rows_ext = np.concatenate(
        [rows, np.zeros((rows.shape[0], 1), np.int32)], axis=1)
    key_pos = tuple(range(arity))
    if shard_w:
        return csr.build_sharded_index(rows_ext, key_pos, arity, shard_w,
                                       capacity=capacity, narrow=False)
    return csr.build_index(
        rows_ext, key_pos, arity,
        capacity=max(int(capacity or 0), _pow2(rows_ext.shape[0])),
        narrow=False)


def _empty_packed(shard_w: int = 0, arity: int = 2) -> IndexData:
    composite = arity > 2
    if not shard_w:
        return csr.empty_index(narrow=False, composite=composite)
    w = int(shard_w)
    return IndexData(
        jnp.full((w, csr.SEG), jnp.int64(csr.SENTINEL), jnp.int64),
        jnp.zeros((w, csr.SEG), jnp.int32), jnp.zeros(w, jnp.int32),
        jnp.full((w, csr.SEG), jnp.int64(csr.SENTINEL), jnp.int64)
        if composite else None)


def _pad_probe(keys, vals: np.ndarray, sent,
               cap: Optional[int] = None) -> Tuple:
    """Pow2-pad a probe batch; ``keys`` is one packed array or a composite
    (hi, lo) pair (padding rows take the sentinel in every key word).
    ``cap`` raises the pad to a ratcheted rung so probe shapes stay pinned
    across batches."""
    if isinstance(keys, tuple):
        hi, lo = keys
        B = max(int(cap or 0), _pow2(hi.shape[0]))
        kh = np.full(B, csr.SENTINEL, np.int64)
        kl = np.full(B, csr.SENTINEL, np.int64)
        kh[:hi.shape[0]] = hi
        kl[:lo.shape[0]] = lo
        v = np.zeros(B, np.int32)
        v[:vals.shape[0]] = vals
        return (jnp.asarray(kh), jnp.asarray(kl)), jnp.asarray(v)
    B = max(int(cap or 0), _pow2(keys.shape[0]))
    k = np.full(B, sent, keys.dtype)
    k[:keys.shape[0]] = keys
    v = np.zeros(B, np.int32)
    v[:vals.shape[0]] = vals
    return jnp.asarray(k), jnp.asarray(v)


def _sds_like(idx: IndexData, cap: Optional[int] = None) -> IndexData:
    """ShapeDtypeStruct skeleton of ``idx`` with its capacity (the last
    axis of every padded array) overridden to ``cap`` — the argument
    prototype prewarm warms a fold against (see :func:`_warm_call`).
    Mirrors dtypes, the composite ``lo`` word and the sharded leading [w]
    axis exactly, so the AOT signature is the runtime signature."""
    S = jax.ShapeDtypeStruct

    def arr(a):
        shp = list(a.shape)
        if cap is not None:
            shp[-1] = int(cap)
        return S(tuple(shp), a.dtype)

    return IndexData(arr(idx.key), arr(idx.val), S(idx.n.shape, idx.n.dtype),
                     None if idx.lo is None else arr(idx.lo))


def _warm_call(fn, *args, **static):
    """Execute a jitted ``fn`` once on zero-filled concretizations of the
    ShapeDtypeStruct prototypes in ``args`` (``static`` kwargs pass
    through).

    This — not ``jit(...).lower(...).compile()`` — is what makes the first
    streaming call at a warmed signature free: jax's AOT path populates
    the trace cache but NOT the jit dispatch executable cache, so a
    lower/compile-only prewarm still pays the full XLA compile (seconds)
    when the stream first crosses onto the rung, invisibly to the trace
    counters.  Zero-filled inputs make every fold a trivially-empty pass
    (all counts 0), so the execution itself costs microseconds."""
    z = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), args)
    jax.block_until_ready(fn(*z, **static))


PREWARM_CROSS_CAP = 128


def _rung_combos(ladders: Dict[str, List[int]],
                 cap: int = PREWARM_CROSS_CAP) -> List[Dict[str, int]]:
    """Committed-rung combinations a multi-relation plan can request.

    Relations grow (and compact) independently, so a plan reading two
    relations can see ANY pair of committed rungs — warming only the
    same-rung diagonal (PR 6) left one compile per first-crossed mixed
    combo.  This enumerates the reachable cross-product of each
    relation's ladder; when the product exceeds ``cap`` (only possible
    with many relations on deep ladders) it falls back to a documented
    bounded subset — the same-rung diagonal plus every one-relation axis
    sweep off the ladder floor — so prewarm stays O(sum of ladder
    lengths) and only simultaneous multi-relation high-rung mixes can
    still pay a first-crossing compile (DESIGN.md §8)."""
    rels = sorted(ladders)
    if not rels:
        return []
    total = 1
    for rel in rels:
        total *= max(len(ladders[rel]), 1)
    if total <= cap:
        return [dict(zip(rels, combo)) for combo in
                itertools.product(*(ladders[rel] for rel in rels))]
    combos: List[Dict[str, int]] = []
    seen = set()

    def add(combo):
        key = tuple(sorted(combo.items()))
        if key not in seen:
            seen.add(key)
            combos.append(combo)

    depth = max(len(ladders[rel]) for rel in rels)
    for i in range(depth):  # the diagonal, clamped per relation
        add({rel: ladders[rel][min(i, len(ladders[rel]) - 1)]
             for rel in rels})
    for rel in rels:  # per-relation sweeps with the others on the floor
        for r in ladders[rel]:
            combo = {other: ladders[other][0] for other in rels}
            combo[rel] = r
            add(combo)
    return combos


@dataclasses.dataclass
class _Regions:
    """Device truth of one projection's regions (+ optional mirrors).

    ``device_resident`` (default): ``d_base/d_cins/d_cdel`` ARE the state —
    sorted device IndexData updated by the jitted folds above; ``base`` /
    ``cins`` / ``cdel`` are lazily-materialized host mirrors for debug and
    differential paths.  Legacy mode inverts this: ``_host`` numpy arrays
    are the truth and ``refresh()`` rebuilds the device mirrors from them.

    With ``shard_w > 0`` every region array carries a leading [w] worker
    axis and each (key, val) entry is stored by exactly one worker
    (``csr.build_sharded_index``) — the distributed engine's
    memory-linearity contract; the folds vmap over the axis, so each worker
    folds only its owned rows.

    ``derived=True`` marks a projection whose (key, ext) columns do NOT
    cover the relation's full row (possible only for arity > 2 relations,
    e.g. the a1->a3 index of ``tri`` ignoring a2).  Such a projection is a
    lossy many-to-one image of the relation, so sorted set folds cannot
    maintain it incrementally (deleting one supporting row must not kill a
    pair another live row still supports); instead ``versioned()`` derives
    it from the relation's live rows on demand, cached until the next
    begin_epoch/commit.  Delta plans never touch derived projections
    (their bindings always cover the row — see DESIGN.md §7), so the warm
    epoch loop stays delta-proportional.
    """

    key_pos: Tuple[int, ...]
    ext_pos: int
    rel: str = "edge"
    rel_arity: int = 0  # the backing relation's TRUE arity
    shard_w: int = 0
    device_resident: bool = True
    narrow: bool = True
    derived: bool = False
    d_base: IndexData = None
    d_cins: IndexData = None
    d_cdel: IndexData = None
    d_uins: IndexData = None
    d_udel: IndexData = None
    # exact live counts (host bookkeeping, pulled once per fold):
    # ints single-host, [w] int64 vectors sharded
    n_base: object = 0
    n_cins: object = 0
    n_cdel: object = 0
    _host: dict = dataclasses.field(default_factory=dict)
    _mirror: dict = dataclasses.field(default_factory=dict)
    _derived_cache: dict = dataclasses.field(default_factory=dict)
    _store: object = None

    @property
    def arity(self) -> int:
        return self.rel_arity or \
            max(max(self.key_pos, default=0), self.ext_pos) + 1

    def _ratchet(self, kind: str):
        """The store ratchet + key quantizing ``kind`` capacities for this
        projection's relation, or None for storeless regions.  All
        non-derived projections of one relation cover its full row, so
        their region counts are EQUAL — one shared (kind, rel) mark per
        relation keeps every projection (and the live LSM) on the same
        rung, halving the fold-signature space."""
        store = self._store
        if store is None:
            return None, None
        r = store.base_ratchet if kind == "base" else store.ratchet
        return r, (kind, self.rel)

    def _build(self, tup: np.ndarray, kind: str = "base") -> IndexData:
        rows = np.asarray(tup).reshape(-1, self.arity)
        ratchet, key = self._ratchet(kind)
        if self.shard_w:
            from repro.core.csr import build_sharded_index
            per = -(-max(rows.shape[0], 1) // self.shard_w)
            cap = _pow2(per) if ratchet is None else \
                ratchet.capacity(key, per)
            idx = build_sharded_index(rows, self.key_pos, self.ext_pos,
                                      self.shard_w, capacity=cap,
                                      narrow=self.narrow)
        else:
            cap = _pow2(rows.shape[0]) if ratchet is None else \
                ratchet.capacity(key, rows.shape[0])
            idx = build_index(rows, self.key_pos, self.ext_pos,
                              capacity=cap, narrow=self.narrow)
        if ratchet is not None:
            # sharded builds may exceed the per-shard floor under skew:
            # feed the REAL capacity back so the rung stays truthful
            ratchet.observe(key, idx.key.shape[-1])
        return idx

    # -- host rows: legacy truth, or the device mode's lazy debug mirror ----
    def _rows(self, name: str) -> np.ndarray:
        if self.derived:
            # base = the backing relation's live rows; committed deltas are
            # folded into the relation itself, never into this projection
            if name == "base":
                return self._store._rel_rows(self.rel)
            return np.zeros((0, self.arity), np.int32)
        if not self.device_resident:
            return self._host[name]
        if name not in self._mirror:
            self._mirror[name] = self._materialize(getattr(self,
                                                           "d_" + name))
            if self._store is not None:
                self._store.stats.mirror_pulls += 1
        return self._mirror[name]

    @property
    def base(self) -> np.ndarray:
        return self._rows("base")

    @property
    def cins(self) -> np.ndarray:
        return self._rows("cins")

    @property
    def cdel(self) -> np.ndarray:
        return self._rows("cdel")

    def _materialize(self, d: IndexData) -> np.ndarray:
        """Reconstruct host tuple rows from the device (key[, lo], val)
        arrays; canonical row-lex (np.unique) order, like the old host
        truth.  Columns outside key_pos/ext_pos (possible only on derived
        projections, which never come through here) stay zero."""
        keys, vals, ns = np.asarray(d.key), np.asarray(d.val), np.asarray(d.n)
        los = None if d.lo is None else np.asarray(d.lo)
        if self.shard_w:
            key = np.concatenate([keys[k][:ns[k]]
                                  for k in range(self.shard_w)])
            val = np.concatenate([vals[k][:ns[k]]
                                  for k in range(self.shard_w)])
            lo = None if los is None else np.concatenate(
                [los[k][:ns[k]] for k in range(self.shard_w)])
        else:
            key, val = keys[:int(ns)], vals[:int(ns)]
            lo = None if los is None else los[:int(ns)]
        rows = np.zeros((key.shape[0], self.arity), np.int32)
        nk = len(self.key_pos)
        kcols = csr.unpack_key(key.astype(np.int64) if lo is None
                               else (key.astype(np.int64),
                                     lo.astype(np.int64)), nk) \
            if nk else None
        for c, p in enumerate(self.key_pos):
            rows[:, p] = kcols[:, c]
        rows[:, self.ext_pos] = val
        order = np.lexsort(tuple(rows[:, c]
                                 for c in range(rows.shape[1] - 1, -1, -1)))
        return rows[order]

    def refresh(self, which=("base", "cins", "cdel")):
        """Legacy mode only: rebuild device mirrors from the host truth."""
        assert not self.device_resident, \
            "device-resident regions are merged, never rebuilt"
        for name in which:
            setattr(self, "d_" + name,
                    self._build(self._host[name],
                                kind="base" if name == "base"
                                else "committed"))

    def set_uncommitted(self, uins: np.ndarray, udel: np.ndarray):
        if self.derived:
            self._derived_cache.clear()  # the "new" image changed
            return
        self.d_uins = self._build(uins, kind="delta")
        self.d_udel = self._build(udel, kind="delta")

    def probe_cdel(self, ins: np.ndarray) -> bool:
        """any(ins ∈ cdel) — device probe, O(|Δ|·log|cdel|)."""
        if self.derived:
            return False  # no committed-delete region to overlap
        key = csr.pack_key(tuple(ins[:, p].astype(np.int32)
                                 for p in self.key_pos))
        kdt = np.dtype(self.d_cdel.key.dtype.name)
        sent = csr.SENTINEL32 if kdt == np.int32 else csr.SENTINEL
        if not isinstance(key, tuple):
            key = key.astype(kdt)
        ratchet, rkey = self._ratchet("probe")
        cap = None if ratchet is None else \
            ratchet.capacity(rkey, ins.shape[0])
        qk, qv = _pad_probe(key, ins[:, self.ext_pos].astype(np.int32),
                            sent, cap=cap)
        return bool(_any_member(self.d_cdel, qk, qv,
                                sharded=bool(self.shard_w)))

    def versioned(self, version: str) -> VersionedIndex:
        if self.derived:
            return self._derived_versioned(version)
        if version == "old":
            return VersionedIndex((self.d_base, self.d_cins), (self.d_cdel,))
        if version == "new":
            return VersionedIndex((self.d_base, self.d_cins, self.d_uins),
                                  (self.d_cdel, self.d_udel))
        if version == "static":
            return VersionedIndex((self.d_base,), ())
        raise ValueError(version)

    def _derived_versioned(self, version: str) -> VersionedIndex:
        """Projection image rebuilt from the relation's live rows: "old"
        (= "static") is the committed state, "new" folds the staged batch.
        Cached until the next begin_epoch/commit/compaction."""
        if version not in ("old", "new", "static"):
            raise ValueError(version)
        tag = "new" if version == "new" else "old"
        idx = self._derived_cache.get(tag)
        if idx is None:
            rows = self._store._rel_rows(self.rel)
            if tag == "new":
                ins, dels = self._store._staged_for(self.rel)
                if dels.size:
                    rows = rows[~rows_isin(rows, dels)]
                if ins.size:
                    rows = np.unique(np.concatenate([rows, ins]), axis=0)
            idx = self._build(rows)
            self._derived_cache[tag] = idx
        return VersionedIndex((idx,), ())


def _diff_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Rows of a not in b (both [N, m] int, any arity)."""
    if a.size == 0 or b.size == 0:
        return a
    if a.shape[1] == 2:
        pa, pb = _pack2(a[:, 0], a[:, 1]), _pack2(b[:, 0], b[:, 1])
        return a[~np.isin(pa, pb)]
    return a[~rows_isin(a, b)]


def _inter_rows(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if a.size == 0 or b.size == 0:
        return a[:0]
    if a.shape[1] == 2:
        pa, pb = _pack2(a[:, 0], a[:, 1]), _pack2(b[:, 0], b[:, 1])
        return a[np.isin(pa, pb)]
    return a[rows_isin(a, b)]


@dataclasses.dataclass
class DeltaResult:
    count_delta: int
    tuples: Optional[np.ndarray]
    weights: Optional[np.ndarray]
    per_dq: List[JoinResult]


@dataclasses.dataclass
class StoreStats:
    """Per-store epoch accounting.  ``normalize_calls`` / ``commit_calls``
    are the facade's one-commit-per-epoch contract: with N standing queries
    on one store both advance by exactly 1 per update epoch.
    ``mirror_pulls`` counts host materializations of device-resident state
    (debug/differential paths only — zero on the warm epoch loop);
    ``live_compactions`` tracks the store-level live-set LSM separately
    from the per-projection ``compactions``.  ``compile_events`` is the
    number of jit traces (= XLA compiles on one backend) recorded by any
    instrumented fold since this store was created — steady state it must
    stay FLAT across epochs (the DESIGN.md §8 compilation-stability
    invariant); ``prewarm_compiles`` is the subset spent walking the AOT
    ladder up front."""

    normalize_calls: int = 0
    commit_calls: int = 0
    compactions: int = 0
    epochs: int = 0
    live_compactions: int = 0
    mirror_pulls: int = 0
    compile_events: int = 0
    prewarm_compiles: int = 0
    # robustness accounting (DESIGN.md §10)
    escalations: int = 0  # capacity rungs bumped after CapacityOverflow
    replays: int = 0  # epoch dataflow re-runs after an escalation
    rollbacks: int = 0  # rollback() calls (faulted commits)
    escalation_compiles: int = 0  # compile events spent re-prewarming


@dataclasses.dataclass
class PreparedBatch:
    """One update batch after :meth:`RegionStore.prepare` (stage A of a
    pipelined epoch, DESIGN.md §9): validated, degenerate-masked, packed
    and sentinel-padded entirely on the host.

    ``rels`` maps relation -> the padded ``(hi, lo, weights)`` probe
    arrays (device-resident stores only); ``raw`` keeps the checked
    ``(rows, weights)`` per relation — the canonical bytes a write-ahead
    log records and the legacy host store normalizes from.  ``was_dict``
    preserves the edge-array sugar of :meth:`RegionStore.normalize`."""

    rels: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]]
    raw: Dict[str, Tuple[np.ndarray, np.ndarray]]
    was_dict: bool


@dataclasses.dataclass
class _RelLive:
    """One relation's live-set state: its own packed three-region LSM
    (device mode) or host truth rows + sorted packed cache (legacy)."""

    arity: int
    # device-resident LSM (key = the row's lex word pair, val ≡ 0)
    lb: IndexData = None
    lc_ins: IndexData = None
    lc_del: IndexData = None
    n_live: list = None  # [n_base, n_cins, n_cdel]
    mirror: Optional[np.ndarray] = None  # lazily-pulled host rows
    # legacy host truth
    rows: Optional[np.ndarray] = None  # [N, arity] unique row-lex
    packed: Optional[np.ndarray] = None  # arity<=2: sorted packed words
    packed_pair: Optional[Tuple[np.ndarray, np.ndarray]] = None  # arity>2


class RegionStore:
    """Owner of every dynamic relation's live set and every projection's
    LSM regions.

    This is the shared substrate under both the single-query engines and the
    :class:`repro.api.GraphSession` facade: projections are created on demand
    (:meth:`ensure`) and SHARED between every query registered against the
    store, so N standing queries pay one region build, one ``normalize`` and
    one ``commit`` per epoch instead of N copies of each.

    The store is MULTI-RELATION: ``initial`` may be a plain [E, 2] edge
    array (sugar for ``{"edge": edges}``) or a dict of n-ary relations
    (arity up to 4, e.g. the ternary ``tri`` relation of §5.4); every
    relation gets its own live-set LSM, and updates arrive as per-relation
    batches (``normalize({"edge": (rows, w), "tri": ...})`` — the bare
    2-column array form still means the edge relation).

    ``device_resident=True`` (default): the source of truth is on device —
    each live set is its own packed three-region LSM, ``normalize`` is
    a jitted membership probe, ``commit``/compaction are jitted sorted-merge
    folds, and ``edges`` / region rows are lazily-pulled debug mirrors.
    ``device_resident=False`` keeps the legacy host-numpy truth (the old
    behaviour, with an incrementally-maintained packed live cache).

    ``shard_w > 0`` builds every device region hash-partitioned over that
    many mesh workers (the distributed engine's layout), n-ary regions
    included — ownership is by the row's composite key, so commits stay
    owner-local and collective-free and no worker holds O(|R|) of any
    relation.
    """

    def __init__(self, initial, shard_w: int = 0,
                 compact_ratio: float = 0.5, device_resident: bool = True):
        self.shard_w = shard_w
        self.compact_ratio = compact_ratio
        self.device_resident = bool(device_resident)
        self.projections: Dict[Projection, _Regions] = {}
        self.stats = StoreStats()
        self._compile_base = compilestats.total()
        # growth hysteresis (DESIGN.md §8): delta/probe/committed caps ride
        # the slack ladder and never shrink; base caps are monotone pow2
        # (factor 2 — no slack: base is the big region, 2x headroom max)
        self.ratchet = Ratchet()
        self.base_ratchet = Ratchet(factor=2)
        self._rels: Dict[str, _RelLive] = {}
        self._staged: Optional[Dict[str, Tuple[np.ndarray, np.ndarray]]] \
            = None
        rels = initial if isinstance(initial, dict) else \
            {"edge": np.asarray(initial, np.int32).reshape(-1, 2)}
        for rel, rows in rels.items():
            self.add_relation(rel, rows)

    def _sync_compile_stats(self):
        self.stats.compile_events = compilestats.total() - self._compile_base

    # -- ratcheted capacities (marks are PER-SHARD units when sharded) -----
    def _per_shard(self, n: int) -> int:
        return -(-max(int(n), 1) // self.shard_w) if self.shard_w \
            else max(int(n), 1)

    def _base_cap(self, rel: str, n: int) -> int:
        return self.base_ratchet.capacity(("base", rel), self._per_shard(n))

    def _delta_cap(self, rel: str, n: int) -> int:
        return self.ratchet.capacity(("delta", rel), self._per_shard(n))

    def _probe_cap(self, rel: str, n: int) -> int:
        return self.ratchet.capacity(("probe", rel), max(int(n), 1))

    def _committed_cap(self, rel: str, n: int) -> int:
        return self.ratchet.capacity(("committed", rel), max(int(n), 1))

    def add_relation(self, rel: str, rows: np.ndarray,
                     arity: Optional[int] = None):
        """Register one dynamic relation with its initial tuples [N, arity]
        (arity 2..4; ``arity`` disambiguates an empty batch).

        Seeding a relation that exists but is still EMPTY (e.g. one
        ``register()`` auto-declared for a query before its tuples were
        materialized) replaces it in place — its projections are rebuilt
        from the seeded rows; a non-empty relation cannot be re-seeded."""
        old = self._rels.get(rel)
        if old is not None:
            staged = bool(self._staged) and rel in self._staged and \
                any(x.size for x in self._staged[rel])
            if self.num_tuples(rel) or staged:
                raise ValueError(f"relation {rel!r} already exists")
        rows = np.asarray(rows)
        if rows.ndim != 2 and not (rows.size == 0 and arity):
            raise ValueError(
                f"initial {rel!r} tuples must be [N, arity], got shape "
                f"{rows.shape}")
        ar = int(arity or rows.shape[1])
        if rows.ndim == 2 and rows.size and rows.shape[1] != ar:
            raise ValueError(
                f"initial {rel!r} tuples are [N, {rows.shape[1]}] but "
                f"arity={ar} was requested")
        if not 2 <= ar <= 4:
            raise ValueError(
                f"relation {rel!r} arity {ar} unsupported (2..4: composite "
                "keys cover up to 4 columns)")
        if old is not None and ar != old.arity:
            raise ValueError(
                f"relation {rel!r} was declared with arity {old.arity}, "
                f"cannot re-seed with arity {ar}")
        rows, _ = _check_batch(rel, rows.reshape(-1, ar), None, ar)
        rows = np.unique(rows, axis=0)
        st = _RelLive(arity=ar)
        if self.device_resident:
            # each live LSM shards like the projections (ownership by
            # packed key), so per-worker live memory stays O(|R|/w)
            st.lb = _packed_index(rows, self.shard_w, ar,
                                  capacity=self._base_cap(rel,
                                                          rows.shape[0]))
            self.base_ratchet.observe(("base", rel), st.lb.key.shape[-1])
            st.lc_ins = _empty_packed(self.shard_w, ar)
            st.lc_del = _empty_packed(self.shard_w, ar)
            zero = np.zeros(self.shard_w, np.int64) if self.shard_w else 0
            nb = _count_of(st.lb) if self.shard_w else rows.shape[0]
            st.n_live = [nb, zero, zero]  # base, cins, cdel
            st.mirror = rows
        else:
            st.rows = rows
            self._refresh_host_cache(st)
        self._rels[rel] = st
        if old is not None:
            # rebuild projections ensured against the empty declaration
            for proj in [p for p in self.projections if p[0] == rel]:
                del self.projections[proj]
                self.ensure(*proj)

    def _refresh_host_cache(self, st: _RelLive):
        if st.arity <= 2:
            hi, _ = _pack_rows(st.rows, st.arity)
            st.packed = np.sort(hi)
        else:
            hi, lo = _pack_rows(st.rows, st.arity)
            order = np.lexsort((lo, hi))
            st.packed_pair = (hi[order], lo[order])

    # -- relation introspection ---------------------------------------------
    @property
    def relations(self) -> Tuple[str, ...]:
        return tuple(self._rels)

    def arity_of(self, rel: str) -> int:
        return self._rel(rel).arity

    def _rel(self, rel: str) -> _RelLive:
        st = self._rels.get(rel)
        if st is None:
            raise KeyError(
                f"unknown relation {rel!r}; known: "
                f"{', '.join(self._rels) or '(none)'} — pass it in the "
                "initial relations dict or add_relation() first")
        return st

    def _rel_rows(self, rel: str) -> np.ndarray:
        """One relation's live rows on the host.  Legacy: the truth.
        Device-resident: a lazily-materialized mirror (oracle/differential
        paths only — the warm epoch loop never touches it)."""
        st = self._rel(rel)
        if not self.device_resident:
            return st.rows
        if st.mirror is None:
            nb, nci, _ = st.n_live
            cap = _pow2(_maxn(np.asarray(nb) + np.asarray(nci)))
            live = _compact_fold(st.lb, st.lc_ins, st.lc_del,
                                 out_cap=cap, sharded=bool(self.shard_w))
            if self.shard_w:
                ns = np.asarray(live.n)
                keys = np.asarray(live.key)
                hi = np.concatenate(
                    [keys[k][:ns[k]] for k in range(self.shard_w)])
                if live.lo is None:
                    lo = np.zeros(hi.shape[0], np.int64)
                else:
                    los = np.asarray(live.lo)
                    lo = np.concatenate(
                        [los[k][:ns[k]] for k in range(self.shard_w)])
            else:
                hi = np.asarray(live.key)[:int(live.n)]
                lo = np.zeros(hi.shape[0], np.int64) if live.lo is None \
                    else np.asarray(live.lo)[:int(live.n)]
            order = np.lexsort((lo, hi))
            st.mirror = _unpack_rows(hi[order], lo[order], st.arity)
            self.stats.mirror_pulls += 1
        return st.mirror

    def relation_rows(self, rel: str) -> np.ndarray:
        """Public host view of one relation's live tuples."""
        return self._rel_rows(rel)

    def num_tuples(self, rel: str) -> int:
        """Live tuple count of one relation, O(1) from tracked sizes."""
        st = self._rel(rel)
        if not self.device_resident:
            return int(st.rows.shape[0])
        nb, nci, ncd = st.n_live
        return _total(nb) + _total(nci) - _total(ncd)

    @property
    def max_live(self) -> int:
        """Largest relation's live size (capacity/AGM sizing input)."""
        return max((self.num_tuples(r) for r in self._rels), default=0)

    # -- the live edge set (edge-relation sugar + legacy aliases) ----------
    @property
    def edges(self) -> np.ndarray:
        return self._rel_rows("edge")

    @property
    def num_edges(self) -> int:
        """Live edge count, O(1) from the tracked region sizes — no mirror
        materialization (|live| = |base| + |cins| − |cdel|)."""
        return self.num_tuples("edge") if "edge" in self._rels else 0

    @property
    def _lb(self) -> IndexData:
        return self._rel("edge").lb

    @property
    def _lc_ins(self) -> IndexData:
        return self._rel("edge").lc_ins

    @property
    def _lc_del(self) -> IndexData:
        return self._rel("edge").lc_del

    @property
    def _n_live(self) -> list:
        return self._rel("edge").n_live

    @property
    def _edges_mirror(self) -> Optional[np.ndarray]:
        return self._rel("edge").mirror

    @property
    def _edges(self) -> np.ndarray:
        return self._rel("edge").rows

    @property
    def _packed_live(self) -> np.ndarray:
        return self._rel("edge").packed

    def ensure(self, rel: str, key_pos: Tuple[int, ...], ext_pos: int,
               arity: Optional[int] = None) -> _Regions:
        """Region storage for one projection, built from the CURRENT live
        relation on first use and reused by every later query that needs the
        same projection (the hoisted per-query path of old DeltaBigJoin).
        ``arity`` lets a plan auto-declare a not-yet-seen relation (created
        empty)."""
        st = self._rels.get(rel)
        if st is None:
            if arity is None:
                self._rel(rel)  # raises with the helpful message
            self.add_relation(rel, np.zeros((0, arity), np.int32))
            st = self._rels[rel]
        proj = (rel, key_pos, ext_pos)
        reg = self.projections.get(proj)
        if reg is not None:
            return reg
        # a projection whose key/ext columns don't cover the relation's
        # full row is a lossy image: it is DERIVED from the live rows on
        # demand instead of folded incrementally (see _Regions docs)
        used = set(key_pos) | {ext_pos}
        covers = used == set(range(st.arity)) and \
            len(key_pos) + 1 == st.arity
        rows = self._rel_rows(rel)
        # narrow is decided ONCE per projection (merges must keep one
        # dtype): auto-widen when an id already collides with the int32
        # sentinel, like build_index's per-build check did.  Composite
        # projections with a single-column hi word (3 bound columns)
        # narrow too — the lo word is always int64.
        narrow = csr.single_word_hi(len(key_pos)) and \
            (rows.size == 0 or int(rows.max()) < int(csr.SENTINEL32))
        reg = _Regions(key_pos, ext_pos, rel=rel, rel_arity=st.arity,
                       shard_w=self.shard_w,
                       device_resident=self.device_resident, narrow=narrow,
                       derived=not covers, _store=self)
        empty = rows[:0]
        if reg.derived:
            self.projections[proj] = reg
            return reg
        if self.device_resident:
            reg.d_base = reg._build(rows)
            reg.d_cins = reg._build(empty, kind="committed")
            reg.d_cdel = reg._build(empty, kind="committed")
            reg.n_base = _count_of(reg.d_base) if self.shard_w \
                else rows.shape[0]
            reg.n_cins = np.zeros(self.shard_w, np.int64) if self.shard_w \
                else 0
            reg.n_cdel = np.zeros(self.shard_w, np.int64) if self.shard_w \
                else 0
            reg._mirror["base"] = rows
            reg._mirror["cins"] = empty
            reg._mirror["cdel"] = empty
        else:
            reg._host = {"base": rows, "cins": empty, "cdel": empty}
            reg.refresh()
        # a projection ensured mid-epoch (after begin_epoch, before commit)
        # must see the staged batch: its base is the PRE-commit live set, so
        # old = base and new = base + uins - udel stay consistent, and the
        # commit fold picks the delta up instead of losing it
        ins, dels = self._staged_for(rel) if self._staged is not None else \
            (empty, empty)
        reg.set_uncommitted(ins, dels)
        self.projections[proj] = reg
        return reg

    def _staged_for(self, rel: str) -> Tuple[np.ndarray, np.ndarray]:
        ar = self._rel(rel).arity
        empty = np.zeros((0, ar), np.int32)
        if not self._staged:
            return empty, empty
        return self._staged.get(rel, (empty, empty))

    def ensure_plan(self, plan: Plan):
        arities = {a.rel: a.arity for a in plan.query.atoms}
        for _id, rel, key_pos, ext_pos, _v in plan.index_ids():
            self.ensure(rel, key_pos, ext_pos, arity=arities.get(rel))
        # the seed relation may carry no index at all (e.g. a binary seed
        # atom whose attrs are fully bound at P_2): declare it anyway so
        # seeds/updates for it resolve
        seed_rel = plan.query.atoms[plan.seed_atom].rel
        if seed_rel not in self._rels:
            self.add_relation(
                seed_rel, np.zeros((0, arities[seed_rel]), np.int32))

    def indices_for(self, plan: Plan) -> Indices:
        """Assemble the plan's VersionedIndex dict off the shared regions."""
        return {
            _id: self.ensure(rel, key_pos, ext_pos).versioned(version)
            for _id, rel, key_pos, ext_pos, version in plan.index_ids()}

    # -- AOT prewarm (DESIGN.md §8) ------------------------------------
    def committed_ladder(self, rel: str, update_batch: int,
                         horizon: Optional[int] = None) -> List[int]:
        """The canonical committed-region rungs relation ``rel`` can visit
        before compaction drains it: counts run from 0 up to the compaction
        threshold plus one last pre-compaction batch.  ``horizon`` caps the
        count at the stream's total expected churn (epochs × batch) so a
        short stream over a huge graph doesn't warm rungs it can never
        reach — an unreached rung costs nothing but prewarm time, a missed
        one costs one compile when crossed."""
        st = self._rel(rel)
        nb = _total(st.n_live[0]) if self.device_resident \
            else st.rows.shape[0]
        hi = int(self.compact_ratio * max(nb, 1)) + 2 * int(update_batch)
        if horizon is not None:
            hi = min(hi, max(int(horizon), 2 * int(update_batch)))
        return self.ratchet.rungs(1, hi)

    def pin_delta_marks(self, update_batch: int) -> int:
        """Pin every relation's probe/delta mark to the update-batch bound
        so delta-sized buffers keep ONE shape for the stream's life (a
        batch can land entirely on one shard, so the per-shard pin is the
        full pow2 of the batch).  Returns the pin."""
        P = _pow2(max(int(update_batch), 1))
        for rel in self._rels:
            self.ratchet.observe(("probe", rel), P)
            self.ratchet.observe(("delta", rel), P)
        return P

    def prewarm_folds(self, update_batch: int,
                      horizon: Optional[int] = None) -> int:
        """AOT-compile the store's fold ladder: every jit signature the
        canonical committed ladder can request this side of a base-region
        regrowth — normalize, the eager re-insertion probes, every
        commit-fold rung transition, and compaction — by executing each
        fold once on zero-filled ShapeDtypeStruct prototypes
        (:func:`_warm_call`).

        After this, a stream of batches ≤ ``update_batch`` triggers ZERO
        XLA compiles until a relation's base region outgrows its pow2 rung
        (amortized-rare; compaction itself replays warmed shapes).
        Returns the compile events spent (also accumulated in
        ``stats.prewarm_compiles``)."""
        if not self.device_resident:
            return 0
        snap = compilestats.snapshot()
        ub = max(int(update_batch), 1)
        P = self.pin_delta_marks(ub)
        sharded = bool(self.shard_w)
        # statics must match the runtime call sites EXACTLY or the warm
        # epoch recompiles: commit runs the fused fold kernel on every
        # platform (sharded included — grid=(w,), no vmap), compaction
        # keeps the single-host-only rank chain
        commit_k = _merge_kernel_on()
        compact_k = _merge_kernel_on() and not sharded
        S = jax.ShapeDtypeStruct
        pv = S((P,), jnp.int32)
        for rel, st in self._rels.items():
            ladder = self.committed_ladder(rel, ub, horizon)
            # (base proto, committed proto, live?) — all non-derived
            # projections of rel share its committed rung (tied marks)
            groups = [(st.lb, st.lc_ins, True)]
            for reg in self.projections.values():
                if reg.rel == rel and not reg.derived:
                    groups.append((reg.d_base, reg.d_cins, False))
            for base_idx, cproto, is_live in groups:
                b_sds = _sds_like(base_idx)
                # delta regions come from the same builders as committed
                # ones, so the dtypes match; capacity is the pinned P
                d_sds = _sds_like(cproto, P)
                qk = (S((P,), jnp.int64), S((P,), jnp.int64)) \
                    if cproto.lo is not None else S((P,), cproto.key.dtype)
                bcap = int(base_idx.key.shape[-1])
                b_outs = list(dict.fromkeys(
                    (bcap, self.base_ratchet.next_rung(bcap))))
                for r in ladder:
                    ci = _sds_like(cproto, r)
                    if is_live:
                        _warm_call(
                            _normalize_core, S((P,), jnp.int64),
                            S((P,), jnp.int64), S((P,), jnp.int32),
                            b_sds, ci, ci, sharded=sharded)
                    _warm_call(_any_member, ci, qk, pv, sharded=sharded)
                    for out in self.ratchet.rungs(r, r + ub):
                        _warm_call(
                            _commit_fold, b_sds, ci, ci, d_sds, d_sds,
                            cins_cap=out, cdel_cap=out, sharded=sharded,
                            use_kernel=commit_k)
                    for out in b_outs:
                        _warm_call(
                            _compact_fold, b_sds, ci, ci, out_cap=out,
                            sharded=sharded, use_kernel=compact_k)
        spent = compilestats.since(snap)
        self.stats.prewarm_compiles += spent
        self._sync_compile_stats()
        return spent

    def kernel_coverage(self, update_batch: int = 64) -> dict:
        """Per-relation kernel-dispatch evidence for the CI coverage gate.

        Traces the EXACT jitted entry points a warm epoch dispatches to —
        the commit fold with the runtime statics (``_merge_kernel_on``,
        current committed rung, pinned delta capacity) and one projection's
        OLD-version signed-membership probe — and counts their
        ``pallas_call`` equations.  Runtime launch counting would need host
        callbacks (banned on the serving path); tracing the same (function,
        statics, shapes) the warm jit cache serves is the static equivalent:
        what the trace contains is what every warm epoch executes.  Pure
        introspection — no ratchet observation, no store mutation."""
        from repro.kernels import count_pallas_calls
        if not self.device_resident:
            return {}
        use_k = _merge_kernel_on()
        sharded = bool(self.shard_w)
        P = self.pin_delta_marks(max(int(update_batch), 1))
        out = {}
        for rel, st in self._rels.items():
            cc = int(st.lc_ins.key.shape[-1])  # current committed rung
            li = _packed_index(np.zeros((0, st.arity), np.int32),
                               self.shard_w, st.arity, capacity=P)
            fold_calls = count_pallas_calls(
                lambda ba, ci, cd, ui, ud: _commit_fold_impl(
                    ba, ci, cd, ui, ud, cins_cap=cc, cdel_cap=cc,
                    sharded=sharded, use_kernel=use_k),
                st.lb, st.lc_ins, st.lc_del, li, li)
            probe_calls = 0
            for reg in self.projections.values():
                if reg.rel != rel or reg.derived:
                    continue
                vi = reg.versioned("old")
                shard0 = (lambda d: jax.tree_util.tree_map(
                    lambda x: x[0], d)) if sharded else (lambda d: d)
                vi = VersionedIndex(tuple(map(shard0, vi.pos)),
                                    tuple(map(shard0, vi.neg)))
                composite = vi.pos[0].lo is not None
                qk = ((jnp.zeros(P, jnp.int64), jnp.zeros(P, jnp.int64))
                      if composite else jnp.zeros(P, jnp.int64))
                qv = jnp.zeros(P, jnp.int32)
                probe_calls = count_pallas_calls(
                    lambda a, b: vi.signed_member(a, b, use_kernel=True),
                    qk, qv)
                break
            out[rel] = {
                "composite": st.lb.lo is not None,
                "key_dtype": str(st.lb.key.dtype),
                "fold_pallas_calls": int(fold_calls),
                "fused_fold": bool(use_k and fold_calls == 1),
                "probe_pallas_calls": int(probe_calls),
            }
        return out

    def indices_sds_for(self, plan: Plan, rung,
                        update_batch: int) -> Indices:
        """ShapeDtypeStruct mirror of :meth:`indices_for` with every
        committed region at ``rung`` (an int, or a per-relation
        ``{rel: rung}`` dict — relations cross rungs independently, see
        :func:`_rung_combos`) and every uncommitted region at the pinned
        delta capacity — the prototype the engines' dataflow steps are
        AOT-lowered against (``GraphSession.prewarm``)."""
        P = self.pin_delta_marks(update_batch)
        out = {}
        for _id, rel, key_pos, ext_pos, version in plan.index_ids():
            reg = self.ensure(rel, key_pos, ext_pos)
            if reg.derived:
                vi = reg._derived_versioned(version)
                out[_id] = VersionedIndex(
                    tuple(_sds_like(p) for p in vi.pos),
                    tuple(_sds_like(n) for n in vi.neg))
                continue
            r = rung[rel] if isinstance(rung, dict) else int(rung)
            base = _sds_like(reg.d_base)
            com = _sds_like(reg.d_cins, r)
            delta = _sds_like(reg.d_uins if reg.d_uins is not None
                              else reg.d_cins, P)
            if version == "static":
                out[_id] = VersionedIndex((base,), ())
            elif version == "old":
                out[_id] = VersionedIndex((base, com), (com,))
            else:  # "new"
                out[_id] = VersionedIndex((base, com, delta), (com, delta))
        return out

    # ------------------------------------------------------------------
    def prepare(self, updates, weights=None) -> "PreparedBatch":
        """Stage A of an update epoch: validate, degenerate-mask, pack and
        sentinel-pad one batch on the HOST — pure numpy, no jax call, no
        device touch.  The returned :class:`PreparedBatch` feeds
        :meth:`normalize_prepared` (stage B, the jitted probe), so a
        serving pipeline can prepare batch k+1 on a prep thread while the
        device is still committing batch k (DESIGN.md §9).

        Accepts the same forms as :meth:`normalize` (bare edge arrays or a
        per-relation dict) and raises the same validation errors."""
        was_dict = isinstance(updates, dict)
        if was_dict:
            if weights is not None:
                raise ValueError(
                    "per-relation batches carry their own weights: pass "
                    "{rel: (rows, weights)}, not a top-level weights "
                    "argument")
            items = {rel: self._split(rel, batch)
                     for rel, batch in updates.items()}
        else:
            items = {"edge": (updates, weights)}
        rels, raw = {}, {}
        for rel, (rows, w) in items.items():
            st = self._rel(rel)
            rows, w = _check_batch(rel, rows, w, st.arity)
            raw[rel] = (rows, w)
            if self.device_resident:
                rels[rel] = self._pad_host(rel, rows, w)
        return PreparedBatch(rels=rels, raw=raw, was_dict=was_dict)

    def normalize_prepared(self, prep: "PreparedBatch") -> Dict:
        """Stage B of :meth:`prepare`: net the prepared batch against the
        live relation state on device (one jitted probe per relation).
        Always returns the per-relation ``{rel: (ins, dels)}`` dict —
        :meth:`normalize` unwraps the edge sugar."""
        faults.fire("store.normalize")
        self.stats.normalize_calls += 1
        out = {}
        for rel, (rows, w) in prep.raw.items():
            if not self.device_resident:
                out[rel] = self._normalize_host(rel, rows, w)
            else:
                out[rel] = self._normalize_device(rel, *prep.rels[rel])
        self._sync_compile_stats()
        return out

    def normalize(self, updates, weights=None):
        """Net out a batch against the live relation state.

        Array form (edge sugar): ``normalize(rows [N,2], weights)`` returns
        ``(ins, dels)``.  Dict form: ``normalize({rel: (rows, w), ...})``
        returns ``{rel: (ins, dels), ...}`` — one epoch, many relations.
        Wrong-arity / negative-id / non-integer batches raise instead of
        being silently reshaped.

        Device-resident: one jitted probe per relation against its packed
        live LSM — O(|Δ|·log|R|), no full scan, no mirror pull.
        Internally ``prepare`` (host pack/pad) then ``normalize_prepared``
        (device probe) — split callable separately for pipelining.
        """
        prep = self.prepare(updates, weights)
        out = self.normalize_prepared(prep)
        return out if prep.was_dict else out["edge"]

    def _split(self, rel: str, batch):
        """One relation's update entry: a bare row array, or (rows, w)."""
        if isinstance(batch, tuple):
            if len(batch) != 2:
                raise ValueError(
                    f"{rel!r} update entry must be rows or (rows, "
                    f"weights), got a {len(batch)}-tuple")
            return batch
        return batch, None

    def _pad_host(self, rel: str, updates: np.ndarray, weights: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Host half of the device normalize: degenerate rows (any repeated
        vertex — the n-ary self-loop) and zero weights are masked to the
        sentinel, rows packed to lex word pairs, all padded to the probe
        rung.  Pure numpy (prep-thread safe)."""
        st = self._rel(rel)
        SENT = np.int64(csr.SENTINEL)
        valid = ~_degenerate_rows(updates) & (weights != 0)
        hi, lo = _pack_rows(updates, st.arity)
        hi = np.where(valid, hi, SENT)
        lo = np.where(valid, lo, SENT)
        B = self._probe_cap(rel, updates.shape[0])
        ph = np.full(B, SENT, np.int64)
        pl = np.full(B, SENT, np.int64)
        pw = np.zeros(B, np.int32)
        ph[:hi.shape[0]] = hi
        pl[:lo.shape[0]] = lo
        pw[:weights.shape[0]] = weights
        return ph, pl, pw

    def _normalize_rel(self, rel: str, updates, weights
                       ) -> Tuple[np.ndarray, np.ndarray]:
        st = self._rel(rel)
        updates, weights = _check_batch(rel, updates, weights, st.arity)
        if not self.device_resident:
            return self._normalize_host(rel, updates, weights)
        return self._normalize_device(rel,
                                      *self._pad_host(rel, updates, weights))

    def _normalize_device(self, rel: str, ph: np.ndarray, pl: np.ndarray,
                          pw: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        st = self._rel(rel)
        dh, dl, dw = jnp.asarray(ph), jnp.asarray(pl), jnp.asarray(pw)
        with _device_scope():
            oih, oil, ni, odh, odl, nd = _normalize_core(
                dh, dl, dw, st.lb, st.lc_ins, st.lc_del,
                sharded=bool(self.shard_w))
        ni, nd = int(ni), int(nd)
        ins = _unpack_rows(np.asarray(oih)[:ni], np.asarray(oil)[:ni],
                           st.arity)
        dels = _unpack_rows(np.asarray(odh)[:nd], np.asarray(odl)[:nd],
                            st.arity)
        return ins, dels

    def _normalize_host(self, rel: str, updates: np.ndarray,
                        weights: np.ndarray):
        """Legacy host path, probing the incrementally-maintained sorted
        packed cache (no per-call re-pack of the live rows)."""
        st = self._rel(rel)
        keep = ~_degenerate_rows(updates)
        updates, weights = updates[keep], weights[keep]
        if st.arity == 2:
            packed = _pack2(updates[:, 0], updates[:, 1])
            uniq, inv = np.unique(packed, return_inverse=True)
            net = np.zeros(uniq.shape[0], np.int64)
            np.add.at(net, inv, weights)
            rows = _unpack2(uniq)
            live = st.packed
            if live.size:
                pos = np.searchsorted(live, uniq)
                exists = (pos < live.shape[0]) & \
                    (live[np.minimum(pos, live.shape[0] - 1)] == uniq)
            else:
                exists = np.zeros(uniq.shape[0], bool)
        else:
            hi, lo = _pack_rows(updates, st.arity)
            pairs = np.stack([hi, lo], 1)
            uniq, inv = np.unique(pairs, axis=0, return_inverse=True)
            net = np.zeros(uniq.shape[0], np.int64)
            np.add.at(net, inv.reshape(-1), weights)
            rows = _unpack_rows(uniq[:, 0], uniq[:, 1], st.arity)
            lh, ll = st.packed_pair
            exists = rows_isin(uniq, np.stack([lh, ll], 1))
        ins = rows[(net > 0) & ~exists]
        dels = rows[(net < 0) & exists]
        return ins.astype(np.int32), dels.astype(np.int32)

    # ------------------------------------------------------------------
    def _maybe_compact(self, force: bool = False):
        if not self.device_resident:
            self._maybe_compact_host(force)
            return
        use_k = _merge_kernel_on() and not self.shard_w
        for rel, st in self._rels.items():
            nb, nci, ncd = st.n_live
            if (force or _total(nci) + _total(ncd) >
                    self.compact_ratio * max(_total(nb), 1)) and \
                    (_total(nci) or _total(ncd)):
                new_nb = np.asarray(nb) - np.asarray(ncd) + np.asarray(nci)
                out_cap = self.base_ratchet.capacity(("base", rel),
                                                     _maxn(new_nb))
                with _device_scope():
                    st.lb = _compact_fold(st.lb, st.lc_ins, st.lc_del,
                                          out_cap=out_cap,
                                          sharded=bool(self.shard_w),
                                          use_kernel=use_k)
                zero = np.zeros(self.shard_w, np.int64) if self.shard_w \
                    else 0
                st.lc_ins = _empty_packed(self.shard_w, st.arity)
                st.lc_del = _empty_packed(self.shard_w, st.arity)
                st.n_live = [new_nb if self.shard_w else int(new_nb),
                             zero, zero]
                self.stats.live_compactions += 1
                st.mirror = None
                # the committed regions drained to zero: restart their
                # rung ladder instead of pinning every future fold at the
                # pre-compaction rung (which would cost O(threshold) per
                # epoch).  The replayed rungs are already in the jit
                # cache, so re-walking the ladder compiles nothing new.
                self.ratchet.reset(("committed", rel))
                # invariant audit: cdel ⊆ base and cins ∩ base = ∅ make the
                # compacted size exact arithmetic — a mismatch means
                # corruption
                assert (np.asarray(_count_of(st.lb)) == new_nb).all()
        for reg in self.projections.values():
            if reg.derived:
                continue  # rebuilt from the relation rows on demand
            committed = _total(reg.n_cins) + _total(reg.n_cdel)
            if not (force or committed >
                    self.compact_ratio * max(_total(reg.n_base), 1)):
                continue
            if committed:
                new_n = np.asarray(reg.n_base) - np.asarray(reg.n_cdel) \
                    + np.asarray(reg.n_cins)
                out_cap = self.base_ratchet.capacity(("base", reg.rel),
                                                     _maxn(new_n))
                with _device_scope():
                    reg.d_base = _compact_fold(
                        reg.d_base, reg.d_cins, reg.d_cdel,
                        out_cap=out_cap,
                        sharded=bool(self.shard_w), use_kernel=use_k)
                assert (np.asarray(_count_of(reg.d_base)) == new_n).all()
                reg.n_base = _count_of(reg.d_base) if self.shard_w \
                    else int(new_n)
                self.ratchet.reset(("committed", reg.rel))
                empty = np.zeros((0, reg.arity), np.int32)
                reg.d_cins = reg._build(empty, kind="committed")
                reg.d_cdel = reg._build(empty, kind="committed")
                reg.n_cins = np.zeros(self.shard_w, np.int64) \
                    if self.shard_w else 0
                reg.n_cdel = np.zeros(self.shard_w, np.int64) \
                    if self.shard_w else 0
                self.stats.compactions += 1
                reg._mirror.clear()

    def _maybe_compact_host(self, force: bool = False):
        for reg in self.projections.values():
            if reg.derived:
                continue
            h = reg._host
            committed = h["cins"].shape[0] + h["cdel"].shape[0]
            if force or committed > self.compact_ratio * max(
                    h["base"].shape[0], 1):
                if h["cins"].size or h["cdel"].size:
                    h["base"] = np.unique(np.concatenate(
                        [_diff_rows(h["base"], h["cdel"]), h["cins"]]),
                        axis=0)
                    self.stats.compactions += 1
                h["cins"] = h["cins"][:0]
                h["cdel"] = h["cdel"][:0]
                reg.refresh()

    def _as_batches(self, ins, dels=None) -> Dict:
        """Array sugar -> per-relation {rel: (ins, dels)} batches.

        Accepts the normalized-dict form ``({rel: (ins, dels)}, None)``,
        the two-dict form ``({rel: ins}, {rel: dels})``, or the legacy
        edge arrays ``(ins, dels)``.
        """
        if isinstance(ins, dict):
            out = {}
            if dels is None:
                for rel, pair in ins.items():
                    ar = self._rel(rel).arity
                    ri, rd = pair
                    out[rel] = (np.asarray(ri, np.int32).reshape(-1, ar),
                                np.asarray(rd, np.int32).reshape(-1, ar))
                return out
            if not isinstance(dels, dict):
                raise ValueError("mixed dict/array (ins, dels) batches")
            for rel in set(ins) | set(dels):
                ar = self._rel(rel).arity
                empty = np.zeros((0, ar), np.int32)
                out[rel] = (np.asarray(ins.get(rel, empty),
                                       np.int32).reshape(-1, ar),
                            np.asarray(dels.get(rel, empty),
                                       np.int32).reshape(-1, ar))
            return out
        return {"edge": (np.asarray(ins, np.int32).reshape(-1, 2),
                         np.asarray(dels, np.int32).reshape(-1, 2))}

    def begin_epoch(self, ins, dels=None):
        """Stage one normalized batch (array sugar for the edge relation,
        or per-relation dicts) as the uncommitted region of EVERY
        projection (after the eager re-insertion compaction check)."""
        batches = self._as_batches(ins, dels)
        # eager compaction iff a committed delete is being re-inserted
        # (would create a positive/negative region overlap, DESIGN.md §2)
        need = False
        for rel, (r_ins, r_dels) in batches.items():
            if not r_ins.size:
                continue
            st = self._rel(rel)
            if self.device_resident:
                if _total(st.n_live[2]):
                    pi = _pack_rows(r_ins, st.arity)
                    probe = pi if st.arity > 2 else pi[0]
                    qk, qv = _pad_probe(probe,
                                        np.zeros(r_ins.shape[0], np.int32),
                                        np.int64(csr.SENTINEL),
                                        cap=self._probe_cap(
                                            rel, r_ins.shape[0]))
                    need = need or bool(_any_member(
                        st.lc_del, qk, qv, sharded=bool(self.shard_w)))
                if not need:
                    need = any(reg.probe_cdel(r_ins)
                               for reg in self.projections.values()
                               if reg.rel == rel and not reg.derived
                               and _total(reg.n_cdel))
            else:
                need = need or any(
                    _inter_rows(r_ins, reg._host["cdel"]).size
                    for reg in self.projections.values()
                    if reg.rel == rel and not reg.derived)
            if int(r_ins.max()) >= int(csr.SENTINEL32) and \
                    any(reg.narrow for reg in self.projections.values()
                        if reg.rel == rel):
                raise ValueError(
                    f"vertex id >= {int(csr.SENTINEL32)} collides with "
                    "the narrow int32 index sentinel of an existing "
                    f"{rel!r} projection; ids this large must be present "
                    "in the initial tuples so the projection is built "
                    "wide")
        self._maybe_compact(force=bool(need))
        self._staged = batches
        for reg in self.projections.values():
            reg.set_uncommitted(*self._staged_for(reg.rel))

    def commit(self, ins, dels=None):
        """Fold uins/udel into the committed regions (with cancellation) and
        advance every updated relation's live set — once per epoch, shared
        by every query.

        Device-resident: jitted sorted-merge/diff folds over the committed
        regions and the staged delta only; the compacted base region object
        passes through UNTOUCHED (no rebuild, no re-upload).

        ATOMIC (DESIGN.md §10): every fold output is computed into a
        staging list first — the store is not mutated until all folds
        succeeded, then the swap is a pure host assignment loop with no
        fault points.  A failure mid-commit (an injected
        ``store.commit.fold`` fault) therefore leaves the store
        bit-identical to the epoch boundary; :meth:`rollback` clears the
        staged batch.
        """
        if self._staged is None:
            # raw commit without begin_epoch: net the args against the live
            # set first (a live "insert" or absent "delete" must be a no-op,
            # exactly as normalize guarantees on the staged path), then
            # stage — so projections and the live set fold the SAME batch
            raw = self._as_batches(ins, dels)
            self.stats.normalize_calls += 1  # matches the staged path
            netted = {
                rel: self._normalize_rel(
                    rel,
                    np.concatenate([ri, rd]),
                    np.concatenate([np.ones(ri.shape[0], np.int32),
                                    -np.ones(rd.shape[0], np.int32)]))
                for rel, (ri, rd) in raw.items()}
            self.begin_epoch(netted)
        batches = self._staged
        if not self.device_resident:
            self._staged = None
            self.stats.commit_calls += 1
            self.stats.epochs += 1
            self._commit_host(batches)
            self._sync_compile_stats()
            return
        use_k = _merge_kernel_on()
        # donation would kill the old committed buffers the moment a fold
        # runs, stranding the rollback target — take the undonated variant
        # whenever a fault could abort the commit midway
        fold_fn = _commit_fold_safe if faults.active() else _commit_fold
        # ---- stage: compute every fold output, store untouched ------------
        staged_rels = []  # (st, new_cins, new_cdel, n_live)
        for rel, (r_ins, r_dels) in batches.items():
            if not (r_ins.size or r_dels.size):
                continue
            st = self._rel(rel)
            # live-set LSM fold (per relation; shard-local when sharded).
            # Delta indices ride the pinned (rel, "delta") rung; both
            # committed outputs share ONE (rel, "committed") rung — tied
            # caps halve the fold-signature space and a rung only ever
            # grows between compactions (ratchet hysteresis).
            faults.fire("store.commit.fold")
            li = _packed_index(r_ins, self.shard_w, st.arity,
                               capacity=self._delta_cap(rel,
                                                        r_ins.shape[0]))
            self.ratchet.observe(("delta", rel), li.key.shape[-1])
            ld = _packed_index(r_dels, self.shard_w, st.arity,
                               capacity=self._delta_cap(rel,
                                                        r_dels.shape[0]))
            self.ratchet.observe(("delta", rel), ld.key.shape[-1])
            nb, nci, ncd = st.n_live
            need = max(_maxn(np.asarray(nci) + np.asarray(_count_of(li))),
                       _maxn(np.asarray(ncd) + np.asarray(_count_of(ld))))
            cc = self._committed_cap(rel, need)
            with _device_scope():
                new_ci, new_cd = fold_fn(
                    st.lb, st.lc_ins, st.lc_del, li, ld,
                    cins_cap=cc, cdel_cap=cc,
                    sharded=bool(self.shard_w), use_kernel=use_k)
            staged_rels.append((st, new_ci, new_cd,
                                [nb, _count_of(new_ci), _count_of(new_cd)]))
        # per-projection folds (vmapped over shards when distributed)
        staged_projs = []  # (reg, d_cins, d_cdel, empty_ins, empty_dels)
        derived_dirty = []
        for reg in self.projections.values():
            r_ins, r_dels = batches.get(
                reg.rel, (np.zeros((0, reg.arity), np.int32),) * 2)
            if reg.derived:
                if r_ins.size or r_dels.size:
                    derived_dirty.append(reg)  # committed rows changed
                continue
            if not (r_ins.size or r_dels.size):
                continue  # untouched relation: regions pass through
            faults.fire("store.commit.fold")
            need = max(
                _maxn(np.asarray(reg.n_cins)
                      + np.asarray(_count_of(reg.d_uins))),
                _maxn(np.asarray(reg.n_cdel)
                      + np.asarray(_count_of(reg.d_udel))))
            cc = self._committed_cap(reg.rel, need)
            with _device_scope():
                d_cins, d_cdel = fold_fn(
                    reg.d_base, reg.d_cins, reg.d_cdel, reg.d_uins,
                    reg.d_udel, cins_cap=cc, cdel_cap=cc,
                    sharded=bool(self.shard_w), use_kernel=use_k)
            staged_projs.append((reg, d_cins, d_cdel,
                                 r_ins[:0], r_dels[:0]))
        # ---- swap: pure host assignments, no fault points -----------------
        self._staged = None
        for st, new_ci, new_cd, n_live in staged_rels:
            st.lc_ins, st.lc_del = new_ci, new_cd
            st.n_live = n_live
            st.mirror = None
        for reg in derived_dirty:
            reg._derived_cache.clear()
        for reg, d_cins, d_cdel, e_ins, e_dels in staged_projs:
            reg.d_cins, reg.d_cdel = d_cins, d_cdel
            reg.n_cins = _count_of(d_cins)
            reg.n_cdel = _count_of(d_cdel)
            reg.set_uncommitted(e_ins, e_dels)
            # commit never touches d_base: keep its mirror (compaction's
            # full clear is the one that must drop it)
            reg._mirror.pop("cins", None)
            reg._mirror.pop("cdel", None)
        self.stats.commit_calls += 1
        self.stats.epochs += 1
        self._maybe_compact()
        self._sync_compile_stats()

    def rollback(self) -> None:
        """Return the store to the epoch boundary: drop the staged batch
        and reset every projection's uncommitted region to empty.  Exact
        by construction — :meth:`commit` swaps nothing in until every fold
        has succeeded, so a failure between :meth:`begin_epoch` and a
        completed commit leaves all committed regions untouched."""
        self._staged = None
        for reg in self.projections.values():
            empty = np.zeros((0, reg.arity), np.int32)
            reg.set_uncommitted(empty, empty)
        self.stats.rollbacks += 1

    def _commit_host(self, batches: Dict):
        for reg in self.projections.values():
            r_ins, r_dels = batches.get(
                reg.rel, (np.zeros((0, reg.arity), np.int32),) * 2)
            if reg.derived:
                if r_ins.size or r_dels.size:
                    reg._derived_cache.clear()
                continue
            h = reg._host
            cins = np.unique(np.concatenate(
                [_diff_rows(h["cins"], r_dels),
                 _diff_rows(r_ins, h["cdel"])]),
                axis=0) if (r_ins.size or h["cins"].size) else h["cins"]
            cdel = np.unique(np.concatenate(
                [h["cdel"], _inter_rows(r_dels, h["base"])]), axis=0) \
                if (r_dels.size or h["cdel"].size) else h["cdel"]
            h["cins"], h["cdel"] = cins, cdel
            reg.refresh(("cins", "cdel"))
            reg.set_uncommitted(r_ins[:0], r_dels[:0])
        for rel, (ins, dels) in batches.items():
            st = self._rel(rel)
            if not (ins.size or dels.size):
                continue
            if st.arity == 2:
                # incremental sorted maintenance of the packed live cache
                # (and the rows derived from it): O(|E|) memmove, no
                # re-pack, no re-sort
                if ins.size:
                    pi = np.sort(_pack2(ins[:, 0], ins[:, 1]))
                    st.packed = np.insert(
                        st.packed, np.searchsorted(st.packed, pi), pi)
                if dels.size:
                    pd = np.sort(_pack2(dels[:, 0], dels[:, 1]))
                    pos = np.searchsorted(st.packed, pd)
                    # normalize guarantees dels ⊆ live, but stay tolerant
                    # of raw commit() calls: only positions that actually
                    # match are removed
                    hit = (pos < st.packed.shape[0]) & \
                        (st.packed[np.minimum(
                            pos, max(st.packed.shape[0] - 1, 0))] == pd)
                    st.packed = np.delete(st.packed, pos[hit])
                st.rows = _unpack2(st.packed)
            else:
                rows = st.rows
                if dels.size:
                    rows = rows[~rows_isin(rows, dels)]
                if ins.size:
                    rows = np.unique(np.concatenate([rows, ins]), axis=0)
                st.rows = rows
                self._refresh_host_cache(st)
        self._maybe_compact()

    # -- durability (DESIGN.md §9) -------------------------------------
    SNAPSHOT_FORMAT = 1

    @staticmethod
    def _index_parts(idx: IndexData):
        parts = [("key", idx.key), ("val", idx.val), ("n", idx.n)]
        if idx.lo is not None:
            parts.append(("lo", idx.lo))
        return parts

    def snapshot(self) -> Tuple[List[np.ndarray], dict]:
        """Serialize the store's dynamic state to ``(leaves, meta)`` —
        the leaves are host arrays in ``meta["names"]`` order (ready for
        ``repro.checkpoint.save_pytree(leaves, ..., extra=meta)``), meta
        is a JSON-safe dict.

        Captured per relation: the live three-region LSM (sorted device
        regions, composite ``lo`` words included) and its exact counts;
        per non-derived projection: the base/cins/cdel regions and counts;
        plus both Ratchet mark sets (so a restored store re-requests the
        SAME buffer shapes — prewarmed executables stay hot) and the epoch
        counters.  Sharded stores serialize per shard: every leaf keeps
        its leading [w] worker axis.

        Must be called at an epoch boundary (nothing staged); the staged
        uncommitted regions are transient by design — a WAL records the
        raw batches instead (``repro.serve.wal``)."""
        if not self.device_resident:
            raise NotImplementedError(
                "snapshot() serializes the device-resident store; the "
                "legacy host store is already plain numpy state")
        if self._staged is not None:
            raise SnapshotError(
                "snapshot mid-epoch: commit (or rollback) the staged batch "
                "first — snapshots are epoch-boundary consistent")
        leaves: List[np.ndarray] = []
        names: List[str] = []

        def emit(prefix, idx):
            for suffix, arr in self._index_parts(idx):
                names.append(f"{prefix}.{suffix}")
                leaves.append(np.asarray(arr))

        meta_rels = {}
        for rel in sorted(self._rels):
            st = self._rels[rel]
            for region, idx in (("lb", st.lb), ("lc_ins", st.lc_ins),
                                ("lc_del", st.lc_del)):
                emit(f"rel/{rel}/{region}", idx)
            meta_rels[rel] = {
                "arity": st.arity,
                "n_live": [np.asarray(n).tolist() for n in st.n_live]}
        projs = []
        for i, (pkey, reg) in enumerate(
                sorted(self.projections.items(), key=lambda kv: repr(kv[0]))):
            spec = {"rel": reg.rel, "key_pos": list(reg.key_pos),
                    "ext_pos": int(reg.ext_pos),
                    "rel_arity": int(reg.rel_arity),
                    "narrow": bool(reg.narrow),
                    "derived": bool(reg.derived)}
            if not reg.derived:
                for region in ("d_base", "d_cins", "d_cdel"):
                    emit(f"proj/{i}/{region}", getattr(reg, region))
                spec["n_base"] = np.asarray(reg.n_base).tolist()
                spec["n_cins"] = np.asarray(reg.n_cins).tolist()
                spec["n_cdel"] = np.asarray(reg.n_cdel).tolist()
            projs.append(spec)
        st_ = self.stats
        meta = {
            "format": self.SNAPSHOT_FORMAT,
            "shard_w": int(self.shard_w),
            "compact_ratio": float(self.compact_ratio),
            "rels": meta_rels,
            "projections": projs,
            "ratchet": [[list(k), v] for k, v in
                        sorted(self.ratchet.marks().items(),
                               key=lambda kv: repr(kv[0]))],
            "base_ratchet": [[list(k), v] for k, v in
                             sorted(self.base_ratchet.marks().items(),
                                    key=lambda kv: repr(kv[0]))],
            "stats": {f: getattr(st_, f) for f in
                      ("normalize_calls", "commit_calls", "compactions",
                       "epochs", "live_compactions")},
            "names": names,
        }
        return leaves, meta

    def restore(self, leaves: List[np.ndarray], meta: dict) -> None:
        """Rebuild this store's dynamic state from a :meth:`snapshot`,
        in place — engines holding a reference re-resolve their regions
        through ``indices_for`` each epoch, so they pick the restored
        truth up without rebuilding.  The mesh width must match the
        snapshot's (failover restores onto the same topology)."""
        if meta.get("format") != self.SNAPSHOT_FORMAT:
            raise ValueError(
                f"unknown snapshot format {meta.get('format')!r}")
        if int(meta["shard_w"]) != int(self.shard_w):
            raise ValueError(
                f"snapshot was taken on a shard_w={meta['shard_w']} store; "
                f"this store has shard_w={self.shard_w} — restore onto the "
                "same mesh width")
        if not self.device_resident:
            raise NotImplementedError(
                "restore() targets the device-resident store")
        by_name = dict(zip(meta["names"], leaves))
        if len(by_name) != len(meta["names"]):
            raise ValueError("snapshot leaves do not match meta['names']")

        def pull(prefix) -> IndexData:
            lo = by_name.get(f"{prefix}.lo")
            return IndexData(jnp.asarray(by_name[f"{prefix}.key"]),
                             jnp.asarray(by_name[f"{prefix}.val"]),
                             jnp.asarray(by_name[f"{prefix}.n"]),
                             None if lo is None else jnp.asarray(lo))

        def nval(v):
            arr = np.asarray(v, np.int64)
            return arr if self.shard_w else int(arr)

        # ratchet marks FIRST: the empty delta regions built below must
        # land on the snapshot's pinned rungs, not re-derive fresh ones
        for ratchet, recs in ((self.ratchet, meta["ratchet"]),
                              (self.base_ratchet, meta["base_ratchet"])):
            ratchet.reset()
            for key, cap in recs:
                ratchet.observe(tuple(key), int(cap))
        self._rels = {}
        for rel, rec in meta["rels"].items():
            st = _RelLive(arity=int(rec["arity"]))
            st.lb = pull(f"rel/{rel}/lb")
            st.lc_ins = pull(f"rel/{rel}/lc_ins")
            st.lc_del = pull(f"rel/{rel}/lc_del")
            st.n_live = [nval(n) for n in rec["n_live"]]
            st.mirror = None
            self._rels[rel] = st
        self.projections = {}
        for i, spec in enumerate(meta["projections"]):
            reg = _Regions(tuple(spec["key_pos"]), int(spec["ext_pos"]),
                           rel=spec["rel"], rel_arity=int(spec["rel_arity"]),
                           shard_w=self.shard_w, device_resident=True,
                           narrow=bool(spec["narrow"]),
                           derived=bool(spec["derived"]), _store=self)
            if not reg.derived:
                reg.d_base = pull(f"proj/{i}/d_base")
                reg.d_cins = pull(f"proj/{i}/d_cins")
                reg.d_cdel = pull(f"proj/{i}/d_cdel")
                reg.n_base = nval(spec["n_base"])
                reg.n_cins = nval(spec["n_cins"])
                reg.n_cdel = nval(spec["n_cdel"])
                empty = np.zeros((0, reg.arity), np.int32)
                reg.set_uncommitted(empty, empty)
            self.projections[(spec["rel"], tuple(spec["key_pos"]),
                              int(spec["ext_pos"]))] = reg
        for f, v in meta["stats"].items():
            setattr(self.stats, f, int(v))
        self._staged = None
        self._sync_compile_stats()


class DeltaBigJoin:
    """Incremental maintenance of one query over dynamic n-ary relations.

    Every atom may read any stored relation (the single binary ``edge``
    relation of subgraph queries, the ternary ``tri`` relation of §5.4, a
    4-ary relation, or a mix); each dQ_i seeds from ITS atom's relation
    batch and the engine runs the same dataflow over all of them.

    Region/commit bookkeeping lives in a :class:`RegionStore`; by default the
    engine owns a private one, but a shared store may be injected (``store=``)
    so many engines ride one graph with one commit per epoch — that is what
    :class:`repro.api.GraphSession` does.  Prefer the session facade for new
    code; this class remains the single-query engine underneath it.
    """

    def __init__(self, query: Query, initial_edges,
                 cfg: BigJoinConfig = BigJoinConfig(mode="collect"),
                 compact_ratio: float = 0.5,
                 store: Optional[RegionStore] = None,
                 device_resident: bool = True):
        self.query = query
        self.cfg = cfg
        self.compact_ratio = compact_ratio
        self.device_resident = device_resident
        self._prewarm_args: Optional[Tuple[int, Optional[int]]] = None
        self.plans: List[Plan] = [make_delta_plan(dq)
                                  for dq in delta_queries(query)]
        if store is None:
            store = self._new_store(initial_edges, compact_ratio)
        self.store = store
        for plan in self.plans:
            self.store.ensure_plan(plan)

    def _new_store(self, edges, compact_ratio: float) -> RegionStore:
        """Private store; the distributed engine overrides this to build
        worker-sharded device regions."""
        return RegionStore(edges, shard_w=0, compact_ratio=compact_ratio,
                           device_resident=self.device_resident)

    # store delegation (public surface predating RegionStore) --------------
    @property
    def edges(self) -> np.ndarray:
        return self.store.edges

    @property
    def projections(self) -> Dict[Projection, _Regions]:
        return self.store.projections

    def normalize(self, updates, weights=None):
        return self.store.normalize(updates, weights)

    def _maybe_compact(self, force: bool = False):
        self.store._maybe_compact(force)

    def _run_plan(self, plan: Plan, indices: Indices, seed: np.ndarray,
                  weights: np.ndarray) -> JoinResult:
        """Run one delta query's dataflow; overridden by the mesh engine."""
        return run_bigjoin(plan, indices, seed, weights, cfg=self.cfg)

    def prewarm(self, update_batch: int,
                horizon: Optional[int] = None) -> int:
        """AOT-compile every (step, seed_step, committed-rung) signature
        this engine's delta plans can request for batches ≤ ``update_batch``
        (the local half of ``GraphSession.prewarm``; the store's fold
        ladder is warmed separately by ``RegionStore.prewarm_folds``).
        Returns the compile events spent."""
        from repro.core.bigjoin import _compiled_fns, make_state
        ub = max(int(update_batch), 1)
        self._prewarm_args = (ub, horizon)
        snap = compilestats.snapshot()
        for plan in self.plans:
            step, seed_step = _compiled_fns(plan, self.cfg)
            state_sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                make_state(plan, self.cfg))
            Sc = int(self.cfg.seed_chunk)
            pfx = jax.ShapeDtypeStruct((Sc, plan.seed_width), jnp.int32)
            wts = jax.ShapeDtypeStruct((Sc,), jnp.int32)
            valid = jax.ShapeDtypeStruct((Sc,), jnp.bool_)
            rels = {rel for _id, rel, *_ in plan.index_ids()}
            # relations cross committed rungs independently, so warm the
            # reachable rung CROSS-PRODUCT, not just the same-rung
            # diagonal (bounded subset over PREWARM_CROSS_CAP combos —
            # see _rung_combos / DESIGN.md §8)
            ladders = {rel: self.store.committed_ladder(rel, ub, horizon)
                       for rel in rels}
            for combo in _rung_combos(ladders):
                idx = self.store.indices_sds_for(plan, combo, ub)
                _warm_call(seed_step, state_sds, idx, pfx, wts, valid)
                _warm_call(step, state_sds, idx)
        return compilestats.since(snap)

    # -- overflow recovery (DESIGN.md §10) ------------------------------
    MAX_ESCALATIONS = 3  # per plan run, before the overflow surfaces

    def _escalate(self, exc: CapacityOverflow) -> None:
        """Recover from one :class:`CapacityOverflow`: bump the offending
        capacity rung(s) on the store ratchet (monotone marks — they
        serialize with snapshots, so an escalation survives failover),
        rebuild this engine's config on the new rungs, and re-prewarm so
        the replay runs on AOT-compiled signatures.  Re-raises when the
        overflow names no buffer this engine can grow."""
        qn = self.query.name
        r = self.store.ratchet
        cfg, changed = self.cfg, False
        if exc.kinds & ESCALATES_OUT:
            new_out = r.escalate(("cap", "out", qn),
                                 floor=cfg.out_capacity)
            cfg = dataclasses.replace(cfg, out_capacity=new_out)
            changed = True
        if exc.kinds & ESCALATES_BATCH:
            new_b = r.escalate(("cap", "batch", qn), floor=cfg.batch)
            cfg = dataclasses.replace(
                cfg, batch=new_b, seed_chunk=max(cfg.seed_chunk, new_b))
            changed = True
        if not changed:
            raise exc
        self.cfg = cfg
        self.store.stats.escalations += 1
        self._reprewarm()

    def _reprewarm(self) -> None:
        """Re-run prewarm (if this engine was ever prewarmed) so the new
        escalated signatures are AOT-compiled off the serving path; the
        compiles are accounted separately (``escalation_compiles``) so the
        zero-serving-compiles gate can subtract them."""
        if self._prewarm_args is None:
            return
        snap = compilestats.snapshot()
        self.prewarm(*self._prewarm_args)
        self.store.stats.escalation_compiles += compilestats.since(snap)

    def _run_plan_escalating(self, plan: Plan, seed: np.ndarray,
                             weights: np.ndarray) -> JoinResult:
        """One plan run with escalate-and-replay: the seed is host-staged
        and the store is read-only during the run, so a replay after a
        rung bump is deterministic and exact."""
        for attempt in range(self.MAX_ESCALATIONS + 1):
            try:
                return self._run_plan(plan, self.store.indices_for(plan),
                                      seed, weights)
            except CapacityOverflow as exc:
                if attempt >= self.MAX_ESCALATIONS:
                    raise
                self._escalate(exc)
                self.store.stats.replays += 1
        raise AssertionError("unreachable")

    # ------------------------------------------------------------------
    def run_delta_plans(self, ins, dels=None) -> DeltaResult:
        """Evaluate dAQ_1..dAQ_n for one staged batch (the store must have
        ``begin_epoch``-ed it); does NOT commit — the caller owns the epoch
        boundary, so a facade can run many queries off one staged batch.

        ``(ins, dels)`` edge arrays, or the per-relation batch dict —
        each dQ_i seeds from the batch of ITS seed atom's relation (n-ary
        dR tuples seed the dataflow at P_r, plan.seed_width)."""
        batches = self.store._as_batches(ins, dels)
        per_dq: List[JoinResult] = []
        total = 0
        tuples, wts = [], []
        for plan in self.plans:
            rel = plan.query.atoms[plan.seed_atom].rel
            r_ins, r_dels = batches.get(
                rel, (np.zeros((0, 2), np.int32),) * 2)
            if r_ins.size == 0 and r_dels.size == 0:
                continue  # this relation did not change: dQ_i is empty
            delta_rows = np.concatenate([r_ins, r_dels], axis=0)
            delta_w = np.concatenate([
                np.ones(r_ins.shape[0], np.int32),
                -np.ones(r_dels.shape[0], np.int32)])
            seed = delta_rows[:, list(plan.seed_cols)]
            res = self._run_plan_escalating(plan, seed, delta_w)
            per_dq.append(res)
            total += res.count
            if res.tuples is not None and res.tuples.size:
                tuples.append(res.tuples)
                wts.append(res.weights)
        out_t = np.concatenate(tuples) if tuples else None
        out_w = np.concatenate(wts) if wts else None
        return DeltaResult(total, out_t, out_w, per_dq)

    def apply(self, updates, weights=None) -> DeltaResult:
        """Process one update batch (edge arrays, or a per-relation dict
        ``{rel: (rows, weights)}``): emit output changes, then commit."""
        batches = self.store.normalize(updates, weights)
        if not isinstance(batches, dict):
            batches = {"edge": batches}
        if all(i.size == 0 and d.size == 0 for i, d in batches.values()):
            # net-zero batch (no-op inserts of live tuples, deletes of
            # absent tuples, +/- cancellations): an EXACT no-op — no region
            # rebuilds, no compaction, no dataflow run.
            return DeltaResult(0, None, None, [])
        self.store.begin_epoch(batches)
        result = self.run_delta_plans(batches)
        self.store.commit(batches)
        return result


def rows_isin(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-membership mask of ``a``'s rows in ``b`` (both [N, m] int).

    Packed-row diff: rows are mapped to dense ids by one ``np.unique`` over
    the concatenation, then compared with ``np.isin`` on the id vectors — no
    Python set-of-tuples.  O((Na+Nb) log) and fully vectorized; this is the
    stress suite's hot path (delta_oracle on every update batch).
    """
    a = np.asarray(a)
    b = np.asarray(b)
    if a.shape[0] == 0 or b.shape[0] == 0:
        return np.zeros(a.shape[0], bool)
    both = np.concatenate([a, b], axis=0)
    _, inv = np.unique(both, axis=0, return_inverse=True)
    inv = inv.reshape(-1)  # numpy>=2.0 may return [N,1]
    return np.isin(inv[:a.shape[0]], inv[a.shape[0]:])


def canon_signed(tuples: Optional[np.ndarray],
                 weights: Optional[np.ndarray]) -> list:
    """Canonical form of a signed tuple multiset: sorted (tuple, net
    weight != 0) pairs.  THE comparison key of every bit-exact
    differential (tests, subprocess harnesses, benchmarks, examples) —
    one implementation, so the checks can never drift."""
    if tuples is None or tuples.size == 0:
        return []
    uniq, inv = np.unique(tuples, axis=0, return_inverse=True)
    net = np.zeros(uniq.shape[0], np.int64)
    np.add.at(net, inv.reshape(-1), weights)
    return sorted((tuple(r), int(n)) for r, n in zip(uniq, net) if n != 0)


def delta_oracle(query: Query, edges_before, edges_after
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Ground truth: signed difference of full recomputation.

    ``edges_before`` / ``edges_after`` are edge arrays (sugar) or full
    relation dicts ``{rel: rows}``.  Returns (tuples [N, m] int32, weights
    [N] ±1) with the added rows first, each block in lexicographic row
    order (``np.unique`` order — the same order the old set-of-tuples
    implementation produced via ``sorted``).
    """
    from repro.core.generic_join import generic_join
    before = edges_before if isinstance(edges_before, dict) \
        else {"edge": edges_before}
    after = edges_after if isinstance(edges_after, dict) \
        else {"edge": edges_after}
    a, _ = generic_join(query, before)
    b, _ = generic_join(query, after)
    m = query.num_attrs
    a = np.unique(np.asarray(a, np.int32).reshape(-1, m), axis=0)
    b = np.unique(np.asarray(b, np.int32).reshape(-1, m), axis=0)
    added = b[~rows_isin(b, a)]
    removed = a[~rows_isin(a, b)]
    t = np.concatenate([added, removed]).astype(np.int32).reshape(-1, m)
    w = np.concatenate([np.ones(added.shape[0], np.int32),
                        -np.ones(removed.shape[0], np.int32)])
    return t, w
