"""Ratcheting capacity hysteresis on a fixed geometric ladder (DESIGN.md §8).

Every device buffer in the repo is sized by ``pow2_capacity`` of its live
count, so a count oscillating around a power-of-two boundary flips the
buffer's static shape back and forth — and every flip is a fresh jit cache
entry (a *bucket flap*).  A :class:`Ratchet` removes the oscillation: per
buffer name it remembers the largest capacity ever granted and

- never shrinks (a count dropping back under the boundary keeps the old
  capacity, so the shape — and the compiled executable — is reused), and
- grows onto a *fixed canonical ladder*: rung ``r0 = pow2_capacity(1)``
  and ``r_{k+1} = r_k * factor``.  With ``factor=4`` the ladder is
  128, 512, 2048, 8192, ... — a quarter of the pow2 shapes, each rung
  with built-in headroom so a count creeping upward crosses few rungs.

The ladder is *history independent*: which rung a count lands on depends
only on the count, never on the path that got there.  That is what lets
``GraphSession.prewarm`` AOT-compile exactly the finite shape set the
runtime can ever request (:meth:`Ratchet.rungs` enumerates it) — a
slack-multiplied ladder would restart from arbitrary pow2 values after a
reset and make every pow2 shape reachable again.

:meth:`observe` floors a mark to a capacity that was actually built
(builders can exceed a request under shard skew) and is also how prewarm
*pins* delta/probe/seed marks to the update-batch bound, collapsing those
shapes to a single signature; pinned marks need not sit on canonical rungs.

Marks are plain host state; :meth:`reset` forgets selected names.  The
store resets its *committed-region* marks at compaction (those regions
drain to ~0 there, and holding them at the pre-compaction rung would make
every later fold pay O(threshold) instead of O(|Δ|) — the rungs it then
revisits are already in the jit cache, so re-walking the ladder costs no
compile).  Delta/probe/seed marks are never reset.
"""
from __future__ import annotations

from typing import Dict, Hashable, List

from repro.core.csr import pow2_capacity

Key = Hashable


class Ratchet:
    """Monotone per-name capacity quantizer onto a fixed geometric ladder."""

    def __init__(self, factor: int = 4):
        if factor < 2 or (factor & (factor - 1)) != 0:
            raise ValueError("factor must be a power of two >= 2")
        self.factor = int(factor)
        self._caps: Dict[Key, int] = {}

    def quantize(self, n: int) -> int:
        """Smallest canonical rung >= ``n`` (128, 128*f, 128*f^2, ...)."""
        n = max(int(n), 1)
        r = pow2_capacity(1)
        while r < n:
            r *= self.factor
        return r

    def capacity(self, name: Key, n: int) -> int:
        """The capacity to build ``name`` at for live count ``n``.

        Returns the stored mark while ``n`` fits it; an overflow quantizes
        onto the canonical ladder and ratchets the mark up.  The result
        never decreases for a given name."""
        n = max(int(n), 1)
        cap = self._caps.get(name, 0)
        if n > cap:
            cap = max(self.quantize(n), cap)
            self._caps[name] = cap
        return cap

    def observe(self, name: Key, cap: int) -> None:
        """Floor ``name``'s mark to a capacity that was actually built.

        Builders may exceed the requested capacity (``build_sharded_index``
        rounds to the largest shard under skew); feeding the real capacity
        back keeps the ratchet — and the prewarm ladder — in sync with the
        shapes the jit cache will actually see.  Also the pinning primitive:
        prewarm observes delta/probe/seed marks at their update-batch bound
        so those buffers keep ONE shape for the life of the stream."""
        cap = int(cap)
        if cap > self._caps.get(name, 0):
            self._caps[name] = cap

    def peek(self, name: Key, default: int = 0) -> int:
        """Current mark without growing it (``default`` if never sighted)."""
        return self._caps.get(name, default)

    def reset(self, *names: Key) -> None:
        """Forget marks (all of them when called with no names)."""
        if not names:
            self._caps.clear()
            return
        for name in names:
            self._caps.pop(name, None)

    def next_rung(self, cap: int) -> int:
        """The smallest canonical rung strictly above ``cap``."""
        r = self.quantize(cap)
        return r * self.factor if r <= int(cap) else r

    def escalate(self, name: Key, floor: int = 0) -> int:
        """Bump ``name``'s mark to the next canonical rung above
        ``max(mark, floor)`` and return it — the overflow-recovery
        primitive (DESIGN.md §10): a :class:`~repro.errors.CapacityOverflow`
        names the buffer that overflowed, the driver escalates its rung,
        re-prewarms the new signature and replays the staged epoch.
        Monotone like every other mark mutation, so escalations persist
        through snapshot/restore and never flap."""
        cur = max(self._caps.get(name, 0), int(floor))
        new = self.next_rung(cur) if cur > 0 else self.quantize(1)
        self._caps[name] = max(new, cur)
        return self._caps[name]

    def rungs(self, lo: int, hi: int) -> List[int]:
        """Canonical rungs covering counts in ``[lo, hi]`` — the AOT
        prewarm ladder.  History independent: every capacity any mark can
        take for a count in range appears here."""
        r = self.quantize(lo)
        hi_cap = self.quantize(max(int(hi), int(lo), 1))
        out = [r]
        while r < hi_cap:
            r *= self.factor
            out.append(r)
        return out

    def marks(self) -> Dict[Key, int]:
        """Copy of the current marks (introspection/tests)."""
        return dict(self._caps)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"Ratchet(factor={self.factor}, {len(self._caps)} marks)"
