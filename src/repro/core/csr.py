"""Sorted-array extension indices (the TPU-native ``Ext``, §2.2).

The paper requires, for each relation/bound-prefix pair, an index exposing:
  (i)   |Ext(p)|            -- count          (O(1) in the paper)
  (ii)  contents of Ext(p)  -- slice          (O(|Ext(p)|))
  (iii) e in Ext(p)         -- membership     (O(1) in the paper)

Hash tables give these on CPUs; on TPUs pointer-chasing is hostile, so we use
*sorted dual arrays*: a packed 64-bit key column (the bound prefix) and a
32-bit value column (the extension), sorted lexicographically.  Counts and
slices come from two ``searchsorted`` probes; membership is a fixed-depth
binary search over the (key,val) pairs — O(log IN) instead of O(1), the same
trade EmptyHeaded makes with its sorted set layouts.

Everything here is a pytree of jnp arrays, so indices shard with
``jax.device_put`` / ``shard_map`` like any other model state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compilestats

# Sentinel keys strictly larger than any real key.  Wide (int64) keys pack
# two int32 columns as a<<32|b with a, b < 2^31, so their maximum is below
# int64-max and the int64 sentinel covers the FULL vertex-id range; narrow
# (int32) keys use int32-max, so ids must stay < 2^31 - 1 (builds auto-widen
# when they don't, and the store's id-domain guard rejects the boundary).
SENTINEL = np.int64(np.iinfo(np.int64).max)
SENTINEL32 = np.int32(2**31 - 1)

# Canonical segment length of the two-level membership kernels (one VPU lane
# row); kernels/intersect/intersect.py imports it from here.  Index
# capacities are rounded up to SEG multiples so the kernels' segment-major
# [cap/SEG, SEG] view is a free reshape (no pad/concat per probe).
SEG = 128


def round_capacity(cap: int) -> int:
    return -(-max(int(cap), 1) // SEG) * SEG


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IndexData:
    """One sorted (key[, lo], val) extension index.

    key: [N] int64, nondecreasing (packed bound-prefix values)
    val: [N] int32, nondecreasing within equal keys
    n:   [] int32, number of live entries (rest is sentinel padding)
    lo:  [N] int64 or None — the secondary word of a *composite* key.

    With <= 2 bound columns the prefix packs into ``key`` alone (``lo`` is
    None).  3 or 4 bound columns use the generalized lexicographic composite
    key: ``key = c0`` and ``lo = c1<<32|c2`` (3 cols) or ``key = c0<<32|c1``
    and ``lo = c2<<32|c3`` (4 cols); entries are lex-sorted by
    (key, lo, val) and every probe is a fixed-depth two-word lex binary
    search (``lex_searchsorted_cols``).  The 3-col split deliberately keeps
    the hi word a SINGLE column so it stays eligible for the narrow (int32)
    dtype — ``lo`` is always int64.
    """

    key: jax.Array
    val: jax.Array
    n: jax.Array
    lo: Optional[jax.Array] = None

    def tree_flatten(self):
        return (self.key, self.val, self.n, self.lo), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.key.shape[0]

    @property
    def composite(self) -> bool:
        return self.lo is not None

    def key_cols(self) -> Tuple[jax.Array, ...]:
        """The lex-ordered key words: (key,) or (key, lo)."""
        return (self.key,) if self.lo is None else (self.key, self.lo)


# A packed probe key: one array (<= 2 bound columns) or a (hi, lo) pair.
PackedKey = Union[jax.Array, np.ndarray, Tuple]


def pack_key(cols: Sequence) -> PackedKey:
    """Pack 1..4 non-negative int32 columns into a lexicographic key.

    1 column  -> int64 key (may be narrowed to int32 by the index builders);
    2 columns -> ``c0<<32 | c1`` int64;
    3 columns -> the composite pair ``(c0, c1<<32|c2)`` — hi stays a single
                 column so the builders may narrow it to int32;
    4 columns -> the composite pair ``(c0<<32|c1, c2<<32|c3)``.

    THE one key-packing implementation — ``bigjoin._pack_cols``,
    ``generic_join``'s host indices, and the region stores all delegate
    here, so device and host keys can never drift.
    """
    cols = tuple(cols)
    xp = jnp if isinstance(cols[0], jax.Array) else np
    if len(cols) == 1:
        return cols[0].astype(xp.int64)
    if len(cols) == 2:
        return (cols[0].astype(xp.int64) << 32) | cols[1].astype(xp.int64)
    if len(cols) == 3:
        return cols[0].astype(xp.int64), ((cols[1].astype(xp.int64) << 32)
                                          | cols[2].astype(xp.int64))
    if len(cols) == 4:
        return ((cols[0].astype(xp.int64) << 32) | cols[1].astype(xp.int64),
                (cols[2].astype(xp.int64) << 32) | cols[3].astype(xp.int64))
    raise ValueError(
        f"composite keys cover at most 4 int32 columns, got {len(cols)}")


def unpack_key(packed: PackedKey, num_cols: int) -> np.ndarray:
    """Inverse of :func:`pack_key` (host): [N, num_cols] int32 columns."""
    M = 0xFFFFFFFF
    if num_cols <= 2:
        p = np.asarray(packed, np.int64)
        if num_cols == 1:
            return p[:, None].astype(np.int32)
        return np.stack([(p >> 32).astype(np.int32),
                         (p & M).astype(np.int32)], 1)
    hi, lo = (np.asarray(packed[0], np.int64), np.asarray(packed[1],
                                                          np.int64))
    if num_cols == 3:
        cols = [hi.astype(np.int32)]
    else:
        cols = [(hi >> 32).astype(np.int32), (hi & M).astype(np.int32)]
    cols.extend([(lo >> 32).astype(np.int32), (lo & M).astype(np.int32)])
    return np.stack(cols, 1)


def single_word_hi(num_key_cols: int) -> bool:
    """True when the packed hi word holds at most ONE bound column, i.e. a
    single int32 id — the precondition for the narrow (int32) key dtype.
    1 bound column packs into hi alone; 3 bound columns split (c0, c1<<32|c2)
    so hi is again one column; 2/4 columns pack two ids into hi and need the
    full 64 bits."""
    return num_key_cols in (0, 1, 3)


def build_index(tuples: np.ndarray, key_pos: Tuple[int, ...], ext_pos: int,
                capacity: int | None = None,
                narrow: bool | None = None) -> IndexData:
    """Build an IndexData from relation tuples [T, arity] (numpy, host).

    Projects to (key columns, ext column), dedups, sorts.  ``capacity``
    (>= live size) allows preallocating room for future deltas.  ``narrow``
    overrides the key-dtype choice — the device-resident region folds merge
    deltas into long-lived indices, so both sides must agree on one dtype
    decided once per projection, not per build.
    """
    tuples = np.asarray(tuples)
    if tuples.ndim != 2:
        raise ValueError("tuples must be [T, arity]")
    key = pack_key(tuple(tuples[:, p].astype(np.int32) for p in key_pos)) \
        if key_pos else np.zeros(tuples.shape[0], np.int64)
    val = tuples[:, ext_pos].astype(np.int32)
    if isinstance(key, tuple):  # composite (hi, lo) key: 3-4 bound columns
        kvl = np.unique(np.stack([key[0], key[1], val.astype(np.int64)],
                                 axis=1), axis=0)
        key, lo, val = kvl[:, 0], kvl[:, 1], kvl[:, 2].astype(np.int32)
    else:
        kv = np.unique(np.stack([key, val.astype(np.int64)], axis=1), axis=0)
        key, lo, val = kv[:, 0], None, kv[:, 1].astype(np.int32)
    n = key.shape[0]
    cap = round_capacity(max(int(capacity or n), n, 1))
    # single-column hi words fit int32 -> halve hi-word bytes (HBM traffic)
    if narrow is None:
        narrow = single_word_hi(len(key_pos)) and (n == 0
                                                   or key.max() < SENTINEL32)
    narrow = narrow and single_word_hi(len(key_pos))
    kdt, sent = (np.int32, SENTINEL32) if narrow else (np.int64, SENTINEL)
    out_k = np.full(cap, sent, kdt)
    out_v = np.zeros(cap, np.int32)
    out_k[:n] = key.astype(kdt)
    out_v[:n] = val
    out_lo = None
    if lo is not None:
        out_lo = np.full(cap, SENTINEL, np.int64)
        out_lo[:n] = lo
        out_lo = jnp.asarray(out_lo)
    return IndexData(jnp.asarray(out_k), jnp.asarray(out_v),
                     jnp.asarray(n, jnp.int32), out_lo)


# Fibonacci-style multiplicative mix shared with the distributed layer:
# owner_of / shard_of MUST agree so host-built shards answer device routing.
SHARD_MIX = 0x9E3779B97F4A7C15
# second mix for folding a composite key's two words into one routing word
SHARD_MIX2 = 0xC2B2AE3D27D4EB4F


def combine_key(hi, lo):
    """Fold a composite (hi, lo) key into ONE 64-bit routing word.

    Collisions only affect placement, never answers — but host (np) and
    device (jnp) MUST agree, so both routes go through this one function."""
    xp = jnp if isinstance(hi, jax.Array) else np
    h = (hi.astype(xp.uint64) * xp.uint64(SHARD_MIX2)) ^ lo.astype(xp.uint64)
    return h.astype(xp.int64)


def shard_of(key: PackedKey, num_shards: int) -> np.ndarray:
    """Hash-partition owner of each packed key, [N] int32 in [0, num_shards)."""
    if isinstance(key, tuple):
        key = combine_key(*key)
    h = (key.astype(np.uint64) * np.uint64(SHARD_MIX)) >> np.uint64(33)
    return (h % np.uint64(max(num_shards, 1))).astype(np.int32)


def pow2_capacity(n: int) -> int:
    """SEG-aligned power-of-two capacity >= n (stable shapes across deltas).

    THE canonical capacity quantizer: every region, probe pad, seed chunk
    and AGM-derived buffer size in the repo goes through this one function
    (``delta._pow2`` and ``session._pow2`` are aliases), so the ladder of
    shapes that can ever key a jit cache is enumerable — see
    :func:`capacity_ladder` and DESIGN.md §8.
    """
    return round_capacity(1 << max(int(n) - 1, 0).bit_length())


# historical (pre-ladder) private name, kept for callers/tests
_pow2_capacity = pow2_capacity


def capacity_ladder(lo: int, hi: int) -> list:
    """All :func:`pow2_capacity` rungs covering live sizes in [lo, hi].

    ``pow2_capacity`` maps any size in (rung/2, rung] to ``rung``, so the
    rungs between ``pow2_capacity(lo)`` and ``pow2_capacity(hi)`` inclusive
    are exactly the capacities a buffer can take while its live size stays
    in the range — the shapes an AOT prewarm must compile."""
    lo_cap, hi_cap = pow2_capacity(lo), pow2_capacity(max(hi, lo))
    rungs = []
    c = lo_cap
    while c <= hi_cap:
        rungs.append(c)
        c = pow2_capacity(c + 1)
    return rungs


def build_sharded_index(tuples: np.ndarray, key_pos: Tuple[int, ...],
                        ext_pos: int, num_shards: int,
                        capacity: int | None = None,
                        narrow: bool | None = None) -> IndexData:
    """Hash-partition one extension index over ``num_shards`` workers.

    Returns an IndexData whose arrays carry a leading [w] worker axis
    (key/val: [w, cap]; n: [w]) ready to shard over a mesh axis.  Every
    (key, val) pair lands on exactly one worker — ``shard_of(key, w)`` —
    which is the paper's cluster-memory-linearity property (§3.2): the sum
    of live entries over workers equals the unsharded index size.

    Per-shard capacity is uniform (stacking needs one shape) and rounded to
    a SEG-aligned power of two of the largest shard, so shapes stay stable
    across update batches and the jit cache stays warm.  ``capacity`` is a
    per-shard floor.  Key narrowness (int32 vs int64) is decided globally so
    every shard row has one dtype and one sentinel.
    """
    tuples = np.asarray(tuples)
    if tuples.ndim != 2:
        raise ValueError("tuples must be [T, arity]")
    w = max(int(num_shards), 1)
    key = pack_key(tuple(tuples[:, p].astype(np.int32) for p in key_pos)) \
        if key_pos else np.zeros(tuples.shape[0], np.int64)
    val = tuples[:, ext_pos].astype(np.int32)
    if isinstance(key, tuple):  # composite: ownership by the combined word
        kvl = np.unique(np.stack([key[0], key[1], val.astype(np.int64)],
                                 axis=1), axis=0)
        key, klo, val = kvl[:, 0], kvl[:, 1], kvl[:, 2].astype(np.int32)
        own = shard_of((key, klo), w)
    else:
        kv = np.unique(np.stack([key, val.astype(np.int64)], axis=1), axis=0)
        key, klo, val = kv[:, 0], None, kv[:, 1].astype(np.int32)
        own = shard_of(key, w)
    counts = np.bincount(own, minlength=w).astype(np.int64)
    cmax = int(counts.max()) if counts.size else 0
    cap = max(_pow2_capacity(cmax), round_capacity(int(capacity or 1)))
    if narrow is None:
        narrow = single_word_hi(len(key_pos)) and (key.size == 0
                                                   or key.max() < SENTINEL32)
    narrow = narrow and single_word_hi(len(key_pos))
    kdt, sent = (np.int32, SENTINEL32) if narrow else (np.int64, SENTINEL)
    out_k = np.full((w, cap), sent, kdt)
    out_v = np.zeros((w, cap), np.int32)
    out_lo = None if klo is None else np.full((w, cap), SENTINEL, np.int64)
    # rows are lexsorted by (key[, lo], val); a stable sort by owner keeps
    # each shard's rows sorted, which is the IndexData invariant.
    order = np.argsort(own, kind="stable")
    sk, sv = key[order].astype(kdt), val[order]
    sl = klo[order] if klo is not None else None
    offs = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    for i in range(w):
        lo, hi = offs[i], offs[i + 1]
        out_k[i, :hi - lo] = sk[lo:hi]
        out_v[i, :hi - lo] = sv[lo:hi]
        if out_lo is not None:
            out_lo[i, :hi - lo] = sl[lo:hi]
    return IndexData(jnp.asarray(out_k), jnp.asarray(out_v),
                     jnp.asarray(counts.astype(np.int32)),
                     None if out_lo is None else jnp.asarray(out_lo))


def empty_index(capacity: int = 1, narrow: bool = True,
                composite: bool = False) -> IndexData:
    """Empty IndexData.  ``narrow`` applies to the hi word only (``lo`` is
    always int64); composite indices may be narrow when the hi word is a
    single column (the 3-col packing) — the caller decides, matching the
    projection's build-time dtype."""
    cap = round_capacity(capacity)
    kdt, sent = (jnp.int32, SENTINEL32) if narrow else (jnp.int64, SENTINEL)
    return IndexData(jnp.full(cap, sent, kdt),
                     jnp.zeros(cap, jnp.int32),
                     jnp.asarray(0, jnp.int32),
                     jnp.full(cap, SENTINEL, jnp.int64) if composite
                     else None)


# ---------------------------------------------------------------------------
# Queries (jnp, vectorized over a batch of probes).
# ---------------------------------------------------------------------------

def index_range(idx: IndexData, qkey: PackedKey
                ) -> Tuple[jax.Array, jax.Array]:
    """(start, count) of the extension list for each packed key [B].

    ``qkey`` is a single packed array, or a (hi, lo) pair probing a
    composite index; sentinel padding sorts above every real key, so the
    full-capacity search needs no live-count mask."""
    if idx.lo is None:
        start = jnp.searchsorted(idx.key, qkey, side="left")
        end = jnp.searchsorted(idx.key, qkey, side="right")
        return start.astype(jnp.int32), (end - start).astype(jnp.int32)
    qh, ql = qkey
    cap_n = jnp.asarray(idx.capacity, jnp.int32)
    start = lex_searchsorted_cols((idx.key, idx.lo), cap_n, (qh, ql), "left")
    end = lex_searchsorted_cols((idx.key, idx.lo), cap_n, (qh, ql), "right")
    return start.astype(jnp.int32), (end - start).astype(jnp.int32)


def index_count(idx: IndexData, qkey: jax.Array) -> jax.Array:
    return index_range(idx, qkey)[1]


def index_kth(idx: IndexData, start: jax.Array, k: jax.Array) -> jax.Array:
    """k-th extension given the range start (no bounds check: caller masks)."""
    pos = jnp.clip(start + k, 0, idx.capacity - 1)
    return idx.val[pos]


def lex_searchsorted_cols(cols: Tuple[jax.Array, ...], n: jax.Array,
                          qcols: Tuple[jax.Array, ...],
                          side: str = "left") -> jax.Array:
    """Lower/upper bound of each lex query in up-to-3 lex-sorted columns.

    The generalized fixed-depth binary search behind every probe: 2 columns
    is the classic (key, val) pair, 3 columns the composite-key
    (key, lo, val) triple.  Vectorized over the query batch; ``side="left"``
    returns the count of entries strictly below each query, ``side="right"``
    the count of entries <= it.
    """
    cap = cols[0].shape[0]
    right = side == "right"
    # +1: an interval of length 1 still needs one comparison to collapse
    depth = max(int(np.ceil(np.log2(max(cap, 2)))), 1) + 1
    lo = jnp.zeros(qcols[0].shape, jnp.int32)
    hi = jnp.broadcast_to(jnp.minimum(jnp.int32(cap), n.astype(jnp.int32)),
                          qcols[0].shape)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        midc = jnp.clip(mid, 0, cap - 1)
        less = jnp.zeros(qcols[0].shape, bool)
        eq = jnp.ones(qcols[0].shape, bool)
        for c, q in zip(cols, qcols):
            mc = c[midc]  # mixed-width compares promote, never truncate
            less = less | (eq & (mc < q))
            eq = eq & (mc == q)
        if right:
            less = less | eq
        lo = jnp.where(less & (lo < hi), mid + 1, lo)
        hi = jnp.where(~less & (lo < hi), mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, depth, body, (lo, hi))
    return lo


def lex_searchsorted(key: jax.Array, val: jax.Array, n: jax.Array,
                     qk: jax.Array, qv: jax.Array,
                     side: str = "left") -> jax.Array:
    """Two-column (key, val) lex bound — the jnp oracle mirrored by the
    Pallas ``intersect``/``merge`` kernels (see ``lex_searchsorted_cols``
    for the generalized composite-key form)."""
    return lex_searchsorted_cols((key, val), n, (qk, qv), side)


def index_member(idx: IndexData, qkey: PackedKey, qval: jax.Array
                 ) -> jax.Array:
    """Membership (qkey, qval) in the index, [B] bool — the pure-jnp oracle.

    Kernel routing happens one level up: ``VersionedIndex.signed_member``
    fuses all regions — composite (hi, lo) keys included — into one Pallas
    launch; this stays the bit-exact reference path.
    """
    qv = qval.astype(jnp.int32)
    if idx.lo is None:
        pos = lex_searchsorted(idx.key, idx.val, idx.n, qkey, qv)
        pos_c = jnp.clip(pos, 0, idx.capacity - 1)
        hit = (idx.key[pos_c] == qkey) & (idx.val[pos_c] == qv)
        return hit & (pos < idx.n)
    qh, ql = qkey
    pos = lex_searchsorted_cols((idx.key, idx.lo, idx.val), idx.n,
                                (qh, ql, qv))
    pos_c = jnp.clip(pos, 0, idx.capacity - 1)
    hit = ((idx.key[pos_c] == qh) & (idx.lo[pos_c] == ql)
           & (idx.val[pos_c] == qv))
    return hit & (pos < idx.n)


# ---------------------------------------------------------------------------
# Sorted-merge fold primitives (device-resident region maintenance).
#
# The incremental entry points of this module: instead of re-hashing and
# re-sorting all rows (``build_index``), an existing device-resident
# IndexData is updated by *rank-based sorted merge* against a sorted delta.
# The only non-trivial step is computing, for each entry of one set, its
# rank in the other (count of entries lexicographically < / <= it); with
# both ranks union/diff/intersect are pure static-shape scatters:
#
#     merge position of a[i] in a ∪ b  =  i + |{kept b < a[i]}|
#     merge position of b[j] in a ∪ b  =  |{a < b[j]}| + |{kept b before j}|
#     a[i] ∈ b                         ⇔  |{b <= a[i]}| > |{b < a[i]}|
#
# Cost is O((|a|+|b|)·log), i.e. proportional to the operands — the commit
# folds of `core/delta.py` only ever pass the committed regions and the
# update delta here, never the compacted base, which is how warm epoch cost
# stays a function of |Δ| + |committed| instead of |E|.
# ---------------------------------------------------------------------------

def index_ranks(a: IndexData, qk: PackedKey, qv: jax.Array,
                use_kernel: bool = False) -> Tuple[jax.Array, jax.Array]:
    """(lt, le) int32 [B]: entries of ``a`` lexicographically < / <= each
    (qk[, qlo], qv) query.  ``use_kernel`` routes through the Pallas rank
    kernel (`kernels/merge`), composite (hi, lo) keys included — the jnp
    fixed-depth searches stay the bit-exact reference path."""
    qv = qv.astype(jnp.int32)
    if a.lo is not None:
        qh, ql = qk
        if use_kernel:
            from repro.kernels.merge.ops import rank_lt_le
            return rank_lt_le(a.key, a.val, a.n, qh, qv, lo=a.lo, qlo=ql)
        cols = (a.key, a.lo, a.val)
        qcols = (qh.astype(jnp.int64), ql.astype(jnp.int64), qv)
        return (lex_searchsorted_cols(cols, a.n, qcols, "left"),
                lex_searchsorted_cols(cols, a.n, qcols, "right"))
    qk = qk.astype(a.key.dtype)
    if use_kernel:
        from repro.kernels.merge.ops import rank_lt_le
        return rank_lt_le(a.key, a.val, a.n, qk, qv)
    lt = lex_searchsorted(a.key, a.val, a.n, qk, qv, side="left")
    le = lex_searchsorted(a.key, a.val, a.n, qk, qv, side="right")
    return lt, le


def _empty_like_caps(key_dtype, capacity: int, composite: bool = False):
    sent = SENTINEL32 if key_dtype == jnp.int32 else SENTINEL
    return (jnp.full(capacity, sent, key_dtype),
            jnp.zeros(capacity, jnp.int32),
            jnp.full(capacity, SENTINEL, jnp.int64) if composite else None)


def _qcols_of(d: IndexData) -> PackedKey:
    """An index's own keys viewed as a probe batch (for rank queries)."""
    return d.key if d.lo is None else (d.key, d.lo)


def _merge_core(a: IndexData, b: IndexData, capacity: int,
                use_kernel: bool = False) -> IndexData:
    """Sorted union a ∪ b into a fresh IndexData of static ``capacity``.

    Both operands are deduped lex-sorted (the IndexData invariant); entries
    present in both appear once (a's copy wins).  capacity must be
    >= |a| + |b| in the worst case; overflowing entries would be dropped,
    so callers size it from exact live counts."""
    cap = int(capacity)
    ii = jnp.arange(a.capacity, dtype=jnp.int32)
    jj = jnp.arange(b.capacity, dtype=jnp.int32)
    a_live = ii < a.n
    b_live = jj < b.n
    lt_a, le_a = index_ranks(a, _qcols_of(b), b.val, use_kernel)  # b in a
    keep_b = b_live & ~(le_a > lt_a)
    kept_cum = jnp.cumsum(keep_b.astype(jnp.int32))
    kept_excl = kept_cum - keep_b.astype(jnp.int32)
    pos_b = jnp.where(keep_b, lt_a + kept_excl, cap)
    lt_b, _ = index_ranks(b, _qcols_of(a), a.val, use_kernel)  # a in b
    # kept-b entries strictly below a[i] = prefix of keep_b over [0, lt_b)
    below = jnp.where(lt_b > 0,
                      kept_cum[jnp.clip(lt_b - 1, 0, b.capacity - 1)], 0)
    pos_a = jnp.where(a_live, ii + below, cap)
    out_k, out_v, out_lo = _empty_like_caps(a.key.dtype, cap,
                                            a.lo is not None)
    out_k = out_k.at[pos_a].set(a.key, mode="drop") \
                 .at[pos_b].set(b.key.astype(a.key.dtype), mode="drop")
    out_v = out_v.at[pos_a].set(a.val, mode="drop") \
                 .at[pos_b].set(b.val, mode="drop")
    if out_lo is not None:
        out_lo = out_lo.at[pos_a].set(a.lo, mode="drop") \
                       .at[pos_b].set(b.lo, mode="drop")
    n = a.n.astype(jnp.int32) + keep_b.sum(dtype=jnp.int32)
    return IndexData(out_k, out_v, n, out_lo)


def _select_core(a: IndexData, b: IndexData, capacity: int, keep_in_b: bool,
                 use_kernel: bool = False) -> IndexData:
    """Compact the entries of ``a`` (not) in ``b`` into static ``capacity``:
    keep_in_b=False is a \\ b (diff), True is a ∩ b (intersect)."""
    cap = int(capacity)
    ii = jnp.arange(a.capacity, dtype=jnp.int32)
    lt, le = index_ranks(b, _qcols_of(a), a.val, use_kernel)
    in_b = le > lt
    keep = (ii < a.n) & (in_b if keep_in_b else ~in_b)
    cum = jnp.cumsum(keep.astype(jnp.int32))
    pos = jnp.where(keep, cum - 1, cap)
    out_k, out_v, out_lo = _empty_like_caps(a.key.dtype, cap,
                                            a.lo is not None)
    out_k = out_k.at[pos].set(a.key, mode="drop")
    out_v = out_v.at[pos].set(a.val, mode="drop")
    if out_lo is not None:
        out_lo = out_lo.at[pos].set(a.lo, mode="drop")
    return IndexData(out_k, out_v, keep.sum(dtype=jnp.int32), out_lo)


@functools.partial(jax.jit, static_argnames=("capacity", "use_kernel"))
def merge_index(a: IndexData, b: IndexData, capacity: int,
                use_kernel: bool = False) -> IndexData:
    """Jitted sorted union (see `_merge_core`)."""
    compilestats.record("csr.merge_index")
    return _merge_core(a, b, capacity, use_kernel)


@functools.partial(jax.jit, static_argnames=("capacity", "use_kernel"))
def diff_index(a: IndexData, b: IndexData, capacity: int,
               use_kernel: bool = False) -> IndexData:
    """Jitted sorted difference a \\ b."""
    compilestats.record("csr.diff_index")
    return _select_core(a, b, capacity, False, use_kernel)


@functools.partial(jax.jit, static_argnames=("capacity", "use_kernel"))
def intersect_index(a: IndexData, b: IndexData, capacity: int,
                    use_kernel: bool = False) -> IndexData:
    """Jitted sorted intersection a ∩ b (probe-sized: O(|a|·log|b|))."""
    compilestats.record("csr.intersect_index")
    return _select_core(a, b, capacity, True, use_kernel)


# ---------------------------------------------------------------------------
# Graph convenience: the dual-CSR edge index.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Graph:
    """A directed graph as an edge list (numpy host container)."""

    edges: np.ndarray  # [E, 2] int32 (src, dst), deduped
    num_vertices: int

    @classmethod
    def from_edges(cls, edges: np.ndarray, num_vertices: int | None = None,
                   dedup: bool = True) -> "Graph":
        edges = np.asarray(edges, np.int32).reshape(-1, 2)
        if dedup and edges.size:
            edges = np.unique(edges, axis=0)
        nv = int(num_vertices if num_vertices is not None
                 else (edges.max() + 1 if edges.size else 0))
        return cls(edges, nv)

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    def forward(self, capacity: int | None = None) -> IndexData:
        """src -> dst (out-neighbour) index."""
        return build_index(self.edges, (0,), 1, capacity)

    def reverse(self, capacity: int | None = None) -> IndexData:
        """dst -> src (in-neighbour) index."""
        return build_index(self.edges, (1,), 0, capacity)

    def undirected(self) -> "Graph":
        e = np.concatenate([self.edges, self.edges[:, ::-1]], axis=0)
        return Graph.from_edges(e, self.num_vertices)

    def degree_relabel(self) -> "Graph":
        """Symmetry-breaking preprocessing (§5.4): relabel vertices by
        (degree, id) ascending and keep edges oriented low->high id."""
        deg = np.zeros(self.num_vertices, np.int64)
        np.add.at(deg, self.edges[:, 0], 1)
        np.add.at(deg, self.edges[:, 1], 1)
        order = np.lexsort((np.arange(self.num_vertices), deg))
        rank = np.empty(self.num_vertices, np.int32)
        rank[order] = np.arange(self.num_vertices, dtype=np.int32)
        e = rank[self.edges]
        lo = np.minimum(e[:, 0], e[:, 1])
        hi = np.maximum(e[:, 0], e[:, 1])
        keep = lo != hi
        return Graph.from_edges(np.stack([lo[keep], hi[keep]], 1),
                                self.num_vertices)
