"""Sorted-array extension indices (the TPU-native ``Ext``, §2.2).

The paper requires, for each relation/bound-prefix pair, an index exposing:
  (i)   |Ext(p)|            -- count          (O(1) in the paper)
  (ii)  contents of Ext(p)  -- slice          (O(|Ext(p)|))
  (iii) e in Ext(p)         -- membership     (O(1) in the paper)

Hash tables give these on CPUs; on TPUs pointer-chasing is hostile, so we use
*sorted dual arrays*: a packed 64-bit key column (the bound prefix) and a
32-bit value column (the extension), sorted lexicographically.  Counts and
slices come from two ``searchsorted`` probes; membership is a fixed-depth
binary search over the (key,val) pairs — O(log IN) instead of O(1), the same
trade EmptyHeaded makes with its sorted set layouts.

Everything here is a pytree of jnp arrays, so indices shard with
``jax.device_put`` / ``shard_map`` like any other model state.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel keys larger than any real key (vertex ids < 2^31 - 1).
SENTINEL = np.int64(2**62)
SENTINEL32 = np.int32(2**31 - 1)

# Canonical segment length of the two-level membership kernels (one VPU lane
# row); kernels/intersect/intersect.py imports it from here.  Index
# capacities are rounded up to SEG multiples so the kernels' segment-major
# [cap/SEG, SEG] view is a free reshape (no pad/concat per probe).
SEG = 128


def round_capacity(cap: int) -> int:
    return -(-max(int(cap), 1) // SEG) * SEG


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IndexData:
    """One sorted (key, val) extension index.

    key: [N] int64, nondecreasing (packed bound-prefix values)
    val: [N] int32, nondecreasing within equal keys
    n:   [] int32, number of live entries (rest is sentinel padding)
    """

    key: jax.Array
    val: jax.Array
    n: jax.Array

    def tree_flatten(self):
        return (self.key, self.val, self.n), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.key.shape[0]


def pack_key(cols: Tuple[np.ndarray, ...] | Tuple[jax.Array, ...]):
    """Pack 1 or 2 non-negative int32 columns into an int64 key."""
    xp = jnp if isinstance(cols[0], jax.Array) else np
    if len(cols) == 1:
        return cols[0].astype(xp.int64)
    if len(cols) == 2:
        return (cols[0].astype(xp.int64) << 32) | cols[1].astype(xp.int64)
    raise NotImplementedError(
        "indices with >2 bound attributes are not needed by paper queries; "
        "extend pack_key with multi-probe search to support them")


def build_index(tuples: np.ndarray, key_pos: Tuple[int, ...], ext_pos: int,
                capacity: int | None = None) -> IndexData:
    """Build an IndexData from relation tuples [T, arity] (numpy, host).

    Projects to (key columns, ext column), dedups, sorts.  ``capacity``
    (>= live size) allows preallocating room for future deltas.
    """
    tuples = np.asarray(tuples)
    if tuples.ndim != 2:
        raise ValueError("tuples must be [T, arity]")
    key = pack_key(tuple(tuples[:, p].astype(np.int32) for p in key_pos)) \
        if key_pos else np.zeros(tuples.shape[0], np.int64)
    val = tuples[:, ext_pos].astype(np.int32)
    kv = np.unique(np.stack([key, val.astype(np.int64)], axis=1), axis=0)
    key, val = kv[:, 0], kv[:, 1].astype(np.int32)
    n = key.shape[0]
    cap = round_capacity(max(int(capacity or n), n, 1))
    # single-column keys fit int32 -> halve index bytes (perf: HBM traffic)
    narrow = len(key_pos) <= 1 and (n == 0 or key.max() < SENTINEL32)
    kdt, sent = (np.int32, SENTINEL32) if narrow else (np.int64, SENTINEL)
    out_k = np.full(cap, sent, kdt)
    out_v = np.zeros(cap, np.int32)
    out_k[:n] = key.astype(kdt)
    out_v[:n] = val
    return IndexData(jnp.asarray(out_k), jnp.asarray(out_v),
                     jnp.asarray(n, jnp.int32))


# Fibonacci-style multiplicative mix shared with the distributed layer:
# owner_of / shard_of MUST agree so host-built shards answer device routing.
SHARD_MIX = 0x9E3779B97F4A7C15


def shard_of(key: np.ndarray, num_shards: int) -> np.ndarray:
    """Hash-partition owner of each packed key, [N] int32 in [0, num_shards)."""
    h = (key.astype(np.uint64) * np.uint64(SHARD_MIX)) >> np.uint64(33)
    return (h % np.uint64(max(num_shards, 1))).astype(np.int32)


def _pow2_capacity(n: int) -> int:
    """SEG-aligned power-of-two capacity >= n (stable shapes across deltas)."""
    return round_capacity(1 << max(int(n) - 1, 0).bit_length())


def build_sharded_index(tuples: np.ndarray, key_pos: Tuple[int, ...],
                        ext_pos: int, num_shards: int,
                        capacity: int | None = None) -> IndexData:
    """Hash-partition one extension index over ``num_shards`` workers.

    Returns an IndexData whose arrays carry a leading [w] worker axis
    (key/val: [w, cap]; n: [w]) ready to shard over a mesh axis.  Every
    (key, val) pair lands on exactly one worker — ``shard_of(key, w)`` —
    which is the paper's cluster-memory-linearity property (§3.2): the sum
    of live entries over workers equals the unsharded index size.

    Per-shard capacity is uniform (stacking needs one shape) and rounded to
    a SEG-aligned power of two of the largest shard, so shapes stay stable
    across update batches and the jit cache stays warm.  ``capacity`` is a
    per-shard floor.  Key narrowness (int32 vs int64) is decided globally so
    every shard row has one dtype and one sentinel.
    """
    tuples = np.asarray(tuples)
    if tuples.ndim != 2:
        raise ValueError("tuples must be [T, arity]")
    w = max(int(num_shards), 1)
    key = pack_key(tuple(tuples[:, p].astype(np.int32) for p in key_pos)) \
        if key_pos else np.zeros(tuples.shape[0], np.int64)
    val = tuples[:, ext_pos].astype(np.int32)
    kv = np.unique(np.stack([key, val.astype(np.int64)], axis=1), axis=0)
    key, val = kv[:, 0], kv[:, 1].astype(np.int32)
    own = shard_of(key, w)
    counts = np.bincount(own, minlength=w).astype(np.int64)
    cmax = int(counts.max()) if counts.size else 0
    cap = max(_pow2_capacity(cmax), round_capacity(int(capacity or 1)))
    narrow = len(key_pos) <= 1 and (key.size == 0 or key.max() < SENTINEL32)
    kdt, sent = (np.int32, SENTINEL32) if narrow else (np.int64, SENTINEL)
    out_k = np.full((w, cap), sent, kdt)
    out_v = np.zeros((w, cap), np.int32)
    # kv is lexsorted by (key, val); a stable sort by owner keeps each
    # shard's rows sorted, which is the IndexData invariant.
    order = np.argsort(own, kind="stable")
    sk, sv = key[order].astype(kdt), val[order]
    offs = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    for i in range(w):
        lo, hi = offs[i], offs[i + 1]
        out_k[i, :hi - lo] = sk[lo:hi]
        out_v[i, :hi - lo] = sv[lo:hi]
    return IndexData(jnp.asarray(out_k), jnp.asarray(out_v),
                     jnp.asarray(counts.astype(np.int32)))


def empty_index(capacity: int = 1, narrow: bool = True) -> IndexData:
    cap = round_capacity(capacity)
    kdt, sent = (jnp.int32, SENTINEL32) if narrow else (jnp.int64, SENTINEL)
    return IndexData(jnp.full(cap, sent, kdt),
                     jnp.zeros(cap, jnp.int32),
                     jnp.asarray(0, jnp.int32))


# ---------------------------------------------------------------------------
# Queries (jnp, vectorized over a batch of probes).
# ---------------------------------------------------------------------------

def index_range(idx: IndexData, qkey: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(start, count) of the extension list for each packed key [B]."""
    start = jnp.searchsorted(idx.key, qkey, side="left")
    end = jnp.searchsorted(idx.key, qkey, side="right")
    return start.astype(jnp.int32), (end - start).astype(jnp.int32)


def index_count(idx: IndexData, qkey: jax.Array) -> jax.Array:
    return index_range(idx, qkey)[1]


def index_kth(idx: IndexData, start: jax.Array, k: jax.Array) -> jax.Array:
    """k-th extension given the range start (no bounds check: caller masks)."""
    pos = jnp.clip(start + k, 0, idx.capacity - 1)
    return idx.val[pos]


def lex_searchsorted(key: jax.Array, val: jax.Array, n: jax.Array,
                     qk: jax.Array, qv: jax.Array) -> jax.Array:
    """Lower bound of (qk,qv) in the lexicographically sorted (key,val) pairs.

    Fixed-depth binary search (depth = ceil(log2 capacity)), vectorized over
    the query batch; this is the pure-jnp oracle mirrored by the Pallas
    ``intersect`` kernel.
    """
    cap = key.shape[0]
    # +1: an interval of length 1 still needs one comparison to collapse
    depth = max(int(np.ceil(np.log2(max(cap, 2)))), 1) + 1
    lo = jnp.zeros(qk.shape, jnp.int32)
    hi = jnp.broadcast_to(jnp.minimum(jnp.int32(cap), n.astype(jnp.int32)),
                          qk.shape)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        mk = key[jnp.clip(mid, 0, cap - 1)]
        mv = val[jnp.clip(mid, 0, cap - 1)]
        less = (mk < qk) | ((mk == qk) & (mv < qv))
        lo = jnp.where(less & (lo < hi), mid + 1, lo)
        hi = jnp.where(~less & (lo < hi), mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, depth, body, (lo, hi))
    return lo


def index_member(idx: IndexData, qkey: jax.Array, qval: jax.Array
                 ) -> jax.Array:
    """Membership (qkey, qval) in the index, [B] bool — the pure-jnp oracle.

    Kernel routing happens one level up: ``VersionedIndex.signed_member``
    fuses all regions into one Pallas launch; this stays the reference path.
    """
    pos = lex_searchsorted(idx.key, idx.val, idx.n, qkey,
                           qval.astype(jnp.int32))
    pos_c = jnp.clip(pos, 0, idx.capacity - 1)
    hit = (idx.key[pos_c] == qkey) & (idx.val[pos_c] == qval.astype(jnp.int32))
    return hit & (pos < idx.n)


# ---------------------------------------------------------------------------
# Graph convenience: the dual-CSR edge index.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Graph:
    """A directed graph as an edge list (numpy host container)."""

    edges: np.ndarray  # [E, 2] int32 (src, dst), deduped
    num_vertices: int

    @classmethod
    def from_edges(cls, edges: np.ndarray, num_vertices: int | None = None,
                   dedup: bool = True) -> "Graph":
        edges = np.asarray(edges, np.int32).reshape(-1, 2)
        if dedup and edges.size:
            edges = np.unique(edges, axis=0)
        nv = int(num_vertices if num_vertices is not None
                 else (edges.max() + 1 if edges.size else 0))
        return cls(edges, nv)

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    def forward(self, capacity: int | None = None) -> IndexData:
        """src -> dst (out-neighbour) index."""
        return build_index(self.edges, (0,), 1, capacity)

    def reverse(self, capacity: int | None = None) -> IndexData:
        """dst -> src (in-neighbour) index."""
        return build_index(self.edges, (1,), 0, capacity)

    def undirected(self) -> "Graph":
        e = np.concatenate([self.edges, self.edges[:, ::-1]], axis=0)
        return Graph.from_edges(e, self.num_vertices)

    def degree_relabel(self) -> "Graph":
        """Symmetry-breaking preprocessing (§5.4): relabel vertices by
        (degree, id) ascending and keep edges oriented low->high id."""
        deg = np.zeros(self.num_vertices, np.int64)
        np.add.at(deg, self.edges[:, 0], 1)
        np.add.at(deg, self.edges[:, 1], 1)
        order = np.lexsort((np.arange(self.num_vertices), deg))
        rank = np.empty(self.num_vertices, np.int32)
        rank[order] = np.arange(self.num_vertices, dtype=np.int32)
        e = rank[self.edges]
        lo = np.minimum(e[:, 0], e[:, 1])
        hi = np.maximum(e[:, 0], e[:, 1])
        keep = lo != hi
        return Graph.from_edges(np.stack([lo[keep], hi[keep]], 1),
                                self.num_vertices)
