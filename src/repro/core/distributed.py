"""Distributed BiGJoin over a device mesh via shard_map (§3.2 / §3.4).

Workers are the devices of one mesh axis.  Every extension index is
hash-partitioned by its packed key (``owner_of``), so the cluster-wide memory
is O(IN) — each edge is stored by exactly one worker per direction, the
paper's linear-memory property.

Lookups are *request/response*: a worker keeps its popped prefixes and sends
(key) / (key,k) / (key,val) requests to the owners — precisely the three
distributed index services of BiGJoin-S (§3.4.1):

    count     C(p)          key        -> |Ext(p)|
    resolve   Ext-Res(p,k)  (key,k)    -> k-th extension
    member    Ext(p·e)      (key,val)  -> membership / deletion bits

Requests travel through a fixed-capacity bucketed ``all_to_all``
(``route_capacity`` slots per peer pair).  Overflowing requests are *not*
dropped: the affected prefix simply does not advance its rem-ext cursor this
round and is retried — backpressure instead of failure, the static-shape
analogue of the paper's Faucet-style flow control [33].  With BiGJoin-S
aggregation (``aggregate=True``, request dedup per key) the balls-into-bins
bound of Thm 3.4 makes overflow improbable at capacity O(B'/w · polylog).

Outputs stay on the producing worker (the paper assumes outputs leave the
cluster); counts/counters are psum-reduced at the end.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat, faults
from repro.core import compilestats, csr
from repro.core import delta as _delta
from repro.core.bigjoin import BigJoinConfig
from repro.core.dataflow_index import VersionedIndex
from repro.core.plan import Plan
from repro.errors import (CapacityOverflow, ESCALATES_BATCH, ESCALATES_OUT,
                          ESCALATES_ROUTE, OVF_OUT, OVF_QUEUE, OVF_ROUTE,
                          OVF_SEED, _KIND_BITS)

AXIS = "workers"


# ---------------------------------------------------------------------------
# hashing / partitioning
# ---------------------------------------------------------------------------

def owner_of_np(key, w: int) -> np.ndarray:
    return csr.shard_of(key, w)


def owner_of(key, w: int) -> jax.Array:
    """Worker owning each packed key — composite (hi, lo) pairs fold into
    one routing word first (``csr.combine_key``, shared with the host-side
    shard builds so routing and placement can never disagree)."""
    if isinstance(key, tuple):
        key = csr.combine_key(*key)
    h = (key.astype(jnp.uint64) * jnp.uint64(csr.SHARD_MIX)) >> jnp.uint64(33)
    return (h % jnp.uint64(w)).astype(jnp.int32)


# region-name subsets backing each logical version (delta.py / §4.3):
# pos regions contribute extensions, neg regions subtract membership.
VERSION_REGIONS = {
    "static": (("base",), ()),
    "old": (("base", "cins"), ("cdel",)),
    "new": (("base", "cins", "uins"), ("cdel", "udel")),
}


def partition_indices(plan: Plan, relations: Dict[str, np.ndarray],
                      w: int, region_tuples: Optional[Dict] = None
                      ) -> Dict[str, VersionedIndex]:
    """Hash-partition every index the plan needs over ``w`` workers.

    Static versions partition ``relations[rel]`` directly.  Delta versions
    ("old"/"new") partition each multi-version REGION of the projection:
    ``region_tuples[(rel, key_pos, ext_pos)]`` must map region names
    (base/cins/cdel/uins/udel) to host tuple arrays — exactly the host truth
    a :class:`repro.core.delta._Regions` maintains.  Every region entry is
    owned by exactly one worker per projection, so cluster memory stays
    O(IN + delta): sharding never replicates, it only splits.

    Returns indices whose arrays carry a leading [w] axis (to be sharded
    over the worker mesh axis).
    """
    out: Dict[str, VersionedIndex] = {}
    for index_id, rel, key_pos, ext_pos, version in plan.index_ids():
        if version == "static":
            base = csr.build_sharded_index(np.asarray(relations[rel]),
                                           key_pos, ext_pos, w)
            out[index_id] = VersionedIndex((base,), ())
            continue
        if region_tuples is None:
            raise ValueError(
                f"plan index {index_id} reads version {version!r}: pass "
                "region_tuples with base/cins/cdel/uins/udel host arrays "
                "(or drive it through DistDeltaBigJoin)")
        regions = region_tuples[(rel, key_pos, ext_pos)]
        pos_names, neg_names = VERSION_REGIONS[version]
        arity = max(max(key_pos, default=0), ext_pos) + 1

        def shard(name):
            rows = np.asarray(regions[name])
            if rows.ndim != 2:  # flat legacy arrays: minimal covering arity
                rows = rows.reshape(-1, arity)
            return csr.build_sharded_index(rows, key_pos, ext_pos, w)

        out[index_id] = VersionedIndex(
            tuple(shard(nm) for nm in pos_names),
            tuple(shard(nm) for nm in neg_names))
    return out


def _local(idx: VersionedIndex) -> VersionedIndex:
    """Strip the leading worker axis inside shard_map."""
    return idx.worker_shard(0)


# ---------------------------------------------------------------------------
# bounded-capacity request/response exchange
# ---------------------------------------------------------------------------

def remote_service(queries, dest: jax.Array, valid: jax.Array, reply_fn,
                   w: int, cap: int, axis: str = AXIS):
    """Route ``queries`` (pytree of [B,...] arrays) to ``dest`` workers, apply
    ``reply_fn`` (pytree of [N,...] -> pytree of [N,...]) at the owner, and
    return (replies [B,...], ok [B]).

    ok=False rows overflowed the per-peer capacity and received no reply.
    """
    B = dest.shape[0]
    dest_eff = jnp.where(valid, dest, w)
    order = jnp.argsort(dest_eff, stable=True).astype(jnp.int32)
    sdest = dest_eff[order]
    first = jnp.searchsorted(sdest, sdest, side="left").astype(jnp.int32)
    slot = jnp.arange(B, dtype=jnp.int32) - first
    ok_sorted = (sdest < w) & (slot < cap)
    flat = jnp.where(ok_sorted, sdest * cap + slot, w * cap)

    def scatter(x):
        buf = jnp.zeros((w * cap,) + x.shape[1:], x.dtype)
        return buf.at[flat].set(x[order], mode="drop")

    send = jax.tree.map(scatter, queries)
    sent_mask = jnp.zeros(w * cap, jnp.int32).at[flat].set(
        jnp.ones(B, jnp.int32), mode="drop")

    def a2a(x):
        return jax.lax.all_to_all(
            x.reshape((w, cap) + x.shape[1:]), axis, 0, 0, tiled=False
        ).reshape((w * cap,) + x.shape[1:])

    recv = jax.tree.map(a2a, send)
    recv_mask = a2a(sent_mask) > 0
    replies_at_owner = reply_fn(recv, recv_mask)
    back = jax.tree.map(a2a, replies_at_owner)

    # gather replies for my rows: row i sits at (dest[i], slot_of_row[i])
    slot_of_row = jnp.zeros(B, jnp.int32).at[order].set(slot)
    ok = (jnp.zeros(B, bool).at[order].set(ok_sorted)) & valid
    gidx = jnp.clip(dest * cap + slot_of_row, 0, w * cap - 1)
    replies = jax.tree.map(lambda x: x[gidx], back)
    recv_load = recv_mask.sum().astype(jnp.int64)  # requests I served
    return replies, ok, recv_load


def dedup_requests(key, valid: jax.Array):
    """BiGJoin-S aggregation (§3.4.2): collapse duplicate request keys.

    ``key`` is one array or a tuple of arrays (composite keys dedup on the
    exact word tuple — never on a lossy hash, which could merge distinct
    keys).  Returns (rep_idx [B] -> representative row, is_rep [B]).  Only
    representative rows are routed; replies are read through rep_idx.
    """
    keys = key if isinstance(key, tuple) else (key,)
    B = keys[0].shape[0]
    skeys = tuple(
        jnp.where(valid, k, jnp.asarray(np.iinfo(k.dtype.name).max, k.dtype))
        for k in keys)
    if len(skeys) == 1:
        order = jnp.argsort(skeys[0], stable=True).astype(jnp.int32)
        sk = skeys[0][order]
        first = jnp.searchsorted(sk, sk, side="left").astype(jnp.int32)
    else:
        # lexsort: LAST key is primary, so feed the tuple reversed
        order = jnp.lexsort(skeys[::-1]).astype(jnp.int32)
        sk = tuple(k[order] for k in skeys)
        diff = jnp.zeros(B - 1, bool) if B > 1 else jnp.zeros(0, bool)
        for c in sk:
            diff = diff | (c[1:] != c[:-1])
        starts = jnp.concatenate([jnp.ones(1, bool), diff])
        # index of each sorted row's group head: running max of start marks
        first = jax.lax.cummax(
            jnp.where(starts, jnp.arange(B, dtype=jnp.int32), 0))
    rep_sorted = order[first]  # representative original row per sorted pos
    rep_idx = jnp.zeros(B, jnp.int32).at[order].set(rep_sorted)
    is_rep = jnp.zeros(B, bool).at[rep_idx].set(True) & valid
    return rep_idx, is_rep


# ---------------------------------------------------------------------------
# distributed dataflow step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistConfig:
    base: BigJoinConfig
    num_workers: int
    route_capacity: int  # per peer-pair slots; <= batch
    aggregate: bool = True  # BiGJoin-S request dedup (§3.4.2)
    balance: bool = False  # BiGJoin-S Balance operator (§3.4.2)
    max_steps: int = 1 << 30
    axis: object = AXIS  # mesh axis name (or tuple of names) for collectives


def _remote_count(idx_local: VersionedIndex, qkey, dest, valid, w, cap,
                  aggregate, axis=AXIS):
    def reply(q, mask):
        return idx_local.count(q)

    if aggregate:
        rep_idx, is_rep = dedup_requests(qkey, valid)
        (cnt,), ok, load = remote_service(
            (qkey,), dest, is_rep, lambda q, m: (reply(q[0], m),), w, cap,
            axis)
        return cnt[rep_idx], ok[rep_idx] | ~valid, load
    (cnt,), ok, load = remote_service(
        (qkey,), dest, valid, lambda q, m: (reply(q[0], m),), w, cap, axis)
    return cnt, ok | ~valid, load


def _remote_resolve(idx_local: VersionedIndex, qkey, k, dest, valid, w,
                    cap, axis=AXIS):
    def reply(q, mask):
        qk, kk = q
        starts, counts = idx_local.ranges(qk)
        return (idx_local.gather(starts, counts, kk),)

    (val,), ok, load = remote_service((qkey, k), dest, valid, reply, w,
                                      cap, axis)
    return val, ok | ~valid, load


def _remote_member(idx_local: VersionedIndex, qkey, qval, dest, valid, w,
                   cap, aggregate, axis=AXIS, use_kernel=False,
                   interpret=None):
    def reply(q, mask):
        qk, qv = q
        # one fused pass over every region: membership and deletion bits
        # come from a single kernel launch (or one jnp reduction)
        mem, dele = idx_local.signed_member(qk, qv, use_kernel, interpret)
        return (mem.astype(jnp.int32) | (dele.astype(jnp.int32) << 1),)

    # dedup on the exact (key, val) tuple: packed into one word for narrow
    # int32 keys, an explicit word tuple for composite keys; wide int64
    # single-word keys cannot widen losslessly, so they skip aggregation
    if isinstance(qkey, tuple):
        pair = qkey + (qval.astype(jnp.int64),)
    elif qkey.dtype == jnp.int32:
        pair = (qkey.astype(jnp.int64) << 32) | qval.astype(jnp.int64)
    else:
        pair = None
    if aggregate and pair is not None:
        rep_idx, is_rep = dedup_requests(pair, valid)
        (bits,), ok, load = remote_service((qkey, qval), dest, is_rep, reply,
                                           w, cap, axis)
        bits, ok = bits[rep_idx], ok[rep_idx]
    else:
        (bits,), ok, load = remote_service((qkey, qval), dest, valid, reply,
                                           w, cap, axis)
    return (bits & 1) > 0, (bits & 2) > 0, ok | ~valid, load


# ---------------------------------------------------------------------------
# the distributed level branch (mirrors bigjoin._level_branch with remote
# lookups + rem-ext deferral backpressure)
# ---------------------------------------------------------------------------

def _build_dist_level(plan: Plan, dcfg: DistConfig, li: int):
    from repro.core.bigjoin import (BigJoinState, LevelQueue, _binding_key,
                                    _compact, _pack_cols, _scatter_append)
    lv = plan.levels[li]
    w, cap, B = dcfg.num_workers, dcfg.route_capacity, dcfg.base.batch
    is_last = li == len(plan.levels) - 1
    new_bound = lv.bound_attrs + (lv.ext_attr,)
    INF = jnp.int32(np.iinfo(np.int32).max)

    def branch(state, indices):
        qu = state.queues[li]
        W = min(B, qu.prefix.shape[0])
        wprefix, wk, wweight = qu.prefix[:W], qu.k[:W], qu.weight[:W]
        valid = jnp.arange(W, dtype=jnp.int32) < qu.size

        # ---- remote count minimization ------------------------------------
        qks, cnts, count_ok = [], [], valid
        recv_load = state.recv_load
        for b in lv.bindings:
            idx = indices[b.index_id]
            qk = _binding_key(wprefix, lv.bound_attrs, b.key_attrs, idx)
            cnt, ok, load = _remote_count(idx, qk, owner_of(qk, w), valid, w,
                                          cap, dcfg.aggregate, dcfg.axis)
            qks.append(qk)
            cnts.append(cnt)
            count_ok = count_ok & ok
            recv_load = recv_load + load
        tot = jnp.stack(cnts, -1)
        min_i = jnp.argmin(tot, -1).astype(jnp.int32)
        min_c = tot.min(-1)

        remaining_true = jnp.maximum(min_c - wk, 0)
        remaining = jnp.where(valid & count_ok, remaining_true, 0)
        acum = jnp.cumsum(remaining, dtype=jnp.int32)
        allowed = jnp.clip(B - (acum - remaining), 0, remaining
                           ).astype(jnp.int32)

        aacum = jnp.cumsum(allowed, dtype=jnp.int32)
        t = jnp.arange(B, dtype=jnp.int32)
        pvalid = t < aacum[-1]
        r = jnp.clip(jnp.searchsorted(aacum, t, side="right"), 0, W - 1)
        r = r.astype(jnp.int32)
        k_off = t - (aacum[r] - allowed[r]) + wk[r]

        # ---- remote extension resolution (Ext-Res lookups) ----------------
        cand = jnp.zeros(B, jnp.int32)
        incomplete = jnp.zeros(B, bool)
        for bi, b in enumerate(lv.bindings):
            idx = indices[b.index_id]
            qk_r = qks[bi][r]
            mask = pvalid & (min_i[r] == bi)
            val, ok, load = _remote_resolve(idx, qk_r, k_off,
                                            owner_of(qk_r, w), mask, w, cap,
                                            dcfg.axis)
            cand = jnp.where(mask, val, cand)
            incomplete = incomplete | (mask & ~ok)
            recv_load = recv_load + load
        new_prefix = jnp.concatenate([wprefix[r], cand[:, None]], axis=1)
        weight = wweight[r]
        alive = pvalid
        n_isect = jnp.asarray(0, jnp.int64)

        # ---- remote intersections ------------------------------------------
        for bi, b in enumerate(lv.bindings):
            idx = indices[b.index_id]
            pos = [list(new_bound).index(a) for a in b.key_attrs]
            qk = _pack_cols(new_prefix, pos, idx.pos[0].key.dtype)
            mem, dele, ok, load = _remote_member(
                idx, qk, cand, owner_of(qk, w), pvalid, w, cap,
                dcfg.aggregate, dcfg.axis, dcfg.base.use_kernel,
                dcfg.base.kernel_interpret)
            recv_load = recv_load + load
            is_min = min_i[r] == bi
            keep = jnp.where(is_min, ~dele, mem)
            n_isect = n_isect + (alive & ~is_min).sum().astype(jnp.int64)
            alive = alive & (keep | ~ok)  # unanswered rows defer, not die
            incomplete = incomplete | (pvalid & ~ok)
        for f in lv.filters:
            lo = new_prefix[:, list(new_bound).index(f.lo)]
            hi = new_prefix[:, list(new_bound).index(f.hi)]
            alive = alive & (lo < hi)

        # ---- rem-ext deferral: advance each prefix past its last complete
        # contiguous proposal only; later survivors are retried next round ---
        inc_off = jnp.where(incomplete, k_off, INF)
        first_inc = jax.ops.segment_min(inc_off, r, num_segments=W)
        first_inc = jnp.minimum(first_inc, INF)
        advance = jnp.clip(jnp.minimum(first_inc, wk + allowed) - wk,
                           0, allowed)
        consumed = valid & count_ok & (wk + advance >= min_c)
        alive = alive & (k_off < first_inc[r])
        n_proposed = (pvalid & (k_off < first_inc[r])).sum()

        # ---- retire / push (identical to the single-host branch) ----------
        kfull = qu.k.at[:W].set(wk + advance)
        live_row = jnp.arange(qu.prefix.shape[0], dtype=jnp.int32) < qu.size
        keep_rows = live_row & ~jnp.pad(consumed,
                                        (0, qu.prefix.shape[0] - W))
        (pfx, kk, ww), nsz = _compact([qu.prefix, kfull, qu.weight],
                                      keep_rows)
        queues = list(state.queues)
        queues[li] = LevelQueue(pfx, kk, ww, nsz)

        out_buf, out_weight = state.out_buf, state.out_weight
        out_n, out_count = state.out_n, state.out_count
        overflow = state.overflow
        if is_last:
            out_count = out_count + (weight * alive).sum().astype(jnp.int64)
            if dcfg.base.mode == "collect":
                perm = np.argsort(np.asarray(plan.attr_order))
                out_buf, n_new, ovf1 = _scatter_append(
                    out_buf, out_n, new_prefix[:, perm], alive)
                out_weight, _, _ = _scatter_append(
                    out_weight, out_n, weight, alive)
                out_n = jnp.minimum(out_n + n_new,
                                    jnp.int32(out_buf.shape[0]))
                overflow = overflow | jnp.where(ovf1, OVF_OUT, 0)
        else:
            nxt = queues[li + 1]
            npfx, n_new, ovf1 = _scatter_append(
                nxt.prefix, nxt.size, new_prefix, alive)
            nk, _, _ = _scatter_append(
                nxt.k, nxt.size, jnp.zeros(B, jnp.int32), alive)
            nw, _, _ = _scatter_append(nxt.weight, nxt.size, weight, alive)
            queues[li + 1] = LevelQueue(
                npfx, nk, nw,
                jnp.minimum(nxt.size + n_new,
                            jnp.int32(nxt.prefix.shape[0])))
            overflow = overflow | jnp.where(ovf1, OVF_QUEUE, 0)

        return BigJoinState(
            tuple(queues), out_buf, out_weight, out_n, out_count, overflow,
            state.proposals + n_proposed.astype(jnp.int64),
            state.intersections + n_isect, recv_load)

    return branch


def build_dist_step(plan: Plan, dcfg: DistConfig):
    """Step on (BigJoinState, piece_queues).  Lock-step level choice: workers
    must agree (they all participate in the collectives), so the globally
    deepest non-empty queue is chosen via psum'd sizes."""
    if dcfg.balance:
        from repro.core.balance import build_balanced_step
        return build_balanced_step(plan, dcfg)

    branches = [_build_dist_level(plan, dcfg, li)
                for li in range(len(plan.levels))]

    def step(carry, indices):
        state, pieces = carry
        sizes = jnp.stack([q.size for q in state.queues])
        gsizes = jax.lax.psum(sizes, dcfg.axis)
        nz = gsizes > 0
        deepest = (len(branches) - 1
                   - jnp.argmax(nz[::-1]).astype(jnp.int32))
        deepest = jnp.clip(deepest, 0, len(branches) - 1)
        return jax.lax.switch(deepest, branches, state, indices), pieces

    return step


# ---------------------------------------------------------------------------
# whole-join program: shard_map( seed -> while(step) -> psum(outputs) )
# ---------------------------------------------------------------------------

def build_per_worker(plan: Plan, dcfg: DistConfig):
    """The SPMD body: fn(indices, seed [1,S,2], seed_n [1], seed_w [1,S])
    run under shard_map.  ``seed_w`` carries signed seed weights (+1/-1), so
    the same program serves static joins (all ones) and Delta-BiGJoin's
    signed dR seeds.  Exposed separately so the multi-pod dry-run can lower
    it on arbitrary meshes (launch/dryrun.py)."""
    from repro.core.bigjoin import make_state
    from repro.core.bigjoin import _scatter_append, _binding_key
    step = build_dist_step(plan, dcfg)
    w, cap = dcfg.num_workers, dcfg.route_capacity
    collect = dcfg.base.mode == "collect"

    def per_worker(indices, seed, seed_n, seed_w):
        compilestats.record("distributed.program")
        seed, seed_n, seed_w = seed[0], seed_n[0], seed_w[0]
        local = {k: _local(v) for k, v in indices.items()}
        state = make_state(plan, dcfg.base, seed_capacity=seed.shape[0])

        # seed enqueue with remote seed filters (P_w prefixes: width 2 for
        # projection-seeded plans, the seed atom's arity for n-ary deltas)
        alive = jnp.arange(seed.shape[0], dtype=jnp.int32) < seed_n
        bound = tuple(plan.attr_order[:plan.seed_width])
        route_ovf = jnp.asarray(0, jnp.int32)
        for b in plan.seed_filters:
            idx = local[b.index_id]
            qk = _binding_key(seed, bound, b.key_attrs, idx)
            qv = seed[:, bound.index(b.ext_attr)]
            mem, _, ok, _ld = _remote_member(
                idx, qk, qv, owner_of(qk, w), alive, w,
                max(cap, seed.shape[0] // max(w // 2, 1) + 1),
                dcfg.aggregate, dcfg.axis, dcfg.base.use_kernel,
                dcfg.base.kernel_interpret)
            # a seed whose route slot overflowed got NO reply; dropping it
            # would silently undercount, so flag OVF_ROUTE and escalate
            route_ovf = route_ovf | jnp.where(
                (alive & ~ok).any(), OVF_ROUTE, 0)
            alive = alive & mem & ok
        for f in plan.seed_ineq:
            alive = alive & (seed[:, bound.index(f.lo)]
                             < seed[:, bound.index(f.hi)])
        state = dataclasses.replace(state,
                                    overflow=state.overflow | route_ovf)
        if not plan.levels:
            # the seed covers every attribute (single-atom delta plans):
            # filtered seeds ARE the outputs; nothing to drain
            wts = seed_w.astype(jnp.int32)
            out_count = state.out_count + (wts * alive).sum().astype(
                jnp.int64)
            out_buf, out_weight = state.out_buf, state.out_weight
            out_n, ovf0 = state.out_n, state.overflow
            if collect:
                perm = np.argsort(np.asarray(plan.attr_order))
                out_buf, n_new, ovf = _scatter_append(
                    out_buf, out_n, seed[:, perm], alive)
                out_weight, _, _ = _scatter_append(
                    out_weight, out_n, wts, alive)
                out_n = jnp.minimum(out_n + n_new,
                                    jnp.int32(out_buf.shape[0]))
                ovf0 = ovf0 | jnp.where(ovf, OVF_OUT, 0)
            state = dataclasses.replace(
                state, out_buf=out_buf, out_weight=out_weight, out_n=out_n,
                out_count=out_count, overflow=ovf0)
            steps = jnp.asarray(0, jnp.int32)
        else:
            q0 = state.queues[0]
            npfx, n_new, ovf = _scatter_append(q0.prefix, q0.size, seed,
                                               alive)
            nk, _, _ = _scatter_append(
                q0.k, q0.size, jnp.zeros(seed.shape[0], jnp.int32), alive)
            nw, _, _ = _scatter_append(
                q0.weight, q0.size, seed_w.astype(jnp.int32), alive)
            from repro.core.bigjoin import LevelQueue
            queues = list(state.queues)
            queues[0] = LevelQueue(npfx, nk, nw, q0.size + n_new)
            state = dataclasses.replace(
                state, queues=tuple(queues),
                overflow=state.overflow | jnp.where(ovf, OVF_SEED, 0))
            if dcfg.balance:
                from repro.core.balance import make_piece_queues
                pieces = make_piece_queues(plan, dcfg)
            else:
                pieces = ()

            def total_active(carry_state):
                st, pcs = carry_state
                sizes = jnp.stack([q.size for q in st.queues]).sum()
                if pcs:
                    sizes = sizes + jnp.stack([p.size for p in pcs]).sum()
                return jax.lax.psum(sizes, dcfg.axis) > 0

            def cond(carry):
                _, active, it = carry
                return active & (it < dcfg.max_steps)

            def body(carry):
                st, _, it = carry
                st = step(st, local)
                return st, total_active(st), it + 1

            carry0 = (state, pieces)
            (state, pieces), _, steps = jax.lax.while_loop(
                cond, body, (carry0, total_active(carry0),
                             jnp.asarray(0, jnp.int32)))

        count = jax.lax.psum(state.out_count, dcfg.axis)
        props = jax.lax.psum(state.proposals, dcfg.axis)
        isect = jax.lax.psum(state.intersections, dcfg.axis)
        # psum per BIT so distinct workers' overflow kinds OR (not add)
        nbits = len(_KIND_BITS)
        shifts = jnp.arange(nbits, dtype=jnp.int32)
        bits = jax.lax.psum((state.overflow >> shifts) & 1, dcfg.axis)
        ovf = jnp.where(bits > 0, jnp.int32(1) << shifts, 0
                        ).sum().astype(jnp.int32)
        max_load = jax.lax.pmax(state.recv_load, dcfg.axis)
        sum_load = jax.lax.psum(state.recv_load, dcfg.axis)
        outs = (count, props, isect, steps, ovf, max_load, sum_load)
        if collect:
            outs = outs + (state.out_buf[None], state.out_weight[None],
                           state.out_n[None])
        return outs

    return per_worker


class DistributedProgram:
    """One whole-join shard_map program: jitted fn(indices, seed [w,S,width],
    seed_n [w], seed_w [w,S]) -> (count, proposals, intersections, steps,
    overflow, max_load, sum_load [, out_buf, out_weight, out_n]).

    The shard_map'd callable is built ONCE and reused: jax.jit caches on
    callable identity, so repeated epochs with stable shapes (the delta
    engine's ratcheted pow2 regions and pinned seed chunks) hit the compile
    cache instead of re-lowering every update batch.  :meth:`warm`
    AOT-compiles the program against ShapeDtypeStruct prototypes
    (``RegionStore.indices_sds_for``) so even the FIRST epoch — and every
    prewarmed capacity-rung crossing — skips XLA entirely (DESIGN.md §8).
    """

    def __init__(self, plan: Plan, dcfg: DistConfig, mesh: Mesh):
        self._per_worker = build_per_worker(plan, dcfg)
        self._mesh = mesh
        self._ax = dcfg.axis
        self.w = dcfg.num_workers
        out_specs = (P(), P(), P(), P(), P(), P(), P())
        if dcfg.base.mode == "collect":
            ax = dcfg.axis
            out_specs = out_specs + (P(ax), P(ax), P(ax))
        self._out_specs = out_specs
        # in_specs must mirror the indices pytree: build per structure
        # (stable per plan, so the jitted wrapper is reused)
        self._cache = {}

    def _jitted(self, treedef):
        f = self._cache.get(treedef)
        if f is None:
            ax = self._ax
            specs = (jax.tree.unflatten(
                treedef, [P(ax)] * treedef.num_leaves),
                P(ax), P(ax), P(ax))
            f = jax.jit(compat.shard_map(
                self._per_worker, mesh=self._mesh, in_specs=specs,
                out_specs=self._out_specs, check_vma=False))
            self._cache[treedef] = f
        return f

    def __call__(self, indices, seed, seed_n, seed_w):
        return self._jitted(jax.tree.structure(indices))(
            indices, seed, seed_n, seed_w)

    def warm(self, indices_sds, chunk: int, width: int) -> None:
        """AOT-compile for per-worker seed chunks of ``chunk`` rows.

        ``indices_sds`` is the ShapeDtypeStruct mirror of the runtime
        indices pytree.  The program runs ONCE on zero-filled inputs (all
        seed counts 0, so the epoch loop body is empty) because only a
        real call lands the executable in the jit dispatch cache
        ``__call__`` reads — ``lower().compile()`` would warm the trace
        cache but leave the first streaming call paying the XLA compile
        (see ``delta._warm_call``)."""
        S = jax.ShapeDtypeStruct
        w = self.w
        _delta._warm_call(
            self._jitted(jax.tree.structure(indices_sds)),
            indices_sds, S((w, int(chunk), int(width)), jnp.int32),
            S((w,), jnp.int32), S((w, int(chunk)), jnp.int32))


def build_distributed_program(plan: Plan, dcfg: DistConfig, mesh: Mesh
                              ) -> DistributedProgram:
    """Build one :class:`DistributedProgram` (kept as the stable public
    constructor — callers treat the result as a callable)."""
    return DistributedProgram(plan, dcfg, mesh)


# ---------------------------------------------------------------------------
# compiled-program cache: one shard_map program per (plan, config, mesh)
# ---------------------------------------------------------------------------

_PROGRAM_CACHE: Dict[Tuple[Plan, "DistConfig", Mesh], object] = {}
_PROGRAM_BUILDS = 0  # monotonic build counter (cache-hit assertions in tests)


def get_distributed_program(plan: Plan, dcfg: DistConfig, mesh: Mesh):
    """The process-wide compiled-program cache.  Plans, configs and meshes
    all hash structurally, so every engine/session asking for the same
    (plan, config, mesh) triple shares ONE shard_map program — and with the
    pow2-padded region/seed shapes, one XLA executable."""
    global _PROGRAM_BUILDS
    key = (plan, dcfg, mesh)
    prog = _PROGRAM_CACHE.get(key)
    if prog is None:
        _PROGRAM_BUILDS += 1
        prog = build_distributed_program(plan, dcfg, mesh)
        _PROGRAM_CACHE[key] = prog
    return prog


def deal_seed(seed: np.ndarray, weights: np.ndarray, w: int,
              width: int = 2, floor: int = 0
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Round-robin deal of a seed batch across ``w`` workers, padded to a
    stable pow2 per-worker chunk (keeps the jitted program's shapes — and
    hence its compile cache — warm across epochs).  ``width`` is the seed
    prefix width (``plan.seed_width``); ``floor`` raises the chunk to a
    ratcheted rung so every delta epoch of a stream shares ONE seed shape
    (the delta engine pins it to the update-batch bound)."""
    seed = np.asarray(seed, np.int32).reshape(-1, width)
    weights = np.asarray(weights, np.int32)
    per = -(-seed.shape[0] // w)
    S = max(_delta._pow2(per), int(floor))
    chunks = np.zeros((w, S, width), np.int32)
    wchunks = np.zeros((w, S), np.int32)
    seed_n = np.zeros(w, np.int32)
    for k in range(w):
        rows = seed[k::w]
        chunks[k, :rows.shape[0]] = rows
        wchunks[k, :rows.shape[0]] = weights[k::w]
        seed_n[k] = rows.shape[0]
    return chunks, seed_n, wchunks


def run_program(program, w: int, collect: bool, indices,
                seed: np.ndarray, weights: np.ndarray, width: int = 2,
                seed_floor: int = 0):
    """Deal the seed, launch one compiled program, unpack psum'd outputs."""
    faults.fire("dist.program")
    chunks, seed_n, wchunks = deal_seed(seed, weights, w, width,
                                        floor=seed_floor)
    out = program(indices, jnp.asarray(chunks), jnp.asarray(seed_n),
                  jnp.asarray(wchunks))
    mask = int(out[4])
    if mask:
        raise CapacityOverflow(mask, where="distributed join",
                               detail=f"w={w} seed_floor={seed_floor}")
    tuples = wts = None
    if collect:
        bufs, ws, ns = (np.asarray(out[7]), np.asarray(out[8]),
                        np.asarray(out[9]))
        tuples = np.concatenate([bufs[i, :ns[i]] for i in range(w)])
        wts = np.concatenate([ws[i, :ns[i]] for i in range(w)])
    from repro.core.bigjoin import JoinResult
    return JoinResult(int(out[0]), tuples, wts, int(out[1]),
                      int(out[2]), int(out[3]))


@dataclasses.dataclass
class DistJoinResult:
    count: int
    proposals: int
    intersections: int
    steps: int
    max_load: int = 0  # max over workers of requests served (Thm 3.4)
    mean_load: float = 0.0
    tuples: Optional[np.ndarray] = None
    weights: Optional[np.ndarray] = None


def distributed_join(plan: Plan, relations: Dict[str, np.ndarray],
                     mesh: Optional[Mesh] = None,
                     cfg: Optional[DistConfig] = None) -> DistJoinResult:
    """End-to-end distributed static join on the given worker mesh."""
    from repro.core.bigjoin import seed_tuples_for
    if mesh is None:
        devs = np.array(jax.devices())
        if cfg is not None:  # honor the caller's worker count on the
            devs = devs[:cfg.num_workers]  # default mesh (w <= devices)
        mesh = Mesh(devs, (AXIS,))
    w = mesh.shape[AXIS]
    if cfg is None:
        base = BigJoinConfig(batch=1024, mode="count")
        cfg = DistConfig(base, w, route_capacity=max(1024 // w, 16) * 4)
    assert cfg.num_workers == w
    indices = partition_indices(plan, relations, w)
    seed = seed_tuples_for(plan, relations)
    sw = plan.seed_width
    per = -(-seed.shape[0] // w)
    pad = np.zeros((per * w - seed.shape[0], sw), np.int32)
    chunks = np.concatenate([seed, pad]).reshape(w, per, sw)
    seed_n = np.full(w, per, np.int32)
    seed_n[-1] = per - pad.shape[0]
    run = build_distributed_program(plan, cfg, mesh)
    out = run(indices, jnp.asarray(chunks), jnp.asarray(seed_n),
              jnp.ones((w, per), jnp.int32))
    if int(out[4]):
        raise CapacityOverflow(int(out[4]), where="distributed static join")
    res = DistJoinResult(int(out[0]), int(out[1]), int(out[2]), int(out[3]),
                         int(out[5]), float(out[6]) / w)
    if cfg.base.mode == "collect":
        bufs, wts, ns = (np.asarray(out[7]), np.asarray(out[8]),
                         np.asarray(out[9]))
        res.tuples = np.concatenate([bufs[i, :ns[i]] for i in range(w)])
        res.weights = np.concatenate([wts[i, :ns[i]] for i in range(w)])
    return res


# ---------------------------------------------------------------------------
# Distributed Delta-BiGJoin (§4): streaming maintenance on the mesh
# ---------------------------------------------------------------------------

def default_delta_config(w: int, batch: int = 1024,
                         mode: str = "collect",
                         out_capacity: int = 1 << 18,
                         balance: bool = False,
                         use_kernel: bool = True,
                         axis=AXIS) -> DistConfig:
    """A DistConfig sized for delta workloads: generous route capacity (the
    deferral backpressure still guarantees correctness if exceeded) and the
    PR-1 fused-kernel default inherited by the delta path."""
    base = BigJoinConfig(batch=batch, seed_chunk=batch, mode=mode,
                         out_capacity=out_capacity, use_kernel=use_kernel)
    return DistConfig(base, w, route_capacity=max(4 * batch // w, 64),
                      balance=balance, axis=axis)


def make_delta_monitor(query, initial_edges, local: bool = False,
                       batch: int = 2048, out_capacity: int = 1 << 20,
                       balance: bool = False, mesh: Optional[Mesh] = None):
    """Deprecated: use :class:`repro.api.GraphSession` — one session owns the
    graph and serves many standing queries off a single commit per epoch.
    Kept as a thin wrapper for old callers; selects the host-local
    :class:`~repro.core.delta.DeltaBigJoin` or mesh-backed
    :class:`DistDeltaBigJoin` with matching B'/output budgets."""
    import warnings
    warnings.warn(
        "make_delta_monitor is deprecated; use repro.api.GraphSession "
        "(register() one or more queries, update() once per epoch)",
        DeprecationWarning, stacklevel=2)
    if local:
        cfg = BigJoinConfig(batch=batch, seed_chunk=batch, mode="collect",
                            out_capacity=out_capacity)
        return _delta.DeltaBigJoin(query, initial_edges, cfg=cfg)
    w = (jax.device_count() if mesh is None else
         int(np.prod([mesh.shape[a] for a in mesh.axis_names])))
    return DistDeltaBigJoin(
        query, initial_edges, mesh=mesh,
        dcfg=default_delta_config(w, batch=batch,
                                  out_capacity=out_capacity,
                                  balance=balance))


class DistDeltaBigJoin(_delta.DeltaBigJoin):
    """Delta-BiGJoin where every region shard lives on a mesh worker.

    Inherits the epoch bookkeeping of :class:`repro.core.delta.
    DeltaBigJoin` (normalize / commit / compaction semantics are identical —
    asserted by the differential stress suite) and overrides only the
    worker layout:

    - every ``_Regions`` multi-version projection is hash-partitioned by
      packed key over the mesh workers (``csr.build_sharded_index``), so
      each region entry has exactly one owner and cluster memory is
      O(IN + delta) — the paper's memory-linearity carried over to the
      maintained setting.  The per-epoch commit folds run shard-local
      (ownership is by key, so a delta entry and the committed entry it
      cancels always share a worker): ``delta._commit_fold`` vmaps the
      sorted-merge over the worker axis with no collectives, and each
      worker folds only its owned rows;
    - each delta query dAQ_i seeds its SIGNED dR batch round-robin across
      workers and runs the request/response dataflow of §3.4
      (``build_dist_step`` / ``build_balanced_step`` under ``balance``),
      with counts and outputs psum-merged;
    - the per-plan shard_map program is built once and jit-cached; pow2
      region/seed padding keeps its shapes stable across epochs, so
      steady-state monitoring never re-lowers.
    """

    def __init__(self, query, initial_edges, mesh: Optional[Mesh] = None,
                 dcfg: Optional[DistConfig] = None,
                 compact_ratio: float = 0.5,
                 store: Optional[_delta.RegionStore] = None,
                 device_resident: bool = True):
        if mesh is None:
            mesh = Mesh(np.array(jax.devices()), (AXIS,))
        self.mesh = mesh
        self.w = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        if dcfg is None:
            dcfg = default_delta_config(self.w)
        axes = dcfg.axis if isinstance(dcfg.axis, tuple) else (dcfg.axis,)
        if dcfg.num_workers != self.w or set(axes) != set(mesh.axis_names):
            raise ValueError(
                "dcfg does not match the mesh: "
                f"{dcfg.num_workers} workers on axes {axes} vs mesh "
                f"{dict(mesh.shape)}")
        if store is not None and store.shard_w != self.w:
            raise ValueError(
                f"shared store is sharded over {store.shard_w} workers, "
                f"mesh has {self.w}")
        self.dcfg = dcfg
        self._programs: Dict[int, object] = {}
        super().__init__(query, initial_edges, cfg=dcfg.base,
                         compact_ratio=compact_ratio, store=store,
                         device_resident=device_resident)

    def _new_store(self, edges, compact_ratio):
        return _delta.RegionStore(edges, shard_w=self.w,
                                  compact_ratio=compact_ratio,
                                  device_resident=self.device_resident)

    def _run_plan(self, plan, indices, seed, weights):
        pi = self.plans.index(plan)
        if pi not in self._programs:
            self._programs[pi] = get_distributed_program(
                plan, self.dcfg, self.mesh)
        # the per-worker seed chunk rides its own ratcheted rung so every
        # epoch of a stream launches ONE program signature (prewarm pins
        # the mark at the update-batch bound; _static_eval full-graph
        # seeds deliberately bypass this key)
        width = plan.seed_width
        per = -(-seed.shape[0] // self.w)
        floor = self.store.ratchet.capacity(("seed", width), per)
        return run_program(self._programs[pi], self.w,
                           self.dcfg.base.mode == "collect", indices,
                           seed, weights, width=width, seed_floor=floor)

    def _escalate(self, exc) -> None:
        """Mesh overflow recovery: grows the per-peer route tables too,
        and rebuilds the shard_map programs on the escalated DistConfig
        (program identity keys on the config, so the stale programs must
        be dropped before the replay)."""
        qn = self.query.name
        r = self.store.ratchet
        base, dcfg, changed = self.dcfg.base, self.dcfg, False
        if exc.kinds & ESCALATES_OUT:
            new_out = r.escalate(("cap", "out", qn),
                                 floor=base.out_capacity)
            base = dataclasses.replace(base, out_capacity=new_out)
            changed = True
        if exc.kinds & ESCALATES_BATCH:
            new_b = r.escalate(("cap", "batch", qn), floor=base.batch)
            base = dataclasses.replace(
                base, batch=new_b, seed_chunk=max(base.seed_chunk, new_b))
            changed = True
        if exc.kinds & ESCALATES_ROUTE:
            new_rt = r.escalate(("cap", "route", qn),
                                floor=dcfg.route_capacity)
            dcfg = dataclasses.replace(dcfg, route_capacity=new_rt)
            changed = True
        if not changed:
            raise exc
        if base is not self.dcfg.base:
            dcfg = dataclasses.replace(dcfg, base=base)
        self.dcfg = dcfg
        self.cfg = base
        self._programs.clear()
        self.store.stats.escalations += 1
        self._reprewarm()

    def prewarm(self, update_batch: int, horizon=None) -> int:
        """AOT-compile every (program, committed-rung) signature this
        engine's delta plans can request for batches ≤ ``update_batch``
        (the mesh half of ``GraphSession.prewarm``)."""
        ub = max(int(update_batch), 1)
        self._prewarm_args = (ub, horizon)
        snap = compilestats.snapshot()
        for pi, plan in enumerate(self.plans):
            if pi not in self._programs:
                self._programs[pi] = get_distributed_program(
                    plan, self.dcfg, self.mesh)
            prog = self._programs[pi]
            width = plan.seed_width
            per = -(-ub // self.w)
            chunk = self.store.ratchet.capacity(("seed", width), per)
            rels = {rel for _id, rel, *_ in plan.index_ids()}
            # reachable rung cross-product, not just the same-rung
            # diagonal — relations grow independently (delta._rung_combos)
            ladders = {rel: self.store.committed_ladder(rel, ub, horizon)
                       for rel in rels}
            for combo in _delta._rung_combos(ladders):
                prog.warm(self.store.indices_sds_for(plan, combo, ub),
                          chunk, width)
        return compilestats.since(snap)
