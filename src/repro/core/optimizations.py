"""§5.4 optimizations: symmetry breaking, triangle indexing, factorization.

These show the engine accommodates the specializations of SEED/FAQ-style
systems (Table 5): each is a *transformation of inputs or queries*, not a
change to the dataflow.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import query as Q
from repro.core.csr import Graph
from repro.core.generic_join import _NpIndex, generic_join
from repro.core.plan import make_plan


def symmetry_break(graph: Graph) -> Graph:
    """Degree-order relabel + orient edges low->high (§5.4 'SYM').

    After this transform, an undirected k-clique appears exactly once as the
    directed clique with a1 < a2 < ... < ak, so the symmetric query variants
    (``Q.four_clique(symmetric=True)`` etc.) enumerate each instance once
    instead of k! times.
    """
    return graph.degree_relabel()


def build_triangle_relation(graph: Graph, engine: str = "bigjoin",
                            **kw) -> np.ndarray:
    """Materialize tri(a1,a2,a3) with a1<a2<a3 on a DAG-ified graph ('TR').

    The ternary relation is then indexable like any other (§5.4: "we support
    general relational queries and can index general relations").
    """
    rels = {Q.EDGE: graph.edges}
    if engine == "bigjoin":
        from repro.core.bigjoin import (BigJoinConfig, build_indices,
                                        run_bigjoin, seed_tuples_for)
        q = Q.triangle(symmetric=True)
        plan = make_plan(q)
        cfg = kw.pop("cfg", None) or BigJoinConfig(
            batch=4096, seed_chunk=4096, out_capacity=1 << 22)
        idx = build_indices(plan, rels)
        res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
        return res.tuples
    tri, _ = generic_join(Q.triangle(symmetric=True), rels)
    return tri


def four_clique_via_tri(graph: Graph, engine: str = "bigjoin",
                        **kw) -> Tuple[int, np.ndarray]:
    """4-clique counting through the tri relation (fewer prefixes explored)."""
    tri = build_triangle_relation(graph, engine, **kw)
    rels = {"tri": tri}
    q = Q.four_clique_tri()
    if engine == "bigjoin":
        from repro.core.bigjoin import (BigJoinConfig, build_indices,
                                        run_bigjoin, seed_tuples_for)
        plan = make_plan(q)
        cfg = kw.pop("cfg", None) or BigJoinConfig(
            batch=4096, seed_chunk=4096, out_capacity=1 << 22)
        idx = build_indices(plan, rels)
        res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
        return res.count, res.tuples
    out, cnt = generic_join(q, rels)
    return cnt, out


def factorized_house_count(graph: Graph) -> int:
    """The house query via factorization (§5.4, [45]).

    house = clique(a2,a3,a4,a5) + a1 adjacent to a2 and a3.  Since a1 does
    not constrain a4/a5, its bindings stay *unflattened*: the count is

        sum over 4-cliques (b,c,d,e) of |{a : e(a,b) and e(a,c)}|

    computed without materializing the Cartesian product.  Assumes a
    symmetry-broken (DAG-ified) graph; counts each undirected house with
    a2<a3 and a4<a5 orientation exactly as the filtered flat query does.
    """
    g = graph
    rels = {Q.EDGE: g.edges}
    cliques, _ = generic_join(Q.four_clique(symmetric=True), rels)
    if cliques.shape[0] == 0:
        return 0
    # On the DAG the atoms force a2<a3<a4<a5 and a1->a2, a1->a3: so per
    # sorted 4-clique the a1 bindings are the common *in*-neighbors of its
    # two smallest vertices — counted, never flattened.
    rev = _NpIndex(g.edges, (1,), 0)  # dst -> src (in-neighbours)
    total = 0
    for row in cliques:
        b, c = np.int64(row[0]), np.int64(row[1])
        sb, cb = rev.ranges(np.array([b]))
        sc, cc = rev.ranges(np.array([c]))
        nb = rev.val[sb[0]:sb[0] + cb[0]]
        nc = rev.val[sc[0]:sc[0] + cc[0]]
        total += int(np.intersect1d(nb, nc, assume_unique=True).shape[0])
    return total
