"""Serial Generic Join (§2.2) — numpy implementation.

This is (a) the optimized single-threaded baseline of the paper's COST
experiment (Fig 4), and (b) the *oracle* against which every dataflow
implementation (BiGJoin, Delta-BiGJoin, distributed, kernels) is tested.

Also provides the *edge-at-a-time* binary-join baseline (§1.2.1) used by the
EmptyHeaded/Arabesque comparison benchmarks, which is provably suboptimal and
demonstrates the intermediate-result blowup GJ avoids.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import csr
from repro.core.plan import Plan, make_plan
from repro.core.query import EDGE, Query


@dataclasses.dataclass
class WorkCounters:
    """Operation counts for worst-case-optimality property tests (Lemma 3.1:
    total work is O(m n MaxOut_Q))."""

    proposals: int = 0
    intersections: int = 0
    count_lookups: int = 0

    @property
    def total(self) -> int:
        return self.proposals + self.intersections + self.count_lookups


class _NpIndex:
    """Host-side sorted extension index (numpy mirror of csr.IndexData).

    Keys come from the ONE shared packer (``csr.pack_key``): a single int64
    word for <= 2 bound columns, a lexicographic (hi, lo) pair for 3-4 —
    so the host oracle and the device indices agree by construction.
    """

    def __init__(self, tuples: np.ndarray, key_pos: Tuple[int, ...],
                 ext_pos: int):
        tuples = np.asarray(tuples)
        key = csr.pack_key(tuple(tuples[:, p].astype(np.int32)
                                 for p in key_pos)) if key_pos else \
            np.zeros(tuples.shape[0], np.int64)
        val = tuples[:, ext_pos].astype(np.int64)
        if isinstance(key, tuple):  # composite (hi, lo) key
            kvl = np.unique(np.stack([key[0], key[1], val], 1), axis=0) \
                if val.size else np.zeros((0, 3), np.int64)
            self.key, self.lo = kvl[:, 0], kvl[:, 1]
            self.val = kvl[:, 2].astype(np.int32)
            self._packed = None
            return
        self.lo = None
        kv = np.unique(np.stack([key, val], 1), axis=0) if key.size else \
            np.zeros((0, 2), np.int64)
        self.key = kv[:, 0]
        self.val = kv[:, 1].astype(np.int32)
        # membership fast path: packed (key,val) when key fits in 31 bits
        self._packed = ((self.key << 32) | kv[:, 1]
                        if (self.key < 2**31).all() else None)

    def ranges(self, qkey) -> Tuple[np.ndarray, np.ndarray]:
        if self.lo is not None:
            qh, ql = qkey
            s = _lex_searchsorted_np((self.key, self.lo), (qh, ql), "left")
            e = _lex_searchsorted_np((self.key, self.lo), (qh, ql), "right")
            return s, (e - s)
        s = np.searchsorted(self.key, qkey, "left")
        e = np.searchsorted(self.key, qkey, "right")
        return s, (e - s)

    def member(self, qkey, qval: np.ndarray) -> np.ndarray:
        qv = qval.astype(np.int64)
        if self.lo is not None:
            qh, ql = qkey
            return _lex_hit_np((self.key, self.lo, self.val.astype(np.int64)),
                               (qh, ql, qv))
        if self._packed is not None:
            q = (qkey.astype(np.int64) << 32) | qv
            pos = np.searchsorted(self._packed, q)
            pos_c = np.minimum(pos, max(len(self._packed) - 1, 0))
            return (len(self._packed) > 0) & (self._packed[pos_c] == q)
        # keys >= 2^31 cannot be packed: vectorized lexicographic binary
        # search over the sorted (key, val) pairs (np.unique sorted them)
        return _lex_hit_np((self.key, self.val.astype(np.int64)), (qkey, qv))


def _lex_searchsorted_np(cols: Tuple[np.ndarray, ...],
                         qcols: Tuple[np.ndarray, ...],
                         side: str = "left") -> np.ndarray:
    """Vectorized lower/upper bound over up-to-3 lex-sorted int64 columns —
    the numpy mirror of ``csr.lex_searchsorted_cols`` (fixed-depth binary
    search: O(B log n) vector ops instead of per-query Python probes)."""
    n = cols[0].shape[0]
    right = side == "right"
    if n == 0:
        return np.zeros(np.asarray(qcols[0]).shape[0], np.int64)
    lo = np.zeros(qcols[0].shape[0], np.int64)
    hi = np.full(qcols[0].shape[0], n, np.int64)
    for _ in range(max(int(np.ceil(np.log2(max(n, 2)))), 1) + 1):
        mid = (lo + hi) >> 1
        mc = np.minimum(mid, n - 1)
        less = np.zeros(lo.shape[0], bool)
        eq = np.ones(lo.shape[0], bool)
        for c, q in zip(cols, qcols):
            v = c[mc]
            less |= eq & (v < q)
            eq &= v == q
        if right:
            less |= eq
        sel = lo < hi
        lo = np.where(less & sel, mid + 1, lo)
        hi = np.where(~less & sel, mid, hi)
    return lo


def _lex_hit_np(cols, qcols) -> np.ndarray:
    """Exact-match membership of lex queries in lex-sorted columns."""
    n = cols[0].shape[0]
    if n == 0:
        return np.zeros(np.asarray(qcols[0]).shape[0], bool)
    pos = _lex_searchsorted_np(cols, qcols, "left")
    pc = np.minimum(pos, n - 1)
    hit = pos < n
    for c, q in zip(cols, qcols):
        hit &= c[pc] == q
    return hit


def _lex_member_np(key: np.ndarray, val: np.ndarray, qk: np.ndarray,
                   qv: np.ndarray) -> np.ndarray:
    """Back-compat wrapper: (key, val) membership via the generic search."""
    return _lex_hit_np((key, val.astype(np.int64)),
                       (qk, qv.astype(np.int64)))


def build_np_indices(plan: Plan, relations: Dict[str, np.ndarray]
                     ) -> Dict[str, _NpIndex]:
    out = {}
    for index_id, rel, key_pos, ext_pos, _version in plan.index_ids():
        out[index_id] = _NpIndex(relations[rel], key_pos, ext_pos)
    return out


def _pack_prefix_key(prefix: np.ndarray, bound_attrs: Tuple[int, ...],
                     key_attrs: Tuple[int, ...]):
    """Pack the bound prefix columns named by ``key_attrs`` — delegates to
    the shared ``csr.pack_key`` (single word, or (hi, lo) for 3-4 cols)."""
    return csr.pack_key(tuple(
        prefix[:, bound_attrs.index(a)].astype(np.int64)
        for a in key_attrs))


def generic_join(query: Query, relations: Dict[str, np.ndarray],
                 plan: Optional[Plan] = None,
                 seed: Optional[np.ndarray] = None,
                 counters: Optional[WorkCounters] = None,
                 enumerate_results: bool = True) -> Tuple[np.ndarray, int]:
    """Run serial GJ.  Returns (results [N, m] in attribute order, count).

    ``seed`` overrides P_2 (used by delta evaluation: seed = dR_i tuples,
    already oriented as (attr_order[0], attr_order[1]) values).
    """
    plan = plan or make_plan(query)
    idx = build_np_indices(plan, relations)
    m = query.num_attrs

    # ---- P_2 --------------------------------------------------------------
    if seed is None:
        rel = np.asarray(relations[query.atoms[plan.seed_atom].rel], np.int64)
        seed_tuples = np.unique(rel[:, list(plan.seed_cols)], axis=0)
    else:
        seed_tuples = np.asarray(seed, np.int64).reshape(
            -1, plan.seed_width)
    prefix = seed_tuples.astype(np.int64)
    bound = tuple(plan.attr_order[:plan.seed_width])
    for b in plan.seed_filters:
        qk = _pack_prefix_key(prefix, bound, b.key_attrs)
        qv = prefix[:, bound.index(b.ext_attr)]
        keep = idx[b.index_id].member(qk, qv)
        if counters:
            counters.intersections += len(prefix)
        prefix = prefix[keep]
    for f in plan.seed_ineq:
        keep = prefix[:, bound.index(f.lo)] < prefix[:, bound.index(f.hi)]
        prefix = prefix[keep]

    # ---- prefix extension levels ------------------------------------------
    for lv in plan.levels:
        if prefix.shape[0] == 0:
            prefix = np.zeros((0, len(lv.bound_attrs) + 1), np.int64)
            continue
        nb = len(lv.bindings)
        starts = np.zeros((nb, prefix.shape[0]), np.int64)
        counts = np.zeros((nb, prefix.shape[0]), np.int64)
        for bi, b in enumerate(lv.bindings):
            qk = _pack_prefix_key(prefix, lv.bound_attrs, b.key_attrs)
            s, c = idx[b.index_id].ranges(qk)
            starts[bi], counts[bi] = s, c
            if counters:
                counters.count_lookups += len(prefix)
        min_i = np.argmin(counts, axis=0)
        min_c = counts[min_i, np.arange(prefix.shape[0])]
        min_s = starts[min_i, np.arange(prefix.shape[0])]
        total = int(min_c.sum())
        if counters:
            counters.proposals += total
        # ragged expand: proposal t belongs to prefix row[t], offset k[t]
        row = np.repeat(np.arange(prefix.shape[0]), min_c)
        cum = np.concatenate([[0], np.cumsum(min_c)])
        k = np.arange(total) - cum[row]
        ext_pos = min_s[row] + k
        # gather candidate extensions from the proposing index
        cand = np.zeros(total, np.int64)
        for bi, b in enumerate(lv.bindings):
            sel = min_i[row] == bi
            if sel.any():
                cand[sel] = idx[b.index_id].val[ext_pos[sel]]
        keep = np.ones(total, bool)
        new_prefix = np.concatenate([prefix[row], cand[:, None]], axis=1)
        new_bound = lv.bound_attrs + (lv.ext_attr,)
        for bi, b in enumerate(lv.bindings):
            sel = keep & (min_i[row] != bi)
            if counters:
                counters.intersections += int(sel.sum())
            if not sel.any():
                continue
            qk = _pack_prefix_key(new_prefix[sel], new_bound, b.key_attrs)
            qv = new_prefix[sel, -1]
            ok = idx[b.index_id].member(qk, qv)
            keep[np.where(sel)[0][~ok]] = False
        for f in lv.filters:
            lo = new_prefix[:, new_bound.index(f.lo)]
            hi = new_prefix[:, new_bound.index(f.hi)]
            keep &= lo < hi
        prefix = new_prefix[keep]
        bound = new_bound

    # reorder columns from attr order to attribute id order
    perm = np.argsort(np.asarray(plan.attr_order))
    result = prefix[:, perm] if enumerate_results else prefix[:0]
    return result.astype(np.int32), int(prefix.shape[0])


# ---------------------------------------------------------------------------
# Edge-at-a-time (binary join) baseline — §1.2.1.
# ---------------------------------------------------------------------------

class IntermediateBlowup(RuntimeError):
    pass


def binary_join(query: Query, relations: Dict[str, np.ndarray],
                max_intermediate: int = 50_000_000,
                ) -> Tuple[np.ndarray, int, int]:
    """Left-deep binary join in a greedy connected atom order.

    Returns (results, count, peak_intermediate).  Raises IntermediateBlowup
    if any intermediate exceeds ``max_intermediate`` rows — the failure mode
    the paper's worst-case-optimal approach provably avoids.
    """
    atoms = list(query.atoms)
    order = [0]
    bound = set(atoms[0].attrs)
    remaining = set(range(1, len(atoms)))
    while remaining:
        nxt = max(remaining,
                  key=lambda i: len(set(atoms[i].attrs) & bound))
        if not set(atoms[nxt].attrs) & bound:
            raise ValueError("disconnected query")
        order.append(nxt)
        bound |= set(atoms[nxt].attrs)
        remaining.discard(nxt)

    first = atoms[order[0]]
    cur = np.asarray(relations[first.rel], np.int64)
    cur_attrs = list(first.attrs)
    peak = cur.shape[0]
    for oi in order[1:]:
        atom = atoms[oi]
        rel = np.asarray(relations[atom.rel], np.int64)
        shared = [a for a in atom.attrs if a in cur_attrs]
        new = [a for a in atom.attrs if a not in cur_attrs]
        kc = [cur_attrs.index(a) for a in shared]
        kr = [atom.attrs.index(a) for a in shared]

        def pk(arr, cols):
            key = arr[:, cols[0]].astype(np.int64)
            for c in cols[1:]:
                key = (key << 21) | arr[:, c].astype(np.int64)
            return key

        ck, rk = pk(cur, kc), pk(rel, kr)
        srt = np.argsort(rk, kind="stable")
        rk_s, rel_s = rk[srt], rel[srt]
        s = np.searchsorted(rk_s, ck, "left")
        e = np.searchsorted(rk_s, ck, "right")
        cnt = e - s
        total = int(cnt.sum())
        peak = max(peak, total)
        if total > max_intermediate:
            raise IntermediateBlowup(
                f"intermediate of {total} rows exceeds cap "
                f"{max_intermediate} at atom {atom}")
        row = np.repeat(np.arange(cur.shape[0]), cnt)
        cum = np.concatenate([[0], np.cumsum(cnt)])
        k = np.arange(total) - cum[row]
        match = rel_s[s[row] + k]
        new_cols = [match[:, atom.attrs.index(a)][:, None] for a in new]
        cur = np.concatenate([cur[row]] + new_cols, axis=1)
        cur_attrs = cur_attrs + new
    for f in query.filters:
        keep = cur[:, cur_attrs.index(f.lo)] < cur[:, cur_attrs.index(f.hi)]
        cur = cur[keep]
    perm = [cur_attrs.index(a) for a in range(query.num_attrs)]
    out = cur[:, perm]
    out = np.unique(out, axis=0)  # binary joins can duplicate under dedup'd
    return out.astype(np.int32), int(out.shape[0]), peak


# ---------------------------------------------------------------------------
# Optimized single-threaded triangle count (COST baseline, Fig 4).
# ---------------------------------------------------------------------------

def fast_triangle_count(edges: np.ndarray) -> int:
    """Degree-ordered merge-intersection triangle counting; vectorized numpy.

    Counts triangles of the *undirected* graph induced by ``edges`` (the
    standard COST formulation).
    """
    e = np.asarray(edges, np.int64)
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    keep = lo != hi
    e = np.unique(np.stack([lo[keep], hi[keep]], 1), axis=0)
    nv = int(e.max()) + 1 if e.size else 0
    deg = np.bincount(e.reshape(-1), minlength=nv)
    rank = np.empty(nv, np.int64)
    rank[np.lexsort((np.arange(nv), deg))] = np.arange(nv)
    a, b = rank[e[:, 0]], rank[e[:, 1]]
    src = np.minimum(a, b)
    dst = np.maximum(a, b)
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    packed = (src << 32) | dst
    # For each edge (u,v): |N+(u) ∩ N+(v)| via membership probes of the
    # smaller out-neighborhood against packed edges.
    starts = np.searchsorted(src, np.arange(nv), "left")
    ends = np.searchsorted(src, np.arange(nv), "right")
    cnt_u = ends[src] - starts[src]
    cnt_v = ends[dst] - starts[dst]
    small_is_u = cnt_u <= cnt_v
    probe_n = np.where(small_is_u, cnt_u, cnt_v)
    probe_start = np.where(small_is_u, starts[src], starts[dst])
    other = np.where(small_is_u, dst, src)
    total = int(probe_n.sum())
    row = np.repeat(np.arange(src.shape[0]), probe_n)
    cum = np.concatenate([[0], np.cumsum(probe_n)])
    k = np.arange(total) - cum[row]
    w = dst[probe_start[row] + k]
    q = (other[row].astype(np.int64) << 32) | w
    pos = np.searchsorted(packed, q)
    pos_c = np.minimum(pos, len(packed) - 1)
    return int((packed[pos_c] == q).sum())
