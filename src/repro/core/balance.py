"""BiGJoin-S (§3.4): the Balance operator and piece-draining dataflow.

The skew problem: after count-minimization a few prefixes may own almost all
candidate extensions (a celebrity vertex's adjacency list), so the worker
holding them does almost all proposal/intersection work.  BiGJoin-S fixes
this by splitting each prefix's extension range into (p, min-i, start, end)
quadruples and dealing equal *work* (not equal prefix counts) to every
worker.

Our deterministic split is the paper's (§3.4.2): each worker divides its
local proposal work T_l into w contiguous chunks of C_l = ceil(T_l/w) and
sends chunk j to worker j.  Every receiver thus gets Σ_l C_l ≈ T/w (±1 per
sender) work.  A chunk intersects at most C_l + 1 prefix rows, so the
per-peer piece capacity is the *static* bound B'//w + 2 and the exchange can
never overflow — the balance guarantee holds deterministically, not just
w.h.p. (the w.h.p. part of Thm 3.4 concerns the hashed index lookups, which
the aggregation in distributed.py addresses).

Received quadruples land in a per-level *piece queue*, drained before any
new balance round fires (scheduling priority: deeper level first; within a
level, pieces before prefixes), which bounds the piece queue at one round's
worth: w · (B'//w + 2).

Delta-BiGJoin rides the same machinery unchanged: the SIGNED seed weights
(±1 dR rows, threaded through ``build_per_worker``) travel inside each
piece quadruple, and the multi-version region lookups are ordinary
``_remote_count``/``_remote_member`` calls against old/new
``VersionedIndex`` shards — ``DistDeltaBigJoin(dcfg.balance=True)`` is
differentially checked in tests/test_delta_stream.py.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bigjoin import (BigJoinState, LevelQueue, _binding_key,
                                _compact, _pack_cols, _scatter_append)
from repro.errors import OVF_OUT, OVF_PIECE, OVF_QUEUE
from repro.core.distributed import (AXIS, DistConfig, _remote_count,
                                    _remote_member, _remote_resolve,
                                    owner_of)
from repro.core.plan import Plan


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PieceQueue:
    """(p, min-i, [kcur, kend), weight) quadruple queue for one level."""

    prefix: jax.Array  # [cap, width] int32
    mini: jax.Array  # [cap] int32
    kcur: jax.Array  # [cap] int32
    kend: jax.Array  # [cap] int32
    weight: jax.Array  # [cap] int32
    size: jax.Array  # [] int32

    def tree_flatten(self):
        return (self.prefix, self.mini, self.kcur, self.kend, self.weight,
                self.size), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def piece_caps(dcfg: DistConfig) -> Tuple[int, int]:
    """(per-peer-pair send capacity, piece queue capacity)."""
    w, B = dcfg.num_workers, dcfg.base.batch
    cap_pair = B // w + 2
    return cap_pair, 2 * w * cap_pair


def make_piece_queues(plan: Plan, dcfg: DistConfig) -> Tuple[PieceQueue, ...]:
    _, qcap = piece_caps(dcfg)
    out = []
    for lv in plan.levels:
        width = len(lv.bound_attrs)
        out.append(PieceQueue(
            jnp.zeros((qcap, width), jnp.int32),
            jnp.zeros(qcap, jnp.int32),
            jnp.zeros(qcap, jnp.int32),
            jnp.zeros(qcap, jnp.int32),
            jnp.zeros(qcap, jnp.int32),
            jnp.asarray(0, jnp.int32)))
    return tuple(out)


# ---------------------------------------------------------------------------
# prefix branch with Balance (replaces proposal/intersect by piece routing)
# ---------------------------------------------------------------------------

def _build_balance_prefix_branch(plan: Plan, dcfg: DistConfig, li: int):
    lv = plan.levels[li]
    w, cap, B = dcfg.num_workers, dcfg.route_capacity, dcfg.base.batch
    cap_pair, _ = piece_caps(dcfg)
    width = len(lv.bound_attrs)

    def branch(carry, indices):
        state, pieces = carry
        qu = state.queues[li]
        W = min(B, qu.prefix.shape[0])
        wprefix, wk, wweight = qu.prefix[:W], qu.k[:W], qu.weight[:W]
        valid = jnp.arange(W, dtype=jnp.int32) < qu.size

        # remote count minimization (identical to the unbalanced branch)
        cnts, count_ok = [], valid
        recv_load = state.recv_load
        for b in lv.bindings:
            idx = indices[b.index_id]
            qk = _binding_key(wprefix, lv.bound_attrs, b.key_attrs, idx)
            cnt, ok, load = _remote_count(idx, qk, owner_of(qk, w), valid, w,
                                          cap, dcfg.aggregate, dcfg.axis)
            cnts.append(cnt)
            count_ok = count_ok & ok
            recv_load = recv_load + load
        tot = jnp.stack(cnts, -1)
        min_i = jnp.argmin(tot, -1).astype(jnp.int32)
        min_c = tot.min(-1)

        remaining = jnp.where(valid & count_ok,
                              jnp.maximum(min_c - wk, 0), 0)
        acum = jnp.cumsum(remaining, dtype=jnp.int32)
        allowed = jnp.clip(B - (acum - remaining), 0, remaining
                           ).astype(jnp.int32)
        aacum = jnp.cumsum(allowed, dtype=jnp.int32)  # end offsets
        loff = aacum - allowed  # start offsets
        T_l = aacum[-1]
        C = (T_l + w - 1) // w  # my chunk size (work per receiver)

        # ---- Balance (§3.4.2): chunk j of my work goes to worker j --------
        j = jnp.arange(w, dtype=jnp.int32)[:, None]  # [w, 1]
        p = jnp.arange(cap_pair, dtype=jnp.int32)[None, :]  # [1, cap_pair]
        chunk_lo = j * C
        chunk_hi = jnp.minimum(chunk_lo + C, T_l)
        rfirst = jnp.searchsorted(aacum, chunk_lo[:, 0], side="right"
                                  ).astype(jnp.int32)[:, None]
        row = jnp.clip(rfirst + p, 0, W - 1)  # [w, cap_pair]
        pstart = jnp.maximum(loff[row], chunk_lo)
        pend = jnp.minimum(aacum[row], chunk_hi)
        pvalid = ((rfirst + p) < W) & (pstart < pend) & (chunk_lo < T_l)
        kstart = wk[row] + (pstart - loff[row])
        kend = kstart + (pend - pstart)

        def a2a(x):
            return jax.lax.all_to_all(x, dcfg.axis, 0, 0, tiled=False)

        r_prefix = a2a(wprefix[row])  # [w, cap_pair, width]
        r_mini = a2a(min_i[row])
        r_kcur = a2a(jnp.where(pvalid, kstart, 0))
        r_kend = a2a(jnp.where(pvalid, kend, 0))
        r_weight = a2a(wweight[row])
        r_valid = a2a(pvalid.astype(jnp.int32)) > 0

        # append received pieces to my piece queue for this level
        pq = pieces[li]
        flat_valid = r_valid.reshape(-1)
        npfx, n_new, ovf = _scatter_append(
            pq.prefix, pq.size, r_prefix.reshape(-1, width), flat_valid)
        nmini, _, _ = _scatter_append(pq.mini, pq.size, r_mini.reshape(-1),
                                      flat_valid)
        nkcur, _, _ = _scatter_append(pq.kcur, pq.size, r_kcur.reshape(-1),
                                      flat_valid)
        nkend, _, _ = _scatter_append(pq.kend, pq.size, r_kend.reshape(-1),
                                      flat_valid)
        nwt, _, _ = _scatter_append(pq.weight, pq.size,
                                    r_weight.reshape(-1), flat_valid)
        pieces = list(pieces)
        pieces[li] = PieceQueue(
            npfx, nmini, nkcur, nkend, nwt,
            jnp.minimum(pq.size + n_new, jnp.int32(pq.prefix.shape[0])))

        # retire consumed prefixes (their balanced work is now owned by the
        # receivers; count_ok deferral still applies)
        consumed = valid & count_ok & ((wk + allowed) >= min_c)
        kfull = qu.k.at[:W].set(wk + allowed)
        live_row = jnp.arange(qu.prefix.shape[0], dtype=jnp.int32) < qu.size
        keep_rows = live_row & ~jnp.pad(consumed,
                                        (0, qu.prefix.shape[0] - W))
        (pfx, kk, ww), nsz = _compact([qu.prefix, kfull, qu.weight],
                                      keep_rows)
        queues = list(state.queues)
        queues[li] = LevelQueue(pfx, kk, ww, nsz)
        state = dataclasses.replace(
            state, queues=tuple(queues),
            overflow=state.overflow | jnp.where(ovf, OVF_PIECE, 0),
            recv_load=recv_load)
        return state, tuple(pieces)

    return branch


# ---------------------------------------------------------------------------
# piece-draining branch: Extension-Resolve + Intersect on balanced ranges
# ---------------------------------------------------------------------------

def _build_piece_branch(plan: Plan, dcfg: DistConfig, li: int):
    lv = plan.levels[li]
    w, cap, B = dcfg.num_workers, dcfg.route_capacity, dcfg.base.batch
    is_last = li == len(plan.levels) - 1
    new_bound = lv.bound_attrs + (lv.ext_attr,)
    INF = jnp.int32(np.iinfo(np.int32).max)

    def branch(carry, indices):
        state, pieces = carry
        pq = pieces[li]
        W = min(B, pq.prefix.shape[0])
        wprefix = pq.prefix[:W]
        wmini, wkcur = pq.mini[:W], pq.kcur[:W]
        wkend, wweight = pq.kend[:W], pq.weight[:W]
        valid = jnp.arange(W, dtype=jnp.int32) < pq.size
        recv_load = state.recv_load

        remaining = jnp.where(valid, jnp.maximum(wkend - wkcur, 0), 0)
        acum = jnp.cumsum(remaining, dtype=jnp.int32)
        allowed = jnp.clip(B - (acum - remaining), 0, remaining
                           ).astype(jnp.int32)
        aacum = jnp.cumsum(allowed, dtype=jnp.int32)
        t = jnp.arange(B, dtype=jnp.int32)
        pvalid = t < aacum[-1]
        r = jnp.clip(jnp.searchsorted(aacum, t, side="right"), 0, W - 1)
        r = r.astype(jnp.int32)
        k_off = t - (aacum[r] - allowed[r]) + wkcur[r]

        # Extension-Resolve (Fig 3)
        qks = []
        for b in lv.bindings:
            idx = indices[b.index_id]
            qks.append(_binding_key(wprefix, lv.bound_attrs, b.key_attrs,
                                    idx))
        cand = jnp.zeros(B, jnp.int32)
        incomplete = jnp.zeros(B, bool)
        for bi, b in enumerate(lv.bindings):
            idx = indices[b.index_id]
            qk_r = qks[bi][r]
            mask = pvalid & (wmini[r] == bi)
            val, ok, load = _remote_resolve(idx, qk_r, k_off,
                                            owner_of(qk_r, w), mask, w, cap,
                                            dcfg.axis)
            cand = jnp.where(mask, val, cand)
            incomplete = incomplete | (mask & ~ok)
            recv_load = recv_load + load

        new_prefix = jnp.concatenate([wprefix[r], cand[:, None]], axis=1)
        weight = wweight[r]
        alive = pvalid
        n_isect = jnp.asarray(0, jnp.int64)

        # Intersect (Fig 3) — aggregated lookups
        for bi, b in enumerate(lv.bindings):
            idx = indices[b.index_id]
            pos = [list(new_bound).index(a) for a in b.key_attrs]
            qk = _pack_cols(new_prefix, pos, idx.pos[0].key.dtype)
            mem, dele, ok, load = _remote_member(
                idx, qk, cand, owner_of(qk, w), pvalid, w, cap,
                dcfg.aggregate, dcfg.axis, dcfg.base.use_kernel,
                dcfg.base.kernel_interpret)
            recv_load = recv_load + load
            is_min = wmini[r] == bi
            keep = jnp.where(is_min, ~dele, mem)
            n_isect = n_isect + (alive & ~is_min).sum().astype(jnp.int64)
            alive = alive & (keep | ~ok)
            incomplete = incomplete | (pvalid & ~ok)
        for f in lv.filters:
            lo = new_prefix[:, list(new_bound).index(f.lo)]
            hi = new_prefix[:, list(new_bound).index(f.hi)]
            alive = alive & (lo < hi)

        inc_off = jnp.where(incomplete, k_off, INF)
        first_inc = jax.ops.segment_min(inc_off, r, num_segments=W)
        advance = jnp.clip(jnp.minimum(first_inc, wkcur + allowed) - wkcur,
                           0, allowed)
        consumed = valid & ((wkcur + advance) >= wkend)
        alive = alive & (k_off < first_inc[r])
        n_proposed = (pvalid & (k_off < first_inc[r])).sum()

        kfull = pq.kcur.at[:W].set(wkcur + advance)
        live_row = jnp.arange(pq.prefix.shape[0], dtype=jnp.int32) < pq.size
        keep_rows = live_row & ~jnp.pad(consumed,
                                        (0, pq.prefix.shape[0] - W))
        (pfx, mini2, kc2, ke2, ww2), nsz = _compact(
            [pq.prefix, pq.mini, kfull, pq.kend, pq.weight], keep_rows)
        pieces = list(pieces)
        pieces[li] = PieceQueue(pfx, mini2, kc2, ke2, ww2, nsz)

        out_buf, out_weight = state.out_buf, state.out_weight
        out_n, out_count = state.out_n, state.out_count
        overflow = state.overflow
        queues = list(state.queues)
        if is_last:
            out_count = out_count + (weight * alive).sum().astype(jnp.int64)
            if dcfg.base.mode == "collect":
                perm = np.argsort(np.asarray(plan.attr_order))
                out_buf, n_new, ovf1 = _scatter_append(
                    out_buf, out_n, new_prefix[:, perm], alive)
                out_weight, _, _ = _scatter_append(
                    out_weight, out_n, weight, alive)
                out_n = jnp.minimum(out_n + n_new,
                                    jnp.int32(out_buf.shape[0]))
                overflow = overflow | jnp.where(ovf1, OVF_OUT, 0)
        else:
            nxt = queues[li + 1]
            npfx, n_new, ovf1 = _scatter_append(
                nxt.prefix, nxt.size, new_prefix, alive)
            nk, _, _ = _scatter_append(
                nxt.k, nxt.size, jnp.zeros(B, jnp.int32), alive)
            nw, _, _ = _scatter_append(nxt.weight, nxt.size, weight, alive)
            queues[li + 1] = LevelQueue(
                npfx, nk, nw,
                jnp.minimum(nxt.size + n_new,
                            jnp.int32(nxt.prefix.shape[0])))
            overflow = overflow | jnp.where(ovf1, OVF_QUEUE, 0)

        state = BigJoinState(
            tuple(queues), out_buf, out_weight, out_n, out_count, overflow,
            state.proposals + n_proposed.astype(jnp.int64),
            state.intersections + n_isect, recv_load)
        return state, tuple(pieces)

    return branch


def build_balanced_step(plan: Plan, dcfg: DistConfig):
    """Priority: deepest level first; within a level pieces before prefixes.

    Branch order: [piece_{L-1}, prefix_{L-1}, ..., piece_0, prefix_0].
    """
    L = len(plan.levels)
    branches, order = [], []
    for li in reversed(range(L)):
        branches.append(_build_piece_branch(plan, dcfg, li))
        order.append(("piece", li))
        branches.append(_build_balance_prefix_branch(plan, dcfg, li))
        order.append(("prefix", li))

    def step(carry, indices):
        state, pieces = carry
        sizes = []
        for kind, li in order:
            sizes.append(pieces[li].size if kind == "piece"
                         else state.queues[li].size)
        gsizes = jax.lax.psum(jnp.stack(sizes), dcfg.axis)
        sel = jnp.argmax(gsizes > 0).astype(jnp.int32)
        sel = jnp.clip(sel, 0, len(branches) - 1)
        return jax.lax.switch(sel, branches, carry, indices)

    return step
