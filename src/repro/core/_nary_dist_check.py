"""Multi-device multi-relation differential harness (§5.4 on a mesh).

Run as a subprocess so the XLA host-platform device-count override applies
before jax initializes (tests and benches must keep seeing 1 device):

    python -m repro.core._nary_dist_check --workers 4 --batches 20

One ``--workers``-way CPU-mesh :class:`repro.api.GraphSession` owns TWO
dynamic relations — the binary ``edge`` stream and the materialized ternary
``tri`` relation — and serves triangle (the tri feeder), 4-clique (the
edge-only reference) and 4-clique-tri (the §5.4 ternary plan).  Every
logical epoch applies one mixed insert/delete edge batch, then the signed
triangle delta to ``tri``; the 4-clique-tri output delta must match the
edge-only 4-clique delta BIT-EXACTLY (signed tuple sets, not counts).
Prints one JSON line: per-epoch wall times, exactness, shard accounting.
"""
import os
import sys

if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--nv", type=int, default=24)
    ap.add_argument("--ne", type=int, default=160)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=256,
                    help="B' proposal budget per worker per step")
    ap.add_argument("--local", action="store_true",
                    help="host-local session instead of the mesh")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.workers}")

    import json
    import time

    import numpy as np

    from repro.api import GraphSession, canon_signed as canon, oracle_count
    from repro.data.synthetic import EdgeUpdateStream, uniform_graph

    e = uniform_graph(args.nv, args.ne, args.seed)
    session = GraphSession(e, local=args.local, batch=args.batch,
                           out_capacity=1 << 18,
                           update_batch=args.batch_size)
    tri = session.register("triangle")
    c4 = session.register("4-clique")
    tri0, _ = tri.enumerate()
    session.add_relation("tri", tri0)
    c4t = session.register("4-clique-tri")
    static_exact = c4t.count() == c4.count() == oracle_count("4-clique", e)

    stream = EdgeUpdateStream(args.nv, args.batch_size, seed=args.seed + 1)
    epochs = []
    all_exact = bool(static_exact)
    live = session.edges
    for step in range(args.batches):
        upd, w = stream.batch_at(step, live=live)
        t0 = time.time()
        r1 = session.update(upd, w)
        td = r1.deltas["triangle"]
        t_upd = td.tuples if td.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = td.weights if td.weights is not None else \
            np.zeros(0, np.int32)
        r2 = session.update({"tri": (t_upd, t_w)})
        dt = time.time() - t0
        live = r1.advance(live)
        a, b = r1.deltas["4-clique"], r2.deltas["4-clique-tri"]
        exact = canon(b.tuples, b.weights) == canon(a.tuples, a.weights)
        all_exact = all_exact and exact
        epochs.append({
            "epoch": step, "updates": int(upd.shape[0]),
            "edge_delta": int(a.count_delta),
            "tri_rel_delta": int(td.count_delta),
            "exact": bool(exact), "elapsed_s": round(dt, 4)})

    # maintained totals survive full recomputation on BOTH plans
    net_exact = (c4.net_change == c4t.net_change ==
                 oracle_count("4-clique", session.edges)
                 - oracle_count("4-clique", e))
    all_exact = all_exact and bool(net_exact)
    shard_entries = sum(
        reg.versioned("new").live_entries()
        for reg in session.store.projections.values() if not reg.derived)
    out = {
        "workers": args.workers,
        "mode": "local" if args.local else "dist",
        "edges_start": int(e.shape[0]),
        "edges_end": int(session.num_edges),
        "tri_end": int(session.num_tuples("tri")),
        "batches": args.batches, "batch_size": args.batch_size,
        "static_exact": bool(static_exact), "net_exact": bool(net_exact),
        "all_exact": bool(all_exact),
        "shard_entries": int(shard_entries),
        "warm_epochs_per_s": round(
            len(epochs[2:]) / max(sum(r["elapsed_s"] for r in epochs[2:]),
                                  1e-9), 2) if len(epochs) > 2 else None,
        "epochs": epochs,
    }
    print(json.dumps(out))
    sys.exit(0 if all_exact else 1)
