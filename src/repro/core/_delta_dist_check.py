"""Multi-device distributed Delta-BiGJoin differential harness.

Run as a subprocess so the XLA host-platform device-count override applies
before jax initializes (tests and benches must keep seeing 1 device):

    python -m repro.core._delta_dist_check --workers 4 --query triangle \
        --batches 20

Per update epoch it applies one mixed insert/delete batch through
``DistDeltaBigJoin`` on a ``--workers``-way CPU mesh and checks the SIGNED
output tuples bit-exactly against ``delta_oracle`` (full recomputation on
the before/after edge sets).  Prints one JSON line: per-epoch wall times,
throughput, exactness, and region-shard memory accounting.
"""
import os
import sys

if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--query", default="triangle")
    ap.add_argument("--nv", type=int, default=40)
    ap.add_argument("--ne", type=int, default=400)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=256,
                    help="B' proposal budget per worker per step")
    ap.add_argument("--balance", action="store_true")
    ap.add_argument("--skew", action="store_true")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the delta_oracle differential (bench mode)")
    ap.add_argument("--local", action="store_true",
                    help="host-local DeltaBigJoin instead of the mesh engine"
                    " (baseline for the streaming benchmark)")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.workers}")

    import json
    import time

    import numpy as np

    from repro.core import query as Q
    from repro.core.delta import DeltaBigJoin, delta_oracle
    from repro.core.distributed import (DistDeltaBigJoin,
                                        default_delta_config)
    from repro.data.synthetic import EdgeUpdateStream, uniform_graph

    rng = np.random.default_rng(args.seed)
    if args.skew:
        u = (rng.zipf(1.4, args.ne) % args.nv).astype(np.int64)
        v = rng.integers(0, args.nv, args.ne)
        keep = u != v
        e = np.unique(np.stack([u[keep], v[keep]], 1).astype(np.int32),
                      axis=0)
    else:
        e = uniform_graph(args.nv, args.ne, args.seed)

    q = Q.query_by_name(args.query)
    if args.local:
        from repro.core.bigjoin import BigJoinConfig
        eng = DeltaBigJoin(q, e, cfg=BigJoinConfig(
            batch=args.batch, seed_chunk=args.batch, mode="collect",
            out_capacity=1 << 18))
    else:
        eng = DistDeltaBigJoin(q, e, dcfg=default_delta_config(
            args.workers, batch=args.batch, balance=args.balance))
    stream = EdgeUpdateStream(args.nv, args.batch_size, seed=args.seed + 1)

    from repro.core.delta import canon_signed as canon

    epochs = []
    all_exact = True
    cur = e
    for step in range(args.batches):
        upd, w = stream.batch_at(step, live=cur)
        t0 = time.time()
        res = eng.apply(upd, w)
        dt = time.time() - t0
        changes = 0 if res.weights is None else int(
            np.abs(res.weights).sum())
        rec = {"epoch": step, "updates": int(upd.shape[0]),
               "count_delta": int(res.count_delta), "changes": changes,
               "elapsed_s": round(dt, 4),
               "updates_per_s": round(upd.shape[0] / max(dt, 1e-9), 1)}
        if not args.no_check:
            ot, ow = delta_oracle(q, cur, eng.edges)
            exact = canon(res.tuples, res.weights) == canon(ot, ow)
            rec["exact"] = bool(exact)
            all_exact = all_exact and exact
        cur = eng.edges.copy()  # keep the stream's live set current
        epochs.append(rec)

    # cluster-memory accounting: total live entries over every worker shard
    shard_entries = sum(
        reg.versioned("new").live_entries()
        for reg in eng.projections.values())
    out = {
        "query": args.query, "workers": args.workers,
        "mode": "local" if args.local else
        ("balance" if args.balance else "dist"),
        "edges_start": int(e.shape[0]), "edges_end": int(eng.edges.shape[0]),
        "batches": args.batches, "batch_size": args.batch_size,
        "all_exact": bool(all_exact), "shard_entries": int(shard_entries),
        "warm_epochs_per_s": round(
            len(epochs[2:]) / max(sum(r["elapsed_s"] for r in epochs[2:]),
                                  1e-9), 2) if len(epochs) > 2 else None,
        "epochs": epochs,
    }
    print(json.dumps(out))
    sys.exit(0 if all_exact else 1)
