"""Query planning for the GJ / BiGJoin dataflow.

A plan fixes the global attribute order (§2.2) and, for every prefix-extension
level, the set of *binding* atoms: atoms that constrain the next attribute in
terms of already-bound attributes.  Each binding atom at each level is backed
by one :class:`~repro.core.csr.PrefixIndex` built at index time.

Subgraph queries are seeded from P_2 = the tuples of one edge atom (§4.2)
rather than the empty prefix; remaining atoms over the first two attributes
become membership filters on the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.query import Atom, DeltaQuery, Filter, Query


@dataclasses.dataclass(frozen=True)
class Binding:
    """One atom constraining the extension of attribute ``ext_attr``.

    ``key_attrs`` are the atom's attributes already bound (in atom order),
    whose values form the lookup key into the atom's PrefixIndex.
    ``atom_idx`` identifies the atom (and hence its version in delta plans).
    ``index_id`` names the PrefixIndex serving this binding.
    """

    atom_idx: int
    rel: str
    key_attrs: Tuple[int, ...]
    ext_attr: int
    index_id: str
    is_last: bool  # True iff this level binds the atom's final free attribute


@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """Extend prefixes over ``bound_attrs`` with ``ext_attr``."""

    ext_attr: int
    bound_attrs: Tuple[int, ...]  # global order restricted to j bound attrs
    bindings: Tuple[Binding, ...]
    filters: Tuple[Filter, ...]  # inequality filters decidable at this level


@dataclasses.dataclass(frozen=True)
class Plan:
    query: Query
    attr_order: Tuple[int, ...]
    seed_atom: int  # atom supplying the seed prefixes P_w
    seed_cols: Tuple[int, ...]  # positions of order[:seed_width] in the atom
    seed_filters: Tuple[Binding, ...]  # other atoms inside the seed prefix
    seed_ineq: Tuple[Filter, ...]
    levels: Tuple[LevelPlan, ...]  # extensions for order[seed_width:]
    versions: Tuple[str, ...]  # per-atom version ("static" unless delta plan)

    @property
    def num_levels(self) -> int:
        return len(self.levels)

    @property
    def seed_width(self) -> int:
        """Width of the seed prefixes: 2 for projection-seeded static plans
        (P_2, §4.2), the seed atom's arity for dR-seeded delta plans — an
        n-ary dR tuple binds ALL its attributes at once (§3.3/Thm 3.2), so
        the dataflow starts at P_r and level li extends
        ``attr_order[seed_width + li]``."""
        return len(self.seed_cols)

    def index_ids(self) -> List[Tuple[str, str, Tuple[int, ...], int, str]]:
        """All (index_id, rel, key_positions, ext_position, version) needed.

        Positions are column positions *within the atom*, so index building
        does not depend on attribute numbering.
        """
        out = []
        seen = set()

        def add(b: Binding, atom: Atom, version: str):
            if b.index_id in seen:
                return
            seen.add(b.index_id)
            key_pos = tuple(atom.attrs.index(a) for a in b.key_attrs)
            ext_pos = atom.attrs.index(b.ext_attr)
            out.append((b.index_id, b.rel, key_pos, ext_pos, version))

        for b in self.seed_filters:
            add(b, self.query.atoms[b.atom_idx], self.versions[b.atom_idx])
        for lv in self.levels:
            for b in lv.bindings:
                add(b, self.query.atoms[b.atom_idx], self.versions[b.atom_idx])
        return out


def _index_id(atom_idx: int, key_attrs: Tuple[int, ...], ext: int,
              version: str) -> str:
    k = ",".join(map(str, key_attrs))
    return f"at{atom_idx}[{k}->{ext}]@{version}"


def choose_attribute_order(q: Query, seed_atom: Optional[int] = None,
                           seed_prefix: int = 2,
                           ) -> Tuple[Tuple[int, ...], int]:
    """Greedy order: start with the seed atom's first ``seed_prefix``
    attributes (2 for projection-seeded plans; the full atom for dR-seeded
    delta plans, Thm 3.2), then repeatedly pick the attribute constrained by
    the most already-bound atoms (ties: smallest id).
    Returns (order, seed_atom)."""
    if seed_atom is None:
        # prefer a binary atom; the attr pair covered by most atoms is a good
        # seed (more filters applied at P_2).  Fall back to any atom's first
        # two attributes (projection-seeded, e.g. the ternary tri relation).
        binary = [i for i, a in enumerate(q.atoms) if a.arity == 2]
        def pair_cover(i):
            s = set(q.atoms[i].attrs[:2])
            return sum(1 for a in q.atoms if set(a.attrs) <= s)
        pool = binary if binary else list(range(q.num_atoms))
        seed_atom = max(pool, key=pair_cover)
    first = q.atoms[seed_atom]
    order = list(first.attrs[:max(int(seed_prefix), 2)])
    bound = set(order)
    while len(order) < q.num_attrs:
        def score(a):
            if a in bound:
                return -1
            return sum(
                1 for atom in q.atoms
                if a in atom.attrs and any(x in bound for x in atom.attrs)
            )
        cand = max((a for a in range(q.num_attrs) if a not in bound),
                   key=lambda a: (score(a), -a))
        if score(cand) == 0:
            raise ValueError("query is disconnected; unsupported seed order")
        order.append(cand)
        bound.add(cand)
    return tuple(order), seed_atom


def make_plan(q: Query, attr_order: Optional[Sequence[int]] = None,
              seed_atom: Optional[int] = None,
              versions: Optional[Sequence[str]] = None,
              seed_width: int = 2) -> Plan:
    """Build the level-by-level plan for ``q`` under ``attr_order``.

    ``seed_width`` is the seed-prefix width: 2 for projection-seeded static
    plans (P_2), the seed atom's arity for dR-seeded delta plans — the
    first ``seed_width`` attributes of the order must be the seed atom's
    attributes, and extension levels cover ``attr_order[seed_width:]``.
    """
    sw = int(seed_width)
    if attr_order is None:
        attr_order, seed_atom = choose_attribute_order(q, seed_atom, sw)
    else:
        attr_order = tuple(attr_order)
        if seed_atom is None:
            for i, atom in enumerate(q.atoms):
                if set(attr_order[:sw]) <= set(atom.attrs):
                    seed_atom = i
                    break
            else:
                raise ValueError(
                    f"no atom covers the first {sw} attributes")
    if versions is None:
        versions = tuple("static" for _ in q.atoms)
    else:
        versions = tuple(versions)

    seed_attrs = attr_order[:sw]
    seed = q.atoms[seed_atom]
    if not set(seed_attrs) <= set(seed.attrs):
        raise ValueError(
            f"seed atom does not cover the first {sw} attributes")
    seed_cols = tuple(seed.attrs.index(a) for a in seed_attrs)

    # Other atoms fully contained in the seed prefix become membership
    # filters on the seed tuples (§4.2): key = all-but-last attr, in atom
    # order, ext = the last — covered by composite keys up to arity 4.
    seed_filters = []
    for i, atom in enumerate(q.atoms):
        if i == seed_atom or not set(atom.attrs) <= set(seed_attrs):
            continue
        key = atom.attrs[:-1]
        ext = atom.attrs[-1]
        seed_filters.append(Binding(
            i, atom.rel, key, ext,
            _index_id(i, key, ext, versions[i]), True))
    seed_ineq = tuple(f for f in q.filters
                      if {f.lo, f.hi} <= set(seed_attrs))

    levels: List[LevelPlan] = []
    bound: List[int] = list(seed_attrs)
    done_filters = set(id(f) for f in seed_ineq)
    for ext in attr_order[sw:]:
        bindings = []
        for i, atom in enumerate(q.atoms):
            if ext not in atom.attrs:
                continue
            bound_in_atom = tuple(a for a in atom.attrs
                                  if a in bound)
            if not bound_in_atom:
                continue  # constrains nothing yet
            free = [a for a in atom.attrs if a not in bound and a != ext]
            bindings.append(Binding(
                i, atom.rel, bound_in_atom, ext,
                _index_id(i, bound_in_atom, ext, versions[i]),
                is_last=not free))
        if not bindings:
            raise ValueError(f"attribute a{ext} unconstrained at its level")
        ineq = tuple(
            f for f in q.filters
            if id(f) not in done_filters
            and {f.lo, f.hi} <= set(bound) | {ext})
        done_filters.update(id(f) for f in ineq)
        levels.append(LevelPlan(ext, tuple(bound), tuple(bindings), ineq))
        bound.append(ext)

    return Plan(q, tuple(attr_order), seed_atom, seed_cols,
                tuple(seed_filters), seed_ineq, tuple(levels), versions)


def make_delta_plan(dq: DeltaQuery,
                    attr_order: Optional[Sequence[int]] = None) -> Plan:
    """Plan for dQ_i: the attribute order starts with ALL of atom i's
    attributes (Thm 3.2) and the dataflow is seeded from dR_i's full tuples
    — width-2 prefixes for binary atoms, width-r for an n-ary dR_i (every
    seed tuple binds the whole atom at once, so the dataflow starts at P_r
    and skips the first r-2 extension levels); atoms k<i read version
    'new', atoms k>i read 'old' (§3.3)."""
    q = dq.query
    seed = q.atoms[dq.seed_atom]
    sw = seed.arity
    if attr_order is None:
        rest_order, _ = choose_attribute_order(q, seed_atom=dq.seed_atom,
                                               seed_prefix=sw)
        attr_order = rest_order
    if set(attr_order[:sw]) != set(seed.attrs):
        raise ValueError(
            "delta attribute order must start with the seed atom's attrs")
    return make_plan(q, attr_order, dq.seed_atom, dq.versions,
                     seed_width=sw)
