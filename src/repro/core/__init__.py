"""The paper's primary contribution: worst-case optimal join dataflows.

- query/plan: conjunctive queries + GJ attribute-order planning
- csr/dataflow_index: sorted-array extension indices (static + multiversion)
- generic_join: serial numpy oracle (COST baseline)
- bigjoin: the batched dataflow primitive + static-join driver
- delta: Delta-GJ / Delta-BiGJoin incremental maintenance
- distributed: shard_map multi-worker dataflow (hash-routed)
- balance: BiGJoin-S skew-resilient operators
- optimizations: §5.4 symmetry breaking / triangle indexing / factorization
"""
