"""Versioned extension indices for the BiGJoin dataflow.

A :class:`VersionedIndex` is the multi-region structure of §4.3 flattened to
arrays: *positive* regions contribute extensions (compacted base, committed
inserts, uncommitted inserts) and *negative* regions subtract membership
(committed / uncommitted deletes).  The three logical versions map to region
subsets:

    static:  pos=(base,)                 neg=()
    old:     pos=(base, cins)            neg=(cdel,)
    new:     pos=(base, cins, uins)      neg=(cdel, udel)

Counts and proposals come from positive regions only; deletions are applied
as a post-filter on proposals and as signed membership.  Update application
(`delta.py`) maintains the invariant that inserts are new edges and deletes
target live edges, so positive regions never contain duplicates and the
signed membership is exact 0/1.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.csr import IndexData, index_member, index_range


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VersionedIndex:
    pos: Tuple[IndexData, ...]
    neg: Tuple[IndexData, ...]

    def tree_flatten(self):
        return (self.pos, self.neg), (len(self.pos), len(self.neg))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children[0]), tuple(children[1]))

    @classmethod
    def static(cls, data: IndexData) -> "VersionedIndex":
        return cls((data,), ())

    @property
    def num_regions(self) -> int:
        return len(self.pos)

    def worker_shard(self, i: int = 0) -> "VersionedIndex":
        """Select worker ``i``'s slice of a sharded index whose regions carry
        a leading [w] worker axis (``csr.build_sharded_index``).  Inside
        ``shard_map`` the per-worker block has w=1, so ``worker_shard(0)``
        strips the axis; on the host it projects any worker's shard for
        inspection and parity tests."""
        def strip(d: IndexData) -> IndexData:
            return IndexData(d.key[i], d.val[i], d.n[i],
                             None if d.lo is None else d.lo[i])
        return VersionedIndex(tuple(strip(p) for p in self.pos),
                              tuple(strip(n) for n in self.neg))

    def live_entries(self) -> int:
        """Total live rows over every region (and every worker shard)."""
        import numpy as np
        return int(sum(np.asarray(d.n).sum()
                       for d in self.pos + self.neg))

    # ---- queries (vectorized over probe batch [B]) ------------------------

    def ranges(self, qkey: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(starts [B,R], counts [B,R]) over positive regions."""
        ss, cs = [], []
        for reg in self.pos:
            s, c = index_range(reg, qkey)
            ss.append(s)
            cs.append(c)
        return jnp.stack(ss, -1), jnp.stack(cs, -1)

    def count(self, qkey: jax.Array) -> jax.Array:
        """Positive-region extension count [B] (exact when no deletions)."""
        _, c = self.ranges(qkey)
        return c.sum(-1)

    def gather(self, starts: jax.Array, counts: jax.Array,
               k: jax.Array) -> jax.Array:
        """k-th extension across concatenated positive regions.

        starts/counts: [B, R] rows already gathered per probe; k: [B].
        """
        val = jnp.zeros(k.shape, jnp.int32)
        off = k
        for r, reg in enumerate(self.pos):
            in_r = (off >= 0) & (off < counts[..., r])
            pos = jnp.clip(starts[..., r] + off, 0, reg.capacity - 1)
            val = jnp.where(in_r, reg.val[pos], val)
            off = off - counts[..., r]
        return val

    @staticmethod
    def _kernel_ok(interpret, regions) -> bool:
        from repro.kernels.intersect.ops import default_interpret, fused_fits
        composite = [r.lo is not None for r in regions]
        if any(composite) and not all(composite):
            return False  # mixed 1-word/2-word regions never share a launch
        return default_interpret(interpret) or fused_fits(regions)

    def signed_member(self, qkey: jax.Array, qval: jax.Array,
                      use_kernel: bool = False,
                      interpret=None) -> Tuple[jax.Array, jax.Array]:
        """(membership, deletion) bits in ONE pass over all regions.

        With ``use_kernel`` this is a single fused ``pallas_call`` across
        every positive and negative region (R launches collapse to 1) —
        composite regions included, with ``qkey`` the (hi, lo) int64 probe
        pair; the jnp path mirrors the same signed-weight reduction.  A
        compiled (non-interpret) call whose regions exceed the VMEM budget
        falls back to the jnp path rather than failing Mosaic compilation.
        """
        if use_kernel and self._kernel_ok(interpret, self.pos + self.neg):
            from repro.kernels.intersect.ops import signed_member
            wpos, wneg = signed_member(self.pos, self.neg, qkey, qval,
                                       interpret=interpret)
            return (wpos - wneg) > 0, wneg > 0
        shape = qkey[0].shape if isinstance(qkey, tuple) else qkey.shape
        w = jnp.zeros(shape, jnp.int32)
        d = jnp.zeros(shape, bool)
        for reg in self.pos:
            w = w + index_member(reg, qkey, qval).astype(jnp.int32)
        for reg in self.neg:
            hit = index_member(reg, qkey, qval)
            w = w - hit.astype(jnp.int32)
            d = d | hit
        return w > 0, d

    def member(self, qkey: jax.Array, qval: jax.Array,
               use_kernel: bool = False, interpret=None) -> jax.Array:
        return self.signed_member(qkey, qval, use_kernel, interpret)[0]

    def deleted(self, qkey, qval: jax.Array,
                use_kernel: bool = False, interpret=None) -> jax.Array:
        shape = qkey[0].shape if isinstance(qkey, tuple) else qkey.shape
        if not self.neg:
            return jnp.zeros(shape, bool)
        if use_kernel and self._kernel_ok(interpret, self.neg):
            from repro.kernels.intersect.ops import signed_member
            _, wneg = signed_member((), self.neg, qkey, qval,
                                    interpret=interpret)
            return wneg > 0
        d = jnp.zeros(shape, bool)
        for reg in self.neg:
            d = d | index_member(reg, qkey, qval)
        return d
