from repro.distributed.sharding import (logical_sharding, shard_params,
                                        ShardingRules)
from repro.distributed.collectives import compressed_psum
