"""Distributed-optimization collectives.

``compressed_psum``: int8-quantized gradient all-reduce with error feedback
for the *cross-pod* data-parallel reduction — the slow inter-pod links carry
1/4 the bytes; the quantization residual is carried forward so the scheme is
unbiased over steps (EF-SGD).  Intra-pod reductions stay full precision.

``psum_scatter_matmul``: the collective-matmul building block — a shard_map
matmul whose contraction-axis reduction is a reduce_scatter instead of
all_reduce + slice, halving collective bytes for TP layers (used by the
§Perf hillclimb).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jax.Array, residual: jax.Array, axis: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 psum over ``axis`` (inside shard_map).

    Returns (mean-reduced gradient f32, new residual).  The residual holds
    what quantization dropped this step; adding it back next step keeps the
    long-run estimate unbiased.
    """
    x = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    new_residual = x - deq
    # int8 tensors sum without overflow in i32; scales are averaged.
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    scale_sum = jax.lax.psum(scale, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    # each peer contributed q_i * scale_i; approximating per-peer scales by
    # their mean is standard EF practice; the residual absorbs the error.
    mean = total.astype(jnp.float32) * (scale_sum / n) / n
    return mean, new_residual


def psum_scatter_matmul(x: jax.Array, w: jax.Array, axis: str,
                        ) -> jax.Array:
    """x [m, k_shard] @ w [k_shard, n] -> reduce_scatter'd [m, n/axis_size].

    The canonical TP second-matmul: partial products are reduce-scattered
    over the output feature axis rather than all-reduced, so each chip keeps
    exactly its shard and the wire bytes halve.
    """
    partial = jnp.einsum("mk,kn->mn", x, w)
    return jax.lax.psum_scatter(partial, axis, scatter_dimension=1,
                                tiled=True)
