"""Logical-axis sharding: name model dimensions once, map to mesh axes.

Model code annotates parameters/activations with *logical* axis names
("embed", "vocab", "expert", "kv", ...); a ShardingRules table maps those to
physical mesh axes ("data", "model", "pod").  This is the MaxText-style
indirection that lets one model definition serve every mesh in launch/mesh.py
— including the multi-pod (pod, data, model) production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# default logical->physical table for the production meshes
DEFAULT_RULES: Dict[str, Optional[object]] = {
    "batch": ("pod", "data"),  # DP over pods x data axis
    "batch_dp3": ("pod", "data", "model"),  # ZeRO-3 cells: DP everywhere
    "seq": None,  # sequence kept unsharded by default (SP selectively)
    "seq_shard": "model",  # sequence parallelism for long-context cells
    "embed": "data",  # FSDP: weight embed-dim over the DP axis
    "mlp": "model",  # TP: hidden of MLPs
    "heads": "model",  # TP: attention heads
    "kv_heads": "model",
    "vocab": "model",  # TP: embedding/unembedding
    "expert": "model",  # EP: MoE experts
    "nodes": ("pod", "data"),  # GNN: node partition
    "edges": ("pod", "data"),  # GNN: edge partition
    "feat": None,
    "table_rows": "model",  # recsys: embedding tables row-sharded
    "candidates": ("pod", "data"),  # retrieval scoring partition
    "workers": ("pod", "data", "model"),  # WCOJ: every chip is a worker
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    table: Tuple[Tuple[str, Optional[object]], ...]

    @classmethod
    def default(cls, **overrides) -> "ShardingRules":
        t = dict(DEFAULT_RULES)
        t.update(overrides)
        return cls(tuple(sorted(t.items(), key=lambda kv: kv[0])))

    def physical(self, logical: Tuple[Optional[str], ...],
                 mesh: Mesh,
                 shape: Optional[Tuple[int, ...]] = None) -> P:
        """Logical -> physical spec.  With ``shape``, axes that do not
        evenly divide their dimension are dropped (8 experts cannot shard
        over a 16-way axis; the next mapped dimension then gets the axis)."""
        sizes = dict(mesh.shape)
        axes = []
        used = set()
        t = dict(self.table)
        for i, name in enumerate(logical):
            if name is None:
                axes.append(None)
                continue
            phys = t.get(name)
            cands = (phys if isinstance(phys, tuple)
                     else ((phys,) if phys else ()))
            kept, prod = [], 1
            for p in cands:
                if p not in sizes or p in used:
                    continue
                if shape is not None and \
                        shape[i] % (prod * sizes[p]) != 0:
                    continue
                kept.append(p)
                used.add(p)
                prod *= sizes[p]
            if not kept:
                axes.append(None)
            elif isinstance(phys, tuple):
                axes.append(tuple(kept))  # keep the declared tuple form
            else:
                axes.append(kept[0])
        return P(*axes)


def logical_sharding(logical: Tuple[Optional[str], ...], mesh: Mesh,
                     rules: Optional[ShardingRules] = None) -> NamedSharding:
    rules = rules or ShardingRules.default()
    return NamedSharding(mesh, rules.physical(logical, mesh))


def shard_params(params, logical_axes, mesh: Mesh,
                 rules: Optional[ShardingRules] = None):
    """device_put a param pytree according to its logical-axes pytree."""
    rules = rules or ShardingRules.default()
    return jax.tree.map(
        lambda p, ax: jax.device_put(
            p, logical_sharding(ax, mesh, rules)),
        params, logical_axes,
        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))


def sharding_tree(logical_axes, mesh: Mesh, template=None,
                  rules: Optional[ShardingRules] = None):
    """Pytree of NamedShardings from a pytree of logical axis tuples.

    ``template`` (matching pytree of arrays/ShapeDtypeStructs) enables the
    shape-aware divisibility filtering of ``ShardingRules.physical``."""
    rules = rules or ShardingRules.default()
    is_ax = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    if template is None:
        return jax.tree.map(
            lambda ax: logical_sharding(ax, mesh, rules), logical_axes,
            is_leaf=is_ax)
    return jax.tree.map(
        lambda ax, leaf: NamedSharding(
            mesh, rules.physical(ax, mesh, tuple(leaf.shape))),
        logical_axes, template, is_leaf=is_ax)
