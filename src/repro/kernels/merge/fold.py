"""Pallas kernel: the fused per-relation commit fold — ONE launch per epoch.

The committed-region fold of one epoch (``delta._commit_fold_impl``)

    cins' = (cins \\ udel) ∪ (uins \\ cdel)
    cdel' = cdel ∪ (udel ∩ base)

is a chain of five rank-based select/merge folds; run as separate jitted jnp
stages every stage round-trips its delta-sized operands through HBM and
re-issues its own fixed-depth searches.  This kernel computes BOTH outputs
in a single ``pallas_call``: the four committed/staged regions live in VMEM
for the whole fold, the select stages become keep-mask + cumsum compaction
gathers, and the merge stages become rank gathers — no scatters anywhere;
every output slot locates its source by binary search, which keeps the body
in the same fixed-depth-search vocabulary as the intersect/extend kernels.

``base`` is deliberately NOT a kernel input: it is the one region whose
size is O(|E|) rather than O(|Δ| + |committed|) and would blow the VMEM
budget.  Its only role in the fold is the membership probe ``udel ∩ base``,
which the caller precomputes with the jnp fixed-depth search (a delta-sized
bit vector, O(|Δ|·log|base|)) and passes in as ``in_ba`` — the fold itself
stays one launch per relation.

Select (keep-mask compaction, gather form):

    kc     = inclusive cumsum of keep;  n_out = kc[cap-1]
    out[t] = src[first i with kc[i] == t+1]   for t < n_out, sentinel after

Disjoint merge (rank-gather form) of sentinel-padded A [capA], B [capB]
(B pre-deduplicated against A, so live entries are disjoint):

    rank_a[i] = i + |{B < A[i]}|    (searched over the FULL padded B with
    rank_b[j] = j + |{A <= B[j]}|    side left/right, so A's sentinel
                                     padding ranks land in
                                     [n_a+n_b, capA-1+n_b] and B's in
                                     [capA+n_b, ∞) — both rank arrays are
                                     strictly increasing and collision-free)
    out[t] = A[ia] if rank_a[ia] == t else B[ib] if rank_b[ib] == t
             else sentinel,   ia/ib = searchsorted(rank_*, t, left)

Sentinel slots gather sentinel sources, so the outputs carry exactly the
``csr._empty_like_caps`` padding and both outputs are bit-identical to the
jnp chain (tests/test_merge_kernel.py).  Composite 2-word keys ride along
as one extra int64 column in every compare — ``csr.lex_searchsorted_cols``
runs unchanged inside the kernel body, so parity is by construction.

Sharded stores run the SAME kernel over ``grid=(w,)`` with (1, cap) blocks:
ownership is by packed key, so every shard's fold is local and the
distributed commit needs no vmap over per-shard launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.csr import IndexData, SENTINEL, lex_searchsorted_cols
from repro.kernels.extend.extend import _searchsorted
from repro.kernels.intersect.ops import FUSED_VMEM_BUDGET, default_interpret


def _iota(n: int) -> jax.Array:
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)[:, 0]


def _sentinels(cols):
    """Per-column padding values of the IndexData layout: key sentinel by
    dtype, int64 sentinel for the composite lo word, 0 for val — exactly
    ``csr._empty_like_caps``."""
    key = cols[0]
    sent = jnp.asarray(np.iinfo(np.dtype(key.dtype.name)).max, key.dtype)
    if len(cols) == 3:
        return (sent, jnp.asarray(SENTINEL, jnp.int64), jnp.int32(0))
    return (sent, jnp.int32(0))


def _member(cols, n, qcols):
    """[B] bool: is each qcols row among the first ``n`` rows of the
    lex-sorted ``cols``?  One fixed-depth search + equality check."""
    cap = cols[0].shape[0]
    pos = lex_searchsorted_cols(cols, n, qcols)
    pc = jnp.clip(pos, 0, cap - 1)
    hit = pos < n
    for c, q in zip(cols, qcols):
        hit = hit & (c[pc] == q)
    return hit


def _compact(cols, keep, sents):
    """Gather the kept rows of ``cols`` to a dense sentinel-padded prefix."""
    cap = keep.shape[0]
    kc = jnp.cumsum(keep.astype(jnp.int32))
    n_out = kc[cap - 1]
    t = _iota(cap)
    src = jnp.clip(_searchsorted(kc, t + 1, "left"), 0, cap - 1)
    valid = t < n_out
    return tuple(jnp.where(valid, c[src], s)
                 for c, s in zip(cols, sents)), n_out


def _rank_merge(a_cols, b_cols, n_a, n_b, out_cap: int, sents):
    """Disjoint sorted merge by rank gather (see module docstring)."""
    capA = a_cols[0].shape[0]
    capB = b_cols[0].shape[0]
    rank_a = _iota(capA) + lex_searchsorted_cols(
        b_cols, jnp.asarray(capB, jnp.int32), a_cols, "left")
    rank_b = _iota(capB) + lex_searchsorted_cols(
        a_cols, jnp.asarray(capA, jnp.int32), b_cols, "right")
    t = _iota(out_cap)
    ia = jnp.clip(_searchsorted(rank_a, t, "left"), 0, capA - 1)
    ib = jnp.clip(_searchsorted(rank_b, t, "left"), 0, capB - 1)
    hit_a = rank_a[ia] == t
    hit_b = rank_b[ib] == t
    outs = tuple(jnp.where(hit_a, ac[ia], jnp.where(hit_b, bc[ib], s))
                 for ac, bc, s in zip(a_cols, b_cols, sents))
    return outs, n_a + n_b


def make_fold_kernel(composite: bool):
    """Build the fused commit-fold kernel.

    Ref layout (inputs): per region in (cins, cdel, uins, udel) order:
    key [1, cap], lo [1, cap] (composite only), val [1, cap], n [1];
    then in_ba [1, cap_udel] int32 (``udel ∩ base`` bits, precomputed).
    Outputs: cins' then cdel', each key[, lo], val as [1, out_cap] plus
    n [1].
    """
    per = 4 if composite else 3

    def kernel(*refs):
        regs = [refs[per * r: per * (r + 1)] for r in range(4)]
        in_ba_ref = refs[per * 4]
        out_refs = refs[per * 4 + 1:]

        def load(reg):
            return tuple(r[...][0] for r in reg[:-1]), reg[-1][0]

        (ci, n_ci), (cd, n_cd), (ui, n_ui), (ud, n_ud) = \
            (load(r) for r in regs)
        in_ba = in_ba_ref[...][0]
        sents = _sentinels(ci)

        # ---- cins' = (cins \ udel) ∪ (uins \ cdel \ kept) -----------------
        keep_ci = (_iota(ci[0].shape[0]) < n_ci) & ~_member(ud, n_ud, ci)
        kept, n_kept = _compact(ci, keep_ci, sents)
        keep_ui = ((_iota(ui[0].shape[0]) < n_ui)
                   & ~_member(cd, n_cd, ui) & ~_member(kept, n_kept, ui))
        fresh, n_fresh = _compact(ui, keep_ui, sents)
        cins_cap = out_refs[0].shape[-1]
        new_ci, n_new_ci = _rank_merge(kept, fresh, n_kept, n_fresh,
                                       cins_cap, sents)

        # ---- cdel' = cdel ∪ (udel ∩ base, deduped vs cdel) ----------------
        keep_ud = ((_iota(ud[0].shape[0]) < n_ud) & (in_ba > 0)
                   & ~_member(cd, n_cd, ud))
        dead, n_dead = _compact(ud, keep_ud, sents)
        cdel_cap = out_refs[per].shape[-1]
        new_cd, n_new_cd = _rank_merge(cd, dead, n_cd, n_dead,
                                       cdel_cap, sents)

        o = 0
        for cols, n_out in ((new_ci, n_new_ci), (new_cd, n_new_cd)):
            for c in cols:
                out_refs[o][...] = c[None, :]
                o += 1
            out_refs[o][...] = n_out.reshape(1)
            o += 1

    return kernel


def fold_fits(cins: IndexData, cdel: IndexData, uins: IndexData,
              udel: IndexData, cins_cap: int, cdel_cap: int) -> bool:
    """Static check that one grid step's working set — the four regions,
    the in_ba bits, both outputs, and the int32 cumsum/rank temporaries
    (bounded by a 2x factor) — fits the compiled kernel's VMEM budget."""
    composite = cins.lo is not None
    extra = 8 if composite else 0

    def b(cap, dt):
        return int(cap) * (jnp.dtype(dt).itemsize + 4 + extra)

    regions = (cins, cdel, uins, udel)
    total = sum(b(r.key.shape[-1], r.key.dtype) for r in regions)
    total += 4 * udel.key.shape[-1]  # in_ba
    total += b(cins_cap, cins.key.dtype) + b(cdel_cap, cdel.key.dtype)
    return 2 * total <= FUSED_VMEM_BUDGET


def commit_fold_ok(cins: IndexData, cdel: IndexData, uins: IndexData,
                   udel: IndexData, cins_cap: int, cdel_cap: int,
                   interpret=None) -> bool:
    """Can the fused kernel serve this fold?  Regions must agree on the
    key layout (all composite or none, one hi-word dtype — true by
    construction for the regions of one RegionStore), and a compiled
    (non-interpret) call must fit the VMEM budget."""
    regions = (cins, cdel, uins, udel)
    if len({r.lo is None for r in regions}) > 1:
        return False
    if len({jnp.dtype(r.key.dtype) for r in regions}) > 1:
        return False
    return default_interpret(interpret) or fold_fits(
        cins, cdel, uins, udel, cins_cap, cdel_cap)


@functools.partial(jax.jit, static_argnames=("cins_cap", "cdel_cap",
                                             "sharded", "interpret"))
def _fold_call(cins, cdel, uins, udel, in_ba, cins_cap: int, cdel_cap: int,
               sharded: bool, interpret: bool):
    composite = cins.lo is not None
    G = cins.key.shape[0] if sharded else 1

    def pack(d):
        def lead(a):
            return a if sharded else a[None]
        cols = [lead(d.key)] + ([lead(d.lo)] if composite else []) \
            + [lead(d.val)]
        return cols + [d.n.reshape(G).astype(jnp.int32)]

    flat = pack(cins) + pack(cdel) + pack(uins) + pack(udel)
    flat.append(in_ba.astype(jnp.int32).reshape(G, -1))
    in_specs = [
        pl.BlockSpec((1, a.shape[-1]), lambda i: (i, 0)) if a.ndim == 2
        else pl.BlockSpec((1,), lambda i: (i,))
        for a in flat]
    kd = cins.key.dtype

    def outset(cap):
        shapes = [jax.ShapeDtypeStruct((G, cap), kd)]
        if composite:
            shapes.append(jax.ShapeDtypeStruct((G, cap), jnp.int64))
        shapes.append(jax.ShapeDtypeStruct((G, cap), jnp.int32))
        shapes.append(jax.ShapeDtypeStruct((G,), jnp.int32))
        return shapes

    out_shape = tuple(outset(cins_cap) + outset(cdel_cap))
    out_specs = tuple(
        pl.BlockSpec((1, s.shape[-1]), lambda i: (i, 0))
        if len(s.shape) == 2 else pl.BlockSpec((1,), lambda i: (i,))
        for s in out_shape)
    outs = pl.pallas_call(
        make_fold_kernel(composite),
        grid=(G,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*flat)
    per = 4 if composite else 3

    def unpack(tup):
        key, val, n = tup[0], tup[-2], tup[-1]
        lo = tup[1] if composite else None
        if not sharded:
            key, val, n = key[0], val[0], n[0]
            lo = None if lo is None else lo[0]
        return IndexData(key, val, n, lo)

    return unpack(outs[:per]), unpack(outs[per:])


def commit_fold(cins: IndexData, cdel: IndexData, uins: IndexData,
                udel: IndexData, in_ba: jax.Array, *, cins_cap: int,
                cdel_cap: int, sharded: bool = False, interpret=None):
    """(cins', cdel') of one epoch in a single ``pallas_call``.

    ``in_ba``: int32/bool [cap_udel] (leading [w] axis when ``sharded``)
    membership bits of udel's rows in the base region, precomputed by the
    caller with the jnp fixed-depth probe.  Caller is responsible for
    gating via :func:`commit_fold_ok`.
    """
    return _fold_call(cins, cdel, uins, udel, in_ba,
                      cins_cap=int(cins_cap), cdel_cap=int(cdel_cap),
                      sharded=bool(sharded),
                      interpret=default_interpret(interpret))
