"""Pure-jnp oracle for the merge rank kernel.

Two fixed-depth lexicographic binary searches over the sorted dual (or, for
composite 2-word keys, triple) arrays — exactly ``csr.lex_searchsorted_cols``
with both sides.  The Pallas kernel (`merge.py`) must match this bit-exactly
(tests/test_merge_kernel.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import lex_searchsorted, lex_searchsorted_cols


def rank_ref(keys: jax.Array, vals: jax.Array, n: jax.Array,
             qk: jax.Array, qv: jax.Array, lo=None, qlo=None):
    """(lt, le) int32 [B]: entries lexicographically < / <= each query.

    ``lo``/``qlo`` carry the int64 secondary words for composite keys.  The
    hi-word compare promotes mixed widths (``lex_searchsorted_cols`` never
    truncates), matching the kernel wrapper's promotion.
    """
    qv = qv.astype(jnp.int32)
    if lo is not None:
        cols = (keys, lo.astype(jnp.int64), vals)
        qcols = (qk, qlo.astype(jnp.int64), qv)
        return (lex_searchsorted_cols(cols, n, qcols, side="left"),
                lex_searchsorted_cols(cols, n, qcols, side="right"))
    # mixed widths promote inside the column compares — never downcast qk
    lt = lex_searchsorted(keys, vals, n, qk, qv, side="left")
    le = lex_searchsorted(keys, vals, n, qk, qv, side="right")
    return lt, le
