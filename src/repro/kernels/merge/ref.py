"""Pure-jnp oracle for the merge rank kernel.

Two fixed-depth lexicographic binary searches over the sorted (key, val)
dual arrays — exactly ``csr.lex_searchsorted`` with both sides.  The Pallas
kernel (`merge.py`) must match this bit-exactly (tests/test_merge_kernel.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import lex_searchsorted


def rank_ref(keys: jax.Array, vals: jax.Array, n: jax.Array,
             qk: jax.Array, qv: jax.Array):
    """(lt, le) int32 [B]: entries lexicographically < / <= each query."""
    qk = qk.astype(keys.dtype)
    qv = qv.astype(jnp.int32)
    lt = lex_searchsorted(keys, vals, n, qk, qv, side="left")
    le = lex_searchsorted(keys, vals, n, qk, qv, side="right")
    return lt, le
