"""Batched merge-rank kernel backing the device-resident RegionStore's
sorted-merge/diff/intersect folds (see merge.py for the rank algebra)."""
from repro.kernels.merge.ops import rank_lt_le  # noqa: F401
