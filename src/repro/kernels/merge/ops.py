"""Routing for the merge rank kernel: compiled Mosaic on TPU, jnp oracle
elsewhere.

Unlike the membership kernels, interpret mode is NOT a production fallback
here — the rank pass sits on the per-epoch commit path, where interpret
overhead would swamp the merge win — so off-TPU the jnp oracle runs
directly and the interpreted kernel exists only for parity tests
(``interpret=True``).
"""
from __future__ import annotations

import jax

from repro.kernels.merge.merge import rank_counts
from repro.kernels.merge.ref import rank_ref


def rank_lt_le(keys: jax.Array, vals: jax.Array, n: jax.Array,
               qk: jax.Array, qv: jax.Array, interpret=None):
    """(lt, le) merge ranks of each (qk, qv) in the sorted index arrays.

    ``interpret=None``: compiled kernel on a TPU backend — IF the
    VMEM-resident index fits the budget (compaction folds pass the full
    base region here; an over-budget index falls back to the jnp oracle
    instead of failing Mosaic, same policy as the intersect kernels) —
    jnp oracle elsewhere.  ``interpret=True`` forces the interpreted
    kernel (parity tests only); ``interpret=False`` forces compiled
    Mosaic.
    """
    if interpret is None:
        from repro.kernels.intersect.ops import FUSED_VMEM_BUDGET
        idx_bytes = keys.shape[-1] * (keys.dtype.itemsize + 4)
        if jax.default_backend() != "tpu" or \
                idx_bytes > FUSED_VMEM_BUDGET:
            return rank_ref(keys, vals, n, qk, qv)
        interpret = False
    return rank_counts(keys, vals, n, qk, qv, interpret=interpret)
