"""Routing for the merge rank kernel: compiled Mosaic on TPU, interpreted
kernel elsewhere.

Platform gating matches the intersect kernels (``default_interpret``): on a
TPU backend the compiled kernel runs IF the VMEM-resident index fits the
budget (an over-budget index falls back to the jnp oracle instead of
failing Mosaic compilation); off-TPU the interpreted kernel is the
*production* path — interpret mode lowers the kernel body through XLA, so
the 4-device CPU CI lane exercises the same fused commit-fold code path the
TPU runs, with bit-exact results (tests/test_merge_kernel.py).
"""
from __future__ import annotations

import jax

from repro.kernels.merge.merge import rank_counts
from repro.kernels.merge.ref import rank_ref


def rank_lt_le(keys: jax.Array, vals: jax.Array, n: jax.Array,
               qk: jax.Array, qv: jax.Array, lo=None, qlo=None,
               interpret=None):
    """(lt, le) merge ranks of each (qk[, qlo], qv) in the sorted index.

    ``lo``/``qlo``: the int64 secondary words when the index carries
    composite 2-word keys.  ``interpret=None`` defers to platform
    detection: compiled kernel on TPU when the index fits the VMEM budget,
    jnp oracle when it does not, interpreted kernel off-TPU.  An explicit
    bool forces that kernel mode.
    """
    if interpret is None:
        from repro.kernels.intersect.ops import (FUSED_VMEM_BUDGET,
                                                 default_interpret)
        interpret = default_interpret(None)
        if not interpret:
            idx_bytes = keys.shape[-1] * (keys.dtype.itemsize + 4
                                          + (8 if lo is not None else 0))
            if idx_bytes > FUSED_VMEM_BUDGET:
                return rank_ref(keys, vals, n, qk, qv, lo=lo, qlo=qlo)
    return rank_counts(keys, vals, n, qk, qv, interpret=bool(interpret),
                       lo=lo, qlo=qlo)
