"""Pallas TPU kernel: batched merge ranks over a sorted (key, val) set.

The device-resident RegionStore (core/delta.py) maintains every index region
by *sorted merge*, never by rebuild.  The only non-trivial step of a sorted
merge/diff/intersect between deduped sets is computing, for each entry of one
set, its RANK in the other — the count of entries lexicographically `<` and
`<=` it.  With both counts every set operation is a pure scatter:

    merge position of a[i] in a ∪ b  =  i + |{b < a[i]}|
    merge position of b[j] in a ∪ b  =  j + |{a <= b[j]}|
    a[i] ∈ b                        ⇔  |{b <= a[i]}| > |{b < a[i]}|

so union/diff/intersect all reduce to one rank pass + one O(n) scatter — the
static-shape analogue of a two-pointer merge (the pointer advance *is* the
rank).  This kernel computes both counts for a BQ query tile per grid step
against the full VMEM-resident index, reusing the two-level segment-major
layout of the intersect kernel (DESIGN.md §2): a router binary search picks
each query's segment, one [BQ, SEG] row gather + lane-wise compares yield the
in-segment counts, and the segment base contributes ``seg * SEG`` entries
(everything in earlier segments is strictly below the query because the
router leader of the query's segment is `<=` it and entries are unique).

ref.py is the pure-jnp oracle (two fixed-depth lexicographic binary
searches); parity is bit-exact.  ops.py routes: compiled Mosaic on TPU
(VMEM-gated), interpreted kernel elsewhere — interpret mode lowers the
kernel body through XLA, so the CPU CI lane runs the same fused fold path
the TPU runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.csr import SEG  # canonical segment length (see csr.py)
from repro.kernels.intersect.intersect import _router_depth

BQ = 256  # queries per grid step


def _rank_counts(keys2d: jax.Array, vals2d: jax.Array, n: jax.Array,
                 qk: jax.Array, qv: jax.Array,
                 los2d: jax.Array | None = None,
                 ql: jax.Array | None = None):
    """(lt, le) int32 [BQ]: entries lexicographically < / <= each query.

    keys2d/vals2d: [num_segments, SEG] sorted segment-major with sentinel
    padding (unique live entries); n: [] live count; qk/qv: [BQ].  For a
    composite 2-word key, ``los2d`` [num_segments, SEG] int64 carries the
    secondary word and ``ql`` [BQ] the query lo word — the router and lane
    compares become 3-word lexicographic (hi, lo, val), one extra row
    gather, same tile shapes as the intersect kernel.
    """
    num_segments = keys2d.shape[0]
    composite = los2d is not None
    rk = keys2d[:, 0]
    rl = los2d[:, 0] if composite else None
    rv = vals2d[:, 0]

    # ---- level 1: last segment whose leader <= query ----------------------
    lo = jnp.zeros(qk.shape, jnp.int32)
    hi = jnp.full(qk.shape, num_segments, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        mc = jnp.clip(mid, 0, num_segments - 1)
        mk = rk[mc]
        mv = rv[mc]
        if composite:
            ml = rl[mc]
            le = (mk < qk) | ((mk == qk)
                             & ((ml < ql) | ((ml == ql) & (mv <= qv))))
        else:
            le = (mk < qk) | ((mk == qk) & (mv <= qv))
        sel = lo < hi
        lo = jnp.where(le & sel, mid + 1, lo)
        hi = jnp.where(~le & sel, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, _router_depth(num_segments), body, (lo, hi))
    seg = jnp.maximum(lo - 1, 0)

    # ---- level 2: in-segment counts from one [BQ, SEG] gather --------------
    kseg = keys2d[seg]
    vseg = vals2d[seg]
    col = jax.lax.broadcasted_iota(jnp.int32, kseg.shape, 1)
    idx = seg[:, None] * SEG + col
    live = idx < n
    keq = kseg == qk[:, None]
    if composite:
        lseg = los2d[seg]
        leq = keq & (lseg == ql[:, None])
        ltv = live & ((kseg < qk[:, None])
                      | (keq & (lseg < ql[:, None]))
                      | (leq & (vseg < qv[:, None])))
        eqv = live & leq & (vseg == qv[:, None])
    else:
        ltv = live & ((kseg < qk[:, None]) | (keq & (vseg < qv[:, None])))
        eqv = live & keq & (vseg == qv[:, None])
    # entries in earlier segments are live (padding is a suffix) and < query
    base = seg * SEG
    lt = base + ltv.sum(axis=1).astype(jnp.int32)
    return lt, lt + eqv.sum(axis=1).astype(jnp.int32)


def rank_kernel(keys_ref, vals_ref, n_ref, qk_ref, qv_ref, lt_ref, le_ref):
    """One grid step: BQ rank queries against the full segment-major index."""
    lt, le = _rank_counts(keys_ref[...], vals_ref[...], n_ref[0],
                          qk_ref[...], qv_ref[...])
    lt_ref[...] = lt
    le_ref[...] = le


def rank_kernel_lex(keys_ref, los_ref, vals_ref, n_ref, qk_ref, ql_ref,
                    qv_ref, lt_ref, le_ref):
    """Composite-key variant: BQ (qk, ql, qv) rank queries, 3-word lex."""
    lt, le = _rank_counts(keys_ref[...], vals_ref[...], n_ref[0],
                          qk_ref[...], qv_ref[...],
                          los2d=los_ref[...], ql=ql_ref[...])
    lt_ref[...] = lt
    le_ref[...] = le


@functools.partial(jax.jit, static_argnames=("interpret",))
def _rank_call(keys2d, vals2d, n, qk, qv, interpret: bool = True,
               los2d=None, ql=None):
    B = qk.shape[0]
    num_segments = keys2d.shape[0]
    grid = (B // BQ,)
    composite = los2d is not None
    full = pl.BlockSpec((num_segments, SEG), lambda i: (0, 0))
    qspec = pl.BlockSpec((BQ,), lambda i: (i,))
    in_specs = [full] + ([full] if composite else []) + [
        full,
        pl.BlockSpec((1,), lambda i: (0,)),
        qspec,
    ] + ([qspec] if composite else []) + [qspec]
    operands = ((keys2d, los2d, vals2d, n, qk, ql, qv) if composite
                else (keys2d, vals2d, n, qk, qv))
    return pl.pallas_call(
        rank_kernel_lex if composite else rank_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((BQ,), lambda i: (i,)),
                   pl.BlockSpec((BQ,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((B,), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.int32)),
        interpret=interpret,
    )(*operands)


def rank_counts(keys: jax.Array, vals: jax.Array, n: jax.Array,
                qk: jax.Array, qv: jax.Array, interpret: bool = True,
                lo=None, qlo=None):
    """(lt, le) [B] via the Pallas kernel, padding handled here.

    keys/vals: [cap] sorted lex (sentinel-padded, the IndexData layout);
    qk/qv: [B] queries; lo/qlo: the int64 secondary words for composite
    2-word keys.  Pads the index to a SEG multiple (segment-major reshape)
    and the query batch to a BQ multiple, then slices back.  Mixed-width
    hi words (narrow int32 index vs int64 queries, or vice versa) are
    promoted, never truncated — rank queries include sentinel-padded
    entries whose counts matter, unlike membership probes.
    """
    from repro.kernels.intersect.ops import (_pad_queries, _segment_major,
                                             _segment_major_lo)
    B = qk.shape[0]
    key_dtype = jnp.result_type(keys.dtype, qk.dtype)
    if key_dtype != keys.dtype:
        # promote a narrow index: re-sentinel the padding so the widened
        # suffix still sorts above every representable query
        live = jnp.arange(keys.shape[0], dtype=jnp.int32) < n
        keys = jnp.where(live, keys.astype(key_dtype),
                         jnp.asarray(np.iinfo(np.dtype(key_dtype.name)).max,
                                     key_dtype))
    keys2d, vals2d = _segment_major(keys.astype(key_dtype),
                                    vals.astype(jnp.int32))
    if lo is None:
        qkp, qvp = _pad_queries(qk, qv, key_dtype)
        los2d = qlp = None
    else:
        qkp, qvp, qlp = _pad_queries(qk, qv, key_dtype, ql=qlo)
        los2d = _segment_major_lo(lo)
    lt, le = _rank_call(keys2d, vals2d,
                        n.astype(jnp.int32).reshape(1), qkp, qvp,
                        interpret=bool(interpret), los2d=los2d, ql=qlp)
    return lt[:B], le[:B]
