"""Pallas TPU kernel: the fused BiGJoin extension step.

One dataflow step of the paper's Fig. 2 pipeline is, per popped prefix
window W and proposal budget B':

    count-minimization  (|Ext(p)| per binding, argmin)
    budget allocation   (rem-ext resumption cursors, prefix-sum)
    ragged expansion    (proposal t -> (prefix row, offset k))
    candidate gather    (k-th extension of the min binding)
    intersection        (membership of the candidate in every other binding,
                         deletion check in the min binding)

The unfused path runs these as ~5·NB separate XLA ops with the B'-sized
candidate batch round-tripping through HBM between every stage, plus R
``pallas_call`` launches per membership probe.  This kernel executes the
whole pipeline in a single ``pallas_call``: proposals are born in VMEM,
filtered in VMEM, and only the surviving (row, cand, alive) triple is
written back — the low-memory analogue of HUGE's fused enumeration stages.

Structure is static per (plan level, config): number of bindings, regions
per binding, array capacities, and the window/budget sizes all specialize
the kernel at trace time.  All searches are fixed-depth vectorized binary
searches (depth = ceil(log2 cap) + 1) over VMEM-resident arrays — the exact
algorithm of ``csr.lex_searchsorted``/``csr.index_range``, so results are
bit-identical to the unfused jnp path.  VMEM budget math lives in DESIGN.md
§"Fused extension pipeline".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _depth(n: int) -> int:
    return max(int(np.ceil(np.log2(max(n, 2)))), 1) + 1


def _searchsorted(arr: jax.Array, q: jax.Array, side: str) -> jax.Array:
    """Vectorized fixed-depth binary search: position of q in sorted arr.

    Matches ``jnp.searchsorted(arr, q, side)`` for nondecreasing ``arr``
    (sentinel padding included in the search range, as in csr.index_range).
    """
    n = arr.shape[0]
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, n, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        m = arr[jnp.clip(mid, 0, n - 1)]
        go = (m < q) if side == "left" else (m <= q)
        sel = lo < hi
        lo = jnp.where(go & sel, mid + 1, lo)
        hi = jnp.where(~go & sel, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, _depth(n), body, (lo, hi))
    return lo


def _lex_member(key: jax.Array, val: jax.Array, n: jax.Array,
                qk: jax.Array, qv: jax.Array) -> jax.Array:
    """int32 [B] membership of (qk, qv) in the sorted (key, val) pairs —
    the SAME search as the jnp oracle (``csr.lex_searchsorted`` is pure jnp
    and runs unchanged inside the kernel body), so parity is by construction
    rather than by a hand-synced copy."""
    from repro.core.csr import lex_searchsorted
    cap = key.shape[0]
    pos = lex_searchsorted(key, val, n, qk, qv)
    pc = jnp.clip(pos, 0, cap - 1)
    hit = (key[pc] == qk) & (val[pc] == qv) & (pos < n)
    return hit.astype(jnp.int32)


def _lex_member3(key: jax.Array, lo: jax.Array, val: jax.Array,
                 n: jax.Array, qh: jax.Array, ql: jax.Array,
                 qv: jax.Array) -> jax.Array:
    """Composite-key membership: (qh, ql, qv) in the lex-sorted
    (key, lo, val) triples — same construction as :func:`_lex_member`,
    through the generalized ``csr.lex_searchsorted_cols``."""
    from repro.core.csr import lex_searchsorted_cols
    cap = key.shape[0]
    pos = lex_searchsorted_cols((key, lo, val), n, (qh, ql, qv))
    pc = jnp.clip(pos, 0, cap - 1)
    hit = ((key[pc] == qh) & (lo[pc] == ql) & (val[pc] == qv)
           & (pos < n))
    return hit.astype(jnp.int32)


def _lex_range2(key: jax.Array, lo: jax.Array, qh: jax.Array,
                ql: jax.Array, side: str) -> jax.Array:
    """2-word lex bound over the FULL capacity (sentinel padding sorts
    above every live pair) — the in-kernel twin of the composite branch of
    ``csr.index_range``, via the same ``lex_searchsorted_cols``."""
    from repro.core.csr import lex_searchsorted_cols
    cap_n = jnp.asarray(key.shape[0], jnp.int32)
    return lex_searchsorted_cols((key, lo), cap_n, (qh, ql), side
                                 ).astype(jnp.int32)


def make_extend_kernel(num_pos, num_neg, batch: int, has_lo=None):
    """Build the fused kernel for a level with ``len(num_pos)`` bindings;
    binding b has ``num_pos[b]`` positive / ``num_neg[b]`` negative regions.

    Ref layout (inputs): per binding, per region (positives then negatives):
    key [cap], val [cap], n [1] — with a lo [cap] word after key when
    ``has_lo[b]`` (composite 2-word keys); then per binding qk [W] (or
    qk [W], ql [W] when composite); then wk [W], valid [W].  Outputs:
    cand [B], row [B], alive [B], allowed [W], consumed [W],
    counters [2] = (n_proposed, n_intersections).
    """
    NB = len(num_pos)
    B = batch
    has_lo = tuple(has_lo) if has_lo else (False,) * NB

    def kernel(*refs):
        # ---- unpack the static ref layout --------------------------------
        pos_refs, neg_refs = [], []
        i = 0
        for b in range(NB):
            per = 4 if has_lo[b] else 3
            pos_refs.append([refs[i + per * r: i + per * (r + 1)]
                             for r in range(num_pos[b])])
            i += per * num_pos[b]
            neg_refs.append([refs[i + per * r: i + per * (r + 1)]
                             for r in range(num_neg[b])])
            i += per * num_neg[b]
        qk_refs = []
        for b in range(NB):
            if has_lo[b]:
                qk_refs.append((refs[i], refs[i + 1]))
                i += 2
            else:
                qk_refs.append((refs[i],))
                i += 1
        wk_ref, valid_ref = refs[i], refs[i + 1]
        (cand_ref, row_ref, alive_ref, allowed_ref, consumed_ref,
         counters_ref) = refs[i + 2:]

        wk = wk_ref[...]
        valid = valid_ref[...] > 0
        W = wk.shape[0]

        def qwords(b):
            return tuple(q[...] for q in qk_refs[b])

        # ---- count minimization (Fig 2 "Count") --------------------------
        starts, counts, totals = [], [], []
        for b in range(NB):
            qw = qwords(b)
            ss, cc = [], []
            tot_b = jnp.zeros((W,), jnp.int32)
            for reg in pos_refs[b]:
                if has_lo[b]:
                    key, lo = reg[0][...], reg[1][...]
                    s = _lex_range2(key, lo, qw[0], qw[1], "left")
                    e = _lex_range2(key, lo, qw[0], qw[1], "right")
                else:
                    key = reg[0][...]
                    s = _searchsorted(key, qw[0], "left")
                    e = _searchsorted(key, qw[0], "right")
                ss.append(s)
                cc.append(e - s)
                tot_b = tot_b + (e - s)
            starts.append(ss)
            counts.append(cc)
            totals.append(tot_b)
        min_i = jnp.zeros((W,), jnp.int32)
        min_c = totals[0]
        for b in range(1, NB):
            better = totals[b] < min_c  # strict: argmin keeps first
            min_i = jnp.where(better, jnp.int32(b), min_i)
            min_c = jnp.minimum(min_c, totals[b])

        # ---- proposal budget allocation (rem-ext resumption) -------------
        remaining = jnp.where(valid, jnp.maximum(min_c - wk, 0), 0)
        acum = jnp.cumsum(remaining, dtype=jnp.int32)
        allowed = jnp.clip(B - (acum - remaining), 0, remaining
                           ).astype(jnp.int32)
        consumed = valid & (allowed == remaining)
        aacum = jnp.cumsum(allowed, dtype=jnp.int32)

        t = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)[:, 0]
        pvalid = t < aacum[W - 1]
        row = jnp.clip(_searchsorted(aacum, t, "right"), 0, W - 1)
        k_off = t - (aacum[row] - allowed[row]) + wk[row]

        # ---- candidate proposal (Fig 2 "Proposal") ------------------------
        cand = jnp.zeros((B,), jnp.int32)
        for b in range(NB):
            off = k_off
            v = jnp.zeros((B,), jnp.int32)
            for r, reg in enumerate(pos_refs[b]):
                key_ref, val_ref = reg[0], reg[-2]
                cap = key_ref.shape[0]
                c_r = counts[b][r][row]
                s_r = starts[b][r][row]
                in_r = (off >= 0) & (off < c_r)
                p = jnp.clip(s_r + off, 0, cap - 1)
                v = jnp.where(in_r, val_ref[...][p], v)
                off = off - c_r
            cand = jnp.where(min_i[row] == b, v, cand)

        # ---- intersection (Fig 2 "Intersect"): signed membership ----------
        alive = pvalid
        n_isect = jnp.zeros((), jnp.int32)
        for b in range(NB):
            qw = qwords(b)
            qkb = tuple(q[row] for q in qw)
            wpos = jnp.zeros((B,), jnp.int32)
            wneg = jnp.zeros((B,), jnp.int32)

            def hits(reg):
                if has_lo[b]:
                    key_ref, lo_ref, val_ref, n_ref = reg
                    return _lex_member3(key_ref[...], lo_ref[...],
                                        val_ref[...], n_ref[0],
                                        qkb[0], qkb[1], cand)
                key_ref, val_ref, n_ref = reg
                return _lex_member(key_ref[...], val_ref[...], n_ref[0],
                                   qkb[0], cand)

            for reg in pos_refs[b]:
                wpos = wpos + hits(reg)
            for reg in neg_refs[b]:
                wneg = wneg + hits(reg)
            is_min = min_i[row] == b
            ok = jnp.where(is_min, ~(wneg > 0), (wpos - wneg) > 0)
            n_isect = n_isect + (alive & ~is_min).sum().astype(jnp.int32)
            alive = alive & ok

        cand_ref[...] = cand
        row_ref[...] = row
        alive_ref[...] = alive.astype(jnp.int32)
        allowed_ref[...] = allowed
        consumed_ref[...] = consumed.astype(jnp.int32)
        counters_ref[...] = jnp.stack(
            [pvalid.sum().astype(jnp.int32), n_isect])

    return kernel


@functools.partial(jax.jit, static_argnames=("structure", "batch",
                                             "interpret"))
def _extend_call(operands, qks, wk, valid, structure, batch: int,
                 interpret: bool = True):
    """operands: flat tuple of (key[, lo], val, n[1]) per region,
    binding-major with positives before negatives; qks: per-binding packed
    query words — one array, or a (hi, lo) pair for composite bindings;
    structure: tuple of (num_pos, num_neg, has_lo) per binding."""
    num_pos = tuple(s[0] for s in structure)
    num_neg = tuple(s[1] for s in structure)
    has_lo = tuple(bool(s[2]) if len(s) > 2 else False for s in structure)
    W = wk.shape[0]
    flat = []
    for reg in operands:
        flat += list(reg)
    for q in qks:
        flat += list(q) if isinstance(q, tuple) else [q]
    flat += [wk, valid]
    out_shape = (
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # cand
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # row
        jax.ShapeDtypeStruct((batch,), jnp.int32),  # alive
        jax.ShapeDtypeStruct((W,), jnp.int32),      # allowed
        jax.ShapeDtypeStruct((W,), jnp.int32),      # consumed
        jax.ShapeDtypeStruct((2,), jnp.int32),      # counters
    )
    return pl.pallas_call(
        make_extend_kernel(num_pos, num_neg, batch, has_lo=has_lo),
        out_shape=out_shape,
        interpret=interpret,
    )(*flat)
