"""Wrapper for the fused extension-step kernel.

``fused_extend`` takes the popped prefix window's per-binding lookup keys
plus every region of every binding's versioned index and runs the whole
count-min -> propose -> intersect pipeline of one BiGJoin level in a single
``pallas_call`` (see extend.py).  Results are bit-identical to the unfused
jnp stage sequence in ``bigjoin._level_branch``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.extend.extend import _extend_call
from repro.kernels.intersect.ops import default_interpret


def fused_extend(pos, neg, qks, wk, valid, batch: int, interpret=None):
    """Run one fused extension step.

    pos/neg: per-binding tuples of sorted-index regions (.key/.val/.n, with
    the composite .lo word when the binding's prefix packs 2 lex words);
    qks: per-binding packed lookup keys [W] — one array, or a (hi, lo)
    int64 pair for composite bindings; wk: rem-ext cursors [W]; valid:
    live-row mask [W]; batch: the proposal budget B'.

    Returns (cand [B], row [B], alive [B] bool, allowed [W],
    consumed [W] bool, counters [2] = (proposed, intersections)).
    """
    structure = []
    operands = []
    qks_cast = []
    for b, (p_regions, n_regions) in enumerate(zip(pos, neg)):
        regions = tuple(p_regions) + tuple(n_regions)
        composite = isinstance(qks[b], tuple)
        structure.append((len(p_regions), len(n_regions), composite))
        qh = qks[b][0] if composite else qks[b]
        key_dtype = jnp.result_type(qh.dtype,
                                    *[r.key.dtype for r in regions])
        for r in regions:
            if composite:
                operands.append((r.key.astype(key_dtype),
                                 r.lo.astype(jnp.int64), r.val,
                                 r.n.reshape(1).astype(jnp.int32)))
            else:
                operands.append((r.key.astype(key_dtype), r.val,
                                 r.n.reshape(1).astype(jnp.int32)))
        if composite:
            qks_cast.append((qh.astype(key_dtype),
                             qks[b][1].astype(jnp.int64)))
        else:
            qks_cast.append(qh.astype(key_dtype))
    cand, row, alive, allowed, consumed, counters = _extend_call(
        tuple(operands), tuple(qks_cast), wk.astype(jnp.int32),
        valid.astype(jnp.int32), structure=tuple(structure), batch=batch,
        interpret=default_interpret(interpret))
    return (cand, row, alive > 0, allowed, consumed > 0, counters)
