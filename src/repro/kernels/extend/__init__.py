"""Fused extension-step kernel: count-min -> propose -> intersect in one
``pallas_call`` (the BiGJoin per-level hot loop, Fig. 2 of the paper)."""
from repro.kernels.extend.ops import fused_extend  # noqa: F401
