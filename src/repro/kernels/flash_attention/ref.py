"""Pure-jnp oracle for flash attention (full-materialization softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: float = 1.0,
                  q_offset: int = 0) -> jax.Array:
    """q [H, Sq, Dh]; k, v [H, Sk, Dh] -> [H, Sq, Dh] (f32 math)."""
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("hqd,hkd->hqk", qf, k.astype(jnp.float32))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    Sq, Sk = q.shape[1], k.shape[1]
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
