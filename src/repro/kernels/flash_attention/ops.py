"""Jit'd multi-head attention wrapper over the flash kernel.

Handles batch folding, GQA head-group expansion and the decode path
(q_offset = KV-cache length).  On CPU the kernel runs in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import _flash_call

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "q_offset"))
def mha(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
        window: int = 0, softcap: float = 0.0, q_offset: int = 0
        ) -> jax.Array:
    """q [B, Sq, Hq, Dh]; k, v [B, Sk, Hkv, Dh] -> [B, Sq, Hq, Dh].

    GQA: Hq must be a multiple of Hkv; kv heads are repeated per group.
    """
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / (Dh ** 0.5)
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, Dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hq, -1, Dh)
    o = _flash_call(qh, kh, vh, causal=causal, window=window,
                    softcap=softcap, scale=scale, q_offset=q_offset,
                    interpret=_INTERPRET)
    return o.reshape(B, Hq, Sq, Dh).transpose(0, 2, 1, 3)
