"""Pallas TPU kernel: blocked online-softmax attention.

The LM architectures' dominant compute is attention; this kernel implements
the standard flash pattern adapted to TPU: the Q block lives in VMEM, the
kernel iterates KV blocks with a running (max, denominator, accumulator)
triple, and the MXU does both the QK^T and PV contractions at bf16 inputs /
f32 accumulation.

Variants required by the assigned architectures (selected by static args):
  causal            — decoder LMs (all)
  sliding window    — mixtral (SWA), gemma2 / llama4-scout local layers
  logit softcap     — gemma2 (tanh cap on attention logits)

Block sizes: BQ x BK = 128 x 128 aligns with the MXU systolic array; the
VMEM working set is q[BQ,Dh] + k/v[BK,Dh] + acc[BQ,Dh] + stats, well under
budget for Dh <= 256 (gemma's head_dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQK = 128  # query block rows
BKV = 128  # kv block rows

NEG_INF = -1e30


def flash_kernel(q_ref, k_ref, v_ref, o_ref, *, seq_k: int, causal: bool,
                 window: int, softcap: float, scale: float, q_offset: int):
    q = q_ref[0].astype(jnp.float32) * scale  # [BQ, Dh]
    bq, dh = q.shape
    q_pos = q_offset + pl.program_id(1) * bq + jax.lax.iota(jnp.int32, bq)

    def body(kb, carry):
        acc, m, l = carry
        k = k_ref[0, pl.ds(kb * BKV, BKV), :].astype(jnp.float32)  # [BK, Dh]
        v = v_ref[0, pl.ds(kb * BKV, BKV), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = kb * BKV + jax.lax.iota(jnp.int32, BKV)
        mask = (k_pos < seq_k)[None, :]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    nkv = (seq_k + BKV - 1) // BKV
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nkv, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "q_offset", "interpret"))
def _flash_call(q, k, v, causal: bool = True, window: int = 0,
                softcap: float = 0.0, scale: float = 1.0, q_offset: int = 0,
                interpret: bool = True):
    """q [H, Sq, Dh]; k, v [H, Sk, Dh] -> o [H, Sq, Dh].

    ``q_offset``: absolute position of q row 0 (decode: cache length)."""
    H, Sq, Dh = q.shape
    Sk = k.shape[1]
    Sq_p = ((Sq + BQK - 1) // BQK) * BQK
    Sk_p = ((Sk + BKV - 1) // BKV) * BKV
    if Sq_p != Sq:
        q = jnp.concatenate(
            [q, jnp.zeros((H, Sq_p - Sq, Dh), q.dtype)], axis=1)
    if Sk_p != Sk:
        k = jnp.concatenate(
            [k, jnp.zeros((H, Sk_p - Sk, Dh), k.dtype)], axis=1)
        v = jnp.concatenate(
            [v, jnp.zeros((H, Sk_p - Sk, Dh), v.dtype)], axis=1)
    grid = (H, Sq_p // BQK)
    out = pl.pallas_call(
        functools.partial(flash_kernel, seq_k=Sk, causal=causal,
                          window=window, softcap=softcap, scale=scale,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQK, Dh), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, Sk_p, Dh), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, Sk_p, Dh), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQK, Dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sq_p, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
