# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def count_pallas_calls(fn, *args) -> int:
    """Number of pallas_call eqns in fn's jaxpr, descending into sub-jaxprs
    (pjit bodies, control-flow branches).  Used by tests and benchmarks to
    verify kernel-launch fusion (one launch per probe / per level branch)."""
    import jax
    closed = jax.make_jaxpr(fn)(*args)

    def walk_jaxpr(jaxpr) -> int:
        c = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                c += 1
            c += sum(walk_param(v) for v in eqn.params.values())
        return c

    def walk_param(v) -> int:
        if isinstance(v, jax.core.ClosedJaxpr):
            return walk_jaxpr(v.jaxpr)
        if isinstance(v, jax.core.Jaxpr):
            return walk_jaxpr(v)
        if isinstance(v, (tuple, list)):
            return sum(walk_param(x) for x in v)
        return 0

    return walk_jaxpr(closed.jaxpr)
