"""Pure-jnp oracle for the segment_sum kernel."""
import jax
import jax.numpy as jnp


def segment_sum_ref(data: jax.Array, seg_ids: jax.Array,
                    num_segments: int) -> jax.Array:
    return jax.ops.segment_sum(data.astype(jnp.float32), seg_ids,
                               num_segments=num_segments)
