"""Jit'd wrapper for the segment-sum kernel (sort, pad, combine partials)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_ops.segment_ops import BE, _segment_sum_call

_INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("num_segments", "is_sorted"))
def segment_sum(data: jax.Array, seg_ids: jax.Array, num_segments: int,
                is_sorted: bool = False) -> jax.Array:
    """[NS, D] f32 segment sum via the Pallas one-hot-matmul kernel.

    ``is_sorted``: promise that seg_ids is nondecreasing (e.g. edges stored
    dst-sorted); otherwise a sort is inserted here.
    """
    E, D = data.shape
    if not is_sorted:
        order = jnp.argsort(seg_ids)
        data, seg_ids = data[order], seg_ids[order]
    Ep = ((E + BE - 1) // BE) * BE
    if Ep != E:
        data = jnp.concatenate(
            [data, jnp.zeros((Ep - E, D), data.dtype)])
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((Ep - E,), num_segments, seg_ids.dtype)])
    partials, segmap = _segment_sum_call(
        data, seg_ids.astype(jnp.int32), num_segments, interpret=_INTERPRET)
    out = jnp.zeros((num_segments, D), jnp.float32)
    return out.at[segmap].add(partials, mode="drop")
