"""Pallas TPU kernel: sorted segment-sum via one-hot MXU matmuls.

Message passing (GNN) and EmbeddingBag (recsys) reduce per-edge/per-token
vectors into per-node/per-bag accumulators.  On GPU this is atomics; TPUs
have no atomics — the native pattern is a *one-hot matmul*: for a block of
BE edges sorted by segment, build the [BE, BS] one-hot of block-local
segment ranks and contract it against the [BE, D] values on the MXU.

Each grid step emits a [BS, D] partial (BS = max distinct segments in a
block = BE) plus a [BS] map of block-local rank -> global segment id; the
jit wrapper scatter-adds partials into the [NS, D] output (one XLA scatter
over G·BS rows instead of E — the kernel does the heavy reduction).

Works with arbitrary segment gaps (rank-based, not offset-based locals).
Accumulation is f32 regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BE = 256  # edges per grid step (rows of the one-hot matmul)


def segment_sum_kernel(data_ref, seg_ref, partial_ref, segmap_ref, *,
                       num_segments: int):
    data = data_ref[...].astype(jnp.float32)  # [BE, D]
    seg = seg_ref[...]  # [BE] int32, sorted; NS = padding sentinel
    valid = seg < num_segments

    prev = jnp.concatenate([seg[:1] - 1, seg[:-1]])
    boundary = (seg != prev).astype(jnp.int32)
    local = jnp.cumsum(boundary) - boundary[0]  # rank within block, starts 0
    local = jnp.where(valid, local, BE - 1)

    onehot = (local[:, None] == jax.lax.iota(jnp.int32, BE)[None, :])
    onehot = (onehot & valid[:, None]).astype(jnp.float32)  # [BE, BS]
    partial_ref[...] = jax.lax.dot_general(
        onehot, data, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # [BS, D]

    segmap = jnp.full((BE,), num_segments, jnp.int32)
    segmap = segmap.at[local].set(jnp.where(valid, seg, num_segments))
    segmap_ref[...] = segmap


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def _segment_sum_call(data, seg, num_segments: int, interpret: bool = True):
    E, D = data.shape
    grid = (E // BE,)
    return pl.pallas_call(
        functools.partial(segment_sum_kernel, num_segments=num_segments),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BE, D), lambda i: (i, 0)),
            pl.BlockSpec((BE,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((BE, D), lambda i: (i, 0)),
            pl.BlockSpec((BE,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((grid[0] * BE, D), jnp.float32),
            jax.ShapeDtypeStruct((grid[0] * BE,), jnp.int32),
        ),
        interpret=interpret,
    )(data, seg)
