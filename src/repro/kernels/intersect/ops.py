"""Jit'd wrappers for the intersect kernels: padding, tiling, region fusion.

``member(keys, vals, n, qk, qv)`` is a drop-in replacement for
``ref.member_ref`` (and for ``csr.index_member``'s jnp path): it pads the
index to a SEG multiple, reshapes it segment-major (every row's first column
is the VMEM router entry) and tiles the query batch over the grid.

``signed_member(pos, neg, qk, qv)`` fuses *all* regions of a versioned index
into one ``pallas_call`` returning (wpos, wneg) hit counts — one launch per
membership probe regardless of how many LSM regions back the index.

On CPU the kernels execute in interpret mode; on a TPU backend the compiled
(non-interpret) path is selected automatically (``default_interpret``), and
callers may force either path with the ``interpret`` argument.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.intersect import (BQ, SEG, _member_call,
                                               _multi_member_call)

_INTERPRET = jax.default_backend() != "tpu"

# VMEM the fused kernels may plan on per core (16 MiB total minus pipeline
# headroom; DESIGN.md §3).  Compiled paths whose region working set exceeds
# this fall back to the jnp oracle instead of failing Mosaic compilation.
FUSED_VMEM_BUDGET = 12 * 2**20


def default_interpret(interpret=None) -> bool:
    """Platform gating: compiled Mosaic on TPU, interpret fallback elsewhere.

    ``interpret=None`` defers to detection; an explicit bool wins."""
    if interpret is None:
        return _INTERPRET
    return bool(interpret)


def fused_fits(regions, batch: int = 0) -> bool:
    """Static check that a fused kernel over ``regions`` (.key/.val arrays,
    VMEM-resident) plus ~48 B/proposal of pipeline vectors fits the budget.

    Only relevant to the compiled path — interpret mode has no VMEM."""
    idx_bytes = sum(
        r.key.shape[0] * (jnp.dtype(r.key.dtype).itemsize + 4)
        for r in regions)
    return idx_bytes + 48 * batch <= FUSED_VMEM_BUDGET


def _pad_to(x: jax.Array, size: int, fill) -> jax.Array:
    pad = size - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])


def _key_max(dtype) -> int:
    return np.iinfo(np.dtype(dtype.name)).max


def _segment_major(keys: jax.Array, vals: jax.Array):
    """Pad a sorted (key, val) index to a SEG multiple and reshape to
    [num_segments, SEG] segment-major tiles (column 0 = router)."""
    kmax = jnp.asarray(_key_max(keys.dtype), keys.dtype)
    vmax = jnp.asarray(np.iinfo(np.int32).max, jnp.int32)
    padded = max(((keys.shape[0] + SEG - 1) // SEG) * SEG, SEG)
    keys2d = _pad_to(keys, padded, kmax).reshape(-1, SEG)
    vals2d = _pad_to(vals.astype(jnp.int32), padded, vmax).reshape(-1, SEG)
    return keys2d, vals2d


def _pad_queries(qk: jax.Array, qv: jax.Array, key_dtype):
    kmax = jnp.asarray(_key_max(jnp.dtype(key_dtype)), key_dtype)
    vmax = jnp.asarray(np.iinfo(np.int32).max, jnp.int32)
    B = qk.shape[0]
    Bp = max(((B + BQ - 1) // BQ) * BQ, BQ)
    return (_pad_to(qk.astype(key_dtype), Bp, kmax),
            _pad_to(qv.astype(jnp.int32), Bp, vmax))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _member_jit(keys, vals, n, qk, qv, interpret: bool):
    keys2d, vals2d = _segment_major(keys, vals)
    qk_p, qv_p = _pad_queries(qk, qv, keys.dtype)
    bits = _member_call(keys2d, vals2d, n.reshape(1).astype(jnp.int32),
                        qk_p, qv_p, interpret=interpret)
    return bits[:qk.shape[0]] > 0


def member(keys: jax.Array, vals: jax.Array, n: jax.Array,
           qk: jax.Array, qv: jax.Array, interpret=None) -> jax.Array:
    """[B] bool membership via the Pallas two-level search kernel."""
    return _member_jit(keys, vals, n, qk, qv,
                       interpret=default_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("num_pos", "interpret"))
def _signed_member_jit(regions, qk, qv, num_pos: int, interpret: bool):
    key_dtype = jnp.result_type(*[k.dtype for k, _, _ in regions])
    prepped = tuple(
        _segment_major(k.astype(key_dtype), v)
        + (n.reshape(1).astype(jnp.int32),)
        for k, v, n in regions)
    qk_p, qv_p = _pad_queries(qk, qv, key_dtype)
    wpos, wneg = _multi_member_call(prepped, qk_p, qv_p, num_pos=num_pos,
                                    interpret=interpret)
    B = qk.shape[0]
    return wpos[:B], wneg[:B]


def signed_member(pos, neg, qk: jax.Array, qv: jax.Array,
                  interpret=None):
    """Fused membership over all regions of a versioned index.

    ``pos``/``neg``: sequences of sorted-index triples (objects with
    .key/.val/.n, e.g. :class:`repro.core.csr.IndexData`).  One
    ``pallas_call`` total.  Returns (wpos, wneg) int32 [B]: hit counts over
    the positive / negative regions."""
    regions = tuple((r.key, r.val, r.n) for r in tuple(pos) + tuple(neg))
    if not regions:
        z = jnp.zeros(qk.shape, jnp.int32)
        return z, z
    return _signed_member_jit(regions, qk, qv, num_pos=len(tuple(pos)),
                              interpret=default_interpret(interpret))
