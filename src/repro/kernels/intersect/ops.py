"""Jit'd wrapper for the intersect kernel: router construction + padding.

``member(keys, vals, n, qk, qv)`` is a drop-in replacement for
``ref.member_ref`` (and for ``csr.index_member``'s jnp path): it pads the
index to a SEG multiple, derives the VMEM router (every SEG-th entry) and
tiles the query batch over the grid.

The router derivation is jnp (it is a strided slice, fused by XLA); the
search itself runs in the Pallas kernel.  On CPU the kernel executes in
interpret mode; on TPU set ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.intersect import BQ, SEG, _member_call

_INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, size: int, fill) -> jax.Array:
    pad = size - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])


@functools.partial(jax.jit, static_argnames=())
def member(keys: jax.Array, vals: jax.Array, n: jax.Array,
           qk: jax.Array, qv: jax.Array) -> jax.Array:
    """[B] bool membership via the Pallas two-level search kernel."""
    kmax = jnp.asarray(np.iinfo(keys.dtype.name).max, keys.dtype)
    vmax = jnp.asarray(np.iinfo(jnp.int32.name if hasattr(jnp.int32, "name")
                                else "int32").max, jnp.int32)
    cap = keys.shape[0]
    padded = ((cap + SEG - 1) // SEG) * SEG
    keys_p = _pad_to(keys, padded, kmax)
    vals_p = _pad_to(vals, padded, vmax)
    router_k = keys_p[::SEG]
    router_v = vals_p[::SEG]

    B = qk.shape[0]
    Bp = ((B + BQ - 1) // BQ) * BQ
    qk_p = _pad_to(qk.astype(keys.dtype), Bp, kmax)
    qv_p = _pad_to(qv.astype(jnp.int32), Bp, vmax)
    bits = _member_call(router_k, router_v, keys_p, vals_p,
                        n.reshape(1).astype(jnp.int32), qk_p, qv_p,
                        interpret=_INTERPRET)
    return bits[:B] > 0
