"""Jit'd wrappers for the intersect kernels: padding, tiling, region fusion.

``member(keys, vals, n, qk, qv)`` is a drop-in replacement for
``ref.member_ref`` (and for ``csr.index_member``'s jnp path): it pads the
index to a SEG multiple, reshapes it segment-major (every row's first column
is the VMEM router entry) and tiles the query batch over the grid.

``signed_member(pos, neg, qk, qv)`` fuses *all* regions of a versioned index
into one ``pallas_call`` returning (wpos, wneg) hit counts — one launch per
membership probe regardless of how many LSM regions back the index.

On CPU the kernels execute in interpret mode; on a TPU backend the compiled
(non-interpret) path is selected automatically (``default_interpret``), and
callers may force either path with the ``interpret`` argument.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.intersect.intersect import (BQ, SEG, _member_call,
                                               _multi_member_call)

_INTERPRET = jax.default_backend() != "tpu"

# VMEM the fused kernels may plan on per core (16 MiB total minus pipeline
# headroom; DESIGN.md §3).  Compiled paths whose region working set exceeds
# this fall back to the jnp oracle instead of failing Mosaic compilation.
FUSED_VMEM_BUDGET = 12 * 2**20


def default_interpret(interpret=None) -> bool:
    """Platform gating: compiled Mosaic on TPU, interpret fallback elsewhere.

    ``interpret=None`` defers to detection; an explicit bool wins."""
    if interpret is None:
        return _INTERPRET
    return bool(interpret)


def fused_fits(regions, batch: int = 0) -> bool:
    """Static check that a fused kernel over ``regions`` (.key/.val arrays,
    VMEM-resident) plus ~48 B/proposal of pipeline vectors fits the budget.

    Composite regions carry the extra int64 ``lo`` word tile (8 B/slot) on
    top of the hi word and the int32 val.  Only relevant to the compiled
    path — interpret mode has no VMEM."""
    idx_bytes = sum(
        r.key.shape[-1] * (jnp.dtype(r.key.dtype).itemsize + 4
                           + (8 if getattr(r, "lo", None) is not None
                              else 0))
        for r in regions)
    return idx_bytes + 48 * batch <= FUSED_VMEM_BUDGET


def _pad_to(x: jax.Array, size: int, fill) -> jax.Array:
    pad = size - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])


def _key_max(dtype) -> int:
    return np.iinfo(np.dtype(dtype.name)).max


def _segment_major(keys: jax.Array, vals: jax.Array):
    """Pad a sorted (key, val) index to a SEG multiple and reshape to
    [num_segments, SEG] segment-major tiles (column 0 = router)."""
    kmax = jnp.asarray(_key_max(keys.dtype), keys.dtype)
    vmax = jnp.asarray(np.iinfo(np.int32).max, jnp.int32)
    padded = max(((keys.shape[0] + SEG - 1) // SEG) * SEG, SEG)
    keys2d = _pad_to(keys, padded, kmax).reshape(-1, SEG)
    vals2d = _pad_to(vals.astype(jnp.int32), padded, vmax).reshape(-1, SEG)
    return keys2d, vals2d


def _segment_major_lo(los: jax.Array) -> jax.Array:
    """The composite lo word as segment-major [num_segments, SEG] int64
    tiles, sentinel (int64-max) padded — the companion of the hi-word tiles
    from :func:`_segment_major` (same row split, column 0 joins the
    router)."""
    lmax = jnp.asarray(np.iinfo(np.int64).max, jnp.int64)
    padded = max(((los.shape[0] + SEG - 1) // SEG) * SEG, SEG)
    return _pad_to(los.astype(jnp.int64), padded, lmax).reshape(-1, SEG)


def _pad_queries(qk: jax.Array, qv: jax.Array, key_dtype, ql=None):
    kmax = jnp.asarray(_key_max(jnp.dtype(key_dtype)), key_dtype)
    vmax = jnp.asarray(np.iinfo(np.int32).max, jnp.int32)
    B = qk.shape[0]
    Bp = max(((B + BQ - 1) // BQ) * BQ, BQ)
    qk_p = _pad_to(qk.astype(key_dtype), Bp, kmax)
    qv_p = _pad_to(qv.astype(jnp.int32), Bp, vmax)
    if ql is None:
        return qk_p, qv_p
    lmax = jnp.asarray(np.iinfo(np.int64).max, jnp.int64)
    return qk_p, qv_p, _pad_to(ql.astype(jnp.int64), Bp, lmax)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _member_jit(keys, vals, n, qk, qv, interpret: bool, los=None, ql=None):
    keys2d, vals2d = _segment_major(keys, vals)
    if los is None:
        qk_p, qv_p = _pad_queries(qk, qv, keys.dtype)
        los2d = ql_p = None
    else:
        qk_p, qv_p, ql_p = _pad_queries(qk, qv, keys.dtype, ql=ql)
        los2d = _segment_major_lo(los)
    bits = _member_call(keys2d, vals2d, n.reshape(1).astype(jnp.int32),
                        qk_p, qv_p, interpret=interpret,
                        los2d=los2d, ql=ql_p)
    return bits[:qk.shape[0]] > 0


def member(keys: jax.Array, vals: jax.Array, n: jax.Array,
           qk: jax.Array, qv: jax.Array, interpret=None,
           los=None, ql=None) -> jax.Array:
    """[B] bool membership via the Pallas two-level search kernel.

    Pass the index's ``los`` word and the query ``ql`` word for composite
    (hi, lo) keys — same single launch, 3-word lex compares."""
    return _member_jit(keys, vals, n, qk, qv,
                       interpret=default_interpret(interpret),
                       los=los, ql=ql)


@functools.partial(jax.jit, static_argnames=("num_pos", "interpret"))
def _signed_member_jit(regions, qk, qv, num_pos: int, interpret: bool,
                       ql=None):
    key_dtype = jnp.result_type(*[reg[0].dtype for reg in regions])
    composite = ql is not None
    if composite:
        def quad(k, lo, v, n):
            k2d, v2d = _segment_major(k.astype(key_dtype), v)
            return (k2d, _segment_major_lo(lo), v2d,
                    n.reshape(1).astype(jnp.int32))
        prepped = tuple(quad(*reg) for reg in regions)
        qk_p, qv_p, ql_p = _pad_queries(qk, qv, key_dtype, ql=ql)
    else:
        prepped = tuple(
            _segment_major(k.astype(key_dtype), v)
            + (n.reshape(1).astype(jnp.int32),)
            for k, v, n in regions)
        qk_p, qv_p = _pad_queries(qk, qv, key_dtype)
        ql_p = None
    wpos, wneg = _multi_member_call(prepped, qk_p, qv_p, num_pos=num_pos,
                                    interpret=interpret, ql=ql_p)
    B = qk.shape[0]
    return wpos[:B], wneg[:B]


def signed_member(pos, neg, qk, qv: jax.Array, interpret=None):
    """Fused membership over all regions of a versioned index.

    ``pos``/``neg``: sequences of sorted-index regions (objects with
    .key/.val/.n and optionally the composite .lo word, e.g.
    :class:`repro.core.csr.IndexData`).  ``qk`` is one packed array, or a
    (hi, lo) pair when the regions are composite.  One ``pallas_call``
    total.  Returns (wpos, wneg) int32 [B]: hit counts over the positive /
    negative regions."""
    all_regions = tuple(pos) + tuple(neg)
    if isinstance(qk, tuple):
        qk, ql = qk
        regions = tuple((r.key, r.lo, r.val, r.n) for r in all_regions)
    else:
        ql = None
        regions = tuple((r.key, r.val, r.n) for r in all_regions)
    if not regions:
        z = jnp.zeros(qk.shape, jnp.int32)
        return z, z
    return _signed_member_jit(regions, qk, qv, num_pos=len(tuple(pos)),
                              interpret=default_interpret(interpret),
                              ql=ql)
