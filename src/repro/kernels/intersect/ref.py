"""Pure-jnp oracle for the intersect (sorted-membership) kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import lex_searchsorted


def member_ref(keys: jax.Array, vals: jax.Array, n: jax.Array,
               qk: jax.Array, qv: jax.Array) -> jax.Array:
    """Membership of (qk, qv) in the lexicographically sorted (keys, vals)
    restricted to the first n live entries.  [B] bool."""
    pos = lex_searchsorted(keys, vals, n, qk.astype(keys.dtype),
                           qv.astype(jnp.int32))
    cap = keys.shape[0]
    pos_c = jnp.clip(pos, 0, cap - 1)
    hit = (keys[pos_c] == qk.astype(keys.dtype)) & \
        (vals[pos_c] == qv.astype(jnp.int32))
    return hit & (pos < n)
