"""Pallas TPU kernel: batched sorted-membership (the Intersect hot spot).

The innermost operation of the WCOJ dataflow is "does extension e of prefix p
exist in relation R_i?" — a lookup of (key, val) in a lexicographically
sorted pair of arrays.  The paper uses CPU hash tables; the TPU-native
structure is a two-level sorted search (DESIGN.md §2):

  level 1 (VMEM): a *router* holding every SEG-th (key,val) pair.  A
      fixed-depth vectorized binary search over the router (VMEM gathers —
      cheap on TPU) locates the SEG-aligned segment of each query.
  level 2 (HBM->VMEM): one dynamic-slice load of the SEG-entry segment per
      query (the same per-row DMA pattern as TPU embedding lookups), then a
      128-lane vector compare.

SEG = 128 aligns the segment load with the VPU lane width.  The query block
(BQ per grid step) bounds VMEM: BQ·(8B+4B) queries + SEG·(8B+4B) segment +
router (capped by ROUTER_MAX entries; beyond that the router itself is
two-level — not needed below 2^23 index entries per shard).

The kernel returns one int32 bit per query.  ref.py is the pure-jnp oracle
(identical fixed-depth lexicographic search, no tiling).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

SEG = 128  # segment length: one VPU lane row per segment fetch
BQ = 256  # queries per grid step


def _router_depth(num_segments: int) -> int:
    return max(int(np.ceil(np.log2(max(num_segments, 2)))), 1) + 1


def member_kernel(router_k_ref, router_v_ref, keys_ref, vals_ref, n_ref,
                  qk_ref, qv_ref, out_ref, *, num_segments: int):
    """One grid step: BQ queries against the full sorted (keys, vals)."""
    qk = qk_ref[...]
    qv = qv_ref[...]
    n = n_ref[0]

    # ---- level 1: vectorized binary search over the VMEM router ----------
    rk = router_k_ref[...]
    rv = router_v_ref[...]
    lo = jnp.zeros(qk.shape, jnp.int32)
    hi = jnp.full(qk.shape, num_segments, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        mk = rk[jnp.clip(mid, 0, num_segments - 1)]
        mv = rv[jnp.clip(mid, 0, num_segments - 1)]
        # segment leader strictly less-or-equal than query -> go right
        le = (mk < qk) | ((mk == qk) & (mv <= qv))
        sel = lo < hi
        lo = jnp.where(le & sel, mid + 1, lo)
        hi = jnp.where(~le & sel, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, _router_depth(num_segments), body, (lo, hi))
    seg = jnp.maximum(lo - 1, 0)  # last segment whose leader <= query

    # ---- level 2: per-query segment DMA + 128-lane compare ----------------
    def probe(i, acc):
        s = seg[i] * SEG
        kseg = jax.lax.dynamic_slice(keys_ref[...], (s,), (SEG,))
        vseg = jax.lax.dynamic_slice(vals_ref[...], (s,), (SEG,))
        idx = s + jax.lax.iota(jnp.int32, SEG)
        hit = ((kseg == qk[i]) & (vseg == qv[i]) & (idx < n)).any()
        return acc.at[i].set(hit.astype(jnp.int32))

    out_ref[...] = jax.lax.fori_loop(
        0, qk.shape[0], probe, jnp.zeros((qk.shape[0],), jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _member_call(router_k, router_v, keys, vals, n, qk, qv,
                 interpret: bool = True):
    B = qk.shape[0]
    num_segments = router_k.shape[0]
    grid = (B // BQ,)
    return pl.pallas_call(
        functools.partial(member_kernel, num_segments=num_segments),
        grid=grid,
        in_specs=[
            pl.BlockSpec((num_segments,), lambda i: (0,)),  # router: VMEM
            pl.BlockSpec((num_segments,), lambda i: (0,)),
            pl.BlockSpec(keys.shape, lambda i: (0,)),  # full index
            pl.BlockSpec(vals.shape, lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BQ,), lambda i: (i,)),  # query tile
            pl.BlockSpec((BQ,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BQ,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(router_k, router_v, keys, vals, n, qk, qv)
