"""Pallas TPU kernels: batched sorted-membership (the Intersect hot spot).

The innermost operation of the WCOJ dataflow is "does extension e of prefix p
exist in relation R_i?" — a lookup of (key, val) in a lexicographically
sorted pair of arrays.  The paper uses CPU hash tables; the TPU-native
structure is a two-level sorted search (DESIGN.md §2):

  level 1 (VMEM): a *router* holding every SEG-th (key,val) pair.  A
      fixed-depth vectorized binary search over the router (VMEM gathers —
      cheap on TPU) locates the SEG-aligned segment of each query.
  level 2 (VMEM): the index is stored segment-major as a [num_segments, SEG]
      tile, so the router is simply column 0 and each query's segment is one
      *row gather*.  All BQ segments are fetched as a single [BQ, SEG] tile
      and reduced with a lane-wise compare — there is no per-query probe
      loop; the whole query block resolves in O(log S) vector ops plus one
      gather, instead of BQ serialized dynamic-slices.

Design notes (fused extension pipeline, DESIGN.md §"Fused extension
pipeline"):

  * SEG = 128 aligns the segment row with the VPU lane width, so the level-2
    compare is exactly one vector op per query row.
  * The query block (BQ per grid step) bounds VMEM: the working set per grid
    step is the full segment-major index (cap·12 B), one [BQ, SEG] gathered
    key tile (BQ·SEG·8 B) + val tile (BQ·SEG·4 B), and the BQ·12 B query
    columns.  With BQ = 256 the gathered tiles are 384 KiB; the index tile
    dominates and caps the per-shard index at ~1 M entries per 12 MiB of
    VMEM.  Larger shards need a second router level (not required below
    2^23 entries per worker) or an HBM-resident index with per-segment DMA.
  * the multi-region kernel (``_make_multi_member_kernel``) evaluates *all*
    positive and negative regions of
    a :class:`~repro.core.dataflow_index.VersionedIndex` in one
    ``pallas_call`` and returns the signed hit counts, replacing R separate
    kernel launches (and R round-trips through HBM for the query batch) with
    one fused pass — the multi-region fusion of this PR's extension-step
    pipeline.

The kernels return int32 hit bits/counts per query.  ref.py is the pure-jnp
oracle (identical fixed-depth lexicographic search, no tiling); parity is
bit-exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# segment length (one VPU lane row per segment fetch) — canonical constant
# lives with the index structure so capacity rounding cannot drift from it
from repro.core.csr import SEG  # noqa: F401  (re-exported for ops.py)

BQ = 256  # queries per grid step


def _router_depth(num_segments: int) -> int:
    return max(int(np.ceil(np.log2(max(num_segments, 2)))), 1) + 1


def _two_level_hits(keys2d: jax.Array, vals2d: jax.Array, n: jax.Array,
                    qk: jax.Array, qv: jax.Array,
                    los2d: jax.Array | None = None,
                    ql: jax.Array | None = None) -> jax.Array:
    """Vectorized two-level membership of (qk[, ql], qv) in a segment-major
    index.

    keys2d/vals2d: [num_segments, SEG] sorted lexicographically row-major
    with sentinel padding; n: [] live entries; qk/qv: [BQ].  Returns int32
    [BQ] hit bits.  Column 0 of keys2d/vals2d *is* the router.  For a
    composite 2-word key, ``los2d`` [num_segments, SEG] int64 carries the
    secondary word (sentinel padding sorts above all live entries, like the
    hi word) and ``ql`` [BQ] the query lo word — the router compare and the
    lane compare become 3-word lexicographic, same tile shapes, one extra
    [BQ, SEG] row gather.
    """
    num_segments = keys2d.shape[0]
    composite = los2d is not None
    rk = keys2d[:, 0]
    rl = los2d[:, 0] if composite else None
    rv = vals2d[:, 0]

    # ---- level 1: vectorized binary search over the implicit router -------
    lo = jnp.zeros(qk.shape, jnp.int32)
    hi = jnp.full(qk.shape, num_segments, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        mc = jnp.clip(mid, 0, num_segments - 1)
        mk = rk[mc]
        mv = rv[mc]
        # segment leader less-or-equal than query -> go right
        if composite:
            ml = rl[mc]
            le = (mk < qk) | ((mk == qk)
                             & ((ml < ql) | ((ml == ql) & (mv <= qv))))
        else:
            le = (mk < qk) | ((mk == qk) & (mv <= qv))
        sel = lo < hi
        lo = jnp.where(le & sel, mid + 1, lo)
        hi = jnp.where(~le & sel, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, _router_depth(num_segments), body, (lo, hi))
    seg = jnp.maximum(lo - 1, 0)  # last segment whose leader <= query

    # ---- level 2: one [BQ, SEG] row gather + lane-wise compare ------------
    kseg = keys2d[seg]  # [BQ, SEG]
    vseg = vals2d[seg]
    col = jax.lax.broadcasted_iota(jnp.int32, kseg.shape, 1)
    idx = seg[:, None] * SEG + col
    hit = (kseg == qk[:, None]) & (vseg == qv[:, None]) & (idx < n)
    if composite:
        hit = hit & (los2d[seg] == ql[:, None])
    return hit.max(axis=1).astype(jnp.int32)


def member_kernel(keys_ref, vals_ref, n_ref, qk_ref, qv_ref, out_ref):
    """One grid step: BQ queries against the full segment-major (keys, vals).

    No per-query probe loop: the segment of every query is located by the
    shared router search and gathered in one [BQ, SEG] tile.
    """
    out_ref[...] = _two_level_hits(keys_ref[...], vals_ref[...], n_ref[0],
                                   qk_ref[...], qv_ref[...])


def member_kernel_lex(keys_ref, los_ref, vals_ref, n_ref, qk_ref, ql_ref,
                      qv_ref, out_ref):
    """Composite-key variant: BQ (qk, ql, qv) queries, 3-word lex compare."""
    out_ref[...] = _two_level_hits(keys_ref[...], vals_ref[...], n_ref[0],
                                   qk_ref[...], qv_ref[...],
                                   los2d=los_ref[...], ql=ql_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def _member_call(keys2d, vals2d, n, qk, qv, interpret: bool = True,
                 los2d=None, ql=None):
    B = qk.shape[0]
    num_segments = keys2d.shape[0]
    grid = (B // BQ,)
    composite = los2d is not None
    full = pl.BlockSpec((num_segments, SEG), lambda i: (0, 0))
    in_specs = [full] + ([full] if composite else []) + [
        full,
        pl.BlockSpec((1,), lambda i: (0,)),
        pl.BlockSpec((BQ,), lambda i: (i,)),  # query tile
    ] + ([pl.BlockSpec((BQ,), lambda i: (i,))] if composite else []) + [
        pl.BlockSpec((BQ,), lambda i: (i,)),
    ]
    operands = ((keys2d, los2d, vals2d, n, qk, ql, qv) if composite
                else (keys2d, vals2d, n, qk, qv))
    return pl.pallas_call(
        member_kernel_lex if composite else member_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((BQ,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# multi-region membership: every region of a VersionedIndex in one launch
# ---------------------------------------------------------------------------

def _make_multi_member_kernel(num_pos: int, num_neg: int,
                              composite: bool = False):
    """Kernel over ``num_pos`` positive + ``num_neg`` negative regions.

    Ref layout: [keys2d, vals2d, n] per region (positives first) — or
    [keys2d, los2d, vals2d, n] when ``composite`` — then qk[, ql], qv;
    outputs (wpos, wneg) — int32 hit counts over the positive / negative
    regions, from which membership is ``wpos - wneg > 0`` and deletion is
    ``wneg > 0``.
    """
    R = num_pos + num_neg
    per = 4 if composite else 3
    nq = 3 if composite else 2

    def kernel(*refs):
        region_refs = refs[:per * R]
        qrefs = refs[per * R: per * R + nq]
        wpos_ref, wneg_ref = refs[per * R + nq], refs[per * R + nq + 1]
        if composite:
            qk, ql, qv = (q[...] for q in qrefs)
        else:
            (qk, qv), ql = (q[...] for q in qrefs), None
        wpos = jnp.zeros(qk.shape, jnp.int32)
        wneg = jnp.zeros(qk.shape, jnp.int32)
        for r in range(R):
            regs = region_refs[per * r: per * (r + 1)]
            if composite:
                keys_ref, los_ref, vals_ref, n_ref = regs
                hits = _two_level_hits(keys_ref[...], vals_ref[...], n_ref[0],
                                       qk.astype(keys_ref.dtype), qv,
                                       los2d=los_ref[...], ql=ql)
            else:
                keys_ref, vals_ref, n_ref = regs
                hits = _two_level_hits(keys_ref[...], vals_ref[...], n_ref[0],
                                       qk.astype(keys_ref.dtype), qv)
            if r < num_pos:
                wpos = wpos + hits
            else:
                wneg = wneg + hits
        wpos_ref[...] = wpos
        wneg_ref[...] = wneg

    return kernel


@functools.partial(jax.jit, static_argnames=("num_pos", "interpret"))
def _multi_member_call(regions, qk, qv, num_pos: int,
                       interpret: bool = True, ql=None):
    """regions: flat tuple of (keys2d [S_r, SEG], vals2d, n [1]) triples —
    or (keys2d, los2d, vals2d, n) quads with ``ql`` for composite keys —
    positives first.  Returns (wpos, wneg) int32 [B]."""
    B = qk.shape[0]
    grid = (B // BQ,)
    composite = ql is not None
    in_specs = []
    operands = []
    for reg in regions:
        keys2d = reg[0]
        s = keys2d.shape[0]
        full = pl.BlockSpec((s, SEG), lambda i: (0, 0))
        in_specs += [full] * (len(reg) - 1) + [
            pl.BlockSpec((1,), lambda i: (0,))]
        operands += list(reg)
    qspec = pl.BlockSpec((BQ,), lambda i: (i,))
    in_specs += [qspec] * (3 if composite else 2)
    operands += [qk, ql, qv] if composite else [qk, qv]
    return pl.pallas_call(
        _make_multi_member_kernel(num_pos, len(regions) - num_pos,
                                  composite=composite),
        grid=grid,
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((BQ,), lambda i: (i,)),
                   pl.BlockSpec((BQ,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((B,), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.int32)),
        interpret=interpret,
    )(*operands)
