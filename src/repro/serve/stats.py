"""Serving observability: per-tenant counters + pool-level aggregates.

Everything here is plain host bookkeeping updated under the pool's lock —
no device calls, no jax imports — so reading stats never perturbs the
epoch pipeline.  ``ServeStats.render()`` is the human surface the
``serve --concurrent`` CLI prints; the dict forms feed the serving
benchmark's JSON rows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


def percentiles(samples: List[float]) -> Dict[str, float]:
    """p50/p95/p99/max (milliseconds in, milliseconds out) plus the
    p99/p50 tail ratio the latency gates key on; zeros when empty."""
    if not samples:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0,
                "p99_p50_ratio": 0.0}
    p50, p95, p99 = np.percentile(samples, [50, 95, 99])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
            "max": float(max(samples)),
            "p99_p50_ratio": float(p99 / max(p50, 1e-9))}


@dataclasses.dataclass
class TenantStats:
    """One tenant's serving counters.

    ``submitted`` counts accepted batches; ``shed`` counts batches the
    bounded ingest queue refused (backpressure — the mesh never stalled
    for them); ``retired`` counts batches whose ticket resolved.
    ``epochs`` is the number of DEVICE epochs run — adaptive coalescing
    folds up to ``coalesce`` queued batches into one epoch, so
    ``retired - epochs`` (= ``coalesced_away``) batches rode a shared
    commit.  ``prep_ms``/``apply_ms`` time the two pipeline stages
    (host pack vs device normalize+dataflow+commit) per epoch.
    """

    name: str
    submitted: int = 0
    retired: int = 0
    shed: int = 0
    failed: int = 0
    epochs: int = 0
    coalesced_away: int = 0
    queue_depth: int = 0
    snapshots: int = 0
    replayed: int = 0
    prewarm_compiles: int = 0
    # -- robustness counters (DESIGN.md §10).  ``escalations`` counts
    # capacity-rung bumps the engines made mid-serve; ``replays`` the
    # epochs transparently re-run after one; ``escalation_compiles`` the
    # jit traces those re-prewarms cost (excluded from the zero-compile
    # serving gate).  ``wal_errors`` counts append attempts that failed
    # and were retried; ``wal_degraded`` latches once retries were
    # exhausted and the tenant now serves WITHOUT durability.
    escalations: int = 0
    replays: int = 0
    escalation_compiles: int = 0
    wal_errors: int = 0
    wal_degraded: bool = False
    quarantined: bool = False
    faults_injected: int = 0
    prep_ms: List[float] = dataclasses.field(default_factory=list)
    apply_ms: List[float] = dataclasses.field(default_factory=list)

    def latency(self) -> Dict[str, float]:
        return percentiles(self.apply_ms)

    def as_dict(self) -> dict:
        d = {f: getattr(self, f) for f in
             ("name", "submitted", "retired", "shed", "failed", "epochs",
              "coalesced_away", "queue_depth", "snapshots", "replayed",
              "prewarm_compiles", "escalations", "replays",
              "escalation_compiles", "wal_errors", "wal_degraded",
              "quarantined", "faults_injected")}
        d["latency_ms"] = self.latency()
        d["prep_ms_p50"] = float(np.median(self.prep_ms)) \
            if self.prep_ms else 0.0
        return d


@dataclasses.dataclass
class ServeStats:
    """Pool-level aggregate over every tenant's :class:`TenantStats`.

    ``serve_compiles`` is the number of jit traces recorded AFTER the last
    tenant admission finished its prewarm — the serving-path compile
    budget; steady state it must be ZERO (the §8 invariant lifted to the
    pool), which the serving-smoke CI lane asserts.
    """

    tenants: Dict[str, TenantStats] = dataclasses.field(default_factory=dict)
    prewarm_compiles: int = 0
    serve_compiles: int = 0
    wall_s: float = 0.0

    def aggregate(self) -> dict:
        eps = sum(t.epochs for t in self.tenants.values())
        ret = sum(t.retired for t in self.tenants.values())
        all_lat = [ms for t in self.tenants.values() for ms in t.apply_ms]
        return {
            "tenants": len(self.tenants),
            "epochs": eps,
            "retired": ret,
            "shed": sum(t.shed for t in self.tenants.values()),
            "snapshots": sum(t.snapshots for t in self.tenants.values()),
            "replayed": sum(t.replayed for t in self.tenants.values()),
            "epochs_per_s": eps / self.wall_s if self.wall_s else 0.0,
            "batches_per_s": ret / self.wall_s if self.wall_s else 0.0,
            "latency_ms": percentiles(all_lat),
            "prewarm_compiles": self.prewarm_compiles,
            "serve_compiles": self.serve_compiles,
            "escalations": sum(t.escalations for t in self.tenants.values()),
            "replays": sum(t.replays for t in self.tenants.values()),
            "escalation_compiles": sum(
                t.escalation_compiles for t in self.tenants.values()),
            "failed": sum(t.failed for t in self.tenants.values()),
            "wal_errors": sum(t.wal_errors for t in self.tenants.values()),
            "wal_degraded": sum(
                1 for t in self.tenants.values() if t.wal_degraded),
            "quarantined": sum(
                1 for t in self.tenants.values() if t.quarantined),
            "faults_injected": sum(
                t.faults_injected for t in self.tenants.values()),
        }

    def render(self) -> str:
        agg = self.aggregate()
        lat = agg["latency_ms"]
        lines = [
            f"pool: {agg['tenants']} tenants, {agg['epochs']} device epochs "
            f"({agg['retired']} batches, {agg['shed']} shed) in "
            f"{self.wall_s:.1f}s — {agg['batches_per_s']:,.1f} batches/s; "
            f"latency p50 {lat['p50']:.1f} ms  p95 {lat['p95']:.1f} ms  "
            f"p99 {lat['p99']:.1f} ms (p99/p50 "
            f"{lat['p99_p50_ratio']:.1f}x); compile events: "
            f"{self.prewarm_compiles} admission + {self.serve_compiles} "
            "serving"]
        if (agg["escalations"] or agg["failed"] or agg["wal_errors"]
                or agg["quarantined"] or agg["faults_injected"]):
            lines.append(
                f"robustness: {agg['escalations']} escalations / "
                f"{agg['replays']} replays "
                f"({agg['escalation_compiles']} compiles), "
                f"{agg['failed']} failed batches, {agg['wal_errors']} WAL "
                f"errors ({agg['wal_degraded']} degraded tenants), "
                f"{agg['quarantined']} quarantined, "
                f"{agg['faults_injected']} faults injected")
        for name in sorted(self.tenants):
            t = self.tenants[name]
            tl = t.latency()
            flags = ""
            if t.escalations or t.failed or t.wal_errors:
                flags = (f"; {t.escalations} escalations/"
                         f"{t.replays} replays, {t.failed} failed, "
                         f"{t.wal_errors} wal_errors")
            if t.wal_degraded:
                flags += " [NON-DURABLE]"
            if t.quarantined:
                flags += " [QUARANTINED]"
            lines.append(
                f"  {name}: {t.epochs} epochs / {t.retired} batches "
                f"({t.coalesced_away} coalesced, {t.shed} shed, depth "
                f"{t.queue_depth}); apply p50 {tl['p50']:.1f} ms p99 "
                f"{tl['p99']:.1f} ms; {t.snapshots} snapshots, "
                f"{t.replayed} replayed" + flags)
        return "\n".join(lines)
