"""Concurrent serving subsystem (DESIGN.md §9).

Multi-tenant sessions, pipelined epochs and snapshot/WAL failover on one
device mesh — the serving layer over :mod:`repro.api`:

- :class:`SessionPool` / :class:`TenantHandle` — N tenants, one mesh,
  bounded ingest queues with backpressure, adaptive batch coalescing,
  prep/apply pipeline, admission prewarm;
- :class:`WriteAheadLog` / :class:`Durability` — raw-batch WAL +
  snapshot cadence; bit-exact restore-and-replay recovery;
- :class:`ServeStats` / :class:`TenantStats` — queue depth, latency
  percentiles, compile events, snapshot/replay counters.
"""
from repro.serve.pool import SessionPool, TenantHandle, Ticket
from repro.serve.stats import ServeStats, TenantStats, percentiles
from repro.serve.wal import Durability, WriteAheadLog

__all__ = ["SessionPool", "TenantHandle", "Ticket", "ServeStats",
           "TenantStats", "percentiles", "Durability", "WriteAheadLog"]
