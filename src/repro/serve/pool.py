"""SessionPool: N tenants multiplexed onto ONE device mesh.

The serving layer the ROADMAP names above ``repro.api`` (cf. HUGE's
scheduler/memory layer around a WCO core, DDSL's long-running maintenance
service): each tenant owns an independent :class:`~repro.api.GraphSession`
— its own graph, standing queries and epoch counter — while every session
shares one mesh and one process-wide jit cache, so N tenants pay ONE set
of compiled fold/dataflow executables (identical shapes hit the cache
across tenants).

Scheduling (DESIGN.md §9):

- **Bounded ingest + backpressure.**  Each tenant has its own bounded
  ingest queue.  ``submit`` on a full queue blocks that CALLER (or sheds
  the batch with ``block=False``) — a slow tenant backs up into its own
  queue and never stalls the mesh or another tenant.
- **Adaptive coalescing.**  The prep stage drains up to ``coalesce``
  queued batches per epoch (bounded by the tenant's ``update_batch`` so
  the pinned probe shape — and the zero-compile guarantee — holds).
  For SIGN-CONSISTENT streams (every delete names a then-live tuple,
  every insert a then-absent one — ``data.synthetic.clean_update_batches``
  generates these) the merged epoch is exact: per-tuple net weight equals
  final-minus-initial membership.  Dirty streams that insert a live tuple
  in one batch and delete it in the next can net differently when merged
  (set semantics clamp the insert; the merged weights cancel instead) —
  tenants needing per-batch set semantics serve with ``coalesce=1``.
  Either way the WAL logs the MERGED batch the device actually applied,
  so recovery replay is always bit-exact with what was served.  All
  tickets of a group resolve to the shared EpochResult.
- **Pipelined epochs.**  A prep thread runs the pure-host stage A
  (``session.prepare``: validate/pack/pad, no jax call) while the apply
  thread runs stage B (``update(prepared=...)``: jitted normalize →
  dataflows → donated commit fold) — batch k+1's host work overlaps batch
  k's device work.  Round-robin across tenants in both stages keeps
  admission fair.  The SINGLE apply thread is also a correctness
  property, not just a scheduling choice: two host threads dispatching
  shard_map programs onto the same devices can interleave their
  collectives' rendezvous and deadlock — all device execution for all
  tenants goes through this one dispatcher.
- **Durability.**  With ``durable_dir``, each tenant gets a
  :class:`~repro.serve.wal.Durability` manager: WAL append before every
  apply, snapshot + WAL truncation on a cadence, recovery at admission
  (see ``wal.py`` for the bit-exact replay contract).

Admission prewarm: ``admit`` walks the session's AOT ladder
(``GraphSession.prewarm``) before the tenant serves, so steady-state
serving triggers ZERO XLA compiles (``ServeStats.serve_compiles``).
"""
from __future__ import annotations

import collections
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import faults
from repro.core import compilestats
from repro.errors import WalError
from repro.serve.stats import ServeStats, TenantStats
from repro.serve.wal import Durability


class Ticket:
    """One submitted batch's future result (thread-safe).

    Resolves to the :class:`~repro.api.session.EpochResult` of the device
    epoch that carried the batch — shared by every batch coalesced into
    that epoch.  Exceptions from the epoch propagate out of
    :meth:`result`."""

    __slots__ = ("_event", "_result", "_error")

    def __init__(self):
        self._event = threading.Event()
        self._result = None
        self._error = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError("epoch still in flight")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result=None, error=None):
        self._result = result
        self._error = error
        self._event.set()


class _Tenant:
    """Pool-internal per-tenant state (guarded by the pool's condition)."""

    def __init__(self, name: str, session, max_queue: int, coalesce: int,
                 durability: Optional[Durability]):
        self.name = name
        self.session = session
        self.max_queue = int(max_queue)
        self.coalesce = max(int(coalesce), 1)
        self.durability = durability
        # ingest: (batches_dict, ticket); prepared: one in-flight slot
        self.ingest = collections.deque()
        self.prepared = None  # (PreparedBatch, tickets, prep_ms)
        self.stats = TenantStats(name=name)
        # robustness (DESIGN.md §10): durable=False after WAL degrade;
        # consecutive_failures feeds the quarantine trip wire.
        self.durable = durability is not None
        self.consecutive_failures = 0
        self.quarantined = False


class TenantHandle:
    """Public face of one admitted tenant."""

    def __init__(self, pool: "SessionPool", name: str):
        self.pool = pool
        self.name = name

    @property
    def session(self):
        return self.pool._tenants[self.name].session

    @property
    def stats(self) -> TenantStats:
        return self.pool._tenants[self.name].stats

    def submit(self, updates, weights=None, *, block: bool = True,
               timeout: Optional[float] = None) -> Optional[Ticket]:
        return self.pool.submit(self.name, updates, weights, block=block,
                                timeout=timeout)

    def __repr__(self):  # pragma: no cover - debug aid
        return f"TenantHandle({self.name!r})"


class SessionPool:
    """Multiplex N tenant GraphSessions onto one mesh (module docstring)."""

    def __init__(self, *, local: Optional[bool] = None, mesh=None,
                 balance: bool = False, update_batch: int = 2048,
                 prewarm: bool = True, horizon: Optional[int] = None,
                 pipeline: bool = True, durable_dir: Optional[str] = None,
                 snapshot_every: int = 8, keep_last: int = 3,
                 fsync: bool = True,
                 on_logged: Optional[Callable[[str, int], None]] = None,
                 quarantine_after: int = 3, wal_retries: int = 3,
                 wal_backoff_s: float = 0.02):
        import jax
        if local is None:
            local = mesh is None and jax.device_count() == 1
        self.local = bool(local)
        if not self.local and mesh is None:
            from jax.sharding import Mesh
            from repro.core.distributed import AXIS
            mesh = Mesh(np.array(jax.devices()), (AXIS,))
        self.mesh = None if self.local else mesh
        self.balance = balance
        self.update_batch = int(update_batch)
        self.prewarm = bool(prewarm)
        self.horizon = horizon
        self.pipeline = bool(pipeline)
        self.durable_dir = durable_dir
        self.snapshot_every = int(snapshot_every)
        self.keep_last = int(keep_last)
        self.fsync = bool(fsync)
        self.on_logged = on_logged  # test hook: fires after WAL append
        # robustness knobs (DESIGN.md §10): a tenant whose epochs fail
        # ``quarantine_after`` times IN A ROW is fenced off (its queue
        # failed, new submits refused) so a poisoned stream can't spin
        # the shared apply thread forever; WAL appends retry
        # ``wal_retries`` times with linear backoff, then the tenant
        # LOUDLY degrades to non-durable serving rather than stalling.
        self.quarantine_after = int(quarantine_after)
        self.wal_retries = int(wal_retries)
        self.wal_backoff_s = float(wal_backoff_s)
        self._cv = threading.Condition()
        self._tenants: Dict[str, _Tenant] = {}
        self._names: List[str] = []
        self._rr = {"prep": 0, "apply": 0}
        self._inflight = 0
        self._stop = False
        self._threads: List[threading.Thread] = []
        self._error: Optional[BaseException] = None
        self._prewarm_compiles = 0
        self._serve_snap = compilestats.snapshot()
        self._t_started = time.perf_counter()

    # -- admission ------------------------------------------------------
    def admit(self, name: str, initial, queries=(), *,
              setup: Optional[Callable] = None, max_queue: int = 64,
              coalesce: int = 8, batch: Optional[int] = None,
              out_capacity: Optional[int] = None,
              update_batch: Optional[int] = None,
              recover: bool = True) -> TenantHandle:
        """Admit one tenant: build its session (on the POOL's mesh),
        register ``queries`` (names/patterns/Query objects), run the
        optional ``setup(session)`` hook (extra relations, subscriptions),
        recover durable state if present, then prewarm — so the tenant's
        serving path never compiles.  Returns its handle."""
        from repro.api import GraphSession
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already admitted")
        session = GraphSession(
            initial, local=self.local, mesh=self.mesh, balance=self.balance,
            batch=batch, out_capacity=out_capacity,
            update_batch=update_batch or self.update_batch)
        for q in queries:
            session.register(q)
        if setup is not None:
            setup(session)
        durability = None
        replayed = 0
        if self.durable_dir:
            durability = Durability(
                os.path.join(self.durable_dir, name), session,
                snapshot_every=self.snapshot_every,
                keep_last=self.keep_last, fsync=self.fsync)
            if recover:
                durability.recover()
                replayed = durability.replayed
        snap = compilestats.snapshot()
        if self.prewarm:
            session.prewarm(horizon=self.horizon)
        spent = compilestats.since(snap)
        tenant = _Tenant(name, session, max_queue, coalesce, durability)
        tenant.stats.prewarm_compiles = spent
        tenant.stats.replayed = replayed
        if durability is not None:
            tenant.stats.snapshots = durability.snapshots
        with self._cv:
            self._tenants[name] = tenant
            self._names.append(name)
            self._prewarm_compiles += spent
            # the serving compile budget — and the throughput wall clock —
            # start AFTER the last admission
            self._serve_snap = compilestats.snapshot()
            self._t_started = time.perf_counter()
            self._cv.notify_all()
        return TenantHandle(self, name)

    def tenant(self, name: str) -> TenantHandle:
        self._tenants[name]  # raises KeyError on unknown tenants
        return TenantHandle(self, name)

    # -- ingest ---------------------------------------------------------
    @staticmethod
    def _as_dict(session, updates, weights) -> Dict[str, Tuple]:
        """Uniform {rel: (rows, weights)} form (host-side, unvalidated —
        ``prepare`` validates after coalescing)."""
        if isinstance(updates, dict):
            if weights is not None:
                raise ValueError(
                    "per-relation batches carry their own weights")
            out = {}
            for rel, batch in updates.items():
                rows, w = session.store._split(rel, batch)
                rows = np.asarray(rows)
                if w is None:
                    w = np.ones(rows.shape[0], np.int32)
                out[rel] = (rows, np.asarray(w))
            return out
        rows = np.asarray(updates)
        if weights is None:
            weights = np.ones(rows.shape[0], np.int32)
        return {"edge": (rows, np.asarray(weights))}

    def submit(self, name: str, updates, weights=None, *,
               block: bool = True, timeout: Optional[float] = None
               ) -> Optional[Ticket]:
        """Enqueue one batch for ``name``.  Bounded-queue backpressure:
        a full queue blocks this caller (``block=True``) or sheds the
        batch and returns None (``block=False`` / timeout expiry) — the
        mesh and the other tenants never wait on it."""
        t = self._tenants[name]
        if t.quarantined:
            raise RuntimeError(
                f"tenant {name!r} is quarantined after "
                f"{self.quarantine_after} consecutive epoch failures")
        batches = self._as_dict(t.session, updates, weights)
        deadline = None if timeout is None else \
            time.perf_counter() + timeout
        with self._cv:
            while len(t.ingest) >= t.max_queue and not self._stop:
                if not block:
                    t.stats.shed += 1
                    return None
                remaining = None if deadline is None else \
                    deadline - time.perf_counter()
                if remaining is not None and remaining <= 0 or \
                        not self._cv.wait(remaining):
                    t.stats.shed += 1
                    return None
            if self._stop:
                raise RuntimeError("pool is closed")
            ticket = Ticket()
            t.ingest.append((batches, ticket))
            t.stats.submitted += 1
            t.stats.queue_depth = len(t.ingest)
            self._inflight += 1
            self._cv.notify_all()
        if self.pipeline:
            self._ensure_started()
        return ticket

    # -- the two pipeline stages ---------------------------------------
    def _next_prep(self):
        """Round-robin pick: one tenant with queued work and a free
        prepared slot; drains its coalesce group.  Caller holds _cv."""
        n = len(self._names)
        for k in range(n):
            i = (self._rr["prep"] + k) % n
            t = self._tenants[self._names[i]]
            if not t.ingest or t.prepared is not None or t.quarantined:
                continue
            self._rr["prep"] = i + 1
            group = [t.ingest.popleft()]
            rows = sum(r.shape[0] for r, _w in group[0][0].values())
            cap = t.session.update_batch
            while t.ingest and len(group) < t.coalesce:
                nxt_rows = sum(r.shape[0]
                               for r, _w in t.ingest[0][0].values())
                if rows + nxt_rows > cap:
                    break  # keep the pinned probe shape (zero-compile)
                group.append(t.ingest.popleft())
                rows += nxt_rows
            t.stats.queue_depth = len(t.ingest)
            self._cv.notify_all()  # queue space freed: unblock submitters
            return t, group
        return None

    @staticmethod
    def _merge(group) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
        """Concatenate a coalesce group's per-relation batches — exact
        under signed-weight netting (normalize sums weights per tuple)."""
        if len(group) == 1:
            return group[0][0]
        merged: Dict[str, List] = {}
        for batches, _ticket in group:
            for rel, (rows, w) in batches.items():
                merged.setdefault(rel, []).append((rows, w))
        return {rel: (np.concatenate([r for r, _ in parts]),
                      np.concatenate([w for _, w in parts]))
                for rel, parts in merged.items()}

    def _prep_one(self, t: _Tenant, group) -> bool:
        """Stage A for one coalesce group (host-only).  Returns False when
        the group failed validation (tickets carry the error)."""
        tickets = [ticket for _b, ticket in group]
        t0 = time.perf_counter()
        try:
            faults.fire("pool.prep")
            prep = t.session.prepare(self._merge(group))
        except Exception as e:  # bad batch: fail its tickets, keep serving
            self._fail_group(t, tickets, e)
            return False
        ms = (time.perf_counter() - t0) * 1e3
        with self._cv:
            if not t.quarantined:
                t.prepared = (prep, tickets, ms)
                self._cv.notify_all()
                return True
        # the fence tripped while we were preparing: fail, don't apply
        err = RuntimeError(f"tenant {t.name!r} is quarantined")
        self._fail_group(t, tickets, err, count_failure=False)
        return False

    def _fail_group(self, t: _Tenant, tickets, error, *,
                    count_failure: bool = True) -> None:
        """Fail one group's tickets; bump the consecutive-failure count
        and trip the quarantine fence when it reaches the threshold
        (failing everything still queued — a poisoned tenant must not
        spin the shared apply thread forever)."""
        dropped = []
        with self._cv:
            t.stats.failed += len(tickets)
            self._inflight -= len(tickets)
            if count_failure:
                t.consecutive_failures += 1
            if (not t.quarantined and self.quarantine_after > 0
                    and t.consecutive_failures >= self.quarantine_after):
                t.quarantined = True
                t.stats.quarantined = True
                while t.ingest:
                    dropped.append(t.ingest.popleft()[1])
                if t.prepared is not None:
                    dropped.extend(t.prepared[1])
                    t.prepared = None
                t.stats.failed += len(dropped)
                self._inflight -= len(dropped)
                t.stats.queue_depth = 0
            self._cv.notify_all()
        for ticket in tickets:
            ticket._resolve(error=error)
        if dropped:
            qerr = RuntimeError(
                f"tenant {t.name!r} quarantined after "
                f"{t.consecutive_failures} consecutive epoch failures")
            for ticket in dropped:
                ticket._resolve(error=qerr)

    def _next_apply(self):
        """Round-robin pick of one tenant with a prepared epoch; takes the
        slot (freeing it for the prep stage).  Caller holds _cv."""
        n = len(self._names)
        for k in range(n):
            i = (self._rr["apply"] + k) % n
            t = self._tenants[self._names[i]]
            if t.prepared is None:
                continue
            self._rr["apply"] = i + 1
            job = t.prepared
            t.prepared = None
            self._cv.notify_all()
            return (t,) + job
        return None

    def _wal_log(self, t: _Tenant, raw) -> Optional[int]:
        """Durably append one epoch's raw batches with bounded retry.

        Each :class:`WalError` rolls back the partial record
        (``abort_last``), counts in ``stats.wal_errors`` and retries
        after a linear backoff; when ``wal_retries`` retries are
        exhausted the tenant LOUDLY degrades to non-durable serving
        (``stats.wal_degraded``) instead of stalling the shared apply
        thread — epochs keep committing, recovery just can't replay
        them.  Returns the logged epoch, or None once degraded."""
        last: Optional[WalError] = None
        for attempt in range(self.wal_retries + 1):
            if last is not None:
                try:
                    t.durability.wal.abort_last()
                except WalError:
                    pass  # torn tail is tolerated by replay anyway
                time.sleep(self.wal_backoff_s * attempt)
            try:
                return t.durability.log(raw)
            except WalError as e:
                last = e
                with self._cv:
                    t.stats.wal_errors += 1
        try:
            t.durability.wal.abort_last()
        except WalError:
            pass
        with self._cv:
            t.durable = False
            t.stats.wal_degraded = True
        return None

    def _sync_robustness(self, t: _Tenant, faults_before: int) -> None:
        """Mirror the session store's escalation counters (absolute —
        the store is per-tenant) and attribute newly injected faults."""
        st = t.session.store.stats
        with self._cv:
            t.stats.escalations = st.escalations
            t.stats.replays = st.replays
            t.stats.escalation_compiles = st.escalation_compiles
            t.stats.faults_injected += len(faults.injected()) - faults_before

    def _apply_one(self, t: _Tenant, prep, tickets, prep_ms):
        """Stage B for one prepared epoch: WAL append (bounded retry /
        degrade), device apply (overflow escalation + replay happens
        INSIDE ``session.update``), snapshot cadence, ticket resolution.
        A failed apply aborts the epoch's WAL record so recovery never
        replays a batch the live run rejected."""
        t0 = time.perf_counter()
        faults_before = len(faults.injected())
        logged = False
        try:
            faults.fire("pool.apply")
            if t.durability is not None and t.durable:
                epoch = self._wal_log(t, prep.raw)
                logged = epoch is not None
                if logged and self.on_logged is not None:
                    self.on_logged(t.name, epoch)
            res = t.session.update(prepared=prep)
            if t.durability is not None and t.durable:
                try:
                    t.durability.maybe_snapshot()
                except Exception:
                    # the epoch is already durable in the WAL; a failed
                    # snapshot only skips the cadence, never the commit
                    with self._cv:
                        t.stats.wal_errors += 1
        except Exception as e:
            if logged:
                try:
                    t.durability.wal.abort_last()
                except WalError:
                    pass
            self._sync_robustness(t, faults_before)
            self._fail_group(t, tickets, e)
            return
        ms = (time.perf_counter() - t0) * 1e3
        self._sync_robustness(t, faults_before)
        with self._cv:
            t.consecutive_failures = 0
            t.stats.epochs += 1
            t.stats.retired += len(tickets)
            t.stats.coalesced_away += len(tickets) - 1
            t.stats.prep_ms.append(prep_ms)
            t.stats.apply_ms.append(ms)
            if t.durability is not None:
                t.stats.snapshots = t.durability.snapshots
            self._inflight -= len(tickets)
            self._cv.notify_all()
        for ticket in tickets:
            ticket._resolve(result=res)

    # -- threads --------------------------------------------------------
    def _ensure_started(self):
        with self._cv:
            if self._threads or self._stop:
                return
            self._threads = [
                threading.Thread(target=self._prep_loop,
                                 name="pool-prep", daemon=True),
                threading.Thread(target=self._apply_loop,
                                 name="pool-apply", daemon=True)]
            for th in self._threads:
                th.start()

    def _prep_loop(self):
        while True:
            with self._cv:
                job = None
                while not self._stop:
                    job = self._next_prep()
                    if job is not None:
                        break
                    self._cv.wait(0.1)
                if job is None:
                    return
            self._prep_one(*job)

    def _apply_loop(self):
        while True:
            with self._cv:
                job = None
                while not self._stop:
                    job = self._next_apply()
                    if job is not None:
                        break
                    self._cv.wait(0.1)
                if job is None:
                    return
            try:
                self._apply_one(*job)
            except BaseException as e:  # pragma: no cover - fatal only
                with self._cv:
                    self._error = e
                    self._cv.notify_all()
                raise

    # -- lifecycle ------------------------------------------------------
    def pump(self):
        """Synchronous pipeline pump (``pipeline=False`` mode and tests):
        run prep+apply inline on the calling thread until idle."""
        while True:
            with self._cv:
                job = self._next_prep()
            if job is not None:
                if not self._prep_one(*job):
                    continue
            with self._cv:
                ajob = self._next_apply()
            if ajob is None:
                if job is None:
                    return
                continue
            self._apply_one(*ajob)

    def drain(self, timeout: Optional[float] = None):
        """Block until every accepted batch has retired (or failed)."""
        if not self.pipeline:
            self.pump()
            return
        self._ensure_started()
        deadline = None if timeout is None else \
            time.perf_counter() + timeout
        with self._cv:
            while self._inflight > 0:
                if self._error is not None:
                    raise RuntimeError(
                        "pool apply thread died") from self._error
                remaining = None if deadline is None else \
                    deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"{self._inflight} batches still in flight")
                self._cv.wait(0.1 if remaining is None
                              else min(remaining, 0.1))

    def close(self, drain: bool = True):
        """Drain (optionally), stop the pipeline threads, flush WALs."""
        if drain and not self._stop:
            self.drain()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for th in self._threads:
            th.join(timeout=10)
        self._threads = []
        for t in self._tenants.values():
            if t.durability is not None:
                t.durability.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc[0] is None)

    # -- observability --------------------------------------------------
    def stats(self) -> ServeStats:
        """Pool aggregate: per-tenant counters + the serving compile
        budget (jit traces since the last admission's prewarm)."""
        with self._cv:
            tenants = {name: t.stats for name, t in self._tenants.items()}
            return ServeStats(
                tenants=tenants,
                prewarm_compiles=self._prewarm_compiles,
                serve_compiles=compilestats.since(self._serve_snap),
                wall_s=time.perf_counter() - self._t_started)
