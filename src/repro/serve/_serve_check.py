"""Concurrent-serving differential harness (DESIGN.md §9).

Run as a subprocess so the XLA host-platform device-count override applies
before jax initializes (tests and benches must keep seeing 1 device):

    # Mode A: N tenants through one SessionPool vs prewarmed isolated
    # oracle sessions — per-epoch deltas bit-exact, serving compiles 0
    python -m repro.serve._serve_check --tenants 4 --workers 4 --epochs 20

    # Mode B: kill/resume failover — an uninterrupted oracle RUN, a victim
    # run killed mid-stream (os._exit right after a WAL append), and a
    # resume run that recovers snapshot+WAL and finishes the stream; the
    # parent diffs per-epoch delta digests and final state digests
    python -m repro.serve._serve_check --supervise --tenants 4 --workers 4 \
        --epochs 20 --kill-at 13

    # Mode C: chaos — a seeded random fault schedule (repro.faults) armed
    # across ALL eight fault points while N tenants serve; failed epochs
    # roll back atomically, overflows escalate+replay transparently, and
    # the final per-tenant state must be BIT-EXACT with a fault-free
    # in-process oracle that applied exactly the batches that succeeded.
    # Failed batches are excluded AND accounted (submitted == retired +
    # failed); serving compiles beyond escalation re-prewarms must be 0.
    python -m repro.serve._serve_check --chaos --tenants 4 --workers 4 \
        --epochs 30 --tight-out 32

Every tenant gets its OWN initial graph and update stream (derived from
``--seed`` + tenant index, so a resume child regenerates them exactly);
batches are drawn with ``insert_frac=0.5`` so the live set stays within its
pow2 base rung — the zero-compile serving budget holds for the whole run
(base-region outgrowth is the documented §8 amortized-rare exception, not a
serving property).  Prints one JSON line; exit code 0 iff every check held.
"""
import os
import sys


def _digest(obj) -> str:
    import hashlib
    return hashlib.sha1(repr(obj).encode()).hexdigest()[:16]


def worker(args) -> int:
    """One serving run (Mode A, or one leg of Mode B).  Drives every
    tenant synchronously — submit one batch per tenant per step, wait for
    all tickets — so per-epoch deltas are attributable and streams can be
    re-derived from the live set after recovery."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.workers}")

    import json
    import time

    import numpy as np

    from repro.api import GraphSession, canon_signed as canon
    from repro.data.synthetic import EdgeUpdateStream, uniform_graph
    from repro.serve import SessionPool

    t_start = time.time()

    def note(msg):
        # stage timings on stderr: CI logs show where a slow run spends
        # its wall clock (cold XLA ladder walks dominate without
        # REPRO_COMPILE_CACHE)
        sys.stderr.write(f"[serve_check +{time.time() - t_start:7.1f}s] "
                         f"{msg}\n")
        sys.stderr.flush()

    names = [f"t{i}" for i in range(args.tenants)]
    graphs = {n: uniform_graph(args.nv, args.ne, args.seed + i)
              for i, n in enumerate(names)}
    streams = {n: EdgeUpdateStream(args.nv, args.batch_size,
                                   insert_frac=0.5, seed=args.seed + 100 + i)
               for i, n in enumerate(names)}

    # In-process oracles FIRST (Mode A only): prewarming them here both
    # keeps the differential honest (identical shapes -> the pool's later
    # admissions hit the jit cache) and keeps oracle traces out of the
    # pool's serving compile budget.
    oracles = {}
    if args.oracle:
        for n in names:
            o = GraphSession(graphs[n], local=args.local,
                             update_batch=args.update_batch)
            o.register(args.query)
            spent = o.prewarm(horizon=args.update_batch * (args.epochs + 2))
            note(f"oracle {n}: {len(graphs[n])} edges, "
                 f"prewarm {spent} compiles")
            oracles[n] = o

    kill_box = {}

    def on_logged(name, epoch):
        # fires right AFTER the WAL append, BEFORE the device apply: the
        # harshest crash point — the record must replay as the apply
        if args.kill_at and name == args.kill_tenant and \
                epoch == args.kill_at:
            sys.stdout.flush()
            os._exit(9)
        kill_box[name] = epoch

    pool = SessionPool(
        local=args.local, update_batch=args.update_batch,
        pipeline=not args.pump, durable_dir=args.durable_dir,
        snapshot_every=args.snapshot_every, fsync=not args.no_fsync,
        on_logged=on_logged if args.durable_dir else None,
        horizon=args.update_batch * (args.epochs + 2))
    handles, starts, lives = {}, {}, {}
    for n in names:
        handles[n] = pool.admit(n, graphs[n], queries=(args.query,),
                                coalesce=1, update_batch=args.update_batch)
        starts[n] = handles[n].session.epoch  # >0 after recovery
        lives[n] = np.asarray(handles[n].session.edges)
        note(f"admitted {n}: start epoch {starts[n]}, "
             f"prewarm {handles[n].stats.prewarm_compiles} compiles, "
             f"replayed {handles[n].stats.replayed}")

    digests = {n: {} for n in names}
    exact = True
    t0 = time.time()
    for step in range(args.epochs):
        tickets = {}
        for n in names:
            if step < starts[n]:
                continue  # this tenant's recovery already covered it
            upd, w = streams[n].batch_at(step, live=lives[n])
            tickets[n] = (handles[n].submit(upd, w), upd, w)
        if args.pump:
            pool.pump()
        served = {}
        for n, (ticket, upd, w) in tickets.items():
            res = ticket.result(timeout=600)
            lives[n] = res.advance(lives[n])
            d = res.deltas[args.query]
            served[n] = canon(d.tuples, d.weights)
            digests[n][str(res.epoch)] = _digest(served[n])
        # every ticket above has resolved, so the pool's apply thread is
        # idle — only NOW is it safe to run the oracles' mesh programs on
        # this thread.  Two host threads dispatching shard_map programs
        # onto the same devices interleave their collectives' rendezvous
        # and deadlock (the pool's single apply thread is what makes the
        # serving path itself safe; see DESIGN.md §9).
        for n, (_ticket, upd, w) in tickets.items():
            if n in oracles:
                ores = oracles[n].update(upd, w)
                od = ores.deltas[args.query]
                exact = exact and (
                    served[n] == canon(od.tuples, od.weights))
    pool.drain()
    note(f"served {args.epochs} steps x {args.tenants} tenants")
    stats = pool.stats()
    final = {}
    for n in names:
        s = handles[n].session
        final[n] = {
            "epoch": int(s.epoch),
            "num_edges": int(s.num_edges),
            "edges": _digest(np.asarray(s.edges).tobytes()),
            "net_change": int(s[args.query].net_change)}
        if n in oracles:
            o = oracles[n]
            exact = exact and (
                final[n]["edges"] == _digest(np.asarray(o.edges).tobytes())
                and final[n]["net_change"]
                == int(o[args.query].net_change))
    pool.close()
    agg = stats.aggregate()
    out = {
        "mode": "worker",
        "workers": args.workers, "local": bool(args.local),
        "tenants": args.tenants, "epochs": args.epochs,
        "starts": {n: int(s) for n, s in starts.items()},
        "oracle_exact": bool(exact) if args.oracle else None,
        "prewarm_compiles": agg["prewarm_compiles"],
        "serve_compiles": agg["serve_compiles"],
        "snapshots": agg["snapshots"],
        "replayed": agg["replayed"],
        "elapsed_s": round(time.time() - t0, 2),
        "digests": digests,
        "final": final,
    }
    print(json.dumps(out))
    ok = (exact if args.oracle else True) and agg["serve_compiles"] == 0
    return 0 if ok else 1


def chaos(args) -> int:
    """Mode C: deterministic chaos run (module docstring).

    Pump mode on purpose: prep+apply run inline on THIS thread, so the
    fault registry's hit counters advance in one deterministic order and
    a (seed, rate) pair — or a pinned ``--faults`` spec — reproduces the
    exact same injection sequence every run.  The fault-free oracles run
    in the same process under ``faults.disabled()`` and apply ONLY the
    batches whose tickets resolved, so any torn commit (a rollback that
    left partial state) or lost/duplicated batch shows up as a digest
    mismatch."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.workers}")

    import json
    import shutil
    import tempfile
    import time

    import numpy as np

    from repro import faults
    from repro.api import GraphSession, canon_signed as canon
    from repro.data.synthetic import EdgeUpdateStream, uniform_graph
    from repro.serve import SessionPool

    t_start = time.time()

    def note(msg):
        sys.stderr.write(f"[chaos +{time.time() - t_start:7.1f}s] {msg}\n")
        sys.stderr.flush()

    names = [f"t{i}" for i in range(args.tenants)]
    graphs = {n: uniform_graph(args.nv, args.ne, args.seed + i)
              for i, n in enumerate(names)}
    streams = {n: EdgeUpdateStream(args.nv, args.batch_size,
                                   insert_frac=0.5, seed=args.seed + 100 + i)
               for i, n in enumerate(names)}

    oracles = {}
    for n in names:
        o = GraphSession(graphs[n], local=args.local,
                         update_batch=args.update_batch)
        o.register(args.query)
        o.prewarm(horizon=args.update_batch * (args.epochs + 2))
        oracles[n] = o
    note(f"{len(oracles)} fault-free oracles prewarmed")

    tmp = args.durable_dir or tempfile.mkdtemp(prefix="serve_chaos_")
    pool = SessionPool(
        local=args.local, update_batch=args.update_batch,
        pipeline=False, durable_dir=tmp,
        snapshot_every=args.snapshot_every, fsync=not args.no_fsync,
        horizon=args.update_batch * (args.epochs + 2))
    handles, lives = {}, {}
    for n in names:
        # --tight-out admits tenants with a deliberately small output
        # rung so real overflows occur and must escalate+replay — the
        # oracles keep default sizing, so exactness also proves the
        # escalated replay path
        handles[n] = pool.admit(
            n, graphs[n], queries=(args.query,), coalesce=1,
            out_capacity=args.tight_out or None,
            update_batch=args.update_batch)
        lives[n] = np.asarray(handles[n].session.edges)
    note(f"admitted {args.tenants} tenants"
         + (f" (tight out rung {args.tight_out})" if args.tight_out else ""))

    if args.faults:
        schedule = faults.parse_spec(args.faults)
        note(f"pinned fault schedule: {args.faults}")
    else:
        schedule = faults.random_schedule(
            args.seed + 777, horizon=args.chaos_horizon,
            rate=args.chaos_rate)
        note(f"random fault schedule: seed {args.seed + 777} "
             f"rate {args.chaos_rate} over {sorted(schedule)}")
    faults.install(schedule)

    counts = {n: {"submitted": 0, "ok": 0, "failed": 0, "refused": 0}
              for n in names}
    digests = {n: {} for n in names}
    exact = True
    t0 = time.time()
    try:
        for step in range(args.epochs):
            tickets = {}
            for n in names:
                upd, w = streams[n].batch_at(step, live=lives[n])
                try:
                    tk = handles[n].submit(upd, w)
                except RuntimeError:  # quarantined: fence holds
                    counts[n]["refused"] += 1
                    continue
                counts[n]["submitted"] += 1
                tickets[n] = (tk, upd, w)
            pool.pump()
            applied = {}
            for n, (tk, upd, w) in tickets.items():
                try:
                    res = tk.result(timeout=600)
                except Exception as e:
                    # failed epoch: rolled back, WAL record aborted —
                    # state must be EXACTLY as if never submitted
                    counts[n]["failed"] += 1
                    note(f"step {step} {n}: failed "
                         f"({type(e).__name__}: {e})")
                    continue
                counts[n]["ok"] += 1
                lives[n] = res.advance(lives[n])
                d = res.deltas[args.query]
                applied[n] = (upd, w, canon(d.tuples, d.weights))
                digests[n][str(res.epoch)] = _digest(applied[n][2])
            # oracles apply ONLY the surviving batches, fault-free, on
            # this same (now idle) thread — see worker() for why the
            # mesh programs must not race the pool's dispatch
            with faults.disabled():
                for n, (upd, w, served) in applied.items():
                    ores = oracles[n].update(upd, w)
                    od = ores.deltas[args.query]
                    exact = exact and served == canon(od.tuples, od.weights)
        pool.drain()
        stats = pool.stats()
        final = {}
        with faults.disabled():
            for n in names:
                s = handles[n].session
                o = oracles[n]
                final[n] = {
                    "epoch": int(s.epoch),
                    "num_edges": int(s.num_edges),
                    "edges": _digest(np.asarray(s.edges).tobytes()),
                    "net_change": int(s[args.query].net_change)}
                exact = exact and (
                    final[n]["edges"]
                    == _digest(np.asarray(o.edges).tobytes())
                    and final[n]["net_change"]
                    == int(o[args.query].net_change))
        injected = faults.injected()
        pool.close()
    finally:
        faults.clear()
        if not args.durable_dir:
            shutil.rmtree(tmp, ignore_errors=True)

    agg = stats.aggregate()
    accounted = all(
        c["submitted"] == c["ok"] + c["failed"] for c in counts.values())
    # escalation re-prewarms are the ONE sanctioned serving-path compile
    # source; everything else must stay zero
    compiles_ok = (agg["serve_compiles"] - agg["escalation_compiles"]) <= 0
    chaotic = len(injected) > 0  # a chaos run that injected nothing
    #                              tested nothing — fail loudly
    out = {
        "mode": "chaos",
        "workers": args.workers, "local": bool(args.local),
        "tenants": args.tenants, "epochs": args.epochs,
        "faults_injected": len(injected),
        "injected": [f"{p}@{h}" for p, h in injected[:40]],
        "counts": counts,
        "escalations": agg["escalations"], "replays": agg["replays"],
        "escalation_compiles": agg["escalation_compiles"],
        "serve_compiles": agg["serve_compiles"],
        "failed": agg["failed"],
        "wal_errors": agg["wal_errors"],
        "wal_degraded": agg["wal_degraded"],
        "quarantined": agg["quarantined"],
        "oracle_exact": bool(exact),
        "accounted": bool(accounted),
        "compiles_ok": bool(compiles_ok),
        "elapsed_s": round(time.time() - t0, 2),
        "final": final,
    }
    print(json.dumps(out))
    ok = exact and accounted and compiles_ok and chaotic
    return 0 if ok else 1


def supervise(args) -> int:
    """Mode B parent: oracle run, victim run (killed mid-stream), resume
    run — then diff digests.  Spawns children of THIS module so the XLA
    device-count override binds before jax loads in each."""
    import json
    import shutil
    import subprocess
    import tempfile

    import time

    def run(extra, expect=0):
        cmd = [sys.executable, "-m", "repro.serve._serve_check",
               "--tenants", str(args.tenants),
               "--workers", str(args.workers),
               "--epochs", str(args.epochs),
               "--nv", str(args.nv), "--ne", str(args.ne),
               "--batch-size", str(args.batch_size),
               "--update-batch", str(args.update_batch),
               "--seed", str(args.seed), "--query", args.query,
               "--snapshot-every", str(args.snapshot_every),
               "--no-oracle", "--no-fsync"] + \
            (["--local"] if args.local else []) + extra
        env = dict(os.environ)
        if expect != 0:
            # the victim child dies by os._exit mid-stream: it must NOT
            # write the shared persistent compile cache — a kill during a
            # cache write leaves a torn entry that poisons every later
            # process reading it (observed as compaction-count assertion
            # failures and segfaults on deserialized executables)
            env.pop("REPRO_COMPILE_CACHE", None)
        sys.stderr.write(f"[supervise] child {extra or ['oracle']}...\n")
        sys.stderr.flush()
        t0 = time.time()
        p = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=1800, env=env)
        sys.stderr.write(f"[supervise] child {extra or ['oracle']} exited "
                         f"{p.returncode} in {time.time() - t0:.0f}s\n")
        sys.stderr.flush()
        if p.returncode != expect:
            sys.stderr.write(p.stdout + p.stderr)
            raise SystemExit(
                f"child {extra} exited {p.returncode}, wanted {expect}")
        line = p.stdout.strip().splitlines()
        return json.loads(line[-1]) if line else None

    tmp = tempfile.mkdtemp(prefix="serve_check_")
    try:
        oracle = run([])  # uninterrupted, no durability: ground truth
        victim_dir = os.path.join(tmp, "victim")
        kill_tenant = f"t{args.tenants // 2}"
        run(["--durable-dir", victim_dir, "--kill-at", str(args.kill_at),
             "--kill-tenant", kill_tenant], expect=9)
        resumed = run(["--durable-dir", victim_dir])

        final_exact = oracle["final"] == resumed["final"]
        # every post-recovery epoch the resume run re-served must produce
        # the oracle's exact signed delta
        tail_exact, compared = True, 0
        for n, per_epoch in resumed["digests"].items():
            for epoch, dg in per_epoch.items():
                compared += 1
                tail_exact = tail_exact and \
                    oracle["digests"][n].get(epoch) == dg
        recovered = any(s > 0 for s in resumed["starts"].values())
        compiles_ok = (oracle["serve_compiles"] == 0
                       and resumed["serve_compiles"] == 0)
        ok = final_exact and tail_exact and recovered and compiles_ok \
            and compared > 0
        print(json.dumps({
            "mode": "supervise",
            "workers": args.workers, "local": bool(args.local),
            "tenants": args.tenants, "epochs": args.epochs,
            "kill_at": args.kill_at, "kill_tenant": kill_tenant,
            "resume_starts": resumed["starts"],
            "replayed": resumed["replayed"],
            "final_exact": bool(final_exact),
            "tail_exact": bool(tail_exact), "tail_compared": compared,
            "serve_compiles": [oracle["serve_compiles"],
                               resumed["serve_compiles"]],
            "all_exact": bool(ok)}))
        return 0 if ok else 1
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--supervise", action="store_true",
                    help="kill/resume failover differential (Mode B)")
    ap.add_argument("--chaos", action="store_true",
                    help="deterministic fault-injection run (Mode C)")
    ap.add_argument("--chaos-rate", type=float, default=0.05,
                    help="per-hit fault probability for the seeded "
                         "random schedule")
    ap.add_argument("--chaos-horizon", type=int, default=400,
                    help="hits per point covered by the random schedule")
    ap.add_argument("--faults", default="",
                    help="pinned fault spec (repro.faults.parse_spec "
                         "syntax) instead of the seeded random schedule")
    ap.add_argument("--tight-out", type=int, default=0,
                    help="chaos: admit tenants with this small output "
                         "rung to force escalate+replay")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--local", action="store_true",
                    help="host-local sessions instead of the mesh")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--nv", type=int, default=24)
    ap.add_argument("--ne", type=int, default=160)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--update-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--query", default="triangle")
    ap.add_argument("--durable-dir", default=None)
    ap.add_argument("--snapshot-every", type=int, default=4)
    ap.add_argument("--no-fsync", action="store_true")
    ap.add_argument("--no-oracle", dest="oracle", action="store_false")
    ap.add_argument("--pump", action="store_true",
                    help="synchronous pump instead of pipeline threads")
    ap.add_argument("--kill-at", type=int, default=0,
                    help="os._exit(9) when --kill-tenant logs this epoch")
    ap.add_argument("--kill-tenant", default="t0")
    args = ap.parse_args(argv)
    if args.supervise:
        return supervise(args)
    if args.chaos:
        return chaos(args)
    return worker(args)


if __name__ == "__main__":
    sys.exit(main())
