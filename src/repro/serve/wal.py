"""Durability for serving sessions: write-ahead update log + snapshots.

The recovery contract (DESIGN.md §9): a tenant's state is a deterministic
function of (initial relations, the ordered raw update batches).  So the
pool logs every epoch's RAW batches to an append-only WAL *before* the
device applies them, snapshots the session every ``snapshot_every`` epochs
(``GraphSession.snapshot`` riding ``repro.checkpoint``), and truncates the
WAL through the snapshot's epoch.  A killed worker then restores the last
intact snapshot and replays the surviving WAL records through the normal
``session.update`` path — normalize nets each replayed batch against the
restored state exactly as the original run did, so the recovered state is
bit-exact, including a record logged but never applied (its replay IS the
apply).

WAL records are one JSON line each: the payload (epoch + base64 row/weight
bytes per relation) is CRC32-guarded, and replay stops at the first torn
or corrupt line — the half-written tail of a crash mid-append loses only
the epoch that never returned to its client.  ``truncate_through`` is an
atomic rewrite (tmp + rename), so a crash mid-truncation leaves either the
old or the new log, never a prefix.
"""
from __future__ import annotations

import base64
import json
import os
import sys
import zlib
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro import faults
from repro.errors import SnapshotError, WalError

Batches = Dict[str, Tuple[np.ndarray, np.ndarray]]


class WriteAheadLog:
    """Append-only epoch log of raw (pre-normalize) update batches."""

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = bool(fsync)
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "ab")
        self._last_offset: Optional[int] = None

    @staticmethod
    def _encode(epoch: int, batches: Batches) -> bytes:
        rels = {}
        for rel in sorted(batches):
            rows, w = batches[rel]
            rows = np.ascontiguousarray(rows, np.int32)
            w = np.ascontiguousarray(w, np.int32)
            rels[rel] = {
                "shape": list(rows.shape),
                "rows": base64.b64encode(rows.tobytes()).decode(),
                "w": base64.b64encode(w.tobytes()).decode()}
        body = json.dumps({"e": int(epoch), "rels": rels}, sort_keys=True)
        crc = zlib.crc32(body.encode())
        return (json.dumps({"b": body, "crc": crc}) + "\n").encode()

    def append(self, epoch: int, batches: Batches) -> None:
        """Durably log one epoch's raw batches (fsync'd by default) —
        called BEFORE the device applies them.

        Raises :class:`WalError` on any I/O failure; the byte offset at
        entry is remembered so ``abort_last`` can truncate away a record
        whose epoch never applied (otherwise recovery would replay it).
        """
        try:
            # record the offset BEFORE the fault point: a failed append
            # must abort back to this record's start, never the previous
            self._last_offset = self._f.tell()
            faults.fire("wal.append")
            self._f.write(self._encode(epoch, batches))
            self._f.flush()
            faults.fire("wal.fsync")
            if self.fsync:
                os.fsync(self._f.fileno())
        except WalError:
            raise
        except (OSError, faults.FaultInjected) as exc:
            raise WalError(f"WAL append failed for epoch {epoch}: {exc}") \
                from exc

    def abort_last(self) -> bool:
        """Truncate the file back to just before the last ``append`` —
        used when the device apply of that epoch failed for good, so a
        later recovery does not replay a batch the live run rejected."""
        if self._last_offset is None:
            return False
        off, self._last_offset = self._last_offset, None
        try:
            self._f.flush()
            self._f.truncate(off)
            self._f.seek(off)
            if self.fsync:
                os.fsync(self._f.fileno())
        except OSError as exc:
            raise WalError(f"WAL abort_last failed: {exc}") from exc
        return True

    @staticmethod
    def _decode(line: bytes) -> Optional[Tuple[int, Batches]]:
        try:
            rec = json.loads(line)
            body = rec["b"]
            if zlib.crc32(body.encode()) != rec["crc"]:
                return None
            payload = json.loads(body)
            batches = {}
            for rel, d in payload["rels"].items():
                shape = tuple(d["shape"])
                rows = np.frombuffer(base64.b64decode(d["rows"]),
                                     np.int32).reshape(shape).copy()
                w = np.frombuffer(base64.b64decode(d["w"]),
                                  np.int32).copy()
                if w.shape[0] != shape[0]:
                    return None
                batches[rel] = (rows, w)
            return int(payload["e"]), batches
        except (KeyError, ValueError, TypeError):
            return None

    def replay(self) -> Iterator[Tuple[int, Batches]]:
        """Yield ``(epoch, batches)`` in log order, stopping at the first
        torn/corrupt record (crash mid-append tolerance)."""
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            for line in f:
                rec = self._decode(line)
                if rec is None:
                    return
                yield rec

    def truncate_through(self, epoch: int) -> None:
        """Atomically drop every record with epoch <= ``epoch`` (the
        snapshot just made them redundant); later records survive
        byte-identical."""
        keep = []
        with open(self.path, "rb") as f:
            for line in f:
                rec = self._decode(line)
                if rec is None:
                    break
                if rec[0] > epoch:
                    keep.append(line)
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.writelines(keep)
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        self._f = open(self.path, "ab")

    def num_records(self) -> int:
        return sum(1 for _ in self.replay())

    @classmethod
    def verify(cls, path: str) -> Dict[str, object]:
        """Classify a WAL file without mutating it.

        Returns a dict with ``status`` one of:

        - ``"clean"``       — every line decodes and CRC-checks;
        - ``"torn_tail"``   — exactly the LAST line is bad (the expected
          crash-mid-append shape; replay loses only that epoch);
        - ``"corrupt_midfile"`` — a bad line is followed by more lines.
          Replay still stops at the first bad record (the suffix may
          depend on state from the lost record), but this shape means
          real data loss beyond a torn tail, so recovery reports it.

        Plus ``records`` (count of valid prefix records), ``lost``
        (lines after the first bad one, incl. it), and ``first_epoch``/
        ``last_epoch`` of the valid prefix (None when empty).
        """
        out: Dict[str, object] = {
            "path": path, "status": "clean", "records": 0,
            "lost": 0, "first_epoch": None, "last_epoch": None}
        if not os.path.exists(path):
            return out
        lines = []
        with open(path, "rb") as f:
            lines = f.readlines()
        bad_at = None
        for i, line in enumerate(lines):
            rec = cls._decode(line)
            if rec is None:
                bad_at = i
                break
            out["records"] = int(out["records"]) + 1
            if out["first_epoch"] is None:
                out["first_epoch"] = rec[0]
            out["last_epoch"] = rec[0]
        if bad_at is not None:
            out["lost"] = len(lines) - bad_at
            out["status"] = ("torn_tail" if bad_at == len(lines) - 1
                             else "corrupt_midfile")
        return out

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class Durability:
    """Snapshot + WAL recovery manager for ONE serving session.

    Protocol per epoch (the pool's apply stage drives it):

    1. ``log(raw_batches)`` — durably append the epoch's raw batches;
    2. device apply (``session.update``);
    3. ``maybe_snapshot()`` — every ``snapshot_every`` epochs, snapshot
       the session (atomic-rename checkpoint) and truncate the WAL
       through the snapshot's epoch, bounding crash replay work to
       ``snapshot_every`` epochs.

    ``recover()`` restores the newest intact snapshot (if any) and
    replays surviving WAL records IN ORDER through ``session.update`` —
    deterministic normalize makes the result bit-exact with the
    uninterrupted run.
    """

    def __init__(self, directory: str, session, snapshot_every: int = 8,
                 keep_last: int = 3, fsync: bool = True):
        from repro.checkpoint import CheckpointManager
        self.directory = directory
        self.session = session
        self.snapshot_every = int(snapshot_every)
        self.manager = CheckpointManager(
            os.path.join(directory, "ckpt"), keep_last=keep_last)
        self.wal = WriteAheadLog(os.path.join(directory, "wal.log"),
                                 fsync=fsync)
        self.snapshots = 0
        self.replayed = 0
        self._last_snapshot_epoch = -1
        self.wal_report: Optional[Dict[str, object]] = None

    def recover(self) -> bool:
        """Restore snapshot + replay WAL onto ``self.session``; returns
        True when any durable state was recovered.

        The WAL is ``verify``-classified first: a torn tail is the
        expected crash shape (silently dropped — that epoch never
        returned to its client); mid-file corruption is remembered in
        ``self.wal_report`` so callers can surface the loss, and replay
        still stops at the first bad record.
        """
        self.wal_report = WriteAheadLog.verify(self.wal.path)
        got = self.manager.restore_latest_raw()
        if got is not None:
            leaves, manifest = got
            self.session.restore(leaves, manifest["extra"])
            self._last_snapshot_epoch = self.session.epoch
        base = self.session.epoch
        for epoch, batches in self.wal.replay():
            if epoch <= base:
                continue  # already inside the snapshot
            if epoch != self.session.epoch + 1:
                raise WalError(
                    f"WAL gap: next record is epoch {epoch} but the "
                    f"session is at {self.session.epoch}")
            self.session.update(batches)
            self.replayed += 1
        return got is not None or self.replayed > 0

    def log(self, raw_batches: Batches) -> int:
        """Append the NEXT epoch's raw batches; returns its epoch number."""
        epoch = self.session.epoch + 1
        self.wal.append(epoch, raw_batches)
        return epoch

    def maybe_snapshot(self, force: bool = False) -> bool:
        """Snapshot + WAL truncation on the cadence (or ``force``)."""
        epoch = self.session.epoch
        due = force or (self.snapshot_every > 0 and epoch > 0
                        and epoch % self.snapshot_every == 0)
        if not due or epoch == self._last_snapshot_epoch:
            return False
        try:
            faults.fire("snapshot.write")
            leaves, meta = self.session.snapshot()
            self.manager.save(leaves, step=epoch, extra=meta)
        except SnapshotError:
            raise
        except (OSError, faults.FaultInjected) as exc:
            raise SnapshotError(
                f"snapshot at epoch {epoch} failed: {exc}") from exc
        self.wal.truncate_through(epoch)
        self._last_snapshot_epoch = epoch
        self.snapshots += 1
        return True

    def close(self) -> None:
        self.wal.close()


def main(argv=None) -> int:
    """``python -m repro.serve.wal verify <dir-or-file>`` — classify a
    WAL (clean / torn_tail / corrupt_midfile).  Exit 0 for clean or a
    torn tail (the tolerated crash shape), 2 for mid-file corruption."""
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 2 or argv[0] != "verify":
        print("usage: python -m repro.serve.wal verify <dir-or-file>",
              file=sys.stderr)
        return 64
    path = argv[1]
    if os.path.isdir(path):
        path = os.path.join(path, "wal.log")
    rep = WriteAheadLog.verify(path)
    print(json.dumps(rep, sort_keys=True))
    return 2 if rep["status"] == "corrupt_midfile" else 0


if __name__ == "__main__":
    raise SystemExit(main())
