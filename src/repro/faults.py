"""Deterministic fault injection (DESIGN.md §10).

A process-wide registry of NAMED fault points wired into the durability
and epoch-commit call sites:

    store.commit.fold   before each committed-region fold of a commit
    store.normalize     before a batch normalize probe
    pool.prep           stage A of a pool epoch (host pack)
    pool.apply          stage B of a pool epoch (device apply)
    wal.append          before a WAL record write
    wal.fsync           before the WAL fsync
    snapshot.write      before a snapshot checkpoint write
    dist.program        before launching a distributed join program

Each call site calls :func:`fire(point)`; the registry counts the hit and
raises :class:`~repro.errors.FaultInjected` when the hit number is in the
point's schedule.  Schedules come from the environment —

    REPRO_FAULTS="wal.fsync@7,store.commit.fold@12"

(fire on the 7th ``wal.fsync`` hit and the 12th ``store.commit.fold``
hit; ``point@3-5`` fires a range, ``point@*`` every hit) — or
programmatically via :func:`install`.  Hit counting is per-point,
process-wide and thread-safe; schedules are deterministic, so a run with
the same inputs injects the same faults (the chaos harness in
``repro.serve._serve_check --chaos`` builds a seeded random schedule with
:func:`random_schedule` and replays it exactly).

:func:`disabled` suspends firing on the current thread — differential
oracles running in the same process as a chaos run use it so scheduled
faults only ever hit the system under test.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.errors import FaultInjected

ENV_VAR = "REPRO_FAULTS"
EVERY = -1  # sentinel hit number: fire on every hit

POINTS = (
    "store.commit.fold", "store.normalize", "pool.prep", "pool.apply",
    "wal.append", "wal.fsync", "snapshot.write", "dist.program",
)

_lock = threading.Lock()
_hits: Dict[str, int] = {}
_sched: Dict[str, Set[int]] = {}
_injected: List[Tuple[str, int]] = []
_env_loaded = False
_tl = threading.local()


def parse_spec(spec: str) -> Dict[str, Set[int]]:
    """Parse ``"wal.fsync@7,store.commit.fold@3-5,pool.apply@*"`` into
    ``{point: {hit numbers}}`` (1-based hits; ``EVERY`` for ``*``)."""
    out: Dict[str, Set[int]] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "@" in part:
            point, at = part.split("@", 1)
        else:
            point, at = part, "*"
        hits = out.setdefault(point.strip(), set())
        at = at.strip()
        if at == "*":
            hits.add(EVERY)
        elif "-" in at:
            lo, hi = at.split("-", 1)
            hits.update(range(int(lo), int(hi) + 1))
        else:
            hits.add(int(at))
    return out


def _load_env_locked() -> None:
    global _env_loaded
    if _env_loaded:
        return
    _env_loaded = True
    spec = os.environ.get(ENV_VAR, "")
    if spec:
        for point, hits in parse_spec(spec).items():
            _sched.setdefault(point, set()).update(hits)


def install(schedule, *, reset_counts: bool = True) -> None:
    """Install a programmatic schedule: a spec string (see
    :func:`parse_spec`) or a ``{point: iterable-of-hit-numbers}`` dict.
    Replaces any existing schedule (env spec included)."""
    global _env_loaded
    if isinstance(schedule, str):
        schedule = parse_spec(schedule)
    with _lock:
        _env_loaded = True  # explicit install overrides the env spec
        _sched.clear()
        for point, hits in schedule.items():
            _sched[point] = {int(h) for h in hits}
        if reset_counts:
            _hits.clear()
            _injected.clear()


def clear() -> None:
    """Drop every schedule and counter (the env spec stays consumed)."""
    install({}, reset_counts=True)


def active() -> bool:
    """True when ANY fault point is armed.  Transactional code paths use
    this to prefer rollback-safe variants (e.g. the commit fold runs
    without buffer donation while faults are armed, so a mid-commit
    rollback never resurrects a donated buffer)."""
    with _lock:
        _load_env_locked()
        return bool(_sched)


def fire(point: str) -> None:
    """Count one hit of ``point``; raise FaultInjected when scheduled."""
    if getattr(_tl, "paused", 0):
        return
    with _lock:
        _load_env_locked()
        if not _sched:
            return
        n = _hits.get(point, 0) + 1
        _hits[point] = n
        hits = _sched.get(point)
        hit = hits is not None and (EVERY in hits or n in hits)
        if hit:
            _injected.append((point, n))
    if hit:
        raise FaultInjected(point, n)


class disabled:
    """Context manager: suspend fault firing on the current thread (hits
    are not counted either) — lets in-process differential oracles share a
    process with a chaos run."""

    def __enter__(self):
        _tl.paused = getattr(_tl, "paused", 0) + 1
        return self

    def __exit__(self, *exc):
        _tl.paused -= 1
        return False


def counts() -> Dict[str, int]:
    """Hit counters per point (introspection/accounting)."""
    with _lock:
        return dict(_hits)


def injected() -> List[Tuple[str, int]]:
    """Chronological ``(point, hit)`` list of faults actually raised."""
    with _lock:
        return list(_injected)


def random_schedule(seed: int, points: Optional[Iterable[str]] = None,
                    horizon: int = 200, rate: float = 0.05
                    ) -> Dict[str, Set[int]]:
    """A seeded random schedule: each of the first ``horizon`` hits of
    each point fires independently with probability ``rate``.  Pure
    function of its arguments — the chaos harness logs (seed, rate) and
    any run can be reproduced exactly."""
    import numpy as np
    rng = np.random.default_rng(int(seed) * 1_000_003 + 7)
    out: Dict[str, Set[int]] = {}
    for point in (POINTS if points is None else points):
        draws = rng.random(int(horizon)) < float(rate)
        hits = {int(i) + 1 for i in np.flatnonzero(draws)}
        if hits:
            out[point] = hits
    return out


__all__ = ["ENV_VAR", "EVERY", "POINTS", "parse_spec", "install", "clear",
           "active", "fire", "disabled", "counts", "injected",
           "random_schedule"]
