"""End-to-end behaviour of the paper's system: static query, incremental
maintenance, and the WCOJ->GNN pipeline integration, exercised together."""
import numpy as np

from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.delta import DeltaBigJoin
from repro.core.csr import Graph
from repro.core.generic_join import generic_join
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def test_end_to_end_static_then_incremental():
    """Load a skewed graph, answer a static query, then keep the answer
    maintained under a mixed update stream — the paper's §5 deployment."""
    g = Graph.from_edges(rmat_graph(9, 6, seed=42))
    q = Q.triangle()
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}

    # static: dataflow vs oracle
    cfg = BigJoinConfig(batch=2048, seed_chunk=2048, mode="count")
    idx = build_indices(plan, rels)
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
    _, ref = generic_join(q, rels, enumerate_results=False)
    assert res.count == ref

    # incremental: stream updates, verify the maintained count
    n0 = g.num_edges - 200
    eng = DeltaBigJoin(q, g.edges[:n0],
                       cfg=BigJoinConfig(batch=2048, seed_chunk=2048,
                                         mode="collect",
                                         out_capacity=1 << 18))
    total = generic_join(q, {Q.EDGE: g.edges[:n0]},
                         enumerate_results=False)[1]
    for lo in range(n0, g.num_edges, 100):
        total += eng.apply(g.edges[lo:lo + 100]).count_delta
    assert total == ref
