"""GraphSession facade lockdown: pattern DSL, shared-store epoch contract,
multi-query differential vs independent engines, and compile-cache hits.

The acceptance bar (ISSUE 3): with 4 standing queries registered,
``session.update`` performs exactly ONE normalize/commit per epoch and ZERO
recompilations after warmup, with every query's signed output delta
bit-exact against an independently-maintained engine.
"""
import numpy as np
import pytest

from repro.api import (GraphSession, PatternSyntaxError, oracle_count,
                       parse_pattern, pattern_of, query_by_name)
from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig, _compiled_fns
from repro.core.delta import DeltaBigJoin, RegionStore

from tests.test_delta import canon
from tests.test_delta_stream import random_batch

CFG = BigJoinConfig(batch=128, seed_chunk=128, out_capacity=1 << 15)


def _start_edges(nv, ne, seed):
    from repro.data.synthetic import uniform_graph
    return uniform_graph(nv, ne, seed)


def _local_session(edges, **kw):
    kw.setdefault("batch", 128)
    kw.setdefault("out_capacity", 1 << 15)
    return GraphSession(edges, local=True, **kw)


# ---------------------------------------------------------------------------
# pattern DSL
# ---------------------------------------------------------------------------

NAMED = ["triangle", "4-clique", "5-clique", "diamond", "house",
         "4-clique-tri"]


@pytest.mark.parametrize("name", NAMED)
def test_dsl_round_trip_equals_builder(name):
    q = query_by_name(name)
    assert parse_pattern(pattern_of(q)) == q


@pytest.mark.parametrize("name", ["triangle", "4-clique", "house"])
def test_dsl_round_trip_symmetric(name):
    q = query_by_name(name, symmetric=True)
    assert parse_pattern(pattern_of(q)) == q


def test_dsl_explicit_triangle_text():
    q = parse_pattern("triangle(a, b, c) := e(a, b), e(a, c), e(b, c)")
    assert q == Q.triangle()


def test_dsl_ternary_relation_and_filters():
    q = parse_pattern(
        "4-clique-tri(a,b,c,d) := tri(a,b,c), tri(a,b,d), tri(a,c,d)")
    assert q == Q.four_clique_tri()
    f = parse_pattern("t(a,b,c) := e(a,b), e(a,c), e(b,c), a < b, b < c")
    assert f.filters == (Q.Filter(0, 1), Q.Filter(1, 2))


def test_dsl_unbound_variable_rejected():
    with pytest.raises(ValueError, match="unbound variable 'd'"):
        parse_pattern("t(a,b,c) := e(a,b), e(b,d)")
    with pytest.raises(ValueError, match="unbound variable"):
        parse_pattern("t(a,b) := e(a,b), a < z")


def test_dsl_arity_mismatch_rejected():
    with pytest.raises(ValueError, match="arity mismatch"):
        parse_pattern("t(a,b,c) := tri(a,b,c), tri(a,b)")
    with pytest.raises(ValueError, match="arity mismatch"):
        parse_pattern("t(a,b,c) := e(a,b,c)")


def test_dsl_syntax_errors():
    for bad in ("tri(a,b,c)", "(a,b) := e(a,b)", "t(a,b) := e(a,b",
                "t(a,a) := e(a,a)", "t() := e(a,b)", "t(a,b) := "):
        with pytest.raises(ValueError):
            parse_pattern(bad)


def test_dsl_uncovered_head_attr_rejected():
    with pytest.raises(ValueError, match="every attribute"):
        parse_pattern("t(a,b,c) := e(a,b)")


def test_query_by_name_aliases():
    assert query_by_name("four_clique") == Q.four_clique()
    assert query_by_name("TRIANGLE") == Q.triangle()
    assert query_by_name("tri") == Q.triangle()
    assert query_by_name("path-3") == Q.path(3)
    with pytest.raises(KeyError):
        query_by_name("nonagon")
    with pytest.raises(ValueError, match="no symmetric"):
        query_by_name("diamond", symmetric=True)
    with pytest.raises(ValueError, match="no symmetric"):
        query_by_name("path-3", symmetric=True)


# ---------------------------------------------------------------------------
# session basics: static eval, registration reuse, subscriptions
# ---------------------------------------------------------------------------

def test_static_count_and_enumerate_match_oracle():
    e = _start_edges(30, 260, 0)
    sess = _local_session(e)
    tri = sess.register("triangle")
    ref = oracle_count("triangle", e)
    assert tri.count() == ref
    t, w = tri.enumerate()
    assert int(w.sum()) == ref
    assert canon(t, w) == canon(*_enumerate_oracle(Q.triangle(), e))


def _enumerate_oracle(q, edges):
    from repro.core.generic_join import generic_join
    t, _ = generic_join(q, {Q.EDGE: edges})
    t = np.unique(np.asarray(t, np.int32).reshape(-1, q.num_attrs), axis=0)
    return t, np.ones(t.shape[0], np.int32)


def test_register_same_name_returns_same_handle():
    sess = _local_session(_start_edges(20, 80, 1))
    a = sess.register("triangle")
    b = sess.register("triangle")
    assert a is b
    with pytest.raises(ValueError, match="different pattern"):
        sess.register("diam(a,b,c,d) := e(a,b), e(b,c), e(d,a), e(d,c)",
                      name="triangle")
    assert sess.query_by_name("triangle") is a


def test_registered_queries_share_region_objects():
    """Satellite: repeated registrations reuse _Regions projections instead
    of re-deriving them — same store, same region OBJECTS, no copies."""
    sess = _local_session(_start_edges(20, 120, 2))
    tri = sess.register("triangle")
    # engines (and their projections) build lazily: registration alone
    # touches no regions, so a static-only handle pays nothing extra
    assert not sess.store.projections
    tri.engine  # force the standing engine
    ids_before = {k: id(v) for k, v in sess.store.projections.items()}
    assert ids_before
    clique = sess.register("4-clique")
    clique.engine
    # triangle's projections were reused untouched (same objects)...
    for k, i in ids_before.items():
        assert id(sess.store.projections[k]) == i
    # ...and both engines resolve through the ONE shared store
    assert tri.engine.store is clique.engine.store is sess.store
    # a second same-shape registration creates no new projections at all
    n = len(sess.store.projections)
    sess.register("tri2(x,y,z) := e(x,y), e(x,z), e(y,z)").engine
    assert len(sess.store.projections) == n


def test_lazy_engine_first_update_is_exact():
    """An engine built lazily INSIDE the first update must see the staged
    batch: projections are created before begin_epoch (ordering contract)."""
    nv = 20
    e = _start_edges(nv, 110, 13)
    sess = _local_session(e)
    sess.register("triangle")  # no engine, no projections yet
    ref = DeltaBigJoin(query_by_name("triangle"), e, cfg=CFG)
    rng = np.random.default_rng(14)
    upd, w = random_batch(rng, nv, sess.edges, 12)
    res = sess.update(upd, w)
    want = ref.apply(upd, w)
    assert canon(res.deltas["triangle"].tuples,
                 res.deltas["triangle"].weights) == \
        canon(want.tuples, want.weights)


def test_subscription_and_noop_epoch():
    e = _start_edges(25, 150, 3)
    sess = _local_session(e)
    tri = sess.register("triangle")
    got = []
    tri.subscribe(lambda epoch, res: got.append((epoch, res.count_delta)))
    commits0 = sess.stats.commit_calls
    # net-zero batch: +1 then -1 on a live edge — an exact no-op epoch
    live = sess.edges[:1]
    res = sess.update(np.concatenate([live, live]),
                      np.array([1, -1], np.int32))
    assert res.is_noop and res.deltas["triangle"].count_delta == 0
    assert sess.stats.commit_calls == commits0  # no-op commits nothing
    upd = np.array([[1, 2], [2, 3], [3, 1]], np.int32)
    sess.update(upd)
    assert len(got) == 2 and got[0][1] == 0
    assert tri.net_change == got[1][1]


# ---------------------------------------------------------------------------
# the acceptance bar: 4 standing queries, one commit, zero recompiles,
# bit-exact vs independent engines
# ---------------------------------------------------------------------------

FOUR = ("triangle", "diamond", "4-clique", "house")


def test_four_standing_queries_one_commit_bitexact_no_recompile():
    nv, ne, epochs = 24, 170, 8
    e = _start_edges(nv, ne, 4)
    sess = _local_session(e)
    handles = [sess.register(n) for n in FOUR]
    independents = {n: DeltaBigJoin(query_by_name(n), e, cfg=CFG)
                    for n in FOUR}
    rng = np.random.default_rng(7)

    jit_sizes = None
    for step in range(epochs):
        upd, w = random_batch(rng, nv, sess.edges, 14)
        before = (sess.stats.normalize_calls, sess.stats.commit_calls)
        res = sess.update(upd, w)
        # exactly one normalize and AT MOST one commit (zero on no-ops),
        # regardless of 4 standing queries
        assert sess.stats.normalize_calls == before[0] + 1
        assert sess.stats.commit_calls in (before[1], before[1] + 1)
        for n in FOUR:
            ref = independents[n].apply(upd, w)
            assert canon(res.deltas[n].tuples, res.deltas[n].weights) == \
                canon(ref.tuples, ref.weights), (n, step)
            np.testing.assert_array_equal(sess.edges, independents[n].edges)
        if step == 2:  # warmup done: snapshot every jitted fn's cache
            jit_sizes = _session_jit_sizes(sess)
    # zero recompilations after warmup: same jitted fns, same cache sizes
    assert jit_sizes, "warmup snapshot missing"
    assert _session_jit_sizes(sess) == jit_sizes
    # and the totals stand up to full recomputation
    for h in handles:
        ref = oracle_count(h.query, sess.edges) - oracle_count(h.query, e)
        assert h.net_change == ref, (h.name, h.net_change, ref)


def _session_jit_sizes(sess):
    """(plan, cfg) -> executable-cache sizes for every compiled dataflow the
    session's standing queries use.  ``_compiled_fns`` is lru-cached, so
    identical (plan, cfg) hits the same jitted callables; their
    ``_cache_size`` growing would mean a re-trace/re-compile."""
    sizes = {}
    for h in sess.handles.values():
        for pi, plan in enumerate(h.engine.plans):
            step, seed_step = _compiled_fns(plan, h.engine.cfg)
            key = (h.name, pi)
            if hasattr(step, "_cache_size"):
                sizes[key] = (step._cache_size(), seed_step._cache_size())
            else:  # pragma: no cover - older jax
                sizes[key] = (0, 0)
    return sizes


def test_mid_stream_registration_is_consistent():
    """A query registered AFTER some epochs sees the live graph: its static
    count is exact at registration and its deltas are exact afterwards."""
    nv = 20
    e = _start_edges(nv, 110, 6)
    sess = _local_session(e)
    sess.register("triangle")
    rng = np.random.default_rng(8)
    for step in range(3):
        upd, w = random_batch(rng, nv, sess.edges, 10)
        sess.update(upd, w)
    mid = sess.edges.copy()
    diam = sess.register("diamond")
    assert diam.count() == oracle_count("diamond", mid)
    for step in range(3):
        upd, w = random_batch(rng, nv, sess.edges, 10)
        sess.update(upd, w)
    want = oracle_count("diamond", sess.edges) - oracle_count("diamond", mid)
    assert diam.net_change == want


@pytest.mark.parametrize("w", [2])
def test_mesh_session_matches_local(w):
    """One mesh-backed session (w workers), two standing queries, exact vs
    the host-local session on the same stream."""
    import jax
    if jax.device_count() < w:
        pytest.skip(f"needs {w} devices (CI runs with 4 virtual devices)")
    nv = 18
    e = _start_edges(nv, 100, 9)
    from tests.test_delta_stream import _mesh
    mesh_sess = GraphSession(e, mesh=_mesh(w), batch=128,
                             out_capacity=1 << 15)
    local_sess = _local_session(e)
    for s in (mesh_sess, local_sess):
        s.register("triangle")
        s.register("diamond")
    assert not mesh_sess.local and mesh_sess.w == w
    assert mesh_sess["triangle"].count() == \
        local_sess["triangle"].count() == oracle_count("triangle", e)
    rng = np.random.default_rng(10)
    for step in range(4):
        upd, wts = random_batch(rng, nv, local_sess.edges, 10)
        a = mesh_sess.update(upd, wts)
        b = local_sess.update(upd, wts)
        for n in ("triangle", "diamond"):
            assert canon(a.deltas[n].tuples, a.deltas[n].weights) == \
                canon(b.deltas[n].tuples, b.deltas[n].weights), (n, step)
        np.testing.assert_array_equal(mesh_sess.edges, local_sess.edges)


def test_mesh_session_program_cache_stable():
    """Distributed program builds stop after warmup: later epochs and
    re-registrations hit the (plan, config, mesh) cache."""
    import jax
    from repro.core import distributed as D
    nv = 16
    e = _start_edges(nv, 90, 11)
    from tests.test_delta_stream import _mesh
    sess = GraphSession(e, mesh=_mesh(1), batch=128, out_capacity=1 << 15)
    sess.register("triangle")
    rng = np.random.default_rng(12)
    for step in range(2):
        upd, w = random_batch(rng, nv, sess.edges, 8)
        sess.update(upd, w)
    builds = D._PROGRAM_BUILDS
    for step in range(3):
        upd, w = random_batch(rng, nv, sess.edges, 8)
        sess.update(upd, w)
    assert D._PROGRAM_BUILDS == builds


# ---------------------------------------------------------------------------
# facade purity: examples and CLIs import only repro.api (+ repro.data)
# ---------------------------------------------------------------------------

def test_examples_and_clis_import_only_the_facade():
    import os
    root = os.path.join(os.path.dirname(__file__), "..")
    targets = [
        os.path.join(root, "examples", "quickstart.py"),
        os.path.join(root, "examples", "incremental_motifs.py"),
        os.path.join(root, "src", "repro", "launch", "run_query.py"),
    ]
    for path in targets:
        src = open(path).read()
        assert "repro.core" not in src and "repro.distributed" not in src, \
            f"{os.path.basename(path)} bypasses repro.api"
        assert "repro.api" in src
    # serve.py: the stream path must go through the facade
    serve = open(os.path.join(root, "src", "repro", "launch",
                              "serve.py")).read()
    stream_body = serve.split("def serve_stream", 1)[1].split("def ", 1)[0]
    assert "repro.api" in stream_body
    assert "repro.core" not in stream_body


def test_auto_sizing_respects_agm():
    from repro.api import auto_sizing
    s_tri = auto_sizing(Q.triangle(), 1 << 14, num_workers=1)
    s_clq = auto_sizing(Q.five_clique(), 1 << 14, num_workers=1)
    assert s_tri.batch >= 256 and s_tri.out_capacity >= 1 << 14
    # denser query, larger worst-case output => no smaller capacities
    assert s_clq.out_capacity >= s_tri.out_capacity
    s_w4 = auto_sizing(Q.triangle(), 1 << 14, num_workers=4)
    assert s_w4.batch <= s_tri.batch  # B' splits across workers
