"""Composite-key kernels through the full n-ary engine (ISSUE 10).

Three contracts:

- **differential**: a RegionStore streaming mixed-sign deltas over a
  narrow-composite (tri, int32 hi), a wide-composite (quad, int64 pair) and
  a single-word (edge) relation IN ONE STORE commits bit-exactly with the
  fused Pallas fold vs the jnp chain, local and hash-sharded w ∈ {2, 4},
  and matches the numpy set-semantics recompute oracle every epoch;
- **structure**: each relation's commit fold lowers to exactly ONE
  ``pallas_call`` and zero host round-trips (no callbacks / device_put) —
  the fused-fold launch budget of DESIGN.md §10;
- **transfer guard**: a warm composite engine epoch (quad-e plan) runs
  under ``jax.transfer_guard("disallow")`` on the fused kernel path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import csr
from repro.core import delta as D
from repro.core.delta import DeltaBigJoin, RegionStore
from repro.kernels import count_pallas_calls

from tests.test_delta import canon
from tests.test_nary_store import (CFG, QUAD_E, _kvset, _rand_rel,
                                   apply_net_nary, random_batch_nary)
from repro.core.delta import delta_oracle


def _mixed_store(rng, nv, shard_w):
    rels = {"tri": np.unique(_rand_rel(rng, nv, 80, 3), axis=0),
            "quad": np.unique(_rand_rel(rng, nv, 60, 4), axis=0),
            "edge": np.unique(_rand_rel(rng, nv, 40, 2), axis=0)}
    store = RegionStore({k: v.copy() for k, v in rels.items()},
                        shard_w=shard_w, compact_ratio=0.4)
    store.ensure("tri", (0, 1), 2)
    store.ensure("quad", (0, 1, 2), 3)
    store.ensure("edge", (0,), 1)
    return rels, store


def _region_triples(store):
    for name, r in store._rels.items():
        yield f"live:{name}", ((r.lb, "base"), (r.lc_ins, "cins"),
                               (r.lc_del, "cdel"))
    for proj, r in store.projections.items():
        if not r.derived:
            yield f"proj:{proj}", ((r.d_base, "base"),
                                   (r.d_cins, "cins"), (r.d_cdel, "cdel"))


def _assert_regions_equal(sa, sb, msg):
    """LIVE-set LSM and projection regions of two stores are bitwise
    identical."""
    for (name, ta), (_, tb) in zip(_region_triples(sa),
                                   _region_triples(sb)):
        for (reg_a, tag), (reg_b, _) in zip(ta, tb):
            assert reg_a.key.dtype == reg_b.key.dtype, (msg, name, tag)
            np.testing.assert_array_equal(
                np.asarray(reg_a.key), np.asarray(reg_b.key),
                err_msg=f"{msg} {name} {tag} key")
            np.testing.assert_array_equal(
                np.asarray(reg_a.val), np.asarray(reg_b.val),
                err_msg=f"{msg} {name} {tag} val")
            np.testing.assert_array_equal(
                np.asarray(reg_a.n), np.asarray(reg_b.n),
                err_msg=f"{msg} {name} {tag} n")
            if reg_a.lo is not None:
                np.testing.assert_array_equal(
                    np.asarray(reg_a.lo), np.asarray(reg_b.lo),
                    err_msg=f"{msg} {name} {tag} lo")


@pytest.mark.parametrize("shard_w", [0, 2, 4], ids=["local", "w2", "w4"])
def test_mixed_narrow_wide_store_kernel_vs_jnp_differential(
        monkeypatch, shard_w):
    """One store, three key layouts (int32-hi composite, int64-pair
    composite, int64 single word): identical mixed-sign streams through the
    fused kernel fold and the jnp chain stay bitwise identical AND match
    the numpy recompute oracle."""
    rng = np.random.default_rng(60 + shard_w)
    nv = 10
    rels, store_k = _mixed_store(np.random.default_rng(77), nv, shard_w)
    _, store_j = _mixed_store(np.random.default_rng(77), nv, shard_w)
    cur = {k: v.copy() for k, v in rels.items()}
    for step in range(8):
        batch = {}
        for name, arity in (("tri", 3), ("quad", 4), ("edge", 2)):
            upd, w = random_batch_nary(rng, nv, cur[name], 8, arity=arity)
            batch[name] = (upd, w)
        for store, on in ((store_k, True), (store_j, False)):
            monkeypatch.setattr(D, "USE_MERGE_KERNEL", on)
            out = store.normalize({k: (u.copy(), w.copy())
                                   for k, (u, w) in batch.items()})
            if any(a.size or b.size for a, b in out.values()):
                store.begin_epoch(out)
                store.commit(out)
        monkeypatch.setattr(D, "USE_MERGE_KERNEL", None)
        for name in cur:
            cur[name] = apply_net_nary(cur[name], *batch[name])
            np.testing.assert_array_equal(
                store_k.relation_rows(name), cur[name],
                err_msg=f"epoch {step} {name} (kernel vs oracle)")
        _assert_regions_equal(store_k, store_j, f"epoch {step}")
    # the narrow lift actually happened where it should: the quad
    # projection binds 3 columns -> int32 hi word; the tri projection
    # binds 2 -> one packed int64 word; the live-set LSMs stay wide by
    # design (_packed_index pins narrow=False — delta batches may carry
    # ids the initial build never saw)
    quad_proj = next(r for r in store_k.projections.values()
                     if r.rel == "quad" and not r.derived)
    assert quad_proj.narrow and quad_proj.d_base.lo is not None
    assert quad_proj.d_base.key.dtype == jnp.int32
    tri_proj = next(r for r in store_k.projections.values()
                    if r.rel == "tri" and not r.derived)
    assert tri_proj.d_base.key.dtype == jnp.int64
    assert store_k._rels["tri"].lb.lo is not None  # composite live LSM


BAD_PRIMS = {"pure_callback", "io_callback", "debug_callback", "callback",
             "infeed", "outfeed", "device_put"}


def _prims_of(closed):
    def _subjaxprs(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from _subjaxprs(x)

    def walk(jaxpr, seen):
        for eqn in jaxpr.eqns:
            seen.add(eqn.primitive.name)
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    walk(sub, seen)

    seen = set()
    walk(closed.jaxpr, seen)
    return seen


@pytest.mark.parametrize("shard_w", [0, 4], ids=["local", "w4"])
@pytest.mark.parametrize("arity", [2, 3, 4])
def test_commit_fold_one_launch_per_relation_no_host(arity, shard_w):
    """The per-relation commit fold with the kernel on: exactly one fused
    pallas_call, zero host round-trips — local and under the sharded vmap."""
    rng = np.random.default_rng(70 + arity)
    rows = np.unique(_rand_rel(rng, 12, 90, arity), axis=0)
    delta = np.unique(_rand_rel(rng, 12, 20, arity), axis=0)
    ba = D._packed_index(rows, shard_w, arity, capacity=256)
    ci = D._packed_index(delta[:10], shard_w, arity, capacity=128)
    cd = D._packed_index(delta[10:15], shard_w, arity, capacity=128)
    ui = D._packed_index(delta[15:], shard_w, arity, capacity=64)
    ud = D._packed_index(rows[:8], shard_w, arity, capacity=64)
    fold = lambda *r: D._commit_fold_impl(
        *r, cins_cap=256, cdel_cap=256, sharded=bool(shard_w),
        use_kernel=True)
    assert count_pallas_calls(fold, ba, ci, cd, ui, ud) == 1
    prims = _prims_of(jax.make_jaxpr(fold)(ba, ci, cd, ui, ud))
    assert not (prims & BAD_PRIMS), prims & BAD_PRIMS
    assert "pallas_call" in prims


def test_warm_composite_engine_epoch_under_transfer_guard(monkeypatch):
    """quad-e (arity-4 composite + edge) engine, merge kernel on: after
    warmup, epochs run under transfer_guard('disallow') — the fused fold
    and composite probe kernels never bounce through the host."""
    monkeypatch.setattr(D, "USE_MERGE_KERNEL", True)
    rng = np.random.default_rng(80)
    nv = 7
    quad0 = np.unique(_rand_rel(rng, nv, 100, 4), axis=0)
    edge0 = np.unique(_rand_rel(rng, nv, 30, 2), axis=0)
    eng = DeltaBigJoin(QUAD_E, {"quad": quad0, "edge": edge0}, cfg=CFG)
    cur = {"quad": quad0, "edge": edge0}

    def epoch():
        qu, qw = random_batch_nary(rng, nv, cur["quad"], 8, arity=4)
        eu, ew = random_batch_nary(rng, nv, cur["edge"], 6, arity=2)
        res = eng.apply({"quad": (qu, qw), "edge": (eu, ew)})
        after = {"quad": apply_net_nary(cur["quad"], qu, qw),
                 "edge": apply_net_nary(cur["edge"], eu, ew)}
        ot, ow = delta_oracle(QUAD_E, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow)
        return after

    for _ in range(3):  # warm up compiles
        cur = epoch()
    monkeypatch.setattr(D, "STRICT_TRANSFERS", True)
    try:
        for _ in range(2):
            cur = epoch()
    finally:
        monkeypatch.setattr(D, "STRICT_TRANSFERS", False)
    np.testing.assert_array_equal(eng.store.relation_rows("quad"),
                                  cur["quad"])
