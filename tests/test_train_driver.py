"""End-to-end driver tests: train/resume-after-kill, serve, query CLI."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ENV = dict(os.environ, PYTHONPATH=SRC)


def run(mod, *args, timeout=900):
    r = subprocess.run([sys.executable, "-m", mod, *args],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_train_checkpoint_restart(tmp_path):
    ck = str(tmp_path / "ck")
    out1 = run("repro.launch.train", "--arch", "gemma2-2b", "--steps", "8",
               "--ckpt-dir", ck, "--ckpt-every", "4", "--log-every", "4")
    assert "final loss" in out1
    # relaunch with more steps: must resume, not restart
    out2 = run("repro.launch.train", "--arch", "gemma2-2b", "--steps", "10",
               "--ckpt-dir", ck, "--ckpt-every", "4", "--log-every", "4")
    assert "resumed from step 8" in out2


@pytest.mark.slow
def test_serve_decodes(tmp_path):
    out = run("repro.launch.serve", "--arch", "mixtral-8x7b", "--batch",
              "2", "--steps", "6", "--prompt-len", "16")
    assert "decode:" in out


@pytest.mark.slow
def test_query_cli_modes():
    out = run("repro.launch.run_query", "--query", "triangle", "--scale",
              "9", "--mode", "static")
    assert "BiGJoin:" in out
    out = run("repro.launch.run_query", "--query", "triangle", "--scale",
              "9", "--mode", "serial")
    assert "serial GJ:" in out
    # static and serial agree on the count
    import re
    counts = set()
    for mode in ("static", "serial"):
        o = run("repro.launch.run_query", "--query", "diamond", "--scale",
                "8", "--mode", mode)
        counts.add(re.search(r": ([\d,]+) results", o).group(1))
    assert len(counts) == 1
