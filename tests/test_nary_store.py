"""Multi-relation, n-ary engine lockdown (ISSUE 5).

Five contracts:

- **composite keys**: 3/4-column keys pack into the (hi, lo) int64 pair,
  probe via the fixed-depth two-word lex search, and flow through the
  sorted-merge folds, the sharded builds (ownership by combined word), and
  the host oracle — bit-exact against python set semantics;
- **one packer**: ``csr.pack_key`` is the only packing implementation —
  ``bigjoin._pack_cols`` and ``generic_join._NpIndex`` delegate, and no
  ``NotImplementedError`` remains on >2-key-column or non-edge paths;
- **validation**: wrong-arity / negative-id / non-integer batches raise
  loudly instead of being reshaped into garbage;
- **n-ary store**: adversarial ``tri``-relation streams (dups, degenerate
  rows, net-zero batches, reinserts after committed deletes) match a numpy
  set-semantics oracle, local AND hash-sharded w ∈ {2, 4}, device AND
  legacy modes, with the warm-path build/transfer spies of
  test_region_store.py carried over;
- **§5.4 end-to-end**: 4-clique-tri over a streamed tri relation is
  bit-exact against the edge-only 4-clique — statically, incrementally,
  and distributed (in-process mesh + subprocess w ∈ {2, 4}).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import csr
from repro.core import delta as D
from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for, _pack_cols)
from repro.core.delta import (DeltaBigJoin, RegionStore, delta_oracle,
                              rows_isin)
from repro.core.generic_join import generic_join
from repro.core.plan import make_delta_plan, make_plan
from repro.core.query import delta_queries

from tests.test_delta import canon
from tests.test_delta_stream import _device_count, _mesh, apply_net

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = BigJoinConfig(batch=128, seed_chunk=128, out_capacity=1 << 15)

QUAD_E = Q.Query("quad-e", 4, (Q.Atom("quad", (0, 1, 2, 3)),
                               Q.Atom("edge", (2, 3))))


def _rand_rel(rng, nv, n, arity):
    return rng.integers(0, nv, (n, arity)).astype(np.int32)


def _kvset(idx):
    """Live (key[, lo], val) entries of an IndexData as a python set."""
    ns = np.asarray(idx.n)
    if ns.ndim:  # sharded: flatten live prefixes
        parts = []
        for k in range(ns.shape[0]):
            cols = [np.asarray(idx.key)[k][:ns[k]]]
            if idx.lo is not None:
                cols.append(np.asarray(idx.lo)[k][:ns[k]])
            cols.append(np.asarray(idx.val)[k][:ns[k]])
            parts.append(set(zip(*[c.tolist() for c in cols])))
        return set().union(*parts) if parts else set()
    n = int(ns)
    cols = [np.asarray(idx.key)[:n]]
    if idx.lo is not None:
        cols.append(np.asarray(idx.lo)[:n])
    cols.append(np.asarray(idx.val)[:n])
    return set(zip(*[c.tolist() for c in cols]))


def _pack_set(rows, nk):
    """Expected (hi[, lo], val) set of [N, nk+1] tuples."""
    rows = np.unique(np.asarray(rows, np.int32), axis=0)
    key = csr.pack_key(tuple(rows[:, i] for i in range(nk)))
    val = rows[:, nk]
    if isinstance(key, tuple):
        return set(zip(key[0].tolist(), key[1].tolist(), val.tolist()))
    return set(zip(key.astype(np.int64).tolist(), val.tolist()))


# ---------------------------------------------------------------------------
# composite (hi, lo) keys through csr
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nk", [3, 4])
def test_composite_build_member_range_match_sets(nk):
    rng = np.random.default_rng(0)
    t = _rand_rel(rng, 15, 300, nk + 1)
    idx = csr.build_index(t, tuple(range(nk)), nk)
    assert idx.composite and idx.lo is not None
    # single-column hi words (nk=3) narrow to int32; packed pairs stay int64
    assert idx.key.dtype == (jnp.int32 if csr.single_word_hi(nk)
                             else jnp.int64)
    # membership: random probes + every live tuple
    probes = np.concatenate([_rand_rel(rng, 17, 200, nk + 1), t[:50]])
    qk = csr.pack_key(tuple(probes[:, i] for i in range(nk)))
    got = np.asarray(csr.index_member(
        idx, (jnp.asarray(qk[0]), jnp.asarray(qk[1])),
        jnp.asarray(probes[:, nk])))
    live = set(map(tuple, t.tolist()))
    want = np.array([tuple(r) in live for r in probes.tolist()])
    np.testing.assert_array_equal(got, want)
    # ranges: distinct-extension counts per composite prefix
    from collections import Counter
    cnt = Counter(tuple(r[:nk]) for r in set(map(tuple, t.tolist())))
    _, c = csr.index_range(idx, (jnp.asarray(qk[0]), jnp.asarray(qk[1])))
    np.testing.assert_array_equal(
        np.asarray(c), [cnt.get(tuple(r[:nk]), 0) for r in probes.tolist()])
    # lex-sorted by (key, lo, val), sentinel padding after n
    n = int(idx.n)
    k = np.asarray(idx.key)[:n]
    lo = np.asarray(idx.lo)[:n]
    v = np.asarray(idx.val)[:n].astype(np.int64)
    trip = np.stack([k, lo, v], 1)
    assert (np.diff([tuple(r) for r in trip.tolist()], axis=0) != 0).any(1) \
        .all() if n > 1 else True
    hi_sent = csr.SENTINEL32 if idx.key.dtype == jnp.int32 else csr.SENTINEL
    assert (np.asarray(idx.key)[n:] == hi_sent).all()
    assert (np.asarray(idx.lo)[n:] == csr.SENTINEL).all()
    # pack/unpack roundtrip
    np.testing.assert_array_equal(csr.unpack_key(qk, nk), probes[:, :nk])


@pytest.mark.parametrize("nk", [3, 4])
def test_composite_fold_primitives_match_set_ops(nk):
    rng = np.random.default_rng(1)
    for trial in range(10):
        ta = _rand_rel(rng, 9, int(rng.integers(0, 80)), nk + 1)
        tb = _rand_rel(rng, 9, int(rng.integers(0, 50)), nk + 1)
        a = csr.build_index(ta, tuple(range(nk)), nk)
        b = csr.build_index(tb, tuple(range(nk)), nk)
        A, B = _pack_set(ta, nk), _pack_set(tb, nk)
        m = csr.merge_index(a, b, 1024)
        d = csr.diff_index(a, b, int(a.capacity))
        x = csr.intersect_index(a, b, int(a.capacity))
        assert _kvset(m) == A | B, trial
        assert _kvset(d) == A - B, trial
        assert _kvset(x) == A & B, trial


def test_composite_sharded_ownership_and_linearity():
    rng = np.random.default_rng(2)
    t = _rand_rel(rng, 12, 400, 4)
    w = 4
    sh = csr.build_sharded_index(t, (0, 1, 2), 3, w)
    local = csr.build_index(t, (0, 1, 2), 3)
    ns = np.asarray(sh.n)
    assert int(ns.sum()) == int(local.n)  # memory linearity
    assert _kvset(sh) == _kvset(local)  # exactly-once, nothing dropped
    for k in range(w):
        keys = np.asarray(sh.key)[k][:ns[k]]
        los = np.asarray(sh.lo)[k][:ns[k]]
        np.testing.assert_array_equal(csr.shard_of((keys, los), w),
                                      np.full(int(ns[k]), k, np.int32))
    # vmapped folds stay shard-local and match the unsharded union
    t2 = _rand_rel(rng, 12, 60, 4)
    sb = csr.build_sharded_index(t2, (0, 1, 2), 3, w, capacity=1)
    vm = jax.jit(jax.vmap(lambda x, y: csr.merge_index(x, y, 1024)))(sh, sb)
    assert _kvset(vm) == _pack_set(t, 3) | _pack_set(t2, 3)


def test_one_shared_packer_no_notimplemented():
    """bigjoin._pack_cols and the host _NpIndex delegate to csr.pack_key;
    3-4 column keys return the (hi, lo) pair instead of raising."""
    rng = np.random.default_rng(3)
    prefix = jnp.asarray(_rand_rel(rng, 50, 40, 4))
    pk = _pack_cols(prefix, [0, 1, 2], jnp.int64)
    assert isinstance(pk, tuple) and len(pk) == 2
    ref = csr.pack_key(tuple(np.asarray(prefix)[:, i] for i in range(3)))
    np.testing.assert_array_equal(np.asarray(pk[0]), ref[0])
    np.testing.assert_array_equal(np.asarray(pk[1]), ref[1])
    from repro.core.generic_join import _NpIndex
    t = _rand_rel(rng, 10, 120, 4)
    npi = _NpIndex(t, (0, 1, 2), 3)
    assert npi.lo is not None
    qs = np.concatenate([t[:30], _rand_rel(rng, 12, 50, 4)])
    qk = csr.pack_key(tuple(qs[:, i] for i in range(3)))
    live = set(map(tuple, t.tolist()))
    want = np.array([tuple(r) in live for r in qs.tolist()])
    np.testing.assert_array_equal(npi.member(qk, qs[:, 3]), want)
    with pytest.raises(ValueError, match="at most 4"):
        csr.pack_key(tuple(np.zeros(2, np.int32) for _ in range(5)))


# ---------------------------------------------------------------------------
# input validation (the old silent reshape(-1, 2) mangling)
# ---------------------------------------------------------------------------

def test_store_rejects_bad_batches():
    store = RegionStore(np.array([[0, 1], [1, 2]], np.int32))
    with pytest.raises(ValueError, match="arity 2"):
        store.normalize(np.zeros((3, 3), np.int32), np.ones(3, np.int32))
    with pytest.raises(ValueError, match="negative id"):
        store.normalize(np.array([[1, -4]], np.int32),
                        np.ones(1, np.int32))
    with pytest.raises(TypeError, match="integer"):
        store.normalize(np.array([[1.5, 2.0]]), np.ones(1, np.int32))
    with pytest.raises(ValueError, match="weights"):
        store.normalize(np.array([[1, 2]], np.int32),
                        np.ones(3, np.int32))
    with pytest.raises(ValueError, match="int32"):
        store.normalize(np.array([[1, 2 ** 31]], np.int64),
                        np.ones(1, np.int32))
    with pytest.raises(KeyError, match="unknown relation"):
        store.normalize({"tri": (np.zeros((1, 3), np.int32),
                                 np.ones(1, np.int32))})


def test_session_update_rejects_bad_batches():
    from repro.api import GraphSession
    sess = GraphSession(np.array([[0, 1], [1, 2]], np.int32), local=True)
    with pytest.raises(ValueError, match="arity 2"):
        sess.update(np.zeros((2, 3), np.int32))
    with pytest.raises(ValueError, match="negative id"):
        sess.update(np.array([[-1, 2]], np.int32))
    with pytest.raises(TypeError, match="integer"):
        sess.update(np.array([[0.5, 1.0]]))


def test_add_relation_validation():
    store = RegionStore(np.array([[0, 1]], np.int32))
    with pytest.raises(ValueError, match="already exists"):
        store.add_relation("edge", np.zeros((0, 2), np.int32))
    with pytest.raises(ValueError, match="arity"):
        store.add_relation("penta", np.zeros((2, 5), np.int32))
    store.add_relation("tri", np.zeros((0, 3), np.int32), arity=3)
    assert store.arity_of("tri") == 3
    # a still-empty declaration may be re-seeded (register-before-
    # materialize, the serve --stream flow) — once, and arity-checked
    with pytest.raises(ValueError, match="arity 3"):
        store.add_relation("tri", np.zeros((2, 4), np.int32))
    store.add_relation("tri", np.array([[1, 2, 3]], np.int32))
    assert store.num_tuples("tri") == 1
    with pytest.raises(ValueError, match="already exists"):
        store.add_relation("tri", np.array([[4, 5, 6]], np.int32))
    # explicit arity contradicting the rows' width must not regroup rows
    with pytest.raises(ValueError, match="arity=4"):
        store.add_relation("quad", np.zeros((4, 3), np.int32), arity=4)
    with pytest.raises(ValueError, match="2..4"):
        store.add_relation("lbl", np.array([[3], [5]], np.int32))


def test_empty_batches_are_noops_not_dtype_errors():
    store = RegionStore(np.array([[0, 1], [1, 2]], np.int32))
    ins, dels = store.normalize([], None)  # plain empty list: float64 array
    assert ins.size == 0 and dels.size == 0
    ins, dels = store.normalize(np.zeros((0, 2)), None)  # float empty
    assert ins.size == 0 and dels.size == 0


def test_dict_batch_rejects_top_level_weights_and_float_weights():
    store = RegionStore({"edge": np.array([[0, 1]], np.int32),
                         "tri": np.array([[1, 2, 3]], np.int32)})
    rows = np.array([[1, 2, 3]], np.int32)
    with pytest.raises(ValueError, match="their own weights"):
        store.normalize({"tri": rows}, -np.ones(1, np.int32))
    with pytest.raises(TypeError, match="integer"):
        store.normalize({"tri": (rows, -np.ones(1))})  # float weights
    # and the dict entry's weights are actually honored
    out = store.normalize({"tri": (rows, -np.ones(1, np.int32))})
    assert out["tri"][1].shape[0] == 1  # a real delete, not a +1 no-op


def test_register_then_seed_relation_flow():
    """register() auto-declares 'tri' empty; add_relation may then seed it
    (the serve --stream ordering), and projections ensured against the
    empty declaration are rebuilt from the seeded rows."""
    from repro.api import GraphSession
    e = np.array([[0, 1], [1, 2], [0, 2], [0, 3], [1, 3], [2, 3]],
                 np.int32)
    sess = GraphSession(e, local=True, batch=128, out_capacity=1 << 14)
    c4t = sess.register("4-clique-tri")
    assert c4t.count() == 0  # tri auto-declared empty
    tris, _ = sess.register("triangle").enumerate()
    sess.add_relation("tri", tris)  # re-seed the empty declaration
    assert c4t.count() == sess.register("4-clique").count() == 1


# ---------------------------------------------------------------------------
# n-ary store: adversarial stream differential vs numpy set semantics
# ---------------------------------------------------------------------------

def apply_net_nary(live, upd, w):
    """Reference semantics: degenerate rows dropped, per-tuple net weight,
    net>0 inserts if absent, net<0 deletes if present."""
    upd = np.asarray(upd, np.int32)
    w = np.asarray(w, np.int64)
    keep = ~D._degenerate_rows(upd)
    upd, w = upd[keep], w[keep]
    uniq, inv = np.unique(upd, axis=0, return_inverse=True)
    net = np.zeros(uniq.shape[0], np.int64)
    np.add.at(net, inv.reshape(-1), w)
    exists = rows_isin(uniq, live) if live.size else \
        np.zeros(uniq.shape[0], bool)
    add = uniq[(net > 0) & ~exists]
    rem = uniq[(net < 0) & exists]
    kept = live[~rows_isin(live, rem)] if rem.size else live
    out = np.concatenate([kept, add]) if add.size else kept
    return np.unique(out, axis=0) if out.size else out.reshape(0,
                                                               upd.shape[1])


def random_batch_nary(rng, nv, live, size, arity=3):
    """Dirty n-ary batches: dups, degenerate rows, live-tuple inserts,
    absent deletes, contradictory duplicates, occasional exact-no-op."""
    flavor = rng.integers(0, 5)
    if flavor == 0 and live.shape[0]:  # nets to an exact no-op
        rows = live[rng.integers(0, live.shape[0], max(size // 2, 1))]
        dup = np.concatenate([rows, rows])
        w = np.concatenate([np.ones(rows.shape[0], np.int32),
                            -np.ones(rows.shape[0], np.int32)])
        dg = np.tile(np.arange(2, dtype=np.int32)[:, None], (1, arity))
        return (np.concatenate([dup, dg]),
                np.concatenate([w, np.ones(2, np.int32)]))
    n_ins = int(rng.integers(0, size + 1))
    n_del = int(rng.integers(0, size // 2 + 1))
    ins = _rand_rel(rng, nv, n_ins, arity)
    parts, wparts = [ins], [np.ones(n_ins, np.int32)]
    if n_del:
        n_live = min(n_del, live.shape[0])
        if n_live:
            parts.append(live[rng.choice(live.shape[0], n_live,
                                         replace=False)])
            wparts.append(-np.ones(n_live, np.int32))
        parts.append(_rand_rel(rng, nv, n_del - n_live + 1, arity))
        wparts.append(-np.ones(n_del - n_live + 1, np.int32))
    if flavor == 2 and n_ins:  # weight piles on duplicate rows
        k = rng.integers(0, n_ins)
        parts.append(ins[k:k + 1].repeat(3, 0))
        wparts.append(np.ones(3, np.int32))
    return np.concatenate(parts), np.concatenate(wparts)


@pytest.mark.parametrize("shard_w", [0, 2, 4], ids=["local", "w2", "w4"])
@pytest.mark.parametrize("device", [True, False], ids=["device", "legacy"])
def test_nary_store_stream_differential(device, shard_w):
    if shard_w and not device:
        pytest.skip("legacy host store has no sharded mode")
    rng = np.random.default_rng(10 + shard_w)
    nv = 12
    tri0 = np.unique(_rand_rel(rng, nv, 90, 3), axis=0)
    store = RegionStore({"tri": tri0}, shard_w=shard_w,
                        compact_ratio=0.3, device_resident=device)
    store.ensure("tri", (0, 1), 2)
    store.ensure("tri", (0, 2), 1)
    cur = tri0.copy()
    for step in range(20):
        upd, w = random_batch_nary(rng, nv, cur, 10)
        out = store.normalize({"tri": (upd, w)})
        ins, dels = out["tri"]
        ref_after = apply_net_nary(cur, upd, w)
        if ins.size or dels.size:
            store.begin_epoch(out)
            store.commit(out)
        np.testing.assert_array_equal(store.relation_rows("tri"),
                                      ref_after, err_msg=f"epoch {step}")
        # normalize's own contract: ins ∉ live, dels ⊆ live
        assert not rows_isin(ins, cur).any()
        assert rows_isin(dels, cur).all()
        # bijective projections track the relation exactly
        for reg in store.projections.values():
            rows = np.unique(np.concatenate(
                [D._diff_rows(reg.base, reg.cdel), reg.cins]), axis=0) \
                if (reg.cins.size or reg.cdel.size) else reg.base
            np.testing.assert_array_equal(rows, ref_after)
        cur = ref_after
    if device:
        assert store.stats.live_compactions + store.stats.compactions > 0


from tests.test_delta_stream import given, settings, st  # noqa: E402


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=10, deadline=None)
def test_nary_store_stream_differential_hypothesis(seed):
    """Hypothesis-driven variant: random seeds, random compaction ratios,
    same numpy set-semantics oracle (auto-skips without hypothesis)."""
    rng = np.random.default_rng(seed)
    nv = int(rng.integers(5, 14))
    tri0 = np.unique(_rand_rel(rng, nv, int(rng.integers(10, 80)), 3),
                     axis=0)
    store = RegionStore({"tri": tri0},
                        compact_ratio=float(rng.choice([0.01, 0.5, 50.0])))
    store.ensure("tri", (0, 1), 2)
    cur = tri0.copy()
    for _ in range(4):
        upd, w = random_batch_nary(rng, nv, cur, 8)
        out = store.normalize({"tri": (upd, w)})
        if any(a.size or b.size for a, b in out.values()):
            store.begin_epoch(out)
            store.commit(out)
        cur = apply_net_nary(cur, upd, w)
        np.testing.assert_array_equal(store.relation_rows("tri"), cur)


def test_nary_sharded_memory_linearity_and_ownership():
    rng = np.random.default_rng(20)
    w, nv = 4, 14
    tri0 = np.unique(_rand_rel(rng, nv, 140, 3), axis=0)
    store = RegionStore({"tri": tri0}, shard_w=w)
    store.ensure("tri", (0, 1), 2)
    cur = tri0.copy()
    for _ in range(6):
        upd, wts = random_batch_nary(rng, nv, cur, 12)
        out = store.normalize({"tri": (upd, wts)})
        if any(a.size or b.size for a, b in out.values()):
            store.begin_epoch(out)
            store.commit(out)
        cur = apply_net_nary(cur, upd, wts)
        st = store._rels["tri"]
        total = 0
        for region in (st.lb, st.lc_ins, st.lc_del):
            ns = np.asarray(region.n)
            assert ns.shape == (w,)
            for k in range(w):
                keys = np.asarray(region.key)[k][:ns[k]]
                los = np.asarray(region.lo)[k][:ns[k]]
                assert (csr.shard_of((keys, los), w) == k).all()
            total += int(ns.sum())
        nb, nci, ncd = (int(np.asarray(n).sum()) for n in st.n_live)
        assert nb + nci - ncd == cur.shape[0]
        assert total == nb + nci + ncd
        np.testing.assert_array_equal(store.relation_rows("tri"), cur)


def test_reinsert_after_committed_delete_tri():
    rng = np.random.default_rng(21)
    tri0 = np.unique(_rand_rel(rng, 10, 70, 3), axis=0)
    q = Q.four_clique_tri()
    eng = DeltaBigJoin(q, {"tri": tri0}, cfg=CFG,
                       compact_ratio=1e9)  # ratio can never fire
    victim = tri0[:6]
    cur = tri0.copy()
    for wsign in (-1, 1, -1):
        wv = wsign * np.ones(victim.shape[0], np.int32)
        res = eng.apply({"tri": (victim, wv)})
        after = apply_net_nary(cur, victim, wv)
        ot, ow = delta_oracle(q, {"tri": cur}, {"tri": after})
        assert canon(res.tuples, res.weights) == canon(ot, ow)
        cur = after
    # the re-insertion forced an eager compaction (overlap prevention)
    assert eng.store.stats.compactions + \
        eng.store.stats.live_compactions > 0


# ---------------------------------------------------------------------------
# warm-path spies: delta-sized staging only, pure-device folds
# ---------------------------------------------------------------------------

def test_nary_warm_commit_no_host_rebuild_or_transfer(monkeypatch):
    rng = np.random.default_rng(22)
    nv = 12
    tri0 = np.unique(_rand_rel(rng, nv, 120, 3), axis=0)
    q = Q.four_clique_tri()
    eng = DeltaBigJoin(q, {"tri": tri0}, cfg=CFG)
    cur = tri0.copy()
    for _ in range(3):  # warm up compiles
        upd, w = random_batch_nary(rng, nv, cur, 8)
        eng.apply({"tri": (upd, w)})
        cur = apply_net_nary(cur, upd, w)

    built_sizes = []
    real_build, real_sharded = csr.build_index, csr.build_sharded_index

    def spy_build(tuples, *a, **k):
        built_sizes.append(np.asarray(tuples).shape[0])
        return real_build(tuples, *a, **k)

    def spy_sharded(tuples, *a, **k):
        built_sizes.append(np.asarray(tuples).shape[0])
        return real_sharded(tuples, *a, **k)

    monkeypatch.setattr(D, "build_index", spy_build)
    monkeypatch.setattr(csr, "build_index", spy_build)
    monkeypatch.setattr(csr, "build_sharded_index", spy_sharded)
    monkeypatch.setattr(D, "STRICT_TRANSFERS", True)

    store = eng.store
    st = store._rels["tri"]
    lb_before = st.lb
    bases_before = {p: r.d_base for p, r in store.projections.items()
                    if not r.derived}
    pulls_before = store.stats.mirror_pulls
    applied = 0
    while applied < 2:
        upd, w = random_batch_nary(rng, nv, cur, 8)
        res = eng.apply({"tri": (upd, w)})
        cur = apply_net_nary(cur, upd, w)
        if res.per_dq:
            applied += 1
    monkeypatch.setattr(D, "STRICT_TRANSFERS", False)
    assert built_sizes and max(built_sizes) <= 64, built_sizes
    assert st.lb is lb_before  # base LSM merged, never rebuilt
    for p, r in store.projections.items():
        if not r.derived:
            assert r.d_base is bases_before[p]
    assert store.stats.mirror_pulls == pulls_before
    np.testing.assert_array_equal(store.relation_rows("tri"), cur)


def test_composite_commit_fold_jaxpr_is_pure_device_compute():
    """The tri relation's LIVE-set LSM keys on the full (hi, lo) composite
    row; its commit fold must still lower to pure device compute."""
    rng = np.random.default_rng(23)
    tri0 = np.unique(_rand_rel(rng, 10, 50, 3), axis=0)
    store = RegionStore({"tri": tri0})
    st = store._rels["tri"]
    ins = np.array([[20, 21, 22], [23, 24, 25]], np.int32)
    ui = D._packed_index(ins, 0, 3)
    ud = D._packed_index(ins[:0], 0, 3)
    assert st.lb.lo is not None and ui.lo is not None  # composite regions
    closed = jax.make_jaxpr(
        lambda ba, ci, cd, ui, ud: D._commit_fold(
            ba, ci, cd, ui, ud, cins_cap=128, cdel_cap=128, sharded=False)
    )(st.lb, st.lc_ins, st.lc_del, ui, ud)
    bad = {"pure_callback", "io_callback", "debug_callback", "callback",
           "infeed", "outfeed", "device_put"}

    def _subjaxprs(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from _subjaxprs(x)

    def walk(jaxpr, seen):
        for eqn in jaxpr.eqns:
            seen.add(eqn.primitive.name)
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    walk(sub, seen)

    seen = set()
    walk(closed.jaxpr, seen)
    assert not (seen & bad), seen & bad


# ---------------------------------------------------------------------------
# 3-col composite keys through the full static + delta stack (quad relation)
# ---------------------------------------------------------------------------

def test_quad_static_parity():
    rng = np.random.default_rng(30)
    quad = np.unique(_rand_rel(rng, 8, 300, 4), axis=0)
    edge = np.unique(_rand_rel(rng, 8, 50, 2), axis=0)
    plan = make_plan(QUAD_E)
    assert any(len(b.key_attrs) == 3
               for lv in plan.levels for b in lv.bindings)
    rels = {"quad": quad, "edge": edge}
    res = run_bigjoin(plan, build_indices(plan, rels),
                      seed_tuples_for(plan, rels), cfg=CFG)
    ref_t, ref_c = generic_join(QUAD_E, rels, plan=plan)
    assert res.count == ref_c
    assert set(map(tuple, res.tuples.tolist())) == \
        set(map(tuple, ref_t.tolist()))


def test_quad_delta_plans_cover_widths():
    """dQ seeded from the 4-ary atom covers every attribute (zero-level
    direct output); dQ seeded from the edge atom walks 3-col-key levels."""
    plans = [make_delta_plan(dq) for dq in delta_queries(QUAD_E)]
    widths = sorted(p.seed_width for p in plans)
    assert widths == [2, 4]
    assert any(len(p.levels) == 0 for p in plans)


def test_quad_stream_differential():
    rng = np.random.default_rng(31)
    nv = 7
    quad0 = np.unique(_rand_rel(rng, nv, 120, 4), axis=0)
    edge0 = np.unique(_rand_rel(rng, nv, 30, 2), axis=0)
    eng = DeltaBigJoin(QUAD_E, {"quad": quad0, "edge": edge0}, cfg=CFG)
    cur = {"quad": quad0, "edge": edge0}
    for step in range(10):
        qu, qw = random_batch_nary(rng, nv, cur["quad"], 8, arity=4)
        eu, ew = random_batch_nary(rng, nv, cur["edge"], 6, arity=2)
        res = eng.apply({"quad": (qu, qw), "edge": (eu, ew)})
        after = {"quad": apply_net_nary(cur["quad"], qu, qw),
                 "edge": apply_net_nary(cur["edge"], eu, ew)}
        ot, ow = delta_oracle(QUAD_E, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow), step
        np.testing.assert_array_equal(eng.store.relation_rows("quad"),
                                      after["quad"])
        np.testing.assert_array_equal(eng.store.relation_rows("edge"),
                                      after["edge"])
        cur = after


def test_single_atom_delta_is_direct_output():
    """A single-atom standing query (monitor the relation itself): the
    delta plan's seed covers every attribute and outputs directly."""
    rng = np.random.default_rng(32)
    tri0 = np.unique(_rand_rel(rng, 9, 40, 3), axis=0)
    ident = Q.Query("tri-id", 3, (Q.Atom("tri", (0, 1, 2)),))
    eng = DeltaBigJoin(ident, {"tri": tri0}, cfg=CFG)
    assert all(len(p.levels) == 0 for p in eng.plans)
    cur = tri0.copy()
    for step in range(6):
        upd, w = random_batch_nary(rng, 9, cur, 8)
        res = eng.apply({"tri": (upd, w)})
        after = apply_net_nary(cur, upd, w)
        ot, ow = delta_oracle(ident, {"tri": cur}, {"tri": after})
        assert canon(res.tuples, res.weights) == canon(ot, ow), step
        cur = after


# ---------------------------------------------------------------------------
# §5.4 end-to-end: 4-clique-tri ≡ 4-clique, local / mesh / subprocess
# ---------------------------------------------------------------------------

def _tri_pipeline(session, rng, nv, epochs, check_every=True):
    """Drive the two-relation session; assert per-epoch bit-exact parity of
    4-clique-tri (tri plan) vs 4-clique (edge plan)."""
    from tests.test_delta_stream import random_batch
    live = session.edges
    for step in range(epochs):
        upd, w = random_batch(rng, nv, live, 12)
        r1 = session.update(upd, w)
        td = r1.deltas["triangle"]
        t_upd = td.tuples if td.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = td.weights if td.weights is not None else \
            np.zeros(0, np.int32)
        r2 = session.update({"tri": (t_upd, t_w)})
        live = r1.advance(live)
        if check_every:
            a, b = r1.deltas["4-clique"], r2.deltas["4-clique-tri"]
            assert canon(b.tuples, b.weights) == \
                canon(a.tuples, a.weights), step


def _fresh_session(edges, **kw):
    from repro.api import GraphSession
    sess = GraphSession(edges, batch=128, out_capacity=1 << 16, **kw)
    tri = sess.register("triangle")
    sess.register("4-clique")
    tri0, _ = tri.enumerate()
    sess.add_relation("tri", tri0)
    sess.register("4-clique-tri")
    return sess


def test_four_clique_tri_session_local_20_epochs():
    from repro.api import oracle_count
    rng = np.random.default_rng(40)
    nv = 16
    e = np.unique(_rand_rel(rng, nv, 110, 2), axis=0)
    e = e[e[:, 0] != e[:, 1]]
    sess = _fresh_session(e, local=True)
    c4, c4t = sess["4-clique"], sess["4-clique-tri"]
    assert c4t.count() == c4.count() == oracle_count("4-clique", e)
    _tri_pipeline(sess, rng, nv, epochs=20)
    assert c4t.net_change == c4.net_change
    ref = oracle_count("4-clique", sess.edges)
    assert c4.net_change == ref - oracle_count("4-clique", e)
    # static re-evaluation off the SAME maintained store (exercises the
    # derived tri projections of the static plan, post-stream)
    assert c4t.count() == c4.count() == ref


@pytest.mark.parametrize("w", [2, 4])
def test_four_clique_tri_session_mesh(w):
    if _device_count() < w:
        pytest.skip(f"needs {w} devices (CI runs with 4 virtual devices)")
    rng = np.random.default_rng(41)
    nv = 14
    e = np.unique(_rand_rel(rng, nv, 90, 2), axis=0)
    e = e[e[:, 0] != e[:, 1]]
    sess = _fresh_session(e, mesh=_mesh(w))
    assert not sess.local and sess.w == w
    _tri_pipeline(sess, rng, nv, epochs=5)
    assert sess["4-clique-tri"].net_change == sess["4-clique"].net_change


def run_check(*args, timeout=1200):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.core._nary_dist_check", *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_subprocess_w2_four_clique_tri_20_batches():
    r = run_check("--workers", "2", "--nv", "20", "--ne", "110",
                  "--batches", "20", "--batch-size", "12")
    assert r["all_exact"] and r["workers"] == 2 and r["batches"] == 20


@pytest.mark.slow
def test_subprocess_w4_four_clique_tri_20_batches():
    r = run_check("--workers", "4", "--nv", "20", "--ne", "110",
                  "--batches", "20", "--batch-size", "12")
    assert r["all_exact"] and r["workers"] == 4


# ---------------------------------------------------------------------------
# derived (non-covering) projections: lossy images stay correct
# ---------------------------------------------------------------------------

def test_derived_projection_survives_shared_support():
    """Two tri tuples sharing an (a1, a3) pair: deleting ONE of them must
    not kill the pair in the derived a1->a3 projection — the classic
    many-to-one trap an incremental set fold would get wrong."""
    tri0 = np.array([[1, 2, 3], [1, 9, 3], [4, 5, 6]], np.int32)
    store = RegionStore({"tri": tri0})
    reg = store.ensure("tri", (0,), 2)  # ignores the middle column
    assert reg.derived
    vi = reg.versioned("old")
    qk = jnp.asarray(np.array([1], np.int64))
    qv = jnp.asarray(np.array([3], np.int32))
    assert bool(np.asarray(vi.member(qk, qv))[0])
    # delete (1, 2, 3); (1, 9, 3) still supports the pair (1 -> 3)
    batch = {"tri": (tri0[:1], -np.ones(1, np.int32))}
    out = store.normalize(batch)
    store.begin_epoch(out)
    new_vi = reg.versioned("new")
    assert bool(np.asarray(new_vi.member(qk, qv))[0])
    store.commit(out)
    vi2 = reg.versioned("old")
    assert bool(np.asarray(vi2.member(qk, qv))[0])
    # deleting the second supporter finally clears the pair
    batch2 = {"tri": (np.array([[1, 9, 3]], np.int32),
                      -np.ones(1, np.int32))}
    out2 = store.normalize(batch2)
    store.begin_epoch(out2)
    store.commit(out2)
    assert not bool(np.asarray(reg.versioned("old").member(qk, qv))[0])
