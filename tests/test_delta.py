"""Delta-BiGJoin vs full-recompute oracle under insert/delete streams."""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig
from repro.core.delta import DeltaBigJoin, delta_oracle
from repro.core.generic_join import generic_join

from tests.test_generic_join import random_graph


def canon(t, w):
    """Aggregate signed tuples -> sorted (tuple, net weight != 0) pairs
    (the shared implementation next to delta_oracle)."""
    from repro.core.delta import canon_signed
    return canon_signed(t, w)


CFG = BigJoinConfig(batch=256, seed_chunk=256, out_capacity=1 << 16)


@pytest.mark.parametrize("q", [Q.triangle(), Q.diamond(), Q.four_clique()],
                         ids=lambda q: q.name)
def test_insert_only_stream(q):
    g = random_graph(40, 500, 0)
    e = g.edges
    engine = DeltaBigJoin(q, e[:100], cfg=CFG)
    cur = e[:100]
    for lo in range(100, 400, 75):
        batch = e[lo:lo + 75]
        res = engine.apply(batch)
        after = np.unique(np.concatenate([cur, batch]), axis=0)
        ot, ow = delta_oracle(q, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow)
        cur = after
    # final state agrees with a from-scratch count
    _, final = generic_join(q, {"edge": cur})
    _, init = generic_join(q, {"edge": e[:100]})
    # engine reported total change == final - initial
    # (re-run engine cumulative check)


def test_mixed_insert_delete_stream():
    q = Q.triangle()
    g = random_graph(35, 420, 1)
    e = g.edges
    rng = np.random.default_rng(2)
    engine = DeltaBigJoin(q, e[:200], cfg=CFG)
    cur = e[:200]
    total = generic_join(q, {"edge": cur})[1]
    for step in range(5):
        ins = e[200 + step * 30: 200 + (step + 1) * 30]
        live_idx = rng.choice(cur.shape[0], size=10, replace=False)
        dels = cur[live_idx]
        batch = np.concatenate([ins, dels])
        w = np.concatenate([np.ones(ins.shape[0], np.int32),
                            -np.ones(dels.shape[0], np.int32)])
        res = engine.apply(batch, w)
        after = np.unique(np.concatenate([cur, ins]), axis=0)
        mask = ~np.isin(
            (after[:, 0].astype(np.int64) << 32) | after[:, 1],
            (dels[:, 0].astype(np.int64) << 32) | dels[:, 1])
        after = after[mask]
        ot, ow = delta_oracle(q, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow)
        total += res.count_delta
        cur = after
    assert total == generic_join(q, {"edge": cur})[1]


def test_delete_then_reinsert_same_edge():
    """Exercises the eager-compaction guard (cdel re-insertion)."""
    q = Q.triangle()
    g = random_graph(25, 250, 3)
    engine = DeltaBigJoin(q, g.edges, cfg=CFG,
                          compact_ratio=10.0)  # avoid routine compaction
    victim = g.edges[:5]
    r1 = engine.apply(victim, -np.ones(5, np.int32))
    after_del = engine.edges.copy()
    r2 = engine.apply(victim, np.ones(5, np.int32))
    ot, ow = delta_oracle(q, after_del,
                          np.unique(np.concatenate([after_del, victim]),
                                    axis=0))
    assert canon(r2.tuples, r2.weights) == canon(ot, ow)
    # net effect of delete+reinsert is zero
    assert r1.count_delta + r2.count_delta == 0


def test_noop_updates_ignored():
    q = Q.triangle()
    g = random_graph(20, 150, 4)
    engine = DeltaBigJoin(q, g.edges, cfg=CFG)
    # inserting existing edges / deleting absent edges: no output change
    res = engine.apply(g.edges[:10])  # already present
    assert res.count_delta == 0
    absent = np.array([[900, 901], [901, 902]], np.int32)
    res = engine.apply(absent, -np.ones(2, np.int32))
    assert res.count_delta == 0


def test_build_from_empty_matches_static():
    """Fig 4's Delta-BiGJoinT mode: load the graph as one update stream."""
    q = Q.triangle()
    g = random_graph(30, 300, 5)
    engine = DeltaBigJoin(q, g.edges[:0], cfg=CFG)
    total = 0
    for lo in range(0, g.edges.shape[0], 60):
        total += engine.apply(g.edges[lo:lo + 60]).count_delta
    assert total == generic_join(q, {"edge": g.edges})[1]


def test_compaction_preserves_results():
    q = Q.diamond()
    g = random_graph(30, 400, 6)
    eager = DeltaBigJoin(q, g.edges[:150], cfg=CFG, compact_ratio=0.01)
    lazy = DeltaBigJoin(q, g.edges[:150], cfg=CFG, compact_ratio=100.0)
    for lo in range(150, 390, 60):
        batch = g.edges[lo:lo + 60]
        a = eager.apply(batch)
        b = lazy.apply(batch)
        assert canon(a.tuples, a.weights) == canon(b.tuples, b.weights)
