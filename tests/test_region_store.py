"""Device-resident RegionStore: fold primitives, compaction, transfer guard.

Four contracts, all from the delta-proportional epoch design (DESIGN.md §6):

- **fold algebra**: the jitted sorted-merge/diff/intersect folds
  (`csr.merge_index` etc) match numpy set semantics bit-exactly, including
  capacity padding, empty operands, narrow/wide key dtypes, and the
  vmapped per-shard path;
- **mode parity**: the device-resident store and the legacy host store are
  interchangeable — identical signed outputs, identical live edge sets,
  identical compaction accounting — over adversarial streams;
- **compaction**: ratio-threshold and eager re-insertion compactions fire
  when (and only when) they should, and a >= 50-epoch stream stays
  bit-exact across compaction boundaries while ``StoreStats.compactions``
  advances;
- **no full-graph work on the warm path**: with ``STRICT_TRANSFERS`` the
  jitted normalize/commit steps run under ``jax.transfer_guard("disallow")``
  — any host<->device copy raises — and a build spy proves the only index
  builds on a warm epoch are delta-sized staging, never a rebuild of base.
"""
import numpy as np
import pytest

import jax

from repro.core import csr
from repro.core import delta as D
from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig
from repro.core.delta import DeltaBigJoin, RegionStore, delta_oracle

from tests.test_delta import canon
from tests.test_delta_stream import _start_edges, apply_net, random_batch

CFG = BigJoinConfig(batch=128, seed_chunk=128, out_capacity=1 << 15)


# ---------------------------------------------------------------------------
# fold primitives vs numpy set semantics
# ---------------------------------------------------------------------------

def _kvset(idx):
    n = int(np.asarray(idx.n).sum())
    if np.asarray(idx.n).ndim:  # sharded: flatten live prefixes
        ns = np.asarray(idx.n)
        ks = np.concatenate([np.asarray(idx.key)[k][:ns[k]]
                             for k in range(ns.shape[0])])
        vs = np.concatenate([np.asarray(idx.val)[k][:ns[k]]
                             for k in range(ns.shape[0])])
        return set(zip(ks.tolist(), vs.tolist()))
    return set(zip(np.asarray(idx.key)[:n].tolist(),
                   np.asarray(idx.val)[:n].tolist()))


def _lex_sorted(idx):
    n = int(idx.n)
    k = np.asarray(idx.key)[:n]
    v = np.asarray(idx.val)[:n]
    if n < 2:
        return True
    dk, dv = np.diff(k.astype(np.int64)), np.diff(v.astype(np.int64))
    return bool(((dk > 0) | ((dk == 0) & (dv > 0))).all())


@pytest.mark.parametrize("narrow", [True, False], ids=["i32", "i64"])
def test_fold_primitives_match_set_ops(narrow):
    rng = np.random.default_rng(0)
    for trial in range(15):
        na, nb = int(rng.integers(0, 70)), int(rng.integers(0, 40))
        ta = rng.integers(0, 40, (na, 2)).astype(np.int32)
        tb = rng.integers(0, 40, (nb, 2)).astype(np.int32)
        a = csr.build_index(ta, (0,), 1, narrow=narrow)
        b = csr.build_index(tb, (0,), 1, narrow=narrow)
        A, B = _kvset(a), _kvset(b)
        m = csr.merge_index(a, b, 512)
        d = csr.diff_index(a, b, int(a.capacity))
        x = csr.intersect_index(a, b, int(a.capacity))
        assert _kvset(m) == A | B and _lex_sorted(m), trial
        assert _kvset(d) == A - B and _lex_sorted(d), trial
        assert _kvset(x) == A & B and _lex_sorted(x), trial
        # sentinel padding: everything past n is the sentinel
        for out in (m, d, x):
            n = int(out.n)
            sent = csr.SENTINEL32 if narrow else csr.SENTINEL
            assert (np.asarray(out.key)[n:] == sent).all()


def test_sharded_fold_matches_unsharded():
    rng = np.random.default_rng(1)
    w = 4
    ta = rng.integers(0, 60, (150, 2)).astype(np.int32)
    tb = rng.integers(0, 60, (30, 2)).astype(np.int32)
    sa = csr.build_sharded_index(ta, (0,), 1, w)
    sb = csr.build_sharded_index(tb, (0,), 1, w, capacity=1)
    la = csr.build_index(ta, (0,), 1)
    lb = csr.build_index(tb, (0,), 1)
    vm = jax.jit(jax.vmap(lambda x, y: csr.merge_index(x, y, 512)))(sa, sb)
    vd = jax.jit(jax.vmap(
        lambda x, y: csr.diff_index(x, y, int(sa.key.shape[1]))))(sa, sb)
    assert _kvset(vm) == _kvset(la) | _kvset(lb)
    assert _kvset(vd) == _kvset(la) - _kvset(lb)
    # ownership is preserved by shard-local folds
    ns = np.asarray(vm.n)
    for k in range(w):
        keys = np.asarray(vm.key)[k][:ns[k]].astype(np.int64)
        assert (csr.shard_of(keys, w) == k).all()


# ---------------------------------------------------------------------------
# device store vs legacy host store: interchangeable
# ---------------------------------------------------------------------------

def test_device_store_matches_legacy_store_stream():
    q = Q.triangle()
    nv = 14
    edges = _start_edges(nv, 80, 3)
    dev = DeltaBigJoin(q, edges, cfg=CFG, device_resident=True)
    leg = DeltaBigJoin(q, edges, cfg=CFG, device_resident=False)
    assert dev.store.device_resident and not leg.store.device_resident
    rng = np.random.default_rng(4)
    cur = edges.copy()
    for step in range(8):
        upd, w = random_batch(rng, nv, cur, 12)
        a = dev.apply(upd, w)
        b = leg.apply(upd, w)
        assert canon(a.tuples, a.weights) == canon(b.tuples, b.weights), step
        assert a.count_delta == b.count_delta
        np.testing.assert_array_equal(dev.edges, leg.edges)
        cur = apply_net(cur, upd, w)
        np.testing.assert_array_equal(dev.edges, cur)


def test_store_normalize_parity_and_noops():
    edges = _start_edges(12, 60, 5)
    dev = RegionStore(edges, device_resident=True)
    leg = RegionStore(edges, device_resident=False)
    rng = np.random.default_rng(6)
    upd, w = random_batch(rng, 12, edges, 16)
    di, dd = dev.normalize(upd, w)
    li, ld = leg.normalize(upd, w)
    np.testing.assert_array_equal(di, li)
    np.testing.assert_array_equal(dd, ld)
    # absent deletes / live inserts / self-loops net to an exact no-op
    live = edges[:4]
    noop = np.concatenate([live, np.array([[7, 7], [900, 901]], np.int32)])
    wn = np.concatenate([np.ones(4, np.int32), np.ones(1, np.int32),
                         -np.ones(1, np.int32)])
    for store in (dev, leg):
        i, d = store.normalize(noop, wn)
        assert i.size == 0 and d.size == 0


# ---------------------------------------------------------------------------
# compaction: threshold, eager re-insertion, long-stream differential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("device", [True, False], ids=["device", "legacy"])
def test_compaction_threshold_behavior(device):
    """committed > ratio * |base| triggers compaction at exactly the epoch
    the running committed size crosses the threshold, for every ensured
    projection."""
    base_edges = np.stack([np.arange(100, dtype=np.int32),
                           np.arange(100, dtype=np.int32) + 1000], 1)
    store = RegionStore(base_edges, compact_ratio=0.35,
                        device_resident=device)
    store.ensure("edge", (0,), 1)
    store.ensure("edge", (1,), 0)
    nproj = len(store.projections)
    fresh = np.stack([np.arange(40, dtype=np.int32) + 500,
                      np.arange(40, dtype=np.int32) + 2000], 1)
    trips = []
    for e in range(4):  # committed grows 10, 20, 30, 40 vs 0.35*100 = 35
        ins = fresh[e * 10:(e + 1) * 10]
        empty = ins[:0]
        store.begin_epoch(ins, empty)
        store.commit(ins, empty)
        trips.append(store.stats.compactions)
    assert trips == [0, 0, 0, nproj]  # fires only once 40 > 35
    for reg in store.projections.values():
        assert reg.cins.shape[0] == 0 and reg.cdel.shape[0] == 0
        assert reg.base.shape[0] == 140


@pytest.mark.parametrize("device", [True, False], ids=["device", "legacy"])
def test_eager_compaction_on_reinsert_after_committed_delete(device):
    q = Q.triangle()
    edges = _start_edges(14, 70, 8)
    engine = DeltaBigJoin(q, edges, cfg=CFG, compact_ratio=1e9,  # never
                          device_resident=device)
    victim = edges[:6]
    cur = engine.edges.copy()
    engine.apply(victim, -np.ones(6, np.int32))
    assert engine.store.stats.compactions == 0  # ratio can't fire
    cur = apply_net(cur, victim, -np.ones(6, np.int32))
    # re-inserting the committed deletes MUST force-compact every projection
    res = engine.apply(victim, np.ones(6, np.int32))
    assert engine.store.stats.compactions == len(engine.projections)
    after = apply_net(cur, victim, np.ones(6, np.int32))
    ot, ow = delta_oracle(q, cur, after)
    assert canon(res.tuples, res.weights) == canon(ot, ow)
    for reg in engine.projections.values():
        assert reg.cdel.shape[0] == 0  # the overlap source is gone


@pytest.mark.parametrize("device", [True, False], ids=["device", "legacy"])
def test_50_epoch_stream_bitexact_across_compactions(device):
    """>= 50 epochs with an aggressive ratio: compactions keep firing and
    every epoch's signed output stays bit-exact vs the recompute oracle."""
    q = Q.triangle()
    nv = 12
    edges = _start_edges(nv, 60, 9)
    engine = DeltaBigJoin(q, edges, cfg=CFG, compact_ratio=0.05,
                          device_resident=device)
    rng = np.random.default_rng(10)
    cur = edges.copy()
    compactions_seen = [0]
    for step in range(52):
        upd, w = random_batch(rng, nv, cur, 8)
        res = engine.apply(upd, w)
        after = apply_net(cur, upd, w)
        np.testing.assert_array_equal(engine.edges, after)
        ot, ow = delta_oracle(q, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow), step
        compactions_seen.append(engine.store.stats.compactions)
        cur = after
    assert engine.store.stats.epochs >= 30  # noise batches may no-op
    # compaction fired repeatedly along the stream, not just once at the end
    assert engine.store.stats.compactions >= 3 * len(engine.projections)
    mid = compactions_seen[len(compactions_seen) // 2]
    assert 0 < mid < engine.store.stats.compactions


# ---------------------------------------------------------------------------
# the warm path: no transfers inside the folds, no full-index rebuilds
# ---------------------------------------------------------------------------

def test_warm_commit_no_host_rebuild_or_transfer(monkeypatch):
    q = Q.triangle()
    nv = 14
    edges = _start_edges(nv, 90, 11)
    engine = DeltaBigJoin(q, edges, cfg=CFG)
    rng = np.random.default_rng(12)
    cur = edges.copy()
    for _ in range(3):  # warm-up epochs (compiles the folds + dataflows)
        upd, w = random_batch(rng, nv, cur, 10)
        engine.apply(upd, w)
        cur = apply_net(cur, upd, w)

    # spy every index build: a warm epoch may stage delta-sized uncommitted
    # regions, but must never rebuild a full-graph index
    built_sizes = []
    real_build, real_sharded = csr.build_index, csr.build_sharded_index

    def spy_build(tuples, *a, **k):
        built_sizes.append(np.asarray(tuples).shape[0])
        return real_build(tuples, *a, **k)

    def spy_sharded(tuples, *a, **k):
        built_sizes.append(np.asarray(tuples).shape[0])
        return real_sharded(tuples, *a, **k)

    monkeypatch.setattr(D, "build_index", spy_build)  # delta's direct ref
    monkeypatch.setattr(csr, "build_index", spy_build)
    monkeypatch.setattr(csr, "build_sharded_index", spy_sharded)
    # every jitted store step now runs under transfer_guard("disallow")
    monkeypatch.setattr(D, "STRICT_TRANSFERS", True)

    store = engine.store
    lb_before = store._lb
    bases_before = {p: reg.d_base for p, reg in store.projections.items()}
    pulls_before = store.stats.mirror_pulls
    applied = 0
    while applied < 2:
        upd, w = random_batch(rng, nv, cur, 10)
        res = engine.apply(upd, w)
        cur = apply_net(cur, upd, w)
        if res.per_dq:  # skip net-zero no-ops: we want real commits
            applied += 1

    monkeypatch.setattr(D, "STRICT_TRANSFERS", False)
    # builds during warm epochs are delta-sized staging only
    assert built_sizes, "staging builds expected"
    assert max(built_sizes) <= 64, built_sizes
    # the compacted base was neither rebuilt nor re-uploaded
    assert store._lb is lb_before
    for p, reg in store.projections.items():
        assert reg.d_base is bases_before[p]
    # and the warm loop never materialized a host mirror
    assert store.stats.mirror_pulls == pulls_before
    np.testing.assert_array_equal(engine.edges, cur)  # mirror still exact


def test_commit_fold_jaxpr_is_pure_device_compute():
    """The commit fold lowers to pure device compute: no host callbacks,
    no transfers anywhere in its jaxpr."""
    edges = _start_edges(10, 40, 13)
    store = RegionStore(edges)
    store.ensure("edge", (0,), 1)
    reg = next(iter(store.projections.values()))
    ins = np.array([[50, 51], [52, 53]], np.int32)
    reg.set_uncommitted(ins, ins[:0])
    closed = jax.make_jaxpr(
        lambda ba, ci, cd, ui, ud: D._commit_fold(
            ba, ci, cd, ui, ud, cins_cap=128, cdel_cap=128, sharded=False)
    )(reg.d_base, reg.d_cins, reg.d_cdel, reg.d_uins, reg.d_udel)
    bad = {"pure_callback", "io_callback", "debug_callback", "callback",
           "infeed", "outfeed", "device_put"}

    def walk(jaxpr, seen):
        for eqn in jaxpr.eqns:
            seen.add(eqn.primitive.name)
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    walk(sub, seen)

    def _subjaxprs(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from _subjaxprs(x)

    seen = set()
    walk(closed.jaxpr, seen)
    assert not (seen & bad), seen & bad


def test_mirror_pull_accounting():
    """.edges / region rows are the ONLY mirror pulls; apply() itself never
    materializes host state in device mode."""
    q = Q.triangle()
    edges = _start_edges(12, 60, 14)
    engine = DeltaBigJoin(q, edges, cfg=CFG)
    store = engine.store
    rng = np.random.default_rng(15)
    upd, w = random_batch(rng, 12, edges, 10)
    before = store.stats.mirror_pulls
    engine.apply(upd, w)
    assert store.stats.mirror_pulls == before
    _ = engine.edges  # explicit debug pull
    assert store.stats.mirror_pulls == before + 1
    _ = engine.edges  # cached until the next commit
    assert store.stats.mirror_pulls == before + 1


def test_sharded_live_lsm_memory_linearity():
    """The store-level live-edge LSM shards like the projections: every
    packed key owned by exactly one worker, shard sizes summing to |E| —
    no O(|E|) array on a single worker."""
    w = 4
    edges = _start_edges(20, 120, 18)
    store = RegionStore(edges, shard_w=w)
    store.ensure("edge", (0,), 1)
    rng = np.random.default_rng(19)
    cur = edges.copy()
    for _ in range(6):
        upd, wts = random_batch(rng, 20, cur, 10)
        ins, dels = store.normalize(upd, wts)
        if ins.size or dels.size:
            store.begin_epoch(ins, dels)
            store.commit(ins, dels)
        cur = apply_net(cur, upd, wts)
        np.testing.assert_array_equal(store.edges, cur)
        total = 0
        for region in (store._lb, store._lc_ins, store._lc_del):
            ns = np.asarray(region.n)
            assert ns.shape == (w,)
            for k in range(w):
                keys = np.asarray(region.key)[k][:ns[k]]
                assert (csr.shard_of(keys, w) == k).all()
            total += int(ns.sum())
        # base + cins - cdel == |live| (cancellation keeps regions disjoint)
        nb, nci, ncd = (int(np.asarray(n).sum()) for n in store._n_live)
        assert nb + nci - ncd == cur.shape[0]
        assert total == nb + nci + ncd


def test_large_vertex_ids_roundtrip_device_store():
    """Packed keys of edges with src >= 2^30 approach int64-max; the int64
    sentinel must stay strictly above ALL of them (regression: a 2^62
    sentinel silently classified such edges as padding)."""
    big = 1 << 30
    edges = np.array([[big, 5], [big + 7, big + 9], [2, 3]], np.int32)
    dev = RegionStore(edges, device_resident=True)
    leg = RegionStore(edges, device_resident=False)
    upd = np.array([[big, 6], [big, 5], [big + 7, big + 9]], np.int32)
    w = np.array([1, -1, -1], np.int32)
    di, dd = dev.normalize(upd, w)
    li, ld = leg.normalize(upd, w)
    np.testing.assert_array_equal(di, li)
    np.testing.assert_array_equal(dd, ld)
    assert di.shape[0] == 1 and dd.shape[0] == 2  # not silently dropped
    for store in (dev, leg):
        store.begin_epoch(di, dd)
        store.commit(di, dd)
    np.testing.assert_array_equal(dev.edges, leg.edges)
    assert (dev.edges == np.array([[2, 3], [big, 6]], np.int32)).all()


def test_legacy_commit_tolerates_absent_deletes():
    """Raw commit() with a delete of an absent edge must not positionally
    remove a different live edge (regression: np.delete on unverified
    searchsorted positions)."""
    edges = np.array([[2, 3], [5, 6]], np.int32)
    for device in (True, False):
        store = RegionStore(edges, device_resident=device)
        absent = np.array([[2, 4]], np.int32)
        store.begin_epoch(absent[:0], absent)
        store.commit(absent[:0], absent)
        np.testing.assert_array_equal(store.edges, edges)


def test_raw_commit_without_begin_epoch_stays_consistent():
    """commit() without a prior begin_epoch must self-stage, so projections
    and the live LSM fold the same batch in both store modes."""
    edges = _start_edges(12, 50, 21)
    ins = np.array([[200, 201]], np.int32)
    dels = edges[:1].copy()
    for device in (True, False):
        store = RegionStore(edges, device_resident=device)
        store.ensure("edge", (0,), 1)
        store.commit(ins, dels)  # raw: no begin_epoch
        want = np.unique(np.concatenate(
            [edges[1:], ins]), axis=0)
        np.testing.assert_array_equal(store.edges, want)
        reg = next(iter(store.projections.values()))
        committed = (reg.base.shape[0] + reg.cins.shape[0]
                     - reg.cdel.shape[0])
        assert committed == want.shape[0]  # projections saw the same batch
        # a raw "insert" of an already-live edge must net out, not
        # duplicate rows (legacy) or poison cins ∩ base = ∅ (device)
        store.commit(want[:1].copy(), want[:0])
        np.testing.assert_array_equal(store.edges, want)
        store._maybe_compact(force=True)  # invariant audit must hold
        np.testing.assert_array_equal(store.edges, want)


def test_projection_ensured_mid_epoch_sees_staged_batch():
    """ensure() between begin_epoch and commit must stage the open batch on
    the new projection, or the commit fold would lose the epoch's delta
    (the legacy path folds the args and was already correct)."""
    edges = _start_edges(12, 50, 20)
    ins = np.array([[100, 101], [102, 103]], np.int32)
    dels = edges[:2].copy()
    for device in (True, False):
        store = RegionStore(edges, device_resident=device)
        store.ensure("edge", (0,), 1)
        i, d = store.normalize(
            np.concatenate([ins, dels]),
            np.concatenate([np.ones(2, np.int32), -np.ones(2, np.int32)]))
        store.begin_epoch(i, d)
        late = store.ensure("edge", (1,), 0)  # mid-epoch registration
        # the staged batch is visible through the "new" version already
        assert int(np.asarray(late.d_uins.n).sum()) == ins.shape[0]
        store.commit(i, d)
        want = apply_net(edges, np.concatenate([ins, dels]),
                         np.concatenate([np.ones(2, np.int32),
                                         -np.ones(2, np.int32)]))
        np.testing.assert_array_equal(store.edges, want)
        # the late projection's committed regions caught the delta
        assert sorted(map(tuple, late.cins.tolist())) == \
            sorted(map(tuple, ins.tolist()))
        assert sorted(map(tuple, late.cdel.tolist())) == \
            sorted(map(tuple, dels.tolist()))


def test_legacy_normalize_uses_packed_cache(monkeypatch):
    """Satellite: the host fallback probes the incrementally-maintained
    sorted packed cache — _pack2 is never re-run over the full edge set."""
    edges = _start_edges(40, 500, 16)
    store = RegionStore(edges, device_resident=False)
    store.ensure("edge", (0,), 1)
    sizes = []
    real = D._pack2

    def spy(a, b):
        sizes.append(np.asarray(a).shape[0])
        return real(a, b)

    rng = np.random.default_rng(17)
    cur = store.edges.copy()
    for _ in range(4):
        upd, w = random_batch(rng, 40, cur, 12)
        monkeypatch.setattr(D, "_pack2", spy)  # spy normalize only: the
        ins, dels = store.normalize(upd, w)    # legacy COMMIT still probes
        monkeypatch.setattr(D, "_pack2", real)  # base (that's why the
        if ins.size or dels.size:               # device store exists)
            store.begin_epoch(ins, dels)
            store.commit(ins, dels)
        cur = apply_net(cur, upd, w)
    assert sizes and max(sizes) <= 40  # batch-sized packs only
    # the cache tracks the live set exactly
    np.testing.assert_array_equal(
        store._packed_live,
        np.sort(real(store.edges[:, 0], store.edges[:, 1])))
    np.testing.assert_array_equal(store.edges, cur)
