"""Optimizer, checkpointing (incl. crash/corruption recovery), data,
sampler, sharding rules, compressed collectives."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.optim import adamw_init, adamw_update, cosine_decay


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray(np.ones(8, np.float32) * 5.0)}
    state = adamw_init(params)
    target = jnp.arange(8, dtype=jnp.float32)

    @jax.jit
    def step(params, state):
        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)
        g = jax.grad(loss)(params)
        p2, s2, gn = adamw_update(params, g, state, lr=0.3,
                                  weight_decay=0.0)
        return p2, s2
    for _ in range(300):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_weight_decay_mask():
    params = {"dense": {"kernel": jnp.ones((4, 4)), "bias": jnp.ones(4)}}
    g = jax.tree.map(jnp.zeros_like, params)
    state = adamw_init(params)
    p2, _, _ = adamw_update(params, g, state, lr=1.0, weight_decay=0.5)
    # matrices decay, vectors don't (default mask = ndim >= 2)
    assert float(p2["dense"]["kernel"][0, 0]) < 1.0
    assert float(p2["dense"]["bias"][0]) == 1.0


def test_cosine_schedule_shape():
    sched = cosine_decay(1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(sched(jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[2] > lrs[3] > lrs[4] >= 1e-4 - 1e-9


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "n": jnp.asarray(7, jnp.int32)}}
    path = save_pytree(tree, str(tmp_path), step=3, extra={"loss": 1.5})
    restored, manifest = load_pytree(tree, path)
    assert manifest["extra"]["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_resume_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"w": jnp.zeros(4)}
    for s in [1, 2, 3, 4]:
        mgr.save({"w": jnp.full(4, float(s))}, s)
    assert mgr.all_steps() == [3, 4]
    restored, manifest = mgr.restore_latest(tree)
    assert manifest["step"] == 4
    assert float(restored["w"][0]) == 4.0


def test_checkpoint_crash_recovery(tmp_path):
    """A torn write (missing manifest) must be skipped on resume."""
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    tree = {"w": jnp.zeros(2)}
    mgr.save({"w": jnp.full(2, 1.0)}, 1)
    # simulate a crash mid-write at step 2: files but no manifest
    broken = os.path.join(str(tmp_path), "ckpt_0000000002")
    os.makedirs(broken)
    with open(os.path.join(broken, "leaf_00000.npy"), "wb") as f:
        f.write(b"garbage")
    restored, manifest = mgr.restore_latest(tree)
    assert manifest["step"] == 1
    # corrupt checksum case
    mgr.save({"w": jnp.full(2, 3.0)}, 3)
    leaf = os.path.join(str(tmp_path), "ckpt_0000000003", "leaf_00000.npy")
    arr = np.load(leaf)
    np.save(leaf, arr + 1.0)  # bytes changed, manifest sha now stale
    restored, manifest = mgr.restore_latest(tree)
    assert manifest["step"] == 1  # fell back past the corrupted one


def test_checkpoint_elastic_resharding(tmp_path):
    """Checkpoint written unsharded restores under a different sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    path = save_pytree(tree, str(tmp_path), step=1)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = load_pytree(tree, path, shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_token_stream_deterministic_and_sharded():
    from repro.data import TokenStream
    a = TokenStream(100, 4, 16, seed=1, shard=0, num_shards=2)
    b = TokenStream(100, 4, 16, seed=1, shard=1, num_shards=2)
    np.testing.assert_array_equal(a.batch_at(5), a.batch_at(5))
    assert not np.array_equal(a.batch_at(5), b.batch_at(5))
    assert a.batch_at(0).shape == (4, 17)
    assert a.batch_at(0).max() < 100


def test_rmat_graph_skew():
    from repro.data import rmat_graph
    e = rmat_graph(10, 8, seed=0)
    deg = np.bincount(e[:, 0], minlength=1 << 10)
    # R-MAT must be heavy-tailed: max degree >> mean degree
    assert deg.max() > 10 * max(deg.mean(), 1)


def test_neighbor_sampler_fanout_and_validity():
    from repro.data import NeighborSampler, uniform_graph
    e = uniform_graph(200, 3000, seed=0)
    s = NeighborSampler(e, 200)
    rng = np.random.default_rng(0)
    nodes = np.arange(50)
    src, dst = s.sample_neighbors(nodes, 5, rng)
    assert len(src) <= 50 * 5
    edge_set = {(int(a), int(b)) for a, b in e}
    for a, b in zip(src, dst):
        assert (int(a), int(b)) in edge_set
    blocks = s.sample_blocks(np.arange(10), [5, 3], seed=1)
    assert len(blocks) == 2
    for blk in blocks:
        assert blk.edge_src.max(initial=-1) < len(blk.src_nodes)
        assert blk.edge_dst.max(initial=-1) < len(blk.dst_nodes)


def test_sharding_rules_drop_missing_axes():
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed import ShardingRules
    rules = ShardingRules.default()
    mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                 ("data", "model"))
    spec = rules.physical(("batch", "seq", "embed"), mesh1)
    assert spec == P(("data",), None, None)  # "pod" dropped on 2D mesh
    spec2 = rules.physical(("batch", "mlp"), mesh1)
    assert spec2 == P(("data",), "model")


def test_quantize_roundtrip_and_error_feedback():
    from repro.distributed.collectives import (dequantize_int8,
                                               quantize_int8)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) * 0.5 + 1e-6

    # error feedback: mean of compressed psums over steps converges to truth
    from repro.distributed.collectives import compressed_psum
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    @jax.jit
    def run(g, res):
        return shard_map(
            lambda g, r: compressed_psum(g, r, "dp"), mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False)(g, res)

    res = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    for _ in range(50):
        out, res = run(x, res)
        acc = acc + out
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(x),
                               atol=float(s))


def test_motif_features_match_oracle():
    from repro.core import query as Q
    from repro.core.csr import Graph
    from repro.core.generic_join import generic_join
    from repro.data.motifs import motif_counts
    from repro.data.synthetic import uniform_graph
    g = Graph.from_edges(uniform_graph(60, 600, seed=2), 60)
    counts = motif_counts(g, "triangle")
    tri, _ = generic_join(Q.triangle(symmetric=True),
                          {Q.EDGE: g.degree_relabel().edges})
    assert counts.sum() == tri.shape[0] * 3
