"""Fused extension pipeline: multi-region membership parity, single-launch
fusion accounting, and fused-extend-step vs the serial GJ oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, build_step,
                                run_bigjoin, seed_tuples_for)
from repro.core.csr import build_index, empty_index
from repro.core.dataflow_index import VersionedIndex
from repro.core.generic_join import generic_join
from repro.core.plan import make_plan

from tests.test_generic_join import random_graph


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

from repro.kernels import count_pallas_calls  # noqa: E402


def random_versioned(rng, n_base=400, n_delta=60, nv=80):
    """A VersionedIndex with a randomized insert/delete region mix
    (pos = base/cins/uins, neg = cdel/udel) over single-column keys."""
    def edges(n):
        return rng.integers(0, nv, size=(max(n, 1), 2)).astype(np.int32)

    base = build_index(edges(n_base), (0,), 1, capacity=n_base + 17)
    cins = build_index(edges(n_delta), (0,), 1)
    uins = build_index(edges(n_delta // 2), (0,), 1)
    cdel = build_index(edges(n_delta // 2), (0,), 1)
    udel = build_index(edges(n_delta // 3), (0,), 1)
    return VersionedIndex((base, cins, uins), (cdel, udel))


# ---------------------------------------------------------------------------
# multi-region membership kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_multi_region_member_parity(seed):
    rng = np.random.default_rng(seed)
    idx = random_versioned(rng)
    B = 300
    qk = jnp.asarray(rng.integers(0, 80, B).astype(np.int32))
    qv = jnp.asarray(rng.integers(0, 80, B).astype(np.int32))
    ref_m = np.asarray(idx.member(qk, qv, use_kernel=False))
    ref_d = np.asarray(idx.deleted(qk, qv, use_kernel=False))
    got_m, got_d = idx.signed_member(qk, qv, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got_m), ref_m)
    np.testing.assert_array_equal(np.asarray(got_d), ref_d)
    np.testing.assert_array_equal(
        np.asarray(idx.member(qk, qv, use_kernel=True)), ref_m)
    np.testing.assert_array_equal(
        np.asarray(idx.deleted(qk, qv, use_kernel=True)), ref_d)


def test_multi_region_member_mixed_empty_regions():
    rng = np.random.default_rng(7)
    base = build_index(rng.integers(0, 30, (200, 2)).astype(np.int32),
                       (0,), 1)
    idx = VersionedIndex((base, empty_index(4)), (empty_index(2),))
    qk = jnp.asarray(rng.integers(0, 30, 64).astype(np.int32))
    qv = jnp.asarray(rng.integers(0, 30, 64).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(idx.member(qk, qv, use_kernel=True)),
        np.asarray(idx.member(qk, qv, use_kernel=False)))


def test_multi_region_member_is_single_launch():
    """R regions -> exactly ONE pallas_call (the per-region path would
    launch R; the fusion must save >= 1 launch whenever R > 1)."""
    rng = np.random.default_rng(3)
    idx = random_versioned(rng)
    R = len(idx.pos) + len(idx.neg)
    assert R > 1
    qk = jnp.zeros(64, jnp.int32)
    qv = jnp.zeros(64, jnp.int32)
    n = count_pallas_calls(
        lambda a, b: idx.member(a, b, use_kernel=True), qk, qv)
    assert n == 1  # saved R - 1 launches


# ---------------------------------------------------------------------------
# fused extend step vs serial GJ oracle
# ---------------------------------------------------------------------------

MOTIFS = [Q.triangle(), Q.four_clique(), Q.diamond()]


@pytest.mark.parametrize("q", MOTIFS, ids=lambda q: q.name)
def test_fused_extend_matches_oracle(q):
    g = random_graph(45, 420, 11)
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    idx = build_indices(plan, rels)
    cfg = BigJoinConfig(batch=256, seed_chunk=128, out_capacity=1 << 16,
                        use_kernel=True)
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
    ref, ref_cnt = generic_join(q, rels, plan=plan)
    assert res.count == ref_cnt
    if ref_cnt:
        np.testing.assert_array_equal(
            np.unique(res.tuples, axis=0), np.unique(ref, axis=0))


@pytest.mark.parametrize("q", MOTIFS, ids=lambda q: q.name)
def test_fused_step_bitexact_vs_jnp_step(q):
    """The fused kernel middle must reproduce the jnp stage sequence
    bit-for-bit: identical output tuples AND identical work counters."""
    g = random_graph(40, 380, 5)
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    idx = build_indices(plan, rels)
    kw = dict(batch=128, seed_chunk=64, out_capacity=1 << 16)
    a = run_bigjoin(plan, idx, seed_tuples_for(plan, rels),
                    cfg=BigJoinConfig(use_kernel=True, **kw))
    b = run_bigjoin(plan, idx, seed_tuples_for(plan, rels),
                    cfg=BigJoinConfig(use_kernel=False, **kw))
    assert a.count == b.count
    assert a.proposals == b.proposals
    assert a.intersections == b.intersections
    assert a.steps == b.steps
    np.testing.assert_array_equal(a.tuples, b.tuples)


def test_fused_level_branch_is_single_launch():
    """Each extension-level branch of the dataflow step lowers to exactly
    one pallas_call: no proposal round-trips through HBM between stages."""
    q = Q.four_clique()
    g = random_graph(30, 250, 9)
    plan = make_plan(q)
    idx = build_indices(plan, {Q.EDGE: g.edges})
    cfg = BigJoinConfig(batch=128, seed_chunk=64, mode="count",
                        use_kernel=True)
    from repro.core.bigjoin import make_state
    step = build_step(plan, cfg)
    state = make_state(plan, cfg)
    n = count_pallas_calls(step, state, idx)
    assert n == len(plan.levels)  # one fused launch per level branch


# ---------------------------------------------------------------------------
# _NpIndex wide-key fallback (satellite: no Python-set probes)
# ---------------------------------------------------------------------------

def test_npindex_wide_key_fallback_vectorized():
    from repro.core.generic_join import _NpIndex
    rng = np.random.default_rng(0)
    # two key columns -> packed keys >= 2^31: the non-packed path
    tuples = np.stack([rng.integers(0, 2**20, 500),
                       rng.integers(0, 2**20, 500),
                       rng.integers(0, 100, 500)], axis=1)
    idx = _NpIndex(tuples, (0, 1), 2)
    assert idx._packed is None
    key = (tuples[:, 0].astype(np.int64) << 32) | tuples[:, 1]
    qk = np.concatenate([key[:50], key[:50] + 1])
    qv = np.concatenate([tuples[:50, 2], tuples[:50, 2]])
    got = idx.member(qk, qv.astype(np.int64))
    truth = {(int(k), int(v)) for k, v in zip(key, tuples[:, 2])}
    exp = np.array([(int(a), int(b)) in truth for a, b in zip(qk, qv)])
    np.testing.assert_array_equal(got, exp)
