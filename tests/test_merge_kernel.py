"""Merge rank kernel (kernels/merge): bit-exact parity with the jnp oracle
and single-launch structure.  The kernel computes, per query, the count of
index entries lexicographically < / <= it — the whole of a sorted
merge/diff/intersect reduces to this one pass plus a scatter (merge.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import csr
from repro.kernels import count_pallas_calls
from repro.kernels.merge.merge import rank_counts
from repro.kernels.merge.ref import rank_ref


def _index(rng, n, narrow, hi=60):
    t = rng.integers(0, hi, (n, 2)).astype(np.int32)
    return csr.build_index(t, (0,), 1, narrow=narrow)


@pytest.mark.parametrize("narrow", [True, False], ids=["i32", "i64"])
@pytest.mark.parametrize("n", [0, 1, 50, 300])
def test_rank_kernel_matches_ref(narrow, n):
    rng = np.random.default_rng(n + narrow)
    idx = _index(rng, n, narrow)
    B = 97  # deliberately not a BQ multiple: exercises query padding
    qk = jnp.asarray(rng.integers(0, 70, B).astype(np.int32)
                     ).astype(idx.key.dtype)
    qv = jnp.asarray(rng.integers(0, 70, B).astype(np.int32))
    lt_r, le_r = rank_ref(idx.key, idx.val, idx.n, qk, qv)
    lt_k, le_k = rank_counts(idx.key, idx.val, idx.n, qk, qv,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(lt_r), np.asarray(lt_k))
    np.testing.assert_array_equal(np.asarray(le_r), np.asarray(le_k))
    # ranks encode membership: le > lt  <=>  (qk, qv) in the index
    member = np.asarray(csr.index_member(idx, qk, qv))
    np.testing.assert_array_equal(np.asarray(le_k) > np.asarray(lt_k),
                                  member)


def test_rank_kernel_is_single_launch():
    rng = np.random.default_rng(7)
    idx = _index(rng, 200, True)
    qk = jnp.asarray(rng.integers(0, 70, 64).astype(np.int32))
    qv = jnp.asarray(rng.integers(0, 70, 64).astype(np.int32))
    calls = count_pallas_calls(
        lambda k, v, n, a, b: rank_counts(k, v, n, a, b, interpret=True),
        idx.key, idx.val, idx.n, qk, qv)
    assert calls == 1


@pytest.mark.parametrize("nk", [3, 4])
@pytest.mark.parametrize("n", [0, 1, 40, 250])
def test_rank_kernel_composite_matches_ref(nk, n):
    """Composite (hi, lo) keys: 3-word lex ranks, kernel == jnp oracle.
    nk=3 builds a narrow int32 hi word, nk=4 a full int64 pair."""
    rng = np.random.default_rng(100 + nk + n)
    t = rng.integers(0, 25, (n, nk + 1)).astype(np.int32)
    idx = csr.build_index(t, tuple(range(nk)), nk)
    assert idx.composite
    assert idx.key.dtype == (jnp.int32 if csr.single_word_hi(nk)
                             else jnp.int64)
    B = 97
    probes = rng.integers(0, 30, (B, nk + 1)).astype(np.int32)
    qh, ql = csr.pack_key(tuple(probes[:, i] for i in range(nk)))
    qh, ql = jnp.asarray(qh), jnp.asarray(ql)
    qv = jnp.asarray(probes[:, nk])
    lt_r, le_r = rank_ref(idx.key, idx.val, idx.n, qh, qv,
                          lo=idx.lo, qlo=ql)
    lt_k, le_k = rank_counts(idx.key, idx.val, idx.n, qh, qv,
                             interpret=True, lo=idx.lo, qlo=ql)
    np.testing.assert_array_equal(np.asarray(lt_r), np.asarray(lt_k))
    np.testing.assert_array_equal(np.asarray(le_r), np.asarray(le_k))
    member = np.asarray(csr.index_member(idx, (qh, ql), qv))
    np.testing.assert_array_equal(np.asarray(le_k) > np.asarray(lt_k),
                                  member)


def test_rank_kernel_narrow_promote_resentinels_padding():
    """int32 index probed with int64 queries above SENTINEL32: the widened
    padding must still sort above every query or the router walks into it."""
    rng = np.random.default_rng(9)
    idx = _index(rng, 60, True)  # narrow, padding = SENTINEL32
    big = np.int64(csr.SENTINEL32) + np.int64(5)
    qk = jnp.asarray(np.array([0, 10, big, csr.SENTINEL - 1], np.int64))
    qv = jnp.asarray(np.array([1, 1, 1, 1], np.int32))
    lt_r, le_r = rank_ref(idx.key, idx.val, idx.n, qk, qv)
    lt_k, le_k = rank_counts(idx.key, idx.val, idx.n, qk, qv,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(lt_r), np.asarray(lt_k))
    np.testing.assert_array_equal(np.asarray(le_r), np.asarray(le_k))
    # queries above every live key rank at exactly n, not into the padding
    assert int(np.asarray(lt_k)[2]) == int(idx.n)


def test_rank_kernel_composite_is_single_launch():
    rng = np.random.default_rng(11)
    t = rng.integers(0, 25, (150, 4)).astype(np.int32)
    idx = csr.build_index(t, (0, 1, 2), 3)
    probes = rng.integers(0, 25, (64, 4)).astype(np.int32)
    qh, ql = csr.pack_key(tuple(probes[:, i] for i in range(3)))
    calls = count_pallas_calls(
        lambda k, l, v, n, a, b, c: rank_counts(
            k, v, n, a, c, interpret=True, lo=l, qlo=b),
        idx.key, idx.lo, idx.val, idx.n,
        jnp.asarray(qh), jnp.asarray(ql), jnp.asarray(probes[:, 3]))
    assert calls == 1


def test_merge_fold_through_kernel_matches_jnp():
    """csr.merge_index(use_kernel=True) (interpret) == the jnp rank path."""
    rng = np.random.default_rng(8)
    a = _index(rng, 120, True)
    b = _index(rng, 40, True)
    import repro.kernels.merge.ops as ops
    real = ops.rank_lt_le
    try:
        # force the interpreted kernel for the routed path
        ops.rank_lt_le = lambda *args: real(*args, interpret=True)
        m_k = csr.merge_index(a, b, 512, use_kernel=True)
    finally:
        ops.rank_lt_le = real
    m_j = csr.merge_index(a, b, 512, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(m_k.key), np.asarray(m_j.key))
    np.testing.assert_array_equal(np.asarray(m_k.val), np.asarray(m_j.val))
    assert int(m_k.n) == int(m_j.n)


# ---------------------------------------------------------------------------
# fused commit fold: ONE pallas_call per relation == the five-stage chain
# ---------------------------------------------------------------------------

from repro.core import delta as D  # noqa: E402


def _regions(rng, arity, shard_w, sizes=(120, 30, 20, 25, 15)):
    """(base, cins, cdel, uins, udel) random packed regions, one dtype."""
    def mk(n, cap):
        rows = rng.integers(0, 30, (n, arity)).astype(np.int32)
        rows = np.unique(rows, axis=0)
        return D._packed_index(rows, shard_w, arity, capacity=cap)
    nb, nci, ncd, nui, nud = sizes
    return (mk(nb, 256), mk(nci, 128), mk(ncd, 128),
            mk(nui, 64), mk(nud, 64))


def _assert_index_equal(a, b):
    assert a.key.dtype == b.key.dtype
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))
    np.testing.assert_array_equal(np.asarray(a.val), np.asarray(b.val))
    np.testing.assert_array_equal(np.asarray(a.n), np.asarray(b.n))
    assert (a.lo is None) == (b.lo is None)
    if a.lo is not None:
        np.testing.assert_array_equal(np.asarray(a.lo), np.asarray(b.lo))


@pytest.mark.parametrize("shard_w", [0, 4], ids=["local", "w4"])
@pytest.mark.parametrize("arity", [2, 3, 4])
def test_fused_commit_fold_matches_chain(arity, shard_w):
    """use_kernel=True (fused pallas fold) == use_kernel=False (jnp chain)
    bit-exactly over every key layout: int64 single word (arity 2), narrow
    int32 hi composite (arity 3), int64 pair composite (arity 4)."""
    rng = np.random.default_rng(40 + arity + shard_w)
    for trial in range(3):
        ba, ci, cd, ui, ud = _regions(rng, arity, shard_w)
        kw = dict(cins_cap=256, cdel_cap=256, sharded=bool(shard_w))
        k_ci, k_cd = D._commit_fold_impl(ba, ci, cd, ui, ud,
                                         use_kernel=True, **kw)
        j_ci, j_cd = D._commit_fold_impl(ba, ci, cd, ui, ud,
                                         use_kernel=False, **kw)
        _assert_index_equal(k_ci, j_ci)
        _assert_index_equal(k_cd, j_cd)


def test_fused_commit_fold_empty_regions():
    """Zero-filled prototypes (the AOT prewarm inputs) run the fused fold
    without error and produce empty outputs."""
    for arity in (2, 3, 4):
        empty = np.zeros((0, arity), np.int32)
        ba = D._packed_index(empty, 0, arity, capacity=256)
        ci = D._packed_index(empty, 0, arity, capacity=128)
        ui = D._packed_index(empty, 0, arity, capacity=64)
        k_ci, k_cd = D._commit_fold_impl(
            ba, ci, ci, ui, ui, cins_cap=256, cdel_cap=256,
            sharded=False, use_kernel=True)
        assert int(k_ci.n) == 0 and int(k_cd.n) == 0


@pytest.mark.parametrize("arity", [2, 3, 4])
def test_fused_commit_fold_is_one_launch(arity):
    """The whole commit fold — both outputs — is ONE pallas_call; only the
    delta-sized udel ∩ base rank probe stays outside the kernel."""
    rng = np.random.default_rng(50 + arity)
    ba, ci, cd, ui, ud = _regions(rng, arity, 0)
    calls = count_pallas_calls(
        lambda *r: D._commit_fold_impl(
            *r, cins_cap=256, cdel_cap=256, sharded=False,
            use_kernel=True),
        ba, ci, cd, ui, ud)
    assert calls == 1
