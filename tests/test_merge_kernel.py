"""Merge rank kernel (kernels/merge): bit-exact parity with the jnp oracle
and single-launch structure.  The kernel computes, per query, the count of
index entries lexicographically < / <= it — the whole of a sorted
merge/diff/intersect reduces to this one pass plus a scatter (merge.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import csr
from repro.kernels import count_pallas_calls
from repro.kernels.merge.merge import rank_counts
from repro.kernels.merge.ref import rank_ref


def _index(rng, n, narrow, hi=60):
    t = rng.integers(0, hi, (n, 2)).astype(np.int32)
    return csr.build_index(t, (0,), 1, narrow=narrow)


@pytest.mark.parametrize("narrow", [True, False], ids=["i32", "i64"])
@pytest.mark.parametrize("n", [0, 1, 50, 300])
def test_rank_kernel_matches_ref(narrow, n):
    rng = np.random.default_rng(n + narrow)
    idx = _index(rng, n, narrow)
    B = 97  # deliberately not a BQ multiple: exercises query padding
    qk = jnp.asarray(rng.integers(0, 70, B).astype(np.int32)
                     ).astype(idx.key.dtype)
    qv = jnp.asarray(rng.integers(0, 70, B).astype(np.int32))
    lt_r, le_r = rank_ref(idx.key, idx.val, idx.n, qk, qv)
    lt_k, le_k = rank_counts(idx.key, idx.val, idx.n, qk, qv,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(lt_r), np.asarray(lt_k))
    np.testing.assert_array_equal(np.asarray(le_r), np.asarray(le_k))
    # ranks encode membership: le > lt  <=>  (qk, qv) in the index
    member = np.asarray(csr.index_member(idx, qk, qv))
    np.testing.assert_array_equal(np.asarray(le_k) > np.asarray(lt_k),
                                  member)


def test_rank_kernel_is_single_launch():
    rng = np.random.default_rng(7)
    idx = _index(rng, 200, True)
    qk = jnp.asarray(rng.integers(0, 70, 64).astype(np.int32))
    qv = jnp.asarray(rng.integers(0, 70, 64).astype(np.int32))
    calls = count_pallas_calls(
        lambda k, v, n, a, b: rank_counts(k, v, n, a, b, interpret=True),
        idx.key, idx.val, idx.n, qk, qv)
    assert calls == 1


def test_merge_fold_through_kernel_matches_jnp():
    """csr.merge_index(use_kernel=True) (interpret) == the jnp rank path."""
    rng = np.random.default_rng(8)
    a = _index(rng, 120, True)
    b = _index(rng, 40, True)
    import repro.kernels.merge.ops as ops
    real = ops.rank_lt_le
    try:
        # force the interpreted kernel for the routed path
        ops.rank_lt_le = lambda *args: real(*args, interpret=True)
        m_k = csr.merge_index(a, b, 512, use_kernel=True)
    finally:
        ops.rank_lt_le = real
    m_j = csr.merge_index(a, b, 512, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(m_k.key), np.asarray(m_j.key))
    np.testing.assert_array_equal(np.asarray(m_k.val), np.asarray(m_j.val))
    assert int(m_k.n) == int(m_j.n)
