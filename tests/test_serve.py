"""Serving subsystem tests (DESIGN.md §9): snapshot/restore round-trips,
WAL durability, SessionPool multi-tenant exactness, backpressure, and the
kill/replay failover differential (subprocess, 4-worker mesh variants ride
``repro.serve._serve_check``)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import GraphSession, canon_signed as canon
from repro.core.delta import RegionStore
from repro.data.synthetic import EdgeUpdateStream, uniform_graph
from repro.serve import Durability, SessionPool, WriteAheadLog, percentiles


def _drive(store, stream, steps, start=0, live=None):
    live = store.edges if live is None else live
    for step in range(start, start + steps):
        upd, w = stream.batch_at(step, live=live)
        ins, dels = store.normalize(upd, w)
        if ins.size or dels.size:
            store.begin_epoch(ins, dels)
            store.commit(ins, dels)
        live = store.edges
    return live


# -- WAL ----------------------------------------------------------------


def test_wal_roundtrip_truncate_torn(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path, fsync=False)
    rng = np.random.default_rng(0)
    recs = {}
    for epoch in range(1, 6):
        rows = rng.integers(0, 50, (8, 2)).astype(np.int32)
        w = rng.choice([-1, 1], 8).astype(np.int32)
        recs[epoch] = (rows, w)
        wal.append(epoch, {"edge": (rows, w)})

    replayed = list(wal.replay())
    assert [e for e, _ in replayed] == [1, 2, 3, 4, 5]
    for epoch, batches in replayed:
        rows, w = recs[epoch]
        assert np.array_equal(batches["edge"][0], rows)
        assert np.array_equal(batches["edge"][1], w)

    # truncation drops the snapshotted prefix, keeps the tail byte-exact
    wal.truncate_through(3)
    assert [e for e, _ in wal.replay()] == [4, 5]
    assert wal.num_records() == 2

    # a torn tail (crash mid-append) silently ends replay at the tear
    wal.close()
    with open(path, "ab") as f:
        f.write(b'{"b": "{\\"e\\": 6')  # half-written record
    wal2 = WriteAheadLog(path, fsync=False)
    assert [e for e, _ in wal2.replay()] == [4, 5]
    # a corrupt CRC also stops replay (and hides later records)
    wal2.close()


# -- RegionStore snapshot/restore ---------------------------------------


@pytest.mark.parametrize("compact_ratio", [0.5, 0.05])
def test_store_snapshot_restore_roundtrip(compact_ratio):
    """Round-trip mid-stream — with ``compact_ratio=0.05`` several
    compactions have happened before the snapshot, so base regions carry
    rewritten capacities and committed marks were reset."""
    edges = uniform_graph(40, 300, seed=1)
    store = RegionStore(edges, compact_ratio=compact_ratio)
    store.ensure("edge", (0,), 1)
    store.ensure("edge", (1,), 0)
    stream = EdgeUpdateStream(40, 24, insert_frac=0.5, seed=2)
    _drive(store, stream, 8)
    leaves, meta = store.snapshot()
    assert json.loads(json.dumps(meta)) == meta  # checkpoint-safe meta

    twin = RegionStore(edges, compact_ratio=compact_ratio)
    twin.ensure("edge", (0,), 1)
    twin.ensure("edge", (1,), 0)
    twin.restore(leaves, meta)
    assert np.array_equal(twin.edges, store.edges)
    assert twin.num_edges == store.num_edges

    # the restored store must CONTINUE bit-exactly, not just read back
    live_a = _drive(store, stream, 4, start=8)
    live_b = _drive(twin, stream, 4, start=8)
    assert np.array_equal(live_a, live_b)


def test_store_snapshot_requires_commit_boundary():
    edges = uniform_graph(30, 120, seed=3)
    store = RegionStore(edges)
    store.ensure("edge", (0,), 1)
    ins, dels = store.normalize(
        np.array([[1, 2], [3, 4]], np.int32), np.array([1, 1], np.int32))
    store.begin_epoch(ins, dels)
    with pytest.raises(RuntimeError):
        store.snapshot()  # uncommitted epoch staged
    store.commit(ins, dels)
    store.snapshot()


def test_session_snapshot_restore_nary_composite():
    """Session round-trip with a ternary relation + composite-key plans
    (4-clique-tri reads tri(a,b,c)): regions, ratchet marks, handle
    net_change and the epoch counter all survive, and the restored session
    serves bit-exact deltas afterwards."""
    edges = uniform_graph(20, 110, seed=4)
    sess = GraphSession(edges, local=True, update_batch=32)
    tri = sess.register("triangle")
    tri0, _ = tri.enumerate()
    sess.add_relation("tri", tri0)
    c4t = sess.register("4-clique-tri")
    stream = EdgeUpdateStream(20, 12, insert_frac=0.5, seed=5)
    live = sess.edges
    for step in range(4):
        upd, w = stream.batch_at(step, live=live)
        res = sess.update(upd, w)
        td = res.deltas["triangle"]
        t_upd = td.tuples if td.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = td.weights if td.weights is not None else \
            np.zeros(0, np.int32)
        sess.update({"tri": (t_upd, t_w)})
        live = res.advance(live)

    leaves, meta = sess.snapshot()
    fresh = GraphSession(edges, local=True, update_batch=32)
    fresh.restore(leaves, meta)
    assert fresh.epoch == sess.epoch
    assert np.array_equal(fresh.edges, sess.edges)
    assert np.array_equal(fresh.relation("tri"), sess.relation("tri"))
    assert set(fresh.handles) == {"triangle", "4-clique-tri"}
    assert fresh["4-clique-tri"].net_change == c4t.net_change

    # continue both sessions in lockstep: every delta must stay bit-exact
    for step in range(4, 7):
        upd, w = stream.batch_at(step, live=live)
        ra, rb = sess.update(upd, w), fresh.update(upd, w)
        for name in ("triangle", "4-clique-tri"):
            da, db = ra.deltas[name], rb.deltas[name]
            assert canon(da.tuples, da.weights) == \
                canon(db.tuples, db.weights)
        live = ra.advance(live)
    assert np.array_equal(fresh.edges, sess.edges)


def test_store_restore_rejects_mismatched_shape():
    edges = uniform_graph(30, 120, seed=6)
    sess = GraphSession(edges, local=True, update_batch=32)
    sess.register("triangle")
    leaves, meta = sess.snapshot()
    meta2 = json.loads(json.dumps(meta))
    meta2["session"]["w"] = 4
    with pytest.raises(ValueError):
        GraphSession(edges, local=True).restore(leaves, meta2)


# -- Durability: snapshot cadence + WAL replay --------------------------


def test_durability_recover_replays_wal(tmp_path):
    """Snapshot every 3 epochs, run 8: recovery = snapshot(6) + replay of
    epochs 7..8 from the WAL, landing bit-exact on the oracle state —
    including a record that was logged but never applied."""
    edges = uniform_graph(30, 150, seed=7)
    stream = EdgeUpdateStream(30, 16, insert_frac=0.5, seed=8)

    oracle = GraphSession(edges, local=True, update_batch=32)
    oracle.register("triangle")
    sess = GraphSession(edges, local=True, update_batch=32)
    sess.register("triangle")
    dur = Durability(str(tmp_path / "t0"), sess, snapshot_every=3,
                     fsync=False)
    live = oracle.edges
    for step in range(8):
        upd, w = stream.batch_at(step, live=live)
        res = oracle.update(upd, w)
        dur.log({"edge": (upd, w)})
        sess.update(upd, w)
        dur.maybe_snapshot()
        live = res.advance(live)
    assert dur.snapshots == 2  # epochs 3 and 6
    assert dur.wal.num_records() == 2  # 7, 8 survive truncation
    # epoch 9 is logged but the worker "dies" before applying it
    upd9, w9 = stream.batch_at(8, live=live)
    dur.log({"edge": (upd9, w9)})
    oracle.update(upd9, w9)

    fresh = GraphSession(edges, local=True, update_batch=32)
    fresh.register("triangle")
    dur2 = Durability(str(tmp_path / "t0"), fresh, snapshot_every=3,
                      fsync=False)
    assert dur2.recover()
    assert dur2.replayed == 3  # 7, 8 and the never-applied 9
    assert fresh.epoch == 9
    assert np.array_equal(fresh.edges, oracle.edges)
    assert fresh["triangle"].net_change == oracle["triangle"].net_change


# -- SessionPool --------------------------------------------------------


def test_pool_multi_tenant_bitexact():
    """Two tenants with different graphs/streams through one pipelined
    pool: every epoch's signed delta and the final state match isolated
    oracle sessions exactly."""
    graphs = {n: uniform_graph(24, 160, seed=i)
              for i, n in enumerate(["a", "b"])}
    streams = {n: EdgeUpdateStream(24, 16, insert_frac=0.5, seed=20 + i)
               for i, n in enumerate(["a", "b"])}
    oracles = {}
    for n, g in graphs.items():
        o = GraphSession(g, local=True, update_batch=64)
        o.register("triangle")
        oracles[n] = o
    with SessionPool(local=True, update_batch=64, prewarm=False) as pool:
        handles = {n: pool.admit(n, g, queries=("triangle",), coalesce=1)
                   for n, g in graphs.items()}
        lives = {n: np.asarray(h.session.edges)
                 for n, h in handles.items()}
        for step in range(6):
            tickets = {}
            for n in graphs:
                upd, w = streams[n].batch_at(step, live=lives[n])
                tickets[n] = (handles[n].submit(upd, w), upd, w)
            for n, (ticket, upd, w) in tickets.items():
                res = ticket.result(timeout=600)
                lives[n] = res.advance(lives[n])
                ores = oracles[n].update(upd, w)
                d, od = res.deltas["triangle"], ores.deltas["triangle"]
                assert canon(d.tuples, d.weights) == \
                    canon(od.tuples, od.weights)
        for n, h in handles.items():
            assert np.array_equal(h.session.edges, oracles[n].edges)
            assert h.session["triangle"].net_change == \
                oracles[n]["triangle"].net_change
        st = pool.stats()
        assert st.tenants["a"].retired == st.tenants["b"].retired == 6


def test_pool_coalescing_exact():
    """Queue 6 clean batches, pump once: adaptive coalescing folds them
    into fewer device epochs whose NET state matches applying the 6
    batches one-by-one.  Clean (sign-consistent) batches are the
    coalescing contract — for dirty batches (insert of a live edge in one
    batch, delete in the next) merged netting may differ from sequential
    application, which is why tenants that need per-batch set semantics
    serve with ``coalesce=1``."""
    from repro.data.synthetic import clean_update_batches
    g = uniform_graph(24, 160, seed=30)
    oracle = GraphSession(g, local=True, update_batch=256)
    oracle.register("triangle")
    pool = SessionPool(local=True, update_batch=256, prewarm=False,
                       pipeline=False)
    h = pool.admit("a", g, queries=("triangle",), coalesce=4)
    tickets = []
    for upd, w in clean_update_batches(g, 24, 16, 6, seed=31):
        oracle.update(upd, w)
        tickets.append(h.submit(upd, w))
    pool.pump()
    for t in tickets:
        assert t.done()
    assert np.array_equal(h.session.edges, oracle.edges)
    assert h.session["triangle"].net_change == \
        oracle["triangle"].net_change
    st = h.stats
    assert st.retired == 6
    assert st.epochs < 6  # coalescing actually folded batches
    assert st.coalesced_away == 6 - st.epochs
    pool.close()


def test_pool_backpressure_shed():
    """A full bounded ingest queue sheds non-blocking submits (counted,
    erroring nobody) instead of stalling the pool."""
    g = uniform_graph(24, 160, seed=40)
    pool = SessionPool(local=True, update_batch=64, prewarm=False,
                       pipeline=False)
    h = pool.admit("a", g, queries=("triangle",), max_queue=2, coalesce=1)
    upd = np.array([[1, 2], [3, 4]], np.int32)
    w = np.ones(2, np.int32)
    t1, t2 = h.submit(upd, w), h.submit(upd, w)
    assert t1 is not None and t2 is not None
    shed = h.submit(upd, w, block=False)
    assert shed is None
    assert h.submit(upd, w, timeout=0.05) is None  # timed block sheds too
    assert h.stats.shed == 2
    pool.pump()
    assert t1.done() and t2.done()
    assert h.stats.retired == 2
    pool.close()


def test_pool_bad_batch_fails_ticket_keeps_serving():
    g = uniform_graph(24, 160, seed=50)
    pool = SessionPool(local=True, update_batch=64, prewarm=False,
                       pipeline=False)
    h = pool.admit("a", g, queries=("triangle",), coalesce=1)
    bad = h.submit(np.zeros((2, 3), np.int32))  # arity mismatch
    pool.pump()
    with pytest.raises(Exception):
        bad.result(timeout=10)
    ok = h.submit(np.array([[1, 2]], np.int32))
    pool.pump()
    assert ok.result(timeout=600) is not None
    assert h.stats.failed == 1 and h.stats.retired == 1
    pool.close()


def test_percentiles_shape():
    p = percentiles([1.0, 2.0, 3.0, 4.0])
    assert set(p) == {"p50", "p95", "p99", "max", "p99_p50_ratio"}
    assert p["max"] == 4.0
    assert percentiles([])["p99"] == 0.0


# -- compile budget: cross-rung prewarm (PR 6 hole) ---------------------


@pytest.mark.slow
def test_prewarm_covers_mixed_rung_combos():
    """Multi-relation plans must be warmed over the CROSS-PRODUCT of the
    relations' committed ladders, not just the diagonal: here the ``tri``
    relation's committed region climbs rungs far faster than ``edge``'s
    (triangle deltas fan out), so warm epochs sit at MIXED rungs — with
    the old diagonal-only prewarm these signatures would compile
    mid-stream."""
    from repro.core import compilestats

    edges = uniform_graph(20, 110, seed=60)
    sess = GraphSession(edges, local=True, update_batch=64)
    tri = sess.register("triangle")
    tri0, _ = tri.enumerate()
    sess.add_relation("tri", tri0)
    sess.register("4-clique-tri")
    sess.prewarm(horizon=64 * 14)
    stream = EdgeUpdateStream(20, 16, insert_frac=0.5, seed=61)
    live = sess.edges
    warm_compiles = 0
    for step in range(12):
        upd, w = stream.batch_at(step, live=live)
        res = sess.update(upd, w)
        td = res.deltas["triangle"]
        t_upd = td.tuples if td.tuples is not None else \
            np.zeros((0, 3), np.int32)
        t_w = td.weights if td.weights is not None else \
            np.zeros(0, np.int32)
        res2 = sess.update({"tri": (t_upd, t_w)})
        warm_compiles += res.compile_events + res2.compile_events
        live = res.advance(live)
    assert warm_compiles == 0, \
        f"{warm_compiles} compile events leaked past the admission prewarm"


# -- failover: kill mid-stream, restore + replay (subprocess) -----------


def _run_check(extra, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    p = subprocess.run(
        [sys.executable, "-m", "repro.serve._serve_check"] + extra,
        capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, p.stdout + p.stderr
    return json.loads(p.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_serve_kill_replay_differential_local():
    """Mode B end-to-end: a serving process killed right after a WAL
    append (epoch logged, never applied) is restarted, restores the last
    snapshot, replays the log, finishes the stream — and lands bit-exact
    on the uninterrupted oracle run, with zero serving-path compiles in
    both surviving runs."""
    out = _run_check(["--supervise", "--local", "--tenants", "2",
                      "--workers", "1", "--epochs", "10", "--kill-at", "6",
                      "--snapshot-every", "3"])
    assert out["all_exact"]
    assert out["final_exact"] and out["tail_exact"]
    assert out["replayed"] > 0
    assert out["serve_compiles"] == [0, 0]


@pytest.mark.slow
def test_serve_pool_mesh_sharded():
    """Mode A on a forced 4-device host mesh under strict transfer
    guards: 4 tenants multiplexed on one mesh, per-epoch deltas bit-exact
    vs prewarmed isolated oracles, zero serving compiles."""
    env_extra = {"REPRO_STRICT_TRANSFERS": "1"}
    env = dict(os.environ)
    env.update(env_extra)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    p = subprocess.run(
        [sys.executable, "-m", "repro.serve._serve_check", "--tenants", "4",
         "--workers", "4", "--epochs", "8", "--no-fsync"],
        capture_output=True, text=True, timeout=1800, env=env)
    assert p.returncode == 0, p.stdout + p.stderr
    out = json.loads(p.stdout.strip().splitlines()[-1])
    assert out["oracle_exact"] and out["serve_compiles"] == 0
