"""Dry-run machinery unit tests (the 512-device runs happen via
``python -m repro.launch.dryrun``; here we test the parsing/extrapolation
logic and run one real cell in a subprocess)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _parse(hlo):
    # import inside: repro.launch.dryrun sets XLA_FLAGS at import; spawn a
    # fresh interpreter so this test process keeps its 1-device world
    code = (
        "import json, sys; sys.argv=['x'];"
        "from repro.launch.dryrun import parse_collectives;"
        f"print(json.dumps(parse_collectives({hlo!r})))")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=SRC,
                                             XLA_FLAGS=""))
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_parse_collectives_shapes_and_factors():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag.1 = bf16[64]{0} all-gather(%y), replica_groups=[16,2]<=[32]
  %aa = s32[8,16]{1,0} all-to-all(%z), replica_groups={{0,1}}
  %cp = f32[4]{0} collective-permute(%w)
  %ard = f32[9] all-reduce-done(%q)
"""
    st = _parse(hlo)
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["result_bytes"] == 128 * 256 * 4
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["result_bytes"] == 64 * 2
    assert st["all-to-all"]["result_bytes"] == 8 * 16 * 4
    assert st["collective-permute"]["result_bytes"] == 16
    # ring all-reduce moves ~2(g-1)/g x result
    assert st["all-reduce"]["wire_bytes"] == int(
        128 * 256 * 4 * 2 * 3 / 4)
    assert st["total_wire_bytes"] > 0


def test_parse_collectives_bf16_convert_correction():
    hlo = ("%ar = f32[100]{0} all-reduce(%wrapped_convert.3), "
           "replica_groups={{0,1}}")
    st = _parse(hlo)
    assert st["all-reduce"]["result_bytes"] == 200  # counted at bf16 width


@pytest.mark.slow
def test_dryrun_one_cell_subprocess(tmp_path):
    """End-to-end: one real 512-device lower+compile (cheap recsys cell)."""
    out = tmp_path / "dry.jsonl"
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "two-tower-retrieval", "--shape", "serve_p99",
         "--mesh", "multi", "--out", str(out)],
        capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text().strip())
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert rec["mesh"] == "2x16x16"
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")
    assert rec["hlo_flops_per_device"] > 0
