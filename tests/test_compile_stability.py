"""Compilation-stability lockdown (ISSUE 6, DESIGN.md §8).

Three layers of the latency-tail contract:

- the :class:`repro.core.capacity.Ratchet` quantizer itself — a fixed,
  history-independent geometric ladder with ratcheting (never-shrinking)
  per-key marks, so prewarm can enumerate exactly the shapes a stream
  will request;
- the streaming contract — after ``GraphSession.prewarm`` an adversarial
  batch-size stream that straddles every pow2 bucket and repeatedly
  crosses committed-region rungs triggers ZERO XLA compiles, local and
  mesh alike (``EpochResult.compile_events == 0`` every epoch);
- the persistent cross-process cache (``REPRO_COMPILE_CACHE``) — a second
  process walking the same ladder compiles nothing: every lowering is a
  cache hit and the cache gains no new entries.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import GraphSession, Ratchet, pow2_capacity
from repro.core import compilestats

# batch sizes straddle the 128/512 pow2 buckets and, cumulatively, walk
# the committed region across rungs (128 -> 512 -> 2048) several times
CROSSING_SIZES = [3, 120, 129, 257, 400, 511, 500, 64, 512, 1]


def _start_edges(nv=400, ne=1500, seed=11):
    from repro.data.synthetic import uniform_graph
    return uniform_graph(nv, ne, seed)


def _churn_batch(rng, live, size):
    """Churn-balanced batch: half deletes drawn from the live set so the
    base region stays on its pow2 rung (net growth would legitimately
    force a base-regrowth recompile, which this test is not about)."""
    k = min(size // 2, live.shape[0])
    sel = rng.choice(live.shape[0], k, replace=False)
    dels = live[sel]
    ins = rng.integers(0, 400, (size - k, 2))
    upd = np.concatenate([ins, dels]).astype(np.int32)
    w = np.concatenate([np.ones(size - k, np.int32),
                        -np.ones(k, np.int32)])
    return upd, w


# ---------------------------------------------------------------------------
# Ratchet unit tests
# ---------------------------------------------------------------------------

def test_ratchet_quantize_fixed_ladder():
    r = Ratchet(factor=4)
    base = pow2_capacity(1)  # the SEG floor anchors the ladder
    assert r.quantize(1) == base
    assert r.quantize(base) == base
    assert r.quantize(base + 1) == base * 4
    assert r.quantize(4 * base + 1) == base * 16
    # history independence: the rung depends only on the count
    assert Ratchet(factor=4).quantize(base + 1) == base * 4


def test_ratchet_capacity_never_shrinks():
    r = Ratchet()
    big = r.capacity("k", 1000)
    assert r.capacity("k", 5) == big  # smaller count keeps the mark
    assert r.capacity("k", 10 * 1000) > big  # larger count grows it
    assert r.peek("k") == r.capacity("k", 1)


def test_ratchet_observe_pins_and_floors():
    r = Ratchet()
    r.observe("k", 300)  # a pinned mark need not be a canonical rung
    assert r.peek("k") == 300
    assert r.capacity("k", 200) == 300  # under the pin: pinned shape wins
    over = r.capacity("k", 400)  # over the pin: canonical rung resumes
    assert over == max(r.quantize(400), 300)
    r.observe("k", 10)  # observe only floors, never lowers
    assert r.peek("k") >= over


def test_ratchet_reset_and_rungs():
    r = Ratchet(factor=4)
    base = pow2_capacity(1)
    r.capacity("a", 1000), r.capacity("b", 1)
    r.reset("a")
    assert r.peek("a") == 0 and r.peek("b") == base
    r.reset()
    assert r.marks() == {}
    assert r.rungs(1, 4 * base + 1) == [base, 4 * base, 16 * base]
    assert r.rungs(base + 1, base + 1) == [4 * base]
    assert r.next_rung(base) == 4 * base
    assert r.next_rung(base - 1) == base
    assert Ratchet(factor=2).rungs(1, 2 * base) == [base, 2 * base]


def test_ratchet_factor_validation():
    for bad in (0, 1, 3, 6, -4):
        with pytest.raises(ValueError):
            Ratchet(factor=bad)


# ---------------------------------------------------------------------------
# zero-recompile streaming contract
# ---------------------------------------------------------------------------

def _run_crossing_stream(session):
    session.register("triangle")
    spent = session.prewarm(horizon=sum(CROSSING_SIZES) * 4)
    assert spent > 0  # the ladder actually compiled something
    assert session.stats.prewarm_compiles == spent
    after_prewarm = session.stats.compile_events
    rng = np.random.default_rng(7)
    live = session.edges
    events = []
    for size in CROSSING_SIZES * 2:  # two passes: re-cross after compaction
        upd, w = _churn_batch(rng, live, size)
        res = session.update(upd, w)
        events.append(res.compile_events)
        live = res.advance(live)
    assert sum(events) == 0, \
        f"prewarmed stream recompiled: per-epoch events {events}"
    # store-level counter stayed FLAT across the whole stream
    assert session.stats.compile_events == after_prewarm


def test_zero_recompiles_after_prewarm_local():
    session = GraphSession(_start_edges(), local=True, batch=512,
                           out_capacity=1 << 16, update_batch=512)
    _run_crossing_stream(session)


@pytest.mark.slow
def test_zero_recompiles_after_prewarm_mesh():
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices (XLA_FLAGS host platform count)")
    session = GraphSession(_start_edges(), local=False, batch=512,
                           out_capacity=1 << 16, update_batch=512)
    _run_crossing_stream(session)


def test_epoch_result_reports_compile_events():
    """Without prewarm the FIRST epoch must report its compiles — the
    counter is the observability half of the contract."""
    session = GraphSession(_start_edges(nv=64, ne=200, seed=3), local=True,
                           batch=128, out_capacity=1 << 14, update_batch=64)
    session.register("triangle")
    rng = np.random.default_rng(0)
    upd, w = _churn_batch(rng, session.edges, 32)
    res = session.update(upd, w)
    assert res.compile_events > 0


# ---------------------------------------------------------------------------
# persistent cross-process compile cache
# ---------------------------------------------------------------------------

_CHILD = """
import json, os
import numpy as np
from repro.core import compilestats
from repro.core.delta import RegionStore

rng = np.random.default_rng(0)
edges = np.unique(rng.integers(0, 60, (200, 2), dtype=np.int32), axis=0)
store = RegionStore(edges, device_resident=True)
store.ensure("edge", (0,), 1)
store.prewarm_folds(16, horizon=32)
d = compilestats.cache_dir()
entries = sum(len(fs) for _, _, fs in os.walk(d))
print(json.dumps({"compiles": compilestats.total(),
                  "hits": compilestats.persistent_hits(),
                  "entries": entries}))
"""


@pytest.mark.slow
def test_persistent_cache_second_process_compiles_nothing(tmp_path):
    env = dict(os.environ)
    env["REPRO_COMPILE_CACHE"] = str(tmp_path / "xla-cache")
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")

    def run():
        out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    r1, r2 = run(), run()
    assert r1["entries"] > 0  # first process populated the cache
    assert r2["compiles"] == r1["compiles"]  # same ladder, same traces
    assert r2["hits"] > 0  # second process deserialized instead of
    assert r2["entries"] == r1["entries"]  # compiling: no new entries


def test_enable_persistent_cache_is_stable(monkeypatch):
    """Without a path (arg or env) enabling is a no-op, and re-enabling the
    active dir is idempotent — flipping jax's global cache config
    mid-process is reserved for process start (module import)."""
    monkeypatch.delenv(compilestats.ENV_VAR, raising=False)
    before = compilestats.cache_dir()
    assert compilestats.enable_persistent_cache() is None
    assert compilestats.cache_dir() == before  # unchanged
    if before is not None:  # idempotent re-enable of the active dir
        assert compilestats.enable_persistent_cache(before) == before
