"""Region partitioning invariants for the distributed Delta-BiGJoin path.

Three contracts, each from the paper's distributed design (§3.2 / §4.3):

- **ownership**: every (key, val) entry of every multi-version projection is
  stored by exactly ONE worker (cluster memory linearity — sharding splits,
  never replicates);
- **compaction transparency**: ``_maybe_compact`` on sharded regions changes
  the region layout, never the answers;
- **no host round-trips**: the distributed delta step is one compiled
  program whose scanned level loop contains collectives only — a jaxpr
  assertion that no callback/infeed primitive appears anywhere inside it.
"""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.csr import (build_index, build_sharded_index, index_member,
                            pack_key, shard_of)
from repro.core.dataflow_index import VersionedIndex
from repro.core.delta import DeltaBigJoin, delta_oracle
from repro.core.plan import make_delta_plan, make_plan
from repro.core.query import delta_queries

from tests.test_delta import canon
from tests.test_delta_stream import (CFG, _dist_engine, _device_count,
                                     _start_edges, apply_net, random_batch)


# ---------------------------------------------------------------------------
# build_sharded_index: ownership + parity with the unsharded build
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("key_pos,ext_pos,arity",
                         [((0,), 1, 2), ((1,), 0, 2), ((0, 1), 2, 3)])
@pytest.mark.parametrize("w", [1, 3, 4])
def test_sharded_index_every_entry_owned_once(key_pos, ext_pos, arity, w):
    rng = np.random.default_rng(0)
    tuples = rng.integers(0, 50, (300, arity)).astype(np.int32)
    sharded = build_sharded_index(tuples, key_pos, ext_pos, w)
    local = build_index(tuples, key_pos, ext_pos)
    ns = np.asarray(sharded.n)
    assert sharded.key.shape[0] == w and ns.shape == (w,)
    # memory linearity: shard sizes sum to the unsharded live size
    assert int(ns.sum()) == int(local.n)
    seen = []
    for k in range(w):
        nk = int(ns[k])
        keys = np.asarray(sharded.key[k][:nk]).astype(np.int64)
        vals = np.asarray(sharded.val[k][:nk]).astype(np.int64)
        # every live entry hashes home: owner_of(key) == its worker row
        np.testing.assert_array_equal(shard_of(keys, w),
                                      np.full(nk, k, np.int32))
        # shard rows keep the strict lexicographic (key, val) invariant
        if nk > 1:
            dk, dv = np.diff(keys), np.diff(vals)
            assert ((dk > 0) | ((dk == 0) & (dv > 0))).all()
        seen.append(np.stack([keys, vals], 1))
    # exactly-once: shards are pairwise disjoint and union to the local index
    allkv = np.concatenate(seen, axis=0)
    assert np.unique(allkv, axis=0).shape[0] == allkv.shape[0]
    lkeys = np.asarray(local.key[:int(local.n)]).astype(np.int64)
    lvals = np.asarray(local.val[:int(local.n)]).astype(np.int64)
    order = np.lexsort((allkv[:, 1], allkv[:, 0]))
    np.testing.assert_array_equal(allkv[order],
                                  np.stack([lkeys, lvals], 1))


def test_sharded_member_answers_match_unsharded():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    tuples = rng.integers(0, 40, (250, 2)).astype(np.int32)
    w = 4
    sharded = build_sharded_index(tuples, (0,), 1, w)
    local = build_index(tuples, (0,), 1)
    probes_k = rng.integers(0, 45, 64).astype(np.int32)
    probes_v = rng.integers(0, 45, 64).astype(np.int32)
    want = np.asarray(index_member(local, jnp.asarray(probes_k),
                                   jnp.asarray(probes_v)))
    own = shard_of(probes_k.astype(np.int64), w)
    vi = VersionedIndex((sharded,), ())
    got = np.zeros(64, bool)
    hit_off_owner = False
    for k in range(w):
        shard = vi.worker_shard(k)
        ans = np.asarray(index_member(shard.pos[0], jnp.asarray(probes_k),
                                      jnp.asarray(probes_v)))
        got |= ans & (own == k)
        hit_off_owner |= bool((ans & (own != k)).any())
    np.testing.assert_array_equal(got, want)
    assert not hit_off_owner  # non-owners never claim membership


# ---------------------------------------------------------------------------
# partition_indices: versioned regions (the old NotImplementedError path)
# ---------------------------------------------------------------------------

def test_partition_indices_versioned_regions_parity():
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    q = Q.triangle()
    plan = make_delta_plan(delta_queries(q)[1])
    assert any(v != "static" for *_x, v in plan.index_ids())
    base = np.unique(rng.integers(0, 30, (200, 2)).astype(np.int32), axis=0)
    keep = base[:, 0] != base[:, 1]
    base = base[keep]
    cins = np.array([[40, 1], [41, 2]], np.int32)
    cdel = base[:3].copy()
    uins = np.array([[50, 5]], np.int32)
    udel = base[4:6].copy()
    regions = {"base": base, "cins": cins, "cdel": cdel,
               "uins": uins, "udel": udel}
    w = 3
    from repro.core.distributed import partition_indices
    region_tuples = {}
    for _id, rel, key_pos, ext_pos, version in plan.index_ids():
        region_tuples[(rel, key_pos, ext_pos)] = regions
    out = partition_indices(plan, {}, w, region_tuples)
    probes_k = jnp.asarray(rng.integers(0, 55, 128).astype(np.int32))
    probes_v = jnp.asarray(rng.integers(0, 55, 128).astype(np.int32))
    for _id, rel, key_pos, ext_pos, version in plan.index_ids():
        names = {"old": ("base", "cins"), "new": ("base", "cins", "uins")}
        neg_names = {"old": ("cdel",), "new": ("cdel", "udel")}
        local = VersionedIndex(
            tuple(build_index(regions[nm], key_pos, ext_pos)
                  for nm in names[version]),
            tuple(build_index(regions[nm], key_pos, ext_pos)
                  for nm in neg_names[version]))
        vi = out[_id]
        assert vi.num_regions == local.num_regions
        # summed shard counts == local counts for every probe key
        cnt = sum(np.asarray(vi.worker_shard(k).count(probes_k))
                  for k in range(w))
        np.testing.assert_array_equal(cnt, np.asarray(local.count(probes_k)))
        # signed membership: OR over shards == local answer
        mem = np.zeros(128, bool)
        dele = np.zeros(128, bool)
        for k in range(w):
            m, d = vi.worker_shard(k).signed_member(probes_k, probes_v)
            mem |= np.asarray(m)
            dele |= np.asarray(d)
        lm, ld = local.signed_member(probes_k, probes_v)
        np.testing.assert_array_equal(mem, np.asarray(lm))
        np.testing.assert_array_equal(dele, np.asarray(ld))


def test_partition_indices_requires_regions_for_delta_versions():
    q = Q.triangle()
    plan = make_delta_plan(delta_queries(q)[0])
    from repro.core.distributed import partition_indices
    with pytest.raises(ValueError, match="DistDeltaBigJoin"):
        partition_indices(plan, {}, 2)


def test_static_partition_unchanged():
    """The static path still matches the oracle after the rewrite."""
    from repro.core.bigjoin import BigJoinConfig
    from repro.core.distributed import DistConfig, distributed_join
    from repro.core.generic_join import generic_join
    e = _start_edges(30, 260, 3)
    q = Q.triangle()
    plan = make_plan(q)
    cfg = DistConfig(BigJoinConfig(batch=128, mode="count"), 1,
                     route_capacity=128)
    res = distributed_join(plan, {Q.EDGE: e}, cfg=cfg)
    assert res.count == generic_join(q, {Q.EDGE: e}, plan=plan)[1]


# ---------------------------------------------------------------------------
# engine-level invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", [1, 2])
def test_engine_memory_linearity_across_stream(w):
    """After every commit, each projection's shard entries sum EXACTLY to
    its host-truth region rows: nothing replicated, nothing dropped."""
    if _device_count() < w:
        pytest.skip(f"needs {w} devices (CI runs with 4 virtual devices)")
    q = Q.triangle()
    edges = _start_edges(18, 110, 9)
    engine = _dist_engine(q, edges, w)
    rng = np.random.default_rng(10)
    cur = edges.copy()
    for _ in range(4):
        upd, wts = random_batch(rng, 18, cur, 12)
        engine.apply(upd, wts)
        cur = engine.edges.copy()
        for reg in engine.projections.values():
            host_rows = (reg.base.shape[0] + reg.cins.shape[0]
                         + reg.cdel.shape[0])
            assert reg.versioned("new").live_entries() == host_rows
            # every region's shard rows hash home to their worker
            for d in (reg.d_base, reg.d_cins, reg.d_cdel):
                ns = np.asarray(d.n)
                for k in range(w):
                    keys = np.asarray(d.key[k][:ns[k]]).astype(np.int64)
                    assert (shard_of(keys, w) == k).all()


@pytest.mark.parametrize("w", [1, 2])
def test_maybe_compact_on_shards_preserves_answers(w):
    """Eager vs never compaction on the mesh engine: identical signed
    outputs every epoch (compaction only reshapes the LSM regions)."""
    if _device_count() < w:
        pytest.skip(f"needs {w} devices (CI runs with 4 virtual devices)")
    q = Q.diamond()
    edges = _start_edges(16, 90, 12)
    from repro.core.distributed import DistDeltaBigJoin, \
        default_delta_config
    from tests.test_delta_stream import _mesh
    dcfg = default_delta_config(w, batch=128, out_capacity=1 << 15)
    eager = DistDeltaBigJoin(q, edges, mesh=_mesh(w), dcfg=dcfg,
                             compact_ratio=0.01)
    lazy = DistDeltaBigJoin(q, edges, mesh=_mesh(w), dcfg=dcfg,
                            compact_ratio=1e9)
    rng = np.random.default_rng(13)
    cur = edges.copy()
    for _ in range(4):
        upd, wts = random_batch(rng, 16, cur, 10)
        a = eager.apply(upd, wts)
        b = lazy.apply(upd, wts)
        assert canon(a.tuples, a.weights) == canon(b.tuples, b.weights)
        np.testing.assert_array_equal(eager.edges, lazy.edges)
        cur = eager.edges.copy()
    # eager engine actually compacted (committed regions folded into base)
    assert all(r.cins.shape[0] == 0 and r.cdel.shape[0] == 0
               for r in eager.projections.values())


# ---------------------------------------------------------------------------
# jaxpr: the level loop is collectives-only (no per-update host trips)
# ---------------------------------------------------------------------------

_HOST_PRIMS = {"pure_callback", "io_callback", "debug_callback", "callback",
               "infeed", "outfeed", "host_local_array_to_global_array"}


def _walk(jaxpr, visit):
    import jax
    for eqn in jaxpr.eqns:
        visit(eqn)
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                _walk(sub, visit)


def _subjaxprs(v):
    import jax
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _subjaxprs(x)


def test_dist_delta_step_has_no_host_roundtrips():
    """Trace the whole per-worker delta program (seed -> while(level step)
    -> psum) and assert: (1) no host-callback primitive anywhere, (2) the
    drain while-loop exists and its body performs the index lookups through
    all_to_all collectives — i.e. every per-update lookup stays on-device
    and in-program."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro import compat
    from repro.configs.wcoj import _abstract_indices
    from repro.core.bigjoin import BigJoinConfig
    from repro.core.distributed import (AXIS, DistConfig, build_per_worker)

    q = Q.triangle()
    plan = make_delta_plan(delta_queries(q)[0])
    w = 1
    dcfg = DistConfig(BigJoinConfig(batch=128, mode="count"), w,
                      route_capacity=64)
    per_worker = build_per_worker(plan, dcfg)
    indices = _abstract_indices(plan, 1 << 12, w, delta=128)
    S = 128
    seed = jax.ShapeDtypeStruct((w, S, 2), jnp.int32)
    seed_n = jax.ShapeDtypeStruct((w,), jnp.int32)
    seed_w = jax.ShapeDtypeStruct((w, S), jnp.int32)
    mesh = Mesh(np.array(jax.devices()[:1]), (AXIS,))
    specs = (jax.tree.map(lambda _: P(AXIS), indices,
                          is_leaf=lambda x: isinstance(
                              x, jax.ShapeDtypeStruct)),
             P(AXIS), P(AXIS), P(AXIS))
    fn = compat.shard_map(per_worker, mesh=mesh, in_specs=specs,
                          out_specs=(P(),) * 7, check_vma=False)
    closed = jax.make_jaxpr(fn)(indices, seed, seed_n, seed_w)

    prims = set()
    _walk(closed.jaxpr, lambda eqn: prims.add(eqn.primitive.name))
    assert not (prims & _HOST_PRIMS), prims & _HOST_PRIMS
    assert "while" in prims  # the drain loop is in-program

    # find every while body; at least one must contain the all_to_all
    # request/response fabric and NONE may contain host primitives
    bodies = []

    def collect(eqn):
        if eqn.primitive.name == "while":
            for v in eqn.params.values():
                bodies.extend(_subjaxprs(v))
    _walk(closed.jaxpr, collect)
    assert bodies
    loop_prims = set()
    for b in bodies:
        _walk(b, lambda eqn: loop_prims.add(eqn.primitive.name))
    assert "all_to_all" in loop_prims
    assert not (loop_prims & _HOST_PRIMS)


def test_one_program_invocation_per_delta_query():
    """The engine launches exactly one distributed program per dAQ_i per
    epoch — updates are batched into the dataflow, never looped on host."""
    q = Q.triangle()
    edges = _start_edges(14, 70, 14)
    engine = _dist_engine(q, edges, 1)
    calls = []
    for pi, prog in list(engine._programs.items()):
        pass  # programs built lazily on first apply

    orig = engine._run_plan
    def spy(plan, indices, seed, weights):
        calls.append(plan)
        return orig(plan, indices, seed, weights)
    engine._run_plan = spy
    upd = np.array([[1, 2], [2, 3], [60, 61]], np.int32)
    engine.apply(upd)
    assert len(calls) == len(engine.plans) == len(delta_queries(q))
