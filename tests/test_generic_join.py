"""Serial GJ oracle: cross-check against the independent binary-join baseline
and closed-form counts on known graphs."""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.csr import Graph
from repro.core.generic_join import (WorkCounters, binary_join,
                                     fast_triangle_count, generic_join)
from repro.core.plan import make_plan


def random_graph(nv, ne, seed=0, skew=False):
    rng = np.random.default_rng(seed)
    if skew:
        # power-law-ish: preferential attachment by zipf sampling
        u = rng.zipf(1.5, ne) % nv
        v = rng.integers(0, nv, ne)
    else:
        u = rng.integers(0, nv, ne)
        v = rng.integers(0, nv, ne)
    keep = u != v
    return Graph.from_edges(np.stack([u[keep], v[keep]], 1).astype(np.int32),
                            nv)


QUERIES = [Q.triangle(), Q.diamond(), Q.four_clique(), Q.house(),
           Q.five_clique(), Q.path(2), Q.path(3)]


@pytest.mark.parametrize("q", QUERIES, ids=lambda q: q.name)
@pytest.mark.parametrize("seed", [0, 1])
def test_gj_matches_binary_join(q, seed):
    g = random_graph(60, 500, seed)
    rels = {Q.EDGE: g.edges}
    res, cnt = generic_join(q, rels)
    ref, ref_cnt, _ = binary_join(q, rels)
    assert cnt == ref_cnt
    if cnt:
        got = np.unique(res, axis=0)
        assert got.shape == ref.shape
        np.testing.assert_array_equal(got, ref)


def test_gj_counts_complete_graph():
    # K_n directed both ways: each ordered triangle (i,j,k) distinct -> n(n-1)(n-2)
    n = 8
    e = np.array([(i, j) for i in range(n) for j in range(n) if i != j],
                 np.int32)
    rels = {Q.EDGE: e}
    _, cnt = generic_join(Q.triangle(), rels)
    assert cnt == n * (n - 1) * (n - 2)


def test_gj_symmetric_triangle_on_dag():
    g = random_graph(80, 800, 3).degree_relabel()
    rels = {Q.EDGE: g.edges}
    _, cnt = generic_join(Q.triangle(symmetric=True), rels)
    # degree-ordered DAG: each undirected triangle appears exactly once
    und = fast_triangle_count(g.edges)
    assert cnt == und


def test_gj_custom_attr_orders_agree():
    g = random_graph(50, 400, 7)
    rels = {Q.EDGE: g.edges}
    q = Q.diamond()
    base = generic_join(q, rels)[1]
    for order in [(0, 1, 2, 3), (1, 2, 3, 0), (3, 0, 1, 2), (3, 2, 1, 0)]:
        try:
            plan = make_plan(q, order)
        except ValueError:
            continue  # order whose first two attrs share no atom
        assert generic_join(q, rels, plan=plan)[1] == base


def test_gj_ternary_tri_relation():
    g = random_graph(40, 300, 5).degree_relabel()
    rels = {Q.EDGE: g.edges}
    tri, _ = generic_join(Q.triangle(symmetric=True), rels)
    cnt4 = generic_join(Q.four_clique(symmetric=True), rels)[1]
    # 4-clique via the ternary tri relation (§5.4) must agree
    rels_t = {"tri": tri}
    cnt4_tri = generic_join(Q.four_clique_tri(), rels_t)[1]
    assert cnt4 == cnt4_tri


def test_work_is_worst_case_optimal():
    # Lemma 3.1: total work = O(m n MaxOut_Q); check a generous constant.
    for seed in range(3):
        g = random_graph(70, 600, seed, skew=True)
        q = Q.triangle()
        ctr = WorkCounters()
        generic_join(q, {Q.EDGE: g.edges}, counters=ctr)
        bound = Q.agm_bound(q, g.num_edges)
        m, n = q.num_attrs, q.num_atoms
        assert ctr.total <= 8 * m * n * max(bound, g.num_edges)


def test_fast_triangle_count_matches_gj():
    g = random_graph(100, 1200, 11)
    und = g.undirected()
    _, cnt = generic_join(Q.triangle(symmetric=True),
                          {Q.EDGE: g.degree_relabel().edges})
    assert fast_triangle_count(g.edges) == cnt
