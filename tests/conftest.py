"""Shared pytest setup: marker registration and accelerator gating.

Kernel tests run their Pallas kernels in interpret mode off-TPU, so they are
*not* skipped on CPU — only tests explicitly marked ``tpu_only`` (compiled
Mosaic paths, VMEM-budget assertions) are skipped when no TPU is attached.
"""
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end test")
    config.addinivalue_line(
        "markers",
        "tpu_only: requires a real TPU backend (compiled, non-interpret "
        "Pallas path); interpret-mode coverage still runs off-TPU")


def pytest_collection_modifyitems(config, items):
    import jax
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="needs TPU backend; interpret-mode parity covered elsewhere")
    for item in items:
        if "tpu_only" in item.keywords:
            item.add_marker(skip)
