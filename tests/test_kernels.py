"""Per-kernel correctness: shape/dtype sweeps + property tests, each
asserting allclose against the pure-jnp ref.py oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # container image may lack hypothesis
    def settings(**_kw):
        return lambda f: f

    class st:  # noqa: N801 - stand-in so strategy expressions still evaluate
        integers = staticmethod(lambda *a, **k: None)

    def given(*_a, **_k):
        def deco(f):
            def stub():
                pytest.skip("hypothesis not installed")
            stub.__name__ = f.__name__
            return stub
        return deco

from repro.kernels.flash_attention.flash_attention import _flash_call
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.intersect.ops import member
from repro.kernels.intersect.ref import member_ref
from repro.kernels.segment_ops.ops import segment_sum
from repro.kernels.segment_ops.ref import segment_sum_ref


# ---------------------------------------------------------------------------
# intersect
# ---------------------------------------------------------------------------

def _sorted_kv(rng, n, key_dtype, key_range=500, val_range=100):
    k = rng.integers(0, key_range, max(n, 1)).astype(key_dtype)
    v = rng.integers(0, val_range, max(n, 1)).astype(np.int32)
    kv = np.stack([k.astype(np.int64), v.astype(np.int64)], 1)
    kv = kv[np.lexsort((kv[:, 1], kv[:, 0]))]
    return kv[:, 0].astype(key_dtype), kv[:, 1].astype(np.int32)


@pytest.mark.parametrize("n", [0, 1, 3, 127, 128, 129, 1000, 5000])
@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_intersect_sweep(n, dtype):
    rng = np.random.default_rng(n + (0 if dtype == np.int32 else 7))
    k, v = _sorted_kv(rng, n, dtype)
    B = 257
    qk = rng.integers(0, 500, B).astype(dtype)
    qv = rng.integers(0, 100, B).astype(np.int32)
    if n:
        idx = rng.integers(0, n, B // 2)
        qk[:B // 2], qv[:B // 2] = k[idx], v[idx]
    args = (jnp.asarray(k), jnp.asarray(v), jnp.asarray(np.int32(n)),
            jnp.asarray(qk), jnp.asarray(qv))
    np.testing.assert_array_equal(np.asarray(member(*args)),
                                  np.asarray(member_ref(*args)))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 300), st.integers(1, 64), st.integers(0, 3))
def test_intersect_property(n, b, seed):
    rng = np.random.default_rng(seed * 1000 + n)
    k, v = _sorted_kv(rng, n, np.int32, key_range=max(n // 2, 2),
                      val_range=8)
    qk = rng.integers(0, max(n // 2, 2), b).astype(np.int32)
    qv = rng.integers(0, 8, b).astype(np.int32)
    args = (jnp.asarray(k), jnp.asarray(v), jnp.asarray(np.int32(n)),
            jnp.asarray(qk), jnp.asarray(qv))
    got = np.asarray(member(*args))
    # independent truth: python set of pairs
    truth = {(int(a), int(c)) for a, c in zip(k[:n], v[:n])}
    exp = np.array([(int(a), int(c)) in truth for a, c in zip(qk, qv)])
    np.testing.assert_array_equal(got, exp)


# ---------------------------------------------------------------------------
# segment_sum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,D,NS", [(1000, 64, 50), (513, 16, 2000),
                                    (256, 256, 1), (7, 8, 4), (300, 70, 33)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_segment_sum_sweep(E, D, NS, dtype):
    rng = np.random.default_rng(E + D)
    data = rng.normal(size=(E, D)).astype(dtype)
    seg = rng.integers(0, NS, E).astype(np.int32)
    got = np.asarray(segment_sum(jnp.asarray(data), jnp.asarray(seg), NS))
    ref = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg),
                                     NS))
    tol = 2e-2 if dtype == np.float16 else 1e-5
    np.testing.assert_allclose(got, ref, rtol=tol, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 400), st.integers(1, 40), st.integers(1, 50))
def test_segment_sum_property(E, D, NS):
    rng = np.random.default_rng(E * 7 + D)
    data = rng.normal(size=(E, D)).astype(np.float32)
    seg = rng.integers(0, NS, E).astype(np.int32)
    got = np.asarray(segment_sum(jnp.asarray(data), jnp.asarray(seg), NS))
    # invariant: total mass preserved
    np.testing.assert_allclose(got.sum(), data.sum(), rtol=1e-4, atol=1e-2)
    ref = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg),
                                     NS))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)


def test_segment_sum_sorted_promise():
    rng = np.random.default_rng(3)
    data = rng.normal(size=(500, 32)).astype(np.float32)
    seg = np.sort(rng.integers(0, 60, 500)).astype(np.int32)
    a = segment_sum(jnp.asarray(data), jnp.asarray(seg), 60, is_sorted=True)
    b = segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), 60)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    dict(H=2, Sq=256, Sk=256, Dh=64, causal=True, window=0, softcap=0.0),
    dict(H=1, Sq=200, Sk=200, Dh=32, causal=True, window=64, softcap=0.0),
    dict(H=2, Sq=130, Sk=130, Dh=64, causal=True, window=0, softcap=30.0),
    dict(H=1, Sq=1, Sk=300, Dh=64, causal=True, window=0, softcap=0.0,
         q_offset=299),
    dict(H=1, Sq=100, Sk=100, Dh=128, causal=False, window=0, softcap=0.0),
    dict(H=1, Sq=64, Sk=64, Dh=256, causal=True, window=0, softcap=0.0),
]


@pytest.mark.parametrize("case", CASES,
                         ids=lambda c: f"S{c['Sq']}x{c['Sk']}d{c['Dh']}"
                         f"{'c' if c['causal'] else ''}"
                         f"{'w' + str(c['window']) if c['window'] else ''}"
                         f"{'cap' if c['softcap'] else ''}")
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    c = dict(case)
    qo = c.pop("q_offset", 0)
    rng = np.random.default_rng(c["Sq"])
    shape_q = (c["H"], c["Sq"], c["Dh"])
    shape_k = (c["H"], c["Sk"], c["Dh"])
    q = jnp.asarray(rng.normal(size=shape_q), dtype)
    k = jnp.asarray(rng.normal(size=shape_k), dtype)
    v = jnp.asarray(rng.normal(size=shape_k), dtype)
    scale = 1.0 / c["Dh"] ** 0.5
    kw = dict(causal=c["causal"], window=c["window"], softcap=c["softcap"],
              scale=scale, q_offset=qo)
    got = np.asarray(_flash_call(q, k, v, **kw), np.float32)
    ref = np.asarray(attention_ref(q, k, v, **kw), np.float32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol)


def test_mha_gqa_expansion():
    rng = np.random.default_rng(0)
    B, Sq, Hq, Hkv, Dh = 2, 64, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, Dh)), jnp.float32)
    out = mha(q, k, v, causal=True)
    # oracle: expand kv heads then ref per batch
    kx = jnp.repeat(k, Hq // Hkv, axis=2)
    vx = jnp.repeat(v, Hq // Hkv, axis=2)
    for b in range(B):
        ref = attention_ref(q[b].transpose(1, 0, 2),
                            kx[b].transpose(1, 0, 2),
                            vx[b].transpose(1, 0, 2),
                            causal=True, scale=1.0 / Dh ** 0.5)
        np.testing.assert_allclose(np.asarray(out[b].transpose(1, 0, 2)),
                                   np.asarray(ref), rtol=3e-4, atol=3e-4)


def test_mha_decode_step_matches_prefill_row():
    """Decoding one token against a cache == last row of full prefill."""
    rng = np.random.default_rng(1)
    B, S, H, Dh = 1, 96, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    full = mha(q, k, v, causal=True)
    last = mha(q[:, -1:], k, v, causal=True, q_offset=S - 1)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), rtol=3e-4,
                               atol=3e-4)
