"""BiGJoin (JAX dataflow) vs the serial GJ oracle."""
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.generic_join import generic_join
from repro.core.plan import make_plan

from tests.test_generic_join import random_graph


def run_query(q, g, cfg=None, **kw):
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    cfg = cfg or BigJoinConfig(batch=256, seed_chunk=128,
                               out_capacity=1 << 16, **kw)
    idx = build_indices(plan, rels)
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
    ref, ref_cnt = generic_join(q, rels, plan=plan)
    return res, ref, ref_cnt


QUERIES = [Q.triangle(), Q.diamond(), Q.four_clique(), Q.house()]


@pytest.mark.parametrize("q", QUERIES, ids=lambda q: q.name)
def test_bigjoin_matches_oracle(q):
    g = random_graph(50, 400, 1)
    res, ref, ref_cnt = run_query(q, g)
    assert res.count == ref_cnt
    if ref_cnt:
        np.testing.assert_array_equal(
            np.unique(res.tuples, axis=0), np.unique(ref, axis=0))


@pytest.mark.parametrize("batch", [16, 64, 1024])
def test_bigjoin_batch_size_invariance(batch):
    """Fig 6 property: B' changes memory/rounds, never results."""
    g = random_graph(40, 350, 2)
    q = Q.diamond()
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    idx = build_indices(plan, rels)
    cfg = BigJoinConfig(batch=batch, seed_chunk=64, out_capacity=1 << 16)
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
    _, ref_cnt = generic_join(q, rels, plan=plan)
    assert res.count == ref_cnt


def test_bigjoin_skewed_graph():
    g = random_graph(80, 900, 3, skew=True)
    res, ref, ref_cnt = run_query(Q.triangle(), g)
    assert res.count == ref_cnt


def test_bigjoin_symmetric_filters():
    g = random_graph(60, 500, 4).degree_relabel()
    res, _, ref_cnt = run_query(Q.four_clique(symmetric=True), g)
    assert res.count == ref_cnt


def test_bigjoin_count_mode():
    g = random_graph(50, 400, 5)
    q = Q.triangle()
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    idx = build_indices(plan, rels)
    cfg = BigJoinConfig(batch=128, seed_chunk=128, mode="count")
    res = run_bigjoin(plan, idx, seed_tuples_for(plan, rels), cfg=cfg)
    assert res.tuples is None
    assert res.count == generic_join(q, rels, plan=plan)[1]


def test_queue_invariant_and_work_bound():
    """Lemma 3.1: queued prefixes stay O(B') per level; work O(mn MaxOut)."""
    from repro.core.bigjoin import build_seed_step, build_step, make_state
    import jax

    g = random_graph(60, 600, 6, skew=True)
    q = Q.four_clique()
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    idx = build_indices(plan, rels)
    cfg = BigJoinConfig(batch=64, seed_chunk=64, mode="count")
    step = jax.jit(build_step(plan, cfg))
    seed_step = jax.jit(build_seed_step(plan, cfg))
    state = make_state(plan, cfg)
    seed = seed_tuples_for(plan, rels)
    max_deep = 0
    for lo in range(0, seed.shape[0], 64):
        chunk = np.zeros((64, 2), np.int32)
        n = seed[lo:lo + 64].shape[0]
        chunk[:n] = seed[lo:lo + 64]
        state = seed_step(state, idx, chunk,
                          np.ones(64, np.int32), np.arange(64) < n)
        while any(int(qu.size) for qu in state.queues):
            state = step(state, idx)
            max_deep = max(max_deep, *[int(qu.size)
                                       for qu in state.queues[1:]])
    assert not bool(state.overflow)
    # levels beyond the seed hold at most one step's pushes (<= B')
    assert max_deep <= cfg.batch
    bound = Q.agm_bound(q, g.num_edges)
    m, n = q.num_attrs, q.num_atoms
    work = int(state.proposals) + int(state.intersections)
    assert work <= 8 * m * n * max(bound, g.num_edges)
    assert int(state.out_count) == generic_join(q, rels, plan=plan)[1]
