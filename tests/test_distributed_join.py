"""Multi-worker distributed join: correctness on 8 host devices (subprocess,
so the device-count override does not leak into this test process) and
in-process checks on a 1-device mesh."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_check(*args, timeout=600):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.core._dist_check", *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_eight_workers_triangle():
    r = run_check("--workers", "8", "--query", "triangle", "--ne", "500")
    assert r["dist_count"] == r["oracle_count"] and r["tuples_exact"]


@pytest.mark.slow
def test_eight_workers_four_clique_skew():
    r = run_check("--workers", "8", "--query", "4-clique", "--ne", "700",
                  "--nv", "70", "--skew")
    assert r["dist_count"] == r["oracle_count"] and r["tuples_exact"]


@pytest.mark.slow
def test_capacity_deferral_correct():
    """Tiny route capacity forces overflow deferral; results must not change."""
    r = run_check("--workers", "8", "--query", "diamond", "--ne", "400",
                  "--route-capacity", "16")
    assert r["dist_count"] == r["oracle_count"] and r["tuples_exact"]
    assert r["steps"] > 5  # actually exercised multiple retry rounds


@pytest.mark.slow
def test_no_aggregation_still_correct():
    r = run_check("--workers", "4", "--query", "triangle", "--ne", "400",
                  "--no-aggregate")
    assert r["dist_count"] == r["oracle_count"] and r["tuples_exact"]


@pytest.mark.slow
def test_balance_mode_correct_and_reduces_skew():
    """BiGJoin-S balance on an adversarial (zipf) input: correct, and the
    max per-worker served load does not exceed the unbalanced one."""
    args = ["--workers", "8", "--query", "triangle", "--ne", "3000",
            "--nv", "120", "--skew"]
    plain = run_check(*args)
    bal = run_check(*args, "--balance")
    assert plain["dist_count"] == plain["oracle_count"]
    assert bal["dist_count"] == bal["oracle_count"] and bal["tuples_exact"]


def test_single_device_mesh_inprocess():
    from repro.core import query as Q
    from repro.core.bigjoin import BigJoinConfig
    from repro.core.distributed import DistConfig, distributed_join
    from repro.core.generic_join import generic_join
    from repro.core.plan import make_plan

    rng = np.random.default_rng(7)
    u, v = rng.integers(0, 40, 400), rng.integers(0, 40, 400)
    keep = u != v
    e = np.unique(np.stack([u[keep], v[keep]], 1).astype(np.int32), axis=0)
    q = Q.triangle()
    plan = make_plan(q)
    cfg = DistConfig(BigJoinConfig(batch=128, mode="count"), 1,
                     route_capacity=128)
    res = distributed_join(plan, {Q.EDGE: e}, cfg=cfg)
    assert res.count == generic_join(q, {Q.EDGE: e}, plan=plan)[1]


def test_owner_hash_consistency():
    from repro.core.distributed import owner_of, owner_of_np
    import jax.numpy as jnp
    k = np.arange(1000, dtype=np.int64) * 2654435761
    for w in (1, 7, 16, 512):
        np.testing.assert_array_equal(
            owner_of_np(k, w), np.asarray(owner_of(jnp.asarray(k), w)))


def test_dedup_requests():
    import jax.numpy as jnp
    from repro.core.distributed import dedup_requests
    key = jnp.asarray([5, 3, 5, 5, 9, 3, 7], jnp.int64)
    valid = jnp.asarray([1, 1, 1, 1, 1, 1, 0], bool)
    rep, is_rep = dedup_requests(key, valid)
    rep = np.asarray(rep)
    # every valid row maps to a representative with the same key
    for i in range(6):
        assert key[rep[i]] == key[i]
    assert int(np.asarray(is_rep).sum()) == 3  # {5, 3, 9}
