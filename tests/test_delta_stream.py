"""Differential stress suite: streaming Delta-BiGJoin vs full-recompute
oracle, host-local AND mesh-distributed, under adversarial update sequences
(mixed insert/delete weights, duplicate edges, self-loops, inserts of live
edges, deletes of absent edges, re-insert-after-committed-delete, net-zero
batches).  Everything is checked as bit-exact SIGNED tuple sets, not counts.

Multi-worker in-process cases need virtual host devices; CI runs this file
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so w in
{1, 2, 4} all execute.  Locally (1 device) the w > 1 cases are covered by
the slow subprocess tests at the bottom (repro.core._delta_dist_check).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig
from repro.core.delta import DeltaBigJoin, delta_oracle, rows_isin

from tests.test_delta import canon

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # container image may lack hypothesis
    HAVE_HYPOTHESIS = False

    def settings(**_kw):
        return lambda f: f

    class st:  # noqa: N801 - stand-in so strategy expressions still evaluate
        integers = staticmethod(lambda *a, **k: None)

    def given(*_a, **_k):
        def deco(f):
            def stub():
                pytest.skip("hypothesis not installed")
            stub.__name__ = f.__name__
            return stub
        return deco

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = BigJoinConfig(batch=128, seed_chunk=128, out_capacity=1 << 15)


def _device_count():
    import jax
    return jax.device_count()


def _mesh(w):
    import jax
    from jax.sharding import Mesh
    from repro.core.distributed import AXIS
    return Mesh(np.array(jax.devices()[:w]), (AXIS,))


def _dist_engine(q, edges, w, batch=128, balance=False):
    from repro.core.distributed import (DistDeltaBigJoin,
                                        default_delta_config)
    dcfg = default_delta_config(w, batch=batch, out_capacity=1 << 15,
                                balance=balance)
    return DistDeltaBigJoin(q, edges, mesh=_mesh(w), dcfg=dcfg)


# ---------------------------------------------------------------------------
# adversarial update-sequence generator + independent host state model
# ---------------------------------------------------------------------------

def _pack(rows):
    rows = np.asarray(rows, np.int64).reshape(-1, 2)
    return (rows[:, 0] << 32) | rows[:, 1]


def _unpack(packed):
    return np.stack([(packed >> 32).astype(np.int32),
                     (packed & 0xFFFFFFFF).astype(np.int32)], 1)


def apply_net(live, upd, w):
    """Reference semantics of one update batch on the live edge set:
    self-loops dropped, per-edge net weight, net>0 inserts if absent,
    net<0 deletes if present — everything else is a no-op."""
    upd = np.asarray(upd, np.int64).reshape(-1, 2)
    w = np.asarray(w, np.int64)
    keep = upd[:, 0] != upd[:, 1]
    upd, w = upd[keep], w[keep]
    pk = (upd[:, 0] << 32) | upd[:, 1]
    uniq, inv = np.unique(pk, return_inverse=True)
    net = np.zeros(uniq.shape[0], np.int64)
    np.add.at(net, inv.reshape(-1), w)
    lk = _pack(live) if np.asarray(live).size else np.zeros(0, np.int64)
    exists = np.isin(uniq, lk)
    add = uniq[(net > 0) & ~exists]
    rem = uniq[(net < 0) & exists]
    new = np.concatenate([lk[~np.isin(lk, rem)], add])
    new.sort()
    return _unpack(new)


def random_batch(rng, nv, live, size):
    """One dirty batch: inserts (self-loops/dups/live collisions included),
    deletes of live and absent edges, contradictory duplicate rows, and an
    occasional all-noise batch that must net to zero."""
    flavor = rng.integers(0, 5)
    if flavor == 0 and live.shape[0]:  # pure-noise: nets to an exact no-op
        rows = live[rng.integers(0, live.shape[0], max(size // 2, 1))]
        dup = np.concatenate([rows, rows])  # +1 then -1 on the same edges
        w = np.concatenate([np.ones(rows.shape[0], np.int32),
                            -np.ones(rows.shape[0], np.int32)])
        loops = np.stack([np.arange(2, dtype=np.int32)] * 2, 1)
        return (np.concatenate([dup, loops]),
                np.concatenate([w, np.ones(2, np.int32)]))
    n_ins = int(rng.integers(0, size + 1))
    n_del = int(rng.integers(0, size // 2 + 1))
    ins = rng.integers(0, nv, (n_ins, 2)).astype(np.int32)  # dups/self-loops
    parts, wparts = [ins], [np.ones(n_ins, np.int32)]
    if n_del:
        n_live = min(n_del, live.shape[0])
        if n_live:
            parts.append(live[rng.choice(live.shape[0], n_live,
                                         replace=False)])
            wparts.append(-np.ones(n_live, np.int32))
        parts.append(rng.integers(0, nv, (n_del - n_live + 1, 2)
                                  ).astype(np.int32))  # absent deletes
        wparts.append(-np.ones(n_del - n_live + 1, np.int32))
    if flavor == 2 and n_ins:  # duplicate some insert rows (weight piles)
        k = rng.integers(0, n_ins)
        parts.append(ins[k:k + 1].repeat(3, 0))
        wparts.append(np.ones(3, np.int32))
    upd = np.concatenate(parts, axis=0)
    w = np.concatenate(wparts)
    return upd, w


def run_stream(q, engine, rng, nv, n_batches, size):
    """Drive ``engine`` with adversarial batches; assert every epoch's
    signed output tuples match delta_oracle on the before/after edge sets
    and that the engine's live set tracks the reference model."""
    cur = engine.edges.copy()
    for step in range(n_batches):
        upd, w = random_batch(rng, nv, cur, size)
        res = engine.apply(upd, w)
        after = apply_net(cur, upd, w)
        np.testing.assert_array_equal(engine.edges, after)
        ot, ow = delta_oracle(q, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow), \
            f"epoch {step}: signed tuple mismatch"
        assert res.count_delta == int(ow.sum()) if ow.size else \
            res.count_delta == 0
        cur = after


def _start_edges(nv, ne, seed):
    rng = np.random.default_rng(seed)
    u, v = rng.integers(0, nv, ne), rng.integers(0, nv, ne)
    keep = u != v
    return np.unique(np.stack([u[keep], v[keep]], 1).astype(np.int32),
                     axis=0)


# ---------------------------------------------------------------------------
# host-local engine differential
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q", [Q.triangle(), Q.diamond(), Q.four_clique()],
                         ids=lambda q: q.name)
def test_local_stream_differential(q):
    nv, size = 16, 14
    edges = _start_edges(nv, 90, 11)
    engine = DeltaBigJoin(q, edges, cfg=CFG)
    run_stream(q, engine, np.random.default_rng(12), nv,
               n_batches=8, size=size)


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=15, deadline=None)
def test_local_stream_differential_hypothesis(seed):
    q = Q.triangle()
    rng = np.random.default_rng(seed)
    nv = int(rng.integers(6, 20))
    edges = _start_edges(nv, int(rng.integers(10, 80)), seed)
    engine = DeltaBigJoin(q, edges, cfg=CFG,
                          compact_ratio=float(rng.choice([0.01, 0.5, 50.0])))
    run_stream(q, engine, rng, nv, n_batches=4, size=10)


# ---------------------------------------------------------------------------
# distributed engine differential (w gated on available devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", [1, 2, 4])
@pytest.mark.parametrize("q", [Q.triangle(), Q.diamond()],
                         ids=lambda q: q.name)
def test_dist_stream_differential(q, w):
    if _device_count() < w:
        pytest.skip(f"needs {w} devices (CI runs with 4 virtual devices)")
    nv, size = 16, 12
    edges = _start_edges(nv, 90, 21)
    engine = _dist_engine(q, edges, w)
    run_stream(q, engine, np.random.default_rng(22), nv,
               n_batches=6, size=size)


@pytest.mark.parametrize("w", [2])
def test_dist_stream_differential_balance(w):
    """BiGJoin-S balance mode under maintenance: same bit-exact contract."""
    if _device_count() < w:
        pytest.skip(f"needs {w} devices (CI runs with 4 virtual devices)")
    q = Q.triangle()
    nv = 16
    edges = _start_edges(nv, 100, 31)
    engine = _dist_engine(q, edges, w, balance=True)
    run_stream(q, engine, np.random.default_rng(32), nv,
               n_batches=6, size=12)


def test_dist_matches_local_bit_exact():
    """Local and 1-worker mesh engines agree epoch-by-epoch (same host
    bookkeeping, different dataflow), including work-independent count."""
    q = Q.diamond()
    nv = 14
    edges = _start_edges(nv, 80, 41)
    loc = DeltaBigJoin(q, edges, cfg=CFG)
    dist = _dist_engine(q, edges, 1)
    rng = np.random.default_rng(42)
    cur = edges.copy()
    for _ in range(5):
        upd, w = random_batch(rng, nv, cur, 12)
        a = loc.apply(upd, w)
        b = dist.apply(upd, w)
        assert canon(a.tuples, a.weights) == canon(b.tuples, b.weights)
        assert a.count_delta == b.count_delta
        np.testing.assert_array_equal(loc.edges, dist.edges)
        cur = loc.edges.copy()


# ---------------------------------------------------------------------------
# subprocess multi-worker differentials (run even with 1 local device)
# ---------------------------------------------------------------------------

def run_check(*args, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.core._delta_dist_check", *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_subprocess_w4_triangle_20_batches():
    r = run_check("--workers", "4", "--query", "triangle", "--nv", "30",
                  "--ne", "250", "--batches", "20", "--batch-size", "24")
    assert r["all_exact"] and r["workers"] == 4 and r["batches"] == 20


@pytest.mark.slow
def test_subprocess_w2_diamond_20_batches():
    r = run_check("--workers", "2", "--query", "diamond", "--nv", "24",
                  "--ne", "160", "--batches", "20", "--batch-size", "16")
    assert r["all_exact"]


@pytest.mark.slow
def test_subprocess_w4_four_clique_20_batches():
    r = run_check("--workers", "4", "--query", "4-clique", "--nv", "18",
                  "--ne", "110", "--batches", "20", "--batch-size", "12")
    assert r["all_exact"]


# ---------------------------------------------------------------------------
# normalize edge-case semantics (regression tests for the no-op contract)
# ---------------------------------------------------------------------------

def test_net_negative_on_non_live_edge_is_noop():
    q = Q.triangle()
    edges = _start_edges(12, 50, 5)
    engine = DeltaBigJoin(q, edges, cfg=CFG)
    absent = np.array([[900, 901], [7, 7], [901, 900]], np.int32)
    before = engine.edges.copy()
    res = engine.apply(absent, -np.ones(3, np.int32))
    assert res.count_delta == 0 and res.tuples is None
    np.testing.assert_array_equal(engine.edges, before)
    ins, dels = engine.normalize(absent, -np.ones(3, np.int32))
    assert ins.size == 0 and dels.size == 0


def test_net_zero_batch_is_exact_noop():
    """+1/-1 cancellations, live-edge inserts, absent deletes and self-loops
    netting to zero must not touch the engine at all: no region rebuilds,
    no compaction, no dataflow run."""
    q = Q.triangle()
    edges = _start_edges(12, 60, 6)
    engine = DeltaBigJoin(q, edges, cfg=CFG, compact_ratio=0.0)  # eager
    live = engine.edges
    upd = np.concatenate([live[:4], live[:4], live[5:8],
                          np.array([[3, 3]], np.int32),
                          np.array([[800, 801]], np.int32)])
    w = np.concatenate([np.ones(4, np.int32), -np.ones(4, np.int32),
                        np.ones(3, np.int32),  # live inserts: no-op
                        np.ones(1, np.int32),  # self-loop
                        -np.ones(1, np.int32)])  # absent delete
    regions_before = {
        proj: (reg.d_base, reg.d_cins, reg.d_cdel)
        for proj, reg in engine.projections.items()}
    res = engine.apply(upd, w)
    assert res.count_delta == 0 and res.tuples is None and res.per_dq == []
    for proj, reg in engine.projections.items():
        # identical OBJECTS: nothing was rebuilt, not merely equal values
        assert (reg.d_base, reg.d_cins, reg.d_cdel) is not None
        assert regions_before[proj][0] is reg.d_base
        assert regions_before[proj][1] is reg.d_cins
        assert regions_before[proj][2] is reg.d_cdel


def test_duplicate_rows_pile_net_weights():
    q = Q.triangle()
    edges = _start_edges(12, 50, 7)
    engine = DeltaBigJoin(q, edges, cfg=CFG)
    absent = np.array([[1, 9]], np.int32)
    if rows_isin(absent, engine.edges)[0]:
        engine.apply(absent, -np.ones(1, np.int32))
    before = engine.edges.copy()
    # +3 then -2 on the same new edge nets to a single insert
    upd = absent.repeat(5, 0)
    w = np.array([1, 1, 1, -1, -1], np.int32)
    engine.apply(upd, w)
    assert rows_isin(absent, engine.edges)[0]
    after_expected = apply_net(before, upd, w)
    np.testing.assert_array_equal(engine.edges, after_expected)


def test_reinsert_after_committed_delete_stream():
    """delete -> commit -> re-insert across separate batches (the eager
    compaction guard) under the differential check."""
    q = Q.triangle()
    edges = _start_edges(14, 70, 8)
    engine = DeltaBigJoin(q, edges, cfg=CFG, compact_ratio=1e9)  # never
    victim = edges[:6]
    cur = engine.edges.copy()
    for upd, w in ((victim, -np.ones(6, np.int32)),
                   (victim, np.ones(6, np.int32)),
                   (victim, -np.ones(6, np.int32))):
        res = engine.apply(upd, w)
        after = apply_net(cur, upd, w)
        ot, ow = delta_oracle(q, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow)
        cur = after


# ---------------------------------------------------------------------------
# vectorized oracle internals
# ---------------------------------------------------------------------------

def test_rows_isin_matches_set_semantics():
    rng = np.random.default_rng(0)
    for m in (2, 3, 4):
        a = rng.integers(0, 6, (40, m)).astype(np.int32)
        b = rng.integers(0, 6, (25, m)).astype(np.int32)
        want = np.array([tuple(r) in set(map(tuple, b.tolist()))
                         for r in a.tolist()])
        np.testing.assert_array_equal(rows_isin(a, b), want)
    assert rows_isin(np.zeros((0, 3), np.int32),
                     np.zeros((4, 3), np.int32)).shape == (0,)
    assert not rows_isin(np.ones((2, 3), np.int32),
                         np.zeros((0, 3), np.int32)).any()


def test_delta_oracle_matches_set_reference():
    """The packed-row np.isin oracle reproduces the old set-of-tuples diff
    exactly (content AND ordering contract: added block then removed block,
    each lexicographically sorted)."""
    from repro.core.generic_join import generic_join
    rng = np.random.default_rng(3)
    q = Q.diamond()
    before = _start_edges(13, 70, 30)
    after = apply_net(before, rng.integers(0, 13, (30, 2)),
                      rng.choice([1, -1], 30).astype(np.int32))
    t, w = delta_oracle(q, before, after)
    a, _ = generic_join(q, {"edge": before})
    b, _ = generic_join(q, {"edge": after})
    pa = set(map(tuple, a.tolist()))
    pb = set(map(tuple, b.tolist()))
    added = sorted(pb - pa)
    removed = sorted(pa - pb)
    ref_t = np.array(added + removed, np.int32).reshape(-1, q.num_attrs)
    ref_w = np.array([1] * len(added) + [-1] * len(removed), np.int32)
    np.testing.assert_array_equal(t, ref_t)
    np.testing.assert_array_equal(w, ref_w)
