"""Robustness suite (DESIGN.md §10): typed overflow errors, the capacity
escalate-and-replay loop, atomic commit/rollback under injected faults,
WAL hardening (abort/verify/degrade) and pool-level quarantine.

Everything state-changing is differential: after any recovered failure the
engine/store/pool must be BIT-EXACT with a run that never failed — the
signed-tuple oracle (``delta_oracle``) and the reference live-set model
(``apply_net``) are the ground truth, as in test_delta_stream.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import faults
from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig
from repro.core.capacity import Ratchet
from repro.core.delta import DeltaBigJoin, delta_oracle
from repro.errors import (OVF_OUT, OVF_QUEUE, OVF_ROUTE, OVF_SEED,
                          CapacityOverflow, FaultInjected, ReproError,
                          SnapshotError, WalError, overflow_kinds)

from tests.test_delta import canon
from tests.test_delta_stream import (_device_count, _mesh, _start_edges,
                                     apply_net)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    yield
    faults.clear()


# ---------------------------------------------------------------------------
# typed errors + overflow bitmask
# ---------------------------------------------------------------------------

def test_overflow_kinds_and_error_types():
    assert set(overflow_kinds(OVF_OUT)) == {"out"}
    assert set(overflow_kinds(OVF_OUT | OVF_ROUTE)) == {"out", "route"}
    assert set(overflow_kinds(OVF_QUEUE | OVF_SEED)) == {"queue", "seed"}
    assert not overflow_kinds(0)
    exc = CapacityOverflow(OVF_OUT | OVF_QUEUE, where="here", detail="d")
    assert exc.mask == (OVF_OUT | OVF_QUEUE)
    assert set(exc.kinds) == {"out", "queue"}
    assert "here" in str(exc) and "out" in str(exc)
    # back-compat: callers catching RuntimeError keep working
    for cls in (CapacityOverflow, WalError, SnapshotError, FaultInjected):
        assert issubclass(cls, ReproError) and issubclass(cls, RuntimeError)


def test_ratchet_escalate_monotone():
    r = Ratchet()
    first = r.escalate(("cap", "out", "q"), floor=24)
    assert first > 24
    second = r.escalate(("cap", "out", "q"), floor=24)
    assert second > first
    assert r.peek(("cap", "out", "q")) == second
    # a later smaller floor never shrinks the mark
    assert r.escalate(("cap", "out", "q"), floor=4) > second


# ---------------------------------------------------------------------------
# fault registry
# ---------------------------------------------------------------------------

def test_faults_parse_install_fire():
    sched = faults.parse_spec("wal.fsync@7,store.commit.fold@3-5,"
                              "pool.apply@*")
    assert sched["wal.fsync"] == {7}
    assert sched["store.commit.fold"] == {3, 4, 5}
    assert sched["pool.apply"] == {faults.EVERY}

    faults.install({"pool.prep": {2}})
    assert faults.active()
    faults.fire("pool.prep")  # hit 1: clean
    with pytest.raises(FaultInjected) as ei:
        faults.fire("pool.prep")  # hit 2: scheduled
    assert ei.value.point == "pool.prep" and ei.value.hit == 2
    faults.fire("pool.prep")  # hit 3: clean again
    assert faults.counts()["pool.prep"] == 3
    assert faults.injected() == [("pool.prep", 2)]

    with faults.disabled():  # oracle paths run fault-free
        faults.install({"pool.prep": {4}})
        faults.fire("pool.prep")
    faults.clear()
    assert not faults.active()


def test_random_schedule_deterministic():
    a = faults.random_schedule(11, rate=0.1)
    b = faults.random_schedule(11, rate=0.1)
    c = faults.random_schedule(12, rate=0.1)
    assert a == b
    assert a != c
    assert all(p in faults.POINTS for p in a)


# ---------------------------------------------------------------------------
# escalate-and-replay: undersized rungs must transparently grow, and the
# replayed epoch must stay bit-exact with the recompute oracle
# ---------------------------------------------------------------------------

def _zipf_batch(rng, nv, live, size, a=1.4):
    """Insert-heavy zipf batch: hot endpoints pile work onto one vertex
    (and, distributed, one worker) — the adversarial skew regime."""
    u = (rng.zipf(a, size) % nv).astype(np.int32)
    v = rng.integers(0, nv, size).astype(np.int32)
    keep = u != v
    rows = [np.stack([u[keep], v[keep]], 1)]
    ws = [np.ones(int(keep.sum()), np.int32)]
    n_del = min(size // 4, live.shape[0])
    if n_del:
        rows.append(live[rng.choice(live.shape[0], n_del, replace=False)])
        ws.append(-np.ones(n_del, np.int32))
    return np.concatenate(rows), np.concatenate(ws)


def _drive_exact(q, engine, rng, nv, n_batches, size):
    cur = engine.edges.copy()
    for step in range(n_batches):
        upd, w = _zipf_batch(rng, nv, cur, size)
        res = engine.apply(upd, w)
        after = apply_net(cur, upd, w)
        np.testing.assert_array_equal(engine.edges, after)
        ot, ow = delta_oracle(q, cur, after)
        assert canon(res.tuples, res.weights) == canon(ot, ow), \
            f"epoch {step}: signed tuple mismatch after escalation"
        cur = after


def test_local_escalate_replay_zipf_exact():
    q = Q.triangle()
    nv = 40
    edges = _start_edges(nv, 150, 3)
    # deliberately tiny rungs: the zipf stream MUST overflow them
    cfg = BigJoinConfig(batch=16, seed_chunk=16, out_capacity=4)
    engine = DeltaBigJoin(q, edges, cfg=cfg)
    _drive_exact(q, engine, np.random.default_rng(5), nv,
                 n_batches=8, size=24)
    st = engine.store.stats
    assert st.escalations >= 1, "tiny rungs never overflowed: not a test"
    assert st.replays >= 1
    assert engine.cfg.out_capacity > 4


def test_local_escalation_bounded():
    """With escalation disabled the same overflow surfaces as a TYPED
    error naming the buffer — no silent truncation, no bare RuntimeError."""
    q = Q.triangle()
    nv = 40
    edges = _start_edges(nv, 150, 3)
    cfg = BigJoinConfig(batch=16, seed_chunk=16, out_capacity=4)
    engine = DeltaBigJoin(q, edges, cfg=cfg)
    engine.MAX_ESCALATIONS = 0
    rng = np.random.default_rng(5)
    with pytest.raises(CapacityOverflow) as ei:
        for _ in range(8):
            upd, w = _zipf_batch(rng, nv, engine.edges.copy(), 24)
            engine.apply(upd, w)
    assert ei.value.kinds, "overflow must name at least one buffer"


@pytest.mark.parametrize("w", [2, 4])
def test_mesh_escalate_replay_zipf_exact(w):
    if _device_count() < w:
        pytest.skip(f"needs {w} devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
    from repro.core.bigjoin import BigJoinConfig as BJC
    from repro.core.distributed import DistConfig, DistDeltaBigJoin
    q = Q.triangle()
    nv = 40
    edges = _start_edges(nv, 150, 3)
    base = BJC(batch=16, seed_chunk=16, out_capacity=4)
    dcfg = DistConfig(base, w, route_capacity=8)
    engine = DistDeltaBigJoin(q, edges, mesh=_mesh(w), dcfg=dcfg)
    _drive_exact(q, engine, np.random.default_rng(7), nv,
                 n_batches=6, size=32)
    assert engine.store.stats.escalations >= 1, \
        "tiny mesh rungs never overflowed: not a test"


def test_route_overflow_is_loud_not_silent():
    """Satellite regression: a seed whose per-peer route slot overflows
    must surface as OVF_ROUTE — the old behavior dropped the seed's
    reply (``ok=False``) and silently undercounted.  Exercised through
    the one plan shape with seed membership filters: an atom contained
    in the seed prefix (tri(a,b,c) join edge(a,b), seeded by tri)."""
    if _device_count() < 4:
        pytest.skip("needs 4 devices")
    from repro.core.bigjoin import BigJoinConfig as BJC
    from repro.core.distributed import DistConfig, distributed_join
    from repro.core.generic_join import generic_join
    from repro.core.plan import make_plan

    q = Q.Query("tri-edge", 3, (Q.Atom("tri", (0, 1, 2)),
                                Q.Atom("edge", (0, 1))))
    rng = np.random.default_rng(0)
    # every tri shares a=0 and has a DISTINCT b (so request aggregation
    # cannot dedup them): the edge filter keyed on (a) routes EVERY
    # worker's whole seed chunk to ONE owner — far past the per-peer
    # route slots
    n = 200
    tri = np.stack(
        [np.zeros(n, np.int32),
         np.arange(1, n + 1, dtype=np.int32),
         (n + 1 + (np.arange(n) % 60)).astype(np.int32)], 1)
    edge = np.unique(np.concatenate(
        [np.stack([np.zeros(n // 2, np.int32),
                   np.arange(1, n // 2 + 1, dtype=np.int32)], 1),
         rng.integers(0, n, (100, 2)).astype(np.int32)]), axis=0)
    rels = {"tri": tri, "edge": edge}
    plan = make_plan(q, attr_order=(0, 1, 2), seed_atom=0, seed_width=3)
    assert plan.seed_filters, "plan must carry a seed membership filter"

    base = BJC(batch=256, mode="count")
    with pytest.raises(CapacityOverflow) as ei:
        distributed_join(plan, rels,
                         cfg=DistConfig(base, 4, route_capacity=4))
    assert "route" in ei.value.kinds

    # with adequate route slots the same join completes and matches the
    # serial oracle — proving the overflow above was real work, not noise
    _, ref_count = generic_join(q, rels, plan=plan,
                                enumerate_results=False)
    res = distributed_join(plan, rels,
                           cfg=DistConfig(base, 4, route_capacity=256))
    assert res.count == ref_count


# ---------------------------------------------------------------------------
# atomic commit: a fault BETWEEN commit folds must roll back to a store
# bit-identical with the pre-epoch snapshot
# ---------------------------------------------------------------------------

def _snap_equal(a, b):
    la, ma = a
    lb, mb = b
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        if np.asarray(x).shape != np.asarray(y).shape or \
                not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    ka = {k: v for k, v in ma.items() if k != "stats"}
    kb = {k: v for k, v in mb.items() if k != "stats"}
    return ka == kb


def test_commit_fault_rolls_back_bit_identical():
    q = Q.triangle()
    nv = 30
    edges = _start_edges(nv, 120, 1)
    engine = DeltaBigJoin(q, edges, cfg=BigJoinConfig(
        batch=64, seed_chunk=64, out_capacity=1 << 12))
    rng = np.random.default_rng(2)
    upd1, w1 = _zipf_batch(rng, nv, engine.edges.copy(), 16)
    engine.apply(upd1, w1)
    pre = engine.store.snapshot()
    pre_edges = engine.edges.copy()

    upd2, w2 = _zipf_batch(rng, nv, engine.edges.copy(), 16)
    faults.install({"store.commit.fold": {2}})
    with pytest.raises(FaultInjected):
        engine.apply(upd2, w2)
    engine.store.rollback()
    faults.clear()

    post = engine.store.snapshot()
    assert _snap_equal(pre, post), \
        "mid-commit fault left partial state after rollback"
    np.testing.assert_array_equal(engine.edges, pre_edges)
    assert engine.store.stats.rollbacks >= 1

    # the SAME batch replays cleanly and matches the oracle
    cur = engine.edges.copy()
    res = engine.apply(upd2, w2)
    after = apply_net(cur, upd2, w2)
    ot, ow = delta_oracle(q, cur, after)
    assert canon(res.tuples, res.weights) == canon(ot, ow)


def test_session_update_rolls_back_on_fault(tmp_path):
    """GraphSession.update is transactional end-to-end: a failed epoch
    leaves epoch counter, live set and store untouched; the retry
    succeeds and matches the never-failed twin session."""
    from repro.api import GraphSession
    from repro.data.synthetic import uniform_graph
    g = uniform_graph(24, 100, 3)
    s = GraphSession(g, local=True)
    s.register("triangle")
    twin = GraphSession(g, local=True)
    twin.register("triangle")

    rng = np.random.default_rng(4)
    batches = [_zipf_batch(rng, 24, np.asarray(s.edges), 12)
               for _ in range(4)]
    s.update(*batches[0])
    twin.update(*batches[0])

    faults.install({"store.normalize": {1}})  # fails s's NEXT update only
    epoch_before = s.epoch
    with pytest.raises(FaultInjected):
        s.update(*batches[1])
    faults.clear()
    assert s.epoch == epoch_before
    for upd, w in batches[1:]:
        rs = s.update(upd, w)
        rt = twin.update(upd, w)
        dq, dt = rs.deltas["triangle"], rt.deltas["triangle"]
        assert canon(dq.tuples, dq.weights) == canon(dt.tuples, dt.weights)
    np.testing.assert_array_equal(np.asarray(s.edges),
                                  np.asarray(twin.edges))
    assert s.epoch == twin.epoch


# ---------------------------------------------------------------------------
# WAL hardening
# ---------------------------------------------------------------------------

def _mk_batches(k):
    return {"edge": (np.full((2, 2), k, np.int32), np.ones(2, np.int32))}


def test_wal_abort_last_and_verify(tmp_path):
    from repro.serve.wal import WriteAheadLog
    p = str(tmp_path / "wal.log")
    w = WriteAheadLog(p, fsync=False)
    for e in (1, 2, 3):
        w.append(e, _mk_batches(e))
    assert WriteAheadLog.verify(p)["status"] == "clean"

    assert w.abort_last()          # epoch 3's apply failed: drop it
    assert not w.abort_last()      # idempotent: nothing staged
    rep = WriteAheadLog.verify(p)
    assert rep["status"] == "clean" and rep["last_epoch"] == 2
    w.append(3, _mk_batches(3))    # the retry re-appends cleanly
    assert [e for e, _ in w.replay()] == [1, 2, 3]
    w.close()


def test_wal_verify_classification(tmp_path):
    from repro.serve.wal import WriteAheadLog
    p = str(tmp_path / "wal.log")
    w = WriteAheadLog(p, fsync=False)
    for e in (1, 2, 3):
        w.append(e, _mk_batches(e))
    w.close()

    with open(p, "ab") as f:       # torn tail: crash mid-append
        f.write(b'{"b": "{\\"e\\": 9')
    rep = WriteAheadLog.verify(p)
    assert rep["status"] == "torn_tail"
    assert rep["records"] == 3 and rep["lost"] == 1

    lines = open(p, "rb").read().splitlines(keepends=True)
    lines[1] = lines[1][:22] + b"X" + lines[1][23:]  # corrupt record 2
    with open(p, "wb") as f:
        f.write(b"".join(lines))
    rep = WriteAheadLog.verify(p)
    assert rep["status"] == "corrupt_midfile"
    assert rep["records"] == 1 and rep["lost"] == 3
    # replay still stops at the first bad record — never resyncs past it
    assert [e for e, _ in WriteAheadLog(p, fsync=False).replay()] == [1]


def test_wal_verify_cli(tmp_path):
    from repro.serve.wal import WriteAheadLog
    p = str(tmp_path / "wal.log")
    w = WriteAheadLog(p, fsync=False)
    w.append(1, _mk_batches(1))
    w.close()

    env = {**os.environ, "PYTHONPATH": SRC}
    r = subprocess.run([sys.executable, "-m", "repro.serve.wal",
                        "verify", str(tmp_path)],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0 and '"clean"' in r.stdout

    good = open(p, "rb").read()
    bad = good[:22] + b"X" + good[23:]
    with open(p, "ab") as f:
        f.write(bad + good)        # bad line followed by a good one
    r = subprocess.run([sys.executable, "-m", "repro.serve.wal",
                        "verify", p],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 2 and '"corrupt_midfile"' in r.stdout


def test_wal_append_fault_is_typed(tmp_path):
    from repro.serve.wal import WriteAheadLog
    p = str(tmp_path / "wal.log")
    w = WriteAheadLog(p, fsync=False)
    w.append(1, _mk_batches(1))
    faults.install({"wal.append": {1}})  # install resets hit counters
    with pytest.raises(WalError):
        w.append(2, _mk_batches(2))
    faults.clear()
    w.abort_last()                 # roll off any partial bytes
    w.append(2, _mk_batches(2))
    assert [e for e, _ in w.replay()] == [1, 2]
    w.close()


# ---------------------------------------------------------------------------
# pool: WAL degrade + quarantine (host-local sessions, synchronous pump)
# ---------------------------------------------------------------------------

def _mini_pool(tmp_path, **kw):
    from repro.data.synthetic import uniform_graph
    from repro.serve import SessionPool
    pool = SessionPool(local=True, pipeline=False, prewarm=False,
                       durable_dir=str(tmp_path / "dur"), fsync=False,
                       **kw)
    h = pool.admit("t0", uniform_graph(20, 80, 0), queries=("triangle",),
                   coalesce=1)
    return pool, h


def test_pool_wal_degrade_serves_on(tmp_path):
    pool, h = _mini_pool(tmp_path, wal_retries=2, wal_backoff_s=0.0)
    rng = np.random.default_rng(0)
    live = np.asarray(h.session.edges)
    faults.install("wal.append@*")
    for _ in range(3):
        upd, w = _zipf_batch(rng, 20, live, 8)
        tk = h.submit(upd, w)
        pool.pump()
        res = tk.result(timeout=60)     # epochs still commit, non-durable
        live = res.advance(live)
    faults.clear()
    st = h.stats
    assert st.wal_degraded and st.wal_errors >= 3
    assert st.retired == 3 and st.failed == 0
    np.testing.assert_array_equal(np.asarray(h.session.edges), live)
    agg = pool.stats().aggregate()
    assert agg["wal_degraded"] == 1
    pool.close()


def test_pool_quarantine_after_consecutive_failures(tmp_path):
    pool, h = _mini_pool(tmp_path, quarantine_after=3)
    rng = np.random.default_rng(1)
    live = np.asarray(h.session.edges)
    batches = [_zipf_batch(rng, 20, live, 6) for _ in range(5)]
    faults.install("store.normalize@*")
    tickets = [h.submit(u, w) for u, w in batches]
    pool.pump()
    faults.clear()
    # first 3 fail on the fault; the last 2 are failed by the fence
    for tk in tickets:
        with pytest.raises((FaultInjected, RuntimeError)):
            tk.result(timeout=60)
    assert h.stats.quarantined and h.stats.failed == 5
    with pytest.raises(RuntimeError, match="quarantined"):
        h.submit(*batches[0])
    agg = pool.stats().aggregate()
    assert agg["quarantined"] == 1 and agg["failed"] == 5
    pool.close(drain=False)


def test_pool_apply_fault_aborts_wal_record(tmp_path):
    """A failed apply must leave NO WAL record behind — recovery replay
    must not re-apply a batch the live run rejected."""
    from repro.serve.wal import WriteAheadLog
    pool, h = _mini_pool(tmp_path)
    rng = np.random.default_rng(2)
    live = np.asarray(h.session.edges)
    upd, w = _zipf_batch(rng, 20, live, 6)
    tk = h.submit(upd, w)
    pool.pump()
    tk.result(timeout=60)

    faults.install({"store.normalize": {1}})  # fail the NEXT apply only
    upd2, w2 = _zipf_batch(rng, 20, live, 6)
    tk2 = h.submit(upd2, w2)
    pool.pump()
    with pytest.raises(FaultInjected):
        tk2.result(timeout=60)
    faults.clear()

    wal_path = str(tmp_path / "dur" / "t0" / "wal.log")
    rep = WriteAheadLog.verify(wal_path)
    assert rep["status"] == "clean" and rep["records"] == 1, \
        "aborted epoch left a WAL record"
    pool.close(drain=False)
