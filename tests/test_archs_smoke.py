"""Per-architecture smoke tests: reduced config, one real forward/train step
on CPU, shapes + finiteness asserted (full configs are dry-run only)."""
import numpy as np
import pytest

from repro.configs import get_arch, list_archs

ARCHS = list_archs()


def test_all_assigned_archs_registered():
    for required in ["llama4-scout-17b-a16e", "mixtral-8x7b", "yi-34b",
                     "gemma-7b", "gemma2-2b", "egnn", "graphcast",
                     "gatedgcn", "gat-cora", "two-tower-retrieval",
                     "wcoj-subgraph"]:
        assert required in ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke(arch):
    spec = get_arch(arch)
    metrics = spec.smoke_run(spec.smoke_config)
    for v in metrics.values():
        assert np.isfinite(v)


@pytest.mark.parametrize("arch", ["llama4-scout-17b-a16e", "mixtral-8x7b",
                                  "yi-34b", "gemma-7b", "gemma2-2b"])
def test_lm_full_config_matches_assignment(arch):
    spec = get_arch(arch)
    cfg = spec.full_config
    expect = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048, 16, 1),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000, 8, 2),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000, 0, 1),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000, 0, 1),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000, 0, 1),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab, cfg.n_experts, cfg.top_k)
    assert got == expect
    if arch in ("gemma-7b", "gemma2-2b"):
        assert cfg.head_dim == 256 and cfg.act == "gelu"
    if arch == "gemma2-2b":
        assert cfg.attn_softcap == 50.0 and cfg.final_softcap == 30.0
        assert cfg.local_global_period == 2
    if arch == "mixtral-8x7b":
        assert cfg.window == 4096


def test_gnn_full_configs_match_assignment():
    assert get_arch("egnn").full_config.n_layers == 4
    assert get_arch("egnn").full_config.d_hidden == 64
    gc = get_arch("graphcast").full_config
    assert gc.n_layers == 16 and gc.d_hidden == 512 and gc.d_out == 227
    gg = get_arch("gatedgcn").full_config
    assert gg.n_layers == 16 and gg.d_hidden == 70
    gat = get_arch("gat-cora").full_config
    assert gat.n_layers == 2 and gat.n_heads == 8


def test_recsys_full_config_matches_assignment():
    cfg = get_arch("two-tower-retrieval").full_config
    assert cfg.embed_dim == 256 and cfg.tower_mlp == (1024, 512, 256)


def test_param_counts_plausible():
    # public parameter counts (active): scout ~17B active/109B total,
    # mixtral ~13B active/47B total, yi 34B, gemma 8.5B, gemma2 2.6B
    cases = {
        "llama4-scout-17b-a16e": (9e9, 20e9, 95e9, 120e9),
        "mixtral-8x7b": (11e9, 15e9, 44e9, 50e9),
        "yi-34b": (30e9, 38e9, 30e9, 38e9),
        "gemma-7b": (7.5e9, 10e9, 7.5e9, 10e9),
        "gemma2-2b": (2.2e9, 3.2e9, 2.2e9, 3.2e9),
    }
    for arch, (alo, ahi, tlo, thi) in cases.items():
        cfg = get_arch(arch).full_config
        assert alo < cfg.active_param_count() < ahi, arch
        assert tlo < cfg.param_count() < thi, arch


def test_long_context_skips_documented():
    for arch, should_skip in [("yi-34b", True), ("gemma-7b", True),
                              ("gemma2-2b", False), ("mixtral-8x7b", False),
                              ("llama4-scout-17b-a16e", False)]:
        cell = get_arch(arch).cells["long_500k"]
        assert (cell.skip_reason is not None) == should_skip, arch


def test_cell_matrix_complete():
    """The assigned 40-cell matrix: 10 archs x 4 shapes each."""
    total = 0
    for arch in ARCHS:
        if arch == "wcoj-subgraph":
            continue
        cells = get_arch(arch).cells
        assert len(cells) == 4, arch
        total += len(cells)
    assert total == 40
