"""§5.4 optimizations: results invariant, work reduced."""
import numpy as np

from repro.core import query as Q
from repro.core.csr import Graph
from repro.core.generic_join import WorkCounters, generic_join
from repro.core.optimizations import (build_triangle_relation,
                                      factorized_house_count,
                                      four_clique_via_tri, symmetry_break)

from tests.test_generic_join import random_graph


def test_symmetry_breaking_counts_each_clique_once():
    g = random_graph(60, 700, 0)
    und = g.undirected()
    sym = symmetry_break(g)
    # directed count over the undirected graph = 24x the symmetric count
    cnt_dir = generic_join(Q.four_clique(), {Q.EDGE: und.edges})[1]
    cnt_sym = generic_join(Q.four_clique(symmetric=True),
                           {Q.EDGE: sym.edges})[1]
    assert cnt_dir == 24 * cnt_sym


def test_triangle_relation_engines_agree():
    g = symmetry_break(random_graph(50, 500, 1))
    t1 = build_triangle_relation(g, engine="bigjoin")
    t2 = build_triangle_relation(g, engine="oracle")
    np.testing.assert_array_equal(np.unique(t1, axis=0),
                                  np.unique(t2, axis=0))


def test_four_clique_via_tri_matches_flat():
    g = symmetry_break(random_graph(55, 650, 2))
    flat = generic_join(Q.four_clique(symmetric=True), {Q.EDGE: g.edges})[1]
    via_tri, _ = four_clique_via_tri(g)
    assert via_tri == flat


def test_tri_rewrite_reduces_work():
    g = symmetry_break(random_graph(70, 900, 3))
    ctr_flat = WorkCounters()
    generic_join(Q.four_clique(symmetric=True), {Q.EDGE: g.edges},
                 counters=ctr_flat)
    tri = build_triangle_relation(g, engine="oracle")
    ctr_tri = WorkCounters()
    generic_join(Q.four_clique_tri(), {"tri": tri}, counters=ctr_tri)
    # Table 5's point: the rewrite explores fewer intermediate prefixes
    assert ctr_tri.proposals < ctr_flat.proposals


def test_factorized_house_matches_flat():
    g = symmetry_break(random_graph(45, 600, 4))
    flat = generic_join(Q.house(symmetric=True), {Q.EDGE: g.edges})[1]
    assert factorized_house_count(g) == flat
