"""Warm epoch latency vs graph scale -> BENCH_epoch_latency.json.

The device-resident store's claim (DESIGN.md §6): steady-state epoch cost is
a function of |Δ| + |committed|, not |E| — normalize is an O(|Δ|·log|E|)
probe and commit folds only the committed regions and the delta, so warm
latency at a fixed batch size should be nearly flat in graph scale, where
the legacy host store rescans the live set.

This benchmark isolates the store path (normalize → begin_epoch → commit on
a store with both edge projections ensured; no query dataflow rides along)
at a fixed 64-update batch over |E| ∈ {1e4, 1e5, 1.6e5, 1e6}, with the
update batches pre-generated so the timed loop is exactly the epoch work.
The 1.6e5 point exists so the acceptance ratio is a clean 16× span from
1e4: the device path must grow < 2× in warm latency across it (the legacy
host path is recorded alongside for contrast, not gated).

The device path additionally walks the AOT prewarm ladder (DESIGN.md §8)
before its timed loop — cold (prewarm) time is reported separately — and
gates the latency TAIL: warm-stream p99/p50 must stay ≤ 5× with the
per-epoch recompile counters reporting zero jit rebuilds after warmup.

Run via ``python -m benchmarks.run --only epoch_latency`` (or directly).
"""
import json
import os
import time

import numpy as np

from benchmarks.common import row

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_epoch_latency.json")

SCALES = [10_000, 100_000, 160_000, 1_000_000]
BASE, SIXTEEN_X = 10_000, 160_000
BATCH = 64
WARMUP, EPOCHS = 4, 16


def _graph(ne: int):
    from repro.data.synthetic import uniform_graph
    nv = max(ne // 8, 64)  # mean degree ~8 at every scale
    # oversample: uniform_graph dedups, so ask for ~8% extra edges
    return nv, uniform_graph(nv, int(ne * 1.08), seed=ne % 97)


def _batches(nv, edges, n_epochs):
    """Pre-generate the update stream + its live-set evolution so the timed
    loop contains ONLY store work.  The untimed tracker store replays the
    exact normalize/commit semantics the timed stores will see (same
    pattern as benchmarks/multi_query.py)."""
    from repro.core.delta import RegionStore
    from repro.data.synthetic import EdgeUpdateStream
    stream = EdgeUpdateStream(nv, BATCH, seed=3)
    tracker = RegionStore(edges, device_resident=False)  # no projections
    out = []
    for step in range(n_epochs):
        upd, w = stream.batch_at(step, live=tracker.edges)
        out.append((upd, w))
        ins, dels = tracker.normalize(upd, w)
        if ins.size or dels.size:
            tracker.begin_epoch(ins, dels)
            tracker.commit(ins, dels)
    return out


def _time_store(edges, batches, device: bool):
    from repro.core import compilestats
    from repro.core.delta import RegionStore
    store = RegionStore(edges, device_resident=device)
    store.ensure("edge", (0,), 1)
    store.ensure("edge", (1,), 0)
    # the device path pays its compiles up front (AOT ladder, DESIGN.md §8)
    # so the timed epochs measure steady-state work, not XLA
    t0 = time.time()
    store.prewarm_folds(BATCH, horizon=len(batches) * BATCH)
    prewarm_s = time.time() - t0
    lat, compiles = [], []
    for upd, w in batches:
        snap = compilestats.snapshot()
        t0 = time.time()
        ins, dels = store.normalize(upd, w)
        if ins.size or dels.size:
            store.begin_epoch(ins, dels)
            store.commit(ins, dels)
        lat.append(time.time() - t0)
        compiles.append(compilestats.since(snap))
    warm = np.asarray(lat[WARMUP:]) * 1e3
    pct = {k: round(float(np.percentile(warm, q)), 3)
           for k, q in (("p50", 50), ("p95", 95), ("p99", 99))}
    pct["max"] = round(float(warm.max()), 3)
    tail = {"cold_prewarm_ms": round(prewarm_s * 1e3, 1),
            "prewarm_compiles": store.stats.prewarm_compiles,
            "warm_compiles": int(sum(compiles[WARMUP:])),
            "epoch_compiles": compiles, **pct,
            "p99_p50_ratio": round(pct["p99"] / max(pct["p50"], 1e-9), 3)}
    return pct["p50"], [round(t * 1e3, 3) for t in lat], store.stats, tail


def main():
    rec = {"bench": "epoch_latency", "batch_size": BATCH,
           "warmup": WARMUP, "epochs": EPOCHS, "scales": {}}
    med = {}
    for ne in SCALES:
        nv, edges = _graph(ne)
        batches = _batches(nv, edges, WARMUP + EPOCHS)
        entry = {"edges": int(edges.shape[0]), "num_vertices": nv}
        for device in (True, False):
            name = "device" if device else "legacy"
            m, per_epoch, stats, tail = _time_store(edges, batches, device)
            entry[f"{name}_warm_ms"] = round(m, 3)
            entry[f"{name}_epoch_ms"] = per_epoch
            entry[f"{name}_compactions"] = stats.compactions
            entry[f"{name}_latency"] = tail
            med[(name, ne)] = m
            row("epoch_latency", f"{name}_E{ne}", m / 1e3,
                f"|E|={edges.shape[0]} warm_ms={m:.2f} "
                f"p99/p50={tail['p99_p50_ratio']}x "
                f"warm_compiles={tail['warm_compiles']}")
        rec["scales"][str(ne)] = entry
    growth = {
        "span": f"{BASE}->{SIXTEEN_X} (16x |E|)",
        "device": round(med[("device", SIXTEEN_X)]
                        / max(med[("device", BASE)], 1e-9), 3),
        "legacy": round(med[("legacy", SIXTEEN_X)]
                        / max(med[("legacy", BASE)], 1e-9), 3),
    }
    rec["growth_16x"] = growth
    rec["device_growth_lt_2x"] = bool(growth["device"] < 2.0)
    # latency-tail gate (ISSUE 6): prewarmed device epochs must be compile
    # free after warmup with a flat tail at EVERY scale
    tails = [rec["scales"][str(ne)]["device_latency"] for ne in SCALES]
    rec["device_p99_p50_max"] = max(t["p99_p50_ratio"] for t in tails)
    rec["device_warm_compiles"] = sum(t["warm_compiles"] for t in tails)
    rec["device_tail_flat"] = bool(rec["device_p99_p50_max"] <= 5.0
                                   and rec["device_warm_compiles"] == 0)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("epoch_latency", "growth_16x_device", 0.0,
        f"{growth['device']}x (<2x: {rec['device_growth_lt_2x']})")
    row("epoch_latency", "tail_flat_device", 0.0,
        f"p99/p50<={rec['device_p99_p50_max']}x "
        f"warm_compiles={rec['device_warm_compiles']} "
        f"(flat: {rec['device_tail_flat']})")
    row("epoch_latency", "json", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
