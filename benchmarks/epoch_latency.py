"""Warm epoch latency vs graph scale -> BENCH_epoch_latency.json.

The device-resident store's claim (DESIGN.md §6): steady-state epoch cost is
a function of |Δ| + |committed|, not |E| — normalize is an O(|Δ|·log|E|)
probe and commit folds only the committed regions and the delta, so warm
latency at a fixed batch size should be nearly flat in graph scale, where
the legacy host store rescans the live set.

This benchmark isolates the store path (normalize → begin_epoch → commit on
a store with both edge projections ensured; no query dataflow rides along)
at a fixed 64-update batch over |E| ∈ {1e4, 1e5, 1.6e5, 1e6}, with the
update batches pre-generated so the timed loop is exactly the epoch work.
The 1.6e5 point exists so the acceptance ratio is a clean 16× span from
1e4: the device path must grow < 2× in warm latency across it (the legacy
host path is recorded alongside for contrast, not gated).

Run via ``python -m benchmarks.run --only epoch_latency`` (or directly).
"""
import json
import os
import time

import numpy as np

from benchmarks.common import row

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_epoch_latency.json")

SCALES = [10_000, 100_000, 160_000, 1_000_000]
BASE, SIXTEEN_X = 10_000, 160_000
BATCH = 64
WARMUP, EPOCHS = 4, 16


def _graph(ne: int):
    from repro.data.synthetic import uniform_graph
    nv = max(ne // 8, 64)  # mean degree ~8 at every scale
    # oversample: uniform_graph dedups, so ask for ~8% extra edges
    return nv, uniform_graph(nv, int(ne * 1.08), seed=ne % 97)


def _batches(nv, edges, n_epochs):
    """Pre-generate the update stream + its live-set evolution so the timed
    loop contains ONLY store work.  The untimed tracker store replays the
    exact normalize/commit semantics the timed stores will see (same
    pattern as benchmarks/multi_query.py)."""
    from repro.core.delta import RegionStore
    from repro.data.synthetic import EdgeUpdateStream
    stream = EdgeUpdateStream(nv, BATCH, seed=3)
    tracker = RegionStore(edges, device_resident=False)  # no projections
    out = []
    for step in range(n_epochs):
        upd, w = stream.batch_at(step, live=tracker.edges)
        out.append((upd, w))
        ins, dels = tracker.normalize(upd, w)
        if ins.size or dels.size:
            tracker.begin_epoch(ins, dels)
            tracker.commit(ins, dels)
    return out


def _time_store(edges, batches, device: bool):
    from repro.core.delta import RegionStore
    store = RegionStore(edges, device_resident=device)
    store.ensure("edge", (0,), 1)
    store.ensure("edge", (1,), 0)
    lat = []
    for upd, w in batches:
        t0 = time.time()
        ins, dels = store.normalize(upd, w)
        if ins.size or dels.size:
            store.begin_epoch(ins, dels)
            store.commit(ins, dels)
        lat.append(time.time() - t0)
    warm = sorted(lat[WARMUP:])
    return warm[len(warm) // 2] * 1e3, [round(t * 1e3, 3) for t in lat], \
        store.stats


def main():
    rec = {"bench": "epoch_latency", "batch_size": BATCH,
           "warmup": WARMUP, "epochs": EPOCHS, "scales": {}}
    med = {}
    for ne in SCALES:
        nv, edges = _graph(ne)
        batches = _batches(nv, edges, WARMUP + EPOCHS)
        entry = {"edges": int(edges.shape[0]), "num_vertices": nv}
        for device in (True, False):
            name = "device" if device else "legacy"
            m, per_epoch, stats = _time_store(edges, batches, device)
            entry[f"{name}_warm_ms"] = round(m, 3)
            entry[f"{name}_epoch_ms"] = per_epoch
            entry[f"{name}_compactions"] = stats.compactions
            med[(name, ne)] = m
            row("epoch_latency", f"{name}_E{ne}", m / 1e3,
                f"|E|={edges.shape[0]} warm_ms={m:.2f}")
        rec["scales"][str(ne)] = entry
    growth = {
        "span": f"{BASE}->{SIXTEEN_X} (16x |E|)",
        "device": round(med[("device", SIXTEEN_X)]
                        / max(med[("device", BASE)], 1e-9), 3),
        "legacy": round(med[("legacy", SIXTEEN_X)]
                        / max(med[("legacy", BASE)], 1e-9), 3),
    }
    rec["growth_16x"] = growth
    rec["device_growth_lt_2x"] = bool(growth["device"] < 2.0)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("epoch_latency", "growth_16x_device", 0.0,
        f"{growth['device']}x (<2x: {rec['device_growth_lt_2x']})")
    row("epoch_latency", "json", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
