"""Fig 5 / Table 4: scaling workers x graph fractions.

Each configuration runs the *distributed* engine in a subprocess with w
forced host devices (1 physical core underneath, so wall-clock does not
speed up — the Fig-5 quantities that transfer to this container are the
per-worker index size, per-worker served load (balance), and round counts,
all of which must scale ~1/w; wall time is reported for completeness)."""
import json
import os
import subprocess
import sys

from benchmarks.common import row

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_cfg(workers, ne, nv, query="triangle", batch=1024):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.core._dist_check",
         "--workers", str(workers), "--query", query, "--ne", str(ne),
         "--nv", str(nv), "--batch", str(batch), "--skew",
         "--route-capacity", str(max(batch // max(workers, 1), 16) * 4)],
        capture_output=True, text=True, timeout=1200, env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def main():
    for frac, ne in [("1/4", 2500), ("1/2", 5000), ("1/1", 10000)]:
        for w in (1, 2, 4, 8):
            r = run_cfg(w, ne, nv=400)
            mean = max(r["mean_load"], 1.0)
            row("fig5_scaling", f"edges{frac.replace('/', 'of')}_w{w}",
                r["warm_s"],
                f"count={r['dist_count']};rounds={r['steps']};"
                f"max_load={r['max_load']};"
                f"load_imbalance={r['max_load'] / mean:.2f};"
                f"edges_per_worker={r['edges'] // w}")


if __name__ == "__main__":
    main()
