"""Render dry-run JSONL records into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.report \
        benchmarks/results/dryrun_single.jsonl
"""
import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def ms(s):
    return f"{s * 1e3:.2f}"


PEAK, HBM, ICI = 197e12, 819e9, 50e9


def terms(r):
    """(Re)derive roofline terms from the recorded raw fields, so older
    records get the structural memory-term definition uniformly."""
    pd = r["per_device"]
    live = (pd["argument_bytes"] or 0) + (pd["temp_bytes"] or 0)
    compute_s = r["hlo_flops_per_device"] / PEAK
    memory_s = 2.0 * live / HBM
    nofusion_s = r["hlo_bytes_per_device"] / HBM
    coll_s = r["collectives"]["total_wire_bytes"] / ICI
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, coll_s)
    useful = r["roofline"]["useful_flops_ratio"]
    # roofline fraction: useful-compute time / bound time
    model_s = r["roofline"]["model_flops_total"] / r["chips"] / PEAK
    frac = model_s / bound if bound > 0 else 0.0
    return compute_s, memory_s, nofusion_s, coll_s, dom, useful, frac


def render(path):
    recs = [json.loads(l) for l in open(path)]
    print("| arch | shape | mesh | args GiB | temp GiB | compute ms | "
          "memory ms | collective ms | dominant | useful | roofline frac |")
    print("|" + "---|" * 11)
    for r in recs:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"SKIPPED ({r['skip_reason'][:48]}…) "
                  f"| | | | | | | |")
            continue
        if r["status"] == "error":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"ERROR: {r['error'][:60]} | | | | | | | |")
            continue
        pd = r["per_device"]
        c, m, nf, co, dom, useful, frac = terms(r)
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt_bytes(pd['argument_bytes'])} "
              f"| {fmt_bytes(pd['temp_bytes'])} "
              f"| {ms(c)} | {ms(m)} | {ms(co)} | {dom} "
              f"| {useful:.2f} | {frac:.3f} |")


def render_intersect(path):
    """Render a BENCH_intersect.json perf-trajectory record as a table."""
    rec = json.load(open(path))
    print(f"backend: {rec.get('backend', '?')}  "
          f"interpret: {rec.get('interpret_mode', '?')}\n")
    print("| stage | variant | throughput | note |")
    print("|" + "---|" * 4)
    m = rec.get("member", {})
    if m:
        print(f"| member | ref | {m['ref_qps']:.0f} q/s | "
              f"n={m['index_entries']} B={m['batch']} |")
        print(f"| member | kernel | {m['kernel_qps']:.0f} q/s | "
              f"bit_exact={m['bit_exact']} |")
    r = rec.get("regions", {})
    if r:
        print(f"| regions(R={r['num_regions']}) | jnp | "
              f"{r['jnp_qps']:.0f} q/s | |")
        print(f"| regions(R={r['num_regions']}) | fused | "
              f"{r['fused_qps']:.0f} q/s | "
              f"{r['fused_pallas_calls']} launch, "
              f"saved {r['launches_saved_vs_per_region']} |")
    for name, b in rec.get("bigjoin", {}).items():
        print(f"| bigjoin | {name} | {b['steps_per_sec']:.1f} steps/s | "
              f"{b['proposals_per_sec']:.0f} proposals/s |")


def render_delta_stream(path):
    """Render a BENCH_delta_stream.json streaming-maintenance record."""
    rec = json.load(open(path))
    print("| config | workers | warm epochs/s | warm upd/s | "
          "warm changes/s | shard entries | exact |")
    print("|" + "---|" * 7)
    for name in ("w1", "w4", "local"):
        r = rec.get(name)
        if not r:
            continue
        print(f"| {r['mode']} | {r['workers']} | {r['warm_epochs_per_s']} "
              f"| {r['warm_updates_per_s']:.0f} "
              f"| {r['warm_changes_per_s']:.0f} | {r['shard_entries']} "
              f"| {r['all_exact']} |")


def _tail_cell(t):
    """One markdown cell for a latency-tail dict (p50/p99/compiles)."""
    if not t:
        return "—"
    return (f"p50 {t['p50']} p99 {t['p99']} ({t['p99_p50_ratio']}x), "
            f"{t['warm_compiles']} warm compiles")


def render_epoch_latency(path):
    """Render a BENCH_epoch_latency.json warm-epoch-scaling record."""
    rec = json.load(open(path))
    print(f"batch={rec['batch_size']} updates/epoch, "
          f"{rec['epochs']} warm epochs (median)\n")
    print("| |E| | device warm ms | legacy warm ms | device/legacy | "
          "device tail (prewarmed) |")
    print("|" + "---|" * 5)
    for ne, r in sorted(rec.get("scales", {}).items(), key=lambda kv:
                        int(kv[0])):
        d, l = r["device_warm_ms"], r["legacy_warm_ms"]
        print(f"| {r['edges']:,} | {d} | {l} | {d / max(l, 1e-9):.2f}x "
              f"| {_tail_cell(r.get('device_latency'))} |")
    g = rec.get("growth_16x", {})
    print(f"\ngrowth over {g.get('span', '?')}: device {g.get('device')}x, "
          f"legacy {g.get('legacy')}x "
          f"(acceptance <2x: {rec.get('device_growth_lt_2x')})")
    if "device_tail_flat" in rec:
        print(f"latency tail: worst p99/p50 {rec['device_p99_p50_max']}x, "
              f"{rec['device_warm_compiles']} jit rebuilds after warmup "
              f"(acceptance p99/p50<=5x & 0 rebuilds: "
              f"{rec['device_tail_flat']})")


def render_nary_stream(path):
    """Render a BENCH_nary_stream.json multi-relation-maintenance record."""
    rec = json.load(open(path))
    print(f"batch={rec['batch_size']} updates/epoch, {rec['epochs']} warm "
          f"epochs (median); all_exact={rec.get('all_exact')}\n")
    print("| |E| | |tri| | edge-plan warm ms | tri-plan warm ms | "
          "tri/edge | edge tail | tri tail | exact |")
    print("|" + "---|" * 8)
    for ne, r in sorted(rec.get("scales", {}).items(),
                        key=lambda kv: int(kv[0])):
        print(f"| {r['edges']:,} | {r['tri_tuples']:,} "
              f"| {r['edge_plan_warm_ms']} | {r['tri_plan_warm_ms']} "
              f"| {r['tri_over_edge']}x "
              f"| {_tail_cell(r.get('edge_plan_latency'))} "
              f"| {_tail_cell(r.get('tri_plan_latency'))} "
              f"| {r['exact']} |")
    if "tail_flat" in rec:
        print(f"\nlatency tail: worst p99/p50 {rec['p99_p50_max']}x, "
              f"{rec['warm_compiles']} jit rebuilds after warmup "
              f"(acceptance p99/p50<=5x & 0 rebuilds: {rec['tail_flat']})")


def render_multi_query(path):
    """Render a BENCH_multi_query.json shared-session record."""
    rec = json.load(open(path))
    print(f"{rec['epochs']} epochs x {rec['batch_size']} updates, "
          f"B'={rec['bprime']}\n")
    print("| N queries | shared epochs/s | independent epochs/s | speedup "
          "| commits (shared/indep) | exact |")
    print("|" + "---|" * 6)
    for n, r in sorted(rec.get("configs", {}).items()):
        print(f"| {n} | {r['shared_warm_epochs_per_s']} "
              f"| {r['independent_warm_epochs_per_s']} | {r['speedup']}x "
              f"| {r['shared_commits']} / {r['independent_commits']} "
              f"| {r['exact']} |")


def render_composite_sweep(path):
    """Render a BENCH_composite_sweep.json kernel-crossover record."""
    rec = json.load(open(path))
    print(f"backend: {rec.get('backend', '?')}  "
          f"interpret: {rec.get('interpret_mode', '?')}  "
          f"index n={rec.get('index_entries'):,}\n")
    print("| family | layout | crossover B' | jnp @max B | kernel @max B |")
    print("|" + "---|" * 5)
    for fam in ("member", "rank", "fold"):
        for nk, r in sorted(rec.get(fam, {}).items()):
            last = r["curve"][-1]
            bp = r.get("crossover_batch", r.get("crossover_delta"))
            hi = r.get("hi_dtype")
            print(f"| {fam} | {nk}{f' ({hi} hi)' if hi else ''} "
                  f"| {bp if bp is not None else 'never (this host)'} "
                  f"| {last['jnp_qps']:.0f} q/s "
                  f"| {last['kernel_qps']:.0f} q/s |")


def render_serve_load(path):
    """Render a BENCH_serve_load.json concurrent-serving record."""
    rec = json.load(open(path))
    seq = rec["sequential"]
    print(f"{rec['epochs_per_tenant']} batches/tenant x "
          f"{rec['batch_size']} updates, coalesce<={rec['coalesce']}; "
          f"sequential baseline {seq['batches_per_s']} batches/s\n")
    print("| tenants | batches/s | vs sequential | coalesce | "
          "apply p50 ms | p99/p50 | serve compiles | exact |")
    print("|" + "---|" * 8)
    for n, r in sorted(rec.get("pool", {}).items(),
                       key=lambda kv: int(kv[0])):
        sp = r["batches_per_s"] / max(seq["batches_per_s"], 1e-9)
        print(f"| {n} | {r['batches_per_s']} | {sp:.2f}x "
              f"| {r['coalesce_ratio']}x | {r['latency_ms']['p50']} "
              f"| {r['p99_p50_ratio']}x | {r['serve_compiles']} "
              f"| {r['final_exact_vs_sequential']} |")
    print(f"\nspeedup at N=4: {rec['speedup_n4']}x (acceptance >=2x: "
          f"{rec['speedup_n4_ge_2x']}); tail: worst p99/p50 "
          f"{rec['p99_p50_max']}x, {rec['serve_compiles_total']} serving "
          f"compiles (flat: {rec['tail_flat']})")


if __name__ == "__main__":
    for p in sys.argv[1:]:
        print(f"\n### {p}\n")
        if "BENCH_intersect" in p:
            render_intersect(p)
        elif "BENCH_delta_stream" in p:
            render_delta_stream(p)
        elif "BENCH_multi_query" in p:
            render_multi_query(p)
        elif "BENCH_nary_stream" in p:
            render_nary_stream(p)
        elif "BENCH_epoch_latency" in p:
            render_epoch_latency(p)
        elif "BENCH_serve_load" in p:
            render_serve_load(p)
        elif "BENCH_composite_sweep" in p:
            render_composite_sweep(p)
        else:
            render(p)
