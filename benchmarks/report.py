"""Render dry-run JSONL records into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.report \
        benchmarks/results/dryrun_single.jsonl
"""
import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def ms(s):
    return f"{s * 1e3:.2f}"


PEAK, HBM, ICI = 197e12, 819e9, 50e9


def terms(r):
    """(Re)derive roofline terms from the recorded raw fields, so older
    records get the structural memory-term definition uniformly."""
    pd = r["per_device"]
    live = (pd["argument_bytes"] or 0) + (pd["temp_bytes"] or 0)
    compute_s = r["hlo_flops_per_device"] / PEAK
    memory_s = 2.0 * live / HBM
    nofusion_s = r["hlo_bytes_per_device"] / HBM
    coll_s = r["collectives"]["total_wire_bytes"] / ICI
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, coll_s)
    useful = r["roofline"]["useful_flops_ratio"]
    # roofline fraction: useful-compute time / bound time
    model_s = r["roofline"]["model_flops_total"] / r["chips"] / PEAK
    frac = model_s / bound if bound > 0 else 0.0
    return compute_s, memory_s, nofusion_s, coll_s, dom, useful, frac


def render(path):
    recs = [json.loads(l) for l in open(path)]
    print("| arch | shape | mesh | args GiB | temp GiB | compute ms | "
          "memory ms | collective ms | dominant | useful | roofline frac |")
    print("|" + "---|" * 11)
    for r in recs:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"SKIPPED ({r['skip_reason'][:48]}…) "
                  f"| | | | | | | |")
            continue
        if r["status"] == "error":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"ERROR: {r['error'][:60]} | | | | | | | |")
            continue
        pd = r["per_device"]
        c, m, nf, co, dom, useful, frac = terms(r)
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt_bytes(pd['argument_bytes'])} "
              f"| {fmt_bytes(pd['temp_bytes'])} "
              f"| {ms(c)} | {ms(m)} | {ms(co)} | {dom} "
              f"| {useful:.2f} | {frac:.3f} |")


if __name__ == "__main__":
    for p in sys.argv[1:]:
        print(f"\n### {p}\n")
        render(p)
