"""Concurrent serving load: N tenants on one pool -> BENCH_serve_load.json.

The serving subsystem's claim (DESIGN.md §9): multiplexing N tenant
sessions onto one mesh through a :class:`repro.serve.SessionPool` beats
serving them one-at-a-time, because (a) the prep thread overlaps batch
k+1's host pack with batch k's device epoch, (b) adaptive coalescing folds
queued batches into shared device epochs (signed-weight netting keeps the
result exact), and (c) every tenant shares ONE jit cache — admission after
the first tenant compiles nothing.

Setup: every tenant gets its own graph and its own pre-generated CLEAN
net-balanced update stream (``data.synthetic.clean_update_batches``:
sign-consistent batches make coalescing exact, and a pinned live count
keeps the base region inside its pow2 rung so the zero-compile serving
budget holds for the whole run).  The sequential baseline drives one
prewarmed session per tenant, one ``session.update`` per batch,
back-to-back on the caller's thread.  The pool runs N client threads
submitting the same batches through ``SessionPool.submit``.

Gates (ISSUE 8):

- ``speedup_n4 >= 2.0`` — aggregate batches/s at N=4 vs the sequential
  baseline's per-tenant rate;
- ``tail_flat`` — zero serving-path compile events at EVERY N (admission
  prewarm covers the whole stream) and apply-latency p99/p50 <= 8x.

Run via ``python -m benchmarks.run --only serve_load`` (or directly).
"""
import json
import os
import time

import numpy as np

from benchmarks.common import row

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_serve_load.json")

TENANTS = [1, 2, 4, 8]
EPOCHS = 24  # batches per tenant
BATCH = 128  # updates per batch
COALESCE = 8
UPDATE_BATCH = COALESCE * BATCH  # a full coalesce group fits the probe
NV, NE = 1 << 9, 2_000


def _graph(i: int):
    from repro.data.synthetic import uniform_graph
    return uniform_graph(NV, NE, seed=i)


def _batches(i: int, edges):
    """Pre-generate tenant i's clean net-balanced stream."""
    from repro.data.synthetic import clean_update_batches
    return clean_update_batches(edges, NV, BATCH, EPOCHS, seed=100 + i)


def _sequential(graphs, batches):
    """Baseline: each tenant served alone, one update per batch, no pool."""
    from repro.api import GraphSession
    from repro.core import compilestats
    sessions = []
    for g in graphs:
        s = GraphSession(g, local=True, update_batch=UPDATE_BATCH)
        s.register("triangle")
        s.prewarm(horizon=EPOCHS * BATCH)
        sessions.append(s)
    snap = compilestats.snapshot()
    lat = []
    t0 = time.perf_counter()
    for s, per_tenant in zip(sessions, batches):
        for upd, w in per_tenant:
            t1 = time.perf_counter()
            s.update(upd, w)
            lat.append((time.perf_counter() - t1) * 1e3)
    wall = time.perf_counter() - t0
    total = sum(len(b) for b in batches)
    return {
        "batches": total,
        "epochs": total,  # one device epoch per batch, by construction
        "wall_s": round(wall, 3),
        "batches_per_s": round(total / wall, 2),
        "latency_ms": {k: round(float(np.percentile(lat, q)), 3)
                       for k, q in (("p50", 50), ("p95", 95), ("p99", 99))},
        "serve_compiles": compilestats.since(snap),
    }, [s.edges for s in sessions]


def _pooled(n, graphs, batches):
    """N client threads submitting through one SessionPool."""
    import threading

    from repro.serve import SessionPool
    pool = SessionPool(local=True, update_batch=UPDATE_BATCH,
                       horizon=EPOCHS * BATCH)
    handles = [pool.admit(f"t{i}", graphs[i], queries=("triangle",),
                          coalesce=COALESCE, max_queue=EPOCHS)
               for i in range(n)]

    def client(i):
        tickets = [handles[i].submit(upd, w) for upd, w in batches[i]]
        tickets[-1].result(timeout=600)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(n)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    pool.drain(timeout=600)
    wall = time.perf_counter() - t0
    agg = pool.stats().aggregate()
    finals = [h.session.edges for h in handles]
    pool.close()
    lat = agg["latency_ms"]
    return {
        "batches": agg["retired"],
        "epochs": agg["epochs"],
        "coalesce_ratio": round(agg["retired"] / max(agg["epochs"], 1), 2),
        "wall_s": round(wall, 3),
        "batches_per_s": round(agg["retired"] / wall, 2),
        "latency_ms": {k: round(lat[k], 3) for k in ("p50", "p95", "p99")},
        "p99_p50_ratio": round(lat["p99_p50_ratio"], 2),
        "serve_compiles": agg["serve_compiles"],
    }, finals


def main():
    nmax = max(TENANTS)
    graphs = [_graph(i) for i in range(nmax)]
    batches = [_batches(i, graphs[i]) for i in range(nmax)]

    # sequential baseline over ONE tenant's stream (the N=1 reference rate)
    seq, seq_finals = _sequential(graphs[:1], batches[:1])
    rec = {"bench": "serve_load", "epochs_per_tenant": EPOCHS,
           "batch_size": BATCH, "coalesce": COALESCE,
           "update_batch": UPDATE_BATCH, "sequential": seq, "pool": {}}
    row("serve_load", "sequential_n1", 1.0 / max(seq["batches_per_s"], 1e-9),
        f"{seq['batches_per_s']} batches/s p50={seq['latency_ms']['p50']}ms")

    exact = True
    for n in TENANTS:
        pooled, finals = _pooled(n, graphs, batches)
        # pooled tenant 0 must land on the sequential baseline's exact
        # final state — coalescing is netting, not approximation
        exact = exact and bool(np.array_equal(finals[0], seq_finals[0]))
        pooled["final_exact_vs_sequential"] = bool(
            np.array_equal(finals[0], seq_finals[0]))
        rec["pool"][str(n)] = pooled
        row("serve_load", f"pool_n{n}",
            1.0 / max(pooled["batches_per_s"], 1e-9),
            f"{pooled['batches_per_s']} batches/s "
            f"coalesce={pooled['coalesce_ratio']}x "
            f"serve_compiles={pooled['serve_compiles']}")

    speedup = rec["pool"]["4"]["batches_per_s"] / \
        max(seq["batches_per_s"], 1e-9)
    rec["speedup_n4"] = round(speedup, 2)
    rec["speedup_n4_ge_2x"] = bool(speedup >= 2.0)
    worst_tail = max(rec["pool"][str(n)]["p99_p50_ratio"] for n in TENANTS)
    total_compiles = sum(rec["pool"][str(n)]["serve_compiles"]
                         for n in TENANTS)
    rec["p99_p50_max"] = worst_tail
    rec["serve_compiles_total"] = total_compiles
    rec["tail_flat"] = bool(worst_tail <= 8.0 and total_compiles == 0)
    rec["all_exact"] = bool(exact)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(rec, f, indent=2)
    row("serve_load", "speedup_n4", 0.0,
        f"{rec['speedup_n4']}x (>=2x: {rec['speedup_n4_ge_2x']})")
    row("serve_load", "tail_flat", 0.0,
        f"p99/p50<={worst_tail}x serve_compiles={total_compiles} "
        f"(flat: {rec['tail_flat']}) exact={rec['all_exact']}")
    row("serve_load", "json", 0.0, OUT_PATH)


if __name__ == "__main__":
    main()
