"""Fig 6: batch-size (B') sensitivity — runtime vs peak queued memory.

The paper's trade-off: small B' starves parallelism/raises round counts;
large B' raises the bounded queue memory.  We sweep B' and report runtime,
rounds, and the exact peak queued-tuple bound m*B (Lemma 3.1)."""
import numpy as np

from benchmarks.common import row, timeit
from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.csr import Graph
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def main(scale=11, edge_factor=8):
    g = Graph.from_edges(rmat_graph(scale, edge_factor, 2)).degree_relabel()
    q = Q.triangle(symmetric=True)
    plan = make_plan(q)
    rels = {Q.EDGE: g.edges}
    idx = build_indices(plan, rels)
    seed = seed_tuples_for(plan, rels)
    base_count = None
    for bp in (64, 256, 1024, 4096, 16384):
        cfg = BigJoinConfig(batch=bp, seed_chunk=bp, mode="count")
        t, res = timeit(
            lambda cfg=cfg: run_bigjoin(plan, idx, seed, cfg=cfg), repeat=1)
        if base_count is None:
            base_count = res.count
        assert res.count == base_count
        queue_bound_tuples = (q.num_attrs - 2) * 2 * bp + bp
        row("fig6_batch_size", f"bprime_{bp}", t,
            f"rounds={res.steps};queued_bound={queue_bound_tuples};"
            f"count={res.count}")


if __name__ == "__main__":
    main()
