"""Tables 2 & 3: worst-case-optimal vs edge-at-a-time.

EmptyHeaded/Arabesque are not runnable here; the *algorithmic* comparison
is: BiGJoin vs the binary-join (edge-at-a-time) baseline on runtime, index
time, and intermediate results considered — the quantity Table 3 shows
explains Arabesque's 10-20x gap (30x more candidate prefixes)."""
import time

import numpy as np

from benchmarks.common import row, timeit
from repro.core import query as Q
from repro.core.bigjoin import (BigJoinConfig, build_indices, run_bigjoin,
                                seed_tuples_for)
from repro.core.csr import Graph
from repro.core.generic_join import (IntermediateBlowup, WorkCounters,
                                     binary_join, generic_join)
from repro.core.plan import make_plan
from repro.data.synthetic import rmat_graph


def main(scale=11, edge_factor=8):
    g = Graph.from_edges(rmat_graph(scale, edge_factor, 1)).degree_relabel()
    rels = {Q.EDGE: g.edges}
    for qname in ("triangle", "4-clique", "diamond"):
        sym = qname in ("triangle", "4-clique")
        q = Q.query_by_name(qname, symmetric=sym)
        plan = make_plan(q)

        t0 = time.time()
        idx = build_indices(plan, rels)
        t_index = time.time() - t0
        cfg = BigJoinConfig(batch=8192, seed_chunk=8192, mode="count")
        seed = seed_tuples_for(plan, rels)
        t_big, res = timeit(
            lambda: run_bigjoin(plan, idx, seed, cfg=cfg), repeat=1)
        row("tab2_3_baselines", f"bigjoin_{qname}", t_big,
            f"count={res.count};index_s={t_index:.2f};"
            f"intermediates={res.proposals}")

        try:
            t0 = time.time()
            _, cnt, peak = binary_join(q, rels,
                                       max_intermediate=30_000_000)
            t_bin = time.time() - t0
            assert cnt == res.count
            row("tab2_3_baselines", f"edge_at_a_time_{qname}", t_bin,
                f"count={cnt};intermediates={peak};"
                f"blowup_vs_wco={peak / max(res.proposals, 1):.1f}x")
        except IntermediateBlowup as e:
            row("tab2_3_baselines", f"edge_at_a_time_{qname}", 0,
                f"FAILED:{e}")


if __name__ == "__main__":
    main()
