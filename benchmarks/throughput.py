"""Table 4 / Fig 5a analogue: Delta-BiGJoin update-stream throughput for
triangle / 4-clique / diamond monitoring (input vs output change rates)."""
import time

import numpy as np

from benchmarks.common import row
from repro.core import query as Q
from repro.core.bigjoin import BigJoinConfig
from repro.core.csr import Graph
from repro.core.delta import DeltaBigJoin
from repro.data.synthetic import rmat_graph


def main(scale=11, edge_factor=8, batches=3, batch_size=1000):
    g = Graph.from_edges(rmat_graph(scale, edge_factor, 4))
    n0 = g.num_edges - batches * batch_size
    for qname in ("triangle", "diamond", "4-clique"):
        q = Q.PAPER_QUERIES[qname]()
        eng = DeltaBigJoin(q, g.edges[:n0], cfg=BigJoinConfig(
            batch=8192, seed_chunk=8192, mode="collect",
            out_capacity=1 << 22))
        t_tot = upd = outs = 0
        for i in range(batches):
            lo = n0 + i * batch_size
            t0 = time.time()
            res = eng.apply(g.edges[lo:lo + batch_size])
            t_tot += time.time() - t0
            upd += batch_size
            outs += 0 if res.weights is None else int(
                np.abs(res.weights).sum())
        row("tab4_throughput", f"delta_{qname}", t_tot / batches,
            f"updates_per_s={upd / t_tot:,.0f};"
            f"output_changes_per_s={outs / t_tot:,.0f}")


if __name__ == "__main__":
    main()
