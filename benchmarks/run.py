"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig4,...]``
prints ``table,name,us_per_call,derived`` CSV rows.
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: fig4,tab2_3,fig5,fig6,tab5,tab4,"
                    "intersect,delta_stream,multi_query,epoch_latency,"
                    "nary_stream,serve_load,composite_sweep")
    args = ap.parse_args()

    from benchmarks import (baseline_compare, batch_size, composite_sweep,
                            cost_table, delta_stream, epoch_latency,
                            intersect_bench, multi_query, nary_stream,
                            optimizations, scaling, serve_load, throughput)
    table = {
        "fig4": cost_table.main,
        "tab2_3": baseline_compare.main,
        "fig5": scaling.main,
        "fig6": batch_size.main,
        "tab5": optimizations.main,
        "tab4": throughput.main,
        "intersect": intersect_bench.main,  # -> BENCH_intersect.json
        "delta_stream": delta_stream.main,  # -> BENCH_delta_stream.json
        "multi_query": multi_query.main,  # -> BENCH_multi_query.json
        "epoch_latency": epoch_latency.main,  # -> BENCH_epoch_latency.json
        "nary_stream": nary_stream.main,  # -> BENCH_nary_stream.json
        "serve_load": serve_load.main,  # -> BENCH_serve_load.json
        "composite_sweep": composite_sweep.main,
        # ^ -> BENCH_composite_sweep.json
    }
    picks = list(table) if args.only == "all" else args.only.split(",")
    print("table,name,us_per_call,derived")
    failures = 0
    for name in picks:
        t0 = time.time()
        try:
            table[name]()
        except Exception:
            traceback.print_exc()
            failures += 1
            print(f"{name},FAILED,0,", flush=True)
        print(f"# {name} finished in {time.time() - t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark groups failed")


if __name__ == '__main__':
    main()
